"""PumArray + Device: the ndarray-like operator frontend over the engine.

``PumArray`` is the one caller-visible value type: it wraps whichever
representation the engine produced (an eager ndarray, a pending
``LazyArray`` of the fused graph, or raw packed-bitmap words) behind
operator overloading, and materializes on demand (``to_numpy()`` /
``np.asarray``). ``Device`` owns the engine an array computes on; used as
a context manager it scopes the default device for :func:`asarray` and
auto-flushes pending work on exit.

>>> import numpy as np
>>> import repro.pum as pum
>>> with pum.device(width=8) as dev:
...     x = dev.asarray(np.array([3, 5, 250], np.uint64))
...     y = (x + 6) * x                  # records into the fused graph
>>> y.to_numpy()                         # flushed on scope exit
array([27, 55,  0], dtype=uint64)
>>> q, r = divmod(y, np.array([4, 7, 9], np.uint64))
>>> np.asarray(q), np.asarray(r)         # one restoring-division pass
(array([6, 7, 0], dtype=uint64), array([3, 6, 0], dtype=uint64))

Plane-wise operators (``&``/``|``/``^``) on out-of-width operands route
through the engine's raw packed-bitmap path (bit-exact on full uint64
words); arithmetic computes modulo ``2**width`` and rejects out-of-width
operands loudly in fused mode — exactly the :class:`PulsarEngine`
contract, now behind one type.
"""

from __future__ import annotations

import contextlib

import numpy as np

from repro.core.engine import LazyArray
from repro.pum.config import EngineConfig

# Innermost active `with device(...)` last; module default built lazily.
_ACTIVE: list["Device"] = []
_DEFAULT: "Device | None" = None


class Device:
    """One PuM compute device: an engine plus its configuration.

    Construction goes through :class:`EngineConfig` (keyword overrides
    accepted); the eager dataplane and fused evaluators are resolved via
    the ``repro.backends`` registry. As a context manager the device
    becomes the scoped default for :func:`asarray` and flushes any
    pending fused graph on exit.
    """

    def __init__(self, config: EngineConfig | None = None, *,
                 _engine=None, **overrides):
        if config is None:
            config = EngineConfig(**overrides)
        elif overrides:
            config = config.replace(**overrides)
        # The sim backend is per-op by construction (the chip model has no
        # word dataplane to fuse over).
        if config.backend == "sim" and config.fuse:
            config = config.replace(fuse=False)
        # Likewise when NO registered fused evaluator supports this
        # width/layout pair (a pinned fused_backend that covers it takes
        # precedence): fall back to per-op eager execution instead of
        # refusing to build — EngineConfig-valid widths up to 64 always
        # yield a working device.
        if config.fuse and config.fused_backend is None:
            from repro.backends import select_backend
            try:
                select_backend(require="fused", width=config.width,
                               layout=config.resolved_layout())
            except LookupError:
                config = config.replace(fuse=False)
        self.config = config
        if _engine is None:
            from repro.core.engine import PulsarEngine
            _engine = PulsarEngine(
                mfr=config.mfr, width=config.width,
                row_bits=config.row_bits, banks=config.banks,
                backend=config.backend, success_db=config.success_db,
                use_pulsar=config.use_pulsar, chained=config.chained,
                controller=config.controller, seed=config.seed,
                fuse=config.fuse, flush_threshold=config.flush_threshold,
                flush_memory_bytes=config.flush_memory_bytes,
                donate_leaves=config.donate_leaves, layout=config.layout,
                leaf_cache_bytes=config.leaf_cache_bytes,
                fused_backend=config.fused_backend,
                ref_postponing=config.ref_postponing,
                reliability=config.reliability,
                cmd_buffer_lookahead=config.cmd_buffer_lookahead)
        self.engine = _engine
        self._scalars: dict[tuple, np.ndarray] = {}

    # -- array construction / lifecycle -------------------------------- #

    def asarray(self, x) -> "PumArray":
        """Wrap ``x`` as a :class:`PumArray` on this device (no compute,
        no charge — arrays enter the dataplane when an op consumes them).
        """
        if isinstance(x, PumArray):
            return x if x.device is self else PumArray(self, x.to_numpy())
        return PumArray(self, np.asarray(x, np.uint64))

    def flush(self) -> None:
        """Materialize every pending fused op graph — all client
        contexts, parked retries, and in-flight async flushes (no-op when
        eager or empty; never touches the cost plane)."""
        self.engine.flush_all()

    def flush_async(self):
        """Compile + dispatch the calling context's pending graph off
        this thread (double-buffered: the caller stages the next flush
        while the worker dispatches the current one). Returns a
        :class:`~repro.core.engine.FlushHandle`; ``result()`` waits and
        re-raises a failed dispatch after parking the graph for retry,
        exactly like a failed synchronous flush."""
        return self.engine.flush_async()

    def capture(self, fn, name: str | None = None):
        """Capture ``fn(*PumArrays) -> PumArray(s)`` as a
        :class:`~repro.pum.capture.CapturedProgram`: first call per input
        shape records + compiles; later calls replay the compiled pipeline
        with zero re-recording (cost charges replay identically)."""
        from repro.pum.capture import CapturedProgram
        return CapturedProgram(self, fn, name=name)

    def client(self, name: str):
        """Scope ops to a named client context (``with dev.client("a"):``)
        — its own recording graph and stats shard, so N logical clients
        share the device without interleaving their programs."""
        return self.engine.client(name)

    def close(self) -> None:
        """Shut the async flush worker down (waits for in-flight
        dispatches); safe to call repeatedly, recreated lazily on the
        next ``flush_async``."""
        self.engine.close()

    def __enter__(self) -> "Device":
        _ACTIVE.append(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        _ACTIVE.remove(self)
        if exc_type is None:
            self.flush()
        self.close()

    # -- cost plane ----------------------------------------------------- #

    @property
    def stats(self):
        """Accumulated :class:`~repro.core.engine.EngineStats` charges."""
        return self.engine.stats

    @property
    def counters(self):
        """The engine's telemetry :class:`~repro.telemetry.CounterBank`
        (flush/pipeline-cache/auto-flush counters — populated only while
        a tracer is attached, e.g. inside :func:`profile`; the
        ``reliability.*`` counters are recorded whenever the reliability
        plane is active)."""
        return self.engine.counters

    @property
    def reliability(self):
        """The engine's :class:`~repro.reliability.ReliabilityPlane`
        (None unless configured or :meth:`calibrate`-attached)."""
        return self.engine.reliability

    def calibrate(self, *, inject: bool = False, attach: bool = True,
                  n_subarrays: int = 4, n_columns: int = 256,
                  n_patterns: int = 8, configs=None,
                  process_variation: float | None = None,
                  seed: int | None = None, save=None, **policy):
        """Profile this device's simulated chip into a
        :class:`~repro.reliability.ReliabilityMap` and (by default) attach
        it: subsequent ops plan their fig-11 replication factor from the
        calibrated per-bank/per-subarray success rates and placement
        steers onto strong banks. With ``inject=True`` the flush-time
        fault-injection + replication-vote/retry loop also turns on
        (requires a fused device). Extra keyword ``policy`` fields
        (``votes``, ``max_attempts``, ``min_margin``, ``target_success``,
        ``steer``, ``flip_scale``, reliability ``seed``) go to the
        :class:`~repro.reliability.ReliabilityConfig`.

        Calibration is seeded from the device config (same device config
        => bit-identical map in any process); ``save=`` persists the map
        as ``.npz`` for reuse via
        ``ReliabilityConfig(map="path.npz")``. The default profile sizes
        are test-scale — production calibration passes larger
        ``n_subarrays``/``n_columns``/``n_patterns``. Returns the map.
        """
        from repro.reliability import (ReliabilityConfig, ReliabilityPlane,
                                       calibrate)
        cfg = self.config
        rmap = calibrate(
            cfg.mfr, banks=cfg.banks, n_subarrays=n_subarrays,
            n_columns=n_columns, n_patterns=n_patterns, configs=configs,
            seed=cfg.seed if seed is None else seed,
            process_variation=process_variation)
        if save is not None:
            rmap.save(save)
        if attach:
            if inject and not self.engine.fuse:
                raise ValueError(
                    "reliability fault injection hooks the fused dispatch "
                    "path; this device runs eager (fuse=False)")
            rcfg = ReliabilityConfig(map=rmap, inject=inject, **policy)
            self.engine.reliability = ReliabilityPlane(
                rcfg, mfr=cfg.mfr, counters=self.engine.counters)
            # Planning/placement caches were computed without the map.
            self.engine._best_cfg_cache.clear()
            self.engine._batch_cache.clear()
            self.config = cfg.replace(reliability=rcfg)
        return rmap

    def reset_stats(self) -> None:
        self.engine.reset_stats()

    def reset_counters(self) -> None:
        """Clear the telemetry :class:`~repro.telemetry.CounterBank` in
        place (the engine — and an attached reliability plane — keep
        writing into the same bank), starting a fresh measurement window
        without recreating the device. For overlapping windows on a live
        device prefer ``counters.snapshot()`` + ``counters.delta()``."""
        self.engine.counters.clear()

    # -- autotuning ----------------------------------------------------- #

    def autotune(self, profile=None, *, apply: bool = True,
                 cost_plane: bool = False, space=None, tuner=None,
                 online: bool = False, window_flushes: int = 16,
                 explore_every: int = 8, drift_threshold: float = 0.5,
                 save=None):
        """Tune this device's execution config from measured telemetry.

        ``profile`` is a :class:`~repro.autotune.WorkloadProfile` (or a
        counter window to extract one from); by default it is taken from
        the device's accumulated counters — run the workload under
        :func:`profile` first (engine counters populate only while a
        tracer is attached). The :class:`~repro.autotune.Tuner` searches
        the discrete config space and returns the frozen
        :class:`~repro.autotune.TunedPlan`; with ``apply=True`` (default)
        the plan's *execution* knobs — fused backend, plane layout,
        auto-flush bounds, crossbar lookahead — are applied live to this
        device. Execution knobs change only where/when programs run:
        outputs and ``EngineStats`` are bit-identical to the static
        config (pinned by tests/autotune). ``cost_plane=True``
        additionally applies the REF-postponing recommendation, which
        changes the *modeled* refresh schedule and therefore EngineStats
        — an explicit opt-in.

        ``online=True`` installs an
        :class:`~repro.autotune.OnlineAutotuner` on the engine: every
        ``window_flushes`` flushes it profiles the counter delta and
        re-tunes when the drift detector fires (exploit) or every
        ``explore_every`` windows (explore). ``save=`` persists the plan
        (``.json``/``.npz``, see ``TunedPlan.save``). Returns the plan
        (``None`` with ``online=True`` before the first window closes).
        """
        from repro.autotune import (OnlineAutotuner, Tuner,
                                    WorkloadProfile)
        if not self.engine.fuse:
            raise ValueError(
                "autotune targets the fused execution pipeline; this "
                "device runs eager (fuse=False)")
        if tuner is None:
            tuner = Tuner(space=space, drift_threshold=drift_threshold)
        if online:
            self.engine.autotuner = OnlineAutotuner(
                self, tuner=tuner, window_flushes=window_flushes,
                explore_every=explore_every,
                drift_threshold=drift_threshold)
            if profile is None:
                return None  # first window closes at flush granularity
        if profile is None:
            profile = WorkloadProfile.from_device(self)
        elif not isinstance(profile, WorkloadProfile):
            profile = WorkloadProfile.from_counters(
                profile, width=self.config.width,
                word_bits=self.config.resolved_layout().word_bits)
        plan = tuner.tune(profile, self.config)
        if apply:
            self._apply_plan(plan, cost_plane=cost_plane)
        if online and self.engine.autotuner is not None:
            self.engine.autotuner.plan = plan
        if save is not None:
            plan.save(save)
        return plan

    def _apply_plan(self, plan, *, cost_plane: bool = False,
                    flush: bool = True) -> None:
        """Reconfigure the live engine to a ``TunedPlan`` (the
        ``calibrate()`` idiom: mutate the engine, drop stale caches,
        replace ``self.config``). With ``flush=True`` pending graphs
        flush first so backend/layout flips never split a recorded
        program across lane formats; the online autotuner calls with
        ``flush=False`` from inside the flush path and the
        backend/layout switch is then deferred while graphs are
        pending."""
        cfg = plan.apply(self.config, cost_plane=cost_plane)
        # A fuse flip cannot be applied to a live engine (it would
        # rebuild the whole execution pipeline mid-stream); the
        # recommendation stays on the returned plan for the caller to
        # construct a new device from.
        if cfg.fuse != self.config.fuse:
            cfg = cfg.replace(fuse=self.config.fuse)
        eng = self.engine
        if flush:
            eng.flush_all()
        with eng._lock:
            eng.flush_threshold = cfg.flush_threshold
            eng.flush_memory_bytes = cfg.flush_memory_bytes
            eng.cmd_buffer_lookahead = cfg.cmd_buffer_lookahead
            pending = bool(eng._inflight) or any(
                g is not None and getattr(g, "ops", None)
                for g in eng._slots.values())
            if pending:
                cfg = cfg.replace(fused_backend=self.config.fused_backend,
                                  layout=self.config.layout)
            else:
                eng.fused_backend = cfg.fused_backend
                eng.layout = cfg.resolved_layout()
            if cost_plane and cfg.ref_postponing != eng.ref_postponing \
                    and cfg.controller == "auto":
                from repro.controller import MemoryController
                from repro.core.cost_model import CostModel as _EngineCost
                eng.controller = MemoryController(
                    n_banks=cfg.banks, postponing=cfg.ref_postponing,
                    lookahead=cfg.cmd_buffer_lookahead)
                eng.ref_postponing = cfg.ref_postponing
                eng.cost = _EngineCost(row_bits=cfg.row_bits,
                                       controller=eng.controller)
            # Planning/batch caches were computed under the old config.
            eng._best_cfg_cache.clear()
            eng._batch_cache.clear()
        self.config = cfg

    @property
    def latency_ms(self) -> float:
        return self.engine.latency_ms

    @property
    def width(self) -> int:
        return self.engine.width

    @property
    def layout(self):
        """The engine's :class:`~repro.kernels.plane_layout.PlaneLayout`
        (the lane word format fused programs compile against)."""
        return self.engine.layout

    def charge(self, kind: str, n_elems: int, width: int | None = None,
               n_planes: int | None = None) -> None:
        """Charge the cost plane for work the host performs on the PuM
        array's behalf (e.g. a popcount over raw 64-bit bitmap words that
        the dataplane computes host-side). Dataplane ops charge
        themselves — this is for explicitly modeled extra passes."""
        self.engine._charge(kind, n_elems, width=width, n_planes=n_planes)

    # -- op dispatch (PumArray operators land here) --------------------- #

    def _op(self, name: str, *operands):
        return getattr(self.engine, "_" + name)(*operands)

    def _broadcast_scalar(self, value, shape: tuple) -> np.ndarray:
        """One shared array per (scalar, shape): handing the engine the
        SAME object on every use lets the fused graph's id()-keyed leaf
        dedup hit, instead of snapshotting a fresh full-size leaf per op.
        Entries are O(1) read-only broadcast views (the engine copies at
        snapshot time anyway), so the bounded cache stays tiny."""
        key = (int(value), shape)
        arr = self._scalars.get(key)
        if arr is None:
            if len(self._scalars) >= 64:
                self._scalars.clear()
            arr = np.broadcast_to(np.uint64(value), shape)
            self._scalars[key] = arr
        return arr

    def __repr__(self) -> str:
        c = self.config
        mode = "fused" if c.fuse else "eager"
        return (f"Device({c.mfr}:{c.width}w:{c.banks}b, "
                f"backend={c.backend!r}, {mode})")


class PumArray:
    """ndarray-like handle for a value on a PuM device.

    Wraps eager ndarrays and pending fused-graph handles behind one type;
    operators record/execute through the owning device's engine and
    charge the cost plane exactly like the engine methods they replace.
    ``to_numpy()`` / ``np.asarray`` materialize (flushing the fused graph
    if pending); ``sum``/``reshape``/``astype`` materialize and return
    plain ndarrays.
    """

    __slots__ = ("_device", "_data")
    # Keep NumPy from consuming us element-wise: binary ops with ndarrays
    # return NotImplemented on the ndarray side and come back through our
    # reflected methods.
    __array_ufunc__ = None
    __array_priority__ = 1000

    def __init__(self, device: Device, data):
        self._device = device
        self._data = data

    # -- introspection -------------------------------------------------- #

    @property
    def device(self) -> Device:
        return self._device

    @property
    def shape(self) -> tuple:
        return self._data.shape

    @property
    def size(self) -> int:
        return self._data.size

    @property
    def ndim(self) -> int:
        return self._data.ndim

    @property
    def dtype(self):
        return np.dtype(np.uint64)

    def __len__(self) -> int:
        if not self.shape:
            raise TypeError("len() of unsized PumArray")
        return self.shape[0]

    def __getitem__(self, idx) -> "PumArray":
        """Basic (NumPy-style) indexing along the lane axes.

        Eager values slice to **views** (no copy, no charge — the lanes
        were already materialized); a pending fused-graph handle forces a
        materialize first (one flush), then slices: a slice is a host
        access pattern, not a dataplane op, so it cannot extend the
        recorded program. Integer indexing yields a 0-d PumArray (use
        ``int(x[i])`` / ``to_numpy()`` for a Python scalar)."""
        data = self._data
        if isinstance(data, LazyArray):
            data = data.materialize()
        out = data[idx]
        if not isinstance(out, np.ndarray):  # 0-d from integer indexing
            out = np.asarray(out, np.uint64)
        return PumArray(self._device, out)

    def __repr__(self) -> str:
        pending = getattr(self._data, "_value", self._data) is None
        state = "pending" if pending else "materialized"
        return f"PumArray(shape={self.shape}, {state}, on {self._device})"

    # -- materialization ------------------------------------------------ #

    def to_numpy(self) -> np.ndarray:
        """The value as a uint64 ndarray (flushes the fused graph if this
        handle is pending)."""
        return np.asarray(self._data, np.uint64)

    def __array__(self, dtype=None, copy=None):
        v = self.to_numpy()
        return v.astype(dtype) if dtype is not None else v

    def sum(self, *args, **kw):
        return self.to_numpy().sum(*args, **kw)

    def reshape(self, *shape, **kw) -> np.ndarray:
        return self.to_numpy().reshape(*shape, **kw)

    def astype(self, dtype, **kw) -> np.ndarray:
        return self.to_numpy().astype(dtype, **kw)

    # -- operator frontend ---------------------------------------------- #

    def _operand(self, other):
        """Unwrap/conform the second operand: same-device PumArrays pass
        their underlying handle through (extending the fused graph);
        foreign-device arrays materialize; scalars broadcast to this
        array's shape so the op stays fusable."""
        if isinstance(other, PumArray):
            return other._data if other._device is self._device \
                else other.to_numpy()
        arr = np.asarray(other, np.uint64)
        if arr.ndim == 0 and self.shape:
            arr = self._device._broadcast_scalar(arr[()], self.shape)
        return arr

    def _binop(self, name: str, other, reflect: bool = False):
        a, b = self._data, self._operand(other)
        if reflect:
            a, b = b, a
        return PumArray(self._device, self._device._op(name, a, b))

    def __and__(self, other):
        return self._binop("and", other)

    def __rand__(self, other):
        return self._binop("and", other, reflect=True)

    def __or__(self, other):
        return self._binop("or", other)

    def __ror__(self, other):
        return self._binop("or", other, reflect=True)

    def __xor__(self, other):
        return self._binop("xor", other)

    def __rxor__(self, other):
        return self._binop("xor", other, reflect=True)

    def __add__(self, other):
        return self._binop("add", other)

    def __radd__(self, other):
        return self._binop("add", other, reflect=True)

    def __sub__(self, other):
        return self._binop("sub", other)

    def __rsub__(self, other):
        return self._binop("sub", other, reflect=True)

    def __mul__(self, other):
        return self._binop("mul", other)

    def __rmul__(self, other):
        return self._binop("mul", other, reflect=True)

    def __floordiv__(self, other):
        return self._binop("div", other)

    def __rfloordiv__(self, other):
        return self._binop("div", other, reflect=True)

    def __mod__(self, other):
        return self._binop("mod", other)

    def __rmod__(self, other):
        return self._binop("mod", other, reflect=True)

    def __divmod__(self, other):
        """(quotient, remainder) sharing ONE restoring-division pass (the
        fused-ISA ``divmod`` tuple op; one cost-plane division charge)."""
        q, r = self._device._op("divmod", self._data,
                                self._operand(other))
        return PumArray(self._device, q), PumArray(self._device, r)

    def __rdivmod__(self, other):
        q, r = self._device._op("divmod", self._operand(other),
                                self._data)
        return PumArray(self._device, q), PumArray(self._device, r)

    def __lt__(self, other):
        """Unsigned ``self < other`` per lane -> 0/1 PumArray."""
        return self._binop("less_than", other)

    def __gt__(self, other):
        return self._binop("less_than", other, reflect=True)

    def _not(self, bit: "PumArray") -> "PumArray":
        ones = self._device._broadcast_scalar(1, bit.shape)
        return PumArray(self._device,
                        self._device._op("xor", bit._data, ones))

    def __le__(self, other):
        """``self <= other`` == NOT(other < self): one compare + one
        plane XOR (both charged — that is what the DRAM would run)."""
        return self._not(self.__gt__(other))

    def __ge__(self, other):
        return self._not(self.__lt__(other))

    def popcount(self, width: int | None = None) -> "PumArray":
        """Per-element set-bit count over ``width`` planes (device width
        by default)."""
        return PumArray(self._device,
                        self._device._op("popcount", self._data, width))

    def reduce_bits(self, kind: str, width: int | None = None
                    ) -> "PumArray":
        """Per-element AND/OR/XOR reduction across the element's bits."""
        return PumArray(self._device,
                        self._device._op("reduce_bits", self._data, kind,
                                         width))

    # -- ndarray comparison/truth semantics (values, not identity) ------ #

    def __eq__(self, other):
        return self.to_numpy() == np.asarray(other)

    def __ne__(self, other):
        return self.to_numpy() != np.asarray(other)

    __hash__ = None  # unhashable, like ndarray

    def __bool__(self):
        return bool(self.to_numpy())


# --------------------------------------------------------------------- #
# Module-level device scoping
# --------------------------------------------------------------------- #


def device(config: EngineConfig | None = None, **overrides) -> Device:
    """Build a :class:`Device` from an :class:`EngineConfig` (or keyword
    overrides of the defaults). Use as a context manager to scope it as
    the default device and auto-flush on exit::

        with pum.device(mfr="M", width=32, controller="auto") as dev:
            y = dev.asarray(x) + x2
    """
    return Device(config, **overrides)


def default_device() -> Device:
    """The innermost active ``with device(...)`` scope, else a process-wide
    default ``Device(EngineConfig())`` built on first use."""
    global _DEFAULT
    if _ACTIVE:
        return _ACTIVE[-1]
    if _DEFAULT is None:
        _DEFAULT = Device(EngineConfig())
    return _DEFAULT


def asarray(x, device: Device | None = None) -> PumArray:
    """Wrap ``x`` as a :class:`PumArray` on ``device`` (default: the
    scoped/default device)."""
    return (device or default_device()).asarray(x)


@contextlib.contextmanager
def profile(device: Device | None = None, path: str | None = None):
    """Trace one device's fused flushes for the duration of the block.

    Attaches a fresh :class:`~repro.telemetry.Tracer` to ``device`` (the
    scoped/default device when omitted), flushes any still-pending graph
    on exit so the trace is complete, then detaches. Yields the tracer;
    with ``path`` the Chrome trace-event JSON (plus the device's counters)
    is written there on exit — open it in Perfetto or ``chrome://tracing``.

        with pum.profile(path="trace.json") as tr:
            y = pum.asarray(x) + x2
        print(tr.span_names())   # flush.record ... flush.materialize

    Profiling is observational only: results, ``Device.stats`` and the
    scheduled command streams are bit-identical with or without it
    (tested in tests/telemetry)."""
    from repro.telemetry import Tracer

    dev = device if device is not None else default_device()
    tracer = Tracer()
    prev = dev.engine.tracer
    dev.engine.tracer = tracer
    try:
        yield tracer
    finally:
        try:
            dev.flush()  # complete the trace: pending graphs span-ify
        finally:
            dev.engine.tracer = prev
            if path is not None:
                tracer.export(path, counters=dev.engine.counters)


def as_device(obj) -> Device:
    """Coerce to a :class:`Device`: passes Devices through and wraps an
    existing ``PulsarEngine`` (compat for call sites that still construct
    engines directly)."""
    if isinstance(obj, Device):
        return obj
    from repro.core.engine import PulsarEngine
    if isinstance(obj, PulsarEngine):
        cfg = EngineConfig(
            mfr=obj.mfr, width=obj.width, row_bits=obj.row_bits,
            banks=obj.banks, backend=obj.backend, use_pulsar=obj.use_pulsar,
            chained=obj.chained, controller=obj.controller, seed=obj.seed,
            fuse=obj.fuse, flush_threshold=obj.flush_threshold,
            flush_memory_bytes=obj.flush_memory_bytes,
            donate_leaves=obj.donate_leaves, success_db=obj.db,
            leaf_cache_bytes=obj.leaf_cache_bytes,
            layout=obj.layout, fused_backend=obj.fused_backend,
            ref_postponing=obj.ref_postponing,
            reliability=(None if obj.reliability is None
                         else obj.reliability.config),
            cmd_buffer_lookahead=obj.cmd_buffer_lookahead)
        return Device(cfg, _engine=obj)
    raise TypeError(f"cannot interpret {type(obj).__name__} as a Device")
