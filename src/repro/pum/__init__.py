"""repro.pum — the public API of the PULSAR PuM compute stack.

Everything an application needs is here; nothing else in the repo is a
stable surface (``PulsarEngine``'s op methods survive only as a
deprecated compat shim). Three pieces:

* :class:`PumArray` — ndarray-like handle with operator overloading
  (``& | ^ + - * // % < > <= >=``, ``divmod()``, ``popcount()``,
  ``reduce_bits()``) unifying eager results, fused lazy handles and raw
  packed-bitmap words behind one type;
* :class:`Device` + :class:`EngineConfig` — configuration and lifecycle
  (``pum.device(...)`` as a context manager scopes the default device
  for ``pum.asarray`` and auto-flushes on exit);
* the backend registry (:func:`register_backend` and friends) — the
  sim-chip, word-domain-CPU and Pallas-TPU evaluators are selected by
  capability lookup; new backends register additively;
* telemetry (:func:`profile`, :class:`Tracer`, :class:`CounterBank`) —
  ``with pum.profile(path="trace.json"):`` traces fused flush phases to
  Chrome trace-event JSON and populates ``Device.counters``; zero
  overhead (and zero behavior change) when not profiling. See
  ``docs/observability.md``.
* reliability (:func:`calibrate`, :class:`ReliabilityMap`,
  :class:`ReliabilityConfig`) — calibrate a simulated chip into a
  per-bank/per-subarray/per-column map, then
  ``EngineConfig(reliability=...)`` (or ``Device.calibrate()``) turns on
  variation-aware replication planning, weak-column steering and —
  opt-in — fault injection with replication-vote correction and retry
  escalation. See ``docs/reliability.md``.
* concurrency (``Device.flush_async`` -> :class:`FlushHandle`,
  ``Device.capture`` -> :class:`CapturedProgram`, ``Device.client``) —
  N client contexts record into one device without interleaving, flushes
  compile/dispatch off the caller's thread, and steady-state programs
  replay a captured pipeline with zero re-recording. See the
  "Concurrent clients & async flush" section of
  ``docs/execution-pipeline.md``.
* autotuning (``Device.autotune`` -> :class:`TunedPlan`, :class:`Tuner`,
  :class:`WorkloadProfile`) — a :class:`WorkloadProfile` extracted from
  measured counters (``Device.reset_counters`` / ``CounterBank``
  snapshot deltas scope the window), a deterministic cost model, and an
  exhaustive search over backend/layout/flush-threshold/REF/lookahead
  knobs. Applied plans change only where/when programs run — outputs
  and ``EngineStats`` stay bit-identical. See ``docs/autotuning.md``.

See ``docs/api.md`` for the full surface, the Device lifecycle, the
backend registry contract, and the old-call -> new-call migration table.
"""

from repro.autotune import TunedPlan, Tuner, WorkloadProfile
from repro.backends import (BackendSpec, available_backends, get_backend,
                            register_backend, select_backend,
                            unregister_backend)
from repro.core.engine import EngineStats, FlushHandle
from repro.kernels.plane_layout import (LAYOUT32, LAYOUT64, PlaneLayout,
                                        get_layout)
from repro.pum.api import (Device, PumArray, as_device, asarray,
                           default_device, device, profile)
from repro.pum.capture import CapturedProgram
from repro.pum.config import EngineConfig
from repro.reliability import ReliabilityConfig, ReliabilityMap, calibrate
from repro.telemetry import CounterBank, Tracer

__all__ = [
    "BackendSpec",
    "CapturedProgram",
    "CounterBank",
    "Device",
    "EngineConfig",
    "EngineStats",
    "FlushHandle",
    "LAYOUT32",
    "LAYOUT64",
    "PlaneLayout",
    "PumArray",
    "ReliabilityConfig",
    "ReliabilityMap",
    "Tracer",
    "TunedPlan",
    "Tuner",
    "WorkloadProfile",
    "as_device",
    "asarray",
    "available_backends",
    "calibrate",
    "default_device",
    "device",
    "get_backend",
    "get_layout",
    "profile",
    "register_backend",
    "select_backend",
    "unregister_backend",
]
