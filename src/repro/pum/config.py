"""EngineConfig — the frozen configuration object behind ``pum.device``.

One immutable dataclass replaces ``PulsarEngine``'s keyword sprawl: every
knob a device needs is named, validated once, and carried by the
:class:`~repro.pum.Device` that owns the engine. ``dataclasses.replace``
derives variants (the idiom the benchmarks use for PULSAR-vs-FracDRAM
pairs).
"""

from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Complete configuration of one PuM device.

    Fields mirror the modeled hardware (``mfr``/``width``/``row_bits``/
    ``banks``), the cost plane (``use_pulsar``/``chained``/``controller``)
    and the execution pipeline (``backend``/``fuse``/auto-flush bounds/
    ``donate_leaves``). Unlike the legacy engine constructor, ``fuse``
    defaults to **True**: the fused lazy pipeline is the production path
    (bit-exact and stats-identical to eager — set ``fuse=False`` to force
    per-op eager execution).

    * ``backend`` — eager-dataplane name resolved through the
      ``repro.backends`` registry: ``"fast"`` (packed NumPy words) or
      ``"sim"`` (bit-exact chip model; implies ``fuse=False``), or any
      registered name with the ``"eager"`` capability.
    * ``controller`` — ``None`` (closed-form bank divide), ``"auto"``
      (build a ``MemoryController``), or a controller instance.
    * ``donate_leaves`` — donate leaf device buffers to the fused trace
      (``jax.jit(..., donate_argnums=...)``): XLA may reuse them for
      intermediates, cutting pipeline peak memory. Results are
      bit-identical either way.
    * ``success_db`` — optional ``SuccessRateDb`` override for the
      characterization data (tests/sensitivity sweeps).
    """

    mfr: str = "M"
    width: int = 32
    row_bits: int = 65536
    banks: int = 16
    backend: str = "fast"
    use_pulsar: bool = True
    chained: bool = False
    controller: Any = None
    seed: int = 0
    fuse: bool = True
    flush_threshold: int | None = 1024
    flush_memory_bytes: int | None = 1 << 30
    donate_leaves: bool = False
    success_db: Any = None

    def __post_init__(self):
        if not 1 <= self.width <= 64:
            raise ValueError(f"width must be in [1, 64], got {self.width}")
        if self.flush_threshold is not None and self.flush_threshold < 1:
            raise ValueError("flush_threshold must be >= 1 or None")

    def replace(self, **changes) -> "EngineConfig":
        """A copy with ``changes`` applied (``dataclasses.replace``)."""
        return dataclasses.replace(self, **changes)
