"""EngineConfig — the frozen configuration object behind ``pum.device``.

One immutable dataclass replaces ``PulsarEngine``'s keyword sprawl: every
knob a device needs is named, validated once, and carried by the
:class:`~repro.pum.Device` that owns the engine. ``dataclasses.replace``
derives variants (the idiom the benchmarks use for PULSAR-vs-FracDRAM
pairs).
"""

from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Complete configuration of one PuM device.

    Fields mirror the modeled hardware (``mfr``/``width``/``row_bits``/
    ``banks``), the cost plane (``use_pulsar``/``chained``/``controller``)
    and the execution pipeline (``backend``/``fuse``/auto-flush bounds/
    ``donate_leaves``). Unlike the legacy engine constructor, ``fuse``
    defaults to **True**: the fused lazy pipeline is the production path
    (bit-exact and stats-identical to eager — set ``fuse=False`` to force
    per-op eager execution).

    * ``backend`` — eager-dataplane name resolved through the
      ``repro.backends`` registry: ``"fast"`` (packed NumPy words) or
      ``"sim"`` (bit-exact chip model; implies ``fuse=False``), or any
      registered name with the ``"eager"`` capability.
    * ``layout`` — plane-layout word bits of the fused dataplane (32 or
      64, or a ``repro.kernels.plane_layout.PlaneLayout``). ``None``
      derives the narrowest canonical layout holding ``width`` (32-bit
      up to width 32, 64-bit above); pass 64 explicitly to run narrow
      values on 64-bit lanes (e.g. to keep raw uint64 bitmaps unsplit).
    * ``fused_backend`` — pin a registered fused evaluator by name (e.g.
      ``"shard-words"``, the multi-device word-axis pipeline); ``None``
      lets the capability lookup pick the best available one.
    * ``controller`` — ``None`` (closed-form bank divide), ``"auto"``
      (build a ``MemoryController``), or a controller instance.
    * ``ref_postponing`` — REF commands batched per rank lockout by the
      ``"auto"`` controller's refresher (1..8; JEDEC allows postponing up
      to 8): longer but rarer refresh windows, priced by ``batch_cost``.
    * ``cmd_buffer_lookahead`` — per-bank command-buffer depth of the
      concurrent-client crossbar (LiteDRAM's ``cmd_buffer_depth``): how
      many pending sequences each bank machine may hold when scheduling
      concurrent streams. Threaded into the ``"auto"`` controller (its
      ``schedule_concurrent`` default); purely an execution knob — the
      single-stream cost plane never consults it.
    * ``donate_leaves`` — donate leaf device buffers to the fused trace
      (``jax.jit(..., donate_argnums=...)``): XLA may reuse them for
      intermediates, cutting pipeline peak memory. Results are
      bit-identical either way.
    * ``leaf_cache_bytes`` — byte budget of the per-device leaf cache
      (staged wire snapshots keyed by buffer pointer + content
      fingerprint, re-served across flushes and capture replays; see
      docs/execution-pipeline.md "Flush-path memory traffic"). ``0`` or
      ``None`` disables the cache. Results and ``EngineStats`` are
      bit-identical either way.
    * ``success_db`` — optional ``SuccessRateDb`` override for the
      characterization data (tests/sensitivity sweeps).
    * ``reliability`` — ``None`` (default: every path unchanged), or a
      ``repro.reliability.ReliabilityConfig`` / calibrated
      ``ReliabilityMap`` (= config with defaults): the engine plans
      replication per op from the map, steers placement onto strong
      banks/subarrays, and — when the config sets ``inject=True`` — runs
      the flush-time fault-injection + replication-vote/retry loop
      (requires ``fuse=True``; see docs/reliability.md).
    """

    mfr: str = "M"
    width: int = 32
    row_bits: int = 65536
    banks: int = 16
    backend: str = "fast"
    use_pulsar: bool = True
    chained: bool = False
    controller: Any = None
    seed: int = 0
    fuse: bool = True
    flush_threshold: int | None = 1024
    flush_memory_bytes: int | None = 1 << 30
    donate_leaves: bool = False
    leaf_cache_bytes: int | None = 1 << 26
    success_db: Any = None
    layout: Any = None
    fused_backend: str | None = None
    ref_postponing: int = 1
    reliability: Any = None
    cmd_buffer_lookahead: int = 8

    def __post_init__(self):
        if not 1 <= self.width <= 64:
            raise ValueError(f"width must be in [1, 64], got {self.width}")
        if self.flush_threshold is not None and self.flush_threshold < 1:
            raise ValueError("flush_threshold must be >= 1 or None")
        if self.cmd_buffer_lookahead < 1:
            raise ValueError("cmd_buffer_lookahead must be >= 1 (each "
                             "bank machine holds at least one sequence)")
        if self.leaf_cache_bytes is not None and self.leaf_cache_bytes < 0:
            raise ValueError("leaf_cache_bytes must be >= 0 or None")
        if not 1 <= self.ref_postponing <= 8:
            raise ValueError("ref_postponing must be in [1, 8] (JEDEC "
                             "allows postponing up to 8 REFs)")
        if self.resolved_layout().word_bits < self.width:
            raise ValueError(
                f"width {self.width} does not fit the "
                f"{self.resolved_layout().word_bits}-bit plane layout")

    def resolved_layout(self):
        """The :class:`~repro.kernels.plane_layout.PlaneLayout` this
        config runs on (``layout`` resolved, or derived from ``width``)."""
        from repro.kernels.plane_layout import get_layout, layout_for_width
        if self.layout is None:
            return layout_for_width(self.width)
        return get_layout(self.layout)

    def replace(self, **changes) -> "EngineConfig":
        """A copy with ``changes`` applied (``dataclasses.replace``)."""
        return dataclasses.replace(self, **changes)
