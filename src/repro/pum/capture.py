"""Cross-call program capture: record once, replay with zero re-recording.

:meth:`repro.pum.Device.capture` wraps a function of PumArrays into a
:class:`CapturedProgram`. The first call records the function's ops into a
dedicated client context, normalizes the graph (CSE + dead-code pruning)
and compiles the fused pipeline exactly like a flush; every later call
with the same input shapes *replays* the compiled pipeline directly — no
graph recording, no normalization, no pipeline-cache probe — rebinding
only the input leaves (constants captured from the closure keep their
staged wire buffers). This is the ``pum.jit`` analogue of PULSAR's
chained staging: the command-program structure is paid once, steady-state
calls pay only the data movement.

The cost plane stays invariant: the charge recipe logged during recording
is replayed on every call, so ``Device.stats`` advances exactly as if the
function had been re-recorded (bit-identical totals, tested).

Contract:

  * the device must be fused (``fuse=True``); eager devices raise;
  * inputs are uint64 arrays (or coercible); outputs are the function's
    PumArray results, returned as materialized uint64 ndarrays;
  * value-mode only — a function whose ops route through the raw
    packed-bitmap path raises at capture time;
  * reliability *fault injection* is unsupported (the vote/retry loop
    re-plans per flush); calibrated planning without injection is fine;
  * a new input *shape* tuple re-records (one cache entry per shape);
    mutating a captured closure constant after recording is undefined —
    constants are snapshotted once.

>>> import numpy as np
>>> import repro.pum as pum
>>> dev = pum.device(width=16, fuse=True)
>>> prog = dev.capture(lambda x, y: (x + y) * x)
>>> a = np.arange(8, dtype=np.uint64); b = a[::-1].copy()
>>> prog(a, b)                       # first call: records + compiles
array([ 0,  7, 14, 21, 28, 35, 42, 49], dtype=uint64)
>>> prog(b, a)                       # replay: same shapes, new data
array([49, 42, 35, 28, 21, 14,  7,  0], dtype=uint64)
>>> prog.n_records, prog.n_replays
(1, 1)
"""

from __future__ import annotations

import dataclasses
import threading

import numpy as np

from repro.core.engine import LazyArray, _stage_wire
from repro.kernels.fused_program import (FusedOp, FusedProgram, get_pipeline,
                                         optimize_program)


@dataclasses.dataclass
class _Recording:
    """One compiled shape-specialization of a captured function."""
    pipeline: object                 # compiled fused pipeline
    plan: list                       # per pipeline input: ("in", i) |
    #                                  ("const", staged wire ndarray)
    out_slots: list[int]             # pipeline output position per result
    out_shapes: list[tuple]
    single: bool                     # fn returned one array (not a tuple)
    n: int                           # dataplane lane count
    pad: int
    width: int
    layout: object
    recipe: tuple                    # charge log to replay per call
    fp_idx: object = None            # 257-sample fingerprint index (cache)


class CaptureHandle:
    """Future-like handle for :meth:`CapturedProgram.call_async`."""

    __slots__ = ("_future", "_value")

    def __init__(self, future=None, value=None):
        self._future = future
        self._value = value

    def done(self) -> bool:
        return self._future is None or self._future.done()

    def result(self, timeout: float | None = None):
        """The captured function's outputs (uint64 ndarrays)."""
        if self._future is not None:
            return self._future.result(timeout)
        return self._value

    def __repr__(self) -> str:
        state = "done" if self.done() else "in-flight"
        return f"CaptureHandle({state})"


class CapturedProgram:
    """A function of PumArrays, compiled once per input-shape signature."""

    def __init__(self, device, fn, name: str | None = None):
        if not device.engine.fuse:
            raise ValueError(
                "capture requires a fused device (fuse=True): an eager "
                "device has no program to record")
        rel = device.engine.reliability
        if rel is not None and rel.inject:
            raise ValueError(
                "capture cannot replay under reliability fault injection "
                "(the vote/retry loop re-plans per flush); capture before "
                "enabling inject, or flush normally")
        self._device = device
        self._fn = fn
        self.name = name or getattr(fn, "__name__", "captured")
        self._ctx = f"capture-{id(self):x}"
        self._lock = threading.Lock()
        self._recordings: dict[tuple, _Recording] = {}
        self.n_records = 0
        self.n_replays = 0

    # ------------------------------------------------------------------ #

    @staticmethod
    def _normalize(inputs) -> list[np.ndarray]:
        return [np.ascontiguousarray(np.asarray(x, np.uint64))
                for x in inputs]

    def __call__(self, *inputs):
        norm = self._normalize(inputs)
        key = tuple(a.shape for a in norm)
        with self._lock:
            rec = self._recordings.get(key)
            if rec is None:
                rec, outs = self._record(norm)
                self._recordings[key] = rec
                self.n_records += 1
                return outs[0] if rec.single else tuple(outs)
        outs = self._replay(rec, norm)
        self.n_replays += 1
        return outs[0] if rec.single else tuple(outs)

    def call_async(self, *inputs) -> CaptureHandle:
        """Replay on the device's flush worker thread; returns a handle
        whose ``result()`` is the outputs. A first call for a new shape
        records synchronously (recording is caller-side by design), then
        returns an already-done handle."""
        norm = self._normalize(inputs)
        key = tuple(a.shape for a in norm)
        with self._lock:
            rec = self._recordings.get(key)
        if rec is None:
            outs = self(*inputs)
            return CaptureHandle(None, outs)

        def run():
            outs = self._replay(rec, norm)
            self.n_replays += 1
            return outs[0] if rec.single else tuple(outs)

        eng = self._device.engine
        return CaptureHandle(eng._ensure_executor().submit(run))

    # ------------------------------------------------------------------ #

    def _record(self, norm: list[np.ndarray]):
        """First call for this shape signature: run ``fn`` in the capture
        client context, detach the recorded graph, compile it, and build
        the per-call leaf binding plan."""
        eng = self._device.engine
        recipe: list = []
        with eng.client(self._ctx):
            eng.flush()  # the capture context's slot must start empty
            eng._local.charge_log = recipe
            eng._local.no_autoflush = True
            try:
                pum_in = [self._device.asarray(a) for a in norm]
                outs = self._fn(*pum_in)
            finally:
                eng._local.charge_log = None
                eng._local.no_autoflush = False
            single = not isinstance(outs, (tuple, list))
            outs = [outs] if single else list(outs)
            with eng._lock:
                g = eng._graph
                eng._graph = None
        if g is None or not g.ops:
            raise ValueError(
                f"capture({self.name}): the function recorded no fused "
                f"ops (did it compute eagerly or return constants?)")
        if g.raw:
            raise ValueError(
                f"capture({self.name}): the function routed through the "
                f"raw packed-bitmap path (out-of-width operands); capture "
                f"is value-mode only — mask inputs to the device width")
        g.state = "done"  # never dispatched via flush; replays own it
        out_ops = []
        for o in outs:
            lz = getattr(o, "_data", o)
            if not (isinstance(lz, LazyArray) and lz._value is None
                    and lz._graph is g):
                raise ValueError(
                    f"capture({self.name}): every output must be a "
                    f"pending PumArray of the captured graph (got "
                    f"{type(o).__name__}; did an op auto-flush or "
                    f"materialize mid-function?)")
            out_ops.append(lz._op_idx)
        unique = list(dict.fromkeys(out_ops))
        n_leaves = len(g.leaves)

        def vid(tag):
            return tag[1] if tag[0] == "leaf" else n_leaves + tag[1]

        program = FusedProgram(
            width=g.width, n_inputs=n_leaves,
            ops=tuple(FusedOp(opcode, tuple(vid(a) for a in args), param)
                      for opcode, args, param in g.ops),
            outputs=tuple(n_leaves + i for i in unique),
            layout=g.layout)
        program, out_pos, leaf_map = optimize_program(program)
        # Replays rebind the leaves, so the pipeline may never donate its
        # input buffers (the staged constants are reused every call).
        pipeline = get_pipeline(program, donate=False,
                                backend=eng.fused_backend)
        by_leaf = {g._leaf_ids[id(a)]: i for i, a in enumerate(norm)
                   if id(a) in g._leaf_ids}
        pad = (-g.n) % 32
        plan = []
        for li in leaf_map:
            if li in by_leaf:
                plan.append(("in", by_leaf[li]))
            else:
                # Closure constants keep the graph's staged wire (already
                # padded; the record-time snapshot or a cached upload).
                plan.append(("const", g.stage_leaf(li)))
        rec = _Recording(
            pipeline=pipeline, plan=plan,
            out_slots=[out_pos[unique.index(i)] for i in out_ops],
            out_shapes=[getattr(o, "shape", ()) for o in outs],
            single=single, n=g.n, pad=pad, width=g.width, layout=g.layout,
            recipe=tuple(recipe), fp_idx=g._fp_idx)
        # First-call outputs come from one replay (the recording itself
        # already charged the cost plane through the ops in ``fn``).
        values = self._replay(rec, norm, charge=False)
        for o, v in zip(outs, values):
            lz = getattr(o, "_data", o)
            lz._value = v
            lz._graph = None
            lz._engine = None
        return rec, values

    def _replay(self, rec: _Recording, norm: list[np.ndarray],
                charge: bool = True) -> list[np.ndarray]:
        eng = self._device.engine
        cache = eng._leaf_cache
        wants = getattr(rec.pipeline, "wants_device", None)
        # Capture pipelines never donate, so cached device buffers are
        # safe to serve whenever the pipeline runs jitted.
        use_dev = cache is not None and wants is not None and wants(
            (rec.n + rec.pad) * rec.layout.wire_words_per_lane)
        hits = misses = 0
        leaves = []
        for kind, v in rec.plan:
            if kind == "const":
                leaves.append(v)
                continue
            arr = norm[v]
            rav = arr.ravel()
            if rav.size != rec.n:
                raise ValueError(
                    f"capture({self.name}): input {v} has {rav.size} "
                    f"lanes; this recording expects {rec.n}")
            entry = ckey = fp = None
            shared = rav.base is not None or rav is arr
            if cache is not None and shared and rav.size:
                fp = rav[rec.fp_idx]
                ckey = (rav.__array_interface__["data"][0], rav.nbytes,
                        rec.layout.name, False)
                entry = cache.lookup(ckey, fp)
            if entry is None:
                misses += 1
                if rec.width < 64 and rav.size \
                        and int(rav.max()) >> rec.width:
                    raise ValueError(
                        f"fused dataplane computes modulo 2**{rec.width};"
                        f" an operand has bits at or above bit "
                        f"{rec.width} — mask inputs to the engine width "
                        f"or use fuse=False")
                wire = _stage_wire(rav, rec.pad, rec.layout, copy=shared)
                if ckey is not None:
                    entry, _ = cache.insert(ckey, fp, wire)
                if entry is None:
                    leaves.append(wire)
                    continue
            else:
                hits += 1
            leaves.append(cache.device_buffer(entry) if use_dev
                          else entry.wire)
        if eng.tracer is not None and (hits or misses):
            if hits:
                eng.counters.inc("engine.leaf_cache.hits", hits)
            if misses:
                eng.counters.inc("engine.leaf_cache.misses", misses)
        if charge:
            # Charge into the capture's own client context: recording and
            # every replay land in ONE stats shard, so totals accumulate
            # in the exact float order a re-recording stream would.
            with eng.client(self._ctx):
                eng._replay_charges(rec.recipe)
            if eng.tracer is not None:
                eng.counters.inc("engine.capture.replay")
        outs = rec.pipeline(*leaves)
        values = []
        for slot, shape in zip(rec.out_slots, rec.out_shapes):
            lanes = rec.layout.from_wire(outs[slot])[:rec.n]
            values.append(lanes.astype(np.uint64).reshape(shape))
        return values

    def __repr__(self) -> str:
        return (f"CapturedProgram({self.name!r}, "
                f"{len(self._recordings)} shape(s), "
                f"records={self.n_records}, replays={self.n_replays})")
