"""Fault injection: map-driven bit flips on fused-pipeline outputs.

The injector turns a :class:`~repro.reliability.calibration.ReliabilityMap`
into concrete bit-flip masks for one fused program execution:

* each dataplane lane lives in one *column* of one (bank, subarray) home —
  lanes tile across homes in ``n_columns``-sized chunks, in calibration
  order or (with steering) ranked best-first;
* a lane's per-execution flip probability is ``1 - (1 - p_col)^n_ops`` —
  the column is exercised once per row-group op of the program;
* a faulting lane flips ONE uniformly chosen bit of its word (a sense-amp
  resolving the wrong way corrupts a single cell's readout).

All randomness is ``np.random.default_rng`` seeded from explicit integer
tuples, so a given (seed, flush, attempt, vote, output) always produces the
same mask in any process — the retry loop and the tests rely on that.
"""

from __future__ import annotations

import numpy as np

from repro.reliability.calibration import ReliabilityMap


class FaultInjector:
    """Per-column fault model for one replication config of a map."""

    def __init__(self, rmap: ReliabilityMap, cfg_idx: int, *, width: int,
                 n_ops: int = 1, steer: bool = True,
                 flip_scale: float = 1.0):
        self.rmap = rmap
        self.cfg_idx = cfg_idx
        self.width = width
        self.n_ops = max(1, int(n_ops))
        self.flip_scale = float(flip_scale)
        if steer:
            self.homes = rmap.home_order(cfg_idx)
        else:
            self.homes = [(b, s) for b in range(rmap.n_banks)
                          for s in range(rmap.n_subarrays)]

    def lane_probs(self, n_lanes: int) -> np.ndarray:
        """Per-lane flip probability for one program execution."""
        nc = self.rmap.n_columns
        nh = len(self.homes)
        p = np.empty(n_lanes, np.float64)
        for k in range(0, n_lanes, nc):
            b, s = self.homes[(k // nc) % nh]
            cols = self.rmap.flip_p[b, s, self.cfg_idx]
            take = min(nc, n_lanes - k)
            p[k:k + take] = cols[:take]
        p = np.clip(p * self.flip_scale, 0.0, 1.0)
        return 1.0 - (1.0 - p) ** self.n_ops

    def sample_mask(self, rng: np.random.Generator, p_eff: np.ndarray,
                    dtype: np.dtype) -> tuple[np.ndarray, int]:
        """One execution's flip mask (lane-dtype, XOR onto clean lanes) and
        the number of injected bits."""
        n = p_eff.shape[0]
        flips = rng.random(n) < p_eff
        bits = rng.integers(0, self.width, n).astype(dtype)
        one = np.ones(n, dtype)
        mask = np.where(flips, np.left_shift(one, bits),
                        np.zeros(n, dtype))
        return mask, int(flips.sum())


def majority_vote(replicas: np.ndarray, width: int, min_margin: int
                  ) -> tuple[np.ndarray, int, int]:
    """Bitwise majority over ``replicas [R, n]`` (unsigned lane words).

    Returns ``(majority, corrected_bits, weak_bits)``:

    * ``corrected_bits`` — bit positions where a minority of replicas
      disagreed and was outvoted;
    * ``weak_bits`` — disagreeing bits whose vote margin ``|2s - R|`` fell
      below ``min_margin`` (too close to trust: the caller retries).
    """
    r, _ = replicas.shape
    dtype = replicas.dtype
    one = dtype.type(1)
    maj = np.zeros(replicas.shape[1], dtype)
    corrected = 0
    weak = 0
    for b in range(width):
        s = ((replicas >> dtype.type(b)) & one).astype(np.int64).sum(axis=0)
        maj |= (2 * s > r).astype(dtype) << dtype.type(b)
        dis = (s > 0) & (s < r)
        corrected += int(dis.sum())
        weak += int((dis & (np.abs(2 * s - r) < min_margin)).sum())
    return maj, corrected, weak
