"""Chip calibration: profile a (simulated) PULSAR chip into a ReliabilityMap.

The companion characterization study (arXiv 2405.06081) shows MAJ success
varies systematically per column, per subarray (Fig 16's M-shaped spatial
profile) and per manufacturer. ``calibrate()`` runs the analog Monte-Carlo
model (`core/analog.column_flip_probs`) over every (bank, subarray,
replication config) of a simulated chip — seeded, so the same chip id always
yields the same map — and persists the result as a ``ReliabilityMap``:

* ``success[b, s, c]`` — fraction of stable columns for config ``c`` in
  subarray ``s`` of bank ``b`` (the paper's per-row-group success rate);
* ``flip_p[b, s, c, col]`` — per-trial flip probability of each column,
  used by the fault injector and by weak-column steering.

Spatial structure: the W-shaped (inverted-M) process-variation profile from
``charact.spatial_pv_multiplier`` across subarrays, plus a seeded per-bank
lot-variation multiplier. The per-(bank, subarray, config) PRNG keys use the
same stable ``zlib.crc32`` fold as ``charact.SuccessRateDb`` so maps are
reproducible across processes.
"""

from __future__ import annotations

import json
import math
import os
import zlib

import jax
import numpy as np

from repro.core import analog
from repro.core.charact import spatial_pv_multiplier
from repro.core.profiles import PROFILES
from repro.core.replication import ReplicationPlan, plan as replication_plan

# Per-trial flip probability at exactly the stability threshold
# (worst margin == TRIAL_TAIL_SIGMA * sigma): columns below this are the
# analog model's "stable" columns.
P_STABLE = 0.5 * math.erfc(analog.TRIAL_TAIL_SIGMA / math.sqrt(2.0))

# Replication configs profiled by default: (MAJ fan-in, N_RG). Filtered per
# manufacturer against max_simul_rows / max_maj_fan_in at calibrate() time.
DEFAULT_CONFIGS = ((3, 4), (3, 8), (3, 16), (3, 32),
                   (5, 8), (5, 16), (5, 32))


def _stable_key(seed: int, *parts) -> jax.Array:
    """Process-stable PRNG key (charact.SuccessRateDb idiom: crc32 of the
    repr, never the salted builtin hash)."""
    h = zlib.crc32(repr(parts).encode())
    return jax.random.PRNGKey(seed * 7919 + h % (2 ** 31))


class ReliabilityMap:
    """Persistent per-bank / per-subarray / per-column reliability profile.

    A plain class (not a dataclass): instances hash/compare by identity so
    a map can sit inside the frozen ``EngineConfig`` without dragging
    megabytes of arrays into equality checks.
    """

    def __init__(self, *, mfr: str, seed: int, n_subarrays: int,
                 n_columns: int, configs: tuple[tuple[int, int], ...],
                 success: np.ndarray, flip_p: np.ndarray,
                 bank_scale: np.ndarray):
        self.mfr = mfr
        self.seed = seed
        self.n_subarrays = n_subarrays
        self.n_columns = n_columns
        self.configs = tuple((int(m), int(n)) for m, n in configs)
        self.success = np.asarray(success, np.float64)
        self.flip_p = np.asarray(flip_p, np.float32)
        self.bank_scale = np.asarray(bank_scale, np.float64)
        expect = (self.n_banks, n_subarrays, len(self.configs), n_columns)
        if self.flip_p.shape != expect:
            raise ValueError(f"flip_p shape {self.flip_p.shape} != {expect}")

    @property
    def n_banks(self) -> int:
        return self.success.shape[0]

    def __repr__(self) -> str:
        return (f"ReliabilityMap(mfr={self.mfr!r}, banks={self.n_banks}, "
                f"subarrays={self.n_subarrays}, columns={self.n_columns}, "
                f"configs={self.configs}, seed={self.seed})")

    # ------------------------------------------------------------------ #
    # Queries

    def config_index(self, m_inputs: int, n_rg: int) -> int | None:
        try:
            return self.configs.index((m_inputs, n_rg))
        except ValueError:
            return None

    def nearest_config(self, m_inputs: int, n_rg: int) -> int:
        """Closest profiled config: same fan-in preferred, then nearest N_RG
        (ties toward the larger, i.e. more-replicated, config)."""
        scored = sorted(
            (abs(m - m_inputs), abs(n - n_rg), -n, i)
            for i, (m, n) in enumerate(self.configs))
        return scored[0][3]

    def escalated_config(self, cfg_idx: int, level: int) -> int:
        """Config after ``level`` escalation steps: same fan-in, next larger
        N_RG per step (more input replication copies — Fig 11's reliability
        lever). Saturates at the largest profiled N_RG for that fan-in."""
        m, n = self.configs[cfg_idx]
        ladder = sorted(i for i, (mi, _) in enumerate(self.configs) if mi == m)
        ladder.sort(key=lambda i: self.configs[i][1])
        pos = ladder.index(cfg_idx)
        return ladder[min(pos + level, len(ladder) - 1)]

    def mean_success(self, m_inputs: int, n_rg: int) -> float | None:
        """Chip-wide mean success for a config, or None if not profiled."""
        i = self.config_index(m_inputs, n_rg)
        if i is None:
            return None
        return float(self.success[:, :, i].mean())

    def home_order(self, cfg_idx: int) -> list[tuple[int, int]]:
        """(bank, subarray) placement homes ranked best-first for a config —
        the steering order for variation-aware scheduling."""
        sr = self.success[:, :, cfg_idx]
        flat = [(float(sr[b, s]), b, s)
                for b in range(self.n_banks)
                for s in range(self.n_subarrays)]
        flat.sort(key=lambda t: (-t[0], t[1], t[2]))
        return [(b, s) for _, b, s in flat]

    def bank_order(self) -> list[int]:
        """Banks ranked by mean success over all subarrays/configs —
        consumed by the controller so batch scheduling prefers strong
        banks."""
        means = self.success.mean(axis=(1, 2))
        return sorted(range(self.n_banks), key=lambda b: (-means[b], b))

    def column_flip_p(self, bank: int, subarray: int,
                      cfg_idx: int) -> np.ndarray:
        return self.flip_p[bank, subarray, cfg_idx]

    def weak_column_frac(self, cfg_idx: int,
                         threshold: float | None = None) -> float:
        """Fraction of columns chip-wide whose flip probability exceeds the
        stability threshold for a config."""
        t = P_STABLE if threshold is None else threshold
        return float((self.flip_p[:, :, cfg_idx] > t).mean())

    def best_plan(self, m_inputs: int, target_success: float
                  ) -> tuple[ReplicationPlan, float]:
        """Cheapest profiled config of fan-in ``m_inputs`` whose chip-wide
        success meets ``target_success`` (fewest rows = fastest ACT chain);
        falls back to the most reliable profiled config when none does.
        Returns (fig-10 replication plan, expected success)."""
        cands = [(n, self.mean_success(m_inputs, n))
                 for m, n in self.configs if m == m_inputs]
        if not cands:
            raise ValueError(f"MAJ{m_inputs} not profiled in this map")
        ok = [(n, s) for n, s in cands if s >= target_success]
        if ok:
            n, s = min(ok, key=lambda t: t[0])
        else:
            n, s = max(cands, key=lambda t: t[1])
        return replication_plan(m_inputs, n), s

    # ------------------------------------------------------------------ #
    # Persistence

    def save(self, path: str | os.PathLike) -> None:
        """Persist as a single .npz (arrays + JSON-encoded metadata)."""
        meta = json.dumps({
            "mfr": self.mfr, "seed": self.seed,
            "n_subarrays": self.n_subarrays, "n_columns": self.n_columns,
            "configs": [list(c) for c in self.configs],
        })
        np.savez_compressed(
            path, success=self.success, flip_p=self.flip_p,
            bank_scale=self.bank_scale,
            meta=np.frombuffer(meta.encode(), dtype=np.uint8))

    @classmethod
    def load(cls, path: str | os.PathLike) -> "ReliabilityMap":
        with np.load(path) as z:
            meta = json.loads(bytes(z["meta"]).decode())
            return cls(
                mfr=meta["mfr"], seed=meta["seed"],
                n_subarrays=meta["n_subarrays"],
                n_columns=meta["n_columns"],
                configs=tuple(tuple(c) for c in meta["configs"]),
                success=z["success"], flip_p=z["flip_p"],
                bank_scale=z["bank_scale"])


def calibrate(mfr: str = "M", *, banks: int = 16, n_subarrays: int = 8,
              n_columns: int = 512, n_patterns: int = 12,
              configs: tuple[tuple[int, int], ...] | None = None,
              seed: int = 0, process_variation: float | None = None,
              bank_sigma: float = 0.06) -> ReliabilityMap:
    """Profile a simulated chip into a :class:`ReliabilityMap`.

    One Monte-Carlo characterization run per (bank, subarray, config):
    seeded static draws (cell caps + sense offsets) under the subarray's
    W-shaped process-variation multiplier and a per-bank lot multiplier,
    reduced to per-column flip probabilities. Same (mfr, seed, shape)
    arguments => bit-identical map, in any process.

    ``process_variation`` overrides the profile's nominal sigma (the
    reliability sweep benchmark scales it to model weaker lots);
    ``bank_sigma`` is the relative spread of the per-bank multiplier.
    """
    profile = PROFILES[mfr]
    if configs is None:
        configs = DEFAULT_CONFIGS
    configs = tuple(
        (m, n) for m, n in configs
        if n <= profile.max_simul_rows and m <= profile.max_maj_fan_in
        and n >= m)
    if not configs:
        raise ValueError(f"no profiled configs fit manufacturer {mfr!r}")
    pv0 = (profile.process_variation if process_variation is None
           else float(process_variation))
    # Per-bank lot variation: seeded, process-stable (PCG64 stream).
    rng = np.random.default_rng([seed, zlib.crc32(mfr.encode())])
    bank_scale = np.clip(1.0 + bank_sigma * rng.standard_normal(banks),
                         0.5, 2.0)

    success = np.zeros((banks, n_subarrays, len(configs)))
    flip_p = np.zeros((banks, n_subarrays, len(configs), n_columns),
                      np.float32)
    for b in range(banks):
        for s in range(n_subarrays):
            pv = pv0 * spatial_pv_multiplier(s, n_subarrays) * bank_scale[b]
            for c, (m, n) in enumerate(configs):
                rp = replication_plan(m, n)  # paper plan: maximal copies
                key = _stable_key(seed, mfr, b, s, m, n)
                prof = analog.column_flip_probs(
                    key, profile, m_inputs=m, copies=rp.copies,
                    n_neutral=rp.n_neutral, n_bitlines=n_columns,
                    n_patterns=n_patterns, process_variation=pv)
                success[b, s, c] = prof.rate
                flip_p[b, s, c] = prof.flip_p
    return ReliabilityMap(mfr=mfr, seed=seed, n_subarrays=n_subarrays,
                          n_columns=n_columns, configs=configs,
                          success=success, flip_p=flip_p,
                          bank_scale=bank_scale)
