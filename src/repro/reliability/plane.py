"""The closed reliability loop: config knob + per-engine runtime plane.

``ReliabilityConfig`` is the frozen knob carried by ``pum.EngineConfig``
(like telemetry in PR 6: absent by default, explicit opt-in). It wraps a
calibrated :class:`ReliabilityMap` plus the injection/vote/retry policy.

``ReliabilityPlane`` is the runtime object one ``PulsarEngine`` owns when
the knob is set. It closes the loop in three places:

* **planning** — ``plan_success``/``note_op`` feed calibrated (optionally
  steering-weighted) success rates into the engine's per-op config search,
  replacing the global ``SuccessRateDb`` means;
* **placement** — ``bank_order`` ranks banks best-first for the memory
  controller's batch schedule;
* **execution** — ``correct()`` wraps each fused-pipeline dispatch:
  R temporal replicas are derived from the clean execution by XOR-ing
  map-driven fault masks, a bitwise majority votes per column, and any
  disagreeing bit whose vote margin is below ``min_margin`` triggers a
  retry at an *escalated* replication config (more copies — Fig 11's
  reliability lever) with two extra votes, bounded by ``max_attempts``.
  Exhausting the attempts degrades to the eager oracle (the clean
  execution), counted as ``reliability.oracle_fallbacks``.

Reliability counters are recorded whenever the plane is active (injection
is an explicit opt-in, so the PR 6 tracer-gating of telemetry counters does
not apply; with ``inject=False`` the plane never touches the dispatch path).
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any

import numpy as np

from repro.reliability.calibration import ReliabilityMap
from repro.reliability.faults import FaultInjector, majority_vote


@dataclasses.dataclass(frozen=True)
class ReliabilityConfig:
    """Frozen reliability knob for ``pum.EngineConfig(reliability=...)``.

    ``map`` is a calibrated :class:`ReliabilityMap` (or a path to a saved
    one). ``inject=False`` (default) keeps the fused dispatch path
    untouched — the map still drives variation-aware planning. With
    ``inject=True`` every flush runs the vote/retry loop described in the
    module docstring. ``flip_scale`` scales the map's flip probabilities
    (benchmark sweeps over lot quality); ``steer=False`` disables
    weak-column-avoiding placement (ablation).
    """

    map: Any = None
    inject: bool = False
    seed: int = 0
    votes: int = 3
    max_attempts: int = 3
    min_margin: int = 2
    target_success: float = 0.99
    steer: bool = True
    flip_scale: float = 1.0

    def __post_init__(self):
        if self.votes < 1 or self.votes % 2 == 0:
            raise ValueError(f"votes must be odd and >= 1, got {self.votes}")
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.min_margin < 1:
            raise ValueError("min_margin must be >= 1")
        if not 0.0 < self.target_success <= 1.0:
            raise ValueError("target_success must be in (0, 1]")
        if self.flip_scale < 0.0:
            raise ValueError("flip_scale must be >= 0")


class ReliabilityPlane:
    """Runtime reliability loop of one engine (see module docstring)."""

    def __init__(self, reliability, *, mfr: str, counters):
        cfg = reliability
        if isinstance(cfg, ReliabilityMap):
            cfg = ReliabilityConfig(map=cfg)
        if not isinstance(cfg, ReliabilityConfig):
            raise TypeError(
                f"reliability= takes a ReliabilityConfig or ReliabilityMap, "
                f"got {type(cfg).__name__}")
        rmap = cfg.map
        if isinstance(rmap, (str, os.PathLike)):
            rmap = ReliabilityMap.load(rmap)
        if not isinstance(rmap, ReliabilityMap):
            raise ValueError(
                "ReliabilityConfig.map must be a ReliabilityMap (run "
                "Device.calibrate() or repro.reliability.calibrate())")
        if rmap.mfr != mfr:
            raise ValueError(
                f"reliability map was calibrated for manufacturer "
                f"{rmap.mfr!r} but the engine models {mfr!r}")
        self.config = cfg
        self.map = rmap
        self.counters = counters
        # Worst (lowest-success) config among the ops recorded since the
        # last flush — the injection/vote loop models that config, since
        # it bounds the program's failure rate.
        self._noted: tuple[float, int, int] | None = None
        self._flush_idx = 0

    @property
    def inject(self) -> bool:
        return self.config.inject

    # ------------------------------------------------------------------ #
    # Planning (engine._cfg_for) and placement (controller batch)

    def plan_success(self, m_inputs: int, n_rg: int) -> float | None:
        """Calibrated success rate for a candidate config, or None when the
        map does not profile it (the engine falls back to the global DB).
        With steering the rate is the mean over the better half of the
        placement homes — steered row groups land on strong subarrays."""
        i = self.map.config_index(m_inputs, n_rg)
        if i is None:
            return None
        sr = np.sort(self.map.success[:, :, i], axis=None)
        if self.config.steer:
            sr = sr[sr.size // 2:]
        return float(sr.mean())

    def note_op(self, m_inputs: int, n_rg: int, sr: float) -> None:
        """Record one charged op's chosen config; the flush-time vote loop
        injects at the *worst* noted config."""
        if self._noted is None or sr < self._noted[0]:
            self._noted = (sr, m_inputs, n_rg)

    def bank_order(self, banks: int) -> list[int]:
        """Map-ranked bank visit order, restricted/extended to ``banks``
        controller banks."""
        order = [b for b in self.map.bank_order() if b < banks]
        order.extend(b for b in range(banks) if b not in order)
        return order

    # ------------------------------------------------------------------ #
    # Execution (engine.flush dispatch)

    def _flush_config(self) -> tuple[int, int]:
        if self._noted is not None:
            return self._noted[1], self._noted[2]
        m, n = max(self.map.configs, key=lambda c: c[1])
        return m, n

    def correct(self, outs, program, n_lanes: int, span=None):
        """Vote/retry loop over one flushed program's wire outputs.

        ``outs`` are the clean pipeline outputs (the eager oracle values).
        Returns wire arrays of the same shapes, either vote-corrected or —
        after ``max_attempts`` weak votes — the clean outputs themselves.
        """
        cfg = self.config
        cnt = self.counters
        layout, width = program.layout, program.width
        clean = [np.asarray(layout.from_wire(o)) for o in outs]
        flush_idx = self._flush_idx
        self._flush_idx += 1
        m, n_rg = self._flush_config()
        self._noted = None
        if not clean:
            return outs
        dtype = clean[0].dtype
        base_idx = self.map.config_index(m, n_rg)
        if base_idx is None:
            base_idx = self.map.nearest_config(m, n_rg)
        n_ops = len(program.ops)
        cnt.inc("reliability.flushes")
        votes = cfg.votes
        result = None
        attempts = 0
        for attempt in range(cfg.max_attempts):
            attempts = attempt + 1
            idx = self.map.escalated_config(base_idx, attempt)
            if attempt:
                cnt.inc("reliability.retries")
                if idx != self.map.escalated_config(base_idx, attempt - 1):
                    cnt.inc("reliability.escalations")
            inj = FaultInjector(self.map, idx, width=width, n_ops=n_ops,
                                steer=cfg.steer, flip_scale=cfg.flip_scale)
            corrected_arrays = []
            n_corrected = 0
            accepted = True
            for t, cl in enumerate(clean):
                p_eff = inj.lane_probs(cl.size)
                reps = np.empty((votes, cl.size), dtype)
                for v in range(votes):
                    rng = np.random.default_rng(
                        [cfg.seed, flush_idx, attempt, v, t])
                    mask, n_flips = inj.sample_mask(rng, p_eff, dtype)
                    cnt.inc("reliability.injected_bits", n_flips)
                    cnt.inc("reliability.exposed_bits", cl.size * width)
                    reps[v] = cl ^ mask
                maj, corrected, weak = majority_vote(reps, width,
                                                     cfg.min_margin)
                cnt.inc("reliability.votes_run", votes)
                if weak:
                    cnt.inc("reliability.weak_bits", weak)
                    accepted = False
                    break
                n_corrected += corrected
                corrected_arrays.append(maj)
            if accepted:
                # Only the delivered vote's corrections count — discarded
                # (retried) attempts report as weak_bits instead.
                cnt.inc("reliability.corrected_bits", n_corrected)
                result = corrected_arrays
                break
            votes += 2  # escalate temporal redundancy alongside the config
        if result is None:
            cnt.inc("reliability.oracle_fallbacks")
            result = clean
        if span is not None:
            span.args["attempts"] = attempts
            span.args["fallback"] = result is clean
        return tuple(layout.to_wire(r) for r in result)
