"""repro.reliability — the closed reliability loop (see docs/reliability.md).

Calibrate a simulated chip into a persistent per-bank/per-subarray/
per-column :class:`ReliabilityMap`, then hand it to a device via
``pum.EngineConfig(reliability=ReliabilityConfig(map=..., inject=True))``:
planning picks the fig-11 replication factor per operation from the map,
placement steers row groups onto strong banks/subarrays, and execution
corrects injected faults by temporal replication voting with bounded retry
escalation (degrading to the eager oracle as a last resort).
"""

from repro.reliability.calibration import (DEFAULT_CONFIGS, P_STABLE,
                                           ReliabilityMap, calibrate)
from repro.reliability.faults import FaultInjector, majority_vote
from repro.reliability.plane import ReliabilityConfig, ReliabilityPlane

__all__ = [
    "DEFAULT_CONFIGS",
    "P_STABLE",
    "FaultInjector",
    "ReliabilityConfig",
    "ReliabilityMap",
    "ReliabilityPlane",
    "calibrate",
    "majority_vote",
]
