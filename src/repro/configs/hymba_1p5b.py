"""hymba-1.5b [hybrid]: 32L d=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16 — parallel attention+mamba heads per layer, SWA with 3
global full-attention layers (first/middle/last) [arXiv:2411.13676]."""

from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, head_dim=64, d_ff=5504, vocab_size=32001,
    attn_kind="gqa", sliding_window=1024,
    global_attn_layers=(0, 15, 31),
    ssm=True, ssm_state=16, ssm_head_dim=64, ssm_expand=2,
    hybrid_parallel=True,
)

SMOKE_CONFIG = ModelConfig(
    name="hymba-smoke", family="hybrid", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
    attn_kind="gqa", sliding_window=32, global_attn_layers=(0,),
    ssm=True, ssm_state=16, ssm_head_dim=32, ssm_expand=2,
    hybrid_parallel=True, vocab_pad_multiple=128, remat="none",
    ssm_chunk=16,
)
