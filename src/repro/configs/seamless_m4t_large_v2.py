"""seamless-m4t-large-v2 [audio]: 24L enc + 24L dec, d=1024 16H (MHA)
d_ff=8192 vocab=256206 — encoder-decoder; the audio frontend is a STUB
(input_specs provides precomputed frame embeddings) [arXiv:2308.11596].

Shape interpretation (DESIGN.md): train_4k = 2048 source frames + 2048
target tokens; decode shapes run the DECODER against a fixed 4096-frame
encoder memory."""

from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio", n_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64, d_ff=8192,
    vocab_size=256206, encoder_decoder=True, n_encoder_layers=24,
    frontend="audio", n_frontend_tokens=4096,
)

SMOKE_CONFIG = ModelConfig(
    name="seamless-smoke", family="audio", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
    encoder_decoder=True, n_encoder_layers=2, frontend="audio",
    n_frontend_tokens=32, vocab_pad_multiple=128, remat="none",
)
