"""qwen2.5-32b [dense]: 64L d=5120 40H (GQA kv=8, head_dim=128)
d_ff=27648 vocab=152064 — GQA, QKV bias [hf:Qwen/Qwen2.5 family]."""

from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=40, n_kv_heads=8, head_dim=128, d_ff=27648,
    vocab_size=152064, qkv_bias=True, rope_theta=1e6,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2.5-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
    qkv_bias=True, vocab_pad_multiple=128, remat="none",
)
