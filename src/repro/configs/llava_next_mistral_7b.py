"""llava-next-mistral-7b [vlm]: Mistral-7B backbone, 32L d=4096 32H
(GQA kv=8, head_dim=128) d_ff=14336 vocab=32000 — anyres tiling gives
2880 patch tokens (5 tiles x 576); the vision tower is a STUB
(input_specs provides precomputed patch embeddings)
[hf:llava-hf/llava-v1.6-mistral-7b-hf]."""

from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm", n_layers=32,
    d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336,
    vocab_size=32000, rope_theta=1e6, frontend="vision",
    n_frontend_tokens=2880,
)

SMOKE_CONFIG = ModelConfig(
    name="llava-smoke", family="vlm", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
    frontend="vision", n_frontend_tokens=16, vocab_pad_multiple=128,
    remat="none",
)
