"""qwen1.5-0.5b [dense]: 24L d=1024 16H (MHA kv=16) d_ff=2816
vocab=151936 — QKV bias [hf:Qwen/Qwen1.5-0.5B]."""

from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, head_dim=64, d_ff=2816,
    vocab_size=151936, qkv_bias=True, rope_theta=1e6,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen1.5-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
    qkv_bias=True, vocab_pad_multiple=128, remat="none",
)
