"""mamba2-130m [ssm]: 24L d=768, attention-free, vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060]."""

from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=0, vocab_size=50280,
    attn_kind="none", ssm=True, ssm_state=128, ssm_head_dim=64,
    ssm_expand=2, tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-smoke", family="ssm", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=512,
    attn_kind="none", ssm=True, ssm_state=16, ssm_head_dim=32,
    ssm_expand=2, tie_embeddings=True, vocab_pad_multiple=128,
    remat="none", ssm_chunk=16,
)
