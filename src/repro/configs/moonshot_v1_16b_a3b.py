"""moonshot-v1-16b-a3b [moe]: 48L d=2048 16H (MHA kv=16) expert
d_ff=1408 vocab=163840, MoE 64 experts top-6 (+2 shared, Moonlight /
DeepSeek-style) [hf:moonshotai/Moonlight-16B-A3B]."""

from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=0, vocab_size=163840,
    moe=True, n_experts=64, top_k=6, moe_d_ff=1408, n_shared_experts=2,
    first_dense_layers=1,
)

SMOKE_CONFIG = ModelConfig(
    name="moonshot-smoke", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, head_dim=32, d_ff=0, vocab_size=512,
    moe=True, n_experts=8, top_k=2, moe_d_ff=64, n_shared_experts=1,
    first_dense_layers=1, vocab_pad_multiple=128, remat="none",
)
