"""deepseek-v2-236b [moe]: 60L d=5120 128H MLA (kv_lora=512,
q_lora=1536, nope 128 / rope 64 / v 128) expert d_ff=1536 vocab=102400,
MoE 160 routed top-6 + 2 shared, first layer dense [arXiv:2405.04434]."""

from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe", n_layers=60, d_model=5120,
    n_heads=128, n_kv_heads=128, d_ff=12288, vocab_size=102400,
    attn_kind="mla", q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    moe=True, n_experts=160, top_k=6, moe_d_ff=1536,
    n_shared_experts=2, first_dense_layers=1,
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-smoke", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=512,
    attn_kind="mla", q_lora_rank=64, kv_lora_rank=32,
    qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32,
    moe=True, n_experts=8, top_k=2, moe_d_ff=64, n_shared_experts=1,
    first_dense_layers=1, vocab_pad_multiple=128, remat="none",
)
