"""Pluggable backend registry: capability lookup for dataplane evaluators.

Before this module existed, backend selection was hard-coded: the engine
branched on ``backend == "sim"`` to build the chip-model ALU, and
``kernels/fused_program.py`` branched on ``jax.default_backend() == "tpu"``
to pick the Pallas vertical evaluator over the word-domain one. Adding a
new evaluator (a width-64 plane backend, a multi-device sharded pipeline)
meant editing both call sites.

Now every evaluator is a registered :class:`BackendSpec` and the call
sites *look capabilities up*:

* the engine resolves its ``backend=`` name to an **eager dataplane**
  builder (capability ``"eager"``), which returns either ``None`` (compute
  on packed NumPy words — the ``"fast"`` default) or an ALU-protocol
  object (the bit-exact ``"sim"`` chip model);
* the fused pipeline resolves a :class:`FusedProgram` to a **fused
  evaluator** (capability ``"fused"``) by :func:`select_backend` — the
  highest-priority available backend whose ``max_width`` covers the
  program and whose declared ``layouts`` include the program's plane
  layout (the lane word format, see ``repro.kernels.plane_layout``).

A future backend is an additive ``register_backend(...)`` call — no
engine or compiler edits; the width-64 evaluators and the multi-device
``shard-words`` pipeline below are exactly that. The full contract (builder signatures per
capability) is documented in ``docs/api.md``; ``repro.pum`` re-exports
the registry functions as the public surface.

This module is intentionally dependency-free (no repro imports at module
level): builders import their implementation lazily so the registry can
be imported from anywhere in the stack without cycles.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """One registered backend.

    ``builder`` signature depends on capability:

    * ``"eager"`` backends: ``builder(engine) -> alu | None`` — called at
      ``PulsarEngine`` construction. Return ``None`` for the packed-NumPy
      word dataplane, or an object with the ``BitSerialAlu`` protocol
      (``words``, ``load``/``store``, ``and_``/``or_``/``xor``/``add``/
      ``sub``/``mul``/``div``) to route small operands through it.
    * ``"fused"`` backends: ``builder(program, interpret=..., donate=...)
      -> fn(*leaves) -> tuple(outs)`` — called (and cached) per program
      structure by ``fused_program.get_pipeline``. Leaves/outputs are flat
      int32 arrays of packed horizontal words.

    ``available`` gates automatic selection (e.g. the Pallas evaluator is
    only auto-selected on a TPU host); an unavailable backend can still be
    requested by name. ``max_width`` bounds the element width the backend
    can evaluate; ``layouts`` declares the plane-layout word sizes (32/64
    — see ``repro.kernels.plane_layout``) its pipelines consume;
    ``priority`` breaks ties (higher wins).
    """
    name: str
    builder: Callable[..., Any]
    capabilities: frozenset[str]
    max_width: int = 32
    priority: int = 0
    available: Callable[[], bool] = lambda: True
    layouts: frozenset[int] = frozenset({32})


_REGISTRY: dict[str, BackendSpec] = {}


def register_backend(name: str, builder: Callable[..., Any], *,
                     capabilities=("fused",), max_width: int = 32,
                     priority: int = 0,
                     available: Callable[[], bool] | None = None,
                     layouts=(32,)) -> BackendSpec:
    """Register (or replace) a backend under ``name`` and return its spec.

    Re-registering an existing name replaces it — callers own their
    namespace; the built-in names are ``fast``, ``sim``, ``words-cpu``,
    ``pallas-tpu``, ``ref-vertical``, their ``-64`` layout variants and
    the multi-device ``shard-words`` pipeline.
    """
    spec = BackendSpec(name=name, builder=builder,
                       capabilities=frozenset(capabilities),
                       max_width=max_width, priority=priority,
                       available=available or (lambda: True),
                       layouts=frozenset(int(b) for b in layouts))
    _REGISTRY[name] = spec
    return spec


def unregister_backend(name: str) -> None:
    """Remove a registered backend (mainly for tests)."""
    _REGISTRY.pop(name, None)


def get_backend(name: str) -> BackendSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; registered: "
            f"{sorted(_REGISTRY)} (register_backend() adds new ones)"
        ) from None


def available_backends(capability: str | None = None) -> tuple[str, ...]:
    """Names of registered backends, optionally filtered by capability
    (registration order; includes unavailable ones — availability is a
    host property, registration is not)."""
    return tuple(n for n, s in _REGISTRY.items()
                 if capability is None or capability in s.capabilities)


# Selection overrides: capability -> pinned backend name. Consulted by
# select_backend before the priority scan — the autotuner's hook for
# steering callers that reach capability lookup without a Device (e.g.
# fused_program.get_pipeline with backend=None). An override only wins
# when its spec actually satisfies the query's capability/width/layout
# constraints; otherwise the normal lookup proceeds, so a pinned name
# can never produce a pipeline the program cannot run on.
_SELECTION_OVERRIDE: dict[str, str] = {}


def set_selection_override(capability: str, name: str | None) -> None:
    """Pin (or with ``None`` unpin) the backend ``select_backend``
    returns for single-capability ``capability`` queries. The pinned
    backend is validated against each query's width/layout constraints
    and skipped when it cannot satisfy them. Prefer the scoped
    :func:`selection_override` context manager."""
    if name is None:
        _SELECTION_OVERRIDE.pop(capability, None)
    else:
        get_backend(name)  # loud on unknown names
        _SELECTION_OVERRIDE[capability] = name


def get_selection_override(capability: str) -> str | None:
    """The currently pinned backend name for ``capability`` (or None)."""
    return _SELECTION_OVERRIDE.get(capability)


@contextlib.contextmanager
def selection_override(capability: str, name: str | None):
    """Scoped :func:`set_selection_override`: pin ``name`` for the
    duration of the block, restoring the previous pin on exit. The
    ``TunedPlan.selection_override()`` entry point."""
    prev = _SELECTION_OVERRIDE.get(capability)
    set_selection_override(capability, name)
    try:
        yield
    finally:
        set_selection_override(capability, prev)


def select_backend(*, require, width: int | None = None,
                   layout=None) -> BackendSpec:
    """Capability lookup: the highest-priority *available* backend whose
    capabilities cover ``require``, whose ``max_width`` covers ``width``,
    and whose declared ``layouts`` include ``layout`` (a word-bit count
    or a ``PlaneLayout``; ``None`` skips the filter). A
    :func:`set_selection_override` pin for the capability takes
    precedence when it satisfies the same constraints. Raises
    ``LookupError`` when nothing matches."""
    need = frozenset((require,) if isinstance(require, str) else require)
    wb = getattr(layout, "word_bits", layout)
    if len(need) == 1:
        pinned = _SELECTION_OVERRIDE.get(next(iter(need)))
        if pinned is not None:
            spec = _REGISTRY.get(pinned)
            if spec is not None and need <= spec.capabilities \
                    and (width is None or spec.max_width >= width) \
                    and (wb is None or wb in spec.layouts):
                return spec
    best: BackendSpec | None = None
    for spec in _REGISTRY.values():
        if not need <= spec.capabilities:
            continue
        if width is not None and spec.max_width < width:
            continue
        if wb is not None and wb not in spec.layouts:
            continue
        if not spec.available():
            continue
        if best is None or spec.priority > best.priority:
            best = spec
    if best is None:
        raise LookupError(
            f"no available backend with capabilities {sorted(need)}"
            + (f" at width {width}" if width is not None else "")
            + (f" on the {wb}-bit plane layout" if wb is not None else "")
            + f"; registered: {sorted(_REGISTRY)}")
    return best


# --------------------------------------------------------------------- #
# Built-in backends. Builders import lazily: the registry stays
# import-cycle-free and costs nothing until a backend is actually used.
# --------------------------------------------------------------------- #


def _build_fast_dataplane(engine) -> None:
    """Packed-NumPy word dataplane: the engine computes ops directly on
    uint64 ndarrays (and fuses through the lazy op graph when asked)."""
    return None


def _build_sim_dataplane(engine):
    """Bit-exact chip-model dataplane: a small simulated DRAM region with
    the dual-rail bit-serial ALU on top (cycle-exact command accounting)."""
    from repro.core.alu import BitSerialAlu
    from repro.core.chip import PulsarChip
    from repro.core.geometry import DramGeometry
    from repro.core.pulsar import PulsarExecutor
    geom = DramGeometry(row_bits=min(engine.row_bits, 2048),
                        rows_per_subarray=512, subarrays_per_bank=2,
                        banks=2)
    chip = PulsarChip(geom, engine.profile, seed=engine.seed)
    chip.decoder = chip.decoder.__class__(geom, engine.profile, None)
    return BitSerialAlu(PulsarExecutor(chip, 0, 0), width=engine.width)


def _build_words_pipeline(program, interpret: bool = False,
                          donate: bool = False):
    from repro.kernels import fused_program
    return fused_program.build_words_pipeline(program, donate=donate)


def _build_pallas_pipeline(program, interpret: bool = False,
                           donate: bool = False):
    from repro.kernels import fused_program
    return fused_program.build_vertical_pipeline(
        program, use_pallas=True, interpret=interpret, donate=donate)


def _build_ref_vertical_pipeline(program, interpret: bool = False,
                                 donate: bool = False):
    from repro.kernels import fused_program
    return fused_program.build_vertical_pipeline(
        program, use_pallas=False, interpret=interpret, donate=donate)


def _build_sharded_words_pipeline(program, interpret: bool = False,
                                  donate: bool = False):
    from repro.kernels import fused_program
    return fused_program.build_sharded_words_pipeline(program,
                                                      donate=donate)


def on_tpu() -> bool:
    """The one TPU-detection rule: gates Pallas auto-selection here and
    the interpret-mode fallback in kernels/{ops,fused_program}.py."""
    import jax
    return jax.default_backend() == "tpu"


def multi_device() -> bool:
    """Gates auto-selection of the sharded word pipeline: with one local
    device the plain word evaluator is the same computation minus the
    placement overhead."""
    import jax
    return len(jax.devices()) > 1


register_backend("fast", _build_fast_dataplane,
                 capabilities=("eager",), max_width=64, priority=10,
                 layouts=(32, 64))
register_backend("sim", _build_sim_dataplane,
                 capabilities=("eager", "sim"), max_width=64,
                 layouts=(32, 64))
register_backend("words-cpu", _build_words_pipeline,
                 capabilities=("fused",), max_width=32, priority=10)
register_backend("pallas-tpu", _build_pallas_pipeline,
                 capabilities=("fused", "vertical"), max_width=32,
                 priority=20, available=on_tpu)
# The vertical jnp oracle: never auto-selected (it exists to validate the
# other two), but requestable by name — get_pipeline(force_vertical=True).
register_backend("ref-vertical", _build_ref_vertical_pipeline,
                 capabilities=("fused", "vertical", "debug"), max_width=32,
                 priority=-10, available=lambda: False)

# 64-bit plane-layout evaluators: the SAME builders, registered
# additively over the wider layout — the registry extension story the
# module docstring promises. The engine reaches them whenever its layout
# is 64-bit (explicit EngineConfig.layout=64 or any width > 32).
register_backend("words-cpu-64", _build_words_pipeline,
                 capabilities=("fused",), max_width=64, priority=10,
                 layouts=(64,))
register_backend("pallas-tpu-64", _build_pallas_pipeline,
                 capabilities=("fused", "vertical"), max_width=64,
                 priority=20, available=on_tpu, layouts=(64,))
register_backend("ref-vertical-64", _build_ref_vertical_pipeline,
                 capabilities=("fused", "vertical", "debug"), max_width=64,
                 priority=-10, available=lambda: False, layouts=(64,))

# Multi-device sharded word pipeline: partitions the program's word axis
# across jax.devices() (jax.sharding mesh placement). Auto-selected only
# on multi-device hosts (beats words-cpu, loses to single-chip Pallas);
# always requestable by name (EngineConfig.fused_backend="shard-words").
register_backend("shard-words", _build_sharded_words_pipeline,
                 capabilities=("fused", "sharded"), max_width=32,
                 priority=15, available=multi_device)
