"""Mamba2 (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD algorithm: within-chunk term is a masked attention-like matmul
(MXU-friendly); across chunks a sequential state scan carries
S in R^{H x N x P}. Decode is a single-step state update (O(1) per token —
why the ssm/hybrid archs run the long_500k cell).

Layer structure (Mamba2 block):
  in_proj -> [z, x, B, C, dt]; depthwise causal conv + SiLU on (x,B,C);
  SSD(x, dt, A, B, C) + D*x; y = RMSNorm(y * silu(z)); out_proj.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import rms_norm


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads


def ssm_params(key: jax.Array, cfg) -> dict:
    d = cfg.d_model
    d_inner, h = ssm_dims(cfg)
    n = cfg.ssm_state
    conv_ch = d_inner + 2 * n  # x + B + C (single group)
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    return {
        "in_proj": jax.random.normal(
            ks[0], (d, 2 * d_inner + 2 * n + h), jnp.float32) * s,
        "conv_w": jax.random.normal(
            ks[1], (cfg.ssm_conv_width, conv_ch), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),          # A = -exp(a_log)
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),   # softplus init ~0.12
        "norm": jnp.ones((d_inner,), jnp.float32),
        "out_proj": jax.random.normal(
            ks[2], (d_inner, d), jnp.float32) / np.sqrt(d_inner),
    }


def _split_proj(cfg, p, u):
    d_inner, h = ssm_dims(cfg)
    n = cfg.ssm_state
    zxbcdt = jnp.einsum("btd,de->bte", u, p["in_proj"].astype(u.dtype))
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_inner + 2 * n]
    dt = zxbcdt[..., -h:]
    return z, xbc, dt


def _causal_conv(p, xbc, dtype):
    """Depthwise causal conv width W via shifted adds (no conv primitive)."""
    w = p["conv_w"].astype(dtype)
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    t = xbc.shape[1]
    out = sum(pad[:, k:k + t] * w[k] for k in range(width))
    return jax.nn.silu(out + p["conv_b"].astype(dtype))


def ssd_chunked(cfg, x, dt, a_log, b, c, d_skip):
    """x: [B,T,H,P]; dt: [B,T,H]; b,c: [B,T,N]. Returns y: [B,T,H,P].
    fp32 internals for numerical stability of the decay products."""
    bs, t, h, pdim = x.shape
    n = b.shape[-1]
    q = min(cfg.ssm_chunk, t)
    while t % q:
        q //= 2
    nc = t // q
    x32 = x.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    b32, c32 = b.astype(jnp.float32), c.astype(jnp.float32)
    neg_a = -jnp.exp(a_log.astype(jnp.float32))          # [H]
    logdec = dt32 * neg_a[None, None]                    # [B,T,H] log a_t
    xc = x32.reshape(bs, nc, q, h, pdim)
    dtc = dt32.reshape(bs, nc, q, h)
    bc = b32.reshape(bs, nc, q, n)
    cc = c32.reshape(bs, nc, q, n)
    lc = logdec.reshape(bs, nc, q, h)
    cum = jnp.cumsum(lc, axis=2)                         # [B,nc,Q,H]
    total = cum[:, :, -1]                                # [B,nc,H]

    # Intra-chunk (attention-like, causal).
    rel = cum[:, :, :, None] - cum[:, :, None, :]        # [B,nc,Q(t),Q(s),H]
    mask = jnp.tril(jnp.ones((q, q), bool))
    att = jnp.where(mask[None, None, :, :, None], jnp.exp(rel), 0.0)
    gbc = jnp.einsum("bcin,bcjn->bcij", cc, bc)          # C[t].B[s]
    w_ts = att * gbc[..., None] * dtc[:, :, None]        # [B,nc,t,s,H]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w_ts, xc)

    # Chunk-local states: S_c = sum_s exp(total - cum[s]) dt[s] B[s] (x) x[s]
    sdec = jnp.exp(total[:, :, None] - cum)              # [B,nc,Q,H]
    s_loc = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", sdec * dtc, bc, xc)

    # Inter-chunk recurrence: S_{c} = exp(total_{c-1}) S_{c-1} + S_loc_{c-1}
    def step(s_prev, inp):
        tot_c, sl_c = inp
        s_out = s_prev                                    # state BEFORE chunk
        s_next = jnp.exp(tot_c)[..., None, None] * s_prev + sl_c
        return s_next, s_out

    tot_sw = jnp.moveaxis(total, 1, 0)                   # [nc,B,H]
    sl_sw = jnp.moveaxis(s_loc, 1, 0)                    # [nc,B,H,N,P]
    init = jnp.zeros((bs, h, n, pdim), jnp.float32)
    _, s_prevs = jax.lax.scan(step, init, (tot_sw, sl_sw))
    s_prev = jnp.moveaxis(s_prevs, 0, 1)                 # [B,nc,H,N,P]

    # Inter-chunk output: y[t] += C[t] . (exp(cum[t]) * S_prev)
    y_inter = jnp.einsum("bcin,bcihnp->bcihp",
                         cc, jnp.exp(cum)[..., None, None] *
                         s_prev[:, :, None])
    y = (y_intra + y_inter).reshape(bs, t, h, pdim)
    y = y + d_skip.astype(jnp.float32)[None, None, :, None] * x32
    return y.astype(x.dtype)


def ssm_forward(cfg, p: dict, u: jax.Array) -> jax.Array:
    """Full-sequence Mamba2 block. u: [B,T,D] -> [B,T,D]."""
    d_inner, h = ssm_dims(cfg)
    n = cfg.ssm_state
    z, xbc, dtraw = _split_proj(cfg, p, u)
    xbc = _causal_conv(p, xbc, u.dtype)
    x = xbc[..., :d_inner]
    b = xbc[..., d_inner:d_inner + n]
    c = xbc[..., d_inner + n:]
    bs, t, _ = u.shape
    xh = x.reshape(bs, t, h, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dtraw.astype(jnp.float32)
                         + p["dt_bias"][None, None].astype(jnp.float32))
    y = ssd_chunked(cfg, xh, dt, p["a_log"], b, c, p["d_skip"])
    y = y.reshape(bs, t, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(u.dtype))


def ssm_naive(cfg, p: dict, u: jax.Array) -> jax.Array:
    """Sequential-recurrence oracle (tests: chunked == naive)."""
    d_inner, h = ssm_dims(cfg)
    n = cfg.ssm_state
    z, xbc, dtraw = _split_proj(cfg, p, u)
    xbc = _causal_conv(p, xbc, u.dtype)
    x = xbc[..., :d_inner]
    b = xbc[..., d_inner:d_inner + n]
    c = xbc[..., d_inner + n:]
    bs, t, _ = u.shape
    pdim = cfg.ssm_head_dim
    xh = x.reshape(bs, t, h, pdim).astype(jnp.float32)
    dt = jax.nn.softplus(dtraw.astype(jnp.float32)
                         + p["dt_bias"][None, None].astype(jnp.float32))
    neg_a = -jnp.exp(p["a_log"].astype(jnp.float32))

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp           # [B,H,P],[B,H],[B,N],[B,N]
        a_t = jnp.exp(dt_t * neg_a[None])   # [B,H]
        state = (a_t[..., None, None] * state
                 + jnp.einsum("bh,bn,bhp->bhnp", dt_t, b_t, x_t))
        y_t = jnp.einsum("bn,bhnp->bhp", c_t, state)
        return state, y_t

    init = jnp.zeros((bs, h, n, pdim), jnp.float32)
    _, ys = jax.lax.scan(step, init, (jnp.moveaxis(xh, 1, 0),
                                      jnp.moveaxis(dt, 1, 0),
                                      jnp.moveaxis(b.astype(jnp.float32), 1, 0),
                                      jnp.moveaxis(c.astype(jnp.float32), 1, 0)))
    y = jnp.moveaxis(ys, 0, 1)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(bs, t, d_inner).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(u.dtype))


# ----------------------------------------------------------------------- #
# Decode (single step)
# ----------------------------------------------------------------------- #

def ssm_init_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    d_inner, h = ssm_dims(cfg)
    n = cfg.ssm_state
    conv_ch = d_inner + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, h, n, cfg.ssm_head_dim), jnp.float32),
    }


def ssm_decode(cfg, p: dict, u: jax.Array, cache: dict):
    """u: [B,1,D] -> (y [B,1,D], new_cache). O(1) per token."""
    d_inner, h = ssm_dims(cfg)
    n = cfg.ssm_state
    pdim = cfg.ssm_head_dim
    z, xbc, dtraw = _split_proj(cfg, p, u)
    window = jnp.concatenate([cache["conv"], xbc], axis=1)  # [B,W,C]
    w = p["conv_w"].astype(u.dtype)
    conv_out = jnp.einsum("bwc,wc->bc", window, w) + p["conv_b"].astype(u.dtype)
    xbc_t = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]
    x = xbc_t[:, :d_inner].reshape(-1, h, pdim).astype(jnp.float32)
    b = xbc_t[:, d_inner:d_inner + n].astype(jnp.float32)
    c = xbc_t[:, d_inner + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dtraw[:, 0].astype(jnp.float32)
                         + p["dt_bias"][None].astype(jnp.float32))
    neg_a = -jnp.exp(p["a_log"].astype(jnp.float32))
    a_t = jnp.exp(dt * neg_a[None])
    state = (a_t[..., None, None] * cache["state"]
             + jnp.einsum("bh,bn,bhp->bhnp", dt, b, x))
    y = jnp.einsum("bn,bhnp->bhp", c, state)
    y = y + p["d_skip"][None, :, None].astype(jnp.float32) * x
    y = y.reshape(-1, 1, d_inner).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    y = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(u.dtype))
    return y, {"conv": new_conv, "state": state}
