"""Unified LM: dense / MoE / SSM / hybrid / enc-dec / multimodal-stub.

One parameterized architecture covers all 10 assigned configs:
  * decoder layers scanned over stacked weights (small HLO, fast compiles,
    remat-friendly — the MaxText-style production pattern),
  * attention: GQA (+bias/qk-norm/SWA) or MLA or none,
  * FFN: SwiGLU, or top-k MoE (+shared experts, leading dense layers),
  * SSM: Mamba2 SSD block (pure SSM or Hymba-style parallel hybrid),
  * encoder-decoder (audio frontend stub) and VLM patch-prefix stub.

Train/prefill paths scan layers; decode paths unroll (per-layer caches may
be heterogeneous: full-seq KV for global layers, window-sized rings for SWA
layers, compressed latents for MLA, [H,N,P] states for SSM).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.constraints import gather_layer_params, maybe_shard
from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_norm, embed, embed_params, norm_params,
                                 swiglu, swiglu_params, unembed)

Params = dict[str, Any]


# ----------------------------------------------------------------------- #
# Init
# ----------------------------------------------------------------------- #

def _layer_params(cfg, key, moe_layer: bool) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"ln1": norm_params(cfg, cfg.d_model)}
    if not cfg.attn_free:
        if cfg.attn_kind == "mla":
            p["attn"] = mla_mod.mla_params(ks[0], cfg)
        else:
            p["attn"] = attn.gqa_params(ks[0], cfg)
    if cfg.ssm:
        p["ssm"] = ssm_mod.ssm_params(ks[1], cfg)
        if cfg.hybrid_parallel:
            p["branch_norm_attn"] = norm_params(cfg, cfg.d_model)
            p["branch_norm_ssm"] = norm_params(cfg, cfg.d_model)
    if cfg.d_ff > 0 or moe_layer:
        p["ln2"] = norm_params(cfg, cfg.d_model)
        if moe_layer:
            p["moe"] = moe_mod.moe_params(ks[2], cfg)
        else:
            p["mlp"] = swiglu_params(ks[2], cfg.d_model, cfg.d_ff)
    return p


def _enc_layer_params(cfg, key) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "ln1": norm_params(cfg, cfg.d_model),
        "attn": attn.gqa_params(ks[0], cfg),
        "ln2": norm_params(cfg, cfg.d_model),
        "mlp": swiglu_params(ks[1], cfg.d_model, cfg.d_ff),
    }


def _dec_xattn_params(cfg, key) -> Params:
    return {"ln_x": norm_params(cfg, cfg.d_model),
            "xattn": attn.gqa_params(key, cfg)}


def init_params(cfg, key: jax.Array) -> Params:
    ke, kl, kd, kx, kf = jax.random.split(key, 5)
    n_scan = cfg.n_layers - cfg.first_dense_layers
    p: Params = {
        "embed": embed_params(ke, cfg.padded_vocab, cfg.d_model,
                              cfg.tie_embeddings),
        "final_norm": norm_params(cfg, cfg.d_model),
    }
    # Leading dense layers (deepseek-style), unstacked.
    for i in range(cfg.first_dense_layers):
        p[f"dense_layer_{i}"] = _layer_params(
            cfg, jax.random.fold_in(kd, i), moe_layer=False)
    # Scanned stack.
    keys = jax.random.split(kl, n_scan)
    p["layers"] = jax.vmap(
        lambda k: _layer_params(cfg, k, moe_layer=cfg.moe))(keys)
    if cfg.encoder_decoder:
        ekeys = jax.random.split(kx, cfg.n_encoder_layers)
        p["enc_layers"] = jax.vmap(
            lambda k: _enc_layer_params(cfg, k))(ekeys)
        p["enc_final_norm"] = norm_params(cfg, cfg.d_model)
        xkeys = jax.random.split(kf, n_scan)
        p["xattn_layers"] = jax.vmap(
            lambda k: _dec_xattn_params(cfg, k))(xkeys)
    return p


def count_params(cfg, active_only: bool = False) -> int:
    shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    if active_only and cfg.moe:
        moe_shapes = jax.eval_shape(
            lambda: moe_mod.moe_params(jax.random.PRNGKey(0), cfg))
        per_layer_expert = sum(
            int(np.prod(moe_shapes[k].shape)) for k in
            ("w_gate", "w_up", "w_down"))
        n_moe_layers = cfg.n_layers - cfg.first_dense_layers
        inactive = per_layer_expert * (1 - cfg.top_k / cfg.n_experts)
        total -= int(n_moe_layers * inactive)
    return total


# ----------------------------------------------------------------------- #
# Layer bodies
# ----------------------------------------------------------------------- #

def _window_schedule(cfg) -> np.ndarray:
    """Per-layer SWA window (0 = full attention)."""
    w = np.full(cfg.n_layers, cfg.sliding_window, np.int32)
    for i in cfg.global_attn_layers:
        w[i % cfg.n_layers] = 0
    return w


def _attn_branch(cfg, lp, h, positions, window):
    if cfg.attn_kind == "mla":
        return mla_mod.mla_attention(cfg, lp["attn"], h, positions)
    return attn.attention(cfg, lp["attn"], h, positions, causal=True,
                          window=window)


def _layer_fwd(cfg, lp: Params, x, positions, window, moe_layer: bool):
    """Returns (x, aux)."""
    aux = {"load_balance_loss": jnp.zeros((), jnp.float32),
           "z_loss": jnp.zeros((), jnp.float32)}
    h = apply_norm(cfg, x, lp["ln1"])
    if cfg.hybrid_parallel:
        a = _attn_branch(cfg, lp, h, positions, window)
        m = ssm_mod.ssm_forward(cfg, lp["ssm"], h)
        x = x + 0.5 * (apply_norm(cfg, a, lp["branch_norm_attn"])
                       + apply_norm(cfg, m, lp["branch_norm_ssm"]))
    elif cfg.ssm:
        x = x + ssm_mod.ssm_forward(cfg, lp["ssm"], h)
    else:
        x = x + _attn_branch(cfg, lp, h, positions, window)
    if "ln2" in lp:
        h2 = apply_norm(cfg, x, lp["ln2"])
        if moe_layer:
            y, aux = moe_mod.moe_ffn(cfg, lp["moe"], h2)
            x = x + y
        else:
            x = x + swiglu(h2, lp["mlp"])
    return x, aux


def _decoder_stack(cfg, params, x, positions):
    """Scanned decoder (train / encoder-free full-sequence path)."""
    windows = jnp.asarray(_window_schedule(cfg))
    for i in range(cfg.first_dense_layers):
        x, _ = _layer_fwd(cfg, params[f"dense_layer_{i}"], x, positions,
                          windows[i], moe_layer=False)

    def body(carry, scanned):
        h = carry
        lp, w = scanned
        lp = gather_layer_params(cfg, lp)  # per-iteration FSDP gather
        h, aux = _layer_fwd(cfg, lp, h, positions, w, moe_layer=cfg.moe)
        return h, aux

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, auxs = jax.lax.scan(
        body, x, (params["layers"], windows[cfg.first_dense_layers:]))
    aux = jax.tree.map(jnp.sum, auxs)
    return x, aux


def _encoder_stack(cfg, params, frames):
    positions = jnp.broadcast_to(
        jnp.arange(frames.shape[1]), frames.shape[:2])

    def body(h, lp):
        a = attn.attention(cfg, lp["attn"],
                           apply_norm(cfg, h, lp["ln1"]), positions,
                           causal=False, window=0)
        h = h + a
        h = h + swiglu(apply_norm(cfg, h, lp["ln2"]), lp["mlp"])
        return h, None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, frames, params["enc_layers"])
    return apply_norm(cfg, h, params["enc_final_norm"])


def _decoder_stack_xattn(cfg, params, x, positions, memory):
    """Enc-dec decoder: self-attn + cross-attn + FFN, scanned."""
    mem_kv = None  # projected per layer inside body

    def body(h, scanned):
        lp, xp = scanned
        h = h + attn.attention(cfg, lp["attn"],
                               apply_norm(cfg, h, lp["ln1"]), positions,
                               causal=True, window=0)
        # Cross attention: project memory K/V with this layer's weights.
        hx = apply_norm(cfg, h, xp["ln_x"])
        mk = jnp.einsum("bsd,dhk->bshk", memory,
                        xp["xattn"]["wk"].astype(h.dtype))
        mv = jnp.einsum("bsd,dhk->bshk", memory,
                        xp["xattn"]["wv"].astype(h.dtype))
        h = h + attn.attention(cfg, xp["xattn"], hx, positions,
                               causal=False, kv=(mk, mv))
        h = h + swiglu(apply_norm(cfg, h, lp["ln2"]), lp["mlp"])
        return h, {"load_balance_loss": jnp.zeros((), jnp.float32),
                   "z_loss": jnp.zeros((), jnp.float32)}

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, auxs = jax.lax.scan(body, x, (params["layers"],
                                     params["xattn_layers"]))
    return x, jax.tree.map(jnp.sum, auxs)


# ----------------------------------------------------------------------- #
# Full-sequence forward (training)
# ----------------------------------------------------------------------- #

def forward(cfg, params: Params, batch: dict) -> tuple[jax.Array, dict]:
    """Returns (logits [B,T,paddedV], aux)."""
    dtype = jnp.dtype(cfg.dtype)
    if cfg.encoder_decoder:
        memory = _encoder_stack(cfg, params, batch["frames"].astype(dtype))
        tokens = batch["tokens"]
        x = embed(tokens, params["embed"], dtype)
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]),
                                     tokens.shape)
        x, aux = _decoder_stack_xattn(cfg, params, x, positions, memory)
    else:
        tokens = batch["tokens"]
        x = embed(tokens, params["embed"], dtype)
        if cfg.frontend == "vision" and "patches" in batch:
            x = jnp.concatenate([batch["patches"].astype(dtype), x], axis=1)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x, aux = _decoder_stack(cfg, params, x, positions)
    x = apply_norm(cfg, x, params["final_norm"])
    logits = unembed(x, params["embed"])
    return logits, aux


def loss_fn(cfg, params: Params, batch: dict, *, z_loss: float = 1e-4,
            moe_aux: float = 1e-2) -> tuple[jax.Array, dict]:
    """Next-token cross entropy; batch['tokens'] is [B, T+1]."""
    tokens = batch["tokens"]
    inner = dict(batch)
    inner["tokens"] = tokens[:, :-1]
    logits, aux = forward(cfg, params, inner)
    labels = tokens[:, 1:]
    if cfg.frontend == "vision" and "patches" in batch:
        logits = logits[:, batch["patches"].shape[1]:]
    logits = logits.astype(jnp.float32)
    # Keep the padded-vocab dim model-sharded through the loss: the gold
    # logit is extracted with an elementwise one-hot reduction (a
    # take_along_axis gather would force an all-gather of the full
    # [B,T,V] logits — observed +100GB/device in the dry-run).
    logits = maybe_shard(logits, ("pod", "data"), None, "model")
    vocab_ids = jnp.arange(cfg.padded_vocab)
    vmask = vocab_ids < cfg.vocab_size
    logits = jnp.where(vmask, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = labels[..., None] == vocab_ids
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = (logz - gold).mean()
    total = nll + z_loss * jnp.mean(logz ** 2)
    metrics = {"nll": nll, "ppl_log": nll}
    if cfg.moe:
        total = total + moe_aux * aux["load_balance_loss"] \
            + 1e-3 * aux["z_loss"]
        metrics["moe_lb"] = aux["load_balance_loss"]
    return total, metrics


# ----------------------------------------------------------------------- #
# Serving: prefill + decode.
#
# Two cache layouts:
#   * UNIFORM archs (same attention kind + window on every layer; no
#     enc-dec/hybrid): caches are STACKED arrays [L, B, ...] and the layer
#     loop is a lax.scan — small HLO, tractable compiles for 60-64-layer
#     models on the 512-device dry-run. Leading dense (deepseek/moonshot)
#     layers run unrolled with their caches in a "dense" list.
#   * heterogeneous archs (hymba per-layer windows, seamless enc-dec):
#     per-layer list of dicts, unrolled loop.
# ----------------------------------------------------------------------- #

def _layer_slice(params: Params, i: int) -> Params:
    """Extract layer i's params from the stacked pytree."""
    return jax.tree.map(lambda x: x[i], params["layers"])


def _resolved_layer(cfg, params: Params, i: int) -> tuple[Params, bool]:
    if i < cfg.first_dense_layers:
        return params[f"dense_layer_{i}"], False
    return _layer_slice(params, i - cfg.first_dense_layers), cfg.moe


def uniform_serving(cfg) -> bool:
    windows = _window_schedule(cfg)
    return (not cfg.hybrid_parallel and not cfg.encoder_decoder
            and len(set(int(w) for w in windows)) == 1)


def _one_layer_cache(cfg, batch: int, max_len: int, window: int,
                     dtype) -> dict:
    c: dict = {}
    dh = cfg.resolved_head_dim
    if not cfg.attn_free:
        if cfg.attn_kind == "mla":
            c["c_kv"] = jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype)
            c["k_rope"] = jnp.zeros((batch, max_len, cfg.qk_rope_head_dim),
                                    dtype)
        else:
            size = max_len if window == 0 else min(max_len, window)
            c["k"] = jnp.zeros((batch, size, cfg.n_kv_heads, dh), dtype)
            c["v"] = jnp.zeros((batch, size, cfg.n_kv_heads, dh), dtype)
    if cfg.ssm:
        c["ssm"] = ssm_mod.ssm_init_cache(cfg, batch, dtype)
    return c


def init_cache(cfg, batch: int, max_len: int, dtype):
    """Decode caches (layout per module docstring)."""
    windows = _window_schedule(cfg)
    if uniform_serving(cfg):
        n_scan = cfg.n_layers - cfg.first_dense_layers
        one = _one_layer_cache(cfg, batch, max_len, int(windows[0]), dtype)
        stack = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_scan,) + x.shape), one)
        dense = [_one_layer_cache(cfg, batch, max_len, int(windows[i]), dtype)
                 for i in range(cfg.first_dense_layers)]
        return {"dense": dense, "stack": stack}
    return [_one_layer_cache(cfg, batch, max_len, int(windows[i]), dtype)
            for i in range(cfg.n_layers)]


def _decode_layer(cfg, lp: Params, moe_layer: bool, c: dict, x, pos,
                  window, memory=None, xp=None):
    """One layer of single-token decode; returns (x, new_cache)."""
    dtype = x.dtype
    c = dict(c)
    h = apply_norm(cfg, x, lp["ln1"])
    if cfg.hybrid_parallel:
        a, c["k"], c["v"] = attn.attention_decode(
            cfg, lp["attn"], h, pos, c["k"], c["v"], window=window)
        m, c["ssm"] = ssm_mod.ssm_decode(cfg, lp["ssm"], h, c["ssm"])
        x = x + 0.5 * (apply_norm(cfg, a, lp["branch_norm_attn"])
                       + apply_norm(cfg, m, lp["branch_norm_ssm"]))
    elif cfg.ssm:
        m, c["ssm"] = ssm_mod.ssm_decode(cfg, lp["ssm"], h, c["ssm"])
        x = x + m
    elif cfg.attn_kind == "mla":
        a, c["c_kv"], c["k_rope"] = mla_mod.mla_decode(
            cfg, lp["attn"], h, pos, c["c_kv"], c["k_rope"],
            absorbed=cfg.mla_absorbed_decode)
        x = x + a
    else:
        a, c["k"], c["v"] = attn.attention_decode(
            cfg, lp["attn"], h, pos, c["k"], c["v"], window=window)
        x = x + a
    if cfg.encoder_decoder and memory is not None and xp is not None:
        hx = apply_norm(cfg, x, xp["ln_x"])
        mk = jnp.einsum("bsd,dhk->bshk", memory,
                        xp["xattn"]["wk"].astype(dtype))
        mv = jnp.einsum("bsd,dhk->bshk", memory,
                        xp["xattn"]["wv"].astype(dtype))
        x = x + attn.attention(cfg, xp["xattn"], hx, pos[:, None],
                               causal=False, kv=(mk, mv))
    if "ln2" in lp:
        h2 = apply_norm(cfg, x, lp["ln2"])
        if moe_layer:
            y, _ = moe_mod.moe_ffn(cfg, lp["moe"], h2)
            x = x + y
        else:
            x = x + swiglu(h2, lp["mlp"])
    return x, c


def decode_step(cfg, params: Params, caches, token: jax.Array,
                pos: jax.Array, memory: jax.Array | None = None):
    """token: [B] int32; pos: [B] absolute position. Returns
    (logits [B, paddedV], new caches)."""
    dtype = jnp.dtype(cfg.dtype)
    x = embed(token[:, None], params["embed"], dtype)  # [B,1,D]
    windows = _window_schedule(cfg)
    if uniform_serving(cfg):
        new_dense = []
        for i in range(cfg.first_dense_layers):
            lp, _ = _resolved_layer(cfg, params, i)
            x, c = _decode_layer(cfg, lp, False, caches["dense"][i], x, pos,
                                 int(windows[i]))
            new_dense.append(c)
        w0 = int(windows[cfg.first_dense_layers]) \
            if cfg.first_dense_layers < cfg.n_layers else 0

        def body(carry, scanned):
            h = carry
            lp, c = scanned
            lp = gather_layer_params(cfg, lp)
            h, c2 = _decode_layer(cfg, lp, cfg.moe, c, h, pos, w0)
            return h, c2

        x, new_stack = jax.lax.scan(body, x,
                                    (params["layers"], caches["stack"]))
        new_caches = {"dense": new_dense, "stack": new_stack}
    else:
        new_list = []
        for i in range(cfg.n_layers):
            lp, moe_layer = _resolved_layer(cfg, params, i)
            xp = (jax.tree.map(lambda t: t[i], params["xattn_layers"])
                  if cfg.encoder_decoder else None)
            x, c = _decode_layer(cfg, lp, moe_layer, caches[i], x, pos,
                                 int(windows[i]), memory=memory, xp=xp)
            new_list.append(c)
        new_caches = new_list
    x = apply_norm(cfg, x, params["final_norm"])
    logits = unembed(x[:, 0], params["embed"])
    return logits, new_caches


def _prefill_layer(cfg, lp: Params, moe_layer: bool, x, positions, window,
                   max_len: int, memory=None, xp=None):
    """One layer of prefill; returns (x, cache_dict)."""
    dtype = x.dtype
    b, t = x.shape[0], x.shape[1]
    c = _one_layer_cache(cfg, b, max_len, window, dtype)
    h = apply_norm(cfg, x, lp["ln1"])
    if cfg.hybrid_parallel:
        a, (k, v) = attn.attention_prefill(cfg, lp["attn"], h, positions,
                                           window=window)
        _write_kv(c, k, v, t)
        m = ssm_mod.ssm_forward(cfg, lp["ssm"], h)
        c["ssm"] = _ssm_prefill_cache(cfg, lp["ssm"], h, c["ssm"])
        x = x + 0.5 * (apply_norm(cfg, a, lp["branch_norm_attn"])
                       + apply_norm(cfg, m, lp["branch_norm_ssm"]))
    elif cfg.ssm:
        m = ssm_mod.ssm_forward(cfg, lp["ssm"], h)
        c["ssm"] = _ssm_prefill_cache(cfg, lp["ssm"], h, c["ssm"])
        x = x + m
    elif cfg.attn_kind == "mla":
        a, (ckv, kr) = mla_mod.mla_prefill(cfg, lp["attn"], h, positions)
        c["c_kv"] = c["c_kv"].at[:, :t].set(ckv)
        c["k_rope"] = c["k_rope"].at[:, :t].set(kr)
        x = x + a
    else:
        a, (k, v) = attn.attention_prefill(cfg, lp["attn"], h, positions,
                                           window=window)
        _write_kv(c, k, v, t)
        x = x + a
    if cfg.encoder_decoder and memory is not None and xp is not None:
        hx = apply_norm(cfg, x, xp["ln_x"])
        mk = jnp.einsum("bsd,dhk->bshk", memory,
                        xp["xattn"]["wk"].astype(dtype))
        mv = jnp.einsum("bsd,dhk->bshk", memory,
                        xp["xattn"]["wv"].astype(dtype))
        x = x + attn.attention(cfg, xp["xattn"], hx, positions,
                               causal=False, kv=(mk, mv))
    if "ln2" in lp:
        h2 = apply_norm(cfg, x, lp["ln2"])
        if moe_layer:
            y, _ = moe_mod.moe_ffn(cfg, lp["moe"], h2)
            x = x + y
        else:
            x = x + swiglu(h2, lp["mlp"])
    return x, c


def prefill(cfg, params: Params, batch: dict, max_len: int):
    """Run the full prompt, build decode caches.

    Returns (last-token logits [B, paddedV], caches, memory|None)."""
    dtype = jnp.dtype(cfg.dtype)
    memory = None
    if cfg.encoder_decoder:
        memory = _encoder_stack(cfg, params, batch["frames"].astype(dtype))
    tokens = batch["tokens"]
    x = embed(tokens, params["embed"], dtype)
    if cfg.frontend == "vision" and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(dtype), x], axis=1)
    b, t = x.shape[0], x.shape[1]
    if cfg.serve_seq_parallel and t % 16 == 0:
        # Small-model serving (§Perf H1.2): weights replicated, sequence
        # sharded over the model axis — elementwise/FFN/proj work divides
        # 16-way with zero collectives; only attention K/V gather per layer.
        x = maybe_shard(x, ("pod", "data"), "model", None)
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    windows = _window_schedule(cfg)
    if uniform_serving(cfg):
        dense = []
        for i in range(cfg.first_dense_layers):
            lp, _ = _resolved_layer(cfg, params, i)
            x, c = _prefill_layer(cfg, lp, False, x, positions,
                                  int(windows[i]), max_len)
            dense.append(c)
        w0 = int(windows[cfg.first_dense_layers]) \
            if cfg.first_dense_layers < cfg.n_layers else 0

        def body(carry, lp):
            h = carry
            lp = gather_layer_params(cfg, lp)
            h, c = _prefill_layer(cfg, lp, cfg.moe, h, positions, w0,
                                  max_len)
            return h, c

        if cfg.remat == "full":
            body = jax.checkpoint(body)
        x, stack = jax.lax.scan(body, x, params["layers"])
        caches = {"dense": dense, "stack": stack}
    else:
        clist = []
        sp = cfg.serve_seq_parallel and t % 16 == 0
        for i in range(cfg.n_layers):
            lp, moe_layer = _resolved_layer(cfg, params, i)
            xp = (jax.tree.map(lambda q: q[i], params["xattn_layers"])
                  if cfg.encoder_decoder else None)
            x, c = _prefill_layer(cfg, lp, moe_layer, x, positions,
                                  int(windows[i]), max_len, memory=memory,
                                  xp=xp)
            if sp:  # re-pin SP after gathers (SSM scans etc.) — §Perf H1.2
                x = maybe_shard(x, ("pod", "data"), "model", None)
            clist.append(c)
        caches = clist
    x = apply_norm(cfg, x, params["final_norm"])
    logits = unembed(x[:, -1], params["embed"])
    return logits, caches, memory


def _write_kv(c: dict, k: jax.Array, v: jax.Array, t: int) -> None:
    """Write prefill K/V into the (possibly window-sized) ring cache."""
    size = c["k"].shape[1]
    if size >= t:
        c["k"] = c["k"].at[:, :t].set(k)
        c["v"] = c["v"].at[:, :t].set(v)
    else:
        # keep the last `size` positions at their ring slots (p mod size)
        last_k, last_v = k[:, t - size:], v[:, t - size:]
        pos = jnp.arange(t - size, t) % size
        c["k"] = c["k"].at[:, pos].set(last_k)
        c["v"] = c["v"].at[:, pos].set(last_v)


def _ssm_prefill_cache(cfg, lp: dict, h: jax.Array, cache: dict) -> dict:
    """Recompute the final SSM state + conv window for decode handoff.

    (Runs the naive recurrence's final-state computation; the forward pass
    already produced outputs via the chunked path.)"""
    d_inner, nh = ssm_mod.ssm_dims(cfg)
    n = cfg.ssm_state
    z, xbc, dtraw = ssm_mod._split_proj(cfg, lp, h)
    xbc_conv = ssm_mod._causal_conv(lp, xbc, h.dtype)
    x = xbc_conv[..., :d_inner]
    b = xbc_conv[..., d_inner:d_inner + n].astype(jnp.float32)
    cc = xbc_conv[..., d_inner + n:]
    bs, t, _ = h.shape
    pdim = cfg.ssm_head_dim
    xh = x.reshape(bs, t, nh, pdim).astype(jnp.float32)
    dt = jax.nn.softplus(dtraw.astype(jnp.float32)
                         + lp["dt_bias"][None, None].astype(jnp.float32))
    neg_a = -jnp.exp(lp["a_log"].astype(jnp.float32))

    def step(state, inp):
        x_t, dt_t, b_t = inp
        a_t = jnp.exp(dt_t * neg_a[None])
        return (a_t[..., None, None] * state
                + jnp.einsum("bh,bn,bhp->bhnp", dt_t, b_t, x_t)), None

    init = cache["state"]
    state, _ = jax.lax.scan(step, init, (jnp.moveaxis(xh, 1, 0),
                                         jnp.moveaxis(dt, 1, 0),
                                         jnp.moveaxis(b, 1, 0)))
    width = cfg.ssm_conv_width
    conv = xbc[:, t - (width - 1):, :] if t >= width - 1 else jnp.pad(
        xbc, ((0, 0), (width - 1 - t, 0), (0, 0)))
    return {"conv": conv, "state": state}
