"""Shared neural layers: norms, RoPE, MLPs, embeddings (pure JAX)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * scale.astype(dt) + bias.astype(dt)


def apply_norm(cfg, x: jax.Array, p: dict) -> jax.Array:
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def norm_params(cfg, dim: int) -> dict:
    p = {"scale": jnp.ones((dim,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((dim,), jnp.float32)
    return p


# ----------------------------------------------------------------------- #
# RoPE
# ----------------------------------------------------------------------- #

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float) -> jax.Array:
    """x: [..., T, H, Dh]; positions: [..., T] int32."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta), jnp.float32)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, Dh/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------- #
# MLP
# ----------------------------------------------------------------------- #

def swiglu(x: jax.Array, p: dict) -> jax.Array:
    """SwiGLU MLP: (silu(x @ w_gate) * (x @ w_up)) @ w_down."""
    g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(x.dtype))


def swiglu_params(key: jax.Array, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_ff)
    return {
        "w_gate": jax.random.normal(k1, (d_model, d_ff), jnp.float32) * s_in,
        "w_up": jax.random.normal(k2, (d_model, d_ff), jnp.float32) * s_in,
        "w_down": jax.random.normal(k3, (d_ff, d_model), jnp.float32) * s_out,
    }


# ----------------------------------------------------------------------- #
# Embedding / unembedding
# ----------------------------------------------------------------------- #

def embed_params(key: jax.Array, padded_vocab: int, d_model: int,
                 tie: bool) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"embedding": jax.random.normal(
        k1, (padded_vocab, d_model), jnp.float32) * 0.02}
    if not tie:
        p["unembed"] = jax.random.normal(
            k2, (padded_vocab, d_model), jnp.float32) * 0.02
    return p


def embed(tokens: jax.Array, p: dict, dtype) -> jax.Array:
    return p["embedding"].astype(dtype)[tokens]


def unembed(x: jax.Array, p: dict) -> jax.Array:
    w = p.get("unembed", p["embedding"])
    return jnp.einsum("...d,vd->...v", x, w.astype(x.dtype))
