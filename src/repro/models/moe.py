"""Mixture-of-Experts: token-choice top-k, capacity-bounded slot dispatch.

Static-shape dispatch (TPU/XLA-friendly, EP-shardable):
  1. router softmax -> top-k (expert, weight) per token,
  2. rank tokens within each expert (sorted scatter), drop beyond capacity,
  3. scatter tokens into an [E, C, D] slot buffer (this is where GSPMD
     inserts the data->expert all-to-all when E is sharded on `model`),
  4. one batched einsum per matrix over all experts (MXU-dense),
  5. weighted scatter-add back to token positions.

Aux losses: switch-style load balance + router z-loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.constraints import maybe_shard


def moe_params(key: jax.Array, cfg) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s_in,
        "w_gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) * s_in,
        "w_up": jax.random.normal(ks[2], (e, d, f), jnp.float32) * s_in,
        "w_down": jax.random.normal(ks[3], (e, f, d), jnp.float32) * s_out,
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": jax.random.normal(k1, (d, fs), jnp.float32) * s_in,
            "w_up": jax.random.normal(k2, (d, fs), jnp.float32) * s_in,
            "w_down": jax.random.normal(k3, (fs, d), jnp.float32) * s_out,
        }
    return p


def moe_ffn(cfg, p: dict, x: jax.Array) -> tuple[jax.Array, dict]:
    """x: [B, T, D] -> (y, aux) with aux = {load_balance_loss, z_loss}."""
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(b * t, d)
    n = b * t
    cap = int(np.ceil(n * k / e * cfg.capacity_factor))

    logits = jnp.einsum("nd,de->ne", xf, p["router"].astype(x.dtype))
    logits32 = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits32, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                     # [N, k]
    top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9)

    # Rank each (token, k) entry within its expert by flat order.
    flat_e = top_e.reshape(-1)                                  # [N*k]
    token_of = jnp.repeat(jnp.arange(n), k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # position within expert = index_in_sorted - start_of_expert
    starts = jnp.cumsum(jnp.bincount(sorted_e, length=e)) - jnp.bincount(
        sorted_e, length=e)
    rank_sorted = jnp.arange(n * k) - starts[sorted_e]
    rank = jnp.zeros(n * k, jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    ok = rank < cap
    # Dropped entries are clamped into slot 0 but contribute zeros (masked
    # add), so no overflow row is needed and the flat buffer stays exactly
    # [E*C, D] — shardable on the expert blocks (E*C % model_size == 0).
    slot = jnp.where(ok, flat_e * cap + rank, 0)
    okf = ok.astype(x.dtype)[:, None]

    # Dispatch scatter-add; constrain to expert-parallel sharding so the
    # buffer (and the scatter producing it) partitions over the `model`
    # axis instead of replicating (this is the data->expert all-to-all).
    buf = jnp.zeros((e * cap, d), x.dtype).at[slot].add(
        xf[token_of] * okf, mode="drop")
    buf = maybe_shard(buf, "model", None)
    h = buf.reshape(e, cap, d)
    h = maybe_shard(h, "model", None, None)
    g = jnp.einsum("ecd,edf->ecf", h, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", h, p["w_up"].astype(x.dtype))
    o = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                   p["w_down"].astype(x.dtype))
    o = maybe_shard(o, "model", None, None).reshape(e * cap, d)

    # Combine: weighted masked gather + scatter-add back to tokens.
    contrib = o[slot] * (top_w.reshape(-1)[:, None].astype(x.dtype) * okf)
    y = jnp.zeros((n, d), x.dtype).at[token_of].add(contrib)
    y = maybe_shard(y, ("pod", "data"), None)

    if cfg.n_shared_experts:
        sp = p["shared"]
        sg = jnp.einsum("nd,df->nf", xf, sp["w_gate"].astype(x.dtype))
        su = jnp.einsum("nd,df->nf", xf, sp["w_up"].astype(x.dtype))
        y = y + jnp.einsum("nf,fd->nd", jax.nn.silu(sg) * su,
                           sp["w_down"].astype(x.dtype))

    # Aux losses (fp32).
    me = probs.mean(0)                                          # mean prob/expert
    ce = jnp.zeros(e, jnp.float32).at[flat_e].add(1.0) / (n * k)  # token frac
    aux = {
        "load_balance_loss": e * jnp.sum(me * ce),
        "z_loss": jnp.mean(jax.nn.logsumexp(logits32, axis=-1) ** 2),
    }
    return y.reshape(b, t, d), aux
