"""GQA attention (QKV bias, QK-norm, sliding window) with KV-cache decode."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, rms_norm

NEG_INF = -1e30


def gqa_params(key: jax.Array, cfg) -> dict:
    d, h, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dh = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    p = {
        "wq": jax.random.normal(ks[0], (d, h, dh), jnp.float32) * s,
        "wk": jax.random.normal(ks[1], (d, hkv, dh), jnp.float32) * s,
        "wv": jax.random.normal(ks[2], (d, hkv, dh), jnp.float32) * s,
        "wo": jax.random.normal(ks[3], (h, dh, d), jnp.float32) / np.sqrt(h * dh),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), jnp.float32)
        p["bk"] = jnp.zeros((hkv, dh), jnp.float32)
        p["bv"] = jnp.zeros((hkv, dh), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return p


def _project_qkv(cfg, p: dict, x: jax.Array, positions: jax.Array):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask(t: int, s: int, causal: bool, window,
          q_offset: int = 0) -> jax.Array:
    """[t, s] additive mask; query i is at absolute position q_offset + i.
    ``window`` may be a traced scalar (per-layer SWA schedule under scan);
    window <= 0 means full attention."""
    qpos = jnp.arange(t)[:, None] + q_offset
    kpos = jnp.arange(s)[None, :]
    ok = jnp.ones((t, s), bool)
    if causal:
        ok = ok & (kpos <= qpos)
    window = jnp.asarray(window)
    ok = ok & ((window <= 0) | (kpos > qpos - window))
    return jnp.where(ok, 0.0, NEG_INF)


# KV sequence lengths at or above this use the chunked (flash-style) path:
# full [T, S] logits for 32k x 32k would be tens of GB per device.
CHUNKED_SDPA_THRESHOLD = 8192
KV_BLOCK = 1024


def _sdpa_dense(q, k, v, mask):
    """q:[B,T,H,dh] k,v:[B,S,Hkv,dh]; grouped heads; fp32 softmax."""
    b, t, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    q = q.reshape(b, t, hkv, g, dh)
    logits = jnp.einsum("bthgk,bshk->bhgts", q, k) / np.sqrt(dh)
    logits = logits.astype(jnp.float32) + mask
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgts,bshk->bthgk", probs, v)
    return out.reshape(b, t, h, dh)


def _sdpa_chunked(q, k, v, causal: bool, window, q_offset: int = 0,
                  kv_block: int = KV_BLOCK):
    """Flash-style online-softmax attention: lax.scan over KV blocks.

    Never materializes the [T, S] logits — peak extra memory is one
    [B, Hkv, G, T, kv_block] block. This is what makes the 32k-prefill
    cells fit (see EXPERIMENTS.md §Dry-run)."""
    b, t, h, dh = q.shape
    s = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    if s % kv_block:
        pad = kv_block - s % kv_block
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s_pad = s + pad
    else:
        s_pad = s
    nb = s_pad // kv_block
    qr = (q.reshape(b, t, hkv, g, dh) / np.sqrt(dh)).astype(q.dtype)
    kb = k.reshape(b, nb, kv_block, hkv, dh)
    vb = v.reshape(b, nb, kv_block, hkv, dh)
    qpos = jnp.arange(t) + q_offset
    window = jnp.asarray(window)

    def body(carry, blk):
        m_prev, l_prev, acc = carry
        k_blk, v_blk, start = blk
        logits = jnp.einsum("bthgk,bshk->bhgts", qr, k_blk
                            ).astype(jnp.float32)
        kpos = start + jnp.arange(kv_block)
        ok = kpos[None, :] < s  # padding
        if causal:
            ok = ok & (kpos[None, :] <= qpos[:, None])
        ok = ok & ((window <= 0) | (kpos[None, :] > qpos[:, None] - window))
        logits = jnp.where(ok[None, None, None], logits, NEG_INF)
        m_blk = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(logits - m_new[..., None])
        scale = jnp.exp(m_prev - m_new)
        l_new = l_prev * scale + p.sum(-1)
        acc = acc * scale[..., None] + jnp.einsum(
            "bhgts,bshk->bhgtk", p.astype(v_blk.dtype), v_blk
        ).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g, t), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, t), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, t, dh), jnp.float32)
    starts = jnp.arange(nb) * kv_block
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), starts))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 3, 1)           # [B,T,Hkv,G,dh]
    return out.reshape(b, t, h, dh).astype(q.dtype)


def _sdpa(q, k, v, mask):
    return _sdpa_dense(q, k, v, mask)


def attention(cfg, p: dict, x: jax.Array, positions: jax.Array,
              causal: bool = True, window: int | None = None,
              kv: tuple[jax.Array, jax.Array] | None = None) -> jax.Array:
    """Full-sequence attention (train / encoder / prefill compute).

    ``kv``: cross-attention memory (enc-dec) — overrides self K/V.
    """
    q, k, v = _project_qkv(cfg, p, x, positions)
    if kv is not None:
        k, v = kv
        if k.shape[1] >= CHUNKED_SDPA_THRESHOLD:
            out = _sdpa_chunked(q, k, v, causal=False, window=0)
        else:
            mask = jnp.zeros((q.shape[1], k.shape[1]), jnp.float32)
            out = _sdpa(q, k, v, mask)
    else:
        w = cfg.sliding_window if window is None else window
        if k.shape[1] >= CHUNKED_SDPA_THRESHOLD:
            out = _sdpa_chunked(q, k, v, causal=causal, window=w)
        else:
            out = _sdpa(q, k, v, _mask(q.shape[1], k.shape[1], causal, w))
    return jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype))


def attention_prefill(cfg, p: dict, x: jax.Array, positions: jax.Array,
                      window: int | None = None):
    """Returns (out, (k_cache, v_cache)) for serving prefill."""
    q, k, v = _project_qkv(cfg, p, x, positions)
    if cfg.serve_seq_parallel:
        # SP serving: q stays sequence-sharded; K/V gather across the model
        # axis (the one collective of the scheme — §Perf H1.2).
        from repro.distributed.constraints import maybe_shard
        k = maybe_shard(k, ("pod", "data"), None, None, None)
        v = maybe_shard(v, ("pod", "data"), None, None, None)
    w = cfg.sliding_window if window is None else window
    if k.shape[1] >= CHUNKED_SDPA_THRESHOLD:
        out = _sdpa_chunked(q, k, v, causal=True, window=w)
    else:
        out = _sdpa(q, k, v, _mask(q.shape[1], k.shape[1], True, w))
    return jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype)), (k, v)


def attention_decode(cfg, p: dict, x: jax.Array, pos: jax.Array,
                     cache_k: jax.Array, cache_v: jax.Array,
                     window: int | None = None):
    """One-token decode. x: [B, 1, D]; pos: [B] current absolute position;
    cache_k/v: [B, C, Hkv, dh], ring-buffered (C = full seq for global
    layers, C = window for SWA layers). Returns (out [B,1,D], new_k, new_v).
    """
    b, _, d = x.shape
    q, k, v = _project_qkv(cfg, p, x, pos[:, None])
    c = cache_k.shape[1]
    idx = pos % c
    cache_k = cache_k.at[jnp.arange(b), idx].set(k[:, 0])
    cache_v = cache_v.at[jnp.arange(b), idx].set(v[:, 0])
    w = cfg.sliding_window if window is None else window
    kpos = jnp.arange(c)[None, :]
    # Absolute position held by ring slot i: pos - ((pos - i) mod C).
    slot_pos = pos[:, None] - ((pos[:, None] - kpos) % c)
    ok = slot_pos >= 0
    if w and w > 0:
        ok = ok & (slot_pos > pos[:, None] - w)
    mask = jnp.where(ok, 0.0, NEG_INF)[:, None, None, None, :]  # b h g t s
    hkv = cache_k.shape[2]
    g = cfg.n_heads // hkv
    dh = cfg.resolved_head_dim
    qr = q.reshape(b, 1, hkv, g, dh)
    logits = jnp.einsum("bthgk,bshk->bhgts", qr, cache_k) / np.sqrt(dh)
    logits = logits.astype(jnp.float32) + mask
    probs = jax.nn.softmax(logits, axis=-1).astype(cache_v.dtype)
    out = jnp.einsum("bhgts,bshk->bthgk", probs, cache_v).reshape(b, 1,
                                                                  cfg.n_heads, dh)
    out = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype))
    return out, cache_k, cache_v
