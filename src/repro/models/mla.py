"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

K/V are compressed into a ``kv_lora_rank`` latent c_kv plus one shared RoPE
key head; queries go through their own low-rank path. Train/prefill
decompress to per-head K/V; decode uses the ABSORBED form — scores and
values are computed directly against the cached latent:

    score[t,h] = (q_nope[h] @ W_uk[h]^T) . c_kv[t]  +  q_rope[h] . k_rope[t]
    out[h]     = (sum_t p[t,h] c_kv[t]) @ W_uv[h]

so the decode cache is [T, kv_lora + rope_dim] (= 576 for DS-V2) instead of
[T, 2*H*dh] (= 65536) — a 113x cache reduction; this is also the §Perf lever
for the deepseek decode cells.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, rms_norm

NEG_INF = -1e30


def mla_params(key: jax.Array, cfg) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    dq, dkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    s = 1.0 / np.sqrt(d)
    p = {
        "w_dq": jax.random.normal(ks[0], (d, dq), jnp.float32) * s,
        "q_norm": jnp.ones((dq,), jnp.float32),
        "w_uq": jax.random.normal(ks[1], (dq, h, dn + dr), jnp.float32)
                / np.sqrt(dq),
        "w_dkv": jax.random.normal(ks[2], (d, dkv), jnp.float32) * s,
        "kv_norm": jnp.ones((dkv,), jnp.float32),
        "w_kr": jax.random.normal(ks[3], (d, dr), jnp.float32) * s,
        "w_uk": jax.random.normal(ks[4], (dkv, h, dn), jnp.float32)
                / np.sqrt(dkv),
        "w_uv": jax.random.normal(ks[5], (dkv, h, dv), jnp.float32)
                / np.sqrt(dkv),
        "wo": jax.random.normal(ks[6], (h, dv, d), jnp.float32)
              / np.sqrt(h * dv),
    }
    return p


def _q_proj(cfg, p, x, positions):
    cq = rms_norm(jnp.einsum("btd,dr->btr", x, p["w_dq"].astype(x.dtype)),
                  p["q_norm"])
    q = jnp.einsum("btr,rhk->bthk", cq, p["w_uq"].astype(x.dtype))
    dn = cfg.qk_nope_head_dim
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _kv_latent(cfg, p, x, positions):
    c_kv = rms_norm(jnp.einsum("btd,dr->btr", x, p["w_dkv"].astype(x.dtype)),
                    p["kv_norm"])
    k_rope = jnp.einsum("btd,dr->btr", x, p["w_kr"].astype(x.dtype))
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


CHUNKED_THRESHOLD = 8192
KV_BLOCK = 1024


def _mla_chunked(cfg, p, q_nope, q_rope, c_kv, k_rope, kv_block=KV_BLOCK):
    """Flash-style online softmax over latent KV blocks; K/V decompress
    happens PER BLOCK inside the scan, so neither the [T,S] logits nor the
    full decompressed K/V ([B,S,H,dh] — 128 heads!) ever materialize."""
    b, t, h, dn = q_nope.shape
    s = c_kv.shape[1]
    scale = 1.0 / np.sqrt(dn + cfg.qk_rope_head_dim)
    dv = cfg.v_head_dim
    if s % kv_block:
        pad = kv_block - s % kv_block
        c_kv = jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    nb = c_kv.shape[1] // kv_block
    cb = jnp.moveaxis(c_kv.reshape(b, nb, kv_block, -1), 1, 0)
    rb = jnp.moveaxis(k_rope.reshape(b, nb, kv_block, -1), 1, 0)
    starts = jnp.arange(nb) * kv_block
    qn = (q_nope * scale).astype(q_nope.dtype)
    qr = (q_rope * scale).astype(q_rope.dtype)
    qpos = jnp.arange(t)

    def body(carry, blk):
        m_prev, l_prev, acc = carry
        c_blk, r_blk, start = blk
        k_nope = jnp.einsum("bsr,rhk->bshk", c_blk,
                            p["w_uk"].astype(c_blk.dtype))
        v_blk = jnp.einsum("bsr,rhk->bshk", c_blk,
                           p["w_uv"].astype(c_blk.dtype))
        logits = (jnp.einsum("bthk,bshk->bhts", qn, k_nope)
                  + jnp.einsum("bthk,bsk->bhts", qr, r_blk)
                  ).astype(jnp.float32)
        kpos = start + jnp.arange(kv_block)
        ok = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] < s)
        logits = jnp.where(ok[None, None], logits, NEG_INF)
        m_blk = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_blk)
        pr = jnp.exp(logits - m_new[..., None])
        sc = jnp.exp(m_prev - m_new)
        l_new = l_prev * sc + pr.sum(-1)
        acc = acc * sc[..., None] + jnp.einsum(
            "bhts,bshk->bhtk", pr.astype(v_blk.dtype), v_blk
        ).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, t), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, t), jnp.float32)
    a0 = jnp.zeros((b, h, t, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (cb, rb, starts))
    out = (acc / jnp.maximum(l[..., None], 1e-30)).astype(q_nope.dtype)
    return jnp.moveaxis(out, 1, 2)  # [B,T,H,dv]


def mla_attention(cfg, p: dict, x: jax.Array, positions: jax.Array,
                  chunked: bool | None = None) -> jax.Array:
    """Train / full-sequence path (decompressed K/V; chunked when long)."""
    b, t, _ = x.shape
    dn, dv = cfg.qk_nope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _q_proj(cfg, p, x, positions)
    c_kv, k_rope = _kv_latent(cfg, p, x, positions)
    use_chunked = (t >= CHUNKED_THRESHOLD) if chunked is None else chunked
    if use_chunked:
        out = _mla_chunked(cfg, p, q_nope, q_rope, c_kv, k_rope)
        return jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype))
    k_nope = jnp.einsum("btr,rhk->bthk", c_kv, p["w_uk"].astype(x.dtype))
    v = jnp.einsum("btr,rhk->bthk", c_kv, p["w_uv"].astype(x.dtype))
    scale = 1.0 / np.sqrt(dn + cfg.qk_rope_head_dim)
    logits = (jnp.einsum("bthk,bshk->bhts", q_nope, k_nope)
              + jnp.einsum("bthk,bsk->bhts", q_rope, k_rope)) * scale
    qpos = jnp.arange(t)[:, None]
    mask = jnp.where(jnp.arange(t)[None, :] <= qpos, 0.0, NEG_INF)
    probs = jax.nn.softmax(logits.astype(jnp.float32) + mask,
                           axis=-1).astype(v.dtype)
    out = jnp.einsum("bhts,bshk->bthk", probs, v)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype))


def mla_prefill(cfg, p: dict, x: jax.Array, positions: jax.Array):
    """Returns (out, (c_kv_cache, k_rope_cache)) — the compressed cache."""
    out = mla_attention(cfg, p, x, positions)
    c_kv, k_rope = _kv_latent(cfg, p, x, positions)
    return out, (c_kv, k_rope)


def mla_decode(cfg, p: dict, x: jax.Array, pos: jax.Array,
               cache_c: jax.Array, cache_kr: jax.Array,
               absorbed: bool = True):
    """One-token decode against the compressed cache.

    absorbed=True: the beyond-paper-efficient path (no decompression).
    absorbed=False: naive baseline — decompress all K/V each step (used as
    the §Perf before/after comparison point).
    """
    b = x.shape[0]
    s = cache_c.shape[1]
    dn, dv, dr = cfg.qk_nope_head_dim, cfg.v_head_dim, cfg.qk_rope_head_dim
    q_nope, q_rope = _q_proj(cfg, p, x, pos[:, None])   # [B,1,H,*]
    c_kv, k_rope = _kv_latent(cfg, p, x, pos[:, None])
    idx = pos % s
    cache_c = cache_c.at[jnp.arange(b), idx].set(c_kv[:, 0])
    cache_kr = cache_kr.at[jnp.arange(b), idx].set(k_rope[:, 0])
    kpos = jnp.arange(s)[None, :]
    slot_pos = pos[:, None] - ((pos[:, None] - kpos) % s)
    mask = jnp.where(slot_pos >= 0, 0.0, NEG_INF)[:, None, :]  # [B,1,S]->bhs
    scale = 1.0 / np.sqrt(dn + dr)
    if absorbed:
        # q_abs[h] = q_nope[h] @ W_uk[h]^T  in latent space
        q_abs = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0],
                           p["w_uk"].astype(x.dtype))
        logits = (jnp.einsum("bhr,bsr->bhs", q_abs, cache_c)
                  + jnp.einsum("bhk,bsk->bhs", q_rope[:, 0], cache_kr)) * scale
        probs = jax.nn.softmax(logits.astype(jnp.float32) + mask,
                               axis=-1).astype(x.dtype)
        out_c = jnp.einsum("bhs,bsr->bhr", probs, cache_c)
        out = jnp.einsum("bhr,rhk->bhk", out_c, p["w_uv"].astype(x.dtype))
    else:
        k_nope = jnp.einsum("bsr,rhk->bshk", cache_c,
                            p["w_uk"].astype(x.dtype))
        v = jnp.einsum("bsr,rhk->bshk", cache_c, p["w_uv"].astype(x.dtype))
        logits = (jnp.einsum("bhk,bshk->bhs", q_nope[:, 0], k_nope)
                  + jnp.einsum("bhk,bsk->bhs", q_rope[:, 0], cache_kr)) * scale
        probs = jax.nn.softmax(logits.astype(jnp.float32) + mask,
                               axis=-1).astype(x.dtype)
        out = jnp.einsum("bhs,bshk->bhk", probs, v)
    out = jnp.einsum("bhk,hkd->bd", out, p["wo"].astype(x.dtype))
    return out[:, None, :], cache_c, cache_kr
