"""Data pipeline: deterministic synthetic corpus + memmap-backed token
streams, shard-aware sampling, background prefetch.

Production posture: the loader yields GLOBAL batches as host numpy; the
trainer device_puts them against the batch sharding (each host would feed
its addressable shards via `jax.make_array_from_process_local_data` on a real
multi-host deployment — single-process here, same code path).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 256
    global_batch: int = 8
    vocab_size: int = 512
    seed: int = 0
    kind: str = "synthetic-lm"   # synthetic-lm | memmap


class SyntheticLM:
    """Deterministic pseudo-corpus with learnable n-gram structure (so a
    training run shows a falling loss, not noise)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # Markov chain with sparse transitions -> learnable structure.
        self._next = rng.integers(0, v, size=(v, 4))

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed * 1_000_003 + step)
        b, t = cfg.global_batch, cfg.seq_len
        toks = np.empty((b, t + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, b)
        choices = rng.integers(0, 4, size=(b, t))
        for i in range(t):
            toks[:, i + 1] = self._next[toks[:, i], choices[:, i]]
        return {"tokens": toks}


class MemmapTokens:
    """Pre-tokenized flat corpus on disk; shard-aware strided sampling."""

    def __init__(self, path: str, cfg: DataConfig, shard: int = 0,
                 n_shards: int = 1):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.cfg = cfg
        self.shard = shard
        self.n_shards = n_shards

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        b, t = cfg.global_batch, cfg.seq_len
        n_windows = (len(self.tokens) - 1) // (t + 1)
        rng = np.random.default_rng(cfg.seed * 7919 + step)
        idx = rng.integers(0, n_windows, b)
        idx = idx[idx % self.n_shards == self.shard % self.n_shards] if \
            self.n_shards > 1 else idx
        while len(idx) < b:
            idx = np.concatenate([idx, idx])[:b]
        out = np.stack([self.tokens[i * (t + 1):(i + 1) * (t + 1)]
                        for i in idx[:b]])
        return {"tokens": out.astype(np.int32)}


def make_source(cfg: DataConfig, path: str | None = None):
    if cfg.kind == "memmap":
        if not path:
            raise ValueError("memmap source needs a path")
        return MemmapTokens(path, cfg)
    return SyntheticLM(cfg)


class Prefetcher:
    """Background-thread prefetch: overlaps host batch synthesis/IO with
    device compute (one of the compute/comm-overlap measures)."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self.q.put(self.source.batch(step), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
