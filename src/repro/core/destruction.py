"""Cold-boot-attack content destruction (paper §6.2, Fig 19).

Destroys a bank's contents by overwriting every row, using:
  * RowClone baseline [25]: 1 WR (pattern row) + one AAP per row,
  * FracDRAM baseline [26]: one Frac per row (rows left at VDD/2),
  * PULSAR: Bulk-Write seeds 2^k rows in one shot, then Multi-RowInit
    greedily covers the bank with the largest available activation blocks
    (each APA covers up to max_rows rows; the greedy N_RG cover issues the
    fewest sequences).

Both the *logical effect* (every row overwritten — verified on the chip
model in tests) and the *latency* (command scheduler) are produced.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.chip import PulsarChip
from repro.core.cost_model import CostModel, OpCost, ZERO
from repro.core.pulsar import PulsarExecutor, build_region


@dataclasses.dataclass
class DestructionReport:
    method: str
    n_sequences: int
    latency_ns: float
    energy_j: float
    rows_destroyed: int

    @property
    def latency_ms(self) -> float:
        return self.latency_ns * 1e-6


def plan_pulsar_cover(rows_per_subarray: int, n_subarrays: int,
                      max_block: int) -> list[int]:
    """Greedy block sizes covering one bank: per subarray, repeatedly take
    the largest power-of-two activation block that fits the remainder."""
    blocks = []
    for _ in range(n_subarrays):
        remaining = rows_per_subarray
        while remaining:
            b = min(max_block, 1 << (remaining.bit_length() - 1))
            blocks.append(b)
            remaining -= b
    return blocks


def pulsar_destruction_cost(cost: CostModel, rows_per_subarray: int,
                            n_subarrays: int, max_block: int) -> OpCost:
    blocks = plan_pulsar_cover(rows_per_subarray, n_subarrays, max_block)
    total = cost.bulk_write()  # seed the pattern into the first block
    for b in blocks:
        if b == 1:
            total = total + cost.aap()          # lone row: RowClone
        else:
            total = total + cost.aap()          # Multi-RowInit block
    return total


def rowclone_destruction_cost(cost: CostModel, n_rows: int) -> OpCost:
    return cost.write_row() + n_rows * cost.aap()


def fracdram_destruction_cost(cost: CostModel, n_rows: int) -> OpCost:
    return n_rows * cost.frac(True)


def destroy_bank_pulsar(chip: PulsarChip, bank: int,
                        pattern: int = 0) -> DestructionReport:
    """Execute PULSAR-based destruction on the chip model (verifiable)."""
    g = chip.geometry
    start_ops = chip.stats.n_ops
    start_lat = chip.stats.latency_ns
    start_e = chip.stats.energy_j
    data = np.full(g.words_per_row, pattern, np.uint32)
    for sa in range(g.subarrays_per_bank):
        x = PulsarExecutor(chip, bank, sa)
        max_block = x.max_n_rg()
        base = sa * g.rows_per_subarray
        covered: set[int] = set()
        # Seed with one Bulk-Write on the largest block.
        rows = x.bulk_write_block(data, max_block)
        covered.update(rows)
        seed_row = rows[0]
        # Greedy Multi-RowInit cover: walk remaining rows; for each uncovered
        # row, activate the largest block anchored near it.
        for r in range(base, base + g.rows_per_subarray):
            if r in covered:
                continue
            done = False
            b = max_block
            while b >= 2 and not done:
                try:
                    rf, rs = chip.decoder.find_group_pair(
                        sa, b, include=(r,))
                    got = set(chip.decoder.activated_rows(rf, rs))
                    if r in got:
                        chip.row_clone(bank, seed_row, rf)
                        chip.multi_row_init(bank, rf, rs)
                        covered.update(got)
                        covered.add(rf)
                        done = True
                except ValueError:
                    pass
                b >>= 1
            if not done:
                chip.row_clone(bank, seed_row, r)
                covered.add(r)
    return DestructionReport(
        method="pulsar",
        n_sequences=chip.stats.n_ops - start_ops,
        latency_ns=chip.stats.latency_ns - start_lat,
        energy_j=chip.stats.energy_j - start_e,
        rows_destroyed=g.rows_per_bank)


def destroy_bank_rowclone(chip: PulsarChip, bank: int,
                          pattern: int = 0) -> DestructionReport:
    g = chip.geometry
    start_ops, start_lat, start_e = (chip.stats.n_ops, chip.stats.latency_ns,
                                     chip.stats.energy_j)
    data = np.full(g.words_per_row, pattern, np.uint32)
    chip.write_row(bank, 0, data)
    for r in range(1, g.rows_per_bank):
        chip.row_clone(bank, 0, r)
    return DestructionReport(
        "rowclone", chip.stats.n_ops - start_ops,
        chip.stats.latency_ns - start_lat, chip.stats.energy_j - start_e,
        g.rows_per_bank)


def destroy_bank_fracdram(chip: PulsarChip, bank: int) -> DestructionReport:
    g = chip.geometry
    start_ops, start_lat, start_e = (chip.stats.n_ops, chip.stats.latency_ns,
                                     chip.stats.energy_j)
    for r in range(g.rows_per_bank):
        chip.frac(bank, r)
    return DestructionReport(
        "fracdram", chip.stats.n_ops - start_ops,
        chip.stats.latency_ns - start_lat, chip.stats.energy_j - start_e,
        g.rows_per_bank)
