"""Input-replication planning (paper §5.1, Fig 10).

For MAJ-M executed with an N-row simultaneous activation, inputs are
"replicated to the maximum extent possible; the remaining rows are then set
to the neutral state": copies = N // M, neutrals = N - M*copies.

With M odd and equal copies c, the charge-shared vote never ties
(net = c * (ones - zeros), |ones - zeros| >= 1), so logical correctness is
preserved: MAJ_{cM+n_neutral}(replicated inputs, neutrals) == MAJ_M(inputs).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ReplicationPlan:
    m_inputs: int      # majority fan-in (odd)
    n_rg: int          # simultaneously activated rows
    copies: int        # copies of each input
    n_neutral: int     # Frac/neutral rows

    @property
    def worst_case_net_votes(self) -> int:
        """Minimum |weighted ones - zeros| over non-tie patterns."""
        return self.copies

    def row_assignment(self) -> list[int]:
        """Slot -> input index (or -1 for neutral) for the N_RG rows."""
        slots = []
        for i in range(self.m_inputs):
            slots.extend([i] * self.copies)
        slots.extend([-1] * self.n_neutral)
        return slots


def plan(m_inputs: int, n_rg: int) -> ReplicationPlan:
    if m_inputs % 2 == 0:
        raise ValueError("majority fan-in must be odd")
    if n_rg < m_inputs:
        raise ValueError(f"cannot perform MAJ{m_inputs} with only {n_rg} rows")
    copies = n_rg // m_inputs
    n_neutral = n_rg - m_inputs * copies
    return ReplicationPlan(m_inputs=m_inputs, n_rg=n_rg, copies=copies,
                           n_neutral=n_neutral)


def plan_pow2(m_inputs: int, n_rg: int) -> ReplicationPlan:
    """Staging-efficient variant: copies rounded DOWN to a power of two so
    each input occupies ONE buddy-aligned block and stages with a single
    seed RowClone + a single intra-block Multi-RowInit (2 AAPs), remaining
    rows neutral. The paper's plan (maximal copies, e.g. 10 for MAJ3@32)
    maximizes sensing margin; this one trades a little margin for init
    latency — both are exposed and the benchmarks search over them.
    """
    if m_inputs % 2 == 0:
        raise ValueError("majority fan-in must be odd")
    if n_rg < m_inputs:
        raise ValueError(f"cannot perform MAJ{m_inputs} with only {n_rg} rows")
    c = n_rg // m_inputs
    copies = 1 << (c.bit_length() - 1)
    return ReplicationPlan(m_inputs=m_inputs, n_rg=n_rg, copies=copies,
                           n_neutral=n_rg - m_inputs * copies)


def fracdram_plan(m_inputs: int = 3) -> ReplicationPlan:
    """FracDRAM baseline: MAJ3 on a 4-row activation, single copies + 1
    neutral (no replication)."""
    return ReplicationPlan(m_inputs=m_inputs, n_rg=m_inputs + 1, copies=1,
                           n_neutral=1)
