"""DRAM command DSL + scheduler.

PuM primitives are expressed as *command programs*; the scheduler assigns
issue times honoring the constraints that still bind under PuM operation:
tFAW (four-activation window, Appendix A power budget), tRRD between ACTs to
different banks, and explicit intra-sequence gaps (violated or nominal) that
the program encodes as ``min_gap`` from the previous command on the same bank.

This gives every benchmark an auditable latency/energy accounting, and the
logical chip model executes the same programs for bit-exact results — one
source of truth for both correctness and cost.
"""

from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Iterable

from repro.core.timing import DramTimings


class Op(enum.Enum):
    ACT = "act"
    PRE = "pre"
    WR = "wr"
    RD = "rd"
    NOP = "nop"


@dataclasses.dataclass(frozen=True)
class Cmd:
    op: Op
    bank: int = 0
    row: int = -1
    # Minimum time since the previous command issued to the same bank.
    # This encodes both nominal (tRAS, tRP, tRCD) and violated (t_apa_gap)
    # sequencing: programs are explicit about their timing intent.
    min_gap: float = 0.0
    tag: str = ""


@dataclasses.dataclass
class ScheduleResult:
    total_ns: float
    energy_j: float
    n_acts: int
    n_pres: int
    n_rdwr: int
    issue_times: list[float]
    # Parallel to ``issue_times``: the command issued at each time, so traces
    # are auditable per command (scheduled multi-bank streams reorder across
    # programs, so positional indexing into the input program is not enough).
    cmds: list[Cmd] = dataclasses.field(default_factory=list)

    @property
    def events(self) -> list[tuple[Cmd, float]]:
        """(cmd, issue_time) pairs in issue order."""
        return list(zip(self.cmds, self.issue_times))

    def counters(self, timings: DramTimings | None = None):
        """Derive a :class:`repro.telemetry.CounterBank` from this trace
        (bus utilization, row hit/miss/conflict, tRRD/tFAW stalls).
        Pure post-hoc replay — the schedule itself is untouched."""
        from repro.telemetry import derive_controller_counters
        return derive_controller_counters(self, timings)


class CommandScheduler:
    """Assigns issue times to a command stream.

    Constraints enforced:
      * per-bank ``min_gap`` sequencing (the program's timing intent),
      * tFAW: at most 4 ACTs per rolling tFAW window (rank-wide),
      * tRRD_S between ACTs to different banks.
    """

    def __init__(self, timings: DramTimings):
        self.t = timings

    def schedule(self, program: Iterable[Cmd]) -> ScheduleResult:
        t = self.t
        now = 0.0
        last_per_bank: dict[int, float] = {}
        act_window: deque[float] = deque()
        last_act = -1e30
        issue_times: list[float] = []
        issued: list[Cmd] = []
        n_acts = n_pres = n_rdwr = 0
        energy = 0.0
        for cmd in program:
            earliest = now
            prev = last_per_bank.get(cmd.bank)
            if prev is not None:
                earliest = max(earliest, prev + cmd.min_gap)
            else:
                earliest = max(earliest, now + cmd.min_gap if not last_per_bank else now)
            if cmd.op is Op.ACT:
                earliest = max(earliest, last_act + t.trrd_s)
                while len(act_window) >= 4:
                    # 4 most recent ACT issue times; 5th must wait tFAW.
                    window_start = act_window[0]
                    if earliest - window_start >= t.tfaw:
                        act_window.popleft()
                    else:
                        earliest = window_start + t.tfaw
                        act_window.popleft()
            issue_times.append(earliest)
            issued.append(cmd)
            last_per_bank[cmd.bank] = earliest
            now = earliest
            if cmd.op is Op.ACT:
                act_window.append(earliest)
                last_act = earliest
                n_acts += 1
                energy += t.e_act
            elif cmd.op is Op.PRE:
                n_pres += 1
                energy += t.e_pre
            elif cmd.op in (Op.WR, Op.RD):
                n_rdwr += 1
                energy += t.e_rdwr_burst
        # The stream's latency includes the tail gap implied by the final
        # command's own duration; programs end with a PRE whose min_gap
        # already accounts for restore, so add one tRP tail.
        total = (issue_times[-1] if issue_times else 0.0)
        return ScheduleResult(total_ns=total, energy_j=energy, n_acts=n_acts,
                              n_pres=n_pres, n_rdwr=n_rdwr,
                              issue_times=issue_times, cmds=issued)


# ---------------------------------------------------------------------- #
# Program builders for the PuM primitives (shared by cost model + chip).
# ---------------------------------------------------------------------- #

def prog_apa_charge_share(bank: int, rf: int, rs: int,
                          t: DramTimings) -> list[Cmd]:
    """Many-input charge sharing (§5.2.2): ACT-(gap)-PRE-(gap)-ACT, then the
    sense amp resolves + restores all activated rows, and the bank precharges."""
    return [
        Cmd(Op.ACT, bank, rf, 0.0, "apa.act1"),
        Cmd(Op.PRE, bank, -1, t.t_apa_gap, "apa.pre"),
        Cmd(Op.ACT, bank, rs, t.t_apa_gap, "apa.act2"),
        Cmd(Op.PRE, bank, -1, t.tras, "apa.pre2"),
        Cmd(Op.NOP, bank, -1, t.trp, "apa.done"),
    ]


def prog_aap_multi_row_init(bank: int, rf: int, rs: int,
                            t: DramTimings) -> list[Cmd]:
    """Multi-RowInit (§5.2.1): first ACT honors tRAS (full sense of R_F),
    PRE violated by second ACT; sense amps overdrive all activated rows."""
    return [
        Cmd(Op.ACT, bank, rf, 0.0, "aap.act1"),
        Cmd(Op.PRE, bank, -1, t.tras, "aap.pre"),
        Cmd(Op.ACT, bank, rs, t.t_apa_gap, "aap.act2"),
        Cmd(Op.PRE, bank, -1, t.tras, "aap.pre2"),
        Cmd(Op.NOP, bank, -1, t.trp, "aap.done"),
    ]


def prog_bulk_write(bank: int, rf: int, rs: int, n_bursts: int,
                    t: DramTimings) -> list[Cmd]:
    """Bulk-Write (§5.2.3): charge-share APA, then WR bursts drive all
    activated rows; one WR command stream writes 2^n rows at once."""
    prog = [
        Cmd(Op.ACT, bank, rf, 0.0, "bw.act1"),
        Cmd(Op.PRE, bank, -1, t.t_apa_gap, "bw.pre"),
        Cmd(Op.ACT, bank, rs, t.t_apa_gap, "bw.act2"),
        Cmd(Op.WR, bank, rs, t.trcd, "bw.wr0"),
    ]
    for i in range(1, n_bursts):
        prog.append(Cmd(Op.WR, bank, rs, t.tccd_l, f"bw.wr{i}"))
    prog.append(Cmd(Op.PRE, bank, -1, t.twr, "bw.pre2"))
    prog.append(Cmd(Op.NOP, bank, -1, t.trp, "bw.done"))
    return prog


def prog_write_row(bank: int, row: int, n_bursts: int,
                   t: DramTimings) -> list[Cmd]:
    """Nominal full-row write (host -> DRAM): ACT, WR bursts, PRE."""
    prog = [
        Cmd(Op.ACT, bank, row, 0.0, "wr.act"),
        Cmd(Op.WR, bank, row, t.trcd, "wr.wr0"),
    ]
    for i in range(1, n_bursts):
        prog.append(Cmd(Op.WR, bank, row, t.tccd_l, f"wr.wr{i}"))
    prog.append(Cmd(Op.PRE, bank, -1, t.twr, "wr.pre"))
    prog.append(Cmd(Op.NOP, bank, -1, t.trp, "wr.done"))
    return prog


def prog_read_row(bank: int, row: int, n_bursts: int,
                  t: DramTimings) -> list[Cmd]:
    prog = [
        Cmd(Op.ACT, bank, row, 0.0, "rd.act"),
        Cmd(Op.RD, bank, row, t.trcd, "rd.rd0"),
    ]
    for i in range(1, n_bursts):
        prog.append(Cmd(Op.RD, bank, row, t.tccd_l, f"rd.rd{i}"))
    prog.append(Cmd(Op.PRE, bank, -1, t.trtp + t.tbl, "rd.pre"))
    prog.append(Cmd(Op.NOP, bank, -1, t.trp, "rd.done"))
    return prog


def prog_frac(bank: int, row: int, t: DramTimings) -> list[Cmd]:
    """FracDRAM Frac op: truncated-restore ACT then PRE -> row at ~VDD/2."""
    return [
        Cmd(Op.ACT, bank, row, 0.0, "frac.act"),
        Cmd(Op.PRE, bank, -1, t.t_frac, "frac.pre"),
        Cmd(Op.NOP, bank, -1, t.trp, "frac.done"),
    ]
