"""Real-world application kernels on the PuM engine (paper Appendix B, Fig 20).

Each kernel returns (result, pum_latency_ms, cpu_latency_ms): results are
verified against direct NumPy in tests; the PuM latency comes from the
device's cost plane, the CPU number is the measured NumPy wall time on this
host (a *context* number — the paper measured a Skylake with AVX-512).

Kernels consume the public :mod:`repro.pum` API: each takes a
:class:`~repro.pum.Device` (a legacy ``PulsarEngine`` is coerced via
``pum.as_device``) and computes through ``PumArray`` operators. Every
kernel runs unchanged on an eager (``fuse=False``) or fused
(``fuse=True``) device and produces identical results and EngineStats:
the packed-bitmap set intersections (BMI/TC/KCS) route through the raw
planewise path (64-bit words split into two 32-bit dataplane lanes), the
arithmetic kernels (BW/KNN/IMS) through the value-mode fused ISA. The
serving/benchmark stacks construct fused devices by default
(fig20_realworld.py, examples/pum_database.py).

Kernels (paper's nine, the bitwise-dominated seven implemented end-to-end;
the two XNOR-CNNs are modeled at op-count level — their conv loops reduce to
XNOR+popcount+add on the same primitives):
  BMI  — bitmap-index query: users active on all of the past D days,
  BW   — BitWeaving scan: count elements with c1 <= v <= c2,
  TC   — triangle counting on bit-packed adjacency,
  KCS  — k-clique-star set intersections,
  KNN  — quantized-L2 k-nearest-neighbour distance sweep,
  IMS  — image segmentation by per-pixel nearest color,
  XNOR — binarized conv layer (XNOR + popcount) op-count model.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.engine import _vec_popcount
from repro.pum import Device, as_device


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e3


def bmi_active_users(dev: Device, daily_bitmaps: np.ndarray,
                     verify: bool = True) -> tuple[int, float, float]:
    """daily_bitmaps: [days, n_users/64] packed uint64. Query: how many users
    were active every day (Fig 20's BMI query). With ``verify=False`` the
    NumPy oracle and the assertion are skipped and cpu_ms reads 0.0 —
    benchmark harnesses verify once, then time the device path alone."""
    dev = as_device(dev)
    days = daily_bitmaps.shape[0]

    def cpu():
        acc = daily_bitmaps[0]
        for d in range(1, days):
            acc = acc & daily_bitmaps[d]
        return int(_vec_popcount(acc).sum())

    want, cpu_ms = _timed(cpu) if verify else (None, 0.0)
    dev.reset_stats()
    acc = dev.asarray(daily_bitmaps[0])
    for d in range(1, days):
        acc = acc & daily_bitmaps[d]
    # Popcount over the 64-bit words' planes (bit-serial adder tree) runs
    # on-device — on a fused device it joins the AND chain in the single
    # compiled pass — and charges the same cost-plane row either way; the
    # host only sums the per-word counts.
    got = int(acc.popcount(width=64).to_numpy().sum())
    if verify:
        assert got == want
    return got, dev.latency_ms, cpu_ms


def bitweaving_scan(dev: Device, column: np.ndarray, c1: int,
                    c2: int) -> tuple[int, float, float]:
    """select count(*) from T where c1 <= col <= c2 (BitWeaving [62])."""
    dev = as_device(dev)

    def cpu():
        return int(((column >= c1) & (column <= c2)).sum())

    want, cpu_ms = _timed(cpu)
    dev.reset_stats()
    col = dev.asarray(column)
    # Strict-compare sentinels (c1-1 < v < c2+1) with the trivially-true
    # bounds short-circuited: c1 == 0 would underflow the lower sentinel
    # to 2**64-1 and a c2 at the width max would overflow the upper one
    # out of width — in both cases the predicate is always true and a
    # real scan would skip the compare pass entirely.
    ge = dev.asarray(np.ones_like(column)) if c1 <= 0 \
        else np.full_like(column, c1 - 1) < col
    le = dev.asarray(np.ones_like(column)) \
        if c2 >= (1 << dev.width) - 1 \
        else col < np.full_like(column, c2 + 1)
    both = ge & le
    dev.charge("popcount", both.size, n_planes=1)
    got = int(both.sum())
    assert got == want
    return got, dev.latency_ms, cpu_ms


def triangle_count(dev: Device, adj_bits: np.ndarray
                   ) -> tuple[int, float, float]:
    """adj_bits: [n, n] {0,1} adjacency (undirected, no self-loops).
    Triangles = sum_{u<v, (u,v) in E} |N(u) & N(v)| / 3 via bitwise AND of
    packed adjacency rows (set-centric SISA style [10])."""
    dev = as_device(dev)
    n = adj_bits.shape[0]
    packed = np.packbits(adj_bits, axis=1, bitorder="little")
    packed64 = np.zeros((n, (packed.shape[1] + 7) // 8 * 8), np.uint8)
    packed64[:, :packed.shape[1]] = packed
    packed64 = packed64.view(np.uint64)

    def cpu():
        tot = 0
        for u in range(n):
            for v in range(u + 1, n):
                if adj_bits[u, v]:
                    tot += int(_vec_popcount(packed64[u] & packed64[v]).sum())
        return tot // 3

    want, cpu_ms = _timed(cpu)
    dev.reset_stats()
    tot = 0
    edges = [(u, v) for u in range(n) for v in range(u + 1, n)
             if adj_bits[u, v]]
    for u, v in edges:
        inter = dev.asarray(packed64[u]) & packed64[v]
        dev.charge("popcount", inter.size, n_planes=64)
        tot += int(_vec_popcount(inter.to_numpy()).sum())
    got = tot // 3
    assert got == want
    return got, dev.latency_ms, cpu_ms


_KCS_MEMO: dict = {}


def _kcs_operands(adj_bits: np.ndarray, cliques: list[tuple[int, ...]]):
    """Packed adjacency rows plus, for uniform-k clique lists, one stacked
    operand per clique position (the j-th members' rows concatenated across
    all cliques). Memoized per (adjacency, clique list): repeat calls return
    the *same* arrays, so the engine's pointer+fingerprint leaf cache serves
    the already-uploaded device buffers with zero bytes staged. The memo
    holds strong references to its keys (ids stay valid) and samples the
    adjacency contents like the engine's leaf fingerprint, so an in-place
    rewrite of the adjacency invalidates the entry; mutating the clique
    *list* in place between calls is outside the contract."""
    key = (adj_bits.__array_interface__["data"][0], adj_bits.shape,
           id(cliques))
    hit = _KCS_MEMO.get(key)
    if (hit is not None and hit[0] is adj_bits and hit[1] is cliques
            and np.array_equal(adj_bits.ravel()[hit[2]], hit[3])):
        return hit[4], hit[5]
    n = adj_bits.shape[0]
    packed = np.packbits(adj_bits, axis=1, bitorder="little")
    pad = np.zeros((n, (packed.shape[1] + 7) // 8 * 8), np.uint8)
    pad[:, :packed.shape[1]] = packed
    rows = pad.view(np.uint64)
    k = len(cliques[0]) if cliques else 0
    stacks = None
    if k and all(len(cl) == k for cl in cliques):
        idx = np.asarray(cliques, dtype=np.intp)
        stacks = tuple(rows[idx[:, j]].reshape(-1) for j in range(k))
    flat = adj_bits.ravel()
    fp_idx = np.linspace(0, flat.size - 1,
                         min(flat.size, 257)).astype(np.int64)
    if len(_KCS_MEMO) >= 4:
        _KCS_MEMO.clear()
    _KCS_MEMO[key] = (adj_bits, cliques, fp_idx, flat[fp_idx].copy(),
                      rows, stacks)
    return rows, stacks


def kclique_star(dev: Device, adj_bits: np.ndarray,
                 cliques: list[tuple[int, ...]],
                 verify: bool = True) -> tuple[int, float, float]:
    """Count vertices adjacent to every member of each k-clique (the star
    extension step of KCS [10]): AND-reduce clique members' adjacency rows.

    Uniform-k clique lists run PULSAR-style as one bulk program: the j-th
    members' rows are stacked into a single operand per clique position and
    the k-1 ANDs execute over all cliques at once (a single flush on a
    fused device); the stacks are memoized (see :func:`_kcs_operands`) so
    repeat calls are pointer-stable and hit the leaf cache. Ragged clique
    lists fall back to the per-clique loop. With ``verify=False`` the NumPy
    oracle and assertion are skipped and cpu_ms reads 0.0."""
    dev = as_device(dev)
    rows, stacks = _kcs_operands(adj_bits, cliques)

    def cpu():
        tot = 0
        for cl in cliques:
            acc = rows[cl[0]]
            for v in cl[1:]:
                acc = acc & rows[v]
            tot += int(_vec_popcount(acc).sum())
        return tot

    want, cpu_ms = _timed(cpu) if verify else (None, 0.0)
    dev.reset_stats()
    if stacks is not None:
        acc = dev.asarray(stacks[0])
        for s in stacks[1:]:
            acc = acc & s
        got = int(acc.popcount(width=64).to_numpy().sum())
    else:
        tot = 0
        for cl in cliques:
            acc = dev.asarray(rows[cl[0]])
            for v in cl[1:]:
                acc = acc & rows[v]
            tot += int(acc.popcount(width=64).to_numpy().sum())
        got = tot
    if verify:
        assert got == want
    return got, dev.latency_ms, cpu_ms


def knn_distances(dev: Device, queries: np.ndarray,
                  refs: np.ndarray) -> tuple[np.ndarray, float, float]:
    """Quantized (8-bit) squared-L2 distances, kNN front half: for each query
    compute distances to all refs; argmin on host (as in the paper, the
    host reads back and selects)."""
    dev = as_device(dev)
    q = queries.astype(np.int64)
    r = refs.astype(np.int64)

    def cpu():
        return (((q[:, None, :] - r[None, :, :]) ** 2).sum(-1)).argmin(1)

    want, cpu_ms = _timed(cpu)
    dev.reset_stats()
    n_q, n_r, f = q.shape[0], r.shape[0], r.shape[1]
    dists = np.zeros((n_q, n_r), np.uint64)
    for j in range(f):
        a = np.repeat(q[:, j], n_r)
        b = np.tile(r[:, j], n_q)
        d = dev.asarray(a.astype(np.uint64)) - b.astype(np.uint64)
        # |a-b|^2 == ((a-b) mod 2^w)^2 mod 2^w needs sign handling; engine
        # works mod 2^width — use the identity (a-b)^2 = (b-a)^2 and mask.
        d2 = d * d
        dists += d2.reshape(n_q, n_r)
    got = dists.argmin(1)
    np.testing.assert_array_equal(got, want)
    return got, dev.latency_ms, cpu_ms


def image_segmentation(dev: Device, img: np.ndarray,
                       colors: np.ndarray) -> tuple[np.ndarray, float, float]:
    """Assign each pixel the nearest of C colors (1-D intensity model,
    per-pixel |p - c| compare network), PuM-side compares + mux."""
    dev = as_device(dev)
    p = img.ravel().astype(np.int64)

    def cpu():
        return np.abs(p[:, None] - colors[None, :].astype(np.int64)).argmin(1)

    want, cpu_ms = _timed(cpu)
    dev.reset_stats()
    # Width-max sentinel (not uint64-max): distances are in-width values,
    # so the compare network works identically on eager and fused devices.
    best = np.full(p.shape, (1 << dev.width) - 1, np.uint64)
    label = np.zeros(p.shape, np.uint64)
    pix = dev.asarray(p.astype(np.uint64))
    for ci, c in enumerate(colors):
        cvec = np.full_like(best, c)
        d1 = pix - cvec
        d2 = dev.asarray(cvec) - pix
        mask_neg = dev.asarray(np.full_like(best, int(c))) < pix
        d = np.where(mask_neg.astype(bool), np.asarray(d1), np.asarray(d2))
        better = dev.asarray(d) < best
        best = np.where(better.astype(bool), d, best)
        label = np.where(better.astype(bool), ci, label)
    np.testing.assert_array_equal(label, want)
    return label, dev.latency_ms, cpu_ms


def xnor_conv_cost(dev: Device, in_ch: int, out_ch: int,
                   kh: int, kw: int, oh: int, ow: int) -> float:
    """Op-count latency model of one binarized conv layer (XNOR-Net [92]):
    per output: XNOR over in_ch*kh*kw bits + popcount + sign. Returns ms."""
    dev = as_device(dev)
    dev.reset_stats()
    n_out = out_ch * oh * ow
    bits = in_ch * kh * kw
    dev.charge("xor2", n_out)                   # fused XNOR plane op
    dev.charge("popcount", n_out, n_planes=min(bits, 64))
    dev.charge("compare", n_out, width=16)
    return dev.latency_ms
