"""DDR4 timing parameters and violated-timing constants (paper §2.2, §5.2).

All values in nanoseconds, DDR4-2400 grade (DRAM Bender's stock part), JEDEC
JESD79-4C. The PuM command sequences *violate* tRAS / tRP with the sub-3ns
gaps the paper reports; nominal parameters still govern everything else, and
tFAW / tRRD limit the activation rate (Appendix A: power constraints).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DramTimings:
    tck: float = 0.833       # DDR4-2400: 1200 MHz clock
    trcd: float = 13.32      # ACT -> RD/WR
    trp: float = 13.32       # PRE -> ACT
    tras: float = 32.0       # ACT -> PRE (restore)
    trc: float = 45.32       # ACT -> ACT (same bank)
    trrd_s: float = 3.332    # ACT -> ACT different bank group
    trrd_l: float = 4.998    # ACT -> ACT same bank group
    tfaw: float = 30.0       # rolling four-activation window
    twr: float = 15.0        # write recovery
    trtp: float = 7.5        # read -> PRE
    tccd_s: float = 3.332    # burst-to-burst, diff bank group
    tccd_l: float = 5.0      # burst-to-burst, same bank group
    tbl: float = 3.332       # BL8 burst duration
    trfc: float = 350.0      # refresh (4 Gb)
    trefi: float = 7800.0    # refresh interval
    # --- violated timings used by PuM sequences (paper: "< 3 ns") ---
    t_apa_gap: float = 2.5   # ACT->PRE and PRE->ACT gap in the APA sequence
    t_frac: float = 9.0      # FracDRAM's truncated restore before PRE
    # Energy per command, nJ-scale (Rambus/Vogelsang-style constants; used
    # only for relative energy reporting).
    e_act: float = 0.909e-9
    e_pre: float = 0.578e-9
    e_rdwr_burst: float = 1.51e-9
    e_ref: float = 26.3e-9   # one all-bank REF cycle (tRFC at IDD5)

    @property
    def t_aap(self) -> float:
        """ACT (full restore) -> PRE -> ACT sequence with violated tRP.

        This is RowClone / Multi-RowInit's trigger: first row fully sensed
        (tRAS honored), PRE interrupted by the second ACT after t_apa_gap,
        then the destination rows are overdriven by the latched sense amps
        for a full restore window, and the bank is finally precharged.
        """
        return self.tras + self.t_apa_gap + self.tras + self.trp

    @property
    def t_apa(self) -> float:
        """ACT -> PRE -> ACT with *both* gaps violated (charge sharing,
        §5.2.2): neither the first row's restore nor the precharge completes;
        after the second ACT all rows share charge, then sense + restore +
        precharge."""
        return self.t_apa_gap + self.t_apa_gap + self.tras + self.trp

    @property
    def t_wr_row(self) -> float:
        """One WR burst into an open row + write recovery + precharge."""
        return self.trcd + self.tbl + self.twr + self.trp

    @property
    def t_frac_op(self) -> float:
        """FracDRAM Frac: ACT truncated at t_frac, then PRE (row left ~VDD/2)."""
        return self.t_frac + self.trp


DDR4_2400 = DramTimings()
