"""Manufacturer / module profiles for the DRAM device model.

The paper characterizes 120 DDR4 chips from two manufacturers (Table 1):

* Mfr. H (SK Hynix): up to 32 simultaneous rows, Frac supported,
  lower success rates (weaker sense amps — paper's hypothesis, §6.1.1).
* Mfr. M (Micron): up to 16 simultaneous rows, Frac NOT supported but sense
  amps biased by cell polarity (footnote 4), higher success rates.
* Samsung: no multi-row activation at all (§7 Limitations) — internal
  circuitry ignores the violated PRE / second ACT.

Analog-model calibration constants are chosen so the simulator lands on the
paper's anchor numbers (see ``tests/core/test_calibration.py`` and
EXPERIMENTS.md §Repro):
  - FracDRAM-style MAJ3 (N=4) on DDR4 ~ 78.85 % mean success,
  - PULSAR MAJ3 @ N=32 ~ 97.91 %, MAJ5 ~ 73.93 %, MAJ7 ~ 29.28 %,
  - bitline deviation of N=32 MAJ3 ~ +159 % vs N=4 (§5.1) — this one is
    *analytic*: ratio = copies * (C_bl + 4C) / (C_bl + 32C) with
    C_bl/C = 5.8 giving 10*(5.8+4)/(5.8+32) = 2.59.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MfrProfile:
    name: str
    # How many predecoder groups can double-latch (paper §4.2): the number of
    # simultaneously activated rows is 2**k, k <= double_latch_groups.
    double_latch_groups: int
    max_simul_rows: int
    frac_supported: bool
    # Sense amps biased to cell polarity (Mfr. M footnote 4): neutral rows are
    # emulated by writing the bias pattern instead of a Frac VDD/2 charge.
    sa_bias_neutral: bool
    # --- analog calibration ---
    cell_cap_ff: float = 20.0        # ITRS 22 nm-class cell capacitance
    bitline_cap_ratio: float = 5.8   # C_bl / C_cell (calibrated, see module doc)
    vdd: float = 1.2
    # Static per-bitline mismatch: sense-amp offset sigma (volts).
    sense_offset_sigma: float = 0.016
    # Per-cell capacitance sigma as a fraction of C_cell ("process variation").
    process_variation: float = 0.20
    # Trial (dynamic) noise sigma in volts; a bitline is "stable" only if its
    # static margin survives ~max |noise| over 10^4 trials (~3.7 sigma).
    trial_noise_sigma: float = 0.004
    # Data-pattern interference (§6.1.1: random patterns hurt; PARBOR-style
    # cell-to-cell coupling). Scales with sqrt(N_activated) (volts per sqrt-row).
    coupling_sigma: float = 0.0035
    # Fraction of (R_F, R_S) pairs whose decoder path supports double-latching
    # per group — chip-level manufacturing yield knob for Table 1 N_RG%.
    pair_yield: float = 0.80
    # Largest demonstrated-reliable MAJ fan-in (§6.1.1: H shows MAJ9 with low
    # success, "MAJ11+ for Mfr H and MAJ9+ for Mfr M" are <1% and omitted).
    max_maj_fan_in: int = 9

    @property
    def bitline_cap_ff(self) -> float:
        return self.cell_cap_ff * self.bitline_cap_ratio


# Calibration (see tests/core/test_analog_calibration.py and EXPERIMENTS.md):
# fitted numerically (grid search over the Monte-Carlo model) against the
# paper's anchors
#   H: MAJ3@4 ~ 0.79, MAJ3@32 ~ 0.98, MAJ5@32 ~ 0.74, MAJ7@32 ~ 0.29
# giving H: offset 33 mV, pv 5%, coupling 2.2 mV/sqrt-row -> simulated
# 0.77 / 0.999 / 0.80 / 0.23. The anchors force a large static sense-amp
# offset plus sqrt(N)-growing coupling noise — matching the paper's own
# hypotheses (weak Mfr-H sense amps; data-pattern cell interference).
# Mfr M: "more robust sense amplifiers" => much smaller offset/coupling.
MFR_H = MfrProfile(
    name="H",
    double_latch_groups=5,
    max_simul_rows=32,
    frac_supported=True,
    sa_bias_neutral=False,
    sense_offset_sigma=0.033,
    process_variation=0.05,
    coupling_sigma=0.0022,
    trial_noise_sigma=0.001,
    pair_yield=0.78,
    max_maj_fan_in=9,
)

MFR_M = MfrProfile(
    name="M",
    double_latch_groups=4,
    max_simul_rows=16,
    frac_supported=False,
    sa_bias_neutral=True,
    sense_offset_sigma=0.008,    # "more robust sense amplifiers" (§6.1.1)
    process_variation=0.08,
    coupling_sigma=0.0011,
    trial_noise_sigma=0.001,
    pair_yield=0.70,
    max_maj_fan_in=7,
)

MFR_S = MfrProfile(
    name="S",
    double_latch_groups=0,       # no multi-row activation (§7)
    max_simul_rows=1,
    frac_supported=False,
    sa_bias_neutral=False,
)

PROFILES: dict[str, MfrProfile] = {"H": MFR_H, "M": MFR_M, "S": MFR_S}
