"""PULSAR executor: MAJ-M with input replication on the chip model (§5).

Staging strategy (the paper's limitation #1 is that chips do not let you pick
arbitrary activation sets, so addresses must be co-designed with the decoder):

* An ``NrgRegion`` is the decoder-determined set of ``N = 2^k`` rows activated
  by APA(rf, rs); each row corresponds to a *combo index* in {0,1}^k choosing,
  per differing predecoder group, either rf's or rs's value.
* The replication plan (c copies per input + neutrals) is packed into the
  combo hypercube with a buddy allocator: every power-of-two block of combo
  indices is itself a decoder-realizable activation set, so a block of
  2^j copies is initialized with ONE Multi-RowInit (plus one RowClone
  copy-in) — this is exactly why Multi-RowInit makes replication cheap
  (Fig 18: init latency is the limiting factor at large N).
* Neutral rows are Frac ops (Mfr. H) or bias-pattern writes (Mfr. M,
  footnote 4).

Per-op cost (AAP = one violated-timing ACT->PRE->ACT):
    copy-ins   = (#binary blocks of c) RowClones          per input
    fills      = (#blocks with size > 1) Multi-RowInits   per input
    neutrals   = n_neutral Frac ops
    compute    = 1 APA (charge share)
    copy-out   = 1 RowClone
The FracDRAM baseline (MAJ3 @ N=4, no replication) degenerates to
3 copy-ins + 1 Frac + APA + copy-out, matching prior work's sequences.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.chip import PulsarChip
from repro.core.decoder import join_groups, split_groups
from repro.core.replication import (ReplicationPlan, plan as replication_plan,
                                    plan_pow2)


@dataclasses.dataclass(frozen=True)
class NrgRegion:
    """An APA-activatable region of 2^k rows in one subarray."""
    bank: int
    rf: int
    rs: int
    # groups (indices into predecoder groups) that differ between rf/rs,
    # in LSB-first combo-bit order.
    varying_groups: tuple[int, ...]
    rows_by_combo: tuple[int, ...]  # combo index -> bank-level row address

    @property
    def k(self) -> int:
        return len(self.varying_groups)

    @property
    def n_rows(self) -> int:
        return 1 << self.k

    def block_anchor_pair(self, start: int, size: int) -> tuple[int, int]:
        """(rf', rs') whose APA set is exactly the combo block
        [start, start+size); block must be buddy-aligned."""
        if size & (size - 1) or start % size:
            raise ValueError("block must be power-of-two sized and aligned")
        j = size.bit_length() - 1
        a = self.rows_by_combo[start]
        b = self.rows_by_combo[start + size - 1]  # flips exactly j low bits
        return a, b


def build_region(chip: PulsarChip, bank: int, subarray: int,
                 n_rg: int, seed: int = 0) -> NrgRegion:
    g = chip.geometry
    rng = np.random.default_rng(seed)
    rf, rs = chip.decoder.find_group_pair(subarray, n_rg, rng)
    widths = g.predecoder_widths
    gf = split_groups(g.local_row(rf), widths)
    gs = split_groups(g.local_row(rs), widths)
    varying = tuple(i for i in range(len(widths)) if gf[i] != gs[i])
    base = subarray * g.rows_per_subarray
    rows = []
    for combo in range(1 << len(varying)):
        vals = list(gf)
        for bit, gi in enumerate(varying):
            if (combo >> bit) & 1:
                vals[gi] = gs[gi]
        rows.append(base + join_groups(tuple(vals), widths))
    region = NrgRegion(bank=bank, rf=rf, rs=rs, varying_groups=varying,
                       rows_by_combo=tuple(rows))
    assert set(region.rows_by_combo) == set(chip.decoder.activated_rows(rf, rs))
    return region


def buddy_assign(m_inputs: int, copies: int, n_neutral: int, k: int
                 ) -> tuple[list[list[tuple[int, int]]], list[tuple[int, int]]]:
    """Pack m_inputs * copies + n_neutral slots into the 2^k combo hypercube.

    Returns (per-input block lists, neutral blocks); blocks are (start, size),
    buddy-aligned. Total demand always equals 2^k (replication plan invariant),
    so the packing is exact.
    """
    demands: list[tuple[int, int]] = []   # (owner, size); owner -1 = neutral
    for owner, count in [(i, copies) for i in range(m_inputs)] + [(-1, n_neutral)]:
        c = count
        bit = 1
        while c:
            if c & 1:
                demands.append((owner, bit))
            c >>= 1
            bit <<= 1
    demands.sort(key=lambda d: -d[1])
    free: dict[int, list[int]] = {1 << k: [0]}  # size -> [starts]
    per_input: list[list[tuple[int, int]]] = [[] for _ in range(m_inputs)]
    neutral_blocks: list[tuple[int, int]] = []
    for owner, size in demands:
        s = size
        while s <= (1 << k) and not free.get(s):
            s <<= 1
        if s > (1 << k):
            raise RuntimeError("buddy packing failed (invariant violated)")
        start = free[s].pop(0)
        while s > size:  # split down
            s >>= 1
            free.setdefault(s, []).append(start + s)
        block = (start, size)
        if owner < 0:
            neutral_blocks.append(block)
        else:
            per_input[owner].append(block)
    return per_input, neutral_blocks


@dataclasses.dataclass
class MajOpReport:
    n_rg: int
    m_inputs: int
    copies: int
    n_neutral: int
    n_copy_in: int
    n_fill: int
    n_frac: int
    n_apa: int = 1
    n_copy_out: int = 1

    @property
    def total_aaps(self) -> int:
        """All violated-timing row-pair sequences (copy-ins, fills, APA,
        copy-out) — the unit prior work counts."""
        return self.n_copy_in + self.n_fill + self.n_apa + self.n_copy_out


class PulsarExecutor:
    """Executes MAJ / init / write primitives with PULSAR's staging."""

    def __init__(self, chip: PulsarChip, bank: int = 0, subarray: int = 0,
                 seed: int = 0):
        self.chip = chip
        self.bank = bank
        self.subarray = subarray
        self.seed = seed
        self._regions: dict[int, NrgRegion] = {}

    def region(self, n_rg: int) -> NrgRegion:
        if n_rg not in self._regions:
            self._regions[n_rg] = build_region(
                self.chip, self.bank, self.subarray, n_rg, self.seed)
        return self._regions[n_rg]

    def max_n_rg(self) -> int:
        p, g = self.chip.profile, self.chip.geometry
        usable = min(p.double_latch_groups, len(g.predecoder_widths))
        if self.chip.decoder.yield_mask is not None:
            usable = min(usable, int(self.chip.decoder.yield_mask[self.subarray].sum()))
        return min(1 << usable, p.max_simul_rows)

    # ------------------------------------------------------------------ #

    def maj(self, dst_row: int, src_rows: list[int], n_rg: int,
            stability_mask: np.ndarray | None = None,
            plan_style: str = "pow2",
            in_place_input: int | None = None) -> MajOpReport:
        """dst = MAJ_M(srcs) via an N_RG-row simultaneous activation with
        input replication. ``src_rows`` may repeat a row (weighted inputs,
        e.g. the MAJ5 full-adder's double ¬Cout).

        plan_style: "pow2" (staging-efficient, default for compute) or
        "max" (paper's maximal replication, used for characterization).

        ``in_place_input``: CHAINED-STAGING optimization (beyond paper):
        after any APA, the charge-shared result is restored to ALL activated
        rows — so when this op's input i is the immediately preceding op's
        output in the SAME region, its copies are already resident in every
        slot (including its own block) and its staging is skipped entirely.
        The caller (the ALU) guarantees residency; the chip model verifies
        it bit-exactly.
        """
        m = len(src_rows)
        rp = (plan_pow2 if plan_style == "pow2" else replication_plan)(m, n_rg)
        region = self.region(n_rg)
        if region.n_rows != n_rg:
            raise RuntimeError("region size mismatch")
        per_input, neutral_blocks = buddy_assign(m, rp.copies, rp.n_neutral,
                                                 region.k)
        chip = self.chip
        n_copy_in = n_fill = n_frac = 0
        for i, blocks in enumerate(per_input):
            if i == in_place_input:
                # Verify residency (model invariant, zero DRAM commands).
                for start, size in blocks:
                    for s in range(start, start + size):
                        r = region.rows_by_combo[s]
                        if not np.array_equal(chip.peek(self.bank, r),
                                              chip.peek(self.bank,
                                                        src_rows[i])):
                            raise RuntimeError(
                                "in_place_input not resident in region")
                continue
            for start, size in blocks:
                first = region.rows_by_combo[start]
                chip.row_clone(self.bank, src_rows[i], first)
                n_copy_in += 1
                if size > 1:
                    a, b = region.block_anchor_pair(start, size)
                    assert a == first  # copy-in landed on the block anchor
                    got = chip.multi_row_init(self.bank, a, b)
                    assert set(got) == {region.rows_by_combo[s]
                                        for s in range(start, start + size)}
                    n_fill += 1
        for start, size in neutral_blocks:
            if chip.profile.frac_supported:
                for s in range(start, start + size):
                    chip.frac(self.bank, region.rows_by_combo[s])
                    n_frac += 1
            else:
                a, b = region.block_anchor_pair(start, size)
                chip.frac_block(self.bank, a, b)
                n_frac += 1 + (1 if size > 1 else 0)
        chip.apa_maj(self.bank, region.rf, region.rs,
                     stability_mask=stability_mask)
        chip.row_clone(self.bank, region.rows_by_combo[0], dst_row)
        return MajOpReport(n_rg=n_rg, m_inputs=m, copies=rp.copies,
                           n_neutral=rp.n_neutral, n_copy_in=n_copy_in,
                           n_fill=n_fill, n_frac=n_frac)

    def fracdram_maj3(self, dst_row: int, src_rows: list[int],
                      stability_mask: np.ndarray | None = None) -> MajOpReport:
        """State-of-the-art baseline [26]: MAJ3 on a 4-row activation, one
        copy per input + one Frac row, no replication."""
        return self.maj(dst_row, src_rows, n_rg=4,
                        stability_mask=stability_mask)

    def multi_row_init_block(self, src_row: int, n_rows: int) -> tuple[int, ...]:
        """Copy src into a 2^j block (Multi-RowInit primitive, §5.2.1)."""
        region = self.region(n_rows)
        first = region.rows_by_combo[0]
        self.chip.row_clone(self.bank, src_row, first)
        return self.chip.multi_row_init(self.bank, region.rf, region.rs)

    def bulk_write_block(self, data: np.ndarray, n_rows: int) -> tuple[int, ...]:
        region = self.region(n_rows)
        return self.chip.bulk_write(self.bank, region.rf, region.rs, data)
