"""Closed-form cost model for PuM operations (paper §6.1.2, Figs 5/17/18/19).

Latency source of truth: the same command programs the executor issues,
scheduled by the same tFAW/tRRD-aware scheduler — so the closed-form numbers
match the executed traces exactly (cross-checked in tests).

Throughput model (paper's): a MAJ op processes ``row_bits`` bitlines (SIMD
lanes) but only the *stable* fraction (success rate) produces usable results:

    throughput = row_bits * success_rate / latency

The FracDRAM baseline is MAJ3 on a 4-row activation with a per-op Frac
(FracDRAM re-establishes the neutral row each operation); PULSAR picks, per
manufacturer and per fan-in M, the N_RG that maximizes throughput — exactly
the paper's methodology ("we choose the N_RG that produces the highest
throughput").
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import commands as cmds
from repro.core.pulsar import buddy_assign
from repro.core.replication import plan as replication_plan, plan_pow2
from repro.core.timing import DDR4_2400, DramTimings


@dataclasses.dataclass(frozen=True)
class OpCost:
    latency_ns: float
    energy_j: float
    n_sequences: int      # violated-timing row sequences (AAP/APA/Frac/...)

    def __add__(self, other: "OpCost") -> "OpCost":
        return OpCost(self.latency_ns + other.latency_ns,
                      self.energy_j + other.energy_j,
                      self.n_sequences + other.n_sequences)

    def __mul__(self, k: float) -> "OpCost":
        return OpCost(self.latency_ns * k, self.energy_j * k,
                      int(round(self.n_sequences * k)))

    __rmul__ = __mul__


ZERO = OpCost(0.0, 0.0, 0)


class CostModel:
    def __init__(self, timings: DramTimings = DDR4_2400,
                 row_bits: int = 65536, controller=None):
        """``controller``: an optional
        :class:`repro.controller.MemoryController`; when set, primitive
        programs are priced through its bank-machine/multiplexer schedule
        (identical to the sequential path for single-bank programs — the
        equivalence is tested) and multi-bank batches can be priced with
        :meth:`maj_unit_programs` + ``controller.batch_cost``.  ``None``
        keeps the legacy sequential ``CommandScheduler`` path."""
        self.t = timings
        self.row_bits = row_bits
        self._wr_bursts = max(1, row_bits // 512)
        self._sched = cmds.CommandScheduler(timings)
        self.controller = controller
        self._cache: dict[tuple, OpCost] = {}

    # ------------------------------------------------------------------ #
    # Primitive costs (scheduled programs)
    # ------------------------------------------------------------------ #

    def _sched_cost(self, prog) -> OpCost:
        if self.controller is not None:
            r = self.controller.schedule(prog)
        else:
            r = self._sched.schedule(prog)
        return OpCost(r.total_ns, r.energy_j, 1)

    def aap(self) -> OpCost:
        return self._sched_cost(cmds.prog_aap_multi_row_init(0, 0, 1, self.t))

    def apa(self) -> OpCost:
        return self._sched_cost(cmds.prog_apa_charge_share(0, 0, 1, self.t))

    def frac(self, frac_supported: bool = True) -> OpCost:
        if frac_supported:
            return self._sched_cost(cmds.prog_frac(0, 0, self.t))
        # Mfr. M: re-init with the bias pattern via RowClone (one AAP).
        return self.aap()

    def write_row(self) -> OpCost:
        return self._sched_cost(
            cmds.prog_write_row(0, 0, self._wr_bursts, self.t))

    def read_row(self) -> OpCost:
        return self._sched_cost(
            cmds.prog_read_row(0, 0, self._wr_bursts, self.t))

    def bulk_write(self) -> OpCost:
        return self._sched_cost(
            cmds.prog_bulk_write(0, 0, 1, self._wr_bursts, self.t))

    # ------------------------------------------------------------------ #
    # MAJ op with PULSAR staging (mirrors PulsarExecutor.maj exactly)
    # ------------------------------------------------------------------ #

    def maj_op(self, m: int, n_rg: int, frac_supported: bool = True,
               reuse_neutral: bool = False,
               plan_style: str = "pow2",
               resident_inputs: int = 0) -> OpCost:
        """Full MAJ-M at N_RG: copy-ins + fills + neutrals + APA + copy-out.

        ``reuse_neutral``: PULSAR-only optimization — neutral rows are
        re-established lazily (they are consumed by each APA, so the faithful
        default re-Fracs them every op, like the executor does).
        ``plan_style``: mirrors PulsarExecutor.maj.
        ``resident_inputs``: chained-staging (PulsarExecutor.maj
        in_place_input): that many inputs' staging is skipped because the
        previous op's APA left their value resident across the region.
        """
        key = ("maj", m, n_rg, frac_supported, reuse_neutral, plan_style,
               resident_inputs)
        if key in self._cache:
            return self._cache[key]
        rp = (plan_pow2 if plan_style == "pow2" else replication_plan)(m, n_rg)
        k = n_rg.bit_length() - 1
        per_input, neutral_blocks = buddy_assign(m, rp.copies, rp.n_neutral, k)
        cost = ZERO
        for blocks in per_input[resident_inputs:]:
            for _start, size in blocks:
                cost = cost + self.aap()            # copy-in RowClone
                if size > 1:
                    cost = cost + self.aap()        # Multi-RowInit fill
        if not reuse_neutral:
            if frac_supported:
                cost = cost + rp.n_neutral * self.frac(True)
            else:
                # bias-pattern block re-init: seed clone + MRI per block
                for _start, size in neutral_blocks:
                    cost = cost + self.aap()
                    if size > 1:
                        cost = cost + self.aap()
        cost = cost + self.apa()                    # charge share
        cost = cost + self.aap()                    # copy-out
        self._cache[key] = cost
        return cost

    def maj_unit_programs(self, m: int, n_rg: int,
                          frac_supported: bool = True,
                          plan_style: str = "pow2",
                          resident_inputs: int = 0,
                          bank: int = 0) -> list[list[cmds.Cmd]]:
        """The primitive command programs composing one MAJ-M@N_RG op, in
        issue order — the schedulable counterpart of :meth:`maj_op` (same
        sequence count and, scheduled back-to-back on one bank, the same
        latency).  This is the *unit* that ``MemoryController.batch_cost``
        replicates across banks to measure bank-parallel speedup and
        refresh interference."""
        rp = (plan_pow2 if plan_style == "pow2" else replication_plan)(m,
                                                                       n_rg)
        k = n_rg.bit_length() - 1
        per_input, neutral_blocks = buddy_assign(m, rp.copies, rp.n_neutral,
                                                 k)
        t = self.t
        progs: list[list[cmds.Cmd]] = []
        for blocks in per_input[resident_inputs:]:
            for _start, size in blocks:
                progs.append(cmds.prog_aap_multi_row_init(bank, 0, 1, t))
                if size > 1:
                    progs.append(cmds.prog_aap_multi_row_init(bank, 0, 1, t))
        if frac_supported:
            progs.extend(cmds.prog_frac(bank, 0, t)
                         for _ in range(rp.n_neutral))
        else:
            for _start, size in neutral_blocks:
                progs.append(cmds.prog_aap_multi_row_init(bank, 0, 1, t))
                if size > 1:
                    progs.append(cmds.prog_aap_multi_row_init(bank, 0, 1, t))
        progs.append(cmds.prog_apa_charge_share(bank, 0, 1, t))
        progs.append(cmds.prog_aap_multi_row_init(bank, 0, 1, t))
        return progs

    def fracdram_maj3(self) -> OpCost:
        """State-of-the-art baseline [26]: MAJ3 @ N=4 (1 Frac per op)."""
        return self.maj_op(3, 4, frac_supported=True)

    # ------------------------------------------------------------------ #
    # ALU op costs (mirror alu.py synthesis; dual-rail => 2x MAJ count)
    # ------------------------------------------------------------------ #

    def logic2(self, m: int, n_rg: int, **kw) -> OpCost:
        """Elementwise AND/OR of two planes (dual-rail)."""
        return 2 * self.maj_op(m, n_rg, **kw)

    def xor2(self, m: int, n_rg: int, **kw) -> OpCost:
        """XOR = 2 AND + 1 OR, dual-rail."""
        return 6 * self.maj_op(m, n_rg, **kw)

    def full_adder(self, maj_fan_in: int, n_rg: int,
                   n_rg3: int | None = None, chained: bool = False,
                   **kw) -> OpCost:
        """MAJ5 path: Cout pair at its own (cheap) MAJ3 config ``n_rg3``,
        Sum pair at the MAJ5 config ``n_rg``.

        ``chained``: double-buffered regions keep each carry chain resident
        (Cout ops reuse Cin; Sum ops reuse the doubled ¬Cout operand) —
        the chained-staging schedule (EXPERIMENTS.md §Perf P4)."""
        n3 = n_rg3 or (4 if maj_fan_in >= 5 else n_rg)
        r3 = 1 if chained else 0
        if maj_fan_in >= 5:
            r5 = 2 if chained else 0   # the doubled ¬Cout operand
            return (2 * self.maj_op(3, n3, resident_inputs=r3, **kw)
                    + 2 * self.maj_op(5, n_rg, resident_inputs=r5, **kw))
        return (2 * self.maj_op(3, n_rg, resident_inputs=r3, **kw)
                + 4 * self.maj_op(3, n_rg, **kw))

    def add(self, width: int, maj_fan_in: int, n_rg: int,
            n_rg3: int | None = None, chained: bool = False, **kw) -> OpCost:
        return width * self.full_adder(maj_fan_in, n_rg, n_rg3,
                                       chained=chained, **kw)

    def mul(self, width: int, maj_fan_in: int, n_rg: int,
            n_rg3: int | None = None, chained: bool = False, **kw) -> OpCost:
        n3 = n_rg3 or (4 if maj_fan_in >= 5 else n_rg)
        ands = width * width * self.logic2(3, n3, **kw)
        adds = (width - 1) * self.add(width, maj_fan_in, n_rg, n_rg3,
                                      chained=chained, **kw)
        return ands + adds

    def div(self, width: int, maj_fan_in: int, n_rg: int,
            n_rg3: int | None = None, chained: bool = False, **kw) -> OpCost:
        we = width + 1
        n3 = n_rg3 or (4 if maj_fan_in >= 5 else n_rg)
        per_iter = (self.add(we, maj_fan_in, n_rg, n_rg3,
                             chained=chained, **kw)                # sub
                    + 2 * we * self.logic2(3, n3, **kw)           # mux ands
                    + we * self.logic2(3, n3, **kw)               # mux or
                    + 2 * self.aap())                             # q-bit clones
        return width * per_iter

    @staticmethod
    def tree_nodes(n_inputs: int, fan_in: int) -> int:
        nodes, level = 0, n_inputs
        while level > 1:
            full, rem = divmod(level, fan_in)
            nodes += full + (1 if rem > 1 else 0)
            level = full + (1 if rem else 0)
        return nodes

    def reduce_tree(self, n_planes: int, maj_fan_in: int, n_rg: int,
                    chained: bool = False, **kw) -> OpCost:
        """AND/OR reduction over n_planes with fan-in (M+1)/2 nodes.
        ``chained``: internal nodes keep one input (the spine: the previous
        node's output) resident in the region."""
        f = (maj_fan_in + 1) // 2
        nodes = self.tree_nodes(n_planes, f)
        leaves_level = -(-n_planes // f)
        internal = max(0, nodes - leaves_level)
        r = 1 if chained else 0
        return (leaves_level * 2 * self.maj_op(maj_fan_in, n_rg, **kw)
                + internal * 2 * self.maj_op(maj_fan_in, n_rg,
                                             resident_inputs=r, **kw))

    def xor_reduce(self, n_planes: int, maj_fan_in: int, n_rg: int,
                   chained: bool = False, **kw) -> OpCost:
        per = self.xor2(min(3, maj_fan_in), n_rg, **kw)
        if chained:
            # the final OR of each XOR chains one AND output in-region.
            per = (4 * self.maj_op(3, n_rg, **kw)
                   + 2 * self.maj_op(3, n_rg, resident_inputs=1, **kw))
        return (n_planes - 1) * per

    # ------------------------------------------------------------------ #
    # Microbenchmark suite (Fig 17): per-element costs on two w-bit vectors
    # ------------------------------------------------------------------ #

    def microbench(self, name: str, maj_fan_in: int, n_rg: int,
                   width: int = 32, **kw) -> OpCost:
        m, n = maj_fan_in, n_rg
        if name in ("and", "or"):
            return self.reduce_tree(2 * width, m, n, **kw)
        if name == "xor":
            return self.xor_reduce(2 * width, m, n, **kw)
        if name == "add":
            return self.add(width, m, n, **kw)
        if name == "sub":
            return self.add(width, m, n, **kw)
        if name == "mul":
            return self.mul(width, m, n, **kw)
        if name == "div":
            return self.div(width, m, n, **kw)
        raise KeyError(name)


MICROBENCHES = ("and", "or", "xor", "add", "sub", "mul", "div")


def throughput_elems_per_s(cost: OpCost, row_bits: int,
                           success_rate: float = 1.0) -> float:
    """Usable elements per second: stable lanes / latency (paper's metric)."""
    if cost.latency_ns <= 0:
        return float("inf")
    return row_bits * success_rate / (cost.latency_ns * 1e-9)
