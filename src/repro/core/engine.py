"""PulsarEngine — the PuM compute engine behind the ``repro.pum`` API.

The public way to use this system is :mod:`repro.pum` (``PumArray``
operator frontend + ``Device``/``EngineConfig`` + the backend registry);
``PulsarEngine``'s dataplane *method* surface (``add``/``and_``/…) is kept
as a thin compat shim that emits ``DeprecationWarning`` and delegates to
the private implementations the new API calls directly. Construction,
``stats``/``reset_stats``, ``flush`` and the cost-plane helpers
(``op_effective_ns``) are NOT deprecated — ``Device`` wraps them.

Two coupled planes:
  * dataplane: bit-exact results. ``backend="fast"`` computes on packed
    NumPy words via the same bit-plane algorithms (vectorized, scales to
    millions of elements; the TPU-accelerated variant of these inner loops is
    kernels/ — same algorithms, Pallas-tiled). ``backend="sim"`` routes every
    operation through the DRAM chip model + command programs (bit-exact AND
    cycle-exact; used by tests and small demos).
  * cost plane: every op is priced by the closed-form cost model with the
    paper's methodology (per-op best-throughput N_RG, stable-lane efficiency,
    optional multi-bank parallelism) so application benchmarks (Fig 20)
    report PuM latencies regardless of dataplane backend.

Fused execution (``fuse=True``, backend="fast" only): dataplane ops record
into a lazy op graph and return ``LazyArray`` handles; ``flush()`` (or any
value access) compiles the whole graph into ONE jit'd bit-plane pipeline
(kernels/fused_program.py) — on TPU operands transpose to vertical layout
once, the Pallas program runs fused, outputs transpose back once; on CPU
the same program fuses in the word domain (transposes cancel, so they are
elided — same semantics, validated in tests). This mirrors in
silicon what PULSAR's chained staging does in the DRAM command stream
(§5.2): batch the op sequence, pay the staging cost once. The *cost plane
is invariant*: every op is charged at record time exactly as in eager mode,
so EngineStats (and fig17/fig20 numbers) are identical in both modes.

The whole integer op set is in the fused ISA — including ``mul``
(shift-add over the add plane) and ``div``/``mod`` (restoring division
over the add/sub planes) — so complete workloads compile to one trace.
Before compilation the recorded graph is normalized (CSE + dead-node
pruning, ``fused_program.optimize_program``); auto-flush thresholds
(``flush_threshold`` recorded ops / ``flush_memory_bytes`` estimated graph
bytes) bound graph growth for long-running callers. Only the sim backend
stays eager-only.

Width semantics: fused arithmetic computes modulo 2**width (the vertical
layout holds ``width`` planes); arithmetic operands with bits at or above
``width`` are rejected at record time rather than silently truncated,
because eager ops compute on raw uint64 values. The *plane-wise* ops
(``and_``/``or_``/``xor``) instead switch to a raw packed-bitmap mode on
out-of-width operands: each 64-bit word reinterprets onto the plane
layout's lanes (two 32-bit lanes per word on the 32-bit layout, the word
itself on the 64-bit layout — bit-exact for bitwise ops at any value
range; this is what realworld's packed-bitmap kernels route through),
and the lanes are re-joined at materialization. Cost charging is
identical either way: ops are priced on the caller-visible element count
before the dataplane splits lanes.

Plane layouts: the lane word format is an explicit
:class:`~repro.kernels.plane_layout.PlaneLayout` (default: the narrowest
canonical layout holding ``width`` — 32-bit up to width 32, 64-bit
above). The fused pipeline, leaf snapshots and the raw lane split all
derive from it, and evaluator selection filters the backend registry by
it — width-64 fused execution is the 64-bit layout plus the additively
registered ``*-64`` evaluators, not a special case. ``fused_backend``
pins a specific registered fused evaluator by name (e.g. the
multi-device ``"shard-words"`` pipeline).
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time
import warnings
import weakref
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.backends import get_backend, select_backend
from repro.core.charact import SuccessRateDb, default_db
from repro.core.cost_model import CostModel, OpCost, ZERO
from repro.core.geometry import PAPER_MODULE
from repro.core.profiles import PROFILES
from repro.kernels import fused_program as _fused
from repro.kernels.fused_program import (FusedOp, FusedProgram, get_pipeline,
                                         optimize_program,
                                         with_fault_injection)
from repro.kernels.plane_layout import (PlaneLayout, get_layout,
                                        layout_for_width)
from repro.telemetry import NULL_TRACER, CounterBank


def _warn_deprecated(method: str, replacement: str) -> None:
    """One-line compat-shim warning: the PulsarEngine op methods survive
    for out-of-tree callers, but in-repo code goes through repro.pum."""
    warnings.warn(
        f"PulsarEngine.{method}() is deprecated; use {replacement} "
        f"(repro.pum — migration table in docs/api.md)",
        DeprecationWarning, stacklevel=3)


@dataclasses.dataclass
class EngineStats:
    """Accumulated cost-plane charges for one engine session.

    Units: ``latency_ns`` and ``refresh_stall_ns`` in nanoseconds,
    ``energy_j`` in joules (per-command energies derive from pJ-scale
    DDR4 IDD figures in the cost model), ``n_sequences`` counts
    row-activation command sequences, ``lane_efficiency`` is the minimum
    success rate (0..1] over the ops used. Charges accrue at op-issue
    time in both eager and fused modes (fused ``flush()`` never touches
    this object), so the two modes are stats-identical by construction.
    """
    latency_ns: float = 0.0
    energy_j: float = 0.0
    n_sequences: int = 0
    lane_efficiency: float = 1.0  # min success rate over ops used
    refresh_stall_ns: float = 0.0  # controller-modeled REF interference

    def as_dict(self) -> dict:
        """Plain-JSON snapshot with explicit units in the key names — the
        same schema telemetry JSON (``BENCH_*.json``) embeds."""
        return {
            "latency_ns": self.latency_ns,
            "energy_j": self.energy_j,
            "n_sequences": self.n_sequences,
            "lane_efficiency": self.lane_efficiency,
            "refresh_stall_ns": self.refresh_stall_ns,
        }

    def __repr__(self) -> str:
        # Defined in the body so @dataclass keeps it (units explicit:
        # the raw ns/J floats render unreadably at DRAM scales).
        return (f"EngineStats(latency={self.latency_ns:,.1f} ns, "
                f"energy={self.energy_j * 1e6:,.3f} uJ, "
                f"sequences={self.n_sequences:,}, "
                f"lane_efficiency={self.lane_efficiency:.4f}, "
                f"refresh_stall={self.refresh_stall_ns:,.1f} ns)")

    def charge(self, cost: OpCost, n_vec_rows: int, banks: int,
               success: float, batch=None) -> None:
        if batch is None:
            # Legacy closed-form divide: ideal bank-level parallelism.
            eff_rows = -(-n_vec_rows // banks)
            self.latency_ns += cost.latency_ns * eff_rows
        else:
            # Controller-scheduled pricing: the measured bank-parallel
            # speedup (tFAW/tRRD/bus-limited, <= banks) and the steady-state
            # refresh slowdown replace the ideal divide.
            speedup = max(1.0, batch.parallel_speedup)
            base = max(cost.latency_ns * n_vec_rows / speedup,
                       cost.latency_ns * (-(-n_vec_rows // banks)))
            total = base * batch.refresh_factor
            self.latency_ns += total
            self.refresh_stall_ns += total - base
        self.energy_j += cost.energy_j * n_vec_rows
        self.n_sequences += cost.n_sequences * n_vec_rows
        self.lane_efficiency = min(self.lane_efficiency, success)


class FlushHandle:
    """Future-like handle for one :meth:`PulsarEngine.flush_async`.

    ``result()`` blocks until the dispatched graph(s) materialize (after
    which every LazyArray the flush covered holds its value) and re-raises
    the flush error on failure — a failed async flush parks its graph for
    retry exactly like a failed synchronous ``flush()``, so a later
    ``flush()``/``materialize()`` recovers the pending handles."""

    __slots__ = ("_future",)

    def __init__(self, future=None):
        self._future = future  # None => the flush had nothing to dispatch

    def done(self) -> bool:
        return self._future is None or self._future.done()

    def result(self, timeout: float | None = None) -> None:
        """Wait for the dispatch; re-raises the flush failure, if any."""
        if self._future is not None:
            self._future.result(timeout)

    def exception(self, timeout: float | None = None):
        if self._future is None:
            return None
        return self._future.exception(timeout)

    def __repr__(self) -> str:
        state = "done" if self.done() else "in-flight"
        return f"FlushHandle({state})"


class LazyArray:
    """Handle for a value pending in the engine's fused op graph.

    Behaves like a read-only array: ``np.asarray`` (or ``materialize()``)
    triggers ``engine.flush()`` on first access. Feeding it back into engine
    ops extends the graph instead of materializing.
    """

    __slots__ = ("_engine", "_graph", "_op_idx", "shape", "__weakref__",
                 "_value")

    def __init__(self, engine: "PulsarEngine", graph: "_OpGraph",
                 op_idx: int, shape: tuple):
        self._engine = engine
        self._graph = graph
        self._op_idx = op_idx
        self.shape = shape
        self._value: np.ndarray | None = None

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def dtype(self):
        return np.dtype(np.uint64)

    def materialize(self) -> np.ndarray:
        if self._value is None:
            g, eng = self._graph, self._engine
            if g is not None and eng is not None:
                # Route to the owning graph: it may belong to another
                # client context, sit on the retry list after a failed
                # flush, or be in flight on the async flush worker — the
                # engine dispatches or waits as appropriate.
                eng._materialize_graph(g)
            elif eng is not None:
                eng.flush()
        if self._value is None:
            raise RuntimeError(
                "LazyArray failed to materialize: the engine flush that "
                "should have produced it did not complete")
        return self._value

    def __array__(self, dtype=None, copy=None):
        v = self.materialize()
        return v.astype(dtype) if dtype is not None else v

    # ndarray conveniences the app kernels lean on: each materializes
    # (flushing the graph) and delegates — results are plain ndarrays.
    def sum(self, *args, **kw):
        return self.materialize().sum(*args, **kw)

    def reshape(self, *shape, **kw) -> np.ndarray:
        return self.materialize().reshape(*shape, **kw)

    def astype(self, dtype, **kw) -> np.ndarray:
        return self.materialize().astype(dtype, **kw)

    # ndarray comparison/truth semantics, not object identity: code ported
    # from eager mode must not silently get `False` from `t1 == t2`.
    def __eq__(self, other):
        return self.materialize() == np.asarray(other)

    def __ne__(self, other):
        return self.materialize() != np.asarray(other)

    __hash__ = None  # unhashable, like ndarray

    def __bool__(self):
        return bool(self.materialize())

    def __repr__(self) -> str:
        state = "pending" if self._value is None else "materialized"
        return f"LazyArray(shape={self.shape}, {state})"


def _DEAD_REF():  # weakref stand-in for ops that must never be outputs
    return None


def _stage_wire(flat, pad: int, layout: PlaneLayout,
                copy: bool = False) -> np.ndarray:
    """Flat lane array -> padded int32 wire array with AT MOST one host
    copy: the pad tail and the lane-dtype conversion fuse into a single
    allocation (NumPy converts during the assignment), and an in-dtype
    unpadded input stages as a pure view unless ``copy`` forces private
    memory (required when ``flat`` still aliases a caller buffer)."""
    if pad:
        out = np.zeros(flat.size + pad, layout.np_dtype)
        out[:flat.size] = flat
        return layout.to_wire(out)
    if flat.dtype != layout.np_dtype:
        return layout.to_wire(flat.astype(layout.np_dtype))
    if copy:
        flat = flat.copy()
    return layout.to_wire(flat)


class _LeafCacheEntry:
    """One cached leaf upload: the private padded host wire plus (lazily)
    its committed device buffer. ``fp`` is the 257-sample content
    fingerprint taken when the source buffer was registered — a lookup
    only hits while the caller's memory still matches it."""

    __slots__ = ("key", "fp", "wire", "dev", "nbytes")

    def __init__(self, key, fp, wire):
        self.key = key
        self.fp = fp
        self.wire = wire        # private padded int32 host wire
        self.dev = None         # committed jax buffer (lazy, non-donating)
        self.nbytes = wire.nbytes


class _LeafCache:
    """Fingerprint-keyed cache of staged leaf uploads (the device-resident
    leaf cache). Keyed on the *caller buffer* — (data pointer, byte size,
    layout, raw mode) — and guarded by the same sampled content
    fingerprint as the graph's leaf dedup, so repeated flushes over the
    same operands (ServeEngine stop predicates, pum_database scans, the
    BMI/k-clique AND-chains) stage zero bytes and re-upload nothing: the
    entry's host wire is private (inserted from a record-time snapshot)
    and its device buffer commits once and survives across flushes and
    ``CapturedProgram`` replays.

    LRU-bounded by ``capacity`` bytes of host wire (the device mirror is
    counted implicitly — it exists only for entries hot enough to hit a
    jitted pipeline). Thread-safe behind its own lock: record-side
    lookups run under the engine lock, but staging/dispatch
    (``_prepare_graph``/``_run_staged``) runs outside it.

    Donation policy: a donating flush never passes a cached buffer to the
    trace — it serves the private host wire (jax device-puts and donates
    a *fresh* buffer) and drops the entry's device residency, so donated
    buffers are evicted and cached ones are never donated."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._lock = threading.Lock()
        self._entries: collections.OrderedDict = collections.OrderedDict()
        self._bytes = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def nbytes(self) -> int:
        return self._bytes

    def lookup(self, key, fp) -> "_LeafCacheEntry | None":
        with self._lock:
            e = self._entries.get(key)
            if e is not None and np.array_equal(e.fp, fp):
                self._entries.move_to_end(key)
                return e
            return None

    def insert(self, key, fp, wire) -> tuple["_LeafCacheEntry | None", int]:
        """Cache ``wire`` (a private buffer) under ``key``; returns
        ``(entry, n_evicted)``. Oversized singletons are not cached."""
        if wire.nbytes > self.capacity:
            return None, 0
        entry = _LeafCacheEntry(key, fp, wire)
        evicted = 0
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self._bytes -= old.nbytes
            self._entries[key] = entry
            self._bytes += entry.nbytes
            while self._bytes > self.capacity and len(self._entries) > 1:
                _, dead = self._entries.popitem(last=False)
                self._bytes -= dead.nbytes
                evicted += 1
        return entry, evicted

    def device_buffer(self, entry: "_LeafCacheEntry"):
        """The entry's committed device buffer (uploads once, lazily)."""
        dev = entry.dev
        if dev is None:
            import jax.numpy as jnp
            dev = jnp.asarray(entry.wire)
            with self._lock:
                if entry.dev is None:
                    entry.dev = dev
                else:       # another flush won the commit race
                    dev = entry.dev
        return dev

    def drop_device(self, entry: "_LeafCacheEntry") -> None:
        """Release device residency (donating flushes: the trace consumes
        a fresh buffer, so any committed mirror is stale weight)."""
        with self._lock:
            entry.dev = None

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._bytes = 0


class _Leaf:
    """One registered operand of an op graph.

    Exactly one staging source is set:

    * ``entry`` — a leaf-cache entry whose fingerprint matched at record
      time: flush stages the cached wire (or its committed device
      buffer) and the record-time ``.copy()`` is elided entirely;
    * ``wire`` — the record-time snapshot, already in padded wire form
      (one fused pad+convert copy when the operand aliased caller
      memory; a zero-copy view when ``ravel()`` already privatized it).
    """

    __slots__ = ("wire", "entry", "nbytes")

    def __init__(self, wire=None, entry=None, nbytes=0):
        self.wire = wire
        self.entry = entry
        self.nbytes = nbytes


# The 257-point fingerprint sample grid per lane count: every graph at a
# given lane count shares one read-only index array — rebuilding it per
# flush (np.linspace + astype) is measurable against small programs.
_FP_IDX_CACHE: dict[int, np.ndarray] = {}


def _fp_indices(n: int) -> np.ndarray:
    idx = _FP_IDX_CACHE.get(n)
    if idx is None:
        if len(_FP_IDX_CACHE) >= 1024:  # unbounded lane-count churn guard
            _FP_IDX_CACHE.clear()
        idx = np.linspace(0, n - 1, min(n, 257)).astype(np.int64)
        idx.setflags(write=False)
        _FP_IDX_CACHE[n] = idx
    return idx


class _OpGraph:
    """Recording buffer for one fused program: leaf operand arrays plus the
    op list, with weakrefs to the handed-out LazyArrays (ops whose handle
    died unreferenced are dead code — never materialized).

    ``raw=True`` marks a packed-bitmap graph: plane-wise ops on raw uint64
    words, each reinterpreted as ``layout.raw_lanes_per_word`` dataplane
    lanes (two 32-bit lanes on the 32-bit layout, one full-width lane on
    the 64-bit layout; ``n`` counts lanes, width is the layout's word
    size). A graph is entirely raw or entirely value-mode; the engine
    flushes at mode boundaries."""

    def __init__(self, n: int, width: int, layout: PlaneLayout,
                 raw: bool = False, cache: "_LeafCache | None" = None):
        self.n = n                      # dataplane lane count (all values)
        self.width = width
        self.layout = layout
        self.raw = raw
        self.cache = cache              # engine's leaf cache (may be None)
        self.leaves: list[_Leaf] = []
        self._leaf_ids: dict[int, int] = {}
        self._pins: list[np.ndarray] = []  # keep id() keys alive (below)
        self._fps: list[np.ndarray] = []   # content fingerprints (below)
        self._fp_idx = _fp_indices(n)
        self._pad = (-n) % 32  # every pipeline tiles lanes in groups of 32
        self.elided_bytes = 0  # snapshot copies skipped (cache hit / view)
        self.cache_evictions = 0
        self.ops: list[tuple[str, tuple, int]] = []  # (opcode, args, param)
        self.results: list = []         # weakref per op
        # perf_counter_ns at first recorded op — set only when a tracer is
        # attached, so flush() can emit the "flush.record" span.
        self.t_start: int | None = None
        # Flush lifecycle (guarded by the engine lock): "recording" in a
        # client context's slot, "queued" parked on the retry list after a
        # failed flush, "flushing" detached and being dispatched (``done``
        # is then an Event concurrent materializers wait on), "done".
        self.state: str = "recording"
        self.done: threading.Event | None = None

    def leaf_id(self, arr: np.ndarray) -> tuple[str, int]:
        """Register an operand under the copy-on-write snapshot contract
        (mod the layout word — the pipeline keeps planes[:width]): the
        graph must not alias caller buffers, or mutations between record
        and flush would silently diverge from eager results. Re-feeding
        the same array object dedups to one pipeline input, guarded by a
        sampled content fingerprint so an in-place mutation between two
        recorded uses registers a fresh leaf instead of reusing the stale
        snapshot. (The guard samples 257 positions; a mutation confined
        to unsampled elements can still alias — call flush() before
        mutating operands in place.)

        The record-time ``.copy()`` is taken only when it is needed:

        * the engine's leaf cache holds an entry for this buffer whose
          fingerprint still matches -> stage straight from the cache,
          copy nothing;
        * ``ravel()`` already privatized the memory (non-contiguous
          operand, e.g. a broadcast scalar) -> the private flat array IS
          the snapshot;
        * otherwise the operand aliases caller memory -> snapshot now
          (directly into padded wire form, one fused copy) and seed the
          cache so the NEXT flush over this buffer stages zero bytes.
        """
        key = id(arr)
        rav = arr.ravel()
        flat = rav
        if self.raw:  # reinterpret uint64 words as layout lanes
            flat = self.layout.raw_lanes(rav)
        idx = self._leaf_ids.get(key)
        if idx is not None and np.array_equal(flat[self._fp_idx],
                                              self._fps[idx]):
            return ("leaf", idx)
        # Width guard is value-mode only: raw lanes carry full words and
        # the raw graph width is the word size, so the scan could never
        # fire there.
        if not self.raw and self.width < 64 and flat.size \
                and int(flat.max()) >> self.width:
            # Loud, not silent: eager ops compute on raw uint64 values
            # (realworld's packed-bitmap kernels rely on that), so
            # truncating here would quietly change their answers.
            raise ValueError(
                f"fused dataplane computes modulo 2**{self.width}; an "
                f"operand has bits at or above bit {self.width} — mask "
                f"inputs to the engine width or use fuse=False")
        i = len(self.leaves)
        self._leaf_ids[key] = i  # latest content owns the dedup slot
        fp = flat[self._fp_idx]  # fancy indexing: always a private copy
        nbytes = flat.size * self.layout.nbytes_per_word
        # ``ravel()`` returns a view (base set) iff the flat memory still
        # belongs to the caller; a fresh copy (base None) is private.
        shared = rav.base is not None or rav is arr
        ckey = entry = None
        if shared and self.cache is not None and flat.size:
            ckey = (flat.__array_interface__["data"][0], flat.nbytes,
                    self.layout.name, self.raw)
            entry = self.cache.lookup(ckey, fp)
        if entry is not None:
            self.elided_bytes += nbytes          # record-time cache hit
            self.leaves.append(_Leaf(entry=entry, nbytes=nbytes))
        else:
            wire = _stage_wire(flat, self._pad, self.layout, copy=shared)
            if wire.base is not None and not shared:
                self.elided_bytes += nbytes      # staged as a pure view
            if ckey is not None:                 # seed for the next flush
                entry, ev = self.cache.insert(ckey, fp, wire)
                self.cache_evictions += ev
            self.leaves.append(_Leaf(wire=wire, entry=None, nbytes=nbytes))
        self._fps.append(fp)
        # Pin the original: the id() dedup key is only valid while the
        # caller's array stays alive.
        self._pins.append(arr)
        return ("leaf", i)

    def stage_leaf(self, li: int) -> np.ndarray:
        """The padded int32 host wire for leaf ``li`` (zero-copy: either
        the record-time snapshot or the cached upload's host wire)."""
        leaf = self.leaves[li]
        e = leaf.entry
        return leaf.wire if e is None else e.wire

    def add_op(self, opcode: str, args: tuple, param: int,
               out: "LazyArray", internal: bool = False) -> int:
        self.ops.append((opcode, args, param))
        # Internal ops (tuple values feeding selectors) record a dead ref:
        # they can never be materialized as a program output.
        self.results.append(_DEAD_REF if internal else weakref.ref(out))
        return len(self.ops) - 1


class PulsarEngine:
    """Bulk bitwise/bit-serial integer SIMD on (simulated) PuM DRAM.

    Dataplane values are unsigned integers carried in uint64 ndarrays;
    arithmetic ops (``add``/``sub``/``mul``/``div``/``mod``/``less_than``/
    ``popcount``/``reduce_bits``) compute modulo ``2**width``. The cost
    plane prices every op in nanoseconds/joules via the paper-calibrated
    ``CostModel`` (``stats.latency_ns`` / ``stats.energy_j``), independent
    of which dataplane backend produced the values.

    With ``fuse=True`` ops return :class:`LazyArray` handles and execute
    as one compiled program per :meth:`flush` — bit-exact and
    stats-identical to eager, including division by zero. The public way
    in is :mod:`repro.pum` (div-by-zero yields 0, as in eager NumPy; a
    ``divmod`` shares one restoring-division pass):

    >>> import numpy as np
    >>> import repro.pum as pum
    >>> with pum.device(mfr="M", width=16, fuse=True) as dev:
    ...     q, r = divmod(dev.asarray(np.array([1000, 7], np.uint64)),
    ...                   np.array([6, 0], np.uint64))
    >>> np.asarray(q)
    array([166,   0], dtype=uint64)
    >>> int(r.to_numpy()[0])
    4
    >>> with pum.device(width=16, fuse=False) as dev2:   # eager twin
    ...     _ = divmod(dev2.asarray(np.array([1000, 7], np.uint64)),
    ...                np.array([6, 0], np.uint64))
    >>> dev.stats == dev2.stats          # identical cost-plane charges
    True

    ``flush_threshold`` (recorded ops) and ``flush_memory_bytes``
    (estimated graph footprint) auto-flush oversized graphs; pass ``None``
    to disable either bound. ``donate_leaves=True`` donates the fused
    pipeline's leaf device buffers to the compiled trace (cuts peak
    memory; bit-exactness unaffected — the engine's snapshots live on the
    host). The ``backend`` name resolves through the ``repro.backends``
    registry (capability ``"eager"``): ``"fast"`` computes on packed
    NumPy words, ``"sim"`` routes through the bit-exact chip model.
    """

    def __init__(self, mfr: str = "M", width: int = 32,
                 row_bits: int = 65536, banks: int = 16,
                 backend: str = "fast",
                 success_db: SuccessRateDb | None = None,
                 use_pulsar: bool = True, chained: bool = False,
                 controller=None, seed: int = 0, fuse: bool = False,
                 flush_threshold: int | None = 1024,
                 flush_memory_bytes: int | None = 1 << 30,
                 donate_leaves: bool = False, layout=None,
                 fused_backend: str | None = None,
                 ref_postponing: int = 1, reliability=None,
                 cmd_buffer_lookahead: int = 8,
                 leaf_cache_bytes: int | None = 1 << 26):
        self.profile = PROFILES[mfr]
        self.mfr = mfr
        self.width = width
        self.row_bits = row_bits
        self.banks = banks
        self.backend = backend
        self.seed = seed
        self.use_pulsar = use_pulsar  # False => FracDRAM baseline costs
        self.chained = chained and use_pulsar  # chained-staging (§Perf P4)
        # Plane layout: the lane word format of the fused dataplane.
        # Default: the narrowest canonical layout holding `width` bits
        # (width <= 32 keeps the exact pre-layout 32-bit behavior).
        self.layout = (layout_for_width(width) if layout is None
                       else get_layout(layout))
        if width > self.layout.word_bits:
            raise ValueError(
                f"width {width} does not fit the {self.layout.word_bits}"
                f"-bit plane layout {self.layout.name!r}")
        # controller="auto" builds a MemoryController over `banks` banks;
        # None keeps the legacy closed-form bank divide (reproduces the
        # pre-controller numbers exactly). `ref_postponing` batches up to
        # N REF commands into one rank lockout (JEDEC allows 8) — longer
        # but rarer refresh windows, priced by batch_cost.
        if not 1 <= ref_postponing <= 8:
            raise ValueError(
                f"ref_postponing must be in [1, 8] (JEDEC allows "
                f"postponing up to 8 REFs), got {ref_postponing}")
        if ref_postponing != 1 and controller != "auto":
            # Loud, not silently inert: the closed-form path never models
            # refresh, and a prebuilt controller carries its own policy.
            raise ValueError(
                "ref_postponing requires controller='auto' (with "
                "controller=None refresh is not modeled; a prebuilt "
                "MemoryController sets postponing= itself)")
        if cmd_buffer_lookahead < 1:
            raise ValueError(f"cmd_buffer_lookahead must be >= 1, got "
                             f"{cmd_buffer_lookahead}")
        if controller == "auto":
            from repro.controller import MemoryController
            controller = MemoryController(n_banks=banks,
                                          postponing=ref_postponing,
                                          lookahead=cmd_buffer_lookahead)
        self.controller = controller
        self.ref_postponing = ref_postponing
        # Crossbar command-buffer depth for concurrent-stream scheduling;
        # execution-only (never priced by the single-stream cost plane).
        self.cmd_buffer_lookahead = cmd_buffer_lookahead
        self.cost = CostModel(row_bits=row_bits, controller=controller)
        self.db = success_db or default_db()
        # Concurrency state: one recording slot + one EngineStats shard
        # per client context (a thread, or a named ``client()`` scope).
        # The RLock guards all record-side mutation (slots, shards, cost
        # caches, retry list); compiled-pipeline dispatch runs outside it.
        self._lock = threading.RLock()
        self._local = threading.local()
        self._slots: dict[tuple, _OpGraph] = {}
        self._stats_shards: dict[tuple, EngineStats] = {}
        self._retry: list[_OpGraph] = []       # failed flushes, FIFO
        self._inflight: dict[int, object] = {}  # id(graph) -> Future
        self._executor: ThreadPoolExecutor | None = None
        # Double-buffered async flush: at most 2 staged dispatches in
        # flight — the caller stages flush k+1 while the worker runs k.
        self._async_slots = threading.BoundedSemaphore(2)
        self._best_cfg_cache: dict[int, tuple[int, int, float]] = {}
        self._batch_cache: dict[tuple, object] = {}
        # Eager-dataplane backend by registry lookup: the builder returns
        # None for the packed-NumPy word dataplane or an ALU-protocol
        # object (see repro.backends.BackendSpec) to route ops through.
        spec = get_backend(backend)
        if "eager" not in spec.capabilities:
            raise ValueError(
                f"backend {backend!r} has no eager dataplane "
                f"(capabilities: {sorted(spec.capabilities)})")
        if width > spec.max_width:
            raise ValueError(
                f"backend {backend!r} supports width <= {spec.max_width}, "
                f"got {width}")
        if not spec.available():
            raise ValueError(f"backend {backend!r} is registered but not "
                             f"available on this host")
        self._alu = spec.builder(self)
        if fuse and self._alu is not None:
            raise ValueError(
                f"fuse=True requires an eager word-dataplane backend "
                f"(builder returns None, e.g. 'fast'); backend "
                f"{backend!r} routes ops through an ALU and stays "
                f"per-op")
        if fused_backend is not None:
            fspec = get_backend(fused_backend)
            if "fused" not in fspec.capabilities:
                raise ValueError(
                    f"fused_backend {fused_backend!r} has no fused "
                    f"evaluator (capabilities: "
                    f"{sorted(fspec.capabilities)})")
            if width > fspec.max_width \
                    or self.layout.word_bits not in fspec.layouts:
                raise ValueError(
                    f"fused_backend {fused_backend!r} covers width <= "
                    f"{fspec.max_width} on layouts "
                    f"{sorted(fspec.layouts)}; engine is width {width} "
                    f"on the {self.layout.word_bits}-bit layout")
        elif fuse:
            # Layout capability query (replaces the old hardwired
            # `width > 32` guard): some registered fused evaluator must
            # cover this width on this plane layout. pum.Device falls
            # back to eager automatically when nothing does.
            try:
                select_backend(require="fused", width=width,
                               layout=self.layout)
            except LookupError as e:
                raise ValueError(
                    f"no registered fused evaluator covers width {width} "
                    f"on the {self.layout.word_bits}-bit plane layout "
                    f"({e}); use fuse=False or register_backend() one"
                ) from None
        if flush_threshold is not None and flush_threshold < 1:
            raise ValueError("flush_threshold must be >= 1 or None")
        if leaf_cache_bytes is not None and leaf_cache_bytes < 0:
            raise ValueError(
                f"leaf_cache_bytes must be >= 0 or None (0/None disables "
                f"the leaf cache), got {leaf_cache_bytes}")
        self.fuse = fuse
        self.fused_backend = fused_backend
        self.flush_threshold = flush_threshold
        self.flush_memory_bytes = flush_memory_bytes
        self.donate_leaves = donate_leaves
        # Device-resident leaf cache: staged leaf uploads keyed on the
        # caller's buffer + content fingerprint, shared across all client
        # contexts of this engine (one cache per device). 0/None disables.
        self.leaf_cache_bytes = leaf_cache_bytes or 0
        self._leaf_cache = (_LeafCache(leaf_cache_bytes)
                            if leaf_cache_bytes else None)
        # Telemetry: counters always exist (cheap dict, written only while
        # a tracer is attached); ``tracer`` is None until someone opts in
        # (pum.profile(), ServeEngine(telemetry=True)) — the disabled path
        # is a single `is None` check per flush, nothing per op.
        self.counters = CounterBank()
        self.tracer = None
        # Autotuner hook: None (default) costs one `is None` check per
        # flush; Device.autotune(online=True) installs an
        # repro.autotune.OnlineAutotuner whose on_flush() closes the
        # measure->decide->apply loop at flush granularity.
        self.autotuner = None
        # Reliability plane: calibrated-map planning/placement plus the
        # flush-time injection + vote/retry loop (repro.reliability). None
        # (default) keeps every path exactly as before — the enabled check
        # is a single `is None` per flush, like the tracer.
        self.reliability = None
        if reliability is not None:
            from repro.reliability import ReliabilityPlane
            self.reliability = ReliabilityPlane(
                reliability, mfr=mfr, counters=self.counters)
            if self.reliability.inject and not fuse:
                raise ValueError(
                    "reliability fault injection hooks the fused dispatch "
                    "path; it requires fuse=True (eager ops never run the "
                    "vote/retry loop)")

    # ------------------------------------------------------------------ #
    # Client contexts (per-thread / named recording slots + stats shards)
    # ------------------------------------------------------------------ #

    def _ctx_key(self) -> tuple:
        name = getattr(self._local, "client", None)
        if name is not None:
            return ("client", name)
        return ("thread", threading.get_ident())

    @contextlib.contextmanager
    def client(self, name: str):
        """Scope ops to a named client context.

        Inside the scope, recorded ops go to the context's own graph slot
        and cost charges to its own stats shard — so N logical clients can
        share one engine (from any threads) without interleaving their
        programs. Without a ``client()`` scope the calling thread is its
        own implicit context."""
        prev = getattr(self._local, "client", None)
        self._local.client = str(name)
        try:
            yield self
        finally:
            self._local.client = prev

    @property
    def _graph(self) -> "_OpGraph | None":
        """The current client context's recording graph (or None)."""
        return self._slots.get(self._ctx_key())

    @_graph.setter
    def _graph(self, g: "_OpGraph | None") -> None:
        key = self._ctx_key()
        if g is None:
            self._slots.pop(key, None)
        else:
            self._slots[key] = g

    def _stats_shard(self) -> EngineStats:
        s = self._stats_shards.get(self._ctx_key())
        if s is None:
            s = self._stats_shards[self._ctx_key()] = EngineStats()
        return s

    @property
    def stats(self) -> EngineStats:
        """Merged cost-plane charges across every client context.

        Per-context shards merge in sorted-key order, so the totals are
        identical no matter which thread/arbitration interleaving produced
        the charges (float addition is order-sensitive; the merge order is
        canonical). With a single context this is bit-identical to the
        pre-concurrency accumulator."""
        with self._lock:
            out = EngineStats()
            for key in sorted(self._stats_shards, key=str):
                s = self._stats_shards[key]
                out.latency_ns += s.latency_ns
                out.energy_j += s.energy_j
                out.n_sequences += s.n_sequences
                out.lane_efficiency = min(out.lane_efficiency,
                                          s.lane_efficiency)
                out.refresh_stall_ns += s.refresh_stall_ns
            return out

    # ------------------------------------------------------------------ #
    # Cost plumbing
    # ------------------------------------------------------------------ #

    def _kind_cost(self, kind: str, m: int, n_rg: int, w: int,
                   n_planes: int | None, n_rg3: int | None = None) -> OpCost:
        fs = self.profile.frac_supported
        ps = "pow2" if self.use_pulsar else "max"
        kw = dict(frac_supported=fs, plan_style=ps)
        ckw = dict(kw, chained=self.chained)
        c = self.cost
        if kind in ("and2", "or2"):
            return c.logic2(min(3, m), n_rg, **kw)
        if kind == "xor2":
            return c.xor2(min(3, m), n_rg, **kw)
        if kind == "add" or kind == "sub":
            return c.add(w, m, n_rg, n_rg3, **ckw)
        if kind == "mul":
            return c.mul(w, m, n_rg, n_rg3, **ckw)
        if kind == "div":
            return c.div(w, m, n_rg, n_rg3, **ckw)
        if kind in ("reduce_and", "reduce_or"):
            return c.reduce_tree(n_planes or w, m, n_rg, **ckw)
        if kind == "reduce_xor":
            return c.xor_reduce(n_planes or w, m, n_rg, **ckw)
        if kind == "popcount":
            out_w = max(1, (n_planes or w).bit_length())
            return (n_planes or w) * out_w * c.full_adder(m, n_rg, n_rg3,
                                                          **ckw)
        if kind == "compare":
            return c.add(w + 1, m, n_rg, n_rg3, **ckw)
        if kind in ("load", "store"):
            return (c.write_row() if kind == "load" else c.read_row()) * (2 * w)
        raise KeyError(kind)

    _ARITH = ("add", "sub", "mul", "div", "popcount", "compare")

    def _cfg_for(self, kind: str, w: int, n_planes: int | None
                 ) -> tuple[int, int, float, OpCost]:
        """Best (maj_fan_in, n_rg[, n_rg3]) for this op kind: minimizes
        latency / success_rate — the paper's per-op configuration search
        ("we choose the N_RG that produces the highest throughput").
        Arithmetic kinds search MAJ3/MAJ5 sub-op configs independently."""
        if not self.use_pulsar:
            # FracDRAM baseline: MAJ3 on 4-row activation only.
            sr = self.db.mean(self.mfr, 3, 4)
            return 3, 4, sr, self._kind_cost(kind, 3, 4, w, n_planes, 4)
        key = (kind, w, n_planes)
        if key not in self._best_cfg_cache:
            prof = self.profile
            cap = prof.max_simul_rows
            pows = [n for n in (4, 8, 16, 32) if n <= cap]
            rel = self.reliability

            def sr_of(m, n):
                if n < m:
                    return 0.0
                if rel is not None:
                    # Variation-aware planning: the calibrated map's
                    # (steering-weighted) rate for profiled configs; the
                    # global DB covers the rest.
                    s = rel.plan_success(m, n)
                    if s is not None:
                        return s
                return self.db.mean(self.mfr, m, n, plan_style="pow2")

            candidates: list[tuple[int, int, int | None]] = []
            if kind in self._ARITH:
                for n3 in pows:                       # MAJ3-only FA
                    candidates.append((3, n3, None))
                if prof.max_maj_fan_in >= 5:
                    for n5 in pows:
                        for n3 in pows:
                            if n5 >= 5:
                                candidates.append((5, n5, n3))
            else:
                m = 3
                while m <= min(prof.max_maj_fan_in, cap):
                    for n in pows:
                        if n >= m:
                            candidates.append((m, n, None))
                    m += 2
            best = None
            best_ok = None  # reliability: best config MEETING the target
            target = (rel.config.target_success if rel is not None else None)
            for m, n, n3 in candidates:
                sr = sr_of(m, n)
                if n3 is not None:
                    sr = min(sr, sr_of(3, n3))
                if sr <= 1e-3:
                    continue
                cost = self._kind_cost(kind, m, n, w, n_planes, n3)
                eff = cost.latency_ns / sr
                if best is None or eff < best[0]:
                    best = (eff, m, n, sr, cost)
                if target is not None and sr >= target \
                        and (best_ok is None or eff < best_ok[0]):
                    best_ok = (eff, m, n, sr, cost)
            assert best is not None, f"no viable config for {kind}"
            # Per-op replication choice (Fig 11): prefer the fastest config
            # whose calibrated success meets the reliability target; only
            # when none does fall back to raw throughput (the vote/retry
            # loop then carries the correction burden).
            self._best_cfg_cache[key] = (best_ok or best)[1:]
        return self._best_cfg_cache[key]

    def _n_vec_rows(self, n_elems: int) -> int:
        return -(-n_elems // self.row_bits)

    def _batch_for(self, kind: str, m: int, n_rg: int):
        """Controller-measured bank-batch cost for this op's dominant
        primitive (the MAJ unit for compute kinds, the full-row transfer
        program for load/store), cached per configuration."""
        if kind in ("load", "store"):
            key = ("io", kind)
        else:
            key = ("maj", m, n_rg, self.chained)
        if key not in self._batch_cache:
            from repro.core import commands as cmds
            t = self.cost.t
            if kind == "load":
                unit = [cmds.prog_write_row(0, 0, self.cost._wr_bursts, t)]
            elif kind == "store":
                unit = [cmds.prog_read_row(0, 0, self.cost._wr_bursts, t)]
            else:
                unit = self.cost.maj_unit_programs(
                    m, n_rg, frac_supported=self.profile.frac_supported,
                    plan_style="pow2" if self.use_pulsar else "max",
                    # Chained staging keeps one input resident per MAJ, so
                    # measure bank contention on the thinner command stream.
                    resident_inputs=1 if self.chained else 0)
            order = (tuple(self.reliability.bank_order(self.banks))
                     if self.reliability is not None else None)
            self._batch_cache[key] = self.controller.batch_cost(
                unit, self.banks, bank_order=order)
        return self._batch_cache[key]

    def _charge(self, kind: str, n_elems: int, width: int | None = None,
                n_planes: int | None = None) -> None:
        with self._lock:
            log = getattr(self._local, "charge_log", None)
            if log is not None:
                # Program capture records the charge recipe so replays
                # price identically to the uncaptured path.
                log.append((kind, n_elems, width, n_planes))
            w = width or self.width
            m, n, sr, cost = self._cfg_for(kind, w, n_planes)
            if self.reliability is not None:
                # The flush-time vote loop injects at the worst config used.
                self.reliability.note_op(m, n, sr)
            batch = (self._batch_for(kind, m, n)
                     if self.controller is not None else None)
            self._stats_shard().charge(cost, self._n_vec_rows(n_elems),
                                       self.banks, sr, batch)

    def _replay_charges(self, recipe) -> None:
        """Re-apply a captured charge recipe (one replayed program)."""
        for kind, n_elems, width, n_planes in recipe:
            self._charge(kind, n_elems, width, n_planes)

    def op_effective_ns(self, kind: str, width: int | None = None,
                        n_planes: int | None = None
                        ) -> tuple[float, float, int, int]:
        """Amortized per-vector-row latency of one op at this engine's bank
        count: ``(latency_ns, success_rate, maj_fan_in, n_rg)``.  With a
        controller the latency is priced through the scheduled bank batch
        (tFAW/tRRD-limited speedup + refresh factor); without one it is the
        closed-form single-bank latency divided by ``banks``."""
        w = width or self.width
        m, n, sr, cost = self._cfg_for(kind, w, n_planes)
        if self.controller is None:
            return cost.latency_ns / self.banks, sr, m, n
        b = self._batch_for(kind, m, n)
        eff = (cost.latency_ns / max(1.0, b.parallel_speedup)
               * b.refresh_factor)
        return eff, sr, m, n

    # ------------------------------------------------------------------ #
    # Dataplane ops (fast backend: NumPy; sim backend: chip model;
    # fuse=True: record into the lazy op graph, execute at flush())
    # ------------------------------------------------------------------ #

    def _mask(self, w: int) -> np.uint64:
        return np.uint64((1 << w) - 1)

    def _coerce(self, x):
        """Engine-op operand: LazyArrays pass through while pending (so the
        graph extends); everything else becomes a uint64 ndarray."""
        if isinstance(x, LazyArray):
            return x if x._value is None else x._value
        return np.asarray(x, np.uint64)

    def _force(self, x) -> np.ndarray:
        return x.materialize() if isinstance(x, LazyArray) else x

    def _can_fuse(self, *operands) -> bool:
        if not self.fuse:
            return False
        shape = operands[0].shape
        return all(x.shape == shape for x in operands[1:])

    def _is_raw_operand(self, x) -> bool:
        """Does this operand carry bits at or above the engine width?
        (Pending raw-graph handles count; pending value-mode handles are
        in-width by construction.)"""
        if isinstance(x, LazyArray):
            if x._value is None:
                return x._graph is not None and x._graph.raw
            x = x._value
        return bool(self.width < 64 and x.size
                    and int(x.max()) >> self.width)

    def _use_raw(self, operands: tuple) -> bool:
        """Plane-wise ops route through the raw packed-bitmap graph when
        any operand is out of width (bit-exact: bitwise ops reinterpret
        cleanly onto the layout's lanes — two 32-bit lanes per word on
        the 32-bit layout, the word itself on the 64-bit one) or when a
        raw graph of the same lane count is already open (in-width words
        join it losslessly — their high bits are zero)."""
        g = self._graph
        if g is not None and g.raw \
                and g.n == self.layout.raw_lanes_per_word \
                * operands[0].size:
            return True
        return any(self._is_raw_operand(x) for x in operands)

    def _record(self, opcode: str, operands: tuple, param: int = 0,
                raw: bool = False, defer_flush: bool = False,
                internal: bool = False) -> LazyArray:
        """Append one op to the lazy graph (starting/flushing as needed)
        and hand back its LazyArray.

        ``defer_flush`` skips the auto-flush threshold check so a multi-op
        lowering (divmod -> selectors) records atomically — a flush
        between the tuple op and its selector would try to materialize a
        tuple value. ``internal=True`` marks an op that must never be a
        program output (its handle only carries the op index for selector
        args): it records a dead weakref so flush() can't see it live."""
        shape = operands[0].shape
        lanes_per_word = self.layout.raw_lanes_per_word if raw else 1
        n = operands[0].size * lanes_per_word  # dataplane lanes
        g = self._graph
        if g is not None and (g.n != n or g.raw != raw):
            if self.tracer is not None:
                self.counters.inc("engine.autoflush.mode_boundary")
            self.flush()  # one program = one lane count and one mode
        # Cross-context materialization (a pending lazy of ANOTHER graph
        # entering as a leaf) may dispatch a flush, so resolve operands
        # before taking the lock for this context's graph mutation.
        # A pending raw popcount also materializes before further use:
        # its lanes are per-lane partial counts that only become the
        # caller-visible word count at the materialize fold, so in-graph
        # consumers would see the packed halves instead of the sum.
        def _needs_fold(x):
            return (x._graph.raw
                    and x._graph.layout.raw_lanes_per_word == 2
                    and x._graph.ops[x._op_idx][0] == "popcount")

        resolved = [x.materialize() if isinstance(x, LazyArray)
                    and (not (x._value is None and x._graph is not None
                              and x._graph is self._graph)
                         or _needs_fold(x))
                    else x for x in operands]
        with self._lock:
            g = self._graph
            if g is None:
                g = self._graph = _OpGraph(
                    n, self.layout.word_bits if raw else self.width,
                    self.layout, raw=raw, cache=self._leaf_cache)
                if self.tracer is not None:
                    g.t_start = time.perf_counter_ns()
            if self.tracer is not None:
                self.counters.inc("engine.ops_recorded")
                self.counters.inc(f"engine.op.{opcode}")
                if raw:
                    self.counters.inc("engine.raw_ops")
            args = []
            for x in resolved:
                if isinstance(x, LazyArray) and x._value is None \
                        and x._graph is g:
                    args.append(("op", x._op_idx))
                else:
                    # Plain array or an already-materialized lazy —
                    # enters as a leaf.
                    arr = x.materialize() if isinstance(x, LazyArray) else x
                    args.append(g.leaf_id(arr))
            out = LazyArray(self, g, len(g.ops), shape)
            g.add_op(opcode, tuple(args), param, out, internal=internal)
            reason = None
            if not defer_flush \
                    and not getattr(self._local, "no_autoflush", False):
                reason = self._graph_over_threshold(g)
                if reason and self.tracer is not None:
                    self.counters.inc(f"engine.autoflush.{reason}")
        if reason:
            self.flush()  # auto-flush: `out` is live, materializes
        return out

    def _graph_over_threshold(self, g: _OpGraph) -> str | None:
        """Auto-flush policy: graph-size (recorded ops) and estimated
        memory (one layout word per lane per held value: leaf snapshots
        plus the pipeline's per-op intermediates). Returns the trigger
        name ("ops"/"memory", doubling as the telemetry counter suffix)
        or None when the graph may keep growing."""
        if self.flush_threshold is not None \
                and len(g.ops) >= self.flush_threshold:
            return "ops"
        if self.flush_memory_bytes is not None:
            est = g.layout.nbytes_per_word * g.n \
                * (len(g.leaves) + len(g.ops))
            if est >= self.flush_memory_bytes:
                return "memory"
        return None

    def flush(self) -> None:
        """Materialize the pending op graph through the fused bit-plane
        pipeline (one transpose in, one fused program, one transpose out).
        The recorded graph is normalized first (CSE + dead-node pruning,
        ``fused_program.optimize_program``) — results and EngineStats are
        unaffected, only redundant dataplane work is dropped. No-op when
        nothing is pending; never touches the cost plane — every op was
        charged at record time.

        Drains, in order: graphs parked by earlier failed flushes (the
        retry list), then the calling context's own pending graph. A
        failure parks the graph back on the retry list (never into a
        recording slot, so the restore cannot interleave with another
        client's in-flight record) and re-raises."""
        while True:
            g = self._take_next(self._ctx_key())
            if g is None:
                return
            self._dispatch_graph(g)

    def flush_all(self) -> None:
        """Flush every client context's pending graph, drain the retry
        list, and wait out in-flight async flushes (``Device.flush`` /
        clean ``with`` exit). Failures propagate like :meth:`flush`."""
        while True:
            with self._lock:
                futs = list(self._inflight.values())
            for f in futs:
                f.result()
            g = self._take_next(None)
            if g is None:
                with self._lock:
                    # An entry whose future resolved is stale (its
                    # registration raced the worker's pop) — drop it
                    # instead of spinning on it.
                    for k, f in list(self._inflight.items()):
                        if f.done():
                            del self._inflight[k]
                    if not self._inflight:
                        return
                continue
            self._dispatch_graph(g)

    def flush_async(self) -> FlushHandle:
        """Compile + dispatch the pending graph off the calling thread.

        The record-side half (dead-code scan, program normalization, leaf
        wire staging) runs on the caller — so at most two flushes are ever
        staged at once (double buffering: the caller stages flush k+1
        while the worker dispatches k; a third call blocks). The compile/
        dispatch/materialize half runs on the engine's single flush worker
        thread. Returns a :class:`FlushHandle`; ``result()`` re-raises a
        failed dispatch after parking the graph for retry exactly like a
        failed synchronous flush."""
        batch: list[_OpGraph] = []
        with self._lock:
            while self._retry:
                batch.append(self._begin_flush(self._retry.pop(0)))
            g = self._slots.pop(self._ctx_key(), None)
            if g is not None and g.ops:
                batch.append(self._begin_flush(g))
        if not batch:
            return FlushHandle(None)
        staged = []
        try:
            for g in batch:
                staged.append((g, self._prepare_graph(g)))
        except BaseException:
            # Nothing reached the worker yet: park the whole batch, in
            # order, so a later flush/materialize retries it.
            with self._lock:
                self._park_graphs(batch)
            raise
        self._async_slots.acquire()
        try:
            fut = self._ensure_executor().submit(self._async_run, staged)
        except BaseException:
            self._async_slots.release()
            with self._lock:
                self._park_graphs([g for g, _ in staged])
            raise
        with self._lock:
            for g, _ in staged:
                self._inflight[id(g)] = fut
        if fut.done():
            # The worker can drain _async_run before the entries above
            # land (its per-graph pops find nothing) — drop them here so
            # flush_all never waits on an already-finished dispatch.
            with self._lock:
                for g, _ in staged:
                    self._inflight.pop(id(g), None)
        if self.tracer is not None:
            self.counters.inc("engine.flush_async")
        return FlushHandle(fut)

    def close(self) -> None:
        """Shut the async flush worker down (waits for in-flight
        dispatches). Safe to call repeatedly; the worker is recreated
        lazily if ``flush_async`` is used again."""
        with self._lock:
            ex, self._executor = self._executor, None
        if ex is not None:
            ex.shutdown(wait=True)

    # -- flush plumbing -------------------------------------------------- #

    def _begin_flush(self, g: _OpGraph) -> _OpGraph:
        """Transition a detached graph to the flushing state (lock held)."""
        g.state = "flushing"
        g.done = threading.Event()
        return g

    def _park_graphs(self, graphs) -> None:
        """Park failed/abandoned flushes for retry (lock held): FIFO on
        the retry list, never back into a recording slot — restoring into
        a slot could interleave with that client's in-flight record."""
        for g in graphs:
            g.state = "queued"
            self._retry.append(g)
            if g.done is not None:
                g.done.set()

    def _take_next(self, key) -> "_OpGraph | None":
        """Pop the next graph to dispatch: retries first, then ``key``'s
        slot (or any slot when ``key`` is None, for flush_all)."""
        with self._lock:
            if self._retry:
                return self._begin_flush(self._retry.pop(0))
            if key is None:
                for k in list(self._slots):
                    return self._begin_flush(self._slots.pop(k))
                return None
            g = self._slots.pop(key, None)
            return None if g is None else self._begin_flush(g)

    def _ensure_executor(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._executor is None:
                self._executor = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="pum-flush")
            return self._executor

    def _async_run(self, staged) -> None:
        """Worker-side half of flush_async: dispatch each staged graph."""
        try:
            for g, st in staged:
                try:
                    if st is not None:
                        self._run_staged(g, st)
                    with self._lock:
                        g.state = "done"
                except BaseException:
                    with self._lock:
                        self._park_graphs([g])
                    raise
                finally:
                    if g.done is not None:
                        g.done.set()
                    with self._lock:
                        self._inflight.pop(id(g), None)
        finally:
            self._async_slots.release()

    def _dispatch_graph(self, g: _OpGraph) -> None:
        """Prepare + dispatch one detached graph on the calling thread."""
        try:
            st = self._prepare_graph(g)
            if st is not None:
                self._run_staged(g, st)
            with self._lock:
                g.state = "done"
        except BaseException:
            # Keep pending handles recoverable after a transient failure
            # (interrupt, backend OOM): park the graph so a later
            # flush/materialize retries instead of orphaning them.
            with self._lock:
                self._park_graphs([g])
            raise
        finally:
            if g.done is not None:
                g.done.set()

    def _materialize_graph(self, g: _OpGraph) -> None:
        """Make ``g``'s live handles hold values, wherever ``g`` is in the
        flush lifecycle: still recording (any context's slot), parked for
        retry, in flight on the async worker (wait on it), or done."""
        fut = None
        with self._lock:
            st = g.state
            if st == "recording":
                for k, v in list(self._slots.items()):
                    if v is g:
                        del self._slots[k]
                        break
                self._begin_flush(g)
            elif st == "queued":
                self._retry.remove(g)
                self._begin_flush(g)
            elif st == "flushing":
                fut = self._inflight.get(id(g))
        if st in ("recording", "queued"):
            self._dispatch_graph(g)
        elif st == "flushing":
            # Another thread is dispatching this graph (sync or async):
            # wait for it; if it failed and parked the graph, retry here.
            if fut is not None:
                fut.result()
            elif g.done is not None:
                g.done.wait()
            if g.state == "queued":
                self._materialize_graph(g)
        # st == "done": values are set (or the flush had no live outputs).

    def _prepare_graph(self, g: _OpGraph):
        """Record-side half of a flush: dead-code scan, program build +
        normalization, leaf wire staging. Returns None when nothing in the
        graph is live (nothing to dispatch)."""
        if not g.ops:
            return None
        tr = NULL_TRACER if self.tracer is None else self.tracer
        if g.t_start is not None:
            # The record phase ran between first op and now; stamp it as a
            # span from the graph's own start time.
            tr.add_span("flush.record", g.t_start, time.perf_counter_ns(),
                        n_ops=len(g.ops), n_leaves=len(g.leaves),
                        raw=g.raw)
        live = [wr() for wr in g.results]
        # Materialize ops whose handle is still referenced; handles that
        # died unreferenced are dead code (their cost was still charged,
        # as in eager mode, but no dataplane work remains).
        out_idx = [i for i, lz in enumerate(live) if lz is not None]
        if not out_idx:
            return None
        n_leaves = len(g.leaves)

        def vid(tag):  # combined id space: leaves first, then ops
            return tag[1] if tag[0] == "leaf" else n_leaves + tag[1]

        with tr.span("flush.optimize", n_ops_in=len(g.ops)) as sp_opt:
            program = FusedProgram(
                width=g.width, n_inputs=n_leaves,
                ops=tuple(FusedOp(opcode, tuple(vid(a) for a in args),
                                  param)
                          for opcode, args, param in g.ops),
                outputs=tuple(n_leaves + i for i in out_idx),
                layout=g.layout)
            program, out_pos, leaf_map = optimize_program(program)
            sp_opt.args["n_ops_out"] = len(program.ops)
        with tr.span("flush.leaf_upload", n_leaves=len(leaf_map)) as sp_up:
            # Leaves are already padded wire (or leaf-cache entries) —
            # staging moves no bytes; cache entries resolve to committed
            # device buffers at dispatch (_run_staged).
            staged_b = skipped_b = hits = 0
            leaves = []
            for li in leaf_map:
                leaf = g.leaves[li]
                if leaf.entry is not None:
                    hits += 1
                    skipped_b += leaf.entry.nbytes
                    leaves.append(leaf.entry)
                else:
                    staged_b += leaf.wire.nbytes
                    leaves.append(leaf.wire)
            if self.tracer is not None:
                sp_up.args["bytes_staged"] = staged_b
                sp_up.args["bytes_skipped"] = skipped_b
                c = self.counters
                if hits:
                    c.inc("engine.leaf_cache.hits", hits)
                if len(leaf_map) - hits:
                    c.inc("engine.leaf_cache.misses", len(leaf_map) - hits)
                if g.cache_evictions:
                    c.inc("engine.leaf_cache.evictions", g.cache_evictions)
                    g.cache_evictions = 0
                if g.elided_bytes:
                    c.inc("engine.snapshot_bytes_elided", g.elided_bytes)
                    g.elided_bytes = 0
                if staged_b:
                    c.inc("engine.leaf_bytes_staged", staged_b)
        return (program, out_pos, live, out_idx, leaves)

    def _run_staged(self, g: _OpGraph, staged) -> None:
        """Dispatch-side half of a flush: compile, run, materialize."""
        program, out_pos, live, out_idx, leaves = staged
        tr = NULL_TRACER if self.tracer is None else self.tracer
        with tr.span("flush.compile") as sp_c:
            if self.tracer is not None:
                misses0 = _fused._cached_pipeline.cache_info().misses
            pipeline = get_pipeline(program, donate=self.donate_leaves,
                                    backend=self.fused_backend)
            if self.tracer is not None:
                hit = (_fused._cached_pipeline.cache_info().misses
                       == misses0)
                self.counters.inc("engine.pipeline_cache.hit" if hit
                                  else "engine.pipeline_cache.miss")
                sp_c.args["cache"] = "hit" if hit else "miss"
        leaves = self._resolve_cached_leaves(g, pipeline, leaves)
        rel = self.reliability
        with tr.span("flush.dispatch", n_ops=len(program.ops),
                     n_lanes=g.n) as sp_d:
            if rel is not None and rel.inject:
                # Fault-injection hook: the pipeline runs once clean
                # (the eager oracle), then the reliability plane votes
                # over map-driven faulty replicas, retrying/escalating
                # on weak margins (repro.reliability.plane).
                voted = with_fault_injection(
                    pipeline,
                    lambda o: rel.correct(o, program, g.n, span=sp_d))
                outs = voted(*leaves)
            else:
                outs = pipeline(*leaves)
        with tr.span("flush.materialize", n_outputs=len(out_idx)):
            for i, pos in zip(out_idx, out_pos):
                lz = live[i]
                lanes = g.layout.from_wire(outs[pos])[:g.n]
                if g.raw:  # re-join the lanes of each caller uint64 word
                    val = g.layout.join_raw(lanes)
                    if g.ops[i][0] == "popcount" \
                            and g.layout.raw_lanes_per_word == 2:
                        # A raw popcount's lanes hold per-lane partial
                        # counts: the word's count is their SUM (the
                        # adder tree's final fold), not a bit-join.
                        val = ((val >> np.uint64(32))
                               + (val & np.uint64(0xFFFFFFFF)))
                else:
                    val = lanes.astype(np.uint64)
                lz._value = val.reshape(lz.shape)
                # A materialized handle never needs the graph again — drop
                # the references so surviving handles don't pin the leaf
                # snapshots (or the engine) for their lifetime.
                lz._graph = None
                lz._engine = None
        if self.tracer is not None:
            self.counters.inc("engine.flushes")
            self.counters.observe("engine.flush_lanes", g.n)
            self.counters.observe("engine.flush_ops", len(program.ops))
        if self.autotuner is not None:
            # Per-flush decision point: the online autotuner counts
            # windows / takes counter deltas here (reentrancy-guarded on
            # its side — a re-tune's own flushes never recurse).
            self.autotuner.on_flush(self)

    def _resolve_cached_leaves(self, g: _OpGraph, pipeline, leaves) -> list:
        """Resolve staged leaf-cache entries against the compiled pipeline:

        * non-donating jitted pipelines (``pipeline.wants_device`` says
          the program is big enough to leave the NumPy short-circuit)
          get the entry's committed device buffer — repeat flushes
          re-upload nothing;
        * everything else gets the entry's private host wire; a donating
          flush additionally drops the entry's device residency (the
          trace device-puts and donates a FRESH buffer — cached buffers
          are never donated, donated ones are never cached).
        """
        if not any(isinstance(x, _LeafCacheEntry) for x in leaves):
            return leaves
        cache = self._leaf_cache
        wants = getattr(pipeline, "wants_device", None)
        wire_words = (g.n + g._pad) * g.layout.wire_words_per_lane
        use_dev = (not self.donate_leaves and wants is not None
                   and wants(wire_words))
        out = []
        for x in leaves:
            if isinstance(x, _LeafCacheEntry):
                if use_dev:
                    out.append(cache.device_buffer(x))
                else:
                    if self.donate_leaves:
                        cache.drop_device(x)
                    out.append(x.wire)
            else:
                out.append(x)
        return out

    _PLANEWISE = frozenset({"and", "or", "xor"})

    def _binary(self, kind: str, opcode: str, a, b, np_fn):
        """kind prices the op (cost plane); opcode names it in the fused
        ISA and the sim-backend ALU dispatch."""
        a, b = self._coerce(a), self._coerce(b)
        self._charge(kind, a.size)
        if self._can_fuse(a, b):
            if opcode in self._PLANEWISE and self._use_raw((a, b)):
                return self._record(opcode, (a, b), raw=True)
            return self._record(opcode, (a, b))
        return self._run2(opcode, self._force(a), self._force(b), np_fn)

    # -- private implementations (the repro.pum bridge) ----------------- #

    def _and(self, a, b):
        return self._binary("and2", "and", a, b, lambda x, y: x & y)

    def _or(self, a, b):
        return self._binary("or2", "or", a, b, lambda x, y: x | y)

    def _xor(self, a, b):
        return self._binary("xor2", "xor", a, b, lambda x, y: x ^ y)

    def _add(self, a, b):
        return self._binary("add", "add", a, b,
                            lambda x, y: (x + y) & self._mask(self.width))

    def _sub(self, a, b):
        return self._binary("add", "sub", a, b,
                            lambda x, y: (x - y) & self._mask(self.width))

    def _mul(self, a, b):
        return self._binary("mul", "mul", a, b,
                            lambda x, y: (x * y) & self._mask(self.width))

    def _divpart(self, a, b, which: str):
        """div or mod: ONE restoring-division charge; in fused mode the op
        lowers to the shared ``divmod`` tuple op plus a selector, so
        ``a // b`` and ``a % b`` of the same operands CSE into one divider
        pass at flush."""
        a, b = self._coerce(a), self._coerce(b)
        self._charge("div", a.size)
        if self._can_fuse(a, b):
            pair = self._record("divmod", (a, b), defer_flush=True,
                                internal=True)
            return self._record("fst" if which == "div" else "snd", (pair,))
        with np.errstate(divide="ignore", invalid="ignore"):
            fn = (lambda x, y: x // y) if which == "div" \
                else (lambda x, y: x % y)
            return self._run2(which, self._force(a), self._force(b), fn)

    def _div(self, a, b):
        return self._divpart(a, b, "div")

    def _mod(self, a, b):
        return self._divpart(a, b, "mod")

    def _divmod(self, a, b):
        """(quotient, remainder) for ONE division charge: the restoring
        divider produces both in the same pass (fused: one ``divmod``
        tuple op + two selectors; eager: one charge, two NumPy ops)."""
        a, b = self._coerce(a), self._coerce(b)
        self._charge("div", a.size)
        if self._can_fuse(a, b):
            pair = self._record("divmod", (a, b), defer_flush=True,
                                internal=True)
            q = self._record("fst", (pair,), defer_flush=True)
            r = self._record("snd", (pair,))
            return q, r
        with np.errstate(divide="ignore", invalid="ignore"):
            af, bf = self._force(a), self._force(b)
            if self._alu is not None and af.size <= self._alu.words * 32:
                # One restoring-division pass on the sim ALU yields both.
                # The ALU's divider assumes nonzero divisors; mask those
                # lanes to 0 to keep the engine-wide x//0 == x%0 == 0
                # contract (unsigned NumPy semantics) on every backend.
                va, vb = self._alu_load2(af, bf)
                vq, vr = self._alu.div(va, vb)
                zero = bf == 0
                out = (np.where(zero, np.uint64(0),
                                self._alu_store(vq, af)),
                       np.where(zero, np.uint64(0),
                                self._alu_store(vr, af)))
                for v in (vq, vr, va, vb):
                    self._alu.free(v)  # return the subarray rows
                return out
            return (af // bf, af % bf)

    def _less_than(self, a, b):
        a, b = self._coerce(a), self._coerce(b)
        self._charge("compare", a.size)
        if self._can_fuse(a, b):
            return self._record("less", (a, b))
        return (self._force(a) < self._force(b)).astype(np.uint64)

    def _popcount(self, a, width: int | None = None):
        a = self._coerce(a)
        w = width or self.width
        self._charge("popcount", a.size, n_planes=w)
        if self._can_fuse(a):
            # Raw packed-bitmap graphs keep popcount planewise on the
            # 64-bit words (the evaluators' adder tree counts the whole
            # word), joining the pending raw program instead of forcing
            # a mode-boundary flush that would materialize the operand.
            if self._use_raw((a,)):
                return self._record("popcount", (a,), raw=True)
            return self._record("popcount", (a,))
        return _vec_popcount(self._force(a))

    def _reduce_bits(self, a, kind: str, width: int | None = None):
        a = self._coerce(a)
        w = width or self.width
        self._charge(f"reduce_{kind}", a.size, n_planes=w)
        if self._can_fuse(a):
            return self._record(f"reduce_{kind}", (a,),
                                param=w if kind == "and" else 0)
        a = self._force(a)
        if kind == "and":
            return (a == self._mask(w)).astype(np.uint64)
        if kind == "or":
            return (a != 0).astype(np.uint64)
        pc = _vec_popcount(a)
        return pc & np.uint64(1)

    # -- deprecated compat shim (the pre-repro.pum method surface) ------ #
    # Each method is a one-line delegate that warns once per call site;
    # semantics are identical to the private implementations above.

    def and_(self, a, b):
        """Deprecated: use ``&`` on :class:`repro.pum.PumArray`."""
        _warn_deprecated("and_", "PumArray.__and__ (a & b)")
        return self._and(a, b)

    def or_(self, a, b):
        """Deprecated: use ``|`` on :class:`repro.pum.PumArray`."""
        _warn_deprecated("or_", "PumArray.__or__ (a | b)")
        return self._or(a, b)

    def xor(self, a, b):
        """Deprecated: use ``^`` on :class:`repro.pum.PumArray`."""
        _warn_deprecated("xor", "PumArray.__xor__ (a ^ b)")
        return self._xor(a, b)

    def add(self, a, b):
        """Deprecated: use ``+`` on :class:`repro.pum.PumArray`."""
        _warn_deprecated("add", "PumArray.__add__ (a + b)")
        return self._add(a, b)

    def sub(self, a, b):
        """Deprecated: use ``-`` on :class:`repro.pum.PumArray`."""
        _warn_deprecated("sub", "PumArray.__sub__ (a - b)")
        return self._sub(a, b)

    def mul(self, a, b):
        """Deprecated: use ``*`` on :class:`repro.pum.PumArray`."""
        _warn_deprecated("mul", "PumArray.__mul__ (a * b)")
        return self._mul(a, b)

    def div(self, a, b):
        """Deprecated: use ``//`` on :class:`repro.pum.PumArray`.
        Unsigned floor division; lanes dividing by zero yield 0 (the
        NumPy unsigned semantics, preserved bit-exactly when fused)."""
        _warn_deprecated("div", "PumArray.__floordiv__ (a // b)")
        return self._div(a, b)

    def mod(self, a, b):
        """Deprecated: use ``%`` on :class:`repro.pum.PumArray`.
        Unsigned remainder, priced as one division (the restoring divider
        computes the remainder alongside the quotient); lanes with a zero
        divisor yield 0."""
        _warn_deprecated("mod", "PumArray.__mod__ (a % b)")
        return self._mod(a, b)

    def divmod(self, a, b):
        """Deprecated: use ``divmod()`` on :class:`repro.pum.PumArray`."""
        _warn_deprecated("divmod", "PumArray.__divmod__ (divmod(a, b))")
        return self._divmod(a, b)

    def less_than(self, a, b):
        """Deprecated: use ``<`` on :class:`repro.pum.PumArray`."""
        _warn_deprecated("less_than", "PumArray.__lt__ (a < b)")
        return self._less_than(a, b)

    def popcount(self, a, width: int | None = None):
        """Deprecated: use :meth:`repro.pum.PumArray.popcount`."""
        _warn_deprecated("popcount", "PumArray.popcount()")
        return self._popcount(a, width)

    def reduce_bits(self, a, kind: str, width: int | None = None):
        """Deprecated: use :meth:`repro.pum.PumArray.reduce_bits`.
        Per-element AND/OR/XOR reduction across the element's bits."""
        _warn_deprecated("reduce_bits", "PumArray.reduce_bits(kind)")
        return self._reduce_bits(a, kind, width)

    def _alu_load2(self, a: np.ndarray, b: np.ndarray):
        """Both operands into sim-ALU vertical registers (one row budget:
        ``alu.words * 32`` lanes — callers guard the size)."""
        alu = self._alu
        return (alu.load(a.ravel()[: alu.words * 32]),
                alu.load(b.ravel()[: alu.words * 32]))

    def _alu_store(self, vec, like: np.ndarray) -> np.ndarray:
        """Read a sim-ALU register back into ``like``'s size and shape."""
        return self._alu.store(vec)[: like.size].reshape(like.shape)

    def _run2(self, name, a, b, np_fn):
        if self._alu is not None and a.size <= self._alu.words * 32:
            alu = self._alu
            va, vb = self._alu_load2(a, b)
            fn = {"and": alu.and_, "or": alu.or_, "xor": alu.xor,
                  "add": alu.add, "sub": alu.sub, "mul": alu.mul}.get(name)
            if fn is None and name in ("div", "mod"):
                # Zero-divisor lanes yield 0 on every backend (the ALU's
                # restoring divider assumes b != 0 elementwise).
                q, r = alu.div(va, vb)
                out = self._alu_store(q if name == "div" else r, a)
                out = np.where(b == 0, np.uint64(0), out)
                vecs = (q, r, va, vb)
            else:
                res = fn(va, vb)
                out = self._alu_store(res, a)
                vecs = (res, va, vb)
            for v in vecs:  # return the subarray rows to the pool: the
                alu.free(v)  # engine owns no Vec past the op
            return out
        return np_fn(a, b)

    # ------------------------------------------------------------------ #

    @property
    def latency_ms(self) -> float:
        return self.stats.latency_ns * 1e-6

    def reset_stats(self) -> None:
        with self._lock:
            self._stats_shards.clear()


_M1 = np.uint64(0x5555555555555555)
_M2 = np.uint64(0x3333333333333333)
_M4 = np.uint64(0x0F0F0F0F0F0F0F0F)
_H01 = np.uint64(0x0101010101010101)


def _vec_popcount(a: np.ndarray) -> np.ndarray:
    """Fixed-iteration SWAR popcount (Hacker's Delight 5-2): 12 vector ops
    regardless of data, replacing the data-dependent shift loop."""
    a = np.asarray(a, np.uint64).copy()
    a -= (a >> np.uint64(1)) & _M1
    a = (a & _M2) + ((a >> np.uint64(2)) & _M2)
    a = (a + (a >> np.uint64(4))) & _M4
    return (a * _H01) >> np.uint64(56)
