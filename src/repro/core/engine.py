"""PulsarEngine — the user-facing PuM compute API.

Two coupled planes:
  * dataplane: bit-exact results. ``backend="fast"`` computes on packed
    NumPy words via the same bit-plane algorithms (vectorized, scales to
    millions of elements; the TPU-accelerated variant of these inner loops is
    kernels/ — same algorithms, Pallas-tiled). ``backend="sim"`` routes every
    operation through the DRAM chip model + command programs (bit-exact AND
    cycle-exact; used by tests and small demos).
  * cost plane: every op is priced by the closed-form cost model with the
    paper's methodology (per-op best-throughput N_RG, stable-lane efficiency,
    optional multi-bank parallelism) so application benchmarks (Fig 20)
    report PuM latencies regardless of dataplane backend.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.alu import BitSerialAlu
from repro.core.charact import SuccessRateDb, default_db
from repro.core.chip import PulsarChip
from repro.core.cost_model import CostModel, OpCost, ZERO
from repro.core.geometry import DramGeometry, PAPER_MODULE
from repro.core.profiles import PROFILES
from repro.core.pulsar import PulsarExecutor


@dataclasses.dataclass
class EngineStats:
    latency_ns: float = 0.0
    energy_j: float = 0.0
    n_sequences: int = 0
    lane_efficiency: float = 1.0  # min success rate over ops used
    refresh_stall_ns: float = 0.0  # controller-modeled REF interference

    def charge(self, cost: OpCost, n_vec_rows: int, banks: int,
               success: float, batch=None) -> None:
        if batch is None:
            # Legacy closed-form divide: ideal bank-level parallelism.
            eff_rows = -(-n_vec_rows // banks)
            self.latency_ns += cost.latency_ns * eff_rows
        else:
            # Controller-scheduled pricing: the measured bank-parallel
            # speedup (tFAW/tRRD/bus-limited, <= banks) and the steady-state
            # refresh slowdown replace the ideal divide.
            speedup = max(1.0, batch.parallel_speedup)
            base = max(cost.latency_ns * n_vec_rows / speedup,
                       cost.latency_ns * (-(-n_vec_rows // banks)))
            total = base * batch.refresh_factor
            self.latency_ns += total
            self.refresh_stall_ns += total - base
        self.energy_j += cost.energy_j * n_vec_rows
        self.n_sequences += cost.n_sequences * n_vec_rows
        self.lane_efficiency = min(self.lane_efficiency, success)


class PulsarEngine:
    """Bulk bitwise/bit-serial integer SIMD on (simulated) PuM DRAM."""

    def __init__(self, mfr: str = "M", width: int = 32,
                 row_bits: int = 65536, banks: int = 16,
                 backend: str = "fast",
                 success_db: SuccessRateDb | None = None,
                 use_pulsar: bool = True, chained: bool = False,
                 controller=None, seed: int = 0):
        self.profile = PROFILES[mfr]
        self.mfr = mfr
        self.width = width
        self.row_bits = row_bits
        self.banks = banks
        self.backend = backend
        self.use_pulsar = use_pulsar  # False => FracDRAM baseline costs
        self.chained = chained and use_pulsar  # chained-staging (§Perf P4)
        # controller="auto" builds a MemoryController over `banks` banks;
        # None keeps the legacy closed-form bank divide (reproduces the
        # pre-controller numbers exactly).
        if controller == "auto":
            from repro.controller import MemoryController
            controller = MemoryController(n_banks=banks)
        self.controller = controller
        self.cost = CostModel(row_bits=row_bits, controller=controller)
        self.db = success_db or default_db()
        self.stats = EngineStats()
        self._best_cfg_cache: dict[int, tuple[int, int, float]] = {}
        self._batch_cache: dict[tuple, object] = {}
        if backend == "sim":
            geom = DramGeometry(row_bits=min(row_bits, 2048),
                                rows_per_subarray=512, subarrays_per_bank=2,
                                banks=2)
            chip = PulsarChip(geom, self.profile, seed=seed)
            chip.decoder = chip.decoder.__class__(geom, self.profile, None)
            self._alu = BitSerialAlu(PulsarExecutor(chip, 0, 0), width=width)

    # ------------------------------------------------------------------ #
    # Cost plumbing
    # ------------------------------------------------------------------ #

    def _kind_cost(self, kind: str, m: int, n_rg: int, w: int,
                   n_planes: int | None, n_rg3: int | None = None) -> OpCost:
        fs = self.profile.frac_supported
        ps = "pow2" if self.use_pulsar else "max"
        kw = dict(frac_supported=fs, plan_style=ps)
        ckw = dict(kw, chained=self.chained)
        c = self.cost
        if kind in ("and2", "or2"):
            return c.logic2(min(3, m), n_rg, **kw)
        if kind == "xor2":
            return c.xor2(min(3, m), n_rg, **kw)
        if kind == "add" or kind == "sub":
            return c.add(w, m, n_rg, n_rg3, **ckw)
        if kind == "mul":
            return c.mul(w, m, n_rg, n_rg3, **ckw)
        if kind == "div":
            return c.div(w, m, n_rg, n_rg3, **ckw)
        if kind in ("reduce_and", "reduce_or"):
            return c.reduce_tree(n_planes or w, m, n_rg, **ckw)
        if kind == "reduce_xor":
            return c.xor_reduce(n_planes or w, m, n_rg, **ckw)
        if kind == "popcount":
            out_w = max(1, (n_planes or w).bit_length())
            return (n_planes or w) * out_w * c.full_adder(m, n_rg, n_rg3,
                                                          **ckw)
        if kind == "compare":
            return c.add(w + 1, m, n_rg, n_rg3, **ckw)
        if kind in ("load", "store"):
            return (c.write_row() if kind == "load" else c.read_row()) * (2 * w)
        raise KeyError(kind)

    _ARITH = ("add", "sub", "mul", "div", "popcount", "compare")

    def _cfg_for(self, kind: str, w: int, n_planes: int | None
                 ) -> tuple[int, int, float, OpCost]:
        """Best (maj_fan_in, n_rg[, n_rg3]) for this op kind: minimizes
        latency / success_rate — the paper's per-op configuration search
        ("we choose the N_RG that produces the highest throughput").
        Arithmetic kinds search MAJ3/MAJ5 sub-op configs independently."""
        if not self.use_pulsar:
            # FracDRAM baseline: MAJ3 on 4-row activation only.
            sr = self.db.mean(self.mfr, 3, 4)
            return 3, 4, sr, self._kind_cost(kind, 3, 4, w, n_planes, 4)
        key = (kind, w, n_planes)
        if key not in self._best_cfg_cache:
            prof = self.profile
            cap = prof.max_simul_rows
            pows = [n for n in (4, 8, 16, 32) if n <= cap]

            def sr_of(m, n):
                return (self.db.mean(self.mfr, m, n, plan_style="pow2")
                        if n >= m else 0.0)

            candidates: list[tuple[int, int, int | None]] = []
            if kind in self._ARITH:
                for n3 in pows:                       # MAJ3-only FA
                    candidates.append((3, n3, None))
                if prof.max_maj_fan_in >= 5:
                    for n5 in pows:
                        for n3 in pows:
                            if n5 >= 5:
                                candidates.append((5, n5, n3))
            else:
                m = 3
                while m <= min(prof.max_maj_fan_in, cap):
                    for n in pows:
                        if n >= m:
                            candidates.append((m, n, None))
                    m += 2
            best = None
            for m, n, n3 in candidates:
                sr = sr_of(m, n)
                if n3 is not None:
                    sr = min(sr, sr_of(3, n3))
                if sr <= 1e-3:
                    continue
                cost = self._kind_cost(kind, m, n, w, n_planes, n3)
                eff = cost.latency_ns / sr
                if best is None or eff < best[0]:
                    best = (eff, m, n, sr, cost)
            assert best is not None, f"no viable config for {kind}"
            self._best_cfg_cache[key] = best[1:]
        return self._best_cfg_cache[key]

    def _n_vec_rows(self, n_elems: int) -> int:
        return -(-n_elems // self.row_bits)

    def _batch_for(self, kind: str, m: int, n_rg: int):
        """Controller-measured bank-batch cost for this op's dominant
        primitive (the MAJ unit for compute kinds, the full-row transfer
        program for load/store), cached per configuration."""
        if kind in ("load", "store"):
            key = ("io", kind)
        else:
            key = ("maj", m, n_rg, self.chained)
        if key not in self._batch_cache:
            from repro.core import commands as cmds
            t = self.cost.t
            if kind == "load":
                unit = [cmds.prog_write_row(0, 0, self.cost._wr_bursts, t)]
            elif kind == "store":
                unit = [cmds.prog_read_row(0, 0, self.cost._wr_bursts, t)]
            else:
                unit = self.cost.maj_unit_programs(
                    m, n_rg, frac_supported=self.profile.frac_supported,
                    plan_style="pow2" if self.use_pulsar else "max",
                    # Chained staging keeps one input resident per MAJ, so
                    # measure bank contention on the thinner command stream.
                    resident_inputs=1 if self.chained else 0)
            self._batch_cache[key] = self.controller.batch_cost(unit,
                                                                self.banks)
        return self._batch_cache[key]

    def _charge(self, kind: str, n_elems: int, width: int | None = None,
                n_planes: int | None = None) -> None:
        w = width or self.width
        m, n, sr, cost = self._cfg_for(kind, w, n_planes)
        batch = (self._batch_for(kind, m, n)
                 if self.controller is not None else None)
        self.stats.charge(cost, self._n_vec_rows(n_elems), self.banks, sr,
                          batch)

    def op_effective_ns(self, kind: str, width: int | None = None,
                        n_planes: int | None = None
                        ) -> tuple[float, float, int, int]:
        """Amortized per-vector-row latency of one op at this engine's bank
        count: ``(latency_ns, success_rate, maj_fan_in, n_rg)``.  With a
        controller the latency is priced through the scheduled bank batch
        (tFAW/tRRD-limited speedup + refresh factor); without one it is the
        closed-form single-bank latency divided by ``banks``."""
        w = width or self.width
        m, n, sr, cost = self._cfg_for(kind, w, n_planes)
        if self.controller is None:
            return cost.latency_ns / self.banks, sr, m, n
        b = self._batch_for(kind, m, n)
        eff = (cost.latency_ns / max(1.0, b.parallel_speedup)
               * b.refresh_factor)
        return eff, sr, m, n

    # ------------------------------------------------------------------ #
    # Dataplane ops (fast backend: NumPy; sim backend: chip model)
    # ------------------------------------------------------------------ #

    def _mask(self, w: int) -> np.uint64:
        return np.uint64((1 << w) - 1)

    def and_(self, a, b):
        a, b = np.asarray(a, np.uint64), np.asarray(b, np.uint64)
        self._charge("and2", a.size)
        return self._run2("and", a, b, lambda x, y: x & y)

    def or_(self, a, b):
        a, b = np.asarray(a, np.uint64), np.asarray(b, np.uint64)
        self._charge("or2", a.size)
        return self._run2("or", a, b, lambda x, y: x | y)

    def xor(self, a, b):
        a, b = np.asarray(a, np.uint64), np.asarray(b, np.uint64)
        self._charge("xor2", a.size)
        return self._run2("xor", a, b, lambda x, y: x ^ y)

    def add(self, a, b):
        a, b = np.asarray(a, np.uint64), np.asarray(b, np.uint64)
        self._charge("add", a.size)
        return self._run2("add", a, b,
                          lambda x, y: (x + y) & self._mask(self.width))

    def sub(self, a, b):
        a, b = np.asarray(a, np.uint64), np.asarray(b, np.uint64)
        self._charge("add", a.size)
        return self._run2("sub", a, b,
                          lambda x, y: (x - y) & self._mask(self.width))

    def mul(self, a, b):
        a, b = np.asarray(a, np.uint64), np.asarray(b, np.uint64)
        self._charge("mul", a.size)
        return self._run2("mul", a, b,
                          lambda x, y: (x * y) & self._mask(self.width))

    def div(self, a, b):
        a, b = np.asarray(a, np.uint64), np.asarray(b, np.uint64)
        self._charge("div", a.size)
        return self._run2("div", a, b, lambda x, y: x // y)

    def less_than(self, a, b):
        a, b = np.asarray(a, np.uint64), np.asarray(b, np.uint64)
        self._charge("compare", a.size)
        return (a < b).astype(np.uint64)

    def popcount(self, a, width: int | None = None):
        a = np.asarray(a, np.uint64)
        w = width or self.width
        self._charge("popcount", a.size, n_planes=w)
        return np.array([bin(int(x)).count("1") for x in a.ravel()],
                        np.uint64).reshape(a.shape) if a.size < 4096 else \
            _vec_popcount(a)

    def reduce_bits(self, a, kind: str, width: int | None = None):
        """Per-element AND/OR/XOR reduction across the element's bits."""
        a = np.asarray(a, np.uint64)
        w = width or self.width
        self._charge(f"reduce_{kind}", a.size, n_planes=w)
        if kind == "and":
            return (a == self._mask(w)).astype(np.uint64)
        if kind == "or":
            return (a != 0).astype(np.uint64)
        pc = _vec_popcount(a)
        return pc & np.uint64(1)

    def _run2(self, name, a, b, np_fn):
        if self.backend == "sim" and a.size <= self._alu.words * 32:
            alu = self._alu
            va, vb = alu.load(a.ravel()[: alu.words * 32]), None
            vb = alu.load(b.ravel()[: alu.words * 32])
            fn = {"and": alu.and_, "or": alu.or_, "xor": alu.xor,
                  "add": alu.add, "sub": alu.sub, "mul": alu.mul}.get(name)
            if fn is None and name == "div":
                q, r = alu.div(va, vb)
                out = alu.store(q)
            else:
                out = alu.store(fn(va, vb))
            return out[: a.size].reshape(a.shape)
        return np_fn(a, b)

    # ------------------------------------------------------------------ #

    @property
    def latency_ms(self) -> float:
        return self.stats.latency_ns * 1e-6

    def reset_stats(self) -> None:
        self.stats = EngineStats()


def _vec_popcount(a: np.ndarray) -> np.ndarray:
    a = a.astype(np.uint64)
    out = np.zeros_like(a)
    while a.any():
        out += a & np.uint64(1)
        a = a >> np.uint64(1)
    return out
