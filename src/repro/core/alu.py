"""Dual-rail bit-serial ALU on the PULSAR executor (paper §2.4, §6.1.2).

Operands are vertical-layout vectors: ``width`` bit-planes plus ``width``
*negated* planes (prior work [25] stores both rails because MAJ gates cannot
implement NOT; majority is self-dual, so every op maintains the negated rail
with the dual MAJ at 2x op cost).

Building blocks:
  * AND-f / OR-f via MAJ_(2f-1) with (f-1) constant all-0 / all-1 rows,
  * full adder: Cout = MAJ3(A,B,Cin); Sum = MAJ5(A,B,Cin,¬Cout,¬Cout)
    (Navi et al. [75]; needs MAJ5 => PULSAR's arithmetic speedup),
    MAJ3-only fallback: Sum = MAJ3(¬Cout, Cin, MAJ3(A,B,¬Cin)) (Ali [4]),
  * XOR = OR(AND(a,¬b), AND(¬a,b)),
  * shifts are free (plane renaming — the vertical layout's raison d'etre),
  * ADD/SUB ripple carry, MUL shift-add, DIV restoring with bit-plane mux.

The ALU executes *real command programs* against the logical chip model —
results are bit-exact vs NumPy (tests) and every op's latency/energy lands in
``chip.stats``. ``op_counts`` mirrors what the closed-form cost model
(cost_model.py) predicts; the two are cross-checked in tests.

Row ownership: a ``Vec`` may alias rows it does not own (constant planes,
renamed shifts, other vectors' planes). Only ``alloc_vec``/op outputs own
their rows; ``free`` must only ever be called on owned vectors — internal
code is careful to respect this.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.chip import PulsarChip
from repro.core.layout import from_vertical, to_vertical
from repro.core.pulsar import PulsarExecutor


@dataclasses.dataclass
class Vec:
    """Handle to a dual-rail vertical vector resident in DRAM rows."""
    width: int
    pos_rows: list[int]   # plane j -> row holding bit j of each element
    neg_rows: list[int]

    def shifted_left(self, k: int, zero_row: int, one_row: int) -> "Vec":
        """x << k: free plane renaming; low planes become constant 0.
        The result ALIASES self's rows — do not free it."""
        return Vec(self.width,
                   [zero_row] * k + self.pos_rows[: self.width - k],
                   [one_row] * k + self.neg_rows[: self.width - k])

    def zero_extended(self, width: int, zero_row: int, one_row: int) -> "Vec":
        if width < self.width:
            raise ValueError("cannot shrink")
        pad = width - self.width
        return Vec(width, self.pos_rows + [zero_row] * pad,
                   self.neg_rows + [one_row] * pad)


class BitSerialAlu:
    def __init__(self, executor: PulsarExecutor, width: int = 32,
                 max_n_rg: int | None = None):
        self.x = executor
        self.chip: PulsarChip = executor.chip
        self.bank = executor.bank
        self.width = width
        geom = self.chip.geometry
        self.words = geom.words_per_row
        cap = executor.max_n_rg()
        self.n_rg = min(max_n_rg or cap, cap)
        if self.n_rg < 4:
            raise RuntimeError("need at least 4-row activation for MAJ3")
        # Home region: rows outside the compute N_RG hold operand planes.
        region_rows = set(executor.region(self.n_rg).rows_by_combo)
        sa = executor.subarray
        base = sa * geom.rows_per_subarray
        self._free_rows = [r for r in range(base, base + geom.rows_per_subarray)
                           if r not in region_rows]
        self.op_counts: dict[str, int] = {}
        # Constant rows (written once; staged into N_RGs like any operand).
        self.zero_row = self._alloc()
        self.one_row = self._alloc()
        self.chip.write_row(self.bank, self.zero_row,
                            np.zeros(self.words, np.uint32))
        self.chip.write_row(self.bank, self.one_row,
                            np.full(self.words, 0xFFFFFFFF, np.uint32))

    # ------------------------------------------------------------------ #

    def _alloc(self) -> int:
        if not self._free_rows:
            raise RuntimeError("subarray out of rows; free some vectors")
        return self._free_rows.pop()

    def free(self, v: Vec) -> None:
        self._free_rows.extend(v.pos_rows)
        self._free_rows.extend(v.neg_rows)
        v.pos_rows, v.neg_rows = [], []

    def _count(self, name: str, n: int = 1) -> None:
        self.op_counts[name] = self.op_counts.get(name, 0) + n

    @property
    def maj_fan_in(self) -> int:
        """Largest odd MAJ fan-in the configured N_RG supports (N_RG >= M)."""
        return self.n_rg - 1 if self.n_rg % 2 == 0 else self.n_rg

    @property
    def and_or_fan_in(self) -> int:
        """AND-f needs MAJ_(2f-1): f = (M+1)/2."""
        return (self.maj_fan_in + 1) // 2

    # ------------------------------------------------------------------ #
    # Data movement
    # ------------------------------------------------------------------ #

    def load(self, values: np.ndarray, width: int | None = None) -> Vec:
        """Host -> DRAM: writes both rails (negated data precomputed on the
        host, as in prior work [25])."""
        width = width or self.width
        values = np.asarray(values, np.uint64) & np.uint64((1 << width) - 1)
        planes = to_vertical(values, width)
        v = Vec(width, [self._alloc() for _ in range(width)],
                [self._alloc() for _ in range(width)])
        for j in range(width):
            self.chip.write_row(self.bank, v.pos_rows[j], planes[j])
            self.chip.write_row(self.bank, v.neg_rows[j], ~planes[j])
        return v

    def store(self, v: Vec, signed: bool = False) -> np.ndarray:
        planes = np.stack([self.chip.read_row(self.bank, r)
                           for r in v.pos_rows])
        return from_vertical(planes, signed=signed)

    def alloc_vec(self, width: int | None = None) -> Vec:
        width = width or self.width
        return Vec(width, [self._alloc() for _ in range(width)],
                   [self._alloc() for _ in range(width)])

    def notted(self, v: Vec) -> Vec:
        """NOT is free: swap rails (result aliases v — do not free)."""
        return Vec(v.width, list(v.neg_rows), list(v.pos_rows))

    def const_vec(self, width: int | None = None) -> Vec:
        """All-zero vector aliasing the constant rows (do not free)."""
        width = width or self.width
        return Vec(width, [self.zero_row] * width, [self.one_row] * width)

    def copy(self, v: Vec) -> Vec:
        """Materialize an owned copy (RowClone per plane)."""
        out = self.alloc_vec(v.width)
        for j in range(v.width):
            self.chip.row_clone(self.bank, v.pos_rows[j], out.pos_rows[j])
            self.chip.row_clone(self.bank, v.neg_rows[j], out.neg_rows[j])
        self._count("rowclone", 2 * v.width)
        return out

    # ------------------------------------------------------------------ #
    # MAJ plumbing: every logical op is a dual pair of MAJ executions.
    # ------------------------------------------------------------------ #

    def _maj_pair(self, dst_pos: int, dst_neg: int, pos_srcs: list[int],
                  neg_srcs: list[int]) -> None:
        m = len(pos_srcs)
        if m > self.n_rg:
            raise ValueError(f"MAJ{m} needs N_RG >= {m}, have {self.n_rg}")
        self.x.maj(dst_pos, pos_srcs, self.n_rg)
        self.x.maj(dst_neg, neg_srcs, self.n_rg)
        self._count(f"maj{m}", 2)

    def _and_rows(self, dst_pos: int, dst_neg: int,
                  pos: list[int], neg: list[int]) -> None:
        pad = len(pos) - 1
        self._maj_pair(dst_pos, dst_neg, pos + [self.zero_row] * pad,
                       neg + [self.one_row] * pad)

    def _or_rows(self, dst_pos: int, dst_neg: int,
                 pos: list[int], neg: list[int]) -> None:
        pad = len(pos) - 1
        self._maj_pair(dst_pos, dst_neg, pos + [self.one_row] * pad,
                       neg + [self.zero_row] * pad)

    # ------------------------------------------------------------------ #
    # Fan-in reduction trees (the Fig 5 / Fig 17 speedup lever)
    # ------------------------------------------------------------------ #

    def _tree_reduce(self, pos_list: list[int], neg_list: list[int],
                     kind: str) -> tuple[int, int]:
        """Reduce planes with AND-f/OR-f nodes of fan-in
        ``self.and_or_fan_in``; frees intermediate scratch greedily.
        Returns an OWNED (pos_row, neg_row)."""
        f = self.and_or_fan_in
        pos, neg = list(pos_list), list(neg_list)
        owned = [False] * len(pos)
        while len(pos) > 1:
            npos, nneg, nown = [], [], []
            for i in range(0, len(pos), f):
                cp, cn, co = pos[i:i + f], neg[i:i + f], owned[i:i + f]
                if len(cp) == 1:
                    npos.append(cp[0]); nneg.append(cn[0]); nown.append(co[0])
                    continue
                dp, dn = self._alloc(), self._alloc()
                if kind == "and":
                    self._and_rows(dp, dn, cp, cn)
                else:
                    self._or_rows(dp, dn, cp, cn)
                for p, n, o in zip(cp, cn, co):
                    if o:
                        self._free_rows.extend([p, n])
                npos.append(dp); nneg.append(dn); nown.append(True)
            pos, neg, owned = npos, nneg, nown
        if not owned[0]:  # degenerate single-plane input: materialize
            dp, dn = self._alloc(), self._alloc()
            self.chip.row_clone(self.bank, pos[0], dp)
            self.chip.row_clone(self.bank, neg[0], dn)
            return dp, dn
        return pos[0], neg[0]

    def reduce_planes(self, v: Vec, kind: str) -> Vec:
        """AND/OR-reduce all planes of ``v`` to a 1-bit vector."""
        p, n = self._tree_reduce(v.pos_rows, v.neg_rows, kind)
        return Vec(1, [p], [n])

    def xor_reduce_planes(self, v: Vec) -> Vec:
        """Parity across planes (binary XOR tree; XOR has no wide-fan-in MAJ
        shortcut in our synthesis — see cost_model notes)."""
        pos, neg = list(v.pos_rows), list(v.neg_rows)
        owned = [False] * len(pos)
        while len(pos) > 1:
            npos, nneg, nown = [], [], []
            for i in range(0, len(pos) - 1, 2):
                r = self.xor(Vec(1, [pos[i]], [neg[i]]),
                             Vec(1, [pos[i + 1]], [neg[i + 1]]))
                for j in (i, i + 1):
                    if owned[j]:
                        self._free_rows.extend([pos[j], neg[j]])
                npos.append(r.pos_rows[0]); nneg.append(r.neg_rows[0])
                nown.append(True)
            if len(pos) % 2:
                npos.append(pos[-1]); nneg.append(neg[-1]); nown.append(owned[-1])
            pos, neg, owned = npos, nneg, nown
        if not owned[0]:
            dp, dn = self._alloc(), self._alloc()
            self.chip.row_clone(self.bank, pos[0], dp)
            self.chip.row_clone(self.bank, neg[0], dn)
            return Vec(1, [dp], [dn])
        return Vec(1, [pos[0]], [neg[0]])

    # ------------------------------------------------------------------ #
    # Element-wise logic
    # ------------------------------------------------------------------ #

    def _zip_op(self, a: Vec, b: Vec, kind: str) -> Vec:
        if a.width != b.width:
            raise ValueError("width mismatch")
        out = self.alloc_vec(a.width)
        for j in range(a.width):
            args = ([a.pos_rows[j], b.pos_rows[j]],
                    [a.neg_rows[j], b.neg_rows[j]])
            if kind == "and":
                self._and_rows(out.pos_rows[j], out.neg_rows[j], *args)
            else:
                self._or_rows(out.pos_rows[j], out.neg_rows[j], *args)
        return out

    def and_(self, a: Vec, b: Vec) -> Vec:
        return self._zip_op(a, b, "and")

    def or_(self, a: Vec, b: Vec) -> Vec:
        return self._zip_op(a, b, "or")

    def xor(self, a: Vec, b: Vec) -> Vec:
        """XOR = OR(AND(a,¬b), AND(¬a,b)) per plane, dual-rail."""
        if a.width != b.width:
            raise ValueError("width mismatch")
        out = self.alloc_vec(a.width)
        t1, t1n, t2, t2n = (self._alloc() for _ in range(4))
        for j in range(a.width):
            self._and_rows(t1, t1n, [a.pos_rows[j], b.neg_rows[j]],
                           [a.neg_rows[j], b.pos_rows[j]])
            self._and_rows(t2, t2n, [a.neg_rows[j], b.pos_rows[j]],
                           [a.pos_rows[j], b.neg_rows[j]])
            self._or_rows(out.pos_rows[j], out.neg_rows[j], [t1, t2],
                          [t1n, t2n])
        self._free_rows.extend([t1, t1n, t2, t2n])
        return out

    # ------------------------------------------------------------------ #
    # Arithmetic
    # ------------------------------------------------------------------ #

    def _full_adder(self, ap: int, an: int, bp: int, bn: int,
                    cp: int, cn: int, sp: int, sn: int,
                    coutp: int, coutn: int) -> None:
        """One dual-rail full adder; MAJ5 path when available (PULSAR),
        MAJ3-only path otherwise (FracDRAM baseline)."""
        self._maj_pair(coutp, coutn, [ap, bp, cp], [an, bn, cn])
        if self.maj_fan_in >= 5:
            # Sum = MAJ5(A, B, Cin, ¬Cout, ¬Cout): the doubled operand is
            # weighted naturally by input replication (Fig 10).
            self._maj_pair(sp, sn, [ap, bp, cp, coutn, coutn],
                           [an, bn, cn, coutp, coutp])
        else:
            tp, tn = self._alloc(), self._alloc()
            # inner = MAJ3(A, B, ¬Cin); Sum = MAJ3(¬Cout, Cin, inner)
            self._maj_pair(tp, tn, [ap, bp, cn], [an, bn, cp])
            self._maj_pair(sp, sn, [coutn, cp, tp], [coutp, cn, tn])
            self._free_rows.extend([tp, tn])

    def add(self, a: Vec, b: Vec, cin_one: bool = False) -> Vec:
        """Ripple-carry a + b (mod 2^width)."""
        if a.width != b.width:
            raise ValueError("width mismatch")
        out = self.alloc_vec(a.width)
        cp = self.one_row if cin_one else self.zero_row
        cn = self.zero_row if cin_one else self.one_row
        c0p, c0n, c1p, c1n = (self._alloc() for _ in range(4))
        for j in range(a.width):
            ncp, ncn = (c0p, c0n) if j % 2 == 0 else (c1p, c1n)
            self._full_adder(a.pos_rows[j], a.neg_rows[j],
                             b.pos_rows[j], b.neg_rows[j], cp, cn,
                             out.pos_rows[j], out.neg_rows[j], ncp, ncn)
            cp, cn = ncp, ncn
        self._free_rows.extend([c0p, c0n, c1p, c1n])
        return out

    def sub(self, a: Vec, b: Vec) -> Vec:
        """a - b = a + ¬b + 1 (two's complement)."""
        return self.add(a, self.notted(b), cin_one=True)

    def mul(self, a: Vec, b: Vec) -> Vec:
        """Shift-add multiply, low ``width`` bits."""
        w = a.width
        acc = self.and_(a, self._broadcast_plane(b, 0, w))
        for j in range(1, w):
            masked = self.and_(a, self._broadcast_plane(b, j, w))
            shifted = masked.shifted_left(j, self.zero_row, self.one_row)
            nxt = self.add(acc, shifted)
            self.free(acc)
            self.free(masked)   # shifted aliased masked; both consumed
            acc = nxt
        return acc

    def _broadcast_plane(self, v: Vec, j: int, width: int) -> Vec:
        """All planes alias plane j of v (free bit-replication)."""
        return Vec(width, [v.pos_rows[j]] * width, [v.neg_rows[j]] * width)

    def mux(self, sel: Vec, t: Vec, f: Vec) -> Vec:
        """Per-element select: sel ? t : f (sel is 1-bit, broadcast)."""
        w = t.width
        sel_b = self._broadcast_plane(sel, 0, w)
        x = self.and_(t, sel_b)
        y = self.and_(f, self.notted(sel_b))
        out = self.or_(x, y)
        self.free(x)
        self.free(y)
        return out

    def div(self, a: Vec, b: Vec) -> tuple[Vec, Vec]:
        """Unsigned restoring division -> (quotient, remainder).

        Internally extends to width+1 bits so the trial subtraction's sign
        bit is exact (invariant: rem < b => rem' = 2*rem + a_j < 2b <= 2^(w+1)).
        Caller contract (as in prior work): b != 0 elementwise.
        """
        w = a.width
        we = w + 1
        bx = b.zero_extended(we, self.zero_row, self.one_row)  # alias
        rem = self.const_vec(we)  # alias of constant zero planes
        rem_owned = False
        qplanes: list[tuple[int, int]] = []
        for j in reversed(range(w)):
            # rem' = (rem << 1) | a_j  — pure aliasing
            shifted = Vec(we, [a.pos_rows[j]] + rem.pos_rows[:we - 1],
                          [a.neg_rows[j]] + rem.neg_rows[:we - 1])
            t = self.sub(shifted, bx)                      # owned
            sign = Vec(1, [t.pos_rows[we - 1]], [t.neg_rows[we - 1]])
            new_rem = self.mux(sign, shifted, t)           # owned
            qp, qn = self._alloc(), self._alloc()
            self.chip.row_clone(self.bank, t.neg_rows[we - 1], qp)
            self.chip.row_clone(self.bank, t.pos_rows[we - 1], qn)
            self._count("rowclone", 2)
            qplanes.append((qp, qn))
            self.free(t)
            if rem_owned:
                self.free(rem)
            rem, rem_owned = new_rem, True
        qplanes.reverse()
        quo = Vec(w, [p for p, _ in qplanes], [n for _, n in qplanes])
        # Shrink remainder to w planes; free the top plane.
        self._free_rows.extend([rem.pos_rows[w], rem.neg_rows[w]])
        rem = Vec(w, rem.pos_rows[:w], rem.neg_rows[:w])
        return quo, rem

    def popcount_planes(self, v: Vec, out_width: int | None = None) -> Vec:
        """Per-element popcount over the planes of v (serial accumulation of
        zero-extended bits; each step is a ripple add)."""
        w_out = out_width or max(1, v.width.bit_length())
        acc: Vec | None = None
        for j in range(v.width):
            ext = Vec(w_out,
                      [v.pos_rows[j]] + [self.zero_row] * (w_out - 1),
                      [v.neg_rows[j]] + [self.one_row] * (w_out - 1))
            if acc is None:
                acc = self.copy(ext)
            else:
                nxt = self.add(acc, ext)
                self.free(acc)
                acc = nxt
        assert acc is not None
        return acc

    def less_than(self, a: Vec, b: Vec) -> Vec:
        """Unsigned a < b via sign of extended subtraction (1-bit vector)."""
        we = a.width + 1
        ax = a.zero_extended(we, self.zero_row, self.one_row)
        bx = b.zero_extended(we, self.zero_row, self.one_row)
        t = self.sub(ax, bx)
        sp, sn = self._alloc(), self._alloc()
        self.chip.row_clone(self.bank, t.pos_rows[we - 1], sp)
        self.chip.row_clone(self.bank, t.neg_rows[we - 1], sn)
        self._count("rowclone", 2)
        self.free(t)
        return Vec(1, [sp], [sn])
