"""PULSAR core: the paper's contribution as a composable library.

Layers (bottom-up):
  geometry/profiles  — DRAM organization + manufacturer behavior,
  decoder            — hierarchical row decoder, simultaneous activation sets,
  timing/commands    — DDR4 timings, violated-timing PuM command programs,
  analog             — charge sharing + process variation (success rates),
  chip               — bit-exact logical PuM state machine,
  replication/pulsar — PULSAR's input replication + staged MAJ execution,
  layout/alu         — vertical data layout + dual-rail bit-serial ALU,
  cost_model/charact — closed-form costs, Monte-Carlo characterization,
  destruction        — cold-boot content destruction use case,
  engine/realworld   — user-facing bulk SIMD API + application kernels.
"""

from repro.core.alu import BitSerialAlu, Vec
from repro.core.charact import SuccessRateDb, default_db
from repro.core.chip import PulsarChip, majority_bits
from repro.core.cost_model import CostModel, MICROBENCHES, OpCost
from repro.core.decoder import RowDecoder
from repro.core.engine import PulsarEngine
from repro.core.geometry import DramGeometry, PAPER_MODULE, TEST_GEOMETRY
from repro.core.profiles import MFR_H, MFR_M, MFR_S, PROFILES, MfrProfile
from repro.core.pulsar import PulsarExecutor, build_region
from repro.core.replication import ReplicationPlan, fracdram_plan, plan
from repro.core.timing import DDR4_2400, DramTimings

__all__ = [
    "BitSerialAlu", "Vec", "SuccessRateDb", "default_db", "PulsarChip",
    "majority_bits", "CostModel", "MICROBENCHES", "OpCost", "RowDecoder",
    "PulsarEngine", "DramGeometry", "PAPER_MODULE", "TEST_GEOMETRY",
    "MFR_H", "MFR_M", "MFR_S", "PROFILES", "MfrProfile", "PulsarExecutor",
    "build_region", "ReplicationPlan", "fracdram_plan", "plan",
    "DDR4_2400", "DramTimings",
]
