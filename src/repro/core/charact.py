"""Characterization harness (paper §6.1.1, Figs 14-16, Table 1).

Runs the analog Monte-Carlo model across (manufacturer, MAJ-M, N_RG) and
aggregates success rates the way the paper does: per-row-group distributions
over sampled N_RGs in sampled subarrays, with systematic (spatial) process
variation across subarrays (Fig 16's M-shaped profile) on top of the random
per-cell variation.
"""

from __future__ import annotations

import dataclasses
import math
import zlib
from functools import lru_cache

import jax
import numpy as np

from repro.core import analog
from repro.core.profiles import PROFILES, MfrProfile
from repro.core.replication import plan as replication_plan, plan_pow2


def spatial_pv_multiplier(subarray: int, n_subarrays: int) -> float:
    """Systematic process-variation modulation across a bank.

    Fig 16 reports an M-shaped success-rate profile (peaks in the 1st and 3rd
    quarters). Success falls when variation rises, so we modulate sigma_pv
    with a W-shaped (inverted-M) profile: minima at x=0.25 and x=0.75.
    """
    x = (subarray + 0.5) / n_subarrays
    # cos(4*pi*x) has minima at 0.25/0.75: map to [0.9, 1.25] multiplier.
    return 1.075 + 0.175 * math.cos(4 * math.pi * x)


@dataclasses.dataclass(frozen=True)
class SuccessPoint:
    mfr: str
    m_inputs: int
    n_rg: int
    mean: float
    q1: float
    q3: float
    lo: float
    hi: float


class SuccessRateDb:
    """Caches Monte-Carlo success rates; the cost model and benchmarks query
    it instead of re-simulating."""

    def __init__(self, n_bitlines: int = 2048, n_groups: int = 24,
                 n_patterns: int = 48, seed: int = 0):
        self.n_bitlines = n_bitlines
        self.n_groups = n_groups
        self.n_patterns = n_patterns
        self.seed = seed
        self._cache: dict[tuple, SuccessPoint] = {}

    def point(self, mfr: str, m_inputs: int, n_rg: int,
              subarray_frac: float | None = None,
              plan_style: str = "max") -> SuccessPoint:
        key = (mfr, m_inputs, n_rg,
               None if subarray_frac is None else round(subarray_frac, 3),
               plan_style)
        if key in self._cache:
            return self._cache[key]
        profile = PROFILES[mfr]
        if n_rg > profile.max_simul_rows:
            raise ValueError(f"Mfr {mfr} caps at {profile.max_simul_rows} rows")
        rp = (plan_pow2 if plan_style == "pow2" else replication_plan)(
            m_inputs, n_rg)
        pv_mult = (spatial_pv_multiplier(int(subarray_frac * 16), 16)
                   if subarray_frac is not None else 1.0)
        # Stable (non-salted) per-key hash for reproducible PRNG streams.
        key_hash = zlib.crc32(repr(key).encode())
        rates = []
        for g in range(self.n_groups):
            key_g = jax.random.PRNGKey(self.seed * 7919 + key_hash % (2**31) + g)
            rate, _ = analog.maj_success_rate(
                key_g, profile, m_inputs=m_inputs, copies=rp.copies,
                n_neutral=rp.n_neutral, n_bitlines=self.n_bitlines,
                n_patterns=self.n_patterns,
                process_variation=profile.process_variation * pv_mult)
            rates.append(rate)
        arr = np.array(rates)
        sp = SuccessPoint(mfr, m_inputs, n_rg, float(arr.mean()),
                          float(np.quantile(arr, 0.25)),
                          float(np.quantile(arr, 0.75)),
                          float(arr.min()), float(arr.max()))
        self._cache[key] = sp
        return sp

    def mean(self, mfr: str, m_inputs: int, n_rg: int,
             plan_style: str = "max") -> float:
        return self.point(mfr, m_inputs, n_rg, plan_style=plan_style).mean

    # ------------------------------------------------------------------ #

    def fig14_maj3_vs_n(self, mfr: str) -> dict[int, SuccessPoint]:
        """MAJ3 success vs N_RG (Fig 14)."""
        prof = PROFILES[mfr]
        out = {}
        for n in (4, 8, 16, 32):
            if n <= prof.max_simul_rows:
                out[n] = self.point(mfr, 3, n)
        return out

    def fig15_majm(self, mfr: str) -> dict[tuple[int, int], SuccessPoint]:
        """MAJ3/5/7/9 success vs N_RG (Fig 15)."""
        prof = PROFILES[mfr]
        out = {}
        for m in (3, 5, 7, 9):
            for n in (4, 8, 16, 32):
                if n >= m and n <= prof.max_simul_rows:
                    out[(m, n)] = self.point(mfr, m, n)
        return out

    def fig16_spatial(self, mfr: str = "H", n_subarrays: int = 16,
                      n_rg: int = 32) -> list[tuple[int, float, float]]:
        """Per-subarray MAJ3 success for PULSAR vs FracDRAM (Fig 16).
        Returns [(subarray, pulsar_rate, fracdram_rate)]."""
        prof = PROFILES[mfr]
        n_rg = min(n_rg, prof.max_simul_rows)
        rows = []
        for sa in range(n_subarrays):
            frac = (sa + 0.5) / n_subarrays
            p = self.point(mfr, 3, n_rg, subarray_frac=frac)
            f = self.point(mfr, 3, 4, subarray_frac=frac)
            rows.append((sa, p.mean, f.mean))
        return rows

    def best_n_rg(self, mfr: str, m_inputs: int,
                  latency_fn) -> tuple[int, float]:
        """Pick the N_RG maximizing throughput = SR / latency(M, N) —
        the paper's per-op configuration search (§6.1.2)."""
        prof = PROFILES[mfr]
        best, best_t = None, -1.0
        n = 4
        while n <= prof.max_simul_rows:
            if n >= m_inputs:
                sr = self.mean(mfr, m_inputs, n)
                thr = sr / latency_fn(m_inputs, n)
                if thr > best_t:
                    best, best_t = n, thr
            n <<= 1
        if best is None:
            raise ValueError(f"MAJ{m_inputs} unsupported on Mfr {mfr}")
        return best, best_t


@lru_cache(maxsize=2)
def default_db(seed: int = 0) -> SuccessRateDb:
    return SuccessRateDb(seed=seed)
