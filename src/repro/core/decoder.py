"""Hierarchical row-decoder model (paper §4.2, Figs 7-9).

A subarray's local row address (e.g. 9 bits for 512 rows) is split across
predecoders A..E with widths ``geometry.predecoder_widths`` (LSB-first).
Each predecoder one-hot-decodes its group and *latches* the asserted output.

An ``ACT R_F -> PRE -> ACT R_S`` (APA) sequence with violated tRP prevents the
PRE from resetting the latches, so after the second ACT every predecoder
holds the outputs for *both* addresses. Stage-2 of the local wordline decoder
asserts the full cross-product: with ``k`` groups in which R_F and R_S differ,
``2**k`` wordlines rise simultaneously.

Manufacturer behavior (profiles):
  * only the lowest ``double_latch_groups`` predecoders keep both latches;
    higher groups are reset by the PRE and take R_S's value only
    (models Mfr. M's 16-row cap and Samsung's non-functionality);
  * a per-chip Bernoulli yield mask marks which (subarray, group) paths
    double-latch at all — reproducing Table 1's N_RG% distributions.
"""

from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core.geometry import DramGeometry
from repro.core.profiles import MfrProfile


def split_groups(addr: int, widths: tuple[int, ...]) -> tuple[int, ...]:
    """Split a local row address into predecoder group values (LSB-first)."""
    out = []
    for w in widths:
        out.append(addr & ((1 << w) - 1))
        addr >>= w
    return tuple(out)


def join_groups(groups: tuple[int, ...], widths: tuple[int, ...]) -> int:
    addr, shift = 0, 0
    for g, w in zip(groups, widths):
        addr |= g << shift
        shift += w
    return addr


@dataclasses.dataclass(frozen=True)
class RowDecoder:
    """Decoder for one bank; pure functions of (R_F, R_S)."""

    geometry: DramGeometry
    profile: MfrProfile
    # (subarrays, n_groups) bool: does this predecoder path double-latch?
    yield_mask: np.ndarray | None = None

    @staticmethod
    def build(geometry: DramGeometry, profile: MfrProfile,
              seed: int) -> "RowDecoder":
        rng = np.random.default_rng(seed)
        n_groups = len(geometry.predecoder_widths)
        mask = rng.random((geometry.subarrays_per_bank, n_groups)) < profile.pair_yield
        return RowDecoder(geometry, profile, mask)

    # ------------------------------------------------------------------ #

    def activated_rows(self, rf: int, rs: int) -> tuple[int, ...]:
        """Row addresses asserted by APA(rf, rs). Sorted, unique.

        rf/rs are bank-level row addresses; both must sit in the same
        subarray (the GWLD decodes the subarray index — different subarrays
        simply activate rs alone, as the GWL switches).
        """
        g = self.geometry
        sa_f, sa_s = g.subarray_of(rf), g.subarray_of(rs)
        if sa_f != sa_s:
            return (rs,)
        widths = g.predecoder_widths
        gf = split_groups(g.local_row(rf), widths)
        gs = split_groups(g.local_row(rs), widths)
        choices: list[tuple[int, ...]] = []
        for i, (a, b) in enumerate(zip(gf, gs)):
            latches_both = (
                a != b
                and i < self.profile.double_latch_groups
                and (self.yield_mask is None or bool(self.yield_mask[sa_s, i]))
            )
            choices.append((a, b) if latches_both else (b,))
        base = sa_s * g.rows_per_subarray
        rows = sorted(
            base + join_groups(combo, widths)
            for combo in itertools.product(*choices)
        )
        return tuple(rows)

    def n_activated(self, rf: int, rs: int) -> int:
        return len(self.activated_rows(rf, rs))

    # ------------------------------------------------------------------ #

    def find_group_pair(self, subarray: int, n_rows: int,
                        rng: np.random.Generator | None = None,
                        include: tuple[int, ...] = ()) -> tuple[int, int]:
        """Find (rf, rs) in ``subarray`` activating exactly ``n_rows`` rows.

        ``include``: bank-level rows that must be inside the activated set
        (used by the ALU row allocator to target staged operand rows).
        Raises ValueError when the chip cannot activate ``n_rows`` rows.
        """
        if n_rows & (n_rows - 1):
            raise ValueError("n_rows must be a power of two")
        k = n_rows.bit_length() - 1
        g = self.geometry
        usable = [
            i for i in range(len(g.predecoder_widths))
            if i < self.profile.double_latch_groups
            and (self.yield_mask is None or bool(self.yield_mask[subarray, i]))
        ]
        if len(usable) < k:
            raise ValueError(
                f"chip (Mfr {self.profile.name}) cannot activate {n_rows} rows "
                f"in subarray {subarray}: only {len(usable)} double-latching "
                f"predecoder groups")
        rng = rng or np.random.default_rng(0)
        widths = g.predecoder_widths
        base = subarray * g.rows_per_subarray
        if include:
            loc = g.local_row(include[0])
            gf = list(split_groups(loc, widths))
        else:
            gf = [int(rng.integers(0, 1 << w)) for w in widths]
        gs = list(gf)
        for i in usable[:k]:
            gs[i] = gf[i] ^ ((1 << widths[i]) - 1 if widths[i] == 1 else 1 + int(rng.integers(0, (1 << widths[i]) - 1)))
            gs[i] &= (1 << widths[i]) - 1
            if gs[i] == gf[i]:  # ensure difference
                gs[i] = (gf[i] + 1) & ((1 << widths[i]) - 1)
        rf = base + join_groups(tuple(gf), widths)
        rs = base + join_groups(tuple(gs), widths)
        assert self.n_activated(rf, rs) == n_rows, (rf, rs)
        return rf, rs

    def nrg_census(self, subarray: int = 0,
                   sample: int | None = None,
                   seed: int = 0) -> dict[int, float]:
        """Fraction of ordered (rf != rs) same-subarray pairs activating each
        row count — Table 1's N_RG% columns.

        ``sample``: if set, Monte-Carlo over that many pairs (the full census
        is exact/brute force over n*(n-1) pairs otherwise).
        """
        g = self.geometry
        n = g.rows_per_subarray
        base = subarray * g.rows_per_subarray
        rng = np.random.default_rng(seed)
        counts: dict[int, int] = {}
        if sample is None:
            pairs = ((a, b) for a in range(n) for b in range(n) if a != b)
            total = n * (n - 1)
        else:
            def _gen():
                for _ in range(sample):
                    a = int(rng.integers(0, n))
                    b = int(rng.integers(0, n - 1))
                    if b >= a:
                        b += 1
                    yield a, b
            pairs = _gen()
            total = sample
        for a, b in pairs:
            c = self.n_activated(base + a, base + b)
            counts[c] = counts.get(c, 0) + 1
        return {k: v / total for k, v in sorted(counts.items())}
