"""Charge-sharing analog model (paper §3.1.1, §5.1; Figs 4 & 11).

Charge conservation on a bitline precharged to VDD/2 when a set of cells is
simultaneously connected:

    dV = sum_i C_i * (V_i - VDD/2) / (C_bl + sum_i C_i)

Data cells hold V_i in {0, VDD}; Frac-neutral cells hold ~VDD/2 and therefore
add denominator capacitance without moving the numerator. The sense amplifier
resolves sign(dV - offset + noise).

Per-bitline *static* draws (process variation): cell capacitances
C_i ~ N(C, (pv*C)^2) and sense offset ~ N(0, sigma_off). Per-trial *dynamic*
noise: N(0, sigma_trial) plus data-pattern coupling ~ N(0, sigma_cpl*sqrt(N))
(random patterns activate neighbor interference — §6.1.1 observation 2).

The paper's "success rate" counts a bitline as stable only if it is correct
over ALL trials (10^4 random-pattern trials); we model that as the static
margin exceeding the ~3.9-sigma trial-noise tail.

Everything is vectorized over bitlines in JAX (the SPICE Monte Carlo of
Figs 4/11 becomes a jit'd batched computation).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.profiles import MfrProfile

# Quantile of the max |N(0,1)| over ~1e4 trials: P(|z| < q)^(1e4) ~ 0.5
TRIAL_TAIL_SIGMA = 3.9


@dataclasses.dataclass(frozen=True)
class BitlineSample:
    """Per-bitline static condition draws."""
    cell_caps: jax.Array      # [n_rows, n_bitlines] femto-farads
    sense_offset: jax.Array   # [n_bitlines] volts


def draw_bitlines(key: jax.Array, profile: MfrProfile, n_rows: int,
                  n_bitlines: int, process_variation: float | None = None
                  ) -> BitlineSample:
    kc, ko = jax.random.split(key)
    pv = profile.process_variation if process_variation is None else process_variation
    caps = profile.cell_cap_ff * (
        1.0 + pv * jax.random.normal(kc, (n_rows, n_bitlines)))
    caps = jnp.clip(caps, 0.05 * profile.cell_cap_ff, None)
    offs = profile.sense_offset_sigma * jax.random.normal(ko, (n_bitlines,))
    return BitlineSample(cell_caps=caps, sense_offset=offs)


@partial(jax.jit, static_argnames=("vdd", "c_bl"))
def bitline_deviation(cell_values: jax.Array, neutral_mask: jax.Array,
                      cell_caps: jax.Array, *, vdd: float,
                      c_bl: float) -> jax.Array:
    """dV per bitline.

    cell_values: [n_rows, B] in {0,1}; neutral_mask: [n_rows] bool (Frac rows);
    cell_caps: [n_rows, B]. Returns [B] volts.
    """
    v = jnp.where(neutral_mask[:, None], 0.5 * vdd,
                  cell_values.astype(jnp.float32) * vdd)
    num = jnp.sum(cell_caps * (v - 0.5 * vdd), axis=0)
    den = c_bl + jnp.sum(cell_caps, axis=0)
    return num / den


def _worst_margins(key: jax.Array, profile: MfrProfile, *, m_inputs: int,
                   copies: int, n_neutral: int, n_bitlines: int,
                   n_patterns: int,
                   process_variation: float | None) -> tuple[jax.Array, float]:
    """Worst-case per-bitline sensing margin over random patterns plus the
    per-trial noise sigma — the shared Monte-Carlo core of
    :func:`maj_success_rate` (stable mask) and :func:`column_flip_probs`
    (per-column failure probabilities). Returns ``(worst [B], sigma)``."""
    n_rows = m_inputs * copies + n_neutral
    kd, kp, kn = jax.random.split(key, 3)
    sample = draw_bitlines(kd, profile, n_rows, n_bitlines, process_variation)

    # Random input patterns per bitline (the paper stores the same operand
    # value across a row, but per-bitline elements differ -> effectively
    # random per bitline). Worst-case patterns dominate stability, so we
    # include all minimal-margin patterns among the random draws.
    patterns = jax.random.bernoulli(
        kp, 0.5, (n_patterns, m_inputs, n_bitlines)).astype(jnp.float32)
    neutral = jnp.concatenate(
        [jnp.zeros(m_inputs * copies, dtype=bool),
         jnp.ones(n_neutral, dtype=bool)])

    def pattern_margin(pat):  # pat: [m_inputs, B]
        cells = jnp.repeat(pat, copies, axis=0)  # replication (Fig 10)
        cells = jnp.concatenate(
            [cells, jnp.zeros((n_neutral, cells.shape[1]))], axis=0)
        dv = bitline_deviation(cells, neutral, sample.cell_caps,
                               vdd=profile.vdd, c_bl=profile.bitline_cap_ff)
        maj = (jnp.sum(pat, axis=0) > m_inputs / 2).astype(jnp.float32)
        sign = jnp.where(maj > 0.5, 1.0, -1.0)
        # Sensed bit = (dv - offset + noise) > 0; margin toward the correct
        # value is sign * (dv - offset).
        return sign * (dv - sample.sense_offset)

    margins = jax.vmap(pattern_margin)(patterns)  # [P, B]
    worst = jnp.min(margins, axis=0)              # [B]
    sigma = jnp.sqrt(profile.trial_noise_sigma ** 2
                     + (profile.coupling_sigma ** 2) * n_rows)
    return worst, sigma


def maj_success_rate(key: jax.Array, profile: MfrProfile, *, m_inputs: int,
                     copies: int, n_neutral: int, n_bitlines: int = 4096,
                     n_patterns: int = 64,
                     process_variation: float | None = None,
                     ) -> tuple[float, jax.Array]:
    """Monte-Carlo success rate of MAJ-M with input replication.

    Returns (mean success rate, per-bitline stable mask). Patterns sweep the
    worst-case input imbalance (|ones-zeros| == 1) plus random patterns,
    mirroring §6.1.1's random-data experiments.
    """
    worst, sigma = _worst_margins(
        key, profile, m_inputs=m_inputs, copies=copies, n_neutral=n_neutral,
        n_bitlines=n_bitlines, n_patterns=n_patterns,
        process_variation=process_variation)
    trial_tail = TRIAL_TAIL_SIGMA * sigma
    stable = worst > trial_tail
    return float(jnp.mean(stable)), stable


@dataclasses.dataclass(frozen=True)
class ColumnProfile:
    """One Monte-Carlo characterization of a row group's bitlines.

    ``rate``/``stable`` match :func:`maj_success_rate` exactly (the same
    margin draws); ``flip_p`` adds the per-column *per-trial* failure
    probability — P(per-trial noise overwhelms the worst-case static
    margin) = Phi(-worst / sigma) — which the reliability plane's fault
    injector uses as the bit-flip rate of each column.
    """
    rate: float
    stable: np.ndarray  # bool  [n_bitlines]
    flip_p: np.ndarray  # float [n_bitlines], per-trial failure probability


def column_flip_probs(key: jax.Array, profile: MfrProfile, *, m_inputs: int,
                      copies: int, n_neutral: int, n_bitlines: int = 4096,
                      n_patterns: int = 64,
                      process_variation: float | None = None
                      ) -> ColumnProfile:
    """Per-column characterization for calibration maps (repro.reliability).

    Shares the Monte-Carlo margin computation with
    :func:`maj_success_rate` (identical ``rate``/``stable`` for identical
    arguments) and additionally converts each bitline's worst-case static
    margin into a per-trial flip probability via the Gaussian noise tail.
    A column with a *negative* worst margin (charge sharing lands on the
    wrong side of the sense amp even before noise) has ``flip_p > 0.5``.
    """
    worst, sigma = _worst_margins(
        key, profile, m_inputs=m_inputs, copies=copies, n_neutral=n_neutral,
        n_bitlines=n_bitlines, n_patterns=n_patterns,
        process_variation=process_variation)
    stable = worst > TRIAL_TAIL_SIGMA * sigma
    # P(margin + N(0, sigma) < 0) = 0.5 * erfc(worst / (sigma * sqrt(2))).
    flip = 0.5 * jax.scipy.special.erfc(
        worst / (sigma * jnp.sqrt(jnp.float32(2.0))))
    return ColumnProfile(rate=float(jnp.mean(stable)),
                         stable=np.asarray(stable),
                         flip_p=np.clip(np.asarray(flip, np.float64), 0, 1))


def deviation_distribution(key: jax.Array, profile: MfrProfile, *,
                           m_inputs: int, copies: int, n_neutral: int,
                           ones: int, n_bitlines: int = 4096,
                           process_variation: float | None = None
                           ) -> jax.Array:
    """|dV| distribution for a fixed input pattern with ``ones`` logic-1
    inputs out of ``m_inputs`` (Figs 4b / 11a)."""
    n_rows = m_inputs * copies + n_neutral
    sample = draw_bitlines(key, profile, n_rows, n_bitlines,
                           process_variation)
    pat = jnp.concatenate([jnp.ones(ones), jnp.zeros(m_inputs - ones)])
    cells = jnp.repeat(pat[:, None], copies, axis=0) * jnp.ones((1, n_bitlines))
    cells = jnp.concatenate(
        [cells, jnp.zeros((n_neutral, n_bitlines))], axis=0)
    neutral = jnp.concatenate(
        [jnp.zeros(m_inputs * copies, dtype=bool),
         jnp.ones(n_neutral, dtype=bool)])
    return bitline_deviation(cells, neutral, sample.cell_caps,
                             vdd=profile.vdd, c_bl=profile.bitline_cap_ff)


def single_row_deviation(key: jax.Array, profile: MfrProfile, *,
                         n_bitlines: int = 4096,
                         process_variation: float | None = None) -> jax.Array:
    """Nominal single-row activation deviation (Fig 4b comparison point)."""
    return deviation_distribution(
        key, profile, m_inputs=1, copies=1, n_neutral=0, ones=1,
        n_bitlines=n_bitlines, process_variation=process_variation)
