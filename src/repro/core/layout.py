"""Vertical data layout (paper §2.4).

Bit-serial PuM places all bits of an element in one DRAM column (bitline):
bit ``j`` of element ``i`` lives on bit-plane row ``j``, bitline ``i``.
Planes are packed uint32 words (bitline ``32w + b`` = bit ``b`` of word ``w``).

``to_vertical`` / ``from_vertical`` are the host-side transposes (the on-TPU
equivalent is kernels/bit_transpose). Shifts in vertical layout are free —
they rename plane rows instead of moving data.
"""

from __future__ import annotations

import numpy as np


def pack_bits(bits: np.ndarray) -> np.ndarray:
    """[..., n_bits] {0,1} -> [..., n_bits/32] uint32 (little-endian lanes)."""
    bits = np.asarray(bits, np.uint8)
    if bits.shape[-1] % 32:
        raise ValueError("n_bits must be a multiple of 32")
    return np.packbits(bits, axis=-1, bitorder="little").view(np.uint32)


def unpack_bits(words: np.ndarray, n_bits: int | None = None) -> np.ndarray:
    """[..., W] uint32 -> [..., 32W] {0,1} uint8."""
    w8 = np.asarray(words, np.uint32).view(np.uint8)
    bits = np.unpackbits(w8, axis=-1, bitorder="little")
    return bits if n_bits is None else bits[..., :n_bits]


def to_vertical(values: np.ndarray, width: int) -> np.ndarray:
    """[n] unsigned ints -> [width, n/32] uint32 bit-planes."""
    values = np.asarray(values, np.uint64)
    n = values.shape[0]
    if n % 32:
        raise ValueError("element count must be a multiple of 32")
    planes = np.empty((width, n // 32), np.uint32)
    for j in range(width):
        planes[j] = pack_bits(((values >> j) & 1).astype(np.uint8))
    return planes


def from_vertical(planes: np.ndarray, signed: bool = False) -> np.ndarray:
    """[width, W] uint32 bit-planes -> [32W] ints (two's complement when
    ``signed``)."""
    width = planes.shape[0]
    vals = np.zeros(planes.shape[1] * 32, np.uint64)
    for j in range(width):
        vals |= unpack_bits(planes[j]).astype(np.uint64) << j
    if signed:
        sign = (vals >> (width - 1)) & 1
        vals = vals.astype(np.int64) - (sign.astype(np.int64) << width)
        return vals
    return vals
