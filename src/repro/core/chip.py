"""Logical DRAM chip model: bit-exact PuM state machine.

State per bank: packed ``uint32`` payload ``[rows, words]`` plus a per-row
``neutral`` mask (Frac VDD/2 rows carry no logical value until overwritten).

Every PuM mutation goes through ``execute``, which pairs the *logical* effect
with the *command program* (commands.py) so correctness and latency/energy
accounting always agree. The analog layer (analog.py) independently models
success rates; `PulsarChip.apa_maj` can optionally apply a per-bitline
stability mask drawn from it (fault injection for the reliability tests).

The model is NumPy-based (host metadata path — command streams are
inherently sequential); the bulk bit-plane math (majority over up to 32
rows) calls the same packed-word algorithms the Pallas kernels implement,
via kernels ref/ops (single source of truth).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import commands as cmds
from repro.core.decoder import RowDecoder
from repro.core.geometry import DramGeometry
from repro.core.profiles import MfrProfile
from repro.core.timing import DDR4_2400, DramTimings


@dataclasses.dataclass
class OpStats:
    """Cumulative cost accounting for a chip session."""
    latency_ns: float = 0.0
    energy_j: float = 0.0
    n_acts: int = 0
    n_pres: int = 0
    n_rdwr: int = 0
    n_ops: int = 0
    trace: list | None = None  # optional (op_name, latency) log

    def add(self, name: str, res: cmds.ScheduleResult) -> None:
        self.latency_ns += res.total_ns
        self.energy_j += res.energy_j
        self.n_acts += res.n_acts
        self.n_pres += res.n_pres
        self.n_rdwr += res.n_rdwr
        self.n_ops += 1
        if self.trace is not None:
            self.trace.append((name, res.total_ns))


def _popcount_rows(rows: np.ndarray) -> np.ndarray:
    """Per-bit-position vote count across rows: [N, W] uint32 -> [W] counts
    per bit, returned as an int32 array broadcast over bits via bit-slicing.

    Implemented as the same bit-sliced carry-save counter the Pallas kernel
    uses (see kernels/maj_n.py); here via NumPy for the host path.
    """
    n = rows.shape[0]
    k = max(1, (n).bit_length())
    planes = [np.zeros_like(rows) for _ in range(k + 1)]
    for i in range(n):
        carry = rows[i]
        for j in range(k + 1):
            t = planes[j] ^ carry
            carry = planes[j] & carry
            planes[j] = t
    # Reassemble counts per bit: counts = sum planes[j] * 2^j, but we only
    # need comparisons; return planes for threshold tests.
    return np.stack(planes)  # [k+1, W] bit-planes of the count


def majority_bits(rows: np.ndarray, threshold: int) -> np.ndarray:
    """Packed-word test (count_of_ones_per_bit >= threshold) across rows.

    rows: [N, W] uint32. threshold in [1, N]. Returns [W] uint32.
    Uses the overflow-counter trick: initialize the counter to
    (2^K - threshold) in every bit lane; after adding the N vote planes,
    lanes whose count >= threshold have overflowed past 2^K.
    """
    n, w = rows.shape
    if not (1 <= threshold <= n):
        raise ValueError(f"threshold {threshold} out of range for {n} rows")
    k = int(n).bit_length()  # counter width; overflow bit tracked separately
    init = (1 << k) - threshold
    planes = [np.full(w, 0xFFFFFFFF, np.uint32) if (init >> j) & 1
              else np.zeros(w, np.uint32) for j in range(k)]
    overflow = np.zeros(w, np.uint32)
    for i in range(n):
        carry = rows[i]
        for j in range(k):
            t = planes[j] ^ carry
            carry = planes[j] & carry
            planes[j] = t
        overflow |= carry
    return overflow


class PulsarChip:
    """One DRAM rank (module-level lockstep) with PuM capability."""

    def __init__(self, geometry: DramGeometry, profile: MfrProfile,
                 seed: int = 0, timings: DramTimings = DDR4_2400,
                 trace: bool = False):
        self.geometry = geometry
        self.profile = profile
        self.timings = timings
        self.decoder = RowDecoder.build(geometry, profile, seed)
        self.scheduler = cmds.CommandScheduler(timings)
        self.rng = np.random.default_rng(seed + 0x5AF)
        g = geometry
        self.banks = np.zeros((g.banks, g.rows_per_bank, g.words_per_row),
                              np.uint32)
        self.neutral = np.zeros((g.banks, g.rows_per_bank), bool)
        self.stats = OpStats(trace=[] if trace else None)
        self._wr_bursts = max(1, g.row_bits // 512)  # BL8 x 64-bit bus

    # ------------------------------------------------------------------ #
    # Host-side (nominal-timing) access
    # ------------------------------------------------------------------ #

    def write_row(self, bank: int, row: int, data: np.ndarray) -> None:
        data = np.asarray(data, np.uint32)
        if data.shape != (self.geometry.words_per_row,):
            raise ValueError(f"row payload must be [{self.geometry.words_per_row}]")
        self.banks[bank, row] = data
        self.neutral[bank, row] = False
        prog = cmds.prog_write_row(bank, row, self._wr_bursts, self.timings)
        self.stats.add("write_row", self.scheduler.schedule(prog))

    def read_row(self, bank: int, row: int) -> np.ndarray:
        if self.neutral[bank, row]:
            raise RuntimeError(f"reading neutral (VDD/2) row {row}: undefined data")
        prog = cmds.prog_read_row(bank, row, self._wr_bursts, self.timings)
        self.stats.add("read_row", self.scheduler.schedule(prog))
        return self.banks[bank, row].copy()

    def peek(self, bank: int, row: int) -> np.ndarray:
        """Test-only: read without cost accounting."""
        return self.banks[bank, row].copy()

    # ------------------------------------------------------------------ #
    # PuM primitives
    # ------------------------------------------------------------------ #

    def frac(self, bank: int, row: int) -> None:
        """Put ``row`` into the neutral VDD/2 state (FracDRAM op).

        On Mfr. M (frac unsupported, footnote 4) the same logical effect is
        obtained by writing the sense-amp bias pattern; the neutral flag is
        still what the charge-sharing vote consumes.
        """
        if self.profile.frac_supported:
            prog = cmds.prog_frac(bank, row, self.timings)
            self.stats.add("frac", self.scheduler.schedule(prog))
        else:
            if not self.profile.sa_bias_neutral:
                raise RuntimeError(
                    f"Mfr {self.profile.name}: no neutral-row mechanism")
            # Mfr M: re-init the row with the bias pattern via RowClone from
            # a resident pattern row (one AAP) — a full WR stream is never
            # needed after the one-time pattern-row setup.
            prog = cmds.prog_aap_multi_row_init(bank, row, row, self.timings)
            self.stats.add("frac.bias_clone", self.scheduler.schedule(prog))
        self.neutral[bank, row] = True

    def frac_block(self, bank: int, rf: int, rs: int) -> tuple[int, ...]:
        """Put a whole decoder block into the neutral state.

        Mfr H: Frac has no multi-row variant -> one Frac per row.
        Mfr M: bias pattern re-init is a RowClone seed + one Multi-RowInit
        over the block (2 AAPs regardless of block size)."""
        rows = self.decoder.activated_rows(rf, rs)
        if self.profile.frac_supported:
            for r in rows:
                self.frac(bank, r)
            return rows
        if not self.profile.sa_bias_neutral:
            raise RuntimeError(
                f"Mfr {self.profile.name}: no neutral-row mechanism")
        prog = cmds.prog_aap_multi_row_init(bank, rf, rs, self.timings)
        self.stats.add("frac.bias_seed", self.scheduler.schedule(prog))
        if len(rows) > 1:
            self.stats.add("frac.bias_mri", self.scheduler.schedule(prog))
        for r in rows:
            self.neutral[bank, r] = True
        return rows

    def apa_maj(self, bank: int, rf: int, rs: int,
                stability_mask: np.ndarray | None = None) -> tuple[int, ...]:
        """Charge-sharing APA (§5.2.2): simultaneous activation of the
        decoder-determined row set; every bitline resolves to the weighted
        majority of non-neutral activated cells; ALL activated rows and the
        row buffer take the result.

        ``stability_mask``: optional [row_bits] bool — bitlines that resolve
        correctly (from the analog model). Unstable bitlines flip to the
        complement (worst-case deterministic fault model).
        Returns the activated row set.
        """
        rows = self.decoder.activated_rows(rf, rs)
        if len(rows) < 2:
            raise RuntimeError(
                f"APA({rf},{rs}) activated {rows}: not a multi-row group "
                f"(Mfr {self.profile.name})")
        data_rows = [r for r in rows if not self.neutral[bank, r]]
        n_data = len(data_rows)
        if n_data == 0:
            raise RuntimeError("charge sharing over only neutral rows")
        # Even vote counts can tie (equilibrium, §2.3); PULSAR's replication
        # plans guarantee |net| >= copies > 0 so ties never occur there. If a
        # tie does occur, the sense amp resolves to its bias (deterministic 0
        # here; the *randomness* of unbiased ties is what QUAC-TRNG exploits,
        # out of scope). Threshold count > n_data/2 ==> count >= n_data//2+1.
        votes = self.banks[bank, list(data_rows)]
        result = majority_bits(votes, n_data // 2 + 1)
        if stability_mask is not None:
            flip = ~_mask_to_words(stability_mask)
            result = result ^ flip
        idx = list(rows)
        self.banks[bank, idx] = result
        self.neutral[bank, idx] = False
        prog = cmds.prog_apa_charge_share(bank, rf, rs, self.timings)
        self.stats.add(f"apa_maj{n_data}", self.scheduler.schedule(prog))
        return rows

    def multi_row_init(self, bank: int, rf: int, rs: int) -> tuple[int, ...]:
        """Multi-RowInit (§5.2.1): copy R_F's content into every row of the
        activated group (R_F fully sensed first; sense amps overdrive)."""
        rows = self.decoder.activated_rows(rf, rs)
        if self.neutral[bank, rf]:
            raise RuntimeError("Multi-RowInit source row is neutral")
        src = self.banks[bank, rf].copy()
        idx = list(rows)
        self.banks[bank, idx] = src
        self.neutral[bank, idx] = False
        # rf itself keeps its value (it is in the activated set by
        # construction when rf/rs share the subarray; if not, rs-only set
        # still gets rf's data because the sense amps latched rf).
        prog = cmds.prog_aap_multi_row_init(bank, rf, rs, self.timings)
        self.stats.add(f"multi_row_init{len(rows)}",
                       self.scheduler.schedule(prog))
        return rows

    def row_clone(self, bank: int, src: int, dst: int) -> None:
        """RowClone baseline [25, 98]: copy one row to one row (AAP)."""
        if self.neutral[bank, src]:
            raise RuntimeError("RowClone source row is neutral")
        self.banks[bank, dst] = self.banks[bank, src]
        self.neutral[bank, dst] = False
        prog = cmds.prog_aap_multi_row_init(bank, src, dst, self.timings)
        self.stats.add("row_clone", self.scheduler.schedule(prog))

    def bulk_write(self, bank: int, rf: int, rs: int,
                   data: np.ndarray) -> tuple[int, ...]:
        """Bulk-Write (§5.2.3): one WR stream drives all activated rows."""
        rows = self.decoder.activated_rows(rf, rs)
        data = np.asarray(data, np.uint32)
        idx = list(rows)
        self.banks[bank, idx] = data
        self.neutral[bank, idx] = False
        prog = cmds.prog_bulk_write(bank, rf, rs, self._wr_bursts,
                                    self.timings)
        self.stats.add(f"bulk_write{len(rows)}", self.scheduler.schedule(prog))
        return rows


def _mask_to_words(mask: np.ndarray) -> np.ndarray:
    """[bits] bool -> packed uint32 words (bit 32w+b -> bit b of word w;
    little-endian platform assumed, as with all packed layouts here)."""
    bits = np.asarray(mask, np.uint8)
    if bits.size % 32:
        raise ValueError("mask length must be a multiple of 32")
    return np.packbits(bits, bitorder="little").view(np.uint32).copy()
