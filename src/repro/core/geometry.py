"""DRAM geometry description.

Models the organization of a DDR4 module at the granularity the paper uses:
channel -> rank -> chip -> bank -> subarray -> row -> bitline (§2.1).

The *logical dataplane* treats one DRAM row as ``row_bits`` bitlines packed
into ``uint32`` words (bit ``b`` of word ``w`` is bitline ``32*w + b``).
The paper operates on module-level rows (all chips in a rank in lockstep):
65 536 bitlines per module row for an x8 rank (Table 1); tests use smaller
geometries for speed — everything is parameterized.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DramGeometry:
    """Geometry of one DRAM bank (module-level lockstep view)."""

    row_bits: int = 65536          # bitlines per (module-level) row
    rows_per_subarray: int = 512   # paper: 512-1024 (Table 1, "SA Size")
    subarrays_per_bank: int = 4    # reverse-engineered: up to 2^7; small default
    banks: int = 16                # DDR4: 16 banks (4 bank groups x 4)
    # Row-address split inside a subarray: predecoder group widths, LSB first.
    # Paper §4.2: predecoders A..E latch 18 bits total = 4+4+4+4+2 outputs
    # from address-bit groups of widths (2,2,2,2,1) over the 9-bit local row
    # address of a 512-row subarray.
    predecoder_widths: tuple[int, ...] = (2, 2, 2, 2, 1)

    def __post_init__(self) -> None:
        if self.row_bits % 32 != 0:
            raise ValueError("row_bits must be a multiple of 32")
        if sum(self.predecoder_widths) != self.local_addr_bits:
            raise ValueError(
                f"predecoder widths {self.predecoder_widths} must cover "
                f"{self.local_addr_bits} local address bits "
                f"(rows_per_subarray={self.rows_per_subarray})"
            )

    @property
    def words_per_row(self) -> int:
        return self.row_bits // 32

    @property
    def rows_per_bank(self) -> int:
        return self.rows_per_subarray * self.subarrays_per_bank

    @property
    def local_addr_bits(self) -> int:
        n = self.rows_per_subarray
        if n & (n - 1):
            raise ValueError("rows_per_subarray must be a power of two")
        return n.bit_length() - 1

    @property
    def row_bytes(self) -> int:
        return self.row_bits // 8

    def subarray_of(self, row: int) -> int:
        return row // self.rows_per_subarray

    def local_row(self, row: int) -> int:
        return row % self.rows_per_subarray


# Geometries used throughout the repo ---------------------------------------

# Module-level geometry matching the paper's evaluation rows (65 536 bitlines,
# 512-row subarrays, Mfr-H-like H0-6 modules).
PAPER_MODULE = DramGeometry(row_bits=65536, rows_per_subarray=512,
                            subarrays_per_bank=16, banks=16)

# Small geometry for unit tests: fast, same code paths.
TEST_GEOMETRY = DramGeometry(row_bits=1024, rows_per_subarray=64,
                             subarrays_per_bank=2, banks=2,
                             predecoder_widths=(2, 2, 2))
