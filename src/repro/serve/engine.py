"""Batched serving engine: continuous batching over prefill/decode steps.

Slot-based scheduler: a fixed decode batch of ``max_batch`` slots; arriving
requests prefill into a free slot's cache region; every engine tick runs one
fused decode step for all active slots. EOS/length-stop frees slots.
(Single-host demo of the production pattern; the jit'd step functions are
the same ones the dry-run lowers for the 256/512-chip meshes.)

Bulk slot bookkeeping routes through the PuM dataplane by default
(``pum_bulk=True``): the per-tick stop predicate — EOS match, generated
length cap, context-length cap, across all active slots — is one fused
PuM program (xor/reduce_or equality + less-than compares) recorded
through ``repro.pum`` operators instead of a per-slot Python conditional.
Results are bit-identical to the host path (tested); the device's cost
plane (``ServeEngine.pum.stats``) prices what that bookkeeping would cost
executed in DRAM. ``pum_bulk=False`` restores the pure-host loop.

``telemetry=True`` records per-tick observability through the shared
``repro.telemetry`` pieces: decode-slot occupancy and stop-predicate
flush latency histograms in ``ServeEngine.counters`` plus ``serve.tick``
/ ``serve.stop_predicate`` spans (with the PuM device's flush phases
nested inside) in ``ServeEngine.tracer``. Telemetry never perturbs token
output (tested) and is fully off — no tracer, no clock reads — by
default.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

import repro.pum as pum
from repro.config.base import ModelConfig
from repro.models.model import decode_step, init_cache, init_params, prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [T] int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params=None, max_batch: int = 4,
                 max_len: int = 256, eos_id: int = 1, seed: int = 0,
                 greedy: bool = True, pum_bulk: bool = True,
                 telemetry: bool = False):
        self.cfg = cfg
        # Fused PuM device for bulk slot bookkeeping (stop masks): ops
        # record lazily and each tick's predicate compiles to one program.
        self.pum = pum.device(width=32, fuse=True) if pum_bulk else None
        # Per-tick telemetry (opt-in): slot occupancy + stop-predicate
        # latency in `counters`, tick/predicate spans in `tracer`. The
        # PuM device's flush phases nest inside by attaching the same
        # tracer to its engine.
        from repro.telemetry import NULL_TRACER, CounterBank, Tracer
        self.counters = CounterBank()
        self.tracer = Tracer() if telemetry else None
        self._tr = self.tracer if telemetry else NULL_TRACER
        self.telemetry = telemetry
        if telemetry and self.pum is not None:
            self.pum.engine.tracer = self.tracer
        self.params = params if params is not None else init_params(
            cfg, jax.random.PRNGKey(seed))
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.greedy = greedy
        self.caches = init_cache(cfg, max_batch, max_len,
                                 jnp.dtype(cfg.dtype))
        self.pos = np.zeros(max_batch, np.int32)
        self.slot_req: list[Request | None] = [None] * max_batch
        self.cur_token = np.zeros(max_batch, np.int32)
        self._decode = jax.jit(
            lambda p, c, t, q: decode_step(cfg, p, c, t, q))
        # One cached prefill closure for the engine's lifetime: a fresh
        # jax.jit per admission would recompile every request even at
        # identical prompt shapes.
        self._prefill = jax.jit(
            lambda p, b: prefill(cfg, p, b, max_len))
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        if self.pum is not None:
            # Warm-up: compile the fixed-shape stop predicate now so the
            # one-time jit cost never lands on a request's first token.
            self._stop_mask_pum([])
            self.pum.reset_stats()

    # ------------------------------------------------------------------ #

    def submit(self, req: Request) -> None:
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> None:
        """Prefill queued requests into free slots (one-at-a-time prefill;
        batched decode)."""
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            t = len(req.prompt)
            logits, caches_b1, _ = self._prefill(
                self.params, {"tokens": jnp.asarray(req.prompt)[None]})

            # Copy the single-request cache into this slot of the batch
            # cache. The batch axis is the unique axis where the two leaf
            # shapes differ (works for both per-layer and stacked layouts).
            def write(slot_c, one_c):
                ax = next((i for i, (a, b) in enumerate(
                    zip(slot_c.shape, one_c.shape)) if a != b), 0)
                idx = [slice(None)] * slot_c.ndim
                idx[ax] = slot
                return slot_c.at[tuple(idx)].set(
                    jnp.take(one_c, 0, axis=ax))

            self.caches = jax.tree.map(write, self.caches, caches_b1)
            tok = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
            req.out_tokens.append(tok)
            req.t_first = time.perf_counter()
            self.slot_req[slot] = req
            self.pos[slot] = t
            self.cur_token[slot] = tok

    def _stop_mask_pum(self, active: list[int]) -> list[bool]:
        """Bulk stop predicate on the fused PuM engine: per active slot,
        ``tok == eos or n_generated >= max_new or pos >= max_len-1``. The
        recorded ops (``^`` + ``reduce_or`` equality, ``<`` length caps)
        compile into one fused program on materialization — semantics
        identical to the host conditional in :meth:`tick`. Operands are
        padded to the full ``max_batch`` decode batch (inactive slots get
        never-stopping dummies and are filtered out), so every tick reuses
        ONE compiled pipeline — it is warmed up in ``__init__`` to keep
        the jit compile off the first-token latency path."""
        dev = self.pum
        m = self.max_batch
        ones = np.ones(m, np.uint64)
        n_out = np.zeros(m, np.uint64)
        cap = np.ones(m, np.uint64)
        pos = np.zeros(m, np.uint64)
        tok = np.zeros(m, np.uint64)
        for s in active:
            req = self.slot_req[s]
            n_out[s] = len(req.out_tokens)
            cap[s] = req.max_new_tokens
            pos[s] = self.pos[s]
            tok[s] = self.cur_token[s]
        limit = np.full(m, self.max_len - 1, np.uint64)
        stop = ((dev.asarray(n_out) < cap) ^ ones) \
            | ((dev.asarray(pos) < limit) ^ ones)   # len cap | ctx cap
        if 0 <= self.eos_id < (1 << dev.width):
            eos = np.full(m, self.eos_id, np.uint64)
            neq = (dev.asarray(tok) ^ eos).reduce_bits("or")
            stop = stop | (neq ^ ones)              # EOS
        full = stop.to_numpy().astype(bool)
        return [bool(full[s]) for s in active]

    def tick(self) -> int:
        """One engine iteration: admit + one fused decode step.
        Returns number of active slots."""
        with self._tr.span("serve.tick") as sp_tick:
            return self._tick_inner(sp_tick)

    def _tick_inner(self, sp_tick) -> int:
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if self.telemetry:
            self.counters.inc("serve.ticks")
            self.counters.observe("serve.active_slots", len(active))
            sp_tick.args["active_slots"] = len(active)
        if not active:
            return 0
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self.cur_token),
            jnp.asarray(self.pos))
        logits = np.asarray(logits[:, :self.cfg.vocab_size])
        for slot in active:
            req = self.slot_req[slot]
            tok = int(np.argmax(logits[slot]))
            req.out_tokens.append(tok)
            self.pos[slot] += 1
            self.cur_token[slot] = tok
        with self._tr.span("serve.stop_predicate",
                           path="pum" if self.pum is not None
                           else "host") as sp:
            if self.pum is not None:
                done = self._stop_mask_pum(active)
            else:
                done = np.array(
                    [self.cur_token[s] == self.eos_id
                     or len(self.slot_req[s].out_tokens)
                     >= self.slot_req[s].max_new_tokens
                     or self.pos[s] >= self.max_len - 1 for s in active])
        if self.telemetry:
            # Latency histogram of the stop-predicate flush (the fused
            # program's record->materialize round trip per tick).
            self.counters.observe("serve.stop_flush_ns", sp.dur_ns)
        for stop, slot in zip(done, active):
            if stop:
                req = self.slot_req[slot]
                req.done = True
                req.t_done = time.perf_counter()
                self.finished.append(req)
                self.slot_req[slot] = None
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Request]:
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.tick()
            ticks += 1
        return self.finished
