"""Batched serving engine: continuous batching over prefill/decode steps.

Slot-based scheduler: a fixed decode batch of ``max_batch`` slots; arriving
requests prefill into a free slot's cache region; every engine tick runs one
fused decode step for all active slots. EOS/length-stop frees slots.
(Single-host demo of the production pattern; the jit'd step functions are
the same ones the dry-run lowers for the 256/512-chip meshes.)

Bulk slot bookkeeping routes through the PuM dataplane by default
(``pum_bulk=True``): the per-tick stop predicate — EOS match, generated
length cap, context-length cap, across all active slots — is one fused
PuM program (xor/reduce_or equality + less-than compares) expressed
through ``repro.pum`` operators instead of a per-slot Python conditional.
The predicate is captured once via ``Device.capture`` at engine
construction, so every steady-state tick *replays* a compiled pipeline —
zero graph re-recording per tick. Results are bit-identical to the host
path (tested); the device's cost plane (``ServeEngine.pum.stats``)
prices what that bookkeeping would cost executed in DRAM
(the captured charge recipe replays per tick, so totals advance exactly
as if re-recorded). ``pum_bulk=False`` restores the pure-host loop.

``async_stop=True`` (requires ``pum_bulk``) dispatches each tick's stop
predicate on the device's flush worker at tick end and resolves it at
the *start* of the next tick — before admission and decode — taking the
predicate latency off the tick's critical path. Token streams are
bit-identical to the synchronous mode: slots free at the same tick
boundary either way, just on the other side of it.

``telemetry=True`` records per-tick observability through the shared
``repro.telemetry`` pieces: decode-slot occupancy and stop-predicate
flush latency histograms in ``ServeEngine.counters`` plus ``serve.tick``
/ ``serve.stop_predicate`` spans (with the PuM device's flush phases
nested inside) in ``ServeEngine.tracer``. Telemetry never perturbs token
output (tested) and is fully off — no tracer, no clock reads — by
default.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

import repro.pum as pum
from repro.config.base import ModelConfig
from repro.models.model import decode_step, init_cache, init_params, prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [T] int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params=None, max_batch: int = 4,
                 max_len: int = 256, eos_id: int = 1, seed: int = 0,
                 greedy: bool = True, pum_bulk: bool = True,
                 telemetry: bool = False, async_stop: bool = False):
        if async_stop and not pum_bulk:
            raise ValueError("async_stop requires pum_bulk=True (the stop "
                             "predicate runs on the PuM flush worker)")
        self.cfg = cfg
        # Fused PuM device for bulk slot bookkeeping (stop masks): ops
        # record lazily and each tick's predicate compiles to one program.
        self.pum = pum.device(width=32, fuse=True) if pum_bulk else None
        # Per-tick telemetry (opt-in): slot occupancy + stop-predicate
        # latency in `counters`, tick/predicate spans in `tracer`. The
        # PuM device's flush phases nest inside by attaching the same
        # tracer to its engine.
        from repro.telemetry import NULL_TRACER, CounterBank, Tracer
        self.counters = CounterBank()
        self.tracer = Tracer() if telemetry else None
        self._tr = self.tracer if telemetry else NULL_TRACER
        self.telemetry = telemetry
        if telemetry and self.pum is not None:
            self.pum.engine.tracer = self.tracer
        self.params = params if params is not None else init_params(
            cfg, jax.random.PRNGKey(seed))
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.greedy = greedy
        self.caches = init_cache(cfg, max_batch, max_len,
                                 jnp.dtype(cfg.dtype))
        self.pos = np.zeros(max_batch, np.int32)
        self.slot_req: list[Request | None] = [None] * max_batch
        self.cur_token = np.zeros(max_batch, np.int32)
        self._decode = jax.jit(
            lambda p, c, t, q: decode_step(cfg, p, c, t, q))
        # One cached prefill closure for the engine's lifetime: a fresh
        # jax.jit per admission would recompile every request even at
        # identical prompt shapes.
        self._prefill = jax.jit(
            lambda p, b: prefill(cfg, p, b, max_len))
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.async_stop = async_stop
        # In-flight stop predicate from the previous tick (async_stop):
        # (CaptureHandle, active slots it was computed over).
        self._stop_pending: tuple | None = None
        if self.pum is not None:
            # Capture the fixed-shape stop predicate once: the warm-up
            # call records + compiles it, so neither the jit cost nor any
            # graph re-recording ever lands on a request's token path —
            # steady-state ticks replay the pipeline.
            self._stop_prog = self.pum.capture(self._stop_expr,
                                               name="serve.stop")
            self._stop_mask_pum([])
            self.pum.reset_stats()

    # ------------------------------------------------------------------ #

    def submit(self, req: Request) -> None:
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> None:
        """Prefill queued requests into free slots (one-at-a-time prefill;
        batched decode)."""
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            t = len(req.prompt)
            logits, caches_b1, _ = self._prefill(
                self.params, {"tokens": jnp.asarray(req.prompt)[None]})

            # Copy the single-request cache into this slot of the batch
            # cache. The batch axis is the unique axis where the two leaf
            # shapes differ (works for both per-layer and stacked layouts).
            def write(slot_c, one_c):
                ax = next((i for i, (a, b) in enumerate(
                    zip(slot_c.shape, one_c.shape)) if a != b), 0)
                idx = [slice(None)] * slot_c.ndim
                idx[ax] = slot
                return slot_c.at[tuple(idx)].set(
                    jnp.take(one_c, 0, axis=ax))

            self.caches = jax.tree.map(write, self.caches, caches_b1)
            tok = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
            req.out_tokens.append(tok)
            req.t_first = time.perf_counter()
            self.slot_req[slot] = req
            self.pos[slot] = t
            self.cur_token[slot] = tok

    def _stop_expr(self, n_out, cap, pos, tok):
        """The stop predicate as a function of PumArrays — captured once
        by ``Device.capture`` in ``__init__``. Per slot:
        ``tok == eos or n_generated >= max_new or pos >= max_len-1``
        (``^`` + ``reduce_or`` equality, ``<`` length caps) — semantics
        identical to the host conditional in :meth:`tick`. The ``ones``/
        ``limit``/``eos`` operands close over engine config, so capture
        snapshots them as constant leaves with staged wire buffers."""
        m = self.max_batch
        ones = np.ones(m, np.uint64)
        limit = np.full(m, self.max_len - 1, np.uint64)
        stop = ((n_out < cap) ^ ones) \
            | ((pos < limit) ^ ones)                # len cap | ctx cap
        if 0 <= self.eos_id < (1 << self.pum.width):
            eos = np.full(m, self.eos_id, np.uint64)
            neq = (tok ^ eos).reduce_bits("or")
            stop = stop | (neq ^ ones)              # EOS
        return stop

    def _stop_operands(self, active: list[int]) -> tuple[np.ndarray, ...]:
        """Snapshot the per-slot predicate operands, padded to the full
        ``max_batch`` decode batch (inactive slots get never-stopping
        dummies and are filtered out on resolve), so every tick hits the
        ONE captured shape specialization."""
        m = self.max_batch
        n_out = np.zeros(m, np.uint64)
        cap = np.ones(m, np.uint64)
        pos = np.zeros(m, np.uint64)
        tok = np.zeros(m, np.uint64)
        for s in active:
            req = self.slot_req[s]
            n_out[s] = len(req.out_tokens)
            cap[s] = req.max_new_tokens
            pos[s] = self.pos[s]
            tok[s] = self.cur_token[s]
        return n_out, cap, pos, tok

    def _stop_mask_pum(self, active: list[int]) -> list[bool]:
        """Synchronous bulk stop predicate: replay the captured pipeline
        and filter to the active slots."""
        full = self._stop_prog(*self._stop_operands(active)).astype(bool)
        return [bool(full[s]) for s in active]

    def tick(self) -> int:
        """One engine iteration: admit + one fused decode step.
        Returns number of active slots."""
        with self._tr.span("serve.tick") as sp_tick:
            return self._tick_inner(sp_tick)

    def _resolve_stop(self) -> None:
        """Join the previous tick's in-flight stop predicate (async_stop)
        and free the slots it stopped. Runs before admission/decode, so
        a slot stopped at tick N never decodes at tick N+1 — token
        streams match the synchronous mode bit for bit."""
        if self._stop_pending is None:
            return
        handle, active = self._stop_pending
        self._stop_pending = None
        full = handle.result().astype(bool)
        self._finish([bool(full[s]) for s in active], active)

    def _finish(self, done, active: list[int]) -> None:
        for stop, slot in zip(done, active):
            if stop:
                req = self.slot_req[slot]
                req.done = True
                req.t_done = time.perf_counter()
                self.finished.append(req)
                self.slot_req[slot] = None

    def _tick_inner(self, sp_tick) -> int:
        self._resolve_stop()
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if self.telemetry:
            self.counters.inc("serve.ticks")
            self.counters.observe("serve.active_slots", len(active))
            sp_tick.args["active_slots"] = len(active)
        if not active:
            return 0
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self.cur_token),
            jnp.asarray(self.pos))
        logits = np.asarray(logits[:, :self.cfg.vocab_size])
        for slot in active:
            req = self.slot_req[slot]
            tok = int(np.argmax(logits[slot]))
            req.out_tokens.append(tok)
            self.pos[slot] += 1
            self.cur_token[slot] = tok
        done = None
        with self._tr.span("serve.stop_predicate",
                           path="pum" if self.pum is not None
                           else "host") as sp:
            if self.pum is None:
                done = np.array(
                    [self.cur_token[s] == self.eos_id
                     or len(self.slot_req[s].out_tokens)
                     >= self.slot_req[s].max_new_tokens
                     or self.pos[s] >= self.max_len - 1 for s in active])
            elif self.async_stop:
                # Dispatch on the flush worker; resolves at the start of
                # the next tick. The span measures only the (cheap)
                # snapshot + submit — the replay runs off-thread.
                self._stop_pending = (
                    self._stop_prog.call_async(*self._stop_operands(active)),
                    active)
            else:
                done = self._stop_mask_pum(active)
        if self.telemetry:
            # Latency histogram of the stop-predicate step on the caller
            # thread (captured-pipeline replay, or submit-only under
            # async_stop — the off-thread saving is the point).
            self.counters.observe("serve.stop_flush_ns", sp.dur_ns)
        if done is not None:
            self._finish(done, active)
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Request]:
        ticks = 0
        # Under async_stop, occupied slots may only be freed by the next
        # tick's resolve — the loop condition sees them as active and
        # naturally runs that one extra (no-decode) tick.
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.tick()
            ticks += 1
        self._resolve_stop()
        return self.finished
