"""repro.autotune — telemetry-driven adaptive execution planning.

Closes the measure → decide → apply loop over the observability
substrate PR 6 built (see ``docs/observability.md``):

* **measure** — :class:`WorkloadProfile` summarizes a
  :class:`~repro.telemetry.CounterBank` window (op mix, graph depth,
  lane count, pipeline-cache hit rate, raw-bitmap share, plus the
  controller's bus-utilization / stall-split / row-conflict / refresh
  counters when present) into a frozen, JSON-round-trippable feature
  vector;
* **decide** — :class:`CostModel` scores candidate configs against a
  profile with the roofline three-term decomposition
  (``launch/roofline.py`` anchors), and :class:`Tuner` exhaustively
  searches the discrete space — fused backend × plane layout ×
  auto-flush bounds × REF postponing × crossbar lookahead — freezing
  the deterministic winner into a :class:`TunedPlan`;
* **apply** — ``Device.autotune()`` applies a plan's *execution* knobs
  live (bit-exact, ``EngineStats``-identical by construction; the
  cost-plane ``ref_postponing`` recommendation is an explicit opt-in),
  and :class:`OnlineAutotuner` re-tunes from per-window counter deltas
  when the :class:`DriftDetector` fires (exploit) or on a fixed cadence
  (explore).

``TunedPlan`` / ``Tuner`` / ``WorkloadProfile`` are re-exported on the
public ``repro.pum`` surface; see ``docs/autotuning.md`` for the profile
schema, the search space, and the invariants.
"""

from repro.autotune.cost import CostModel, Estimate
from repro.autotune.profile import WorkloadProfile
from repro.autotune.tuner import (DriftDetector, OnlineAutotuner,
                                  SearchSpace, TunedPlan, Tuner)

__all__ = [
    "CostModel",
    "DriftDetector",
    "Estimate",
    "OnlineAutotuner",
    "SearchSpace",
    "TunedPlan",
    "Tuner",
    "WorkloadProfile",
]
