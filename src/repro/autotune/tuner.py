"""Tuner — deterministic search over the discrete execution-config space.

``Tuner.tune(profile, config)`` enumerates every candidate knob
combination the host can actually run (fused backends resolved through
the ``repro.backends`` registry, layouts covering the device width,
auto-flush bounds, REF postponing where a controller exists, crossbar
lookahead), scores each with the :class:`~repro.autotune.CostModel`, and
freezes the winner into a :class:`TunedPlan`. The search is exhaustive
and the enumeration order is sorted, so the same profile produces the
same plan in any process (pinned cross-process by
``tests/autotune/test_tuner.py``); the baseline (the config as-is) is
scored first and candidates must *strictly* beat the incumbent — no
measured signal, no change.

Plans split their knobs into two tiers, preserving the engine's
cost-plane invariant:

* **execution knobs** — ``fused_backend``, plane layout,
  ``flush_threshold`` / ``flush_memory_bytes``, crossbar
  ``cmd_buffer_lookahead``, and ``fuse`` itself (the cost model prices
  eager per-op dispatch against fused staging + leaf-upload traffic, so
  a window dominated by snapshot bytes can recommend ``fuse=False``) —
  change only *where/when* programs run.
  ``TunedPlan.apply`` (and ``Device.autotune``) applies these by
  default: outputs and ``EngineStats`` are bit-identical to the static
  config.
* **cost-plane knobs** — ``ref_postponing`` — change the *modeled*
  refresh schedule and therefore ``EngineStats``. The tuner still
  searches and records them, but application is an explicit
  ``cost_plane=True`` opt-in.

:class:`DriftDetector` compares a fresh profile against the one a plan
was tuned on; :class:`OnlineAutotuner` hangs off the engine's per-flush
hook and closes the explore/exploit loop — re-tune when drift fires
(exploit the new regime) or every ``explore_every`` windows (explore).
"""

from __future__ import annotations

import dataclasses
import json
import threading

from repro.autotune.cost import CostModel
from repro.autotune.profile import WorkloadProfile

PLAN_SCHEMA = "repro.autotune/1"

_KNOB_FIELDS = ("fused_backend", "word_bits", "flush_threshold",
                "flush_memory_bytes", "ref_postponing",
                "cmd_buffer_lookahead", "fuse")


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """The discrete config space the tuner enumerates.

    ``backends=None`` resolves the candidate list from the backend
    registry at tune time (every *available* backend with the
    ``"fused"`` capability); thresholds of ``None`` mean "unbounded".
    """

    backends: tuple | None = None
    layouts: tuple = (32, 64)
    flush_thresholds: tuple = (64, 256, 1024, 4096)
    flush_memory_bytes: tuple = (1 << 30,)
    ref_postponing: tuple = (1, 2, 4, 8)
    cmd_buffer_lookahead: tuple = (2, 8, 32)
    fuse: tuple = (True, False)


@dataclasses.dataclass(frozen=True)
class _Knobs:
    """One candidate point (also the baseline's shape)."""

    fused_backend: str
    word_bits: int
    flush_threshold: int | None
    flush_memory_bytes: int | None
    ref_postponing: int
    cmd_buffer_lookahead: int
    fuse: bool = True


@dataclasses.dataclass(frozen=True)
class TunedPlan:
    """Frozen output of one ``Tuner.tune`` call.

    Carries the winning knobs, the modeled score (and the baseline's,
    for the measured-improvement claim), the profile it was tuned on
    (the drift detector's reference), and JSON/npz persistence —
    ``save("plan.json")`` / ``save("plan.npz")`` round-trip through
    :meth:`load` exactly like ``ReliabilityMap``.
    """

    fused_backend: str
    word_bits: int = 32
    flush_threshold: int | None = 1024
    flush_memory_bytes: int | None = 1 << 30
    ref_postponing: int = 1
    cmd_buffer_lookahead: int = 8
    fuse: bool = True
    score_s: float = 0.0
    baseline_score_s: float = 0.0
    estimate: dict = dataclasses.field(default_factory=dict)
    profile: WorkloadProfile = dataclasses.field(
        default_factory=WorkloadProfile)
    schema: str = PLAN_SCHEMA

    # -- knob views ----------------------------------------------------- #

    def knobs(self) -> dict:
        """The searched knobs alone (no scores/profile)."""
        return {f: getattr(self, f) for f in _KNOB_FIELDS}

    def non_default(self, config) -> dict:
        """Knobs that differ from ``config``'s resolved values — what
        this plan would actually *change*. Empty means the static
        config already wins under the measured profile."""
        base = _Knobs(**_config_knobs(config))
        return {f: getattr(self, f) for f in _KNOB_FIELDS
                if getattr(self, f) != getattr(base, f)}

    def apply(self, config, *, cost_plane: bool = False):
        """``config`` with this plan's execution knobs applied (an
        ``EngineConfig``-shaped object with ``.replace``), including the
        ``fuse`` recommendation — fused and eager are bit-exact and
        stats-identical by construction, so the flip is still an
        execution knob (live devices pin their current ``fuse``; see
        ``Device._apply_plan``). Execution
        knobs never change outputs or ``EngineStats``; with
        ``cost_plane=True`` the REF-postponing recommendation is applied
        too (forcing ``controller="auto"`` when none is configured) —
        that changes the modeled refresh schedule, i.e. EngineStats."""
        changes = dict(fused_backend=self.fused_backend,
                       layout=self.word_bits,
                       flush_threshold=self.flush_threshold,
                       flush_memory_bytes=self.flush_memory_bytes,
                       cmd_buffer_lookahead=self.cmd_buffer_lookahead,
                       fuse=self.fuse)
        if cost_plane and self.ref_postponing != config.ref_postponing:
            changes["ref_postponing"] = self.ref_postponing
            if config.controller is None:
                changes["controller"] = "auto"
        return config.replace(**changes)

    def selection_override(self):
        """Context manager pinning this plan's fused backend in the
        ``repro.backends`` registry (``selection_override``) — the hook
        for callers that reach ``get_pipeline`` without a ``Device``."""
        from repro.backends import selection_override
        return selection_override("fused", self.fused_backend)

    # -- persistence ---------------------------------------------------- #

    def as_dict(self) -> dict:
        d = {f: getattr(self, f) for f in _KNOB_FIELDS}
        d.update(schema=self.schema, score_s=self.score_s,
                 baseline_score_s=self.baseline_score_s,
                 estimate=dict(self.estimate),
                 profile=self.profile.as_dict())
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TunedPlan":
        d = dict(d)
        schema = d.get("schema", PLAN_SCHEMA)
        if schema != PLAN_SCHEMA:
            raise ValueError(f"unsupported plan schema {schema!r} "
                             f"(this build reads {PLAN_SCHEMA!r})")
        d["profile"] = WorkloadProfile.from_dict(d.get("profile", {}))
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    def save(self, path) -> None:
        """Persist as ``.json`` (canonical text) or ``.npz`` (the JSON
        embedded as a uint8 buffer, the ``ReliabilityMap`` idiom)."""
        blob = json.dumps(self.as_dict(), sort_keys=True, indent=2)
        if str(path).endswith(".npz"):
            import numpy as np
            np.savez_compressed(
                path, plan=np.frombuffer(blob.encode(), np.uint8))
        else:
            with open(path, "w") as f:
                f.write(blob + "\n")

    @classmethod
    def load(cls, path) -> "TunedPlan":
        if str(path).endswith(".npz"):
            import numpy as np
            with np.load(path) as z:
                blob = z["plan"].tobytes().decode()
        else:
            with open(path) as f:
                blob = f.read()
        return cls.from_dict(json.loads(blob))

    def __repr__(self) -> str:
        gain = (self.baseline_score_s / self.score_s
                if self.score_s else 1.0)
        return (f"TunedPlan({self.fused_backend!r}, u{self.word_bits}, "
                f"threshold={self.flush_threshold}, "
                f"ref={self.ref_postponing}, "
                f"lookahead={self.cmd_buffer_lookahead}, "
                f"modeled {gain:.2f}x vs static)")


def _config_knobs(config) -> dict:
    """The knob values ``config`` resolves to today (the baseline)."""
    layout = config.resolved_layout()
    name = config.fused_backend
    if name is None:
        from repro.backends import select_backend
        name = select_backend(require="fused", width=config.width,
                              layout=layout).name
    return dict(fused_backend=name, word_bits=layout.word_bits,
                flush_threshold=config.flush_threshold,
                flush_memory_bytes=config.flush_memory_bytes,
                ref_postponing=config.ref_postponing,
                cmd_buffer_lookahead=config.cmd_buffer_lookahead,
                fuse=config.fuse)


class Tuner:
    """Exhaustive deterministic search; see module docstring."""

    def __init__(self, space: SearchSpace | None = None,
                 cost_model: CostModel | None = None,
                 drift_threshold: float = 0.5):
        self.space = space or SearchSpace()
        self.cost_model = cost_model or CostModel()
        self.drift_threshold = drift_threshold

    # -- candidate enumeration ------------------------------------------ #

    def _backend_names(self) -> list[str]:
        if self.space.backends is not None:
            return sorted(self.space.backends)
        from repro.backends import available_backends, get_backend
        return sorted(
            n for n in available_backends("fused")
            if get_backend(n).available())

    def candidates(self, config) -> list[_Knobs]:
        """Every runnable candidate, in a deterministic order that lists
        the config's *current* value first in each dimension — ``tune``
        keeps the first incumbent among equal scores, so a knob only
        changes when some candidate is strictly better along it (no
        score signal, no gratuitous churn). REF postponing is only
        searched when the config already runs the ``"auto"`` controller
        — on the closed-form cost path a postponing change would
        silently mean nothing."""
        from repro.backends import get_backend
        base = _config_knobs(config)

        def order(values, key, sort=lambda v: (v is None, v or 0)):
            return sorted(set(values), key=lambda v: (v != base[key],
                                                      sort(v)))

        sp = self.space
        refs = order(sp.ref_postponing if config.controller == "auto"
                     else (config.ref_postponing,), "ref_postponing")
        thresholds = order(sp.flush_thresholds, "flush_threshold")
        mem = order(sp.flush_memory_bytes, "flush_memory_bytes")
        lookaheads = order(sp.cmd_buffer_lookahead, "cmd_buffer_lookahead")
        layouts = order(sp.layouts, "word_bits")
        backends = order(self._backend_names(), "fused_backend",
                         sort=lambda v: v)
        # Eager (fuse=False) candidates keep a valid backend/layout pair:
        # the plan stays fully applicable if the caller re-enables fusion.
        fuses = order(sp.fuse, "fuse", sort=lambda v: not v)
        out: list[_Knobs] = []
        for fu in fuses:
            for wb in layouts:
                if wb < config.width:
                    continue
                for name in backends:
                    spec = get_backend(name)
                    if "fused" not in spec.capabilities \
                            or spec.max_width < config.width \
                            or wb not in spec.layouts:
                        continue
                    for t in thresholds:
                        for m in mem:
                            for r in refs:
                                for la in lookaheads:
                                    out.append(_Knobs(
                                        fused_backend=name, word_bits=wb,
                                        flush_threshold=t,
                                        flush_memory_bytes=m,
                                        ref_postponing=r,
                                        cmd_buffer_lookahead=la,
                                        fuse=fu))
        return out

    # -- search --------------------------------------------------------- #

    def tune(self, profile: WorkloadProfile, config=None) -> TunedPlan:
        """Score the baseline and every candidate; freeze the winner.

        The baseline is the incumbent: a candidate must beat it (and
        every earlier candidate) *strictly*, so ties keep the static
        config and the sorted enumeration order makes the argmin unique
        — same profile, same plan, any process.
        """
        if config is None:
            from repro.pum.config import EngineConfig
            config = EngineConfig()
        base = _Knobs(**_config_knobs(config))
        best, best_est = base, self.cost_model.estimate(profile, base)
        baseline_s = best_est.total_s
        for cand in self.candidates(config):
            est = self.cost_model.estimate(profile, cand)
            if est.total_s < best_est.total_s * (1.0 - 1e-9):
                best, best_est = cand, est
        return TunedPlan(
            **dataclasses.asdict(best), score_s=best_est.total_s,
            baseline_score_s=baseline_s, estimate=best_est.as_dict(),
            profile=profile)

    def should_retune(self, plan: TunedPlan,
                      profile: WorkloadProfile) -> bool:
        """Has the workload drifted from the profile ``plan`` was tuned
        on far enough to justify a re-tune?"""
        return DriftDetector(plan.profile,
                             threshold=self.drift_threshold).fired(profile)


class DriftDetector:
    """Counter-drift detector: compares a fresh window's profile against
    a baseline profile feature by feature.

    Fraction-valued features compare by absolute difference (they live
    in [0, 1]); magnitude features (lanes, graph depth) compare by
    relative change; the op mix compares by total-variation distance.
    ``drift`` is the max over all of these — ``fired`` when it reaches
    ``threshold`` (default 0.5: a feature moved half its scale).
    """

    _RELATIVE = ("lanes", "ops_per_flush", "leaf_bytes_per_flush")

    def __init__(self, baseline: WorkloadProfile,
                 threshold: float = 0.5):
        self.baseline = baseline
        self.threshold = threshold

    def drift(self, profile: WorkloadProfile) -> float:
        old = self.baseline.scalar_features()
        new = profile.scalar_features()
        worst = 0.0
        for k in sorted(old):
            o, n = old[k], new[k]
            if k in self._RELATIVE:
                d = abs(n - o) / max(abs(o), 1.0)
            else:
                d = abs(n - o)
            worst = max(worst, d)
        ops = set(self.baseline.op_mix) | set(profile.op_mix)
        tv = 0.5 * sum(abs(self.baseline.op_mix.get(op, 0.0)
                           - profile.op_mix.get(op, 0.0))
                       for op in sorted(ops))
        return max(worst, tv)

    def fired(self, profile: WorkloadProfile) -> bool:
        return self.drift(profile) >= self.threshold


class OnlineAutotuner:
    """Explore/exploit re-tuning hung off the engine's per-flush hook.

    Installed by ``Device.autotune(online=True)`` as ``engine.autotuner``;
    the engine calls :meth:`on_flush` at the end of every staged
    dispatch (sync or async worker thread — the hook is reentrancy- and
    thread-guarded). Every ``window_flushes`` flushes it takes a counter
    delta (``CounterBank.delta``), profiles it, and re-tunes when the
    drift detector fires (**exploit** the detected regime change
    immediately) or on every ``explore_every``-th window regardless
    (**explore**: the incumbent plan may have gone stale without any
    single feature drifting past threshold).

    Live application is restricted to what is safe mid-stream: the
    auto-flush bounds and lookahead always apply; the backend/layout
    switch waits for a window where no recorded graphs are pending (a
    layout flip under a half-recorded graph would split one program
    across lane formats).
    """

    def __init__(self, device, tuner: Tuner | None = None,
                 window_flushes: int = 16, explore_every: int = 8,
                 drift_threshold: float = 0.5):
        if window_flushes < 1 or explore_every < 1:
            raise ValueError("window_flushes and explore_every must be "
                             ">= 1")
        self.device = device
        self.tuner = tuner or Tuner(drift_threshold=drift_threshold)
        self.window_flushes = window_flushes
        self.explore_every = explore_every
        self.plan: TunedPlan | None = None
        self.windows = 0
        self.retunes = 0
        self._flushes = 0
        self._mark = device.engine.counters.snapshot()
        self._lock = threading.Lock()
        self._busy = False

    def on_flush(self, engine) -> None:
        """The engine's per-flush decision point. Cheap until a window
        boundary; never raises into the flush path."""
        with self._lock:
            if self._busy:
                return  # a re-tune's own flushes don't recurse
            self._flushes += 1
            if self._flushes < self.window_flushes:
                return
            self._flushes = 0
            delta = engine.counters.delta(self._mark)
            self._mark = engine.counters.snapshot()
            self._busy = True
        try:
            self._window_closed(delta)
        finally:
            self._busy = False

    def _window_closed(self, delta) -> None:
        cfg = self.device.config
        try:
            prof = WorkloadProfile.from_counters(
                delta, width=cfg.width,
                word_bits=cfg.resolved_layout().word_bits)
        except ValueError:
            return  # window carried no recorded ops (tracer detached)
        self.windows += 1
        if self.plan is not None \
                and not self.tuner.should_retune(self.plan, prof) \
                and self.windows % self.explore_every != 0:
            return
        plan = self.tuner.tune(prof, cfg)
        self.retunes += 1
        self.plan = plan
        if plan.non_default(cfg):
            self.device._apply_plan(plan, flush=False)
