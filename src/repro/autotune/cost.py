"""Deterministic cost model scoring candidate configs against a profile.

The same three-term roofline decomposition ``launch/roofline.py`` applies
to the LM dry-runs, re-anchored to the fused PuM pipeline: a **compute**
term (weighted word-ops through the candidate backend's modeled
throughput), a **memory** term (operand traffic through the candidate
tier's bandwidth), and an **overhead** term (per-flush dispatch plus
pipeline-cache compile amortization). A fourth **controller** term prices
the scheduler effects the profile actually measured — refresh lockouts
shrunk by REF postponing, tRRD/tFAW stalls shrunk by crossbar lookahead —
and is zero when the window carried no controller counters.

Everything here is a *model*: the point is deterministic, transitive
ranking of candidates from one measured profile (same profile => same
ranking in any process — the property the tuner's cross-process
determinism test pins), not absolute wall-clock prediction. Constants
derive from the roofline module's TPU-v5e anchors (``PEAK_FLOPS``,
``HBM_BW``) with a fixed host derating, so the two models stay coupled:
retune the roofline anchors and the autotuner moves with them.
"""

from __future__ import annotations

import dataclasses
import math

from repro.launch.roofline import HBM_BW, PEAK_FLOPS

# Host-tier anchors, derived from the roofline chip constants with fixed
# deratings (a host core sustains ~1/16 of HBM bandwidth and a far
# smaller fraction of MXU peak on scalar word ops).
HOST_BW = HBM_BW / 16.0            # bytes/s — host DRAM stream
HOST_WORD_RATE = PEAK_FLOPS / 1e5  # word-ops/s — scalar/SIMD host lanes

# Modeled relative throughput of each fused backend (word-rate and
# bandwidth multipliers over the host anchors). ``ref-vertical`` is the
# per-plane jnp oracle — priced so it can never win (it exists to
# validate the others, mirroring its priority=-10 registration).
BACKEND_SPEED = {
    "words-cpu": (1.0, 1.0),
    # words-cpu-64 runs the jitted uint32-pair evaluator (carry chained
    # across lane pairs) — same host anchors as the 32-bit word path.
    "words-cpu-64": (1.0, 1.0),
    "shard-words": (1.6, 1.6),
    "pallas-tpu": (8.0, 16.0),
    "pallas-tpu-64": (8.0, 16.0),
    "ref-vertical": (0.05, 1.0),
    "ref-vertical-64": (0.05, 1.0),
}
DEFAULT_SPEED = (0.5, 1.0)         # unknown registered backends

# Fixed per-event costs (seconds): one staged dispatch, one jit trace,
# one eager per-op dispatch (backend call + cost-plane charge — what a
# fuse=False candidate pays instead of flush overhead and jit traces).
FLUSH_OVERHEAD_S = 50e-6
COMPILE_S = 30e-3
EAGER_DISPATCH_S = 50e-6

# Word-domain cost weights per fused opcode (multiples of one plane op
# per lane; ``width``-dependent opcodes scale in :func:`_op_weight`).
OP_WEIGHT = {
    "and": 1.0, "or": 1.0, "xor": 1.0, "not": 1.0,
    "add": 1.5, "sub": 1.5, "less_than": 2.0,
    "popcount": 2.0, "reduce_bits": 2.0,
    "fst": 0.0, "snd": 0.0,   # tuple selectors: free at dispatch
}


def _op_weight(opcode: str, width: int) -> float:
    if opcode == "mul":
        return max(2.0, width / 4.0)
    if opcode in ("div", "mod", "divmod"):
        return float(max(4, width))
    return OP_WEIGHT.get(opcode, 1.5)


@dataclasses.dataclass(frozen=True)
class Estimate:
    """Scored candidate: the four modeled terms plus their sum (seconds
    per measured window — only comparisons between candidates scored
    against the SAME profile are meaningful)."""

    compute_s: float
    memory_s: float
    overhead_s: float
    controller_s: float

    @property
    def total_s(self) -> float:
        return (self.compute_s + self.memory_s + self.overhead_s
                + self.controller_s)

    def as_dict(self) -> dict:
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "overhead_s": self.overhead_s,
                "controller_s": self.controller_s,
                "total_s": self.total_s}


class CostModel:
    """Scores ``(profile, candidate-knobs)`` pairs deterministically.

    ``estimate`` accepts any object with the candidate knob attributes
    (``fused_backend``, ``word_bits``, ``flush_threshold``,
    ``flush_memory_bytes``, ``ref_postponing``, ``cmd_buffer_lookahead``,
    and optionally ``fuse`` — absent means fused) — both the tuner's
    internal candidates and a frozen :class:`~repro.autotune.TunedPlan`
    qualify. ``fuse=False`` candidates are priced as the eager per-op
    path: no jit traces and no leaf staging, but ``eager_dispatch_s``
    per recorded op — the term that lets a window whose measured
    ``leaf_bytes_per_flush`` dominates (memory-bound raw AND chains over
    fresh bitmaps) flip the recommendation off the fused pipeline.
    """

    def __init__(self, *, speed=None, flush_overhead_s: float =
                 FLUSH_OVERHEAD_S, compile_s: float = COMPILE_S,
                 eager_dispatch_s: float = EAGER_DISPATCH_S):
        self.speed = dict(BACKEND_SPEED if speed is None else speed)
        self.flush_overhead_s = flush_overhead_s
        self.compile_s = compile_s
        self.eager_dispatch_s = eager_dispatch_s

    # -- candidate-adjusted workload geometry --------------------------- #

    def _lanes(self, profile, word_bits: int) -> float:
        """Mean lanes per flush under the candidate layout: raw-mode ops
        split each caller uint64 into ``64 / word_bits`` lanes, so the
        raw share of the measured lane count rescales by the ratio of
        candidate to measured raw splits; value-mode lanes are one per
        element regardless of layout."""
        raw = profile.raw_fraction
        if raw <= 0 or profile.word_bits == word_bits:
            return profile.lanes
        scale = (64.0 / word_bits) / (64.0 / profile.word_bits)
        return profile.lanes * ((1.0 - raw) + raw * scale)

    def _flush_geometry(self, profile, knobs,
                        lanes: float) -> tuple[float, int]:
        """``(depth, n_flushes)`` of the window under the candidate's
        auto-flush bounds. When the measured window was dominated by
        threshold-forced flushes (``autoflush_ops_fraction >= 0.5``) the
        *natural* program is longer than any one measured graph — the
        whole window is treated as one logical program that candidate
        thresholds re-chunk, so a larger ``flush_threshold`` genuinely
        merges flushes (and a smaller one splits them)."""
        depth = max(1.0, profile.ops_per_flush)
        flushes = max(1, profile.flushes)
        window_ops = depth * flushes
        natural = (window_ops
                   if profile.autoflush_ops_fraction >= 0.5 else depth)
        cap = float(natural)
        if knobs.flush_threshold is not None:
            cap = min(cap, float(knobs.flush_threshold))
        if knobs.flush_memory_bytes is not None:
            per_op_bytes = 2.0 * lanes * (knobs.word_bits // 8)
            if per_op_bytes > 0:
                cap = min(cap, knobs.flush_memory_bytes / per_op_bytes)
        cap = max(1.0, cap)
        return cap, math.ceil(window_ops / cap)

    # -- scoring -------------------------------------------------------- #

    def estimate(self, profile, knobs) -> Estimate:
        """Modeled seconds for one measured window re-run under
        ``knobs`` (see class docstring for the knob attributes)."""
        word_rate, bw = self.speed.get(knobs.fused_backend, DEFAULT_SPEED)
        lanes = self._lanes(profile, knobs.word_bits)
        depth = max(1.0, profile.ops_per_flush)
        flushes = max(1, profile.flushes)
        weight = sum(frac * _op_weight(op, profile.width)
                     for op, frac in sorted(profile.op_mix.items())) or 1.0

        # Compute: weighted word-ops through the backend's lane rate.
        word_ops = lanes * depth * weight * flushes
        compute_s = word_ops / (HOST_WORD_RATE * word_rate)

        # Memory: ~3 operand streams per op through the backend's tier.
        byte_traffic = 3.0 * lanes * (knobs.word_bits // 8) \
            * depth * flushes
        memory_s = byte_traffic / (HOST_BW * bw)

        if getattr(knobs, "fuse", True):
            # Leaf staging: the snapshot/upload bytes the flush path
            # actually measured (net of leaf-cache hits and elided
            # snapshots), re-paid per flush through host DRAM. Folded
            # into the memory term — it is data movement, and it is the
            # cost eager execution never pays (operands stream in place,
            # un-snapshotted).
            memory_s += profile.leaf_bytes_per_flush * flushes / HOST_BW

            # Overhead: staged dispatches (candidate thresholds re-chunk
            # the window, see _flush_geometry) plus compile amortization.
            # A candidate whose chunking differs from the measured
            # structure pays at least one fresh jit trace over the window.
            depth_c, n_flushes = self._flush_geometry(profile, knobs,
                                                      lanes)
            miss_rate = 1.0 - profile.cache_hit_rate
            if abs(depth_c - depth) > 0.5:
                miss_rate = max(miss_rate, 1.0 / n_flushes)
            overhead_s = n_flushes * self.flush_overhead_s \
                + miss_rate * n_flushes * self.compile_s
        else:
            # Eager (fuse=False): the host word dataplane at the base
            # anchors — no fused backend, no jit traces, no leaf
            # snapshots — but one dispatch per recorded op.
            compute_s = word_ops / HOST_WORD_RATE
            memory_s = byte_traffic / HOST_BW
            overhead_s = depth * flushes * self.eager_dispatch_s

        # Controller: measured refresh/stall shares of the dataplane
        # time, shrunk by the candidate's REF postponing (longer, rarer
        # lockouts amortize per-REF overhead) and command lookahead
        # (deeper reordering hides tRRD/tFAW spacing).
        base = compute_s + memory_s
        refresh_s = base * profile.refresh_fraction \
            * (0.85 + 0.15 / knobs.ref_postponing)
        stall_frac = (profile.stall_trrd_fraction
                      + profile.stall_tfaw_fraction)
        stall_s = base * stall_frac \
            / (1.0 + math.log2(max(1, knobs.cmd_buffer_lookahead)) / 6.0)
        return Estimate(compute_s=compute_s, memory_s=memory_s,
                        overhead_s=overhead_s,
                        controller_s=refresh_s + stall_s)
