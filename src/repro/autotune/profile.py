"""WorkloadProfile — what the telemetry counters say a workload *is*.

The autotuner never looks at raw counter dumps: it looks at a
:class:`WorkloadProfile`, a small frozen summary extracted from a
:class:`~repro.telemetry.CounterBank` window (typically a
``CounterBank.delta`` between two ``snapshot()`` calls, or the bank a
``pum.profile()`` block populated). The profile normalizes everything to
rates and fractions so two windows of different lengths describe the
same workload identically — that is what makes the drift detector and
the cross-process determinism guarantee possible.

Engine counters feeding the profile (written while a tracer is
attached — see ``docs/observability.md``): ``engine.ops_recorded`` /
``engine.op.<opcode>`` / ``engine.raw_ops`` for the op mix,
``engine.flushes`` + the ``engine.flush_lanes`` histogram for graph
depth and lane count, ``engine.pipeline_cache.{hit,miss}`` for compile
amortization, ``engine.autoflush.{ops,memory}`` for threshold pressure,
``engine.leaf_bytes_staged`` + ``engine.leaf_cache.{hits,misses}`` for
flush-path data movement (what leaf snapshots actually cost — the term
that lets the cost model price fused staging against eager streaming).
Controller counters (``derive_controller_counters`` replays of the
scheduler audit trail) contribute the bus-utilization / stall-split /
row-conflict / refresh features when present; they default to zero when
the window carried no scheduled command trace.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json


def _counters_mapping(counters) -> tuple[dict, dict]:
    """Normalize a CounterBank / as_dict() payload / plain mapping to
    ``(counters, histograms)`` dicts."""
    if hasattr(counters, "as_dict"):
        d = counters.as_dict()
        return d["counters"], d["histograms"]
    if isinstance(counters, dict) and "counters" in counters:
        return dict(counters["counters"]), dict(counters.get(
            "histograms", {}))
    return dict(counters), {}


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    """Frozen, JSON-round-trippable summary of one measured window.

    All features are window-length independent (fractions, means,
    ratios); ``ops`` and ``flushes`` carry the absolute window size for
    confidence weighting. ``width``/``word_bits`` record the device
    configuration the window was measured under — the cost model needs
    them to rescale lane counts for candidate layouts.
    """

    ops: int = 0                    # dataplane ops recorded in the window
    flushes: int = 0                # fused flushes dispatched
    ops_per_flush: float = 0.0      # mean graph depth at dispatch
    lanes: float = 0.0              # mean dataplane lanes per flush
    op_mix: dict = dataclasses.field(default_factory=dict)
    raw_fraction: float = 0.0       # share of ops on the raw bitmap path
    cache_hit_rate: float = 0.0     # pipeline-cache hits / flushes
    autoflush_ops_fraction: float = 0.0     # flushes forced by op count
    autoflush_memory_fraction: float = 0.0  # flushes forced by memory est
    leaf_bytes_per_flush: float = 0.0  # staged leaf-snapshot bytes / flush
    leaf_cache_hit_rate: float = 0.0   # leaf-cache hits / lookups
    bus_utilization: float = 0.0    # cmd-bus busy / wall (controller)
    stall_trrd_fraction: float = 0.0   # tRRD stall / wall
    stall_tfaw_fraction: float = 0.0   # tFAW stall / wall
    row_conflict_ratio: float = 0.0    # conflicts / column commands
    refresh_fraction: float = 0.0      # refresh stall / wall
    width: int = 32                 # device element width measured under
    word_bits: int = 32             # plane-layout word bits measured under

    @classmethod
    def from_counters(cls, counters, *, width: int = 32,
                      word_bits: int = 32) -> "WorkloadProfile":
        """Extract a profile from a counter window.

        ``counters`` is a :class:`~repro.telemetry.CounterBank` (e.g.
        ``Device.counters``, or a ``delta`` between two snapshots), its
        ``as_dict()`` payload, or a plain ``{name: value}`` mapping.
        Raises ``ValueError`` when the window recorded no dataplane ops —
        engine counters populate only while a tracer is attached, so an
        empty window almost always means the workload ran outside
        ``pum.profile()``.
        """
        c, hists = _counters_mapping(counters)
        ops = int(c.get("engine.ops_recorded", 0))
        if ops <= 0:
            raise ValueError(
                "counter window records no dataplane ops "
                "(engine.ops_recorded == 0); run the workload under "
                "pum.profile(dev) (engine counters populate only while "
                "a tracer is attached) or pass an explicit profile")
        flushes = int(c.get("engine.flushes", 0))
        mix = {k[len("engine.op."):]: v / ops
               for k, v in sorted(c.items())
               if k.startswith("engine.op.")}
        lanes_h = hists.get("engine.flush_lanes")
        lanes = (lanes_h["total"] / lanes_h["count"]
                 if lanes_h and lanes_h["count"] else 0.0)
        hits = c.get("engine.pipeline_cache.hit", 0)
        misses = c.get("engine.pipeline_cache.miss", 0)
        lhits = c.get("engine.leaf_cache.hits", 0)
        lmisses = c.get("engine.leaf_cache.misses", 0)
        wall = c.get("wall_ns", 0.0)
        cols = (c.get("row.hit", 0) + c.get("row.miss", 0)
                + c.get("row.conflict", 0))
        return cls(
            ops=ops,
            flushes=flushes,
            ops_per_flush=ops / flushes if flushes else float(ops),
            lanes=lanes,
            op_mix=mix,
            raw_fraction=c.get("engine.raw_ops", 0) / ops,
            cache_hit_rate=(hits / (hits + misses)
                            if hits + misses else 0.0),
            autoflush_ops_fraction=(c.get("engine.autoflush.ops", 0)
                                    / flushes if flushes else 0.0),
            autoflush_memory_fraction=(c.get("engine.autoflush.memory", 0)
                                       / flushes if flushes else 0.0),
            leaf_bytes_per_flush=(c.get("engine.leaf_bytes_staged", 0)
                                  / flushes if flushes else 0.0),
            leaf_cache_hit_rate=(lhits / (lhits + lmisses)
                                 if lhits + lmisses else 0.0),
            bus_utilization=c.get("cmd_bus_utilization", 0.0),
            stall_trrd_fraction=(c.get("stall.trrd_ns", 0.0) / wall
                                 if wall else 0.0),
            stall_tfaw_fraction=(c.get("stall.tfaw_ns", 0.0) / wall
                                 if wall else 0.0),
            row_conflict_ratio=(c.get("row.conflict", 0) / cols
                                if cols else 0.0),
            refresh_fraction=(c.get("refresh.stall_ns", 0.0) / wall
                              if wall else 0.0),
            width=int(width),
            word_bits=int(word_bits),
        )

    @classmethod
    def from_device(cls, dev) -> "WorkloadProfile":
        """Profile from a device's accumulated counters (everything since
        construction / the last ``Device.reset_counters()``)."""
        cfg = dev.config
        return cls.from_counters(dev.counters, width=cfg.width,
                                 word_bits=cfg.resolved_layout().word_bits)

    # -- serialization / identity --------------------------------------- #

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["op_mix"] = dict(sorted(self.op_mix.items()))
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "WorkloadProfile":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    def fingerprint(self) -> str:
        """Stable content hash (sha256 of the canonical JSON): same
        profile => same fingerprint in any process."""
        blob = json.dumps(self.as_dict(), sort_keys=True,
                          separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def scalar_features(self) -> dict:
        """The scalar feature vector the drift detector compares (op_mix
        is handled separately as a distribution distance)."""
        return {
            "ops_per_flush": self.ops_per_flush,
            "lanes": self.lanes,
            "raw_fraction": self.raw_fraction,
            "cache_hit_rate": self.cache_hit_rate,
            "autoflush_ops_fraction": self.autoflush_ops_fraction,
            "autoflush_memory_fraction": self.autoflush_memory_fraction,
            "leaf_bytes_per_flush": self.leaf_bytes_per_flush,
            "leaf_cache_hit_rate": self.leaf_cache_hit_rate,
            "bus_utilization": self.bus_utilization,
            "stall_trrd_fraction": self.stall_trrd_fraction,
            "stall_tfaw_fraction": self.stall_tfaw_fraction,
            "row_conflict_ratio": self.row_conflict_ratio,
            "refresh_fraction": self.refresh_fraction,
        }

    def __repr__(self) -> str:
        top = sorted(self.op_mix.items(), key=lambda kv: -kv[1])[:3]
        mix = "+".join(f"{k}:{v:.0%}" for k, v in top)
        return (f"WorkloadProfile(ops={self.ops}, flushes={self.flushes}, "
                f"depth={self.ops_per_flush:.1f}, lanes={self.lanes:.0f}, "
                f"raw={self.raw_fraction:.0%}, mix={mix})")
