"""Config system: model / parallelism / training configs + arch registry."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # --- attention ---
    attn_kind: str = "gqa"           # gqa | mla | none
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0          # 0 -> full attention
    global_attn_layers: tuple[int, ...] = ()   # full-attn layers under SWA
    # --- MLA (deepseek-v2) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0      # leading dense layers (deepseek-style)
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / hybrid) ---
    ssm: bool = False
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    hybrid_parallel: bool = False    # Hymba: parallel attn + ssm heads
    # --- encoder-decoder ---
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    # --- modality frontend stubs ---
    frontend: str = "none"           # none | audio | vision
    n_frontend_tokens: int = 0       # e.g. 2880 anyres patch tokens (llava)
    # --- misc ---
    norm: str = "rmsnorm"
    act: str = "silu"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "full"              # none | full
    vocab_pad_multiple: int = 2048
    # Small-model serving: replicated weights + sequence-parallel
    # activations on the model axis (set by launch/steps.py; §Perf H1.2).
    serve_seq_parallel: bool = False
    # MLA decode: absorbed-matrix form (True) vs per-step decompression
    # (False — the naive baseline; §Perf H3).
    mla_absorbed_decode: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab_size + m - 1) // m * m

    @property
    def attn_free(self) -> bool:
        return self.attn_kind == "none"

    def param_count(self) -> int:
        """Total parameters (exact to the construction in model.py)."""
        from repro.models.model import count_params  # local import, no cycle
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params
        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the assigned grid."""
    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int


LM_SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 10000
    grad_clip: float = 1.0
    z_loss: float = 1e-4
    moe_aux_loss: float = 1e-2
    microbatches: int = 1            # gradient accumulation
    grad_compression: bool = False   # int8 + error feedback on DP all-reduce
    checkpoint_every: int = 100
    seed: int = 0


ARCH_IDS = (
    "hymba-1.5b", "qwen1.5-0.5b", "qwen3-1.7b", "qwen2.5-32b",
    "phi3-medium-14b", "seamless-m4t-large-v2", "llava-next-mistral-7b",
    "moonshot-v1-16b-a3b", "deepseek-v2-236b", "mamba2-130m",
)

_MODULES = {
    "hymba-1.5b": "hymba_1p5b",
    "qwen1.5-0.5b": "qwen1p5_0p5b",
    "qwen3-1.7b": "qwen3_1p7b",
    "qwen2.5-32b": "qwen2p5_32b",
    "phi3-medium-14b": "phi3_medium_14b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "mamba2-130m": "mamba2_130m",
}


def get_config(arch: str) -> ModelConfig:
    """``--arch <id>`` entry point."""
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE_CONFIG


def shapes_for(arch: str) -> dict[str, ShapeConfig]:
    """The shape cells assigned to an arch, with documented skips."""
    cfg = get_config(arch)
    shapes = dict(LM_SHAPES)
    # long_500k only for sub-quadratic archs (SSM/hybrid) — see DESIGN.md.
    if cfg.family not in ("ssm", "hybrid"):
        shapes.pop("long_500k")
    return shapes
