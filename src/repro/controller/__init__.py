"""Bank-parallel PuM memory controller (paper §7: multi-bank parallelism).

Discrete-event analogue of a LiteDRAM/gram-style controller:

  * :class:`~repro.controller.bank_machine.BankMachine` — per-bank FSM with
    open-row tracking, an open/closed-page precharge policy, and a queue of
    PuM command programs (violated-timing sequences are atomic units).
  * :class:`~repro.controller.multiplexer.CommandMultiplexer` — round-robin +
    refresh-priority arbiter for the shared command bus, enforcing the
    rank-wide constraints (tFAW, tRRD, tCCD, one command per tCK).
  * :class:`~repro.controller.refresher.Refresher` — tREFI/tRFC REF injection
    that stalls new PuM sequences while letting in-flight ones drain.
  * :class:`~repro.controller.controller.MemoryController` — the facade:
    accepts ``Cmd`` programs tagged with target banks and returns a
    cycle-accounted, ``ScheduleResult``-compatible trace.
  * :class:`~repro.controller.crossbar.Crossbar` — N client ports feeding
    the bank machines through a lookahead feeder with per-bank round-robin
    grants (LiteDRAM crossbar analogue); ``CrossbarTrace`` attributes every
    issued command back to its port for post-hoc fairness audits.
"""

from repro.controller.bank_machine import BankMachine, BankState
from repro.controller.controller import (BankBatchCost, ControllerTrace,
                                         MemoryController, retarget_program)
from repro.controller.crossbar import ClientPort, Crossbar, CrossbarTrace
from repro.controller.multiplexer import CommandMultiplexer
from repro.controller.refresher import Refresher

__all__ = [
    "BankMachine", "BankState", "CommandMultiplexer", "Refresher",
    "MemoryController", "ControllerTrace", "BankBatchCost",
    "retarget_program", "Crossbar", "ClientPort", "CrossbarTrace",
]
