"""Shared command-bus arbiter (LiteDRAM/gram ``Multiplexer`` analogue).

Event-driven in continuous ns time: at each step the multiplexer computes,
for every bank machine's head command, the earliest legal issue time under

  * the bank's own ``min_gap`` sequencing (BankMachine),
  * tRRD between ACTs rank-wide (same constraint the sequential
    ``CommandScheduler`` applies, so single-bank schedules match exactly),
  * tFAW — at most 4 ACTs per rolling window,
  * tCCD_S between column (RD/WR) commands on the shared data bus,
  * command-bus occupancy — one (non-NOP) command per tCK,

then issues the earliest candidate, breaking ties round-robin.  When the
refresher is due, banks finish their in-flight sequence but may not start a
new one; once all pending heads sit at sequence boundaries the refresher
gets the rank for tRP + tRFC and every bank's open row is closed.
"""

from __future__ import annotations

import dataclasses
from collections import deque

from repro.controller.bank_machine import BankMachine
from repro.controller.refresher import Refresher
from repro.core.commands import Cmd, Op
from repro.core.timing import DramTimings

_EPS = 1e-9


@dataclasses.dataclass
class MuxResult:
    events: list[tuple[Cmd, float]]
    n_acts: int
    n_pres: int
    n_rdwr: int
    energy_j: float
    refresh_windows: list[tuple[float, float]]
    n_refreshes: int
    refresh_stall_ns: float
    per_bank_last: dict[int, float]
    # Timing set the arbitration ran under (for ``.counters()`` derivation).
    timings: DramTimings | None = None
    # Parallel to ``events``: (bank, seq_id) of the sequence each command
    # belongs to, so a crossbar can attribute issued commands back to the
    # client port that submitted the sequence (pure audit metadata — the
    # arbitration itself never reads it).
    seqs: list[tuple[int, int]] | None = None

    @property
    def total_ns(self) -> float:
        return self.events[-1][1] if self.events else 0.0

    def counters(self, timings: DramTimings | None = None):
        """Derive a :class:`repro.telemetry.CounterBank` from this trace
        (commands per type, bus utilization, row hit/miss/conflict,
        tRRD/tFAW stall time, refresh lockout). Pure post-hoc replay of
        ``events`` — the arbitration itself stays byte-identical."""
        from repro.telemetry import derive_controller_counters
        return derive_controller_counters(self, timings)


class CommandMultiplexer:
    def __init__(self, timings: DramTimings, machines: list[BankMachine],
                 refresher: Refresher | None = None, feeder=None):
        self.t = timings
        self.machines = machines
        self.refresher = refresher
        # Optional refill hook called at the top of every arbitration step
        # (before the bank-machine scan).  A crossbar uses it to top the
        # per-bank queues up to its lookahead depth from the client ports;
        # with ``feeder=None`` the loop below is byte-identical to the
        # pre-crossbar multiplexer.
        self.feeder = feeder

    # ------------------------------------------------------------------ #

    def _rank_constraints(self, when: float, cmd: Cmd, last_act: float,
                          faw: deque, last_col: float,
                          last_bus: float) -> float:
        t = self.t
        if cmd.op is Op.ACT:
            when = max(when, last_act + t.trrd_s)
            # Rolling four-activation window — same rule as the sequential
            # CommandScheduler (the deque never exceeds 4 entries).
            if len(faw) >= 4 and when - faw[0] < t.tfaw:
                when = faw[0] + t.tfaw
        elif cmd.op in (Op.RD, Op.WR):
            when = max(when, last_col + t.tccd_s)
        if cmd.op is not Op.NOP:
            when = max(when, last_bus + t.tck)
        return when

    def run(self) -> MuxResult:
        t = self.t
        ref = self.refresher
        events: list[tuple[Cmd, float]] = []
        n_acts = n_pres = n_rdwr = 0
        energy = 0.0
        last_act = -1e30
        last_col = -1e30
        last_bus = -1e30
        faw: deque[float] = deque()
        rr = 0
        nb = len(self.machines)
        refresh_stall = 0.0
        seqs: list[tuple[int, int]] = []

        while True:
            if self.feeder is not None:
                self.feeder()
            if not any(len(bm) for bm in self.machines):
                break
            best_idx = -1
            best_time = float("inf")
            blocked = False
            for off in range(nb):
                idx = (rr + off) % nb
                bm = self.machines[idx]
                q = bm.head()
                if q is None:
                    continue
                when = self._rank_constraints(bm.earliest_issue(), q.cmd,
                                              last_act, faw, last_col,
                                              last_bus)
                if ref is not None and q.seq_start and ref.blocks(when):
                    blocked = True
                    continue
                if when < best_time - _EPS:
                    best_time, best_idx = when, idx
            if best_idx < 0:
                # Every pending bank sits at a sequence boundary past the
                # refresh deadline: grant the rank to the refresher.
                assert blocked and ref is not None
                idle = max((bm.last_issue or 0.0) for bm in self.machines)
                start = max(ref.next_due, idle, last_bus + t.tck)
                end = ref.execute(start)
                for bm in self.machines:
                    bm.note_refresh(end)
                last_bus = start
                energy += t.e_ref * ref.postponing
                refresh_stall += end - start
                continue

            bm = self.machines[best_idx]
            q = bm.issue(best_time)
            cmd = q.cmd
            events.append((cmd, best_time))
            seqs.append((bm.bank, q.seq_id))
            if cmd.op is Op.ACT:
                if len(faw) >= 4:
                    faw.popleft()
                faw.append(best_time)
                last_act = best_time
                n_acts += 1
                energy += t.e_act
            elif cmd.op is Op.PRE:
                n_pres += 1
                energy += t.e_pre
            elif cmd.op in (Op.RD, Op.WR):
                last_col = best_time
                n_rdwr += 1
                energy += t.e_rdwr_burst
            if cmd.op is not Op.NOP:
                last_bus = best_time
            rr = (best_idx + 1) % nb

        per_bank = {bm.bank: bm.last_issue for bm in self.machines
                    if bm.last_issue is not None}
        return MuxResult(events=events, n_acts=n_acts, n_pres=n_pres,
                         n_rdwr=n_rdwr, energy_j=energy,
                         refresh_windows=list(ref.windows) if ref else [],
                         n_refreshes=ref.n_refreshes if ref else 0,
                         refresh_stall_ns=refresh_stall,
                         per_bank_last=per_bank, timings=t, seqs=seqs)
