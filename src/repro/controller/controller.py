"""``MemoryController`` — the bank-parallel scheduling facade.

Accepts the existing ``Cmd`` programs (each targeting one bank) and returns a
cycle-accounted :class:`ControllerTrace`, a drop-in ``ScheduleResult`` with
the refresh/bank accounting on top.  A single-bank program schedules to the
exact same issue times as the sequential ``CommandScheduler`` (equivalence is
tested); multi-bank program sets overlap under tFAW/tRRD/tCCD and yield to
REF every tREFI.

:meth:`MemoryController.batch_cost` is the cost-plane entry point: it prices
one *unit* program list replicated across N banks, both as a raw makespan
(bank-parallel speedup, tFAW/tRRD-limited) and amortized over a ≥2·tREFI
steady-state window (refresh interference factor).  The engine uses these
measured factors instead of the old closed-form ``ceil(rows/banks)`` divide.
"""

from __future__ import annotations

import dataclasses
import math

from repro.controller.bank_machine import BankMachine
from repro.controller.multiplexer import CommandMultiplexer
from repro.controller.refresher import Refresher
from repro.core.commands import Cmd, ScheduleResult
from repro.core.timing import DDR4_2400, DramTimings


def retarget_program(prog, bank: int) -> list[Cmd]:
    """Copy of ``prog`` with every command redirected to ``bank``."""
    return [dataclasses.replace(c, bank=bank) if c.bank != bank else c
            for c in prog]


@dataclasses.dataclass
class ControllerTrace(ScheduleResult):
    """ScheduleResult + the controller's refresh/bank accounting."""
    n_refreshes: int = 0
    refresh_stall_ns: float = 0.0
    refresh_windows: list = dataclasses.field(default_factory=list)
    per_bank_ns: dict = dataclasses.field(default_factory=dict)
    # The timing set the schedule ran under, so ``.counters()`` derives
    # bus-utilization/stall numbers against the right clock.
    timings: DramTimings | None = None


@dataclasses.dataclass(frozen=True)
class BankBatchCost:
    """Measured cost of one unit program replicated across ``banks`` banks."""
    banks: int
    unit_ns: float         # unit scheduled alone on one bank
    makespan_ns: float     # banks concurrent copies, refresh off
    amortized_ns: float    # per batch over a >=2*tREFI window, refresh on
    n_refreshes: int
    refresh_stall_ns: float

    @property
    def parallel_speedup(self) -> float:
        """Effective bank parallelism in [1, banks] (tFAW/tRRD-limited)."""
        if self.makespan_ns <= 0:
            return float(self.banks)
        return self.banks * self.unit_ns / self.makespan_ns

    @property
    def refresh_factor(self) -> float:
        """Steady-state slowdown >= 1 from periodic REF lockouts."""
        if self.makespan_ns <= 0:
            return 1.0
        return max(1.0, self.amortized_ns / self.makespan_ns)


class MemoryController:
    """Bank machines + multiplexer + refresher behind one ``schedule`` call.

    Stateless across calls: every ``schedule`` builds fresh bank machines,
    so the controller can be shared by cost model, engine, and benchmarks.
    """

    def __init__(self, timings: DramTimings = DDR4_2400, n_banks: int = 16,
                 refresh: bool = True, trefi: float | None = None,
                 trfc: float | None = None, postponing: int = 1,
                 open_page: bool = True, lookahead: int = 8):
        self.t = timings
        self.n_banks = n_banks
        self.refresh = refresh
        self.trefi = timings.trefi if trefi is None else trefi
        self.trfc = timings.trfc if trfc is None else trfc
        self.postponing = postponing
        self.open_page = open_page
        # Crossbar command-buffer depth (LiteDRAM cmd_buffer_depth):
        # the default per-bank lookahead schedule_concurrent runs with.
        # Never consulted by the single-stream schedule/batch_cost paths,
        # so it is a pure execution knob (EngineConfig/the autotuner set
        # it as cmd_buffer_lookahead).
        if lookahead < 1:
            raise ValueError(f"lookahead must be >= 1, got {lookahead}")
        self.lookahead = lookahead
        self._batch_cache: dict[tuple, BankBatchCost] = {}

    # ------------------------------------------------------------------ #

    def _machines(self) -> list[BankMachine]:
        return [BankMachine(b, self.t, self.open_page)
                for b in range(self.n_banks)]

    def _refresher(self, enabled: bool) -> Refresher:
        return Refresher(self.t, trefi=self.trefi, trfc=self.trfc,
                         postponing=self.postponing,
                         enabled=enabled and self.refresh)

    @staticmethod
    def _as_programs(programs) -> list[list[Cmd]]:
        if programs and isinstance(programs[0], Cmd):
            return [list(programs)]
        return [list(p) for p in programs]

    def schedule(self, programs, refresh: bool | None = None
                 ) -> ControllerTrace:
        """Schedule one program (flat ``list[Cmd]``) or many programs.

        Each program must target a single bank (its commands' ``bank``
        field); programs for different banks overlap on the command bus.
        """
        progs = self._as_programs(programs)
        machines = self._machines()
        by_id = {bm.bank: bm for bm in machines}
        for prog in progs:
            if not prog:
                continue
            banks = {c.bank for c in prog}
            if len(banks) != 1:
                raise ValueError(
                    f"program spans banks {sorted(banks)}; submit one "
                    f"program per bank")
            bank = prog[0].bank
            if bank not in by_id:
                raise ValueError(f"bank {bank} out of range "
                                 f"(controller has {self.n_banks})")
            by_id[bank].enqueue_program(prog)
        mux = CommandMultiplexer(self.t, machines, self._refresher(
            True if refresh is None else refresh))
        r = mux.run()
        return ControllerTrace(
            total_ns=r.total_ns, energy_j=r.energy_j, n_acts=r.n_acts,
            n_pres=r.n_pres, n_rdwr=r.n_rdwr,
            issue_times=[t for _, t in r.events],
            cmds=[c for c, _ in r.events],
            n_refreshes=r.n_refreshes, refresh_stall_ns=r.refresh_stall_ns,
            refresh_windows=r.refresh_windows, per_bank_ns=r.per_bank_last,
            timings=self.t)

    def schedule_batch(self, unit_programs, banks: int,
                       n_batches: int = 1, refresh: bool | None = None,
                       bank_order: tuple[int, ...] | None = None
                       ) -> ControllerTrace:
        """``n_batches`` copies of the unit program list on each of
        ``banks`` banks (unit programs run back-to-back per bank).

        ``bank_order`` names the physical banks to use and their visit
        order (default: banks 0..banks-1 in index order) — the reliability
        plane passes a calibration-ranked order so batches prefer strong
        banks."""
        if bank_order is None:
            targets = range(banks)
        else:
            targets = list(bank_order)[:banks]
            bad = [b for b in targets if not 0 <= b < self.n_banks]
            if bad or len(set(targets)) != len(targets):
                raise ValueError(
                    f"bank_order must be distinct bank ids < "
                    f"{self.n_banks}, got {list(bank_order)!r}")
        progs = []
        for b in targets:
            for _ in range(n_batches):
                for prog in self._as_programs(unit_programs):
                    progs.append(retarget_program(prog, b))
        return self.schedule(progs, refresh=refresh)

    def schedule_concurrent(self, streams, lookahead: int | None = None,
                            auto_precharge: bool = False,
                            refresh: bool | None = None):
        """Schedule N concurrent client streams through the crossbar.

        ``streams`` is a list of per-client program lists (each program a
        single-bank ``list[Cmd]``, same contract as :meth:`schedule`).
        One :class:`~repro.controller.crossbar.ClientPort` is opened per
        stream; ports contending for a bank are granted round-robin with
        at most ``lookahead`` pending sequences per bank machine (default:
        the controller's own ``lookahead``).  Returns
        a :class:`~repro.controller.crossbar.CrossbarTrace` whose
        ``port_of`` attributes every issued command to its client.

        With a single stream this is byte-for-byte :meth:`schedule`
        (pinned by the golden-trace tests)."""
        from repro.controller.crossbar import Crossbar
        if lookahead is None:
            lookahead = self.lookahead
        xbar = Crossbar(timings=self.t, n_banks=self.n_banks,
                        n_ports=max(1, len(streams)), lookahead=lookahead,
                        auto_precharge=auto_precharge, refresh=self.refresh,
                        trefi=self.trefi, trfc=self.trfc,
                        postponing=self.postponing,
                        open_page=self.open_page)
        for i, progs in enumerate(streams):
            xbar.port(i).submit(progs)
        return xbar.run(refresh=refresh)

    # ------------------------------------------------------------------ #
    # Cost-plane entry point
    # ------------------------------------------------------------------ #

    @staticmethod
    def _signature(progs) -> tuple:
        return tuple(tuple((c.op.value, round(c.min_gap, 6)) for c in p)
                     for p in progs)

    def batch_cost(self, unit_programs, banks: int,
                   bank_order: tuple[int, ...] | None = None
                   ) -> BankBatchCost:
        """Measured bank-parallel + refresh cost of one unit across banks.

        The unit (a list of programs, e.g. one MAJ op's primitive sequences)
        is scheduled (a) alone on one bank, (b) replicated on ``banks``
        banks refresh-free (raw makespan), and (c) repeated until the
        simulated window spans at least two tREFI with refresh on, giving
        the amortized steady-state batch latency.

        Units: all latencies are nanoseconds (DDR4-2400 tCK grid from
        ``core/timing.py``). The returned ``BankBatchCost`` carries the
        dimensionless ``parallel_speedup`` (single-bank time / per-unit
        batch time, <= banks — tFAW/tRRD/bus-limited) and
        ``refresh_factor`` (steady-state slowdown >= 1.0) that
        ``EngineStats.charge`` applies to the closed-form single-bank
        latency; results are cached per (banks, program signature).
        This is the cost plane's only entry point into the controller:
        both eager and fused engine modes price through it identically.
        """
        banks = max(1, min(banks, self.n_banks))
        progs = self._as_programs(unit_programs)
        order = None if bank_order is None else tuple(bank_order)
        key = (banks, order, self._signature(progs))
        if key in self._batch_cache:
            return self._batch_cache[key]
        unit = self.schedule_batch(progs, 1, refresh=False,
                                   bank_order=order).total_ns
        makespan = self.schedule_batch(progs, banks, refresh=False,
                                       bank_order=order).total_ns
        if self.refresh and makespan > 0:
            # Repeat batches until the window spans >= 2 tREFI, then isolate
            # the refresh slowdown by comparing the same window with REF
            # injection on vs off (pipelining across batches cancels out).
            reps = max(2, min(256, math.ceil(
                2 * self.trefi * self.postponing / makespan)))
            t_ref = self.schedule_batch(progs, banks, n_batches=reps,
                                        refresh=True, bank_order=order)
            t_off = self.schedule_batch(progs, banks, n_batches=reps,
                                        refresh=False, bank_order=order)
            factor = max(1.0, t_ref.total_ns / max(t_off.total_ns, 1e-9))
            amortized = makespan * factor
            n_ref, stall = t_ref.n_refreshes, t_ref.refresh_stall_ns
        else:
            amortized, n_ref, stall = makespan, 0, 0.0
        out = BankBatchCost(banks=banks, unit_ns=unit, makespan_ns=makespan,
                            amortized_ns=amortized, n_refreshes=n_ref,
                            refresh_stall_ns=stall)
        self._batch_cache[key] = out
        return out
