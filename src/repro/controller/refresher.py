"""Refresh generation (LiteDRAM/gram ``Refresher`` analogue).

Every tREFI the refresher requests rank ownership.  The multiplexer lets
in-flight PuM sequences drain (a violated-timing APA cannot be split), stops
launching new sequences, and then grants the rank: the refresher issues a
precharge-all (tRP) followed by one or more REFs (tRFC each), a rank-wide
lockout during which no bank may issue.  ``postponing`` batches up to N
requests into one lockout (JEDEC allows postponing up to 8 REFs).
"""

from __future__ import annotations

from repro.core.timing import DramTimings


class Refresher:
    def __init__(self, timings: DramTimings, trefi: float | None = None,
                 trfc: float | None = None, postponing: int = 1,
                 enabled: bool = True):
        assert 1 <= postponing <= 8
        self.t = timings
        self.trefi = timings.trefi if trefi is None else trefi
        self.trfc = timings.trfc if trfc is None else trfc
        self.postponing = postponing
        self.enabled = enabled
        if enabled and self.trefi * postponing <= self.lockout_ns:
            raise ValueError(
                f"tREFI*postponing ({self.trefi * postponing}ns) must exceed "
                f"the refresh lockout ({self.lockout_ns}ns); the rank would "
                f"do nothing but refresh")
        self.next_due = self.trefi * postponing
        self.n_refreshes = 0
        self.busy_ns = 0.0
        self.windows: list[tuple[float, float]] = []

    @property
    def lockout_ns(self) -> float:
        """Precharge-all + the batched REFs."""
        return self.t.trp + self.trfc * self.postponing

    def blocks(self, when: float) -> bool:
        """True if a *new* sequence starting at ``when`` must wait for REF."""
        return self.enabled and when >= self.next_due - 1e-9

    def execute(self, start: float) -> float:
        """Run the refresh lockout starting at ``start``; returns its end."""
        end = start + self.lockout_ns
        self.windows.append((start, end))
        self.n_refreshes += self.postponing
        self.busy_ns += end - start
        # Periodic tREFI schedule; never re-arm inside the lockout itself.
        self.next_due = max(self.next_due + self.trefi * self.postponing, end)
        return end
