"""Per-bank command state machine (LiteDRAM/gram ``BankMachine`` analogue).

Each bank machine owns a FIFO of commands grouped into *sequences* (one PuM
command program each).  A sequence is the atomicity unit for refresh: the
multiplexer may interleave commands of different banks freely, but a REF can
only take the rank once every in-flight sequence has drained — a violated
timing ACT-PRE-ACT (APA/AAP) can never be split by a refresh.

The bank machine tracks open-row state across issued commands and, for
nominal row accesses submitted via :meth:`enqueue_access`, applies the
row-hit/row-miss precharge policy (open-page by default, closed-page /
auto-precharge optionally): a hit issues the column command directly, an
idle bank activates first, a miss precharges, re-activates, then issues.
"""

from __future__ import annotations

import dataclasses
import enum
from collections import deque

from repro.core.commands import Cmd, Op
from repro.core.timing import DramTimings


class BankState(enum.Enum):
    IDLE = "idle"        # all rows precharged
    ACTIVE = "active"    # one row latched in the sense amps


@dataclasses.dataclass
class QueuedCmd:
    cmd: Cmd
    seq_start: bool      # first command of a sequence (refresh-safe point)
    seq_id: int


class BankMachine:
    """FSM + command queue for one DRAM bank.

    The multiplexer asks :meth:`earliest_issue` when this bank's head command
    could go out under *per-bank* constraints (the program's ``min_gap``
    sequencing and any post-refresh floor); rank-wide constraints (tFAW,
    tRRD, tCCD, bus occupancy) are the multiplexer's job — mirroring the
    split in LiteDRAM/gram.
    """

    def __init__(self, bank_id: int, timings: DramTimings,
                 open_page: bool = True):
        self.bank = bank_id
        self.t = timings
        self.open_page = open_page
        self.queue: deque[QueuedCmd] = deque()
        self.state = BankState.IDLE
        self.open_row: int | None = None
        self.last_issue: float | None = None  # time of last issued command
        self.floor = 0.0                      # earliest issue (refresh lockout)
        self._seq_counter = 0
        # Projected state at the queue tail, used by the precharge policy.
        self._tail_row: int | None = None
        self._tail_col_op: Op | None = None

    # ------------------------------------------------------------------ #
    # Enqueue
    # ------------------------------------------------------------------ #

    def enqueue_program(self, prog) -> int:
        """Queue one PuM command program as an atomic sequence."""
        sid = self._seq_counter
        self._seq_counter += 1
        for i, cmd in enumerate(prog):
            if cmd.bank != self.bank:
                cmd = dataclasses.replace(cmd, bank=self.bank)
            self.queue.append(QueuedCmd(cmd, i == 0, sid))
            if cmd.op is Op.ACT:
                self._tail_row = cmd.row
            elif cmd.op is Op.PRE:
                self._tail_row = None
            elif cmd.op in (Op.RD, Op.WR):
                self._tail_col_op = cmd.op
        return sid

    def enqueue_access(self, row: int, write: bool = False,
                       n_bursts: int = 1,
                       auto_precharge: bool | None = None) -> int:
        """Nominal row access under the precharge policy (row hit/miss).

        ``auto_precharge`` overrides the machine-level page policy for this
        one access: ``True`` appends a closing PRE (closed-page), ``False``
        leaves the row open, ``None`` (default) follows ``self.open_page``.
        The crossbar uses this for lookahead-driven auto-precharge — when
        the next queued request for the bank targets a different row, the
        PRE rides along with this access instead of costing a conflict."""
        t = self.t
        col = Op.WR if write else Op.RD
        prog: list[Cmd] = []
        if self._tail_row == row:                       # row hit
            first_gap = t.tccd_l
        elif self._tail_row is None:                    # bank idle
            prog.append(Cmd(Op.ACT, self.bank, row, 0.0, "bm.act"))
            first_gap = t.trcd
        else:                                           # row miss
            if self._tail_col_op is Op.WR:
                pre_gap = t.twr + t.tbl
            elif self._tail_col_op is Op.RD:
                pre_gap = t.trtp + t.tbl
            else:
                pre_gap = t.tras
            prog.append(Cmd(Op.PRE, self.bank, -1, pre_gap, "bm.pre"))
            prog.append(Cmd(Op.ACT, self.bank, row, t.trp, "bm.act"))
            first_gap = t.trcd
        prog.append(Cmd(col, self.bank, row, first_gap, "bm.col0"))
        for i in range(1, n_bursts):
            prog.append(Cmd(col, self.bank, row, t.tccd_l, f"bm.col{i}"))
        closed = ((not self.open_page) if auto_precharge is None
                  else auto_precharge)
        if closed:                                      # closed-page policy
            tail = t.twr if write else t.trtp + t.tbl
            prog.append(Cmd(Op.PRE, self.bank, -1, tail, "bm.prea"))
        return self.enqueue_program(prog)

    # ------------------------------------------------------------------ #
    # Multiplexer interface
    # ------------------------------------------------------------------ #

    def __len__(self) -> int:
        return len(self.queue)

    def head(self) -> QueuedCmd | None:
        return self.queue[0] if self.queue else None

    def earliest_issue(self) -> float:
        """Per-bank earliest issue time for the head command."""
        q = self.queue[0]
        t = self.floor
        if self.last_issue is not None:
            t = max(t, self.last_issue + q.cmd.min_gap)
        else:
            t = max(t, q.cmd.min_gap)
        return t

    def issue(self, when: float) -> QueuedCmd:
        """Pop the head command; update FSM/open-row state."""
        q = self.queue.popleft()
        self.last_issue = when
        if q.cmd.op is Op.ACT:
            self.state = BankState.ACTIVE
            self.open_row = q.cmd.row
        elif q.cmd.op is Op.PRE:
            self.state = BankState.IDLE
            self.open_row = None
        return q

    def note_refresh(self, lockout_end: float) -> None:
        """A rank REF closed every row; resume no earlier than the lockout
        end, and re-activate if the queued head assumed an open row."""
        self.state = BankState.IDLE
        self.open_row = None
        self.floor = max(self.floor, lockout_end)
        if self.queue:
            q0 = self.queue[0]
            if q0.cmd.op in (Op.RD, Op.WR):
                q0.cmd = dataclasses.replace(q0.cmd, min_gap=self.t.trcd)
                q0.seq_start = False
                self.queue.appendleft(QueuedCmd(
                    Cmd(Op.ACT, self.bank, q0.cmd.row, 0.0, "bm.reopen"),
                    True, q0.seq_id))
