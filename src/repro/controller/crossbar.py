"""Client crossbar (LiteDRAM ``crossbar.py`` analogue, PULSAR serve tier).

N concurrent client streams share one rank through per-client
:class:`ClientPort` objects.  Each port demuxes its requests into
per-(port, bank) FIFOs; the crossbar feeds the existing per-bank
:class:`~repro.controller.bank_machine.BankMachine` FSMs through the
multiplexer's ``feeder`` hook, topping each bank up to a configurable
*lookahead* depth of pending sequences (LiteDRAM's
``cmd_buffer_lookahead``).  Arbitration between ports contending for the
same bank is round-robin per bank, so no port can be starved while it has
work queued; rank-wide tFAW/tRRD/tCCD/bus constraints and refresh priority
stay entirely in :class:`~repro.controller.multiplexer.CommandMultiplexer`,
untouched.

Two request kinds per port:

  * :meth:`ClientPort.submit` — PuM command programs (violated-timing
    sequences, the atomic unit refresh may not split), exactly what
    ``MemoryController.schedule`` accepts;
  * :meth:`ClientPort.submit_access` — nominal row accesses priced under
    the page policy.  With ``auto_precharge=True`` the crossbar peeks at
    the *next* queued access for the bank (across all ports, in grant
    order): if it targets a different row, the closing PRE is appended to
    this access up front instead of being paid as a row-miss conflict.

Single-client equivalence: with one port, eager refill reproduces the
exact bank-machine queues ``MemoryController.schedule`` would have built,
so the multiplexer makes identical decisions and the trace is
byte-for-byte the legacy schedule (pinned by the golden-trace tests).

>>> from repro.controller import Crossbar
>>> from repro.core.commands import Cmd, Op
>>> xb = Crossbar(n_ports=2, refresh=False)
>>> prog = [Cmd(Op.ACT, 0, 5, 0.0), Cmd(Op.PRE, 0, -1, 10.0)]
>>> xb.port(0).submit([prog])
>>> xb.port(1).submit([[Cmd(Op.ACT, 1, 7, 0.0), Cmd(Op.PRE, 1, -1, 10.0)]])
>>> tr = xb.run()
>>> sorted(set(tr.port_of))
[0, 1]
>>> len(tr.cmds) == len(tr.port_of) == 4
True
"""

from __future__ import annotations

import dataclasses
from collections import deque

from repro.controller.bank_machine import BankMachine
from repro.controller.controller import ControllerTrace
from repro.controller.multiplexer import CommandMultiplexer
from repro.controller.refresher import Refresher
from repro.core.commands import Cmd
from repro.core.timing import DDR4_2400, DramTimings


@dataclasses.dataclass(frozen=True)
class _Access:
    """A nominal row access waiting in a port's per-bank FIFO."""
    row: int
    write: bool
    n_bursts: int


class ClientPort:
    """One client's submission endpoint: per-bank FIFOs of requests.

    Order is preserved per (port, bank) — requests a client submits to the
    same bank issue in submission order; requests to different banks may
    overlap freely (that is the point of the crossbar)."""

    def __init__(self, xbar: "Crossbar", port_id: int):
        self.xbar = xbar
        self.port = port_id
        # bank -> FIFO of list[Cmd] (program) | _Access
        self.queues: list[deque] = [deque() for _ in range(xbar.n_banks)]

    def __len__(self) -> int:
        return sum(len(q) for q in self.queues)

    def submit(self, programs) -> None:
        """Queue PuM command programs (one bank each, like ``schedule``)."""
        if programs and isinstance(programs[0], Cmd):
            programs = [list(programs)]
        for prog in programs:
            prog = list(prog)
            if not prog:
                continue
            banks = {c.bank for c in prog}
            if len(banks) != 1:
                raise ValueError(
                    f"program spans banks {sorted(banks)}; submit one "
                    f"program per bank")
            bank = prog[0].bank
            self._check_bank(bank)
            self.queues[bank].append(prog)

    def submit_access(self, bank: int, row: int, write: bool = False,
                      n_bursts: int = 1) -> None:
        """Queue a nominal row access (priced by the bank's page policy)."""
        self._check_bank(bank)
        self.queues[bank].append(_Access(row, write, n_bursts))

    def _check_bank(self, bank: int) -> None:
        if not 0 <= bank < self.xbar.n_banks:
            raise ValueError(f"bank {bank} out of range "
                             f"(crossbar has {self.xbar.n_banks})")


@dataclasses.dataclass
class CrossbarTrace(ControllerTrace):
    """ControllerTrace + per-command client-port attribution."""
    # Parallel to ``cmds``/``issue_times``: the port that submitted the
    # sequence each command belongs to, and the (bank, seq_id) identity of
    # that sequence (for atomicity audits against refresh windows).
    port_of: list[int] = dataclasses.field(default_factory=list)
    seqs: list = dataclasses.field(default_factory=list)
    n_ports: int = 1

    def counters(self, timings: DramTimings | None = None):
        """Controller counters + per-port arbitration counters
        (grant counts, starvation gaps) — both pure audit-trail replays."""
        from repro.telemetry import (derive_controller_counters,
                                     derive_port_counters)
        bank = derive_controller_counters(self, timings)
        bank.merge(derive_port_counters(self))
        return bank


class Crossbar:
    """Port demux + lookahead feeder over the existing bank machines.

    ``lookahead`` bounds how many *sequences* may sit in a bank machine's
    queue at once; the feeder refills lazily as the multiplexer drains, so
    a port submitting an unbounded stream cannot monopolize a bank queue —
    later-arriving ports get interleaved within ``lookahead`` sequences.
    """

    def __init__(self, timings: DramTimings = DDR4_2400, n_banks: int = 16,
                 n_ports: int = 2, lookahead: int = 8,
                 auto_precharge: bool = False, refresh: bool = True,
                 trefi: float | None = None, trfc: float | None = None,
                 postponing: int = 1, open_page: bool = True):
        if n_ports < 1:
            raise ValueError(f"n_ports must be >= 1, got {n_ports}")
        if lookahead < 1:
            raise ValueError(f"lookahead must be >= 1, got {lookahead}")
        self.t = timings
        self.n_banks = n_banks
        self.n_ports = n_ports
        self.lookahead = lookahead
        self.auto_precharge = auto_precharge
        self.refresh = refresh
        self.trefi = timings.trefi if trefi is None else trefi
        self.trfc = timings.trfc if trfc is None else trfc
        self.postponing = postponing
        self.open_page = open_page
        self.ports = [ClientPort(self, p) for p in range(n_ports)]

    def port(self, i: int) -> ClientPort:
        return self.ports[i]

    # ------------------------------------------------------------------ #

    @staticmethod
    def _pending_seqs(bm: BankMachine) -> int:
        return sum(1 for q in bm.queue if q.seq_start)

    def _next_row(self, bank: int, rr: int) -> int | None:
        """Row of the next access the feeder would grant for ``bank``
        (None if the next request is a raw program or nothing is queued).
        Drives lookahead auto-precharge."""
        for off in range(self.n_ports):
            q = self.ports[(rr + off) % self.n_ports].queues[bank]
            if q:
                head = q[0]
                return head.row if isinstance(head, _Access) else None
        return None

    def run(self, refresh: bool | None = None) -> CrossbarTrace:
        """Drain every port through the shared multiplexer.

        Stateless like ``MemoryController.schedule``: fresh bank machines
        and refresher per call; the ports' queues are consumed."""
        machines = [BankMachine(b, self.t, self.open_page)
                    for b in range(self.n_banks)]
        refresher = Refresher(
            self.t, trefi=self.trefi, trfc=self.trfc,
            postponing=self.postponing,
            enabled=self.refresh if refresh is None else refresh)
        # Per-bank round-robin pointer over ports (grant fairness) and
        # (bank, seq_id) -> port attribution for the audit trail.
        rr = [0] * self.n_banks
        seq_port: dict[tuple[int, int], int] = {}

        def feed() -> None:
            for b, bm in enumerate(machines):
                while self._pending_seqs(bm) < self.lookahead:
                    chosen = -1
                    for off in range(self.n_ports):
                        p = (rr[b] + off) % self.n_ports
                        if self.ports[p].queues[b]:
                            chosen = p
                            break
                    if chosen < 0:
                        break
                    req = self.ports[chosen].queues[b].popleft()
                    if isinstance(req, _Access):
                        apre = None
                        if self.auto_precharge:
                            nxt = self._next_row(b, (chosen + 1)
                                                 % self.n_ports)
                            apre = nxt is not None and nxt != req.row
                        sid = bm.enqueue_access(req.row, req.write,
                                                req.n_bursts,
                                                auto_precharge=apre)
                    else:
                        sid = bm.enqueue_program(req)
                    seq_port[(b, sid)] = chosen
                    rr[b] = (chosen + 1) % self.n_ports

        mux = CommandMultiplexer(self.t, machines, refresher, feeder=feed)
        r = mux.run()
        port_of = [seq_port[key] for key in r.seqs]
        return CrossbarTrace(
            total_ns=r.total_ns, energy_j=r.energy_j, n_acts=r.n_acts,
            n_pres=r.n_pres, n_rdwr=r.n_rdwr,
            issue_times=[t for _, t in r.events],
            cmds=[c for c, _ in r.events],
            n_refreshes=r.n_refreshes, refresh_stall_ns=r.refresh_stall_ns,
            refresh_windows=r.refresh_windows, per_bank_ns=r.per_bank_last,
            timings=self.t, port_of=port_of, seqs=list(r.seqs),
            n_ports=self.n_ports)
