"""Span tracer with Chrome trace-event export (loads in Perfetto).

The tracer records *spans* — named wall-clock intervals with optional
attributes — around the fused pipeline's flush phases and the serve
tier's ticks. Export is the Chrome trace-event JSON format
(``tracer.export("trace.json")``), so any trace opens directly in
Perfetto / ``chrome://tracing``.

Zero-overhead-when-disabled contract: nothing in the repo constructs a
``Tracer`` unless asked (``pum.profile()``, ``ServeEngine(telemetry=
True)``); instrumented code paths use :data:`NULL_TRACER` when none is
attached, whose ``span()`` returns a shared no-op context manager — no
clock reads, no allocation, no event list. Tracing never feeds back into
scheduling, results, or the cost plane (invariance is tested).
"""

from __future__ import annotations

import json
import time


class Span:
    """One open span: a context manager stamping enter/exit wall time.

    After exit, ``dur_ns`` holds the span duration (integer nanoseconds);
    callers feed it into ``CounterBank.observe`` for latency histograms.
    """

    __slots__ = ("_tracer", "name", "args", "_t0", "dur_ns")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.args = args
        self._t0 = 0
        self.dur_ns = 0

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        t1 = time.perf_counter_ns()
        self.dur_ns = t1 - self._t0
        self._tracer._events.append((self.name, self._t0, t1, self.args))


class _NullSpan:
    """Shared no-op span: enter/exit do nothing, ``dur_ns`` stays 0."""

    __slots__ = ()
    name = ""
    dur_ns = 0

    @property
    def args(self) -> dict:
        # A fresh throwaway dict per access: instrumented code may late-set
        # span attributes (``sp.args["k"] = v``); on the shared null span
        # those writes must vanish instead of accreting on a class dict.
        return {}

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        pass


_NULL_SPAN = _NullSpan()


class _NullTracer:
    """Disabled-path stand-in: every method is a no-op returning the
    shared null span. Instrumented code writes ``tr = tracer or
    NULL_TRACER`` and stays branch-free."""

    __slots__ = ()

    def span(self, name: str, **args) -> _NullSpan:
        return _NULL_SPAN

    def add_span(self, name: str, t0_ns: int, t1_ns: int, **args) -> None:
        pass

    def instant(self, name: str, **args) -> None:
        pass


NULL_TRACER = _NullTracer()


class Tracer:
    """Collects spans; exports Chrome trace-event JSON.

    >>> tr = Tracer()
    >>> with tr.span("phase", detail=3):
    ...     pass
    >>> [name for name, *_ in tr.events]
    ['phase']
    """

    __slots__ = ("_events",)

    def __init__(self):
        # (name, t0_ns, t1_ns, args) — perf_counter_ns timestamps.
        self._events: list[tuple[str, int, int, dict]] = []

    @property
    def events(self) -> list[tuple[str, int, int, dict]]:
        """Recorded spans as ``(name, t0_ns, t1_ns, args)`` tuples
        (instants have ``t1_ns == t0_ns``)."""
        return list(self._events)

    def span(self, name: str, **args) -> Span:
        """Context manager timing one named phase."""
        return Span(self, name, args)

    def add_span(self, name: str, t0_ns: int, t1_ns: int, **args) -> None:
        """Record a span from explicit ``perf_counter_ns`` timestamps
        (used for phases whose start predates the tracer's attention,
        e.g. the record phase stamped at first-op time)."""
        self._events.append((name, t0_ns, t1_ns, args))

    def instant(self, name: str, **args) -> None:
        """Record a zero-duration marker."""
        now = time.perf_counter_ns()
        self._events.append((name, now, now, args))

    def span_names(self) -> list[str]:
        """Names of recorded spans, in start order."""
        return [name for name, *_ in sorted(self._events,
                                            key=lambda e: e[1])]

    # -- export --------------------------------------------------------- #

    def to_chrome(self, counters=None) -> dict:
        """The trace as a Chrome trace-event object (``traceEvents`` of
        complete/instant events, microsecond timestamps). ``counters``
        (a ``CounterBank``) is attached as a final instant event so the
        numbers travel with the trace."""
        events = []
        for name, t0, t1, args in sorted(self._events, key=lambda e: e[1]):
            ev = {"name": name, "ph": "X" if t1 > t0 else "i",
                  "ts": t0 / 1e3, "pid": 0, "tid": 0}
            if t1 > t0:
                ev["dur"] = (t1 - t0) / 1e3
            else:
                ev["s"] = "g"
            if args:
                ev["args"] = dict(args)
            events.append(ev)
        if counters is not None:
            ts = events[-1]["ts"] + events[-1].get("dur", 0) if events else 0
            events.append({"name": "counters", "ph": "i", "ts": ts,
                           "pid": 0, "tid": 0, "s": "g",
                           "args": counters.as_dict()})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export(self, path: str, counters=None) -> str:
        """Write the Chrome trace JSON to ``path`` (open it in Perfetto
        or ``chrome://tracing``); returns ``path``."""
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.to_chrome(counters), f, indent=1)
        return path

    def __repr__(self) -> str:
        return f"Tracer({len(self._events)} spans)"
