"""CounterBank + post-hoc controller counter derivation.

The observability contract of this repo is *derive, don't instrument the
scheduler*: the controller's hot loop stays byte-identical whether or not
anyone is watching, and every controller counter is computed after the
fact from the ``ScheduleResult.cmds``/``issue_times`` audit trail the
multiplexer already emits (the same split gram makes between its
``Multiplexer`` and the passive ``core/bandwidth.py`` observer).

:class:`CounterBank` is the one counter container used across the stack —
engine flush counters, serve-tier occupancy/latency histograms, and the
derived controller counters all render through the same
``as_dict()``/``__repr__`` schema, so telemetry JSON and interactive
inspection agree.

Units: every counter name carries its unit as a suffix where one applies
(``*_ns`` nanoseconds, ``*_j`` joules); unsuffixed counters are plain
event counts. Histogram observations are raw values bucketed by power of
two (``observe``).
"""

from __future__ import annotations

import math
from collections import deque


class CounterBank:
    """Named monotonic counters plus power-of-two value histograms.

    ``inc(name, v)`` accumulates a counter; ``observe(name, v)`` records a
    sample into a histogram (count / total / min / max / log2 buckets —
    the shape a latency distribution needs without storing samples).
    Everything renders through :meth:`as_dict` with plain-JSON types.
    """

    __slots__ = ("_counters", "_hists")

    def __init__(self):
        self._counters: dict[str, float] = {}
        self._hists: dict[str, dict] = {}

    # -- counters ------------------------------------------------------- #

    def inc(self, name: str, value: float = 1) -> None:
        self._counters[name] = self._counters.get(name, 0) + value

    def get(self, name: str, default: float = 0) -> float:
        return self._counters.get(name, default)

    def __getitem__(self, name: str) -> float:
        return self._counters[name]

    def __contains__(self, name: str) -> bool:
        return name in self._counters

    def __len__(self) -> int:
        return len(self._counters) + len(self._hists)

    # -- histograms ----------------------------------------------------- #

    def observe(self, name: str, value: float) -> None:
        """Record one sample into the ``name`` histogram (log2 buckets:
        bucket ``k`` counts samples in ``(2**(k-1), 2**k]``; non-positive
        samples land in bucket 0)."""
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = {"count": 0, "total": 0.0,
                                     "min": math.inf, "max": -math.inf,
                                     "buckets": {}}
        h["count"] += 1
        h["total"] += value
        h["min"] = min(h["min"], value)
        h["max"] = max(h["max"], value)
        k = 0 if value <= 1 else math.ceil(math.log2(value))
        h["buckets"][k] = h["buckets"].get(k, 0) + 1

    def histogram(self, name: str) -> dict:
        """Snapshot of one histogram: ``count``/``total``/``min``/``max``/
        ``mean``/``buckets`` (bucket key = log2 upper bound)."""
        h = self._hists[name]
        return dict(h, mean=(h["total"] / h["count"] if h["count"] else 0.0),
                    buckets=dict(h["buckets"]))

    # -- windows -------------------------------------------------------- #

    def snapshot(self) -> "CounterBank":
        """An independent deep copy of the bank's current state — the
        start marker of a measurement window (pair with :meth:`delta`).
        Mutating either bank afterwards never affects the other."""
        s = CounterBank()
        s._counters = dict(self._counters)
        s._hists = {name: {"count": h["count"], "total": h["total"],
                           "min": h["min"], "max": h["max"],
                           "buckets": dict(h["buckets"])}
                    for name, h in self._hists.items()}
        return s

    def delta(self, prev: "CounterBank") -> "CounterBank":
        """This bank minus an earlier :meth:`snapshot` — the counters a
        window accumulated, without resetting the live bank (so
        long-lived devices can be profiled per window: the autotuner's
        drift windows are exactly these deltas). Counters subtract;
        histograms subtract count/total/buckets (their ``mean`` stays
        exact); a window's true ``min``/``max`` are not recoverable from
        two cumulative states, so the live bank's values are kept.
        Zero-change entries are dropped."""
        out = CounterBank()
        for name, v in self._counters.items():
            dv = v - prev._counters.get(name, 0)
            if dv:
                out._counters[name] = dv
        for name, h in self._hists.items():
            p = prev._hists.get(name)
            count = h["count"] - (p["count"] if p else 0)
            if not count:
                continue
            buckets = dict(h["buckets"])
            if p:
                for k, n in p["buckets"].items():
                    buckets[k] = buckets.get(k, 0) - n
            out._hists[name] = {
                "count": count,
                "total": h["total"] - (p["total"] if p else 0.0),
                "min": h["min"], "max": h["max"],
                "buckets": {k: n for k, n in buckets.items() if n},
            }
        return out

    def clear(self) -> None:
        """Reset every counter and histogram **in place** (holders of a
        reference to this bank — the engine, an attached reliability
        plane — keep writing into the same object)."""
        self._counters.clear()
        self._hists.clear()

    # -- aggregate views ------------------------------------------------ #

    def merge(self, other: "CounterBank") -> "CounterBank":
        """Accumulate ``other`` into this bank (counters add; histograms
        combine bucket-wise). Returns self for chaining."""
        for name, v in other._counters.items():
            self.inc(name, v)
        for name, h in other._hists.items():
            mine = self._hists.get(name)
            if mine is None:
                self._hists[name] = {"count": h["count"], "total": h["total"],
                                     "min": h["min"], "max": h["max"],
                                     "buckets": dict(h["buckets"])}
            else:
                mine["count"] += h["count"]
                mine["total"] += h["total"]
                mine["min"] = min(mine["min"], h["min"])
                mine["max"] = max(mine["max"], h["max"])
                for k, n in h["buckets"].items():
                    mine["buckets"][k] = mine["buckets"].get(k, 0) + n
        return self

    def as_dict(self) -> dict:
        """Plain-JSON snapshot: ``{"counters": {...}, "histograms": {...}}``
        (the schema ``BENCH_*.json`` embeds and ``docs/observability.md``
        documents)."""
        return {
            "counters": dict(sorted(self._counters.items())),
            "histograms": {name: self.histogram(name)
                           for name in sorted(self._hists)},
        }

    def __repr__(self) -> str:
        parts = [f"{k}={v:g}" for k, v in sorted(self._counters.items())]
        parts += [f"{k}=hist(n={h['count']})"
                  for k, h in sorted(self._hists.items())]
        body = ", ".join(parts[:8]) + (", ..." if len(parts) > 8 else "")
        return f"CounterBank({body})"


# --------------------------------------------------------------------- #
# Post-hoc controller counter derivation
# --------------------------------------------------------------------- #


def derive_controller_counters(result, timings=None) -> CounterBank:
    """Derive controller counters from a scheduled command trace.

    ``result`` is anything carrying the audit trail — a
    ``ScheduleResult`` (``cmds`` + ``issue_times``), a ``MuxResult``
    (``events``), or a ``ControllerTrace`` (which adds refresh
    accounting). Derivation is pure: the trace is only read, so the
    schedule stays byte-identical whether or not counters are derived
    (tested in tests/telemetry).

    Counters produced (units in the name):

    * ``cmd.act`` / ``cmd.pre`` / ``cmd.rdwr`` / ``cmd.nop`` /
      ``cmd.total`` — commands issued per type (``total`` excludes NOPs,
      which never occupy the command bus).
    * ``wall_ns`` — schedule makespan (last issue time).
    * ``cmd_bus_busy_ns`` — command-bus occupancy (one tCK per non-NOP
      command); ``cmd_bus_utilization`` = busy / wall.
    * ``data_bus_busy_ns`` — data-bus occupancy (one tBL burst per
      RD/WR); ``data_bus_utilization`` = busy / wall.
    * ``row.hit`` / ``row.miss`` / ``row.conflict`` — per column command:
      *hit* = no ACT needed since the previous column on that bank (the
      row was already latched), *miss* = an ACT on an idle bank preceded
      it, *conflict* = the preceding ACT re-opened a bank whose last PRE
      closed a *different* row. Also emitted per bank as
      ``bank<N>.row_{hit,miss,conflict}``.
    * ``stall.trrd_ns`` / ``stall.tfaw_ns`` — ACT issue delay beyond the
      bank's own readiness attributable to rank-wide tRRD spacing and to
      the rolling four-activation window.
    * ``refresh.n`` / ``refresh.lockout_ns`` / ``refresh.stall_ns`` —
      REF accounting, when the trace carries it (``ControllerTrace``).
    * ``energy_j`` — when the trace carries it.

    ``timings`` defaults to the trace's own ``timings`` attribute when it
    has one (``MuxResult``/``ControllerTrace``), else DDR4-2400.
    """
    from repro.core.commands import Op

    if timings is None:
        timings = getattr(result, "timings", None)
    if timings is None:
        from repro.core.timing import DDR4_2400
        timings = DDR4_2400
    t = timings

    events = list(result.events)
    bank = CounterBank()
    n_act = n_pre = n_rdwr = n_nop = 0

    # Per-bank open-row replay for hit/miss/conflict classification, and
    # per-bank last-issue for stall attribution.
    open_row: dict[int, int | None] = {}   # bank -> latched row
    closed_row: dict[int, int] = {}        # bank -> row its last PRE closed
    col_kind: dict[int, str] = {}     # bank -> classification of next column
    last_bank_issue: dict[int, float] = {}
    faw: deque[float] = deque()
    last_act = -math.inf
    trrd_stall = tfaw_stall = 0.0

    for cmd, when in events:
        if cmd.op is Op.ACT:
            # Stall attribution: delay past the bank's own readiness,
            # credited first to tRRD spacing, then to the tFAW window
            # (matching the order the multiplexer applies them).
            prev = last_bank_issue.get(cmd.bank)
            ready = cmd.min_gap if prev is None else prev + cmd.min_gap
            trrd_ready = last_act + t.trrd_s
            tfaw_ready = faw[0] + t.tfaw if len(faw) >= 4 else -math.inf
            trrd_stall += max(0.0, min(when, trrd_ready) - ready)
            tfaw_stall += max(0.0,
                              min(when, tfaw_ready) - max(ready, trrd_ready))
            if len(faw) >= 4:
                faw.popleft()
            faw.append(when)
            last_act = when
            n_act += 1
            # Row-buffer classification for the next column command: an
            # ACT on an idle bank is a miss; an ACT re-opening a bank
            # whose last PRE closed a different row is a conflict.
            prev_row = closed_row.get(cmd.bank)
            col_kind[cmd.bank] = ("conflict" if prev_row is not None
                                  and prev_row != cmd.row else "miss")
            open_row[cmd.bank] = cmd.row
        elif cmd.op is Op.PRE:
            n_pre += 1
            if open_row.get(cmd.bank) is not None:
                closed_row[cmd.bank] = open_row[cmd.bank]
            open_row[cmd.bank] = None
        elif cmd.op in (Op.RD, Op.WR):
            n_rdwr += 1
            kind = col_kind.pop(cmd.bank, "hit")
            bank.inc(f"row.{kind}")
            bank.inc(f"bank{cmd.bank}.row_{kind}")
        else:
            n_nop += 1
        if cmd.op is not Op.NOP:
            last_bank_issue[cmd.bank] = when

    wall = events[-1][1] if events else 0.0
    n_total = n_act + n_pre + n_rdwr
    bank.inc("cmd.act", n_act)
    bank.inc("cmd.pre", n_pre)
    bank.inc("cmd.rdwr", n_rdwr)
    bank.inc("cmd.nop", n_nop)
    bank.inc("cmd.total", n_total)
    bank.inc("wall_ns", wall)
    bank.inc("cmd_bus_busy_ns", n_total * t.tck)
    bank.inc("data_bus_busy_ns", n_rdwr * t.tbl)
    if wall > 0:
        bank.inc("cmd_bus_utilization", n_total * t.tck / wall)
        bank.inc("data_bus_utilization", n_rdwr * t.tbl / wall)
    bank.inc("stall.trrd_ns", trrd_stall)
    bank.inc("stall.tfaw_ns", tfaw_stall)

    energy = getattr(result, "energy_j", None)
    if energy is not None:
        bank.inc("energy_j", energy)
    n_ref = getattr(result, "n_refreshes", None)
    if n_ref is not None:
        bank.inc("refresh.n", n_ref)
        bank.inc("refresh.lockout_ns",
                 sum(e - s for s, e in
                     getattr(result, "refresh_windows", ())))
        bank.inc("refresh.stall_ns",
                 getattr(result, "refresh_stall_ns", 0.0))
    return bank


def derive_port_counters(trace) -> CounterBank:
    """Derive per-client-port arbitration counters from a crossbar trace.

    ``trace`` is a ``CrossbarTrace`` (or anything carrying ``events``,
    ``port_of``, ``seqs``, ``n_ports``). Like
    :func:`derive_controller_counters`, this is a pure replay of the
    audit trail — the crossbar's grant decisions are attributed after
    the fact, never instrumented in the arbitration loop.

    Counters produced:

    * ``xbar.n_ports`` — port count of the trace.
    * ``port<P>.cmds`` — non-NOP commands attributed to port P.
    * ``port<P>.seqs`` — sequences (atomic grant units) port P won.
    * ``port<P>.grant_gap_max_ns`` — the longest interval between two
      consecutive sequence *starts* granted to port P while P still had
      later work (the starvation bound: round-robin arbitration keeps
      this finite for any port with queued requests).
    """
    from repro.core.commands import Op

    bank = CounterBank()
    n_ports = int(getattr(trace, "n_ports", 1))
    bank.inc("xbar.n_ports", n_ports)
    events = list(trace.events)
    port_of = list(getattr(trace, "port_of", ()))
    seqs = list(getattr(trace, "seqs", ()))
    cmds = [0] * n_ports
    seq_seen: set = set()
    seq_count = [0] * n_ports
    # Sequence-start grant times per port, in issue order.
    grant_times: list[list[float]] = [[] for _ in range(n_ports)]
    for (cmd, when), p, sq in zip(events, port_of, seqs):
        if cmd.op is not Op.NOP:
            cmds[p] += 1
        if sq not in seq_seen:
            seq_seen.add(sq)
            seq_count[p] += 1
            grant_times[p].append(when)
    for p in range(n_ports):
        bank.inc(f"port{p}.cmds", cmds[p])
        bank.inc(f"port{p}.seqs", seq_count[p])
        gaps = [b - a for a, b in zip(grant_times[p], grant_times[p][1:])]
        bank.inc(f"port{p}.grant_gap_max_ns", max(gaps, default=0.0))
    return bank


def check_timing_invariants(result, timings=None,
                            eps: float = 1e-6) -> list[str]:
    """Audit a scheduled command trace against the rank-wide DRAM timing
    contract. Returns a list of human-readable violation strings — empty
    means the schedule is clean.

    Pure post-hoc replay (same audit trail as
    :func:`derive_controller_counters`); checks exactly the constraints
    ``CommandMultiplexer._rank_constraints`` enforces, independently
    re-derived so a scheduler bug cannot hide in shared code:

    * **tRRD_S** — consecutive ACTs (any banks) at least ``trrd_s``
      apart;
    * **tFAW** — any four consecutive ACTs span at least ``tfaw``
      (rolling window);
    * **tCCD_S** — consecutive column (RD/WR) commands at least
      ``tccd_s`` apart;
    * **bus tCK** — consecutive non-NOP commands at least one ``tck``
      apart (one command bus);
    * **refresh lockout** — no command issues strictly inside a refresh
      window, and no sequence straddles one (in-flight sequences drain
      before the rank is granted to the refresher) — checked when the
      trace carries ``refresh_windows`` (and ``seqs`` for atomicity).

    ``eps`` absorbs float rounding in the ns-domain event times.
    """
    from repro.core.commands import Op

    if timings is None:
        timings = getattr(result, "timings", None)
    if timings is None:
        from repro.core.timing import DDR4_2400
        timings = DDR4_2400
    t = timings

    events = list(result.events)
    violations: list[str] = []
    acts: deque[float] = deque(maxlen=4)
    last_act = last_col = last_bus = None
    for i, (cmd, when) in enumerate(events):
        if cmd.op is Op.ACT:
            if last_act is not None and when - last_act < t.trrd_s - eps:
                violations.append(
                    f"tRRD: ACT@{when:.3f} (bank {cmd.bank}) only "
                    f"{when - last_act:.3f} ns after previous ACT "
                    f"(< {t.trrd_s})")
            if len(acts) == 4 and when - acts[0] < t.tfaw - eps:
                violations.append(
                    f"tFAW: ACT@{when:.3f} (bank {cmd.bank}) is the 5th "
                    f"ACT within {when - acts[0]:.3f} ns (< {t.tfaw})")
            acts.append(when)
            last_act = when
        elif cmd.op in (Op.RD, Op.WR):
            if last_col is not None and when - last_col < t.tccd_s - eps:
                violations.append(
                    f"tCCD: {cmd.op.name}@{when:.3f} (bank {cmd.bank}) "
                    f"only {when - last_col:.3f} ns after previous "
                    f"column command (< {t.tccd_s})")
            last_col = when
        if cmd.op is not Op.NOP:
            if last_bus is not None and when - last_bus < t.tck - eps:
                violations.append(
                    f"bus: {cmd.op.name}@{when:.3f} (bank {cmd.bank}) "
                    f"only {when - last_bus:.3f} ns after previous "
                    f"command (< tCK {t.tck})")
            last_bus = when

    windows = list(getattr(result, "refresh_windows", ()) or ())
    if windows:
        for cmd, when in events:
            if cmd.op is Op.NOP:
                continue
            for start, end in windows:
                if start + eps < when < end - eps:
                    violations.append(
                        f"refresh: {cmd.op.name}@{when:.3f} (bank "
                        f"{cmd.bank}) issued inside refresh lockout "
                        f"[{start:.3f}, {end:.3f}]")
        seqs = list(getattr(result, "seqs", ()) or ())
        if len(seqs) == len(events):
            span: dict = {}
            for sq, (_, when) in zip(seqs, events):
                s = span.setdefault(sq, [when, when])
                s[0] = min(s[0], when)
                s[1] = max(s[1], when)
            for sq, (s0, s1) in span.items():
                for start, end in windows:
                    if s0 < start - eps and s1 > start + eps:
                        violations.append(
                            f"refresh: sequence {sq} straddles the "
                            f"lockout starting at {start:.3f} "
                            f"(spans [{s0:.3f}, {s1:.3f}])")
    return violations
