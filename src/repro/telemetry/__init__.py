"""repro.telemetry — zero-overhead-when-disabled observability.

Three pieces, one contract:

* :class:`CounterBank` — named monotonic counters + log2-bucket
  histograms; the single counter container used by the engine, the
  serve tier, and the derived controller counters.
* :func:`derive_controller_counters` — post-hoc replay of a
  ``ScheduleResult``/``MuxResult`` command trace into bus-utilization,
  row-buffer, stall, and refresh counters. Derivation only *reads* the
  audit trail the controller already emits, so scheduling stays
  byte-identical whether or not anyone is watching.
  :func:`derive_port_counters` extends the same replay to a
  ``CrossbarTrace``'s per-client-port attribution (grant counts,
  starvation gaps), and :func:`check_timing_invariants` audits any
  trace against the rank-wide tRRD/tFAW/tCCD/bus/refresh contract,
  returning a list of violations (empty = clean).
* :class:`Tracer` / :data:`NULL_TRACER` — span context-managers around
  the fused pipeline's flush phases, exportable as Chrome trace-event
  JSON (opens in Perfetto).

See ``docs/observability.md`` for counter definitions, units, and the
span taxonomy.
"""

from repro.telemetry.counters import (CounterBank, check_timing_invariants,
                                      derive_controller_counters,
                                      derive_port_counters)
from repro.telemetry.tracer import NULL_TRACER, Span, Tracer

__all__ = [
    "CounterBank",
    "NULL_TRACER",
    "Span",
    "Tracer",
    "check_timing_invariants",
    "derive_controller_counters",
    "derive_port_counters",
]
