"""repro.telemetry — zero-overhead-when-disabled observability.

Three pieces, one contract:

* :class:`CounterBank` — named monotonic counters + log2-bucket
  histograms; the single counter container used by the engine, the
  serve tier, and the derived controller counters.
* :func:`derive_controller_counters` — post-hoc replay of a
  ``ScheduleResult``/``MuxResult`` command trace into bus-utilization,
  row-buffer, stall, and refresh counters. Derivation only *reads* the
  audit trail the controller already emits, so scheduling stays
  byte-identical whether or not anyone is watching.
* :class:`Tracer` / :data:`NULL_TRACER` — span context-managers around
  the fused pipeline's flush phases, exportable as Chrome trace-event
  JSON (opens in Perfetto).

See ``docs/observability.md`` for counter definitions, units, and the
span taxonomy.
"""

from repro.telemetry.counters import CounterBank, derive_controller_counters
from repro.telemetry.tracer import NULL_TRACER, Span, Tracer

__all__ = [
    "CounterBank",
    "NULL_TRACER",
    "Span",
    "Tracer",
    "derive_controller_counters",
]
