"""Fault tolerance: heartbeats, straggler detection, supervised restart,
elastic re-mesh planning.

Scaled-out posture (1000+ nodes): every worker ticks a heartbeat; the
monitor flags missing ticks (dead node -> restart from checkpoint with a
shrunk mesh) and per-step-time z-score outliers (straggler -> report; the
scheduler can re-shard around it). In this single-process container the
mechanisms are exercised by tests (thread workers, killed child processes)
— same control logic a multi-host deployment would run on the coordinator.
"""

from __future__ import annotations

import dataclasses
import subprocess
import sys
import threading
import time


@dataclasses.dataclass
class WorkerState:
    last_beat: float
    step_times: list = dataclasses.field(default_factory=list)


class HeartbeatMonitor:
    def __init__(self, timeout_s: float = 5.0, window: int = 32,
                 straggler_factor: float = 2.0):
        self.timeout_s = timeout_s
        self.window = window
        self.straggler_factor = straggler_factor
        self.workers: dict[str, WorkerState] = {}
        self._lock = threading.Lock()

    def beat(self, worker: str, step_time_s: float | None = None) -> None:
        with self._lock:
            st = self.workers.setdefault(worker, WorkerState(time.time()))
            st.last_beat = time.time()
            if step_time_s is not None:
                st.step_times.append(step_time_s)
                st.step_times = st.step_times[-self.window:]

    def dead_workers(self, now: float | None = None) -> list[str]:
        # `now is None`, not truthiness: now=0.0 is a legitimate epoch in
        # tests/replays and must not silently become the wall clock.
        now = time.time() if now is None else now
        with self._lock:
            return [w for w, st in self.workers.items()
                    if now - st.last_beat > self.timeout_s]

    def stragglers(self) -> list[str]:
        """Workers whose mean step time exceeds straggler_factor x the fleet
        median (median-based: robust to the straggler itself, and meaningful
        at any fleet size, unlike a z-score which saturates at small n)."""
        with self._lock:
            means = {w: sum(st.step_times) / len(st.step_times)
                     for w, st in self.workers.items() if st.step_times}
        if len(means) < 3:
            return []
        vals = sorted(means.values())
        med = vals[len(vals) // 2]
        return [w for w, v in means.items()
                if v > self.straggler_factor * med]


def plan_elastic_mesh(healthy_devices: int, model_parallel: int
                      ) -> tuple[int, int]:
    """Largest (data, model) mesh fitting the healthy-device count with the
    model axis preserved (TP degree is fixed by memory); DP shrinks."""
    if healthy_devices < model_parallel:
        raise RuntimeError(
            f"not enough devices ({healthy_devices}) for TP={model_parallel}")
    data = healthy_devices // model_parallel
    return data, model_parallel


class Supervisor:
    """Restart-on-failure loop for a training child process.

    The child checkpoints every K steps; on a non-zero exit the supervisor
    relaunches it with --resume (and, if devices changed, the new mesh) —
    the checkpoint manager reshards on restore."""

    def __init__(self, argv: list[str], max_restarts: int = 3):
        self.argv = argv
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(self) -> int:
        while True:
            proc = subprocess.run([sys.executable] + self.argv)
            if proc.returncode == 0:
                return 0
            self.restarts += 1
            if self.restarts > self.max_restarts:
                return proc.returncode
