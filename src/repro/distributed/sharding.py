"""Sharding rules: DP / TP / EP / SP over the (pod, data, model) mesh.

Name-based rules map parameter paths to PartitionSpecs:
  * vocab (embedding/unembedding)      -> model
  * attention heads (q and kv)         -> model when divisible, else
    replicated (decided per-arch; uneven shards are avoided by construction)
  * FFN hidden                          -> model (all assigned d_ff are
    divisible by 16)
  * MoE experts                         -> model (EP: 64/16, 160/16)
  * MLA latent up-projections (heads)   -> model
  * SSM projections                     -> replicated in the baseline
    (mixed-boundary channel packing; lifted in the §Perf pass)
  * batch                               -> (pod?, data)
  * everything 1-D (norms, biases)      -> replicated

Optimizer states mirror their parameters (same tree structure).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes, model_axis_size


def _shardable(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_pspec(cfg, path_s: str, shape: tuple[int, ...], msize: int,
                dsize: int = 1, fsdp: bool = False,
                daxes: tuple[str, ...] = ("data",)) -> P:
    """PartitionSpec for one parameter. ``shape`` may include a leading
    stacked-layer dim (never sharded); rules index from the trailing dims.

    ``fsdp``: additionally shard a second (non-TP) dim over the data axes —
    ZeRO-3 via GSPMD: the compiler inserts per-layer weight all-gathers and
    gradient reduce-scatters. Used for training (and for serving models
    whose TP-only shards exceed HBM)."""
    none = P()
    dax = daxes if len(daxes) > 1 else daxes[0]

    def fs(dim_size):
        """data-axis entry for an fsdp-shardable dim."""
        return dax if fsdp and _shardable(dim_size, dsize) else None

    def spec_trailing(*trailing):
        pad = len(shape) - len(trailing)
        return P(*([None] * pad + list(trailing)))

    name = path_s.rsplit("/", 1)[-1]
    if len(shape) <= 1:
        return none
    # --- embeddings ---
    if name in ("embedding", "unembed"):
        if _shardable(shape[0], msize):
            return P("model", fs(shape[1]))
        return none
    # --- attention (GQA) ---
    if name == "wq" or name in ("wk", "wv"):
        h = shape[-2]
        if _shardable(h, msize):
            return spec_trailing(fs(shape[-3]), "model", None)
        return spec_trailing(fs(shape[-3]), None, None)
    if name == "wo":
        h = shape[-3]
        if _shardable(h, msize):
            return spec_trailing("model", None, fs(shape[-1]))
        return spec_trailing(None, None, fs(shape[-1]))
    if name in ("bq", "bk", "bv"):
        h = shape[-2]
        return (spec_trailing("model", None)
                if _shardable(h, msize) else none)
    # --- MLA ---
    if name in ("w_uq", "w_uk", "w_uv"):
        h = shape[-2]
        return (spec_trailing(fs(shape[-3]), "model", None)
                if _shardable(h, msize)
                else spec_trailing(fs(shape[-3]), None, None))
    if name in ("w_dq", "w_dkv", "w_kr"):
        return spec_trailing(fs(shape[-2]), None)
    # --- MoE ---
    if "moe" in path_s and name in ("w_gate", "w_up", "w_down"):
        e = shape[-3]
        if _shardable(e, msize):
            return spec_trailing("model", fs(shape[-2]), None)
        return spec_trailing(None, fs(shape[-2]), None)
    if name == "router":
        return none
    # --- dense MLP / shared experts ---
    if name in ("w_gate", "w_up"):
        f = shape[-1]
        return (spec_trailing(fs(shape[-2]), "model")
                if _shardable(f, msize)
                else spec_trailing(fs(shape[-2]), None))
    if name == "w_down":
        f = shape[-2]
        return (spec_trailing("model", fs(shape[-1]))
                if _shardable(f, msize)
                else spec_trailing(None, fs(shape[-1])))
    # --- SSM: TP-replicated in baseline; FSDP on d_model/d_inner dims ---
    if name in ("in_proj", "out_proj"):
        return spec_trailing(fs(shape[-2]), None)
    if name == "conv_w":
        return none
    return none


def param_shardings(cfg, mesh: Mesh, params_shape: Any, fsdp: bool = False,
                    tp: bool = True):
    """NamedSharding pytree for a params (or optimizer-state) shape tree.

    ``tp=False`` (small-model serving): weights replicate (the embedding /
    unembedding keep vocab TP — they are the one big matmul) and the model
    axis carries SEQUENCE parallelism instead — this removes the per-layer
    FFN all-reduce entirely (§Perf H1 iteration 2)."""
    msize = model_axis_size(mesh)
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1

    def one(path, leaf):
        name = _path_str(path)
        if not tp and name.rsplit("/", 1)[-1] not in ("embedding", "unembed"):
            return NamedSharding(mesh, P())
        spec = param_pspec(cfg, name, leaf.shape, msize,
                           dsize=dsize, fsdp=fsdp, daxes=daxes)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_pspec(mesh: Mesh) -> P:
    axes = data_axes(mesh)
    return P(axes if len(axes) > 1 else axes[0])


def batch_shardings(mesh: Mesh, batch_shape: Any, *, batch_divisible: bool
                    = True):
    """Shard the leading (batch) dim of every batch leaf over (pod, data);
    falls back to replication when the batch is too small (long_500k B=1,
    where sequence sharding takes over via activation constraints)."""
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))

    def one(leaf):
        if leaf.shape and leaf.shape[0] % dsize == 0:
            return NamedSharding(mesh, batch_pspec(mesh))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, batch_shape)


def cache_shardings(cfg, mesh: Mesh, cache_shape: Any,
                    tp_threshold_bytes: float = 256e6):
    """Decode caches: batch -> data axes; kv-head dim -> model when it
    divides; seq (ring) dim -> model for B=1 long-context cells (SP).

    ``tp_threshold_bytes``: model-axis sharding of the KV head/head_dim is
    a MEMORY measure, but it back-propagates into the attention compute and
    (when only head_dim divides) forces partial-sum all-reduces per
    attention block — observed to make hymba's 32k prefill 128x
    collective-bound (§Perf H1). So it is applied only when the
    batch-sharded leaf exceeds this per-device size."""
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))
    msize = model_axis_size(mesh)
    dspec = daxes if len(daxes) > 1 else daxes[0]

    def one(path, leaf):
        s = leaf.shape
        p = _path_str(path)
        name = p.rsplit("/", 1)[-1]
        base_rank = {"k": 4, "v": 4, "c_kv": 3, "k_rope": 3,
                     "conv": 3, "state": 4}.get(name, len(s))
        lead = [None] * (len(s) - base_rank)  # stacked-layer dims: unsharded
        bi = len(s) - base_rank               # batch-dim index
        batch_ok = s and s[bi] % dsize == 0
        bdim = dspec if batch_ok else None
        nbytes = float(np.prod(s)) * leaf.dtype.itemsize
        per_dev = nbytes / (dsize if batch_ok else 1)
        if name in ("k", "v"):
            # [*, B, C, Hkv, dh]: prefer kv-head TP; fall back to head_dim
            # TP (partial-sum attention); SP on the ring for B=1 cells.
            need_tp = per_dev > tp_threshold_bytes
            hdim = ("model" if need_tp and _shardable(s[bi + 2], msize)
                    else None)
            ddim = ("model" if need_tp and hdim is None
                    and _shardable(s[bi + 3], msize) else None)
            cdim = (dspec if not batch_ok and _shardable(s[bi + 1], dsize)
                    else None)
            return NamedSharding(mesh, P(*lead, bdim, cdim, hdim, ddim))
        if name in ("c_kv", "k_rope"):
            # [*, B, C, R]: flash-decoding layout — the cache SEQUENCE
            # shards over `model`, so absorbed-MLA scores compute locally
            # per seq-shard and only [B, H, R] partials cross the wire.
            # (R-dim TP was 700x worse: the score contraction over a
            # sharded R made XLA all-gather the whole cache — §Perf H3.)
            cdim = None
            if _shardable(s[bi + 1], msize):
                cdim = "model"
            elif not batch_ok and _shardable(s[bi + 1], dsize):
                cdim = dspec
            return NamedSharding(mesh, P(*lead, bdim, cdim, None))
        return NamedSharding(
            mesh, P(*lead, bdim, *([None] * (base_rank - 1))))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def replicated(mesh: Mesh, tree: Any):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


# --------------------------------------------------------------------- #
# PuM word-axis sharding (the fused dataplane's `shard-words` backend)
# --------------------------------------------------------------------- #


def words_mesh(devices=None) -> Mesh:
    """1-D ``("words",)`` mesh over the local devices: the PuM fused
    dataplane is elementwise across packed words, so the word axis is the
    one natural partition dimension (every device runs the same fused
    program on its slice, no collectives)."""
    devices = jax.devices() if devices is None else list(devices)
    return Mesh(np.array(devices), ("words",))


def words_sharding(mesh: Mesh) -> NamedSharding:
    """Leading-axis sharding of a flat packed-word array over ``mesh``."""
    return NamedSharding(mesh, P("words"))
