"""Activation sharding-constraint helpers that degrade gracefully.

``maybe_shard(x, *axes)`` applies a with_sharding_constraint when the
surrounding (abstract) mesh actually has the named axes — so model code can
carry production constraints (EP dispatch buffers, logits vocab sharding)
while the same code runs unconstrained on a single CPU device in tests.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _current_axes() -> tuple[str, ...]:
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:  # noqa: BLE001 — older API fallback
        return ()
    if mesh is None or getattr(mesh, "empty", True):
        return ()
    return tuple(mesh.axis_names)


def gather_layer_params(cfg, lp):
    """Constrain one scanned layer slice to its TP-only sharding (drop the
    FSDP data-axis factor). Inside a lax.scan body this forces GSPMD to
    slice-then-gather each layer's weights per iteration, instead of
    all-gathering the whole stacked [L, ...] tensor before the loop (which
    is what blows temp memory to ~model-size on big models)."""
    from repro.distributed.sharding import param_pspec  # lazy: no cycle
    axes = _current_axes()
    if "model" not in axes:
        return lp
    try:
        mesh = jax.sharding.get_abstract_mesh()
        msize = dict(zip(mesh.axis_names, mesh.axis_sizes))["model"]
    except Exception:  # noqa: BLE001
        return lp

    def one(path, leaf):
        parts = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        spec = param_pspec(cfg, "/".join(parts), leaf.shape, msize,
                           dsize=1, fsdp=False)
        return jax.lax.with_sharding_constraint(leaf, spec)

    return jax.tree_util.tree_map_with_path(one, lp)


def maybe_shard(x: jax.Array, *spec) -> jax.Array:
    """spec entries: axis name, tuple of axis names, or None. Entries whose
    axes are absent from the current mesh collapse to None."""
    axes = _current_axes()
    if not axes:
        return x

    def ok(entry):
        if entry is None:
            return None
        if isinstance(entry, tuple):
            kept = tuple(a for a in entry if a in axes)
            return kept if kept else None
        return entry if entry in axes else None

    cleaned = [ok(e) for e in spec]
    if all(c is None for c in cleaned):
        return x
    return jax.lax.with_sharding_constraint(x, P(*cleaned))
