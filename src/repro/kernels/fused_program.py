"""Fused bit-plane program compiler: one trace for a whole op graph.

PULSAR's performance case is command-stream economy — many-input MAJ and
Multi-RowInit collapse chains of per-op activations into one fused sequence
(§5.2). This module is the dataplane mirror of that argument: instead of the
engine dispatching every op through Python with its own layout conversion
and intermediate materialization, a recorded op sequence (``FusedProgram``)
compiles into a *single* ``jax.jit`` trace that

  1. transposes each operand horizontal -> vertical ONCE (bit_transpose32),
  2. evaluates the whole program on bit-planes (intermediates stay in
     registers/fusion scope — XLA sees one elementwise DAG),
  3. transposes the requested outputs back ONCE.

The same program IR runs in three backends, all bit-exact against each
other (tests/kernels):

  * ``run_program_pallas`` — Pallas kernel sharing the ``BLOCK_WORDS``
    (8, 128) tiling of maj_n / bitserial_add: the full program executes per
    VMEM-resident block, so N ops cost one HBM round-trip instead of N.
  * ``run_program_ref`` — the vertical jnp oracle (semantics anchor,
    validates the Pallas kernel in interpret mode).
  * ``run_program_words`` — horizontal word-domain jnp evaluator: the CPU
    execution path. On a scalar ISA the vertical form loses ~10x (a ripple
    add is 32 dependent plane passes vs one hardware add), and the two
    bit_transpose32 calls bracketing the program cancel algebraically —
    so the CPU pipeline elides the layout conversion entirely and fuses
    the whole graph in the word domain (same elimination of per-op
    dispatch/materialization, minus the transposes). This is the same
    CPU-vs-TPU dispatch split ops.py applies to every kernel.

Programs are frozen/hashable, so compiled pipelines are cached on graph
*structure*: re-recording the same op sequence over new batches reuses the
trace (jax.jit additionally caches per operand shape).

Value semantics: elements are unsigned, width-bit (everything is computed
modulo 2**width — the vertical layout physically holds ``width`` planes).
Opcodes: and/or/xor (plane-wise), add/sub (ripple carry/borrow),
mul (shift-add over the add plane), div/mod (restoring division over the
add/sub planes; lanes dividing by zero yield 0, matching unsigned NumPy),
less (unsigned compare -> 0/1), popcount (adder tree over the element's
planes), reduce_and(param=w) (== mask(w)), reduce_or (!= 0), reduce_xor
(parity).

Tuple op: ``divmod`` runs the restoring divider ONCE and yields the
(quotient, remainder) *pair*; the selector ops ``fst``/``snd`` extract the
components. A tuple value must be consumed through selectors — it can
never itself be a program output. The engine lowers ``div``/``mod``/
``divmod`` through this form, so ``a // b`` and ``a % b`` of the same
operands CSE into one divider pass at flush (the standalone ``div``/
``mod`` opcodes remain valid IR for directly-authored programs).

Word format: a program carries a :class:`~repro.kernels.plane_layout.
PlaneLayout` naming its lane word (32- or 64-bit). Every evaluator is
parameterized over it — SWAR popcount masks, div/mod selector constants
and the width mask derive from the layout instead of being uint32
literals, and the vertical pack/unpack tiles a 64-bit lane as two 32x32
transposes. The pipeline ABI stays flat int32 "wire" arrays
(``layout.wire_words_per_lane`` words per lane) at every layout.

Backend selection goes through the registry in :mod:`repro.backends`
(capability ``"fused"``): on TPU the ``pallas-tpu`` evaluator wins by
priority, elsewhere ``words-cpu``; ``ref-vertical`` is requestable by
name for validation. Backends declare the layouts they consume — the
64-bit evaluators (``words-cpu-64``/``pallas-tpu-64``/``ref-vertical-64``)
and the multi-device ``shard-words`` pipeline are additive
``register_backend`` calls over the same builders.

Before compilation the engine normalizes each recorded graph with
``optimize_program`` (common-subexpression elimination + dead-node/leaf
pruning). The optimizer is a pure function of graph structure, so the
normalized program remains the pipeline-cache key: re-recording the same
op sequence over new batches still hits the cached trace.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.backends import get_backend, on_tpu as _on_tpu, select_backend
from repro.kernels import ref
from repro.kernels.bit_transpose import bit_transpose32 as _pl_transpose
from repro.kernels.plane_layout import LAYOUT32, PlaneLayout

LANE = 128
SUBLANE = 8
BLOCK_WORDS = SUBLANE * LANE  # one (8, 128) int32 tile per grid step

OPCODES = ("and", "or", "xor", "add", "sub", "mul", "div", "mod", "divmod",
           "fst", "snd", "less", "popcount", "reduce_and", "reduce_or",
           "reduce_xor")

# Opcodes whose operand order does not matter: CSE canonicalizes their
# argument tuples by sorting so `add(a, b)` and `add(b, a)` unify.
COMMUTATIVE = frozenset({"and", "or", "xor", "add", "mul"})


@dataclasses.dataclass(frozen=True)
class FusedOp:
    """One instruction: ``args`` are value ids in the program's combined id
    space (leaf inputs 0..n_inputs-1, then op results in program order)."""
    opcode: str
    args: tuple[int, ...]
    param: int = 0  # reduce_and: the eager path's mask width w


@dataclasses.dataclass(frozen=True)
class FusedProgram:
    """A straight-line bit-plane program (hashable == pipeline cache key).

    Value-id space: leaf inputs occupy ids ``0..n_inputs-1``; op ``i``'s
    result is id ``n_inputs + i``. ``outputs`` lists the value ids to
    materialize. Values are unsigned width-bit integers; every opcode
    computes modulo ``2**width``. ``layout`` names the lane word format
    the pipeline evaluates in (and is part of the cache key — the same
    op structure compiled at two layouts is two pipelines).
    """
    width: int
    n_inputs: int
    ops: tuple[FusedOp, ...]
    outputs: tuple[int, ...]  # value ids to materialize
    layout: PlaneLayout = LAYOUT32


def optimize_program(program: FusedProgram
                     ) -> tuple[FusedProgram, tuple[int, ...],
                                tuple[int, ...]]:
    """Common-subexpression elimination + dead-node/leaf pruning.

    Returns ``(optimized, out_pos, leaf_map)``:

    * ``optimized`` — the normalized program. Structurally identical
      recordings normalize identically, so it remains a valid pipeline
      cache key (commutative args are sorted, duplicate ops unified,
      unreferenced ops and leaves dropped, ids renumbered densely).
    * ``out_pos`` — for each entry of ``program.outputs``, the index into
      ``optimized.outputs`` holding its value (CSE can map several
      requested outputs onto one computed value).
    * ``leaf_map`` — original leaf ids still used, in the order the
      optimized program expects its inputs.

    The optimizer never changes values (CSE only unifies syntactically
    identical ops, whose results are equal by determinism) and never
    touches the cost plane (the engine charges at record time).

    >>> p = FusedProgram(width=8, n_inputs=2, ops=(
    ...     FusedOp("add", (0, 1)), FusedOp("add", (1, 0)),
    ...     FusedOp("xor", (2, 3)), FusedOp("and", (0, 0))), outputs=(4,))
    >>> opt, out_pos, leaf_map = optimize_program(p)
    >>> len(opt.ops)   # add(1,0) unified with add(0,1); dead and() pruned
    2
    >>> opt.ops[1].args  # xor of the shared add with itself
    (2, 2)
    >>> out_pos, leaf_map
    ((0,), (0, 1))
    """
    n_in = program.n_inputs
    canon: dict[int, int] = {}     # original op id -> canonical value id
    table: dict[tuple, int] = {}   # (opcode, args, param) -> value id
    kept: list[tuple[int, FusedOp]] = []
    for i, op in enumerate(program.ops):
        vid = n_in + i
        args = tuple(canon.get(a, a) for a in op.args)
        if op.opcode in COMMUTATIVE:
            args = tuple(sorted(args))
        key = (op.opcode, args, op.param)
        prev = table.get(key)
        if prev is not None:
            canon[vid] = prev
        else:
            table[key] = canon[vid] = vid
            kept.append((vid, FusedOp(op.opcode, args, op.param)))
    out_canon = [canon.get(v, v) for v in program.outputs]
    needed = set(out_canon)
    for vid, op in reversed(kept):  # backward liveness from the outputs
        if vid in needed:
            needed.update(op.args)
    live = [(vid, op) for vid, op in kept if vid in needed]
    leaf_map = tuple(sorted(v for v in needed if v < n_in))
    remap = {old: new for new, old in enumerate(leaf_map)}
    for j, (vid, _) in enumerate(live):
        remap[vid] = len(leaf_map) + j
    ops = tuple(FusedOp(op.opcode, tuple(remap[a] for a in op.args),
                        op.param) for _, op in live)
    outputs: list[int] = []
    pos_of: dict[int, int] = {}
    out_pos = []
    for v in out_canon:
        rv = remap[v]
        if rv not in pos_of:
            pos_of[rv] = len(outputs)
            outputs.append(rv)
        out_pos.append(pos_of[rv])
    opt = FusedProgram(width=program.width, n_inputs=len(leaf_map),
                       ops=ops, outputs=tuple(outputs),
                       layout=program.layout)
    return opt, tuple(out_pos), leaf_map


def eval_fused_ops(program: FusedProgram, env: list) -> list:
    """Evaluate ``program`` over ``env`` (list of plane-list values, leaves
    first), appending one value per op. Pure jnp on whatever array type the
    planes are — traces identically under jax.jit and inside a Pallas body.
    """
    width = program.width
    zero = jnp.zeros_like(env[0][0])
    for op in program.ops:
        xs = [env[a] for a in op.args]
        env.append(_apply_op(op, xs, width, zero))
    return env


def _apply_op(op: FusedOp, xs: list, width: int, zero):
    def scalar(plane):  # 0/1 result plane -> width-plane value
        return [plane] + [zero] * (width - 1)

    if op.opcode == "and":
        return [a & b for a, b in zip(xs[0], xs[1])]
    if op.opcode == "or":
        return [a | b for a, b in zip(xs[0], xs[1])]
    if op.opcode == "xor":
        return [a ^ b for a, b in zip(xs[0], xs[1])]
    if op.opcode == "add":
        return ref.plane_add(xs[0], xs[1])
    if op.opcode == "sub":
        return ref.plane_sub(xs[0], xs[1])[0]
    if op.opcode == "mul":
        return ref.plane_mul(xs[0], xs[1])
    if op.opcode in ("div", "mod"):
        q, r = ref.plane_divmod(xs[0], xs[1])
        return q if op.opcode == "div" else r
    if op.opcode == "divmod":
        return ref.plane_divmod(xs[0], xs[1])  # tuple value: one divider
    if op.opcode == "fst":
        return xs[0][0]
    if op.opcode == "snd":
        return xs[0][1]
    if op.opcode == "less":
        return scalar(ref.plane_sub(xs[0], xs[1])[1])
    if op.opcode == "popcount":
        counts = ref.plane_popcount(xs[0])
        return (counts + [zero] * width)[:width]
    if op.opcode == "reduce_and":
        # Eager semantics: value == mask(w). Bits below w must all be set,
        # bits at/above w must all be clear (values are width-bit).
        w = min(op.param or width, width)
        if op.param and op.param > width:
            return scalar(zero)  # mask(w) > any width-bit value
        low = ref.plane_reduce(xs[0][:w], "and")
        if w < width:
            low = low & ~ref.plane_reduce(xs[0][w:], "or")
        return scalar(low)
    if op.opcode == "reduce_or":
        return scalar(ref.plane_reduce(xs[0], "or"))
    if op.opcode == "reduce_xor":
        return scalar(ref.plane_reduce(xs[0], "xor"))
    raise KeyError(op.opcode)


# --------------------------------------------------------------------- #
# jnp runner (CPU path / oracle)
# --------------------------------------------------------------------- #


def run_program_ref(program: FusedProgram, x: jax.Array) -> jax.Array:
    """x: [n_inputs, width, W] int32 plane stacks -> [n_out, width, W]."""
    env = [[x[i, j] for j in range(program.width)]
           for i in range(program.n_inputs)]
    env = eval_fused_ops(program, env)
    return jnp.stack([jnp.stack(env[v]) for v in program.outputs])


# --------------------------------------------------------------------- #
# Horizontal word-domain evaluator (CPU execution path)
# --------------------------------------------------------------------- #


def _word_popcount(x, layout: PlaneLayout = LAYOUT32, xp=jnp):
    """SWAR popcount at the layout's word size (Hacker's Delight 5-2);
    masks and the final shift derive from the layout, so the same code
    serves 32- and 64-bit lanes (and NumPy or jnp arrays alike)."""
    m1, m2, m4, h01 = (layout.word_scalar(c, xp)
                       for c in layout.swar_consts)
    x = x - ((x >> 1) & m1)
    x = (x & m2) + ((x >> 2) & m2)
    x = (x + (x >> 4)) & m4
    return (x * h01) >> layout.popcount_shift


def _apply_word_op(op: FusedOp, xs: list, width: int, mask,
                   layout: PlaneLayout, xp):
    dt = layout.dtype_name

    def trunc(v):  # modulo 2**width; free when width fills the word
        return v if mask is None else v & mask

    if op.opcode == "and":
        return xs[0] & xs[1]
    if op.opcode == "or":
        return xs[0] | xs[1]
    if op.opcode == "xor":
        return xs[0] ^ xs[1]
    if op.opcode == "add":
        return trunc(xs[0] + xs[1])
    if op.opcode == "sub":
        return trunc(xs[0] - xs[1])
    if op.opcode == "mul":
        return trunc(xs[0] * xs[1])
    if op.opcode in ("div", "mod", "divmod"):
        # Unsigned NumPy semantics: x // 0 == x % 0 == 0 per lane.
        if xp is np:
            # NumPy integer division BY ZERO already yields 0 (the very
            # semantics the engine exposes), so no masking passes — this
            # is the same errstate idiom the eager dataplane uses.
            with np.errstate(divide="ignore", invalid="ignore"):
                if op.opcode == "div":
                    return xs[0] // xs[1]
                if op.opcode == "mod":
                    return xs[0] % xs[1]
                return (xs[0] // xs[1], xs[0] % xs[1])
        # XLA leaves division by zero undefined: guard the lanes. One
        # hardware division per op — the remainder derives from the
        # quotient (x % y == x - (x // y) * y, exact for unsigned).
        zero_div = xs[1] == 0
        safe = xp.where(zero_div, layout.word_scalar(1, xp), xs[1])
        zero = layout.word_scalar(0, xp)
        q = xs[0] // safe
        if op.opcode == "div":
            return xp.where(zero_div, zero, q)
        r = xs[0] - q * safe
        if op.opcode == "divmod":  # tuple value, consumed by fst/snd
            return (xp.where(zero_div, zero, q),
                    xp.where(zero_div, zero, r))
        return xp.where(zero_div, zero, r)
    if op.opcode == "fst":
        return xs[0][0]
    if op.opcode == "snd":
        return xs[0][1]
    if op.opcode == "less":
        return (xs[0] < xs[1]).astype(dt)
    if op.opcode == "popcount":
        return _word_popcount(xs[0], layout, xp)
    if op.opcode == "reduce_and":
        w = op.param or width
        if w > layout.word_bits:  # mask(w) exceeds any width-bit value
            return xp.zeros_like(xs[0])
        return (xs[0] == layout.word_scalar(layout.mask(w), xp)).astype(dt)
    if op.opcode == "reduce_or":
        return (xs[0] != 0).astype(dt)
    if op.opcode == "reduce_xor":
        return _word_popcount(xs[0], layout, xp) & layout.word_scalar(1, xp)
    raise KeyError(op.opcode)


def run_program_words(program: FusedProgram, leaves: list) -> tuple:
    """Same program, horizontal layout: leaves are flat lane-dtype word
    arrays (element i = word i) of the program's layout, returns one array
    per program output. Operands are masked to ``width`` bits on entry —
    identical value semantics to the vertical evaluators (everything is
    modulo 2**width). Computes with whichever array module the leaves
    belong to (jnp under jit; NumPy for the 64-bit host path, where jax
    would need the x64 flag)."""
    layout = program.layout
    xp = np if isinstance(leaves[0], np.ndarray) else jnp
    # Natural-word programs need no masking at all: every lane op wraps
    # at the word boundary by construction.
    mask = (None if program.width == layout.word_bits
            else layout.word_scalar(layout.mask(program.width), xp))
    env = list(leaves) if mask is None else [x & mask for x in leaves]
    # Dead-value liveness: drop each intermediate after its last use so
    # the allocator recycles warm buffers instead of holding every
    # temporary of the whole program live (NumPy path: this is the
    # difference between cache-resident reuse and a fresh page-faulting
    # allocation per op; under jit the env holds tracers and XLA does its
    # own liveness, so it is free there).
    last_use: dict[int, int] = {v: len(program.ops) for v in program.outputs}
    for i, op in enumerate(program.ops):
        for a in op.args:
            last_use[a] = max(last_use.get(a, -1), i)
    for i, op in enumerate(program.ops):
        env.append(_apply_word_op(op, [env[a] for a in op.args],
                                  program.width, mask, layout, xp))
        for a in op.args:
            if last_use[a] == i:
                env[a] = None
    return tuple(env[v] for v in program.outputs)


# --------------------------------------------------------------------- #
# Pallas variant (BLOCK_WORDS tiling, whole program per VMEM block)
# --------------------------------------------------------------------- #


def _program_kernel(x_ref, o_ref, *, program: FusedProgram):
    env = [[x_ref[i, j] for j in range(program.width)]
           for i in range(program.n_inputs)]
    env = eval_fused_ops(program, env)
    for t, vid in enumerate(program.outputs):
        for j in range(program.width):
            o_ref[t, j] = env[vid][j]


@functools.partial(jax.jit, static_argnames=("program", "interpret"))
def run_program_pallas(program: FusedProgram, x: jax.Array,
                       interpret: bool = False) -> jax.Array:
    """Pallas execution of ``run_program_ref``: same [n_in, width, W] ->
    [n_out, width, W] contract, program evaluated per (8, 128) block."""
    n_in, width, w = x.shape
    pad = (-w) % BLOCK_WORDS
    xp = jnp.pad(x, ((0, 0), (0, 0), (0, pad))).astype(jnp.int32)
    blocks = xp.shape[2] // BLOCK_WORDS
    xb = xp.reshape(n_in, width, blocks, SUBLANE, LANE)
    n_out = len(program.outputs)
    out = pl.pallas_call(
        functools.partial(_program_kernel, program=program),
        grid=(blocks,),
        in_specs=[pl.BlockSpec((n_in, width, 1, SUBLANE, LANE),
                               lambda i: (0, 0, i, 0, 0))],
        out_specs=pl.BlockSpec((n_out, width, 1, SUBLANE, LANE),
                               lambda i: (0, 0, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_out, width, blocks, SUBLANE, LANE),
                                       jnp.int32),
        interpret=interpret,
    )(xb)
    return out.reshape(n_out, width, blocks * BLOCK_WORDS)[:, :, :w] \
        .astype(x.dtype)


# --------------------------------------------------------------------- #
# End-to-end pipeline: pack -> run -> unpack, one jit trace, cached.
# Evaluator chosen by capability lookup in the repro.backends registry.
# --------------------------------------------------------------------- #


def get_pipeline(program: FusedProgram, force_pallas: bool = False,
                 interpret: bool = False, force_vertical: bool = False,
                 donate: bool = False, backend: str | None = None):
    """Compiled callable for ``program``: ``fn(*leaves) -> tuple(outs)``.

    Leaves are flat int32 *wire* arrays of packed horizontal words
    (``program.layout.wire_words_per_lane`` int32 words per lane, lane
    count a multiple of 32); outputs likewise. One jit trace end to
    end. The evaluator is resolved through the backend registry
    (``repro.backends``, capability ``"fused"``, filtered by the
    program's layout): on TPU the Pallas vertical evaluator wins
    (operands bit-transpose once, the fused program runs per VMEM block,
    outputs transpose back once); elsewhere the word-domain evaluator
    runs. ``backend=`` names a registered evaluator explicitly;
    ``force_pallas``/``force_vertical`` are shorthands for the built-in
    names at the program's layout. With ``donate=True`` the leaf
    device buffers are donated to the trace (``donate_argnums``) so XLA
    may reuse them for intermediates — the engine's leaf snapshots stay on
    the host, so donation never invalidates caller-visible data. Cached
    on (program structure, backend, donate); jit handles per-shape
    specialization.
    """
    wb = program.layout.word_bits
    if backend is None:
        if force_pallas:
            backend = "pallas-tpu" if wb == 32 else f"pallas-tpu-{wb}"
        elif force_vertical:
            backend = "ref-vertical" if wb == 32 else f"ref-vertical-{wb}"
        else:
            backend = select_backend(require="fused", width=program.width,
                                     layout=program.layout).name
    spec = get_backend(backend)
    if wb not in spec.layouts:
        raise ValueError(
            f"backend {backend!r} does not support the {wb}-bit plane "
            f"layout (declares {sorted(spec.layouts)})")
    # Cache on the resolved BackendSpec, not the name: re-registering a
    # name creates a new (frozen, hashable) spec, so stale pipelines
    # compiled by a replaced builder can never be served.
    return _cached_pipeline(program, spec, interpret, donate)


@functools.lru_cache(maxsize=256)  # bounded: one jit callable per structure
def _cached_pipeline(program: FusedProgram, spec, interpret: bool,
                     donate: bool):
    return spec.builder(program, interpret=interpret, donate=donate)


def with_fault_injection(pipeline, injector):
    """Fault-injection hook over a compiled pipeline.

    ``injector(outs) -> outs`` receives the tuple of clean wire outputs
    after each execution and returns the outputs to hand to the caller —
    the reliability plane (``repro.reliability``) uses this to derive
    fault-injected replicas from the clean run, majority-vote them, and
    retry on weak margins. The wrapper is built per flush only when
    injection is enabled, so the disabled path still calls the cached
    pipeline directly (zero overhead, same object identity for the
    pipeline cache).
    """
    def injected(*leaves):
        return injector(pipeline(*leaves))

    return injected


def _donating(fn, n_leaves: int):
    """Wrap a jit'd pipeline so its leaf buffers are donated: operands are
    committed to the device first (donating raw NumPy args would fall back
    to a copy with a warning), then handed over for XLA to reuse. Donation
    is opportunistic — a program usually has fewer outputs than leaves, so
    some donated buffers go unused; jax's warning about those is expected
    and silenced."""
    jitted = jax.jit(fn, donate_argnums=tuple(range(n_leaves)))

    def call(*leaves):
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            return jitted(*(jnp.asarray(x) for x in leaves))

    return call


def build_words_pipeline(program: FusedProgram, donate: bool = False):
    """Word-domain pipeline (the CPU execution path): the bracketing
    transpose pair cancels algebraically, so the program fuses directly
    on horizontal words. At the 32-bit layout this is one jax.jit trace;
    at the 64-bit layout it evaluates in NumPy (uint64 under jax needs
    the global x64 flag, which would change dtype promotion repo-wide),
    so ``donate`` is a no-op there — NumPy has no device buffers."""
    layout = program.layout
    if layout.word_bits != 32:
        def np_word_pipeline(*leaves):
            outs = run_program_words(
                program, [layout.from_wire(x) for x in leaves])
            return tuple(layout.to_wire(o) for o in outs)

        return np_word_pipeline

    def word_pipeline(*leaves):
        outs = run_program_words(
            program,
            [jax.lax.bitcast_convert_type(x, jnp.uint32)
             for x in leaves])
        return tuple(jax.lax.bitcast_convert_type(o, jnp.int32)
                     for o in outs)

    if donate:
        return _donating(word_pipeline, program.n_inputs)
    return jax.jit(word_pipeline)


def build_sharded_words_pipeline(program: FusedProgram,
                                 donate: bool = False):
    """Multi-device word-domain pipeline (``shard-words``): the program's
    word axis partitions across ``jax.devices()`` on a 1-D ``("words",)``
    mesh, so ONE flush executes one program on every local device. The
    program is elementwise across words, so the sharding is
    communication-free — GSPMD places each shard's slice of the fused
    elementwise DAG on its device; outputs gather on read-back.

    Leaves pad to a multiple of 32 x n_devices before placement (the
    engine slices its lane count back out of the outputs, exactly as it
    does for the 32-lane padding). ``donate`` is ignored: donated input
    buffers would alias the per-device shards the caller still owns.
    """
    from repro.distributed.sharding import words_mesh, words_sharding

    if program.layout.word_bits != 32:
        raise ValueError("shard-words shards the 32-bit word layout; "
                         "register a 64-bit variant to widen it")
    sharding = words_sharding(words_mesh())
    n_dev = sharding.mesh.size

    def word_pipeline(*leaves):
        outs = run_program_words(
            program,
            [jax.lax.bitcast_convert_type(x, jnp.uint32)
             for x in leaves])
        return tuple(jax.lax.bitcast_convert_type(o, jnp.int32)
                     for o in outs)

    jitted = jax.jit(word_pipeline)

    def sharded_pipeline(*leaves):
        n = np.asarray(leaves[0]).shape[0]
        pad = (-n) % (32 * n_dev)
        placed = [jax.device_put(np.pad(np.asarray(x, np.int32), (0, pad)),
                                 sharding) for x in leaves]
        return tuple(np.asarray(o)[:n] for o in jitted(*placed))

    return sharded_pipeline


def build_vertical_pipeline(program: FusedProgram, use_pallas: bool,
                            interpret: bool = False, donate: bool = False):
    """Vertical bit-plane pipeline: transpose in once, run the fused
    program (Pallas kernel or jnp oracle), transpose out once. The
    layout's pack/unpack maps horizontal wire words onto ``width`` bit
    planes — a 64-bit lane is two stacked 32x32 transpose tiles, so the
    one 32x32 transpose kernel serves every layout."""
    width = program.width
    layout = program.layout
    if use_pallas:
        interp = interpret or not _on_tpu()
        transpose = functools.partial(_pl_transpose, interpret=interp)
        run = functools.partial(run_program_pallas, program,
                                interpret=interp)
    else:
        transpose = ref.bit_transpose32
        run = functools.partial(run_program_ref, program)

    def pipeline(*leaves):
        stack = jnp.stack([layout.pack_planes(leaf, transpose, width)
                           for leaf in leaves])
        outs = run(stack)
        return tuple(layout.unpack_planes(outs[t], transpose, width)
                     for t in range(outs.shape[0]))

    if donate:
        return _donating(pipeline, program.n_inputs)
    return jax.jit(pipeline)
