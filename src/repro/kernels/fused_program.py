"""Fused bit-plane program compiler: one trace for a whole op graph.

PULSAR's performance case is command-stream economy — many-input MAJ and
Multi-RowInit collapse chains of per-op activations into one fused sequence
(§5.2). This module is the dataplane mirror of that argument: instead of the
engine dispatching every op through Python with its own layout conversion
and intermediate materialization, a recorded op sequence (``FusedProgram``)
compiles into a *single* ``jax.jit`` trace that

  1. transposes each operand horizontal -> vertical ONCE (bit_transpose32),
  2. evaluates the whole program on bit-planes (intermediates stay in
     registers/fusion scope — XLA sees one elementwise DAG),
  3. transposes the requested outputs back ONCE.

The same program IR runs in three backends, all bit-exact against each
other (tests/kernels):

  * ``run_program_pallas`` — Pallas kernel sharing the ``BLOCK_WORDS``
    (8, 128) tiling of maj_n / bitserial_add: the full program executes per
    VMEM-resident block, so N ops cost one HBM round-trip instead of N.
  * ``run_program_ref`` — the vertical jnp oracle (semantics anchor,
    validates the Pallas kernel in interpret mode).
  * ``run_program_words`` — horizontal word-domain evaluator: the CPU
    execution path. On a scalar ISA the vertical form loses ~10x (a ripple
    add is 32 dependent plane passes vs one hardware add), and the two
    bit_transpose32 calls bracketing the program cancel algebraically —
    so the CPU pipeline elides the layout conversion entirely and fuses
    the whole graph in the word domain (same elimination of per-op
    dispatch/materialization, minus the transposes). This is the same
    CPU-vs-TPU dispatch split ops.py applies to every kernel.
  * ``run_program_pairs`` — the jitted 64-bit lane path: a 64-bit lane
    evaluates as a (lo, hi) pair of uint32 words (the wire layout's two
    int32 words, bitcast), with the carry chained across the pair in
    every arithmetic op — 64-bit add/sub/mul/divmod never materialize a
    uint64 dtype, so the wide path runs under ``jax.jit`` without the
    global x64 flag. divmod is Knuth Algorithm D over base-2^16 digits
    (one hardware uint32 division per quotient digit).

Word-domain pipelines short-circuit per call to the NumPy evaluator when
the program is tiny (``_NP_CUTOFF_WIRE_OPS`` wire-words x ops): for a
2-op bitmap AND over a handful of lanes, one XLA dispatch costs more
than the whole program.

Programs are frozen/hashable, so compiled pipelines are cached on graph
*structure*: re-recording the same op sequence over new batches reuses the
trace (jax.jit additionally caches per operand shape).

Value semantics: elements are unsigned, width-bit (everything is computed
modulo 2**width — the vertical layout physically holds ``width`` planes).
Opcodes: and/or/xor (plane-wise), add/sub (ripple carry/borrow),
mul (shift-add over the add plane), div/mod (restoring division over the
add/sub planes; lanes dividing by zero yield 0, matching unsigned NumPy),
less (unsigned compare -> 0/1), popcount (adder tree over the element's
planes), reduce_and(param=w) (== mask(w)), reduce_or (!= 0), reduce_xor
(parity).

Tuple op: ``divmod`` runs the restoring divider ONCE and yields the
(quotient, remainder) *pair*; the selector ops ``fst``/``snd`` extract the
components. A tuple value must be consumed through selectors — it can
never itself be a program output. The engine lowers ``div``/``mod``/
``divmod`` through this form, so ``a // b`` and ``a % b`` of the same
operands CSE into one divider pass at flush (the standalone ``div``/
``mod`` opcodes remain valid IR for directly-authored programs).

Word format: a program carries a :class:`~repro.kernels.plane_layout.
PlaneLayout` naming its lane word (32- or 64-bit). Every evaluator is
parameterized over it — SWAR popcount masks, div/mod selector constants
and the width mask derive from the layout instead of being uint32
literals, and the vertical pack/unpack tiles a 64-bit lane as two 32x32
transposes. The pipeline ABI stays flat int32 "wire" arrays
(``layout.wire_words_per_lane`` words per lane) at every layout.

Backend selection goes through the registry in :mod:`repro.backends`
(capability ``"fused"``): on TPU the ``pallas-tpu`` evaluator wins by
priority, elsewhere ``words-cpu``; ``ref-vertical`` is requestable by
name for validation. Backends declare the layouts they consume — the
64-bit evaluators (``words-cpu-64``/``pallas-tpu-64``/``ref-vertical-64``)
and the multi-device ``shard-words`` pipeline are additive
``register_backend`` calls over the same builders.

Before compilation the engine normalizes each recorded graph with
``optimize_program`` (common-subexpression elimination + dead-node/leaf
pruning). The optimizer is a pure function of graph structure, so the
normalized program remains the pipeline-cache key: re-recording the same
op sequence over new batches still hits the cached trace.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.backends import get_backend, on_tpu as _on_tpu, select_backend
from repro.kernels import ref
from repro.kernels.bit_transpose import bit_transpose32 as _pl_transpose
from repro.kernels.plane_layout import LAYOUT32, PlaneLayout

LANE = 128
SUBLANE = 8
BLOCK_WORDS = SUBLANE * LANE  # one (8, 128) int32 tile per grid step

OPCODES = ("and", "or", "xor", "add", "sub", "mul", "div", "mod", "divmod",
           "fst", "snd", "less", "popcount", "reduce_and", "reduce_or",
           "reduce_xor")

# Opcodes whose operand order does not matter: CSE canonicalizes their
# argument tuples by sorting so `add(a, b)` and `add(b, a)` unify.
COMMUTATIVE = frozenset({"and", "or", "xor", "add", "mul"})


@dataclasses.dataclass(frozen=True)
class FusedOp:
    """One instruction: ``args`` are value ids in the program's combined id
    space (leaf inputs 0..n_inputs-1, then op results in program order)."""
    opcode: str
    args: tuple[int, ...]
    param: int = 0  # reduce_and: the eager path's mask width w


@dataclasses.dataclass(frozen=True)
class FusedProgram:
    """A straight-line bit-plane program (hashable == pipeline cache key).

    Value-id space: leaf inputs occupy ids ``0..n_inputs-1``; op ``i``'s
    result is id ``n_inputs + i``. ``outputs`` lists the value ids to
    materialize. Values are unsigned width-bit integers; every opcode
    computes modulo ``2**width``. ``layout`` names the lane word format
    the pipeline evaluates in (and is part of the cache key — the same
    op structure compiled at two layouts is two pipelines).
    """
    width: int
    n_inputs: int
    ops: tuple[FusedOp, ...]
    outputs: tuple[int, ...]  # value ids to materialize
    layout: PlaneLayout = LAYOUT32


def optimize_program(program: FusedProgram
                     ) -> tuple[FusedProgram, tuple[int, ...],
                                tuple[int, ...]]:
    """Common-subexpression elimination + dead-node/leaf pruning.

    Returns ``(optimized, out_pos, leaf_map)``:

    * ``optimized`` — the normalized program. Structurally identical
      recordings normalize identically, so it remains a valid pipeline
      cache key (commutative args are sorted, duplicate ops unified,
      unreferenced ops and leaves dropped, ids renumbered densely).
    * ``out_pos`` — for each entry of ``program.outputs``, the index into
      ``optimized.outputs`` holding its value (CSE can map several
      requested outputs onto one computed value).
    * ``leaf_map`` — original leaf ids still used, in the order the
      optimized program expects its inputs.

    The optimizer never changes values (CSE only unifies syntactically
    identical ops, whose results are equal by determinism) and never
    touches the cost plane (the engine charges at record time).

    >>> p = FusedProgram(width=8, n_inputs=2, ops=(
    ...     FusedOp("add", (0, 1)), FusedOp("add", (1, 0)),
    ...     FusedOp("xor", (2, 3)), FusedOp("and", (0, 0))), outputs=(4,))
    >>> opt, out_pos, leaf_map = optimize_program(p)
    >>> len(opt.ops)   # add(1,0) unified with add(0,1); dead and() pruned
    2
    >>> opt.ops[1].args  # xor of the shared add with itself
    (2, 2)
    >>> out_pos, leaf_map
    ((0,), (0, 1))
    """
    return _optimize_cached(program)


@functools.lru_cache(maxsize=512)
def _optimize_cached(program: FusedProgram):
    # Memoized body of optimize_program: programs are frozen/hashable
    # (they already key the pipeline cache) and the result is immutable,
    # so repeat flushes of the same recorded structure skip the whole
    # normalization pass.
    n_in = program.n_inputs
    canon: dict[int, int] = {}     # original op id -> canonical value id
    table: dict[tuple, int] = {}   # (opcode, args, param) -> value id
    kept: list[tuple[int, FusedOp]] = []
    for i, op in enumerate(program.ops):
        vid = n_in + i
        args = tuple(canon.get(a, a) for a in op.args)
        if op.opcode in COMMUTATIVE:
            args = tuple(sorted(args))
        key = (op.opcode, args, op.param)
        prev = table.get(key)
        if prev is not None:
            canon[vid] = prev
        else:
            table[key] = canon[vid] = vid
            kept.append((vid, FusedOp(op.opcode, args, op.param)))
    out_canon = [canon.get(v, v) for v in program.outputs]
    # Narrow each divmod consumed by only one kind of selector into the
    # direct div / mod op: the engine lowers both ``//`` and ``%`` through
    # the shared tuple op, so a program using just one half would
    # otherwise pay for both division passes in every evaluator. Running
    # AFTER unification keeps `a // b; a % b` pairs (CSE merges their two
    # divmod records, giving the pair both selector kinds) on the single
    # divider pass; the orphaned pair falls to the liveness prune below.
    users: dict[int, set] = {}
    for _, op in kept:
        for a in op.args:
            users.setdefault(a, set()).add(op.opcode)
    out_set = set(out_canon)
    pair_args = {vid: op.args for vid, op in kept
                 if op.opcode == "divmod" and vid not in out_set
                 and users.get(vid) in ({"fst"}, {"snd"})}
    if pair_args:
        kept = [(vid, FusedOp("div" if op.opcode == "fst" else "mod",
                              pair_args[op.args[0]]))
                if op.opcode in ("fst", "snd") and op.args[0] in pair_args
                else (vid, op)
                for vid, op in kept]
    needed = set(out_canon)
    for vid, op in reversed(kept):  # backward liveness from the outputs
        if vid in needed:
            needed.update(op.args)
    live = [(vid, op) for vid, op in kept if vid in needed]
    leaf_map = tuple(sorted(v for v in needed if v < n_in))
    remap = {old: new for new, old in enumerate(leaf_map)}
    for j, (vid, _) in enumerate(live):
        remap[vid] = len(leaf_map) + j
    ops = tuple(FusedOp(op.opcode, tuple(remap[a] for a in op.args),
                        op.param) for _, op in live)
    outputs: list[int] = []
    pos_of: dict[int, int] = {}
    out_pos = []
    for v in out_canon:
        rv = remap[v]
        if rv not in pos_of:
            pos_of[rv] = len(outputs)
            outputs.append(rv)
        out_pos.append(pos_of[rv])
    opt = FusedProgram(width=program.width, n_inputs=len(leaf_map),
                       ops=ops, outputs=tuple(outputs),
                       layout=program.layout)
    return opt, tuple(out_pos), leaf_map


def eval_fused_ops(program: FusedProgram, env: list) -> list:
    """Evaluate ``program`` over ``env`` (list of plane-list values, leaves
    first), appending one value per op. Pure jnp on whatever array type the
    planes are — traces identically under jax.jit and inside a Pallas body.
    """
    width = program.width
    zero = jnp.zeros_like(env[0][0])
    for op in program.ops:
        xs = [env[a] for a in op.args]
        env.append(_apply_op(op, xs, width, zero))
    return env


def _apply_op(op: FusedOp, xs: list, width: int, zero):
    def scalar(plane):  # 0/1 result plane -> width-plane value
        return [plane] + [zero] * (width - 1)

    if op.opcode == "and":
        return [a & b for a, b in zip(xs[0], xs[1])]
    if op.opcode == "or":
        return [a | b for a, b in zip(xs[0], xs[1])]
    if op.opcode == "xor":
        return [a ^ b for a, b in zip(xs[0], xs[1])]
    if op.opcode == "add":
        return ref.plane_add(xs[0], xs[1])
    if op.opcode == "sub":
        return ref.plane_sub(xs[0], xs[1])[0]
    if op.opcode == "mul":
        return ref.plane_mul(xs[0], xs[1])
    if op.opcode in ("div", "mod"):
        q, r = ref.plane_divmod(xs[0], xs[1])
        return q if op.opcode == "div" else r
    if op.opcode == "divmod":
        return ref.plane_divmod(xs[0], xs[1])  # tuple value: one divider
    if op.opcode == "fst":
        return xs[0][0]
    if op.opcode == "snd":
        return xs[0][1]
    if op.opcode == "less":
        return scalar(ref.plane_sub(xs[0], xs[1])[1])
    if op.opcode == "popcount":
        counts = ref.plane_popcount(xs[0])
        return (counts + [zero] * width)[:width]
    if op.opcode == "reduce_and":
        # Eager semantics: value == mask(w). Bits below w must all be set,
        # bits at/above w must all be clear (values are width-bit).
        w = min(op.param or width, width)
        if op.param and op.param > width:
            return scalar(zero)  # mask(w) > any width-bit value
        low = ref.plane_reduce(xs[0][:w], "and")
        if w < width:
            low = low & ~ref.plane_reduce(xs[0][w:], "or")
        return scalar(low)
    if op.opcode == "reduce_or":
        return scalar(ref.plane_reduce(xs[0], "or"))
    if op.opcode == "reduce_xor":
        return scalar(ref.plane_reduce(xs[0], "xor"))
    raise KeyError(op.opcode)


# --------------------------------------------------------------------- #
# jnp runner (CPU path / oracle)
# --------------------------------------------------------------------- #


def run_program_ref(program: FusedProgram, x: jax.Array) -> jax.Array:
    """x: [n_inputs, width, W] int32 plane stacks -> [n_out, width, W]."""
    env = [[x[i, j] for j in range(program.width)]
           for i in range(program.n_inputs)]
    env = eval_fused_ops(program, env)
    return jnp.stack([jnp.stack(env[v]) for v in program.outputs])


# --------------------------------------------------------------------- #
# Horizontal word-domain evaluator (CPU execution path)
# --------------------------------------------------------------------- #


def _word_popcount(x, layout: PlaneLayout = LAYOUT32, xp=jnp):
    """SWAR popcount at the layout's word size (Hacker's Delight 5-2);
    masks and the final shift derive from the layout, so the same code
    serves 32- and 64-bit lanes (and NumPy or jnp arrays alike)."""
    m1, m2, m4, h01 = (layout.word_scalar(c, xp)
                       for c in layout.swar_consts)
    x = x - ((x >> 1) & m1)
    x = (x & m2) + ((x >> 2) & m2)
    x = (x + (x >> 4)) & m4
    return (x * h01) >> layout.popcount_shift


def _apply_word_op(op: FusedOp, xs: list, width: int, mask,
                   layout: PlaneLayout, xp):
    dt = layout.dtype_name

    def trunc(v):  # modulo 2**width; free when width fills the word
        return v if mask is None else v & mask

    if op.opcode == "and":
        return xs[0] & xs[1]
    if op.opcode == "or":
        return xs[0] | xs[1]
    if op.opcode == "xor":
        return xs[0] ^ xs[1]
    if op.opcode == "add":
        return trunc(xs[0] + xs[1])
    if op.opcode == "sub":
        return trunc(xs[0] - xs[1])
    if op.opcode == "mul":
        return trunc(xs[0] * xs[1])
    if op.opcode in ("div", "mod", "divmod"):
        # Unsigned NumPy semantics: x // 0 == x % 0 == 0 per lane.
        if xp is np:
            # NumPy integer division BY ZERO already yields 0 (the very
            # semantics the engine exposes), so no masking passes — this
            # is the same errstate idiom the eager dataplane uses.
            with np.errstate(divide="ignore", invalid="ignore"):
                if op.opcode == "div":
                    return xs[0] // xs[1]
                if op.opcode == "mod":
                    return xs[0] % xs[1]
                return (xs[0] // xs[1], xs[0] % xs[1])
        # XLA leaves division by zero undefined: guard the lanes. One
        # hardware division per op — the remainder derives from the
        # quotient (x % y == x - (x // y) * y, exact for unsigned).
        zero_div = xs[1] == 0
        safe = xp.where(zero_div, layout.word_scalar(1, xp), xs[1])
        zero = layout.word_scalar(0, xp)
        q = xs[0] // safe
        if op.opcode == "div":
            return xp.where(zero_div, zero, q)
        r = xs[0] - q * safe
        if op.opcode == "divmod":  # tuple value, consumed by fst/snd
            return (xp.where(zero_div, zero, q),
                    xp.where(zero_div, zero, r))
        return xp.where(zero_div, zero, r)
    if op.opcode == "fst":
        return xs[0][0]
    if op.opcode == "snd":
        return xs[0][1]
    if op.opcode == "less":
        return (xs[0] < xs[1]).astype(dt)
    if op.opcode == "popcount":
        return _word_popcount(xs[0], layout, xp)
    if op.opcode == "reduce_and":
        w = op.param or width
        if w > layout.word_bits:  # mask(w) exceeds any width-bit value
            return xp.zeros_like(xs[0])
        return (xs[0] == layout.word_scalar(layout.mask(w), xp)).astype(dt)
    if op.opcode == "reduce_or":
        return (xs[0] != 0).astype(dt)
    if op.opcode == "reduce_xor":
        return _word_popcount(xs[0], layout, xp) & layout.word_scalar(1, xp)
    raise KeyError(op.opcode)


def run_program_words(program: FusedProgram, leaves: list) -> tuple:
    """Same program, horizontal layout: leaves are flat lane-dtype word
    arrays (element i = word i) of the program's layout, returns one array
    per program output. Operands are masked to ``width`` bits on entry —
    identical value semantics to the vertical evaluators (everything is
    modulo 2**width). Computes with whichever array module the leaves
    belong to: jnp under jit (the 32-bit pipeline), NumPy for the
    small-program short-circuit and as the semantics oracle the
    uint32-pair path (``run_program_pairs``) is tested against."""
    layout = program.layout
    xp = np if isinstance(leaves[0], np.ndarray) else jnp
    # Natural-word programs need no masking at all: every lane op wraps
    # at the word boundary by construction.
    mask = (None if program.width == layout.word_bits
            else layout.word_scalar(layout.mask(program.width), xp))
    env = list(leaves) if mask is None else [x & mask for x in leaves]
    if xp is np:
        # Release each value after its last use (outputs excepted) so
        # the allocator recycles the big intermediate buffers — holding
        # the whole env alive costs fresh pages per op and roughly
        # doubles the evaluator's wall time on full-plane programs.
        # (Under jit env holds tracers; XLA does its own liveness.)
        last_use = {}
        for i, op in enumerate(program.ops):
            for a in op.args:
                last_use[a] = i
        keep = set(program.outputs)
        for i, op in enumerate(program.ops):
            env.append(_apply_word_op(op, [env[a] for a in op.args],
                                      program.width, mask, layout, xp))
            for a in op.args:
                if last_use[a] == i and a not in keep:
                    env[a] = None
        return tuple(env[v] for v in program.outputs)
    for op in program.ops:
        env.append(_apply_word_op(op, [env[a] for a in op.args],
                                  program.width, mask, layout, xp))
    return tuple(env[v] for v in program.outputs)


# --------------------------------------------------------------------- #
# Jitted 64-bit lane path: uint32 (lo, hi) pairs, carry chained in the IR
# --------------------------------------------------------------------- #


def _mulhi32(x, y):
    """High 32 bits of the 64-bit product of two uint32 arrays, via
    16-bit limbs (no uint64 dtype anywhere)."""
    x0, x1 = x & 0xFFFF, x >> 16
    y0, y1 = y & 0xFFFF, y >> 16
    lo_lo = x0 * y0
    mid1 = x1 * y0 + (lo_lo >> 16)
    mid2 = x0 * y1 + (mid1 & 0xFFFF)
    return x1 * y1 + (mid1 >> 16) + (mid2 >> 16)


def _pair_divmod(a, b):
    """Unsigned 64-bit divmod on uint32 (lo, hi) pairs — Knuth Algorithm D
    over base-2^16 digits (Hacker's Delight divmnu): normalize the
    divisor so its top digit has the high bit set, estimate each quotient
    digit with ONE hardware uint32 division, correct it at most twice,
    multiply-subtract in 16-bit digits, add back on the (rare) overdraw.
    Lanes dividing by zero yield (0, 0), matching unsigned NumPy."""
    alo, ahi = a
    blo, bhi = b
    u32 = jnp.uint32
    zero = jnp.zeros_like(alo)
    one = jnp.ones_like(alo)
    bz = (blo | bhi) == 0
    vlo = jnp.where(bz, one, blo)
    vhi = jnp.where(bz, zero, bhi)
    # Normalization shift: clz of the 64-bit divisor (s in [0, 63]).
    s = jnp.where(vhi != 0, jax.lax.clz(vhi),
                  32 + jax.lax.clz(vlo)).astype(u32)
    sl = s & 31
    big = s >= 32
    # Shifts by (32 - sl) are clamped (&31) and gated by sl == 0 selects:
    # XLA leaves out-of-range shift amounts undefined.
    up = jnp.where(sl == 0, zero, vlo >> ((32 - sl) & 31))
    lo_sh = vlo << sl
    hi_sh = (vhi << sl) | up
    vn_lo = jnp.where(big, zero, lo_sh)
    vn_hi = jnp.where(big, lo_sh, hi_sh)
    vn = (vn_lo & 0xFFFF, vn_lo >> 16, vn_hi & 0xFFFF, vn_hi >> 16)
    # Dividend << s as a 128-bit value in four 32-bit words w0..w3.
    a0 = alo << sl
    a1 = (ahi << sl) | jnp.where(sl == 0, zero, alo >> ((32 - sl) & 31))
    a2 = jnp.where(sl == 0, zero, ahi >> ((32 - sl) & 31))
    w0 = jnp.where(big, zero, a0)
    w1 = jnp.where(big, a0, a1)
    w2 = jnp.where(big, a1, a2)
    w3 = jnp.where(big, a2, zero)
    un = [w0 & 0xFFFF, w0 >> 16, w1 & 0xFFFF, w1 >> 16,
          w2 & 0xFFFF, w2 >> 16, w3 & 0xFFFF, w3 >> 16]
    B = 1 << 16
    q = [zero] * 4
    # un[7] < 2^15 <= vn[3] after normalization, so quotient digit 4 is
    # always zero: iterate j = 3..0 only.
    for j in (3, 2, 1, 0):
        num = (un[j + 4] << 16) | un[j + 3]
        qhat = num // vn[3]             # the one hardware division
        rhat = num - qhat * vn[3]
        for _ in range(2):              # Knuth: at most two corrections
            ok = rhat < B
            over = (qhat >= B) | (qhat * vn[2] > ((rhat << 16) | un[j + 2]))
            dec = (ok & over).astype(u32)
            qhat = qhat - dec
            rhat = rhat + vn[3] * dec
        # Multiply-subtract qhat * vn from un[j..j+4] in 16-bit digits;
        # borrows ride the uint32 wraparound (t's top bits encode the
        # signed borrow because |t| < 2^17).
        k = zero
        for i in range(4):
            p = qhat * vn[i]
            t = un[i + j] - k - (p & 0xFFFF)
            un[i + j] = t & 0xFFFF
            k = (p >> 16) + ((B - (t >> 16)) & 0xFFFF)
        t = un[j + 4] - k
        neg = t >> 31                   # borrow out: qhat was one too big
        negb = neg.astype(bool)
        q[j] = qhat - neg
        c = zero
        for i in range(4):              # add-back, selected where needed
            w = un[i + j] + vn[i] + c
            un[i + j] = jnp.where(negb, w & 0xFFFF, un[i + j])
            c = w >> 16
        un[j + 4] = jnp.where(negb, (t + c) & 0xFFFF, t & 0xFFFF)
    # Remainder: un[0..3] denormalized by s; quotient digits q[0..3].
    r_lo_n = un[0] | (un[1] << 16)
    r_hi_n = un[2] | (un[3] << 16)
    down = jnp.where(sl == 0, zero, r_hi_n << ((32 - sl) & 31))
    rlo_s = (r_lo_n >> sl) | down
    rhi_s = r_hi_n >> sl
    quo = (jnp.where(bz, zero, q[0] | (q[1] << 16)),
           jnp.where(bz, zero, q[2] | (q[3] << 16)))
    rem = (jnp.where(bz, zero, jnp.where(big, rhi_s, rlo_s)),
           jnp.where(bz, zero, jnp.where(big, zero, rhi_s)))
    return quo, rem


def _apply_pair_op(op: FusedOp, xs: list, width: int, mask, layout):
    """One opcode on uint32 (lo, hi) pair values — the 64-bit-lane mirror
    of ``_apply_word_op`` (identical value semantics, pinned by tests)."""
    u32 = jnp.uint32

    def trunc(lo, hi):  # modulo 2**width; free at the natural word
        return (lo, hi) if mask is None else (lo & mask[0], hi & mask[1])

    if op.opcode == "and":
        return (xs[0][0] & xs[1][0], xs[0][1] & xs[1][1])
    if op.opcode == "or":
        return (xs[0][0] | xs[1][0], xs[0][1] | xs[1][1])
    if op.opcode == "xor":
        return (xs[0][0] ^ xs[1][0], xs[0][1] ^ xs[1][1])
    if op.opcode == "add":
        (alo, ahi), (blo, bhi) = xs[0], xs[1]
        slo = alo + blo
        return trunc(slo, ahi + bhi + (slo < alo).astype(u32))
    if op.opcode == "sub":
        (alo, ahi), (blo, bhi) = xs[0], xs[1]
        return trunc(alo - blo, ahi - bhi - (alo < blo).astype(u32))
    if op.opcode == "mul":
        (alo, ahi), (blo, bhi) = xs[0], xs[1]
        hi = _mulhi32(alo, blo) + alo * bhi + ahi * blo  # mod-2^64 high
        return trunc(alo * blo, hi)
    if op.opcode in ("div", "mod", "divmod"):
        q, r = _pair_divmod(xs[0], xs[1])
        if op.opcode == "div":
            return q
        if op.opcode == "mod":
            return r
        return (q, r)  # tuple value, consumed by fst/snd
    if op.opcode == "fst":
        return xs[0][0]
    if op.opcode == "snd":
        return xs[0][1]
    zero = jnp.zeros_like(xs[0][0])
    if op.opcode == "less":
        (alo, ahi), (blo, bhi) = xs[0], xs[1]
        lt = (ahi < bhi) | ((ahi == bhi) & (alo < blo))
        return (lt.astype(u32), zero)
    if op.opcode == "popcount":
        lo, hi = xs[0]
        pc = (_word_popcount(lo, LAYOUT32, jnp)
              + _word_popcount(hi, LAYOUT32, jnp))
        return (pc, zero)
    if op.opcode == "reduce_and":
        w = op.param or width
        if w > layout.word_bits:  # mask(w) exceeds any width-bit value
            return (zero, zero)
        lo, hi = xs[0]
        mlo = (1 << min(w, 32)) - 1
        mhi = 0 if w <= 32 else (1 << (w - 32)) - 1
        eq = (lo == u32(mlo)) & (hi == u32(mhi))
        return (eq.astype(u32), zero)
    if op.opcode == "reduce_or":
        lo, hi = xs[0]
        return (((lo | hi) != 0).astype(u32), zero)
    if op.opcode == "reduce_xor":
        lo, hi = xs[0]
        return (_word_popcount(lo ^ hi, LAYOUT32, jnp) & u32(1), zero)
    raise KeyError(op.opcode)


def run_program_pairs(program: FusedProgram, leaves: list) -> tuple:
    """The jitted 64-bit lane path: each flat int32 wire leaf (lo, hi
    interleaved little-endian) deinterleaves into a uint32 (lo, hi) pair,
    the whole program evaluates on pairs with carries chained across the
    pair in every arithmetic op, and outputs re-interleave to wire. Pure
    jnp — one fused elementwise DAG under jax.jit, no uint64 dtype (so no
    global x64 flag), bit-exact against ``run_program_words`` (tests)."""
    layout = program.layout
    width = program.width
    mask = None
    if width < layout.word_bits:
        mask = (jnp.asarray((1 << min(width, 32)) - 1, jnp.uint32),
                jnp.asarray(0 if width <= 32 else (1 << (width - 32)) - 1,
                            jnp.uint32))
    env = []
    for w in leaves:
        v = jax.lax.bitcast_convert_type(jnp.asarray(w),
                                         jnp.uint32).reshape(-1, 2)
        lo, hi = v[:, 0], v[:, 1]
        env.append((lo, hi) if mask is None
                   else (lo & mask[0], hi & mask[1]))
    for op in program.ops:
        env.append(_apply_pair_op(op, [env[a] for a in op.args],
                                  width, mask, layout))
    outs = []
    for vid in program.outputs:
        lo, hi = env[vid]
        wire = jnp.stack([lo, hi], axis=-1).reshape(-1)
        outs.append(jax.lax.bitcast_convert_type(wire, jnp.int32))
    return tuple(outs)


# --------------------------------------------------------------------- #
# Pallas variant (BLOCK_WORDS tiling, whole program per VMEM block)
# --------------------------------------------------------------------- #


def _program_kernel(x_ref, o_ref, *, program: FusedProgram):
    env = [[x_ref[i, j] for j in range(program.width)]
           for i in range(program.n_inputs)]
    env = eval_fused_ops(program, env)
    for t, vid in enumerate(program.outputs):
        for j in range(program.width):
            o_ref[t, j] = env[vid][j]


@functools.partial(jax.jit, static_argnames=("program", "interpret"))
def run_program_pallas(program: FusedProgram, x: jax.Array,
                       interpret: bool = False) -> jax.Array:
    """Pallas execution of ``run_program_ref``: same [n_in, width, W] ->
    [n_out, width, W] contract, program evaluated per (8, 128) block."""
    n_in, width, w = x.shape
    pad = (-w) % BLOCK_WORDS
    xp = jnp.pad(x, ((0, 0), (0, 0), (0, pad))).astype(jnp.int32)
    blocks = xp.shape[2] // BLOCK_WORDS
    xb = xp.reshape(n_in, width, blocks, SUBLANE, LANE)
    n_out = len(program.outputs)
    out = pl.pallas_call(
        functools.partial(_program_kernel, program=program),
        grid=(blocks,),
        in_specs=[pl.BlockSpec((n_in, width, 1, SUBLANE, LANE),
                               lambda i: (0, 0, i, 0, 0))],
        out_specs=pl.BlockSpec((n_out, width, 1, SUBLANE, LANE),
                               lambda i: (0, 0, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_out, width, blocks, SUBLANE, LANE),
                                       jnp.int32),
        interpret=interpret,
    )(xb)
    return out.reshape(n_out, width, blocks * BLOCK_WORDS)[:, :, :w] \
        .astype(x.dtype)


# --------------------------------------------------------------------- #
# End-to-end pipeline: pack -> run -> unpack, one jit trace, cached.
# Evaluator chosen by capability lookup in the repro.backends registry.
# --------------------------------------------------------------------- #


def get_pipeline(program: FusedProgram, force_pallas: bool = False,
                 interpret: bool = False, force_vertical: bool = False,
                 donate: bool = False, backend: str | None = None):
    """Compiled callable for ``program``: ``fn(*leaves) -> tuple(outs)``.

    Leaves are flat int32 *wire* arrays of packed horizontal words
    (``program.layout.wire_words_per_lane`` int32 words per lane, lane
    count a multiple of 32); outputs likewise. One jit trace end to
    end. The evaluator is resolved through the backend registry
    (``repro.backends``, capability ``"fused"``, filtered by the
    program's layout): on TPU the Pallas vertical evaluator wins
    (operands bit-transpose once, the fused program runs per VMEM block,
    outputs transpose back once); elsewhere the word-domain evaluator
    runs. ``backend=`` names a registered evaluator explicitly;
    ``force_pallas``/``force_vertical`` are shorthands for the built-in
    names at the program's layout. With ``donate=True`` the leaf
    device buffers are donated to the trace (``donate_argnums``) so XLA
    may reuse them for intermediates — the engine's leaf snapshots stay on
    the host, so donation never invalidates caller-visible data. Cached
    on (program structure, backend, donate); jit handles per-shape
    specialization.
    """
    wb = program.layout.word_bits
    if backend is None:
        if force_pallas:
            backend = "pallas-tpu" if wb == 32 else f"pallas-tpu-{wb}"
        elif force_vertical:
            backend = "ref-vertical" if wb == 32 else f"ref-vertical-{wb}"
        else:
            backend = select_backend(require="fused", width=program.width,
                                     layout=program.layout).name
    spec = get_backend(backend)
    if wb not in spec.layouts:
        raise ValueError(
            f"backend {backend!r} does not support the {wb}-bit plane "
            f"layout (declares {sorted(spec.layouts)})")
    # Cache on the resolved BackendSpec, not the name: re-registering a
    # name creates a new (frozen, hashable) spec, so stale pipelines
    # compiled by a replaced builder can never be served.
    return _cached_pipeline(program, spec, interpret, donate)


@functools.lru_cache(maxsize=256)  # bounded: one jit callable per structure
def _cached_pipeline(program: FusedProgram, spec, interpret: bool,
                     donate: bool):
    return spec.builder(program, interpret=interpret, donate=donate)


def with_fault_injection(pipeline, injector):
    """Fault-injection hook over a compiled pipeline.

    ``injector(outs) -> outs`` receives the tuple of clean wire outputs
    after each execution and returns the outputs to hand to the caller —
    the reliability plane (``repro.reliability``) uses this to derive
    fault-injected replicas from the clean run, majority-vote them, and
    retry on weak margins. The wrapper is built per flush only when
    injection is enabled, so the disabled path still calls the cached
    pipeline directly (zero overhead, same object identity for the
    pipeline cache).
    """
    def injected(*leaves):
        return injector(pipeline(*leaves))

    return injected


def _donating(fn, n_leaves: int):
    """Wrap a jit'd pipeline so its leaf buffers are donated: operands are
    committed to the device first (donating raw NumPy args would fall back
    to a copy with a warning), then handed over for XLA to reuse. Donation
    is opportunistic — a program usually has fewer outputs than leaves, so
    some donated buffers go unused; jax's warning about those is expected
    and silenced."""
    jitted = jax.jit(fn, donate_argnums=tuple(range(n_leaves)))

    def call(*leaves):
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            return jitted(*(jnp.asarray(x) for x in leaves))

    return call


# Per-call NumPy short-circuit threshold for word pipelines, in
# wire-words x ops: below it, XLA dispatch overhead (which grows with
# the leaf count — each argument is canonicalized and placed) costs more
# than evaluating the whole program in NumPy with last-use buffer
# recycling (a k-clique AND pair over a few lanes is ~100 wire-ops and
# stays NumPy; the paper-scale 30-leaf BMI scan is ~10^7 wire-ops and
# the 2M-lane prog16 staple is ~10^7 — both win jitted, where XLA's
# one-pass loop fusion replaces ~n_ops full-array traversals with one).
# Read at call time so tests can pin either path.
_NP_CUTOFF_WIRE_OPS = 1 << 20


def build_words_pipeline(program: FusedProgram, donate: bool = False):
    """Word-domain pipeline (the CPU execution path): the bracketing
    transpose pair cancels algebraically, so the program fuses directly
    on horizontal words — one jax.jit trace at EVERY layout. 32-bit
    lanes evaluate on uint32 words; 64-bit lanes evaluate as uint32
    (lo, hi) pairs (``run_program_pairs``, carry chained across the pair
    in the IR), so the wide path no longer drops to un-jitted NumPy and
    ``donate`` works at both layouts. Tiny programs short-circuit per
    call to the NumPy word evaluator (``_NP_CUTOFF_WIRE_OPS``), and so
    do 64-bit programs containing division: x86 has no SIMD integer
    divide, so the pair evaluator's Knuth long division scalarizes the
    fused XLA loop (~100 elementwise passes per divmod), while NumPy's
    hardware 64-bit ``divq`` is one pass — with copy-on-write staging
    the host path wins at every size."""
    layout = program.layout
    n_ops = max(1, len(program.ops))
    np_div64 = layout.word_bits == 64 and any(
        op.opcode in ("div", "mod", "divmod") for op in program.ops)

    if layout.word_bits == 32:
        def core(*leaves):
            outs = run_program_words(
                program,
                [jax.lax.bitcast_convert_type(x, jnp.uint32)
                 for x in leaves])
            return tuple(jax.lax.bitcast_convert_type(o, jnp.int32)
                         for o in outs)
    else:
        def core(*leaves):
            return run_program_pairs(program, leaves)

    jitted = (_donating(core, program.n_inputs) if donate
              else jax.jit(core))

    def np_words(*leaves):
        outs = run_program_words(
            program, [layout.from_wire(np.asarray(x)) for x in leaves])
        return tuple(layout.to_wire(o) for o in outs)

    def word_pipeline(*leaves):
        if np_div64:
            return np_words(*leaves)
        if leaves and leaves[0].size * n_ops <= _NP_CUTOFF_WIRE_OPS \
                and all(isinstance(x, np.ndarray) for x in leaves):
            return np_words(*leaves)
        return jitted(*leaves)

    # Leaf-cache protocol (engine._resolve_cached_leaves): cached device
    # buffers are only worth serving when the call will actually run
    # jitted — and never into a donating trace.
    word_pipeline.wants_device = (
        lambda wire_words: not donate and not np_div64
        and wire_words * n_ops > _NP_CUTOFF_WIRE_OPS)
    return word_pipeline


def build_sharded_words_pipeline(program: FusedProgram,
                                 donate: bool = False):
    """Multi-device word-domain pipeline (``shard-words``): the program's
    word axis partitions across ``jax.devices()`` on a 1-D ``("words",)``
    mesh, so ONE flush executes one program on every local device. The
    program is elementwise across words, so the sharding is
    communication-free — GSPMD places each shard's slice of the fused
    elementwise DAG on its device; outputs gather on read-back.

    Leaves pad to a multiple of 32 x n_devices before placement (the
    engine slices its lane count back out of the outputs, exactly as it
    does for the 32-lane padding). ``donate`` is ignored: donated input
    buffers would alias the per-device shards the caller still owns.
    """
    from repro.distributed.sharding import words_mesh, words_sharding

    if program.layout.word_bits != 32:
        raise ValueError("shard-words shards the 32-bit word layout; "
                         "register a 64-bit variant to widen it")
    sharding = words_sharding(words_mesh())
    n_dev = sharding.mesh.size

    def word_pipeline(*leaves):
        outs = run_program_words(
            program,
            [jax.lax.bitcast_convert_type(x, jnp.uint32)
             for x in leaves])
        return tuple(jax.lax.bitcast_convert_type(o, jnp.int32)
                     for o in outs)

    jitted = jax.jit(word_pipeline)

    def sharded_pipeline(*leaves):
        n = np.asarray(leaves[0]).shape[0]
        pad = (-n) % (32 * n_dev)
        placed = [jax.device_put(np.pad(np.asarray(x, np.int32), (0, pad)),
                                 sharding) for x in leaves]
        return tuple(np.asarray(o)[:n] for o in jitted(*placed))

    return sharded_pipeline


def build_vertical_pipeline(program: FusedProgram, use_pallas: bool,
                            interpret: bool = False, donate: bool = False):
    """Vertical bit-plane pipeline: transpose in once, run the fused
    program (Pallas kernel or jnp oracle), transpose out once. The
    layout's pack/unpack maps horizontal wire words onto ``width`` bit
    planes — a 64-bit lane is two stacked 32x32 transpose tiles, so the
    one 32x32 transpose kernel serves every layout."""
    width = program.width
    layout = program.layout
    if use_pallas:
        interp = interpret or not _on_tpu()
        transpose = functools.partial(_pl_transpose, interpret=interp)
        run = functools.partial(run_program_pallas, program,
                                interpret=interp)
    else:
        transpose = ref.bit_transpose32
        run = functools.partial(run_program_ref, program)

    def pipeline(*leaves):
        stack = jnp.stack([layout.pack_planes(leaf, transpose, width)
                           for leaf in leaves])
        outs = run(stack)
        return tuple(layout.unpack_planes(outs[t], transpose, width)
                     for t in range(outs.shape[0]))

    fn = _donating(pipeline, program.n_inputs) if donate \
        else jax.jit(pipeline)

    def vertical_pipeline(*leaves):
        return fn(*leaves)

    # Leaf-cache protocol: the vertical path is always jitted, so cached
    # device buffers are always worth serving (unless donating).
    vertical_pipeline.wants_device = lambda wire_words: not donate
    return vertical_pipeline
