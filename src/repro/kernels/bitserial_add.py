"""Pallas TPU kernel: vertical-layout bit-serial ripple add.

Fuses the PuM full-adder loop (alu.py) over all ``width`` bit-planes into a
single VMEM-resident pass: the carry lives in registers instead of being
written back per plane (on DRAM each carry costs 2-6 row activations; on TPU
it is free — this asymmetry is a §Perf observation in EXPERIMENTS.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
BLOCK_WORDS = SUBLANE * LANE


def _add_kernel(a_ref, b_ref, o_ref, *, width: int):
    carry = jnp.zeros(a_ref.shape[1:], jnp.int32)
    for j in range(width):  # static unroll (width <= 64)
        a, b = a_ref[j], b_ref[j]
        axb = a ^ b
        o_ref[j] = axb ^ carry
        carry = (a & b) | (carry & axb)  # carry = MAJ3(a, b, carry)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitserial_add(a: jax.Array, b: jax.Array,
                  interpret: bool = False) -> jax.Array:
    """a, b: [width, W] int32 bit-planes -> [width, W] sum planes."""
    if a.shape != b.shape:
        raise ValueError("shape mismatch")
    width, w = a.shape
    pad = (-w) % BLOCK_WORDS
    ap = jnp.pad(a, ((0, 0), (0, pad))).astype(jnp.int32)
    bp = jnp.pad(b, ((0, 0), (0, pad))).astype(jnp.int32)
    blocks = ap.shape[1] // BLOCK_WORDS
    ab = ap.reshape(width, blocks, SUBLANE, LANE)
    bb = bp.reshape(width, blocks, SUBLANE, LANE)
    spec = pl.BlockSpec((width, 1, SUBLANE, LANE), lambda i: (0, i, 0, 0))
    out = pl.pallas_call(
        functools.partial(_add_kernel, width=width),
        grid=(blocks,),
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((width, 1, SUBLANE, LANE),
                               lambda i: (0, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((width, blocks, SUBLANE, LANE),
                                       jnp.int32),
        interpret=interpret,
    )(ab, bb)
    return out.reshape(width, blocks * BLOCK_WORDS)[:, :w].astype(a.dtype)
