"""PlaneLayout — the explicit word-format contract of the fused dataplane.

Before this module existed the 32-bit word was an *implicit* contract:
``bit_transpose32`` tiles, ``uint32`` SWAR constants in the word-domain
evaluator, ``astype(np.uint32)`` leaf snapshots in the engine, the
hardcoded 2x32 raw-lane split, and ``max_width=32`` capability checks all
had to agree by convention. PULSAR's primitives are width-agnostic —
many-input MAJ and Multi-RowInit operate on however many columns are
activated simultaneously (§5.2) — so widening the lane format should be a
*data* change, not a six-module edit.

A :class:`PlaneLayout` names one lane format:

* ``word_bits`` — bits per dataplane lane word (32 or 64);
* lane dtypes (``np_dtype``/``dtype_name``) — what leaf snapshots and
  word-domain values are carried in;
* SWAR constants (``swar_consts``/``popcount_shift``) — the Hacker's
  Delight 5-2 popcount masks at this word size, derived not hardcoded;
* wire format (``to_wire``/``from_wire``) — every fused pipeline takes
  flat **int32** arrays (``wire_words_per_lane`` words per lane), so the
  pipeline ABI is layout-independent;
* vertical packing (``pack_planes``/``unpack_planes``) — horizontal
  words -> bit planes and back, built from any 32x32 bit-matrix
  transpose kernel (Pallas on TPU, the jnp oracle elsewhere): a 64-bit
  lane transposes as two 32x32 tiles (low/high words), so the existing
  transpose kernel serves every layout;
* raw packed-bitmap split (``raw_lanes``/``join_raw``/
  ``raw_lanes_per_word``) — how a caller-visible uint64 word maps onto
  dataplane lanes in the planewise raw mode (2 lanes at 32-bit words,
  1 lane at 64-bit words).

Layouts are frozen and hashable — a :class:`FusedProgram` carries its
layout, so the structure-keyed pipeline cache keys on it for free.
``LAYOUT32`` / ``LAYOUT64`` are the canonical instances; ``get_layout``
resolves a ``word_bits`` (or a layout, passed through) to one of them.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PlaneLayout:
    """One lane word format of the fused dataplane (frozen, hashable)."""

    name: str
    word_bits: int

    # ------------------------------------------------------------------ #
    # Lane dtype
    # ------------------------------------------------------------------ #

    @property
    def dtype_name(self) -> str:
        """Unsigned lane dtype name (valid for NumPy and jnp alike)."""
        return f"uint{self.word_bits}"

    @property
    def np_dtype(self):
        return np.dtype(self.dtype_name)

    @property
    def nbytes_per_word(self) -> int:
        return self.word_bits // 8

    def word_scalar(self, value: int, xp):
        """``value`` as a 0-d lane-dtype scalar of array module ``xp``
        (``numpy`` or ``jax.numpy``)."""
        return xp.asarray(value, self.dtype_name)

    def mask(self, width: int) -> int:
        """``width``-bit all-ones as a Python int (callers wrap it with
        :meth:`word_scalar` for the module they compute in)."""
        return (1 << width) - 1

    # ------------------------------------------------------------------ #
    # SWAR popcount constants (Hacker's Delight 5-2 at this word size)
    # ------------------------------------------------------------------ #

    @property
    def swar_consts(self) -> tuple[int, int, int, int]:
        """(m1, m2, m4, h01) repeating-byte masks for ``word_bits``."""
        reps = self.word_bits // 8

        def rep(byte: int) -> int:
            return int.from_bytes(bytes([byte]) * reps, "little")

        return rep(0x55), rep(0x33), rep(0x0F), rep(0x01)

    @property
    def popcount_shift(self) -> int:
        """Final SWAR shift: the count accumulates in the top byte."""
        return self.word_bits - 8

    # ------------------------------------------------------------------ #
    # Wire format: every pipeline ABI is flat int32 arrays
    # ------------------------------------------------------------------ #

    @property
    def wire_words_per_lane(self) -> int:
        return self.word_bits // 32

    def to_wire(self, lanes: np.ndarray) -> np.ndarray:
        """Flat lane-dtype array -> flat int32 wire array (a view when the
        input is contiguous; 64-bit lanes interleave as lo, hi)."""
        return np.ascontiguousarray(lanes).view(np.int32)

    def from_wire(self, wire) -> np.ndarray:
        """Flat int32 wire array (NumPy or device array) -> lane-dtype
        NumPy array."""
        arr = np.ascontiguousarray(np.asarray(wire, np.int32))
        return arr.view(self.np_dtype)

    # ------------------------------------------------------------------ #
    # Raw packed-bitmap mode: caller uint64 words <-> dataplane lanes
    # ------------------------------------------------------------------ #

    @property
    def raw_lanes_per_word(self) -> int:
        """Dataplane lanes per caller-visible uint64 word in raw mode."""
        return 64 // self.word_bits

    def raw_lanes(self, words: np.ndarray) -> np.ndarray:
        """Flat uint64 words -> flat lane-dtype array (bit-preserving
        reinterpretation; the 32-bit layout splits each word in two)."""
        return np.ascontiguousarray(words).view(self.np_dtype)

    def join_raw(self, lanes: np.ndarray) -> np.ndarray:
        """Inverse of :meth:`raw_lanes` (always copies — callers own the
        result)."""
        return np.ascontiguousarray(lanes).copy().view(np.uint64)

    # ------------------------------------------------------------------ #
    # Vertical packing: horizontal wire words <-> bit planes
    # ------------------------------------------------------------------ #

    def pack_planes(self, words, transpose, width: int):
        """Flat int32 wire array -> [width, n/32] int32 bit planes.

        ``transpose`` is any [32, G] -> [32, G] 32x32 bit-matrix
        transpose (``ref.bit_transpose32`` or the Pallas kernel). Lane
        count n must be a multiple of 32. A 64-bit lane is two stacked
        32x32 tiles: low words become planes 0..31, high words 32..63.
        """
        import jax.numpy as jnp

        wpl = self.wire_words_per_lane
        n = words.shape[0] // wpl
        g = n // 32
        parts = [transpose(words[k::wpl].reshape(g, 32).T)
                 for k in range(wpl)]
        planes = parts[0] if wpl == 1 else jnp.concatenate(parts)
        return planes[:width]

    def unpack_planes(self, planes, transpose, width: int):
        """[width, g] int32 bit planes -> flat int32 wire array (the
        inverse of :meth:`pack_planes`; missing high planes are zero)."""
        import jax.numpy as jnp

        g = planes.shape[1]
        if width < self.word_bits:
            planes = jnp.concatenate(
                [planes, jnp.zeros((self.word_bits - width, g),
                                   planes.dtype)])
        wpl = self.wire_words_per_lane
        parts = [transpose(planes[32 * k:32 * (k + 1)]).T.reshape(32 * g)
                 for k in range(wpl)]
        if wpl == 1:
            return parts[0]
        return jnp.stack(parts, axis=1).reshape(wpl * 32 * g)


LAYOUT32 = PlaneLayout(name="u32", word_bits=32)
LAYOUT64 = PlaneLayout(name="u64", word_bits=64)

_LAYOUTS = {32: LAYOUT32, 64: LAYOUT64}


def get_layout(word_bits) -> PlaneLayout:
    """Resolve ``word_bits`` (32/64, or a PlaneLayout passed through) to
    a canonical layout."""
    if isinstance(word_bits, PlaneLayout):
        return word_bits
    try:
        return _LAYOUTS[int(word_bits)]
    except (KeyError, TypeError, ValueError):
        raise ValueError(
            f"no plane layout with word_bits={word_bits!r}; "
            f"available: {sorted(_LAYOUTS)}") from None


def layout_for_width(width: int) -> PlaneLayout:
    """The narrowest canonical layout whose word holds ``width`` bits."""
    for bits in sorted(_LAYOUTS):
        if width <= bits:
            return _LAYOUTS[bits]
    raise ValueError(f"no plane layout covers width {width}")
