"""Pallas TPU kernel: charge-sharing Monte-Carlo inner loop.

The analog success-rate characterization (analog.py, Figs 4/11/14-16) is a
large batched computation: deviation = sum_i C_i (V_i - VDD/2) / (C_bl +
sum_i C_i) over [n_rows, n_bitlines] fields, repeated over patterns and
Monte-Carlo groups. This kernel fuses the row reduction in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
BLOCK = SUBLANE * LANE


def _cs_kernel(v_ref, c_ref, o_ref, *, n: int, vdd: float, c_bl: float):
    num = jnp.zeros(v_ref.shape[1:], jnp.float32)
    den = jnp.full(v_ref.shape[1:], c_bl, jnp.float32)
    for i in range(n):  # static unroll: n <= 32 rows
        c = c_ref[i]
        num = num + c * (v_ref[i] - 0.5 * vdd)
        den = den + c
    o_ref[...] = num / den


@functools.partial(jax.jit, static_argnames=("vdd", "c_bl", "interpret"))
def charge_share(v: jax.Array, caps: jax.Array, *, vdd: float, c_bl: float,
                 interpret: bool = False) -> jax.Array:
    """v, caps: [N, B] float32 -> dV [B] float32."""
    if v.shape != caps.shape:
        raise ValueError("shape mismatch")
    n, b = v.shape
    pad = (-b) % BLOCK
    vp = jnp.pad(v, ((0, 0), (0, pad))).astype(jnp.float32)
    cp = jnp.pad(caps, ((0, 0), (0, pad))).astype(jnp.float32)
    blocks = vp.shape[1] // BLOCK
    vb = vp.reshape(n, blocks, SUBLANE, LANE)
    cb = cp.reshape(n, blocks, SUBLANE, LANE)
    spec = pl.BlockSpec((n, 1, SUBLANE, LANE), lambda i: (0, i, 0, 0))
    out = pl.pallas_call(
        functools.partial(_cs_kernel, n=n, vdd=vdd, c_bl=c_bl),
        grid=(blocks,),
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((1, SUBLANE, LANE), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((blocks, SUBLANE, LANE), jnp.float32),
        interpret=interpret,
    )(vb, cb)
    return out.reshape(blocks * BLOCK)[:b]
