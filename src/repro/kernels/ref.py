"""Pure-jnp oracles for every Pallas kernel (single source of truth for
semantics; kernels are validated against these across shape/dtype sweeps)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def maj_n(x: jax.Array, threshold: int) -> jax.Array:
    """Packed-word majority: out bit = (popcount over N rows >= threshold).

    x: [N, W] int32/uint32 bit-planes. Returns [W] of x.dtype.

    This is the TPU-native form of PULSAR's many-input charge sharing
    (§5.2.2): one pass over N operand planes produces the MAJ-N plane.
    """
    n, _ = x.shape
    if not (1 <= threshold <= n):
        raise ValueError(f"threshold {threshold} not in [1,{n}]")
    bits = jnp.stack([(jax.lax.shift_right_logical(x, jnp.array(b, x.dtype))
                       & jnp.array(1, x.dtype)) for b in range(32)])
    counts = bits.sum(axis=1)  # [32, W] per-bit vote counts
    maj = (counts >= threshold).astype(x.dtype)
    out = jnp.zeros_like(x[0])
    for b in range(32):
        out = out | (maj[b] << jnp.array(b, x.dtype))
    return out


def maj_n_fast(x: jax.Array, threshold: int) -> jax.Array:
    """Bit-sliced carry-save implementation of maj_n (the Pallas kernel's
    algorithm, in jnp): K counter planes + overflow trick — ~6N int32 ops
    per word instead of the oracle's 32x bit-unpack (§Perf K0).
    Semantics identical to maj_n (validated in tests)."""
    n, w = x.shape
    if not (1 <= threshold <= n):
        raise ValueError(f"threshold {threshold} not in [1,{n}]")
    k = max(1, int(n).bit_length())
    init = (1 << k) - threshold
    planes = [jnp.full((w,), -1, jnp.int32) if (init >> j) & 1
              else jnp.zeros((w,), jnp.int32) for j in range(k)]
    overflow = jnp.zeros((w,), jnp.int32)
    xi = x.astype(jnp.int32)
    for i in range(n):
        carry = xi[i]
        for j in range(k):
            t = planes[j] ^ carry
            carry = planes[j] & carry
            planes[j] = t
        overflow = overflow | carry
    return overflow.astype(x.dtype)


def bitserial_add(a: jax.Array, b: jax.Array) -> jax.Array:
    """Vertical-layout ripple add: a, b: [width, W] bit-planes -> [width, W].

    Carry chain runs across planes: the PuM full-adder loop (alu.py) fused
    into one pass (carry = MAJ3(a,b,c), the paper's own carry identity)."""
    w = a.shape[0]
    outs = []
    carry = jnp.zeros_like(a[0])
    for j in range(w):
        s = a[j] ^ b[j] ^ carry
        carry = (a[j] & b[j]) | (carry & (a[j] ^ b[j]))
        outs.append(s)
    return jnp.stack(outs)


def bit_transpose32(x: jax.Array) -> jax.Array:
    """32x32 bit-matrix transpose (horizontal <-> vertical layout).

    x: [32, G] int32 — row k holds word k of G independent 32x32 tiles.
    Returns [32, G]: out[j] bit i == x[i] bit j (per tile).
    Hacker's Delight masked-swap network; the HD form transposes with both
    axes bit-reversed, so rows are loaded and stored in reversed order to
    obtain LSB-first semantics (index games only — no extra data movement).
    """
    rows = [x[31 - k] for k in range(32)]
    m = 0x0000FFFF
    j = 16
    while j != 0:
        k = 0
        while k < 32:
            mask = jnp.array(np.int32(np.uint32(m)), x.dtype)
            t = (rows[k] ^ jax.lax.shift_right_logical(
                rows[k + j], jnp.array(j, x.dtype))) & mask
            rows[k] = rows[k] ^ t
            rows[k + j] = rows[k + j] ^ (t << jnp.array(j, x.dtype))
            k = (k + j + 1) & ~j
        j >>= 1
        m = (m ^ (m << j)) & 0xFFFFFFFF if j else m
    return jnp.stack(rows[::-1])


def charge_share(v: jax.Array, caps: jax.Array, *, vdd: float,
                 c_bl: float) -> jax.Array:
    """Bitline deviation: v, caps [N, B] -> dV [B] (analog.py's core)."""
    num = jnp.sum(caps * (v - 0.5 * vdd), axis=0)
    den = c_bl + jnp.sum(caps, axis=0)
    return num / den


def multi_row_broadcast(src: jax.Array, n: int) -> jax.Array:
    """Multi-RowInit dataplane: one row plane -> n identical planes."""
    return jnp.broadcast_to(src[None], (n,) + src.shape)
