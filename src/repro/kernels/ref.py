"""Pure-jnp oracles for every Pallas kernel (single source of truth for
semantics; kernels are validated against these across shape/dtype sweeps)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def maj_n(x: jax.Array, threshold: int) -> jax.Array:
    """Packed-word majority: out bit = (popcount over N rows >= threshold).

    x: [N, W] int32/uint32 bit-planes. Returns [W] of x.dtype.

    This is the TPU-native form of PULSAR's many-input charge sharing
    (§5.2.2): one pass over N operand planes produces the MAJ-N plane.
    """
    n, _ = x.shape
    if not (1 <= threshold <= n):
        raise ValueError(f"threshold {threshold} not in [1,{n}]")
    bits = jnp.stack([(jax.lax.shift_right_logical(x, jnp.array(b, x.dtype))
                       & jnp.array(1, x.dtype)) for b in range(32)])
    counts = bits.sum(axis=1)  # [32, W] per-bit vote counts
    maj = (counts >= threshold).astype(x.dtype)
    out = jnp.zeros_like(x[0])
    for b in range(32):
        out = out | (maj[b] << jnp.array(b, x.dtype))
    return out


def maj_n_fast(x: jax.Array, threshold: int) -> jax.Array:
    """Bit-sliced carry-save implementation of maj_n (the Pallas kernel's
    algorithm, in jnp): K counter planes + overflow trick — ~6N int32 ops
    per word instead of the oracle's 32x bit-unpack (§Perf K0).
    Semantics identical to maj_n (validated in tests)."""
    n, w = x.shape
    if not (1 <= threshold <= n):
        raise ValueError(f"threshold {threshold} not in [1,{n}]")
    k = max(1, int(n).bit_length())
    init = (1 << k) - threshold
    planes = [jnp.full((w,), -1, jnp.int32) if (init >> j) & 1
              else jnp.zeros((w,), jnp.int32) for j in range(k)]
    overflow = jnp.zeros((w,), jnp.int32)
    xi = x.astype(jnp.int32)
    for i in range(n):
        carry = xi[i]
        for j in range(k):
            t = planes[j] ^ carry
            carry = planes[j] & carry
            planes[j] = t
        overflow = overflow | carry
    return overflow.astype(x.dtype)


def bitserial_add(a: jax.Array, b: jax.Array) -> jax.Array:
    """Vertical-layout ripple add: a, b: [width, W] bit-planes -> [width, W].

    Carry chain runs across planes: the PuM full-adder loop (alu.py) fused
    into one pass (carry = MAJ3(a,b,c), the paper's own carry identity)."""
    w = a.shape[0]
    outs = []
    carry = jnp.zeros_like(a[0])
    for j in range(w):
        s = a[j] ^ b[j] ^ carry
        carry = (a[j] & b[j]) | (carry & (a[j] ^ b[j]))
        outs.append(s)
    return jnp.stack(outs)


def bit_transpose32(x: jax.Array) -> jax.Array:
    """32x32 bit-matrix transpose (horizontal <-> vertical layout).

    x: [32, G] int32 — row k holds word k of G independent 32x32 tiles.
    Returns [32, G]: out[j] bit i == x[i] bit j (per tile).
    Hacker's Delight masked-swap network; the HD form transposes with both
    axes bit-reversed, so rows are loaded and stored in reversed order to
    obtain LSB-first semantics (index games only — no extra data movement).
    """
    rows = [x[31 - k] for k in range(32)]
    m = 0x0000FFFF
    j = 16
    while j != 0:
        k = 0
        while k < 32:
            mask = jnp.array(np.int32(np.uint32(m)), x.dtype)
            t = (rows[k] ^ jax.lax.shift_right_logical(
                rows[k + j], jnp.array(j, x.dtype))) & mask
            rows[k] = rows[k] ^ t
            rows[k + j] = rows[k + j] ^ (t << jnp.array(j, x.dtype))
            k = (k + j + 1) & ~j
        j >>= 1
        m = (m ^ (m << j)) & 0xFFFFFFFF if j else m
    return jnp.stack(rows[::-1])


def charge_share(v: jax.Array, caps: jax.Array, *, vdd: float,
                 c_bl: float) -> jax.Array:
    """Bitline deviation: v, caps [N, B] -> dV [B] (analog.py's core)."""
    num = jnp.sum(caps * (v - 0.5 * vdd), axis=0)
    den = c_bl + jnp.sum(caps, axis=0)
    return num / den


def multi_row_broadcast(src: jax.Array, n: int) -> jax.Array:
    """Multi-RowInit dataplane: one row plane -> n identical planes."""
    return jnp.broadcast_to(src[None], (n,) + src.shape)


# --------------------------------------------------------------------- #
# Vertical-layout plane algebra (fused-program building blocks)
#
# A *value* is a list of ``width`` same-shaped integer bit-plane arrays
# (plane j = bit j of every element). These helpers are pure jnp on the
# plane lists, so the same code traces inside a jax.jit pipeline AND
# inside a Pallas kernel body (kernels/fused_program.py uses both).
# --------------------------------------------------------------------- #


def _full_add(x, y, carry):
    """One full-adder plane step: (sum, carry-out); carry may be None
    (treated as zero without emitting ops)."""
    axb = x ^ y
    s = axb if carry is None else axb ^ carry
    c = x & y
    return s, (c if carry is None else c | (carry & axb))


def plane_add(a: list, b: list) -> list:
    """Ripple add, modulo 2^width (carry-out dropped): the fused form of
    bitserial_add on value lists."""
    out, carry = [], None
    for x, y in zip(a, b):
        s, carry = _full_add(x, y, carry)
        out.append(s)
    return out


def plane_sub(a: list, b: list) -> tuple[list, jax.Array]:
    """Borrow-ripple subtract modulo 2^width. Returns (difference planes,
    final borrow plane) — the borrow is the unsigned a < b predicate."""
    out, borrow = [], None
    for x, y in zip(a, b):
        xxy = x ^ y
        out.append(xxy if borrow is None else xxy ^ borrow)
        nb = ~x & y
        borrow = nb if borrow is None else nb | (borrow & ~xxy)
    return out, borrow


def plane_popcount(planes: list) -> list:
    """Per-element popcount over ``planes`` (each a 1-bit vertical number):
    pairwise carry-save adder tree -> ceil(log2(n+1)) count planes."""
    nums = [[p] for p in planes]
    while len(nums) > 1:
        nxt = []
        for i in range(0, len(nums) - 1, 2):
            a, b = nums[i], nums[i + 1]
            out, carry = [], None
            for j in range(max(len(a), len(b))):
                x = a[j] if j < len(a) else None
                y = b[j] if j < len(b) else None
                if y is None:
                    x, y = y, x
                if x is None:  # single operand + carry: half add
                    if carry is None:
                        out.append(y)
                    else:
                        out.append(y ^ carry)
                        carry = y & carry
                else:
                    s, carry = _full_add(x, y, carry)
                    out.append(s)
            if carry is not None:
                out.append(carry)
            nxt.append(out)
        if len(nums) % 2:
            nxt.append(nums[-1])
        nums = nxt
    return nums[0]


def plane_reduce(planes: list, kind: str) -> jax.Array:
    """AND/OR/XOR fold across an element's planes -> one 0/1 plane."""
    acc = planes[0]
    for p in planes[1:]:
        acc = acc & p if kind == "and" else \
            acc | p if kind == "or" else acc ^ p
    return acc


def plane_mul(a: list, b: list) -> list:
    """Shift-add multiply modulo 2^width: for each set bit j of ``b`` add
    ``a << j`` into the accumulator (partial products are the AND of the
    shifted planes with b's plane j — the fused form of alu.py's bit-serial
    multiplier, built entirely from plane_add)."""
    width = len(a)
    acc = [x & b[0] for x in a]
    for j in range(1, width):
        # (a << j) & b[j], restricted to the planes that survive the
        # modulo-2^width truncation: planes [j, width) of the accumulator.
        partial = [x & b[j] for x in a[:width - j]]
        acc = acc[:j] + plane_add(acc[j:], partial)
    return acc


def plane_divmod(a: list, b: list) -> tuple[list, list]:
    """Restoring long division on plane lists: (quotient, remainder).

    Classic MSB-first schoolbook division over the add/sub planes: shift
    the partial remainder left one plane (tracking the bit shifted out of
    plane width-1 — if set, the remainder already exceeds any width-bit
    divisor), bring in the next dividend bit, and use plane_sub's borrow
    as the ``remainder >= divisor`` predicate to select per lane between
    the restored and subtracted remainder (a bitwise mux — every lane
    divides independently).

    Division by zero follows the eager NumPy semantics the engine exposes
    (``x // 0 == 0`` and ``x % 0 == 0`` for unsigned ints): lanes whose
    divisor is zero are masked to zero in both outputs.
    """
    width = len(a)
    zero = a[0] ^ a[0]
    rem = [zero] * width
    quot: list = [None] * width
    for i in reversed(range(width)):
        hi = rem[width - 1]            # bit shifted out: rem >= 2**width
        rem = [a[i]] + rem[:-1]        # rem = (rem << 1) | dividend bit i
        diff, borrow = plane_sub(rem, b)
        qbit = hi | ~borrow            # rem >= b  (per lane)
        quot[i] = qbit
        rem = [(qbit & d) | (~qbit & r) for d, r in zip(diff, rem)]
    nonzero = plane_reduce(b, "or")    # per-lane divisor != 0 mask
    return ([q & nonzero for q in quot], [r & nonzero for r in rem])
