# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# Public dispatch surface (TPU: Pallas; CPU: jnp oracle — see ops.py):

from repro.kernels.fused_program import (FusedOp, FusedProgram,  # noqa: F401
                                         get_pipeline)
from repro.kernels.ops import (bit_transpose32, bitserial_add,  # noqa: F401
                               charge_share, maj_n, run_fused_program)
