"""Pallas TPU kernel: 32x32 bit-matrix transpose (layout conversion).

Horizontal (one uint32 word per element) <-> vertical (bit-planes along the
"bitline"/lane axis) conversion is the staging hot-spot of every bit-serial
PuM framework (§2.4). On TPU we keep tiles in VMEM and run the Hacker's
Delight masked-swap network on the 32 sublane rows; the G tile axis maps to
VPU lanes, so all tiles transpose in parallel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

LANE = 128
G_BLOCK = LANE  # tiles per grid step (one lane row)


def _transpose_kernel(x_ref, o_ref):
    # Reversed load/store order converts the HD bit-reversed transpose to
    # LSB-first semantics (see ref.bit_transpose32).
    rows = [x_ref[31 - k] for k in range(32)]
    m = 0x0000FFFF
    j = 16
    while j != 0:
        mask = jnp.array(np.int32(np.uint32(m)), jnp.int32)
        shift = jnp.array(j, jnp.int32)
        k = 0
        while k < 32:
            t = (rows[k] ^ jax.lax.shift_right_logical(rows[k + j], shift)) & mask
            rows[k] = rows[k] ^ t
            rows[k + j] = rows[k + j] ^ (t << shift)
            k = (k + j + 1) & ~j
        j >>= 1
        if j:
            m = (m ^ (m << j)) & 0xFFFFFFFF
    for k in range(32):
        o_ref[k] = rows[31 - k]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bit_transpose32(x: jax.Array, interpret: bool = False) -> jax.Array:
    """x: [32, G] int32 (row k = word k of G tiles) -> [32, G] transposed."""
    if x.shape[0] != 32:
        raise ValueError("leading dim must be 32")
    g = x.shape[1]
    pad = (-g) % (8 * LANE)
    xp = jnp.pad(x, ((0, 0), (0, pad))).astype(jnp.int32)
    gp = xp.shape[1]
    blocks = gp // (8 * LANE)
    xb = xp.reshape(32, blocks, 8, LANE)
    out = pl.pallas_call(
        _transpose_kernel,
        grid=(blocks,),
        in_specs=[pl.BlockSpec((32, 1, 8, LANE), lambda i: (0, i, 0, 0))],
        out_specs=pl.BlockSpec((32, 1, 8, LANE), lambda i: (0, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((32, blocks, 8, LANE), jnp.int32),
        interpret=interpret,
    )(xb)
    return out.reshape(32, gp)[:, :g].astype(x.dtype)
