"""Jit'd dispatch wrappers for the Pallas kernels.

On TPU the Pallas path runs natively; on CPU (this container) the wrappers
dispatch to the jnp oracle by default — Pallas interpret mode executes the
kernel body in Python per grid step and is for validation, not speed. Tests
exercise interpret=True explicitly (tests/kernels/).

Layout conventions (docs/architecture.md): *horizontal* operands are flat
packed words (element i = word i); *vertical* operands are bit-plane
stacks ``[width, W]`` where plane j holds bit j of every element
(``bit_transpose32`` converts 32x32 tiles between the two). ``maj_n`` /
``bitserial_add`` / ``run_fused_program`` operate on vertical planes;
values are unsigned modulo 2**width.
"""

from __future__ import annotations

from repro.backends import on_tpu as _on_tpu
from repro.kernels import ref
from repro.kernels.bit_transpose import bit_transpose32 as _pl_transpose
from repro.kernels.bitserial_add import bitserial_add as _pl_add
from repro.kernels.charge_share import charge_share as _pl_cs
from repro.kernels.fused_program import (FusedProgram, run_program_pallas,
                                         run_program_ref)
from repro.kernels.maj_n import maj_n as _pl_maj


def maj_n(x, threshold: int, force_pallas: bool = False,
          interpret: bool = False):
    if _on_tpu() or force_pallas:
        return _pl_maj(x, threshold, interpret=interpret or not _on_tpu())
    # CPU: the bit-sliced form beats the unpack-sum oracle ~20x (§Perf K0).
    return ref.maj_n_fast(x, threshold)


def bitserial_add(a, b, force_pallas: bool = False, interpret: bool = False):
    if _on_tpu() or force_pallas:
        return _pl_add(a, b, interpret=interpret or not _on_tpu())
    return ref.bitserial_add(a, b)


def bit_transpose32(x, force_pallas: bool = False, interpret: bool = False):
    if _on_tpu() or force_pallas:
        return _pl_transpose(x, interpret=interpret or not _on_tpu())
    return ref.bit_transpose32(x)


def charge_share(v, caps, *, vdd: float, c_bl: float,
                 force_pallas: bool = False, interpret: bool = False):
    if _on_tpu() or force_pallas:
        return _pl_cs(v, caps, vdd=vdd, c_bl=c_bl,
                      interpret=interpret or not _on_tpu())
    return ref.charge_share(v, caps, vdd=vdd, c_bl=c_bl)


def run_fused_program(program: FusedProgram, x, force_pallas: bool = False,
                      interpret: bool = False):
    """Evaluate a fused program on *vertical plane stacks*: x [n_in, width,
    W] int32 -> [n_out, width, W]. Like the other wrappers here, the CPU
    fallback is the jnp oracle (validation form). Callers holding flat
    horizontal operands — the engine's flush() — should use
    ``fused_program.get_pipeline`` instead: on CPU it switches to the
    word-domain evaluator, which is the actual speed path."""
    if _on_tpu() or force_pallas:
        return run_program_pallas(program, x,
                                  interpret=interpret or not _on_tpu())
    return run_program_ref(program, x)
