"""Pallas TPU kernel: MAJ-N over packed bit-planes.

The TPU-native adaptation of PULSAR's many-input charge sharing (§5.2.2):
one kernel pass streams N operand bit-planes HBM->VMEM and reduces them
in-register with a bit-sliced carry-save counter — N+1 planes of traffic
for an N-input majority, vs 2(N-1)-ish planes for a chained MAJ3 tree
(the same command-count argument the paper makes for DRAM).

Counter trick: initialize a K-bit bit-sliced counter (K = ceil(log2(N+1)))
to 2^K - threshold in every bit lane; after accumulating the N vote planes,
lanes whose count reached ``threshold`` have overflowed past 2^K — the OR of
carry-outs is exactly the majority plane. All ops are VPU int32 logicals.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
BLOCK_WORDS = SUBLANE * LANE  # one (8,128) int32 tile per grid step


def _maj_kernel(x_ref, o_ref, *, n: int, k: int, init: int):
    shape = x_ref.shape[1:]  # (1, 8, 128)
    planes = [jnp.full(shape, -1, jnp.int32) if (init >> j) & 1
              else jnp.zeros(shape, jnp.int32) for j in range(k)]
    overflow = jnp.zeros(shape, jnp.int32)
    for i in range(n):  # static unroll: N <= 32
        carry = x_ref[i]
        for j in range(k):
            t = planes[j] ^ carry
            carry = planes[j] & carry
            planes[j] = t
        overflow = overflow | carry
    o_ref[...] = overflow


@functools.partial(jax.jit, static_argnames=("threshold", "interpret"))
def maj_n(x: jax.Array, threshold: int, interpret: bool = False) -> jax.Array:
    """x: [N, W] int32 packed bit-planes -> [W] majority plane."""
    n, w = x.shape
    if not (1 <= threshold <= n):
        raise ValueError(f"threshold {threshold} not in [1,{n}]")
    k = max(1, int(n).bit_length())  # counter width (overflow separate)
    init = (1 << k) - threshold
    pad = (-w) % BLOCK_WORDS
    xp = jnp.pad(x, ((0, 0), (0, pad))).astype(jnp.int32)
    wp = xp.shape[1]
    blocks = wp // BLOCK_WORDS
    xb = xp.reshape(n, blocks, SUBLANE, LANE)
    out = pl.pallas_call(
        functools.partial(_maj_kernel, n=n, k=k, init=init),
        grid=(blocks,),
        in_specs=[pl.BlockSpec((n, 1, SUBLANE, LANE),
                               lambda i: (0, i, 0, 0))],
        out_specs=pl.BlockSpec((1, SUBLANE, LANE), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((blocks, SUBLANE, LANE), jnp.int32),
        interpret=interpret,
    )(xb)
    return out.reshape(wp)[:w].astype(x.dtype)
