"""Optimizers (AdamW, SGD+momentum), LR schedules, global-norm clipping.

Pure-JAX (no optax): states are pytrees mirroring params, so every sharding
rule that applies to a param applies to its optimizer moments for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1


def adamw_init(params: Params) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> tuple[Params, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(cfg: AdamWConfig, params: Params, grads: Params,
                 state: dict, lr_scale: jax.Array | float = 1.0
                 ) -> tuple[Params, dict]:
    step = state["step"] + 1
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.learning_rate * lr_scale

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        p32 = p.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        nhat = nu / bc2
        p32 = p32 - lr * (mhat / (jnp.sqrt(nhat) + cfg.eps)
                          + cfg.weight_decay * p32)
        return p32.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}


def sgd_momentum_init(params: Params) -> dict:
    return {"mom": jax.tree.map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32)}


def sgd_momentum_update(params: Params, grads: Params, state: dict,
                        lr: float, momentum: float = 0.9
                        ) -> tuple[Params, dict]:
    def upd(p, g, m):
        m = momentum * m + g.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["mom"])
    out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
    return (treedef.unflatten([o[0] for o in out]),
            {"mom": treedef.unflatten([o[1] for o in out]),
             "step": state["step"] + 1})


def warmup_cosine(step: jax.Array, warmup: int, total: int,
                  floor: float = 0.1) -> jax.Array:
    """LR multiplier: linear warmup then cosine decay to `floor`."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, warmup)
    prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0, 1)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)
