"""Int8 gradient compression with error feedback (distributed-optimization
trick for cross-pod DP all-reduce).

Per-tensor symmetric int8 quantization; the residual (quantization error) is
carried in the optimizer-side error buffer and re-added next step, making the
compressed SGD trajectory track the exact one (error-feedback guarantee).
On the wire this cuts DP all-reduce bytes 4x (fp32) / 2x (bf16); the dry-run
roofline's collective term reflects it when enabled.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any


def init_error(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """fp -> (int8 payload, scale)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads_with_feedback(grads: Params, error: Params
                                 ) -> tuple[Params, Params]:
    """Returns (decompressed grads as seen post-all-reduce, new error).

    In the jit graph, quantize -> (all-reduce happens on the int8 payload
    under GSPMD when the caller puts it on the wire) -> dequantize. Here we
    fuse the round trip and keep the residual."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress(corrected)
        deq = decompress(q, s)
        return deq.astype(g.dtype), corrected - deq
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
