"""Gradient compression for cross-pod DP all-reduce: int8 with error
feedback, plus 1-bit sign/mask bitmaps routed through the PuM dataplane.

Per-tensor symmetric int8 quantization; the residual (quantization error) is
carried in the optimizer-side error buffer and re-added next step, making the
compressed SGD trajectory track the exact one (error-feedback guarantee).
On the wire this cuts DP all-reduce bytes 4x (fp32) / 2x (bf16); the dry-run
roofline's collective term reflects it when enabled.

The 1-bit path (signSGD-style) compresses a gradient tensor to two packed
uint64 bitmaps — per-element sign and a magnitude mask — plus one scale.
Combining bitmaps is bulk bitwise work, exactly PULSAR's sweet spot, so it
routes through :mod:`repro.pum`'s **raw packed-bitmap planewise path**
(``&``/``|``/``^`` on full-range uint64 words, split into 2x32-bit
dataplane lanes by the engine): the wire payload is ``sign & mask``, and
cross-worker sign agreement is a bitwise 3-way majority
(``MAJ3(a,b,c) = (a&b) | (b&c) | (a&c)`` — the paper's own carry/majority
identity, here over packed bitmaps). Eager and fused devices produce
bit-identical bitmaps with identical cost-plane charges (tested in
tests/train).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

import repro.pum as pum

Params = Any


def init_error(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """fp -> (int8 payload, scale)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads_with_feedback(grads: Params, error: Params
                                 ) -> tuple[Params, Params]:
    """Returns (decompressed grads as seen post-all-reduce, new error).

    In the jit graph, quantize -> (all-reduce happens on the int8 payload
    under GSPMD when the caller puts it on the wire) -> dequantize. Here we
    fuse the round trip and keep the residual."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress(corrected)
        deq = decompress(q, s)
        return deq.astype(g.dtype), corrected - deq
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


# --------------------------------------------------------------------- #
# 1-bit sign/mask bitmaps on the PuM raw planewise path
# --------------------------------------------------------------------- #


def pack_bitmap(bits: np.ndarray) -> np.ndarray:
    """Pack a flat boolean vector into uint64 words, LSB-first (bit i of
    word w = element 64*w + i); zero-padded to a whole word count."""
    bits = np.asarray(bits, bool).ravel()
    packed = np.packbits(bits, bitorder="little")
    pad = (-packed.size) % 8
    if pad:
        packed = np.pad(packed, (0, pad))
    return packed.view(np.uint64)


def unpack_bitmap(words: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`pack_bitmap`: the first ``n`` bits as booleans."""
    return np.unpackbits(np.asarray(words, np.uint64).view(np.uint8),
                         bitorder="little")[:n].astype(bool)


def sign_mask_bitmaps(t, tau: float) -> tuple[np.ndarray, np.ndarray,
                                              float]:
    """Host-side quantization front end: (sign_words, mask_words, scale)
    for one tensor. ``sign`` bit = (t < 0); ``mask`` bit = (|t| >= tau);
    ``scale`` = mean magnitude of the masked elements (the signSGD
    reconstruction scale)."""
    flat = np.asarray(t, np.float32).ravel()
    sign = flat < 0
    mask = np.abs(flat) >= tau
    scale = float(np.abs(flat[mask]).mean()) if mask.any() else 0.0
    return pack_bitmap(sign), pack_bitmap(mask), scale


def pum_wire_bitmap(sign_words: np.ndarray, mask_words: np.ndarray,
                    device: "pum.Device | None" = None) -> np.ndarray:
    """The wire payload ``sign & mask`` computed on the PuM dataplane
    (raw packed-bitmap planewise path — full-range uint64 words)."""
    dev = device or pum.default_device()
    return (dev.asarray(sign_words) & mask_words).to_numpy()


def pum_sign_majority3(a: np.ndarray, b: np.ndarray, c: np.ndarray,
                       device: "pum.Device | None" = None) -> np.ndarray:
    """Bitwise 3-way majority of packed sign bitmaps (cross-worker sign
    agreement for majority-vote signSGD): MAJ3 = (a&b) | (b&c) | (a&c),
    five planewise ops on the PuM dataplane."""
    dev = device or pum.default_device()
    pa = dev.asarray(a)
    ab, bc, ac = pa & b, dev.asarray(b) & c, pa & c
    return ((ab | bc) | ac).to_numpy()


def decode_sign_bitmaps(wire_words: np.ndarray, mask_words: np.ndarray,
                        scale: float, n: int) -> np.ndarray:
    """Reconstruct the dense float32 tensor from the 1-bit payload:
    +-scale where the mask bit is set (sign from the wire bitmap), 0
    elsewhere."""
    sign = unpack_bitmap(wire_words, n)
    mask = unpack_bitmap(mask_words, n)
    return np.where(mask, np.where(sign, -scale, scale), 0.0) \
        .astype(np.float32)


def compress_grads_sign_with_feedback(grads: Params, error: Params,
                                      device: "pum.Device | None" = None,
                                      tau_factor: float = 1.0
                                      ) -> tuple[Params, Params]:
    """1-bit analogue of :func:`compress_grads_with_feedback`: per tensor,
    quantize ``grad + error`` to sign/mask bitmaps (threshold ``tau =
    tau_factor * mean|g|``), AND them into the wire payload **on the PuM
    dataplane**, and carry the reconstruction residual as the next error.
    Returns (decompressed grads, new error)."""
    dev = device or pum.default_device()

    def one(g, e):
        corrected = np.asarray(g, np.float32) + np.asarray(e, np.float32)
        tau = tau_factor * float(np.abs(corrected).mean())
        sign_w, mask_w, scale = sign_mask_bitmaps(corrected, tau)
        wire_w = pum_wire_bitmap(sign_w, mask_w, dev)
        deq = decode_sign_bitmaps(wire_w, mask_w, scale,
                                  corrected.size).reshape(corrected.shape)
        return (jnp.asarray(deq, jnp.asarray(g).dtype),
                jnp.asarray(corrected - deq, jnp.float32))

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
