"""Training loop: jit'd step with donation, grad accumulation, clipping,
warmup-cosine schedule, optional int8 grad compression, checkpoint/resume,
heartbeat + straggler hooks.

The same build_train_step() powers the dry-run lowering (launch/dryrun.py)
and the real CPU training example (examples/train_lm.py) — one code path.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig, TrainConfig
from repro.models.model import init_params, loss_fn
from repro.train import grad_compress
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   clip_by_global_norm, warmup_cosine)


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, key: jax.Array):
    params = init_params(cfg, key)
    opt = adamw_init(params)
    if tcfg.grad_compression:
        opt["err"] = grad_compress.init_error(params)
    return params, opt


def build_train_step(cfg: ModelConfig, tcfg: TrainConfig
                     ) -> Callable[..., Any]:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).
    Microbatching: batch's leading dim is split into tcfg.microbatches
    accumulation slices via lax.scan (keeps peak activation memory flat)."""
    acfg = AdamWConfig(learning_rate=tcfg.learning_rate,
                       beta1=tcfg.beta1, beta2=tcfg.beta2, eps=tcfg.eps,
                       weight_decay=tcfg.weight_decay)

    def loss_of(params, batch):
        return loss_fn(cfg, params, batch, z_loss=tcfg.z_loss,
                       moe_aux=tcfg.moe_aux_loss)

    def train_step(params, opt_state, batch):
        mb = tcfg.microbatches
        if mb > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(mb, b // mb, *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc(carry, mb_batch):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_of, has_aux=True)(
                    params, mb_batch)
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            (gsum, lsum), _ = jax.lax.scan(acc, (zero, 0.0), micro)
            grads = jax.tree.map(lambda g: g / mb, gsum)
            loss = lsum / mb
            metrics = {"nll": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        if tcfg.grad_compression:
            grads, new_err = grad_compress.compress_grads_with_feedback(
                grads, opt_state["err"])
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr_scale = warmup_cosine(opt_state["step"], tcfg.warmup_steps,
                                 tcfg.total_steps)
        core_state = {k: opt_state[k] for k in ("mu", "nu", "step")}
        new_params, new_core = adamw_update(acfg, params, grads, core_state,
                                            lr_scale)
        new_opt = dict(new_core)
        if tcfg.grad_compression:
            new_opt["err"] = new_err
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = gnorm
        metrics["lr_scale"] = lr_scale
        return new_params, new_opt, metrics

    return train_step


@dataclasses.dataclass
class TrainLoopHooks:
    on_step: Callable[[int, dict, float], None] | None = None
    heartbeat: Callable[[float], None] | None = None


def train_loop(cfg: ModelConfig, tcfg: TrainConfig, data_iter,
               n_steps: int, checkpoint=None, resume: bool = True,
               hooks: TrainLoopHooks | None = None,
               jit_kwargs: dict | None = None):
    """Run n_steps; returns (params, opt_state, history)."""
    hooks = hooks or TrainLoopHooks()
    params, opt = init_train_state(cfg, tcfg, jax.random.PRNGKey(tcfg.seed))
    start = 0
    if checkpoint is not None and resume:
        latest = checkpoint.latest_step()
        if latest is not None:
            params, opt, meta = checkpoint.restore(latest, params, opt)
            start = meta["step"]
    step_fn = jax.jit(build_train_step(cfg, tcfg),
                      donate_argnums=(0, 1), **(jit_kwargs or {}))
    history = []
    for step in range(start, n_steps):
        batch = next(data_iter)
        t0 = time.perf_counter()
        params, opt, metrics = step_fn(params, opt, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        if hooks.heartbeat:
            hooks.heartbeat(dt)
        history.append({k: float(v) for k, v in metrics.items()})
        if hooks.on_step:
            hooks.on_step(step, history[-1], dt)
        if checkpoint is not None and tcfg.checkpoint_every and \
                (step + 1) % tcfg.checkpoint_every == 0:
            checkpoint.save(step + 1, params, opt)
    if checkpoint is not None:
        checkpoint.save(n_steps, params, opt)
        checkpoint.wait()
    return params, opt, history
