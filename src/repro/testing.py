"""Fixed-seed fallbacks for the optional ``hypothesis`` dependency.

Tests import property-testing decorators via::

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from repro.testing import given, settings, st

When hypothesis is absent, ``given`` degrades to running the test body over
a deterministic, fixed-seed sample of each strategy (no shrinking, no
database) so the suite still collects and exercises the properties.
"""

from __future__ import annotations

import functools
import inspect

import numpy as np

_FALLBACK_SEED = 0xF411BACC
_MAX_EXAMPLES = 25  # cap fallback sampling; hypothesis gets the full budget


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)


class st:
    """Mirror of the ``hypothesis.strategies`` entry points the tests use."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        seq = list(elements)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


def settings(max_examples: int = 10, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kw):
            # Read at call time: @settings may sit above @given (it then
            # decorates the wrapper) or below it (it decorated fn).
            n = min(getattr(wrapper, "_fallback_max_examples",
                            getattr(fn, "_fallback_max_examples", 10)),
                    _MAX_EXAMPLES)
            rng = np.random.default_rng(_FALLBACK_SEED)
            for _ in range(n):
                drawn = {k: s.example(rng) for k, s in strategies.items()}
                fn(*args, **drawn, **kw)
        # The drawn params are filled here, not by pytest: hide the original
        # signature so pytest does not look for same-named fixtures.
        wrapper.__dict__.pop("__wrapped__", None)
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco
