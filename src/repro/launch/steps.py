"""Step builders + shape-only input specs for every (arch x shape) cell.

``input_specs`` returns weak-type-correct ShapeDtypeStruct stand-ins (no
device allocation); ``build_cell`` returns the jit-able step function plus
the full argument spec/sharding pytrees — shared by the multi-pod dry-run,
the roofline analysis, and (with real arrays) the train/serve drivers.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import (ModelConfig, ShapeConfig, TrainConfig,
                               get_config)
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        param_shardings, replicated)
from repro.models.model import (decode_step, init_cache, init_params, prefill)
from repro.train.optimizer import adamw_init
from repro.train.trainer import build_train_step

SDS = jax.ShapeDtypeStruct


def _sds(shape, dtype):
    return SDS(tuple(shape), jnp.dtype(dtype))


# ----------------------------------------------------------------------- #
# Input specs per cell
# ----------------------------------------------------------------------- #

def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Training/prefill batch stand-ins. For enc-dec, the seq budget splits
    between source frames and target tokens; for VLM, patch tokens come out
    of the text budget (DESIGN.md shape notes)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.encoder_decoder:
            half = s // 2
            return {"frames": _sds((b, half, cfg.d_model), "float32"),
                    "tokens": _sds((b, half + 1), "int32")}
        if cfg.frontend == "vision":
            n_txt = s - cfg.n_frontend_tokens
            return {"patches": _sds((b, cfg.n_frontend_tokens, cfg.d_model),
                                    "float32"),
                    "tokens": _sds((b, n_txt + 1), "int32")}
        return {"tokens": _sds((b, s + 1), "int32")}
    # prefill
    if cfg.encoder_decoder:
        return {"frames": _sds((b, cfg.n_frontend_tokens, cfg.d_model),
                               "float32"),
                "tokens": _sds((b, s), "int32")}
    if cfg.frontend == "vision":
        return {"patches": _sds((b, cfg.n_frontend_tokens, cfg.d_model),
                                "float32"),
                "tokens": _sds((b, s - cfg.n_frontend_tokens), "int32")}
    return {"tokens": _sds((b, s), "int32")}


def params_specs(cfg: ModelConfig, dtype: str | None = None) -> Any:
    tree = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    if dtype is None:
        return tree
    dt = jnp.dtype(dtype)

    def cast(x):
        return SDS(x.shape, dt) if jnp.issubdtype(x.dtype, jnp.floating) \
            else x
    return jax.tree.map(cast, tree)


def opt_specs(params_tree: Any) -> Any:
    return jax.eval_shape(adamw_init, params_tree)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    b, s = shape.global_batch, shape.seq_len
    return jax.eval_shape(
        lambda: init_cache(cfg, b, s, jnp.bfloat16))


def input_specs(arch: str, shape: ShapeConfig) -> dict:
    """Public stand-in API (deliverable e.2): every model input as a
    ShapeDtypeStruct, keyed by argument name."""
    cfg = get_config(arch)
    if shape.kind == "train":
        params = params_specs(cfg)
        return {"params": params, "opt_state": opt_specs(params),
                "batch": batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"params": params_specs(cfg, "bfloat16"),
                "batch": batch_specs(cfg, shape)}
    spec = {"params": params_specs(cfg, "bfloat16"),
            "caches": cache_specs(cfg, shape),
            "token": _sds((shape.global_batch,), "int32"),
            "pos": _sds((shape.global_batch,), "int32")}
    if cfg.encoder_decoder:
        spec["memory"] = _sds((shape.global_batch, cfg.n_frontend_tokens,
                               cfg.d_model), "bfloat16")
    return spec


# ----------------------------------------------------------------------- #
# Cell = (fn, arg specs, in_shardings, out_shardings, donate)
# ----------------------------------------------------------------------- #

@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    fn: Callable
    args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple

    def lower(self):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate_argnums)
        return jitted.lower(*self.args)


def build_cell(arch: str, shape: ShapeConfig, mesh: Mesh,
               tcfg: TrainConfig | None = None,
               overrides: dict | None = None) -> Cell:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    tcfg = tcfg or TrainConfig()
    # Memory-tier rule for training (§Perf P1/H2):
    #   small: params+moments replicated over data (pure TP+DP),
    #   medium (moments don't fit TP-only): ZeRO-2-style — params TP-only,
    #     moments FSDP over data (grads reduce-scatter + params re-gather,
    #     same wire bytes as the plain all-reduce; NO per-layer weight
    #     gathers in fwd/bwd),
    #   huge (params alone don't fit TP-only): full ZeRO-3 FSDP.
    param_fsdp = cfg.param_count() * 4 / 16 > 10e9
    opt_fsdp = param_fsdp or cfg.param_count() * 12 / 16 > 12e9
    if shape.kind == "train":
        params = params_specs(cfg)
        opt = opt_specs(params)
        batch = batch_specs(cfg, shape)
        p_sh = param_shardings(cfg, mesh, params, fsdp=param_fsdp)
        o_sh = {"mu": param_shardings(cfg, mesh, opt["mu"], fsdp=opt_fsdp),
                "nu": param_shardings(cfg, mesh, opt["nu"], fsdp=opt_fsdp),
                "step": NamedSharding(mesh, P())}
        b_sh = batch_shardings(mesh, batch)
        fn = build_train_step(cfg, tcfg)
        return Cell(arch, shape, fn, (params, opt, batch),
                    (p_sh, o_sh, b_sh), (p_sh, o_sh, None),
                    donate_argnums=(0, 1))
    # Serving: TP-only weights (latency path) unless the bf16 TP shard
    # exceeds HBM (deepseek-v2-class -> FSDP-gathered weights); SMALL models
    # (<4 GB bf16) instead replicate weights and run sequence-parallel on
    # the model axis — no per-layer FFN all-reduce at all (§Perf H1.2).
    pbytes = cfg.param_count() * 2
    serve_fsdp = pbytes / 16 > 12e9
    serve_sp = pbytes <= 4e9
    if serve_sp and shape.kind in ("prefill", "decode"):
        cfg = dataclasses.replace(cfg, serve_seq_parallel=True)
    if shape.kind == "prefill":
        params = params_specs(cfg, "bfloat16")
        batch = batch_specs(cfg, shape)
        p_sh = param_shardings(cfg, mesh, params, fsdp=serve_fsdp,
                               tp=not serve_sp)
        b_sh = batch_shardings(mesh, batch)
        caches = cache_specs(cfg, shape)
        c_sh = cache_shardings(cfg, mesh, caches)

        def prefill_fn(p, b):
            logits, caches_out, memory = prefill(cfg, p, b, shape.seq_len)
            return logits, caches_out, memory

        mem_sh = None
        return Cell(arch, shape, prefill_fn, (params, batch),
                    (p_sh, b_sh), (None, c_sh, mem_sh), donate_argnums=())
    # decode
    params = params_specs(cfg, "bfloat16")
    caches = cache_specs(cfg, shape)
    p_sh = param_shardings(cfg, mesh, params, fsdp=serve_fsdp,
                           tp=not serve_sp)
    c_sh = cache_shardings(cfg, mesh, caches)
    tok = _sds((shape.global_batch,), "int32")
    pos = _sds((shape.global_batch,), "int32")
    t_sh = batch_shardings(mesh, tok)
    args: tuple = (params, caches, tok, pos)
    in_sh: tuple = (p_sh, c_sh, t_sh, t_sh)
    if cfg.encoder_decoder:
        mem = _sds((shape.global_batch, cfg.n_frontend_tokens, cfg.d_model),
                   "bfloat16")
        m_sh = batch_shardings(mesh, mem)

        def decode_fn(p, c, t, q, memory):
            return decode_step(cfg, p, c, t, q, memory=memory)

        args = args + (mem,)
        in_sh = in_sh + (m_sh,)
    else:
        def decode_fn(p, c, t, q):
            return decode_step(cfg, p, c, t, q)

    return Cell(arch, shape, decode_fn, args, in_sh, (None, c_sh),
                donate_argnums=(1,))
