"""Production mesh builders.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — required for the smoke tests / benches to
see 1 CPU device while dryrun.py forces 512 host devices.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None):
    """Small mesh over whatever devices exist (CPU tests)."""
    n = n_devices or len(jax.devices())
    if n % 2 == 0:
        return jax.make_mesh((n // 2, 2), ("data", "model"))
    return jax.make_mesh((n, 1), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that carry the batch dimension (pod composes with data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1
