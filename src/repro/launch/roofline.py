"""Roofline analysis (deliverable g): three-term model per (arch x shape).

Consumes dry-run records (launch/dryrun.py JSONL) and produces the
EXPERIMENTS.md §Roofline table.

Sources & corrections (documented in EXPERIMENTS.md §Dry-run caveats):
  * XLA cost_analysis counts while-loop bodies ONCE (verified empirically:
    an 8-step scan of matmuls reports 1 matmul of FLOPs), so every scanned
    path (layer stacks, chunked attention) under-reports — we therefore use
    an ANALYTIC per-cell FLOPs/bytes model (exact layer arithmetic from the
    configs) for the compute/memory terms, and report the raw HLO number as
    a cross-check ("hlo_flops_raw").
  * Collective bytes are parsed from the post-SPMD HLO with loop-body
    instructions bucketed separately; the body bucket is multiplied by the
    cell's dominant loop trip count (the layer scan).

Terms (TPU v5e-class constants, per chip):
    compute_s    = analytic_FLOPs / (n_chips * 197e12)
    memory_s     = analytic_HBM_bytes_per_chip / 819e9
    collective_s = (main + trip*region weighted bytes) / 50e9
"""

from __future__ import annotations

import argparse
import dataclasses
import json

from repro.config.base import LM_SHAPES, get_config
from repro.models.model import uniform_serving

# TPU v5e-class hardware constants (per chip).
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link


# --------------------------------------------------------------------- #
# Analytic FLOPs model (forward, per token, per layer)
# --------------------------------------------------------------------- #

def _attn_flops_per_token(cfg, ctx: float) -> float:
    d = cfg.d_model
    if cfg.attn_free:
        return 0.0
    if cfg.attn_kind == "mla":
        dq, dkv = cfg.q_lora_rank, cfg.kv_lora_rank
        dn, dr, dv = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                      cfg.v_head_dim)
        h = cfg.n_heads
        proj = (2 * d * dq + 2 * dq * h * (dn + dr)
                + 2 * d * (dkv + dr) + 2 * dkv * h * (dn + dv)
                + 2 * h * dv * d)
        attn = 2 * ctx * h * (dn + dr) + 2 * ctx * h * dv
        return proj + attn
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    proj = 2 * d * dh * (h + 2 * hkv) + 2 * h * dh * d
    attn = 4 * ctx * h * dh  # scores + probs*V
    return proj + attn


def _ffn_flops_per_token(cfg) -> float:
    d = cfg.d_model
    if cfg.moe:
        f = cfg.moe_d_ff
        routed = cfg.top_k * 6 * d * f * cfg.capacity_factor
        shared = cfg.n_shared_experts * 6 * d * f
        return 2 * d * cfg.n_experts + routed + shared
    if cfg.d_ff:
        return 6 * d * cfg.d_ff
    return 0.0


def _ssm_flops_per_token(cfg) -> float:
    if not cfg.ssm:
        return 0.0
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = di // cfg.ssm_head_dim
    q = cfg.ssm_chunk
    proj = 2 * d * (2 * di + 2 * n + h) + 2 * di * d
    conv = 2 * cfg.ssm_conv_width * (di + 2 * n)
    # SSD: intra-chunk (C.B weights + weighted x) + state build/read.
    ssd = 2 * q * n + 2 * q * di + 4 * n * di
    return proj + conv + ssd


def _layer_flops_per_token(cfg, layer: int, ctx: float) -> float:
    from repro.models.model import _window_schedule
    w = _window_schedule(cfg)[layer]
    lctx = min(ctx, float(w)) if w > 0 else ctx
    total = 0.0
    if cfg.hybrid_parallel:
        total += _attn_flops_per_token(cfg, lctx) + _ssm_flops_per_token(cfg)
    elif cfg.ssm:
        total += _ssm_flops_per_token(cfg)
    else:
        total += _attn_flops_per_token(cfg, lctx)
    if layer < cfg.first_dense_layers and cfg.moe:
        total += 6 * cfg.d_model * cfg.d_ff  # leading dense layer
    else:
        total += _ffn_flops_per_token(cfg)
    return total


def analytic_flops(arch: str, shape_name: str) -> float:
    """Global FLOPs for one step of the cell."""
    cfg = get_config(arch)
    shape = LM_SHAPES[shape_name]
    s, b = shape.seq_len, shape.global_batch
    if shape.kind == "train":
        tokens, ctx = b * s, s / 2
        mult = 3.0 + (1.0 if cfg.remat == "full" else 0.0)  # fwd+bwd+remat
        head = 2 * cfg.d_model * cfg.padded_vocab * tokens * 3.0
        enc = cfg.n_encoder_layers if cfg.encoder_decoder else 0
    elif shape.kind == "prefill":
        tokens, ctx = b * s, s / 2
        mult, head = 1.0, 0.0
        enc = cfg.n_encoder_layers if cfg.encoder_decoder else 0
    else:  # decode: one token, full cache context
        tokens, ctx = b * 1, float(s)
        mult = 1.0
        head = 2 * cfg.d_model * cfg.padded_vocab * tokens
        enc = 0
    per_tok = sum(_layer_flops_per_token(cfg, i, ctx)
                  for i in range(cfg.n_layers))
    if enc:
        per_tok += enc * (_attn_flops_per_token(cfg, ctx)
                          + 6 * cfg.d_model * cfg.d_ff)
    return per_tok * tokens * mult + head


def analytic_bytes_per_chip(arch: str, shape_name: str, n_dev: int,
                            msize: int = 16) -> float:
    """Dominant HBM traffic per chip per step (params/optimizer, caches,
    layer activations)."""
    cfg = get_config(arch)
    shape = LM_SHAPES[shape_name]
    s, b = shape.seq_len, shape.global_batch
    n = cfg.param_count()
    d, nl = cfg.d_model, cfg.n_layers
    if shape.kind == "train":
        fsdp = n * 12 / msize > 12e9
        shard = n_dev if fsdp else msize
        # fwd + remat reads of bf16 weights, fp32 grad write + AdamW
        # read/modify/write of params and both moments.
        param_traffic = (2 * 2 + 4 * 7) * n / shard
        tokens_local = b * s / (n_dev / msize)
        act = tokens_local * d * nl * 2 * 6  # rd+wr, fwd+bwd+remat
        return param_traffic + act
    # serving: bf16 params; TP-only unless huge
    serve_fsdp = n * 2 / msize > 12e9
    shard = n_dev if serve_fsdp else msize
    params_b = 2 * n / shard
    if shape.kind == "prefill":
        tokens_local = b * s / (n_dev / msize)
        act = tokens_local * d * nl * 2 * 3
        cache = _cache_bytes(cfg, b, s) / n_dev
        return params_b + act + cache
    # decode: read whole cache + params each step
    cache = _cache_bytes(cfg, b, s) / n_dev
    return params_b + 2 * cache / 2 + b * d * nl * 2 / n_dev


def _cache_bytes(cfg, b: int, max_len: int) -> float:
    from repro.models.model import _window_schedule
    total = 0.0
    windows = _window_schedule(cfg)
    for i in range(cfg.n_layers):
        if not cfg.attn_free:
            if cfg.attn_kind == "mla":
                total += b * max_len * (cfg.kv_lora_rank
                                        + cfg.qk_rope_head_dim) * 2
            else:
                size = max_len if windows[i] == 0 else min(
                    max_len, int(windows[i]))
                total += 2 * b * size * cfg.n_kv_heads * \
                    cfg.resolved_head_dim * 2
        if cfg.ssm:
            di = cfg.ssm_expand * cfg.d_model
            h = di // cfg.ssm_head_dim
            total += b * h * cfg.ssm_state * cfg.ssm_head_dim * 4
    return total


def loop_trip(arch: str, shape_name: str) -> int:
    """Dominant while-loop trip count for region-collective correction."""
    cfg = get_config(arch)
    shape = LM_SHAPES[shape_name]
    n_scan = cfg.n_layers - cfg.first_dense_layers
    if shape.kind == "train":
        return n_scan
    if uniform_serving(cfg):
        return n_scan
    if shape.kind == "prefill":
        return max(1, shape.seq_len // 1024)  # chunked-attention scan
    return 1  # unrolled decode


# --------------------------------------------------------------------- #

@dataclasses.dataclass
class RooflinePoint:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    analytic_flops_total: float
    hlo_flops_raw: float
    status: str = "ok"

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / analytic compiled FLOPs — remat/dispatch waste."""
        return (self.model_flops / self.analytic_flops_total
                if self.analytic_flops_total > 0 else 0.0)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound time: the score the perf loop
        maximizes."""
        if self.bound_s <= 0:
            return 0.0
        n_dev = 512 if self.mesh == "2x16x16" else 256
        useful_s = self.model_flops / (n_dev * PEAK_FLOPS)
        return useful_s / self.bound_s


def model_flops(arch: str, shape_name: str) -> float:
    """Classic estimator: train 6*N*D tokens; prefill 2*N*D; decode 2*N/tok
    (N = active params for MoE)."""
    cfg = get_config(arch)
    shape = LM_SHAPES[shape_name]
    n = cfg.active_param_count() if cfg.moe else cfg.param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def analyze_record(rec: dict) -> RooflinePoint:
    n_dev = rec.get("n_devices", 512 if rec["mesh"] == "2x16x16" else 256)
    af = analytic_flops(rec["arch"], rec["shape"])
    ab = analytic_bytes_per_chip(rec["arch"], rec["shape"], n_dev)
    coll = rec.get("collectives", {})
    main_w = coll.get("total_weighted", 0.0) - coll.get("region_weighted", 0.0)
    region_w = coll.get("region_weighted", 0.0)
    coll_bytes = main_w + region_w * loop_trip(rec["arch"], rec["shape"])
    return RooflinePoint(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=af / (n_dev * PEAK_FLOPS),
        memory_s=ab / HBM_BW,
        collective_s=coll_bytes / ICI_BW,
        model_flops=model_flops(rec["arch"], rec["shape"]),
        analytic_flops_total=af,
        hlo_flops_raw=rec.get("flops", -1.0),
        status=rec.get("status", "ok"),
    )


FIX_HINTS = {
    "compute": "cut recompute (remat policy) or raise per-chip tile "
               "efficiency (fusion, larger microbatch)",
    "memory": "keep weights/KV resident (TP split), bf16 caches, fuse "
              "elementwise chains, bigger attention blocks",
    "collective": "reshard (align TP with heads/latent), hierarchical DP "
                  "reduce, async overlap, int8 gradient compression",
}


def to_markdown(points: list) -> str:
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "dominant | MODEL_FLOPS | useful % | roofline frac | what moves it |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for p in points:
        if p.status != "ok":
            lines.append(f"| {p.arch} | {p.shape} | {p.mesh} "
                         f"| - | - | - | FAILED | - | - | - | - |")
            continue
        lines.append(
            f"| {p.arch} | {p.shape} | {p.mesh} | {p.compute_s:.2e} | "
            f"{p.memory_s:.2e} | {p.collective_s:.2e} | {p.dominant} | "
            f"{p.model_flops:.2e} | {100*p.useful_ratio:.0f}% | "
            f"{100*p.roofline_fraction:.1f}% | {FIX_HINTS[p.dominant]} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("records", nargs="+", help="dry-run JSONL file(s)")
    ap.add_argument("--md", default=None)
    args = ap.parse_args()
    points = []
    for path in args.records:
        with open(path) as f:
            for line in f:
                rec = json.loads(line)
                if rec.get("status") == "ok" and "flops" in rec:
                    points.append(analyze_record(rec))
    md = to_markdown(points)
    print(md)
    if args.md:
        with open(args.md, "w") as f:
            f.write(md + "\n")


if __name__ == "__main__":
    main()
