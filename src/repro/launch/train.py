"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 300 --ckpt-dir /tmp/run1

Wires together: config registry, synthetic/memmap data + prefetch, jit'd
train step (donation, accumulation, clipping, schedule), checkpoint manager
(async, resume), heartbeat/straggler monitor, supervisor-compatible exit
codes. `--simulate-preemption N` kills the process at step N (non-zero exit)
to exercise the Supervisor + resume path.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys

from repro.config.base import (ARCH_IDS, TrainConfig, get_config,
                               get_smoke_config)
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, Prefetcher, make_source
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.train.trainer import TrainLoopHooks, train_loop


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--data", default=None, help="memmap token file")
    ap.add_argument("--simulate-preemption", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=20,
                       total_steps=args.steps,
                       microbatches=args.microbatches,
                       grad_compression=args.grad_compression,
                       checkpoint_every=args.ckpt_every)
    dcfg = DataConfig(seq_len=args.seq_len, global_batch=args.global_batch,
                      vocab_size=cfg.vocab_size,
                      kind="memmap" if args.data else "synthetic-lm")
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    monitor = HeartbeatMonitor()
    start_step = 0
    if ckpt is not None and not args.no_resume:
        start_step = ckpt.latest_step() or 0
    data = Prefetcher(make_source(dcfg, args.data), start_step=start_step)

    def on_step(step, metrics, dt):
        monitor.beat("worker0", dt)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {metrics['loss']:.4f} "
                  f"nll {metrics['nll']:.4f} gnorm {metrics['grad_norm']:.3f} "
                  f"{dt*1e3:.0f} ms", flush=True)
        if args.simulate_preemption and step + 1 >= args.simulate_preemption:
            print(f"[train] simulated preemption at step {step + 1}",
                  flush=True)
            data.close()
            os._exit(42)

    try:
        params, opt, history = train_loop(
            cfg, tcfg, data, args.steps, checkpoint=ckpt,
            resume=not args.no_resume,
            hooks=TrainLoopHooks(on_step=on_step,
                                 heartbeat=lambda dt: None))
    finally:
        data.close()
    first = history[0]["loss"] if history else float("nan")
    last = history[-1]["loss"] if history else float("nan")
    print(f"[train] {args.arch}: loss {first:.4f} -> {last:.4f} over "
          f"{len(history)} steps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
