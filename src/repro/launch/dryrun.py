import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run (deliverable e): lower + compile EVERY
(architecture x input-shape) cell on the production meshes.

  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out results/dryrun

For each cell: .lower() -> .compile() must succeed; we record
memory_analysis() (proves it fits), cost_analysis() (FLOPs/bytes for the
roofline), and the collective-byte census parsed from the post-SPMD HLO.

NOTE the XLA_FLAGS line ABOVE this docstring: it must execute before any
jax import (device count locks on first backend init), and only in this
entrypoint — tests and benches see the real single CPU device.
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax

from repro.config.base import ARCH_IDS, LM_SHAPES, get_config, shapes_for
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

# HLO collective ops whose operand bytes count toward the collective term.
COLLECTIVE_RE = re.compile(
    r"^\s*(?:\S+\s*=\s*)?"
    r"((?:[a-z0-9-]+)?(?:all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?)"
    r"\(", re.MULTILINE)
SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64|f64)"
                      r"\[([0-9,]*)\]")

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8}

COLLECTIVE_FACTOR = {  # per-chip wire traffic multiplier on local bytes
    "all-reduce": 2.0,          # ring AR = RS + AG
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective in the (post-SPMD, per-device)
    HLO, split by whether the instruction sits in the entry computation or
    inside a while-loop body region.

    XLA's cost_analysis (and a naive text census) counts while bodies ONCE,
    not x trip-count — every lax.scan (layer stacks, chunked attention)
    under-reports. The roofline layer multiplies the 'region' bucket by the
    cell's dominant loop trip count (the layer scan).

    Returns {kind: bytes, 'total_weighted': ..., 'region_weighted': ...}.
    """
    out: dict[str, float] = {}
    weighted = 0.0
    region_weighted = 0.0
    in_region = False
    for line in hlo_text.splitlines():
        ls = line.strip()
        # Computation block headers: scan bodies/conditions are %region_*.
        if ls.endswith("{") and (ls.startswith("%") or
                                 ls.startswith("ENTRY")):
            in_region = ls.startswith("%region")
            continue
        m = re.search(
            r"=\s*(\S+?)\s+"
            r"((?:all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?)\(", ls)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        kind_base = kind.replace("-start", "")
        nbytes = 0
        for dt, dims in SHAPE_RE.findall(shape_str):
            n = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        out[kind_base] = out.get(kind_base, 0) + nbytes
        w = COLLECTIVE_FACTOR[kind_base] * nbytes
        weighted += w
        if in_region:
            region_weighted += w
    out["total_weighted"] = weighted
    out["region_weighted"] = region_weighted
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None) -> dict:
    shape = LM_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "n_devices": mesh.size}
    t0 = time.time()
    with mesh:
        cell = build_cell(arch, shape, mesh, overrides=overrides)
        lowered = cell.lower()
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        rec["lower_compile_s"] = round(time.time() - t0, 1)
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        rec["flops"] = float(cost.get("flops", -1.0))
        rec["bytes_accessed"] = float(cost.get("bytes accessed", -1.0))
        rec["transcendentals"] = float(cost.get("transcendentals", 0.0))
        hlo = compiled.as_text()
        rec["collectives"] = parse_collective_bytes(hlo)
        rec["hlo_bytes"] = len(hlo)
    return rec


def _run_one_inline(arch: str, sname: str, multi_pod: bool,
                    out: str | None) -> dict:
    tag = f"{arch} x {sname} x {'2x16x16' if multi_pod else '16x16'}"
    try:
        rec = run_cell(arch, sname, multi_pod)
        rec["status"] = "ok"
        print(f"[dryrun] OK   {tag}: flops={rec['flops']:.3e} "
              f"argbytes={rec['memory'].get('argument_size_in_bytes', 0):.3e} "
              f"temp={rec['memory'].get('temp_size_in_bytes', 0):.3e} "
              f"coll={rec['collectives']['total_weighted']:.3e} "
              f"({rec['lower_compile_s']}s)", flush=True)
    except Exception as e:  # noqa: BLE001 — report, keep sweeping
        rec = {"arch": arch, "shape": sname,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "status": "fail", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
        print(f"[dryrun] FAIL {tag}: {rec['error']}", flush=True)
    if out:
        with open(out, "a") as f:
            f.write(json.dumps(rec) + "\n")
    jax.clear_caches()  # bound host RAM across the 80-cell sweep
    return rec


def _run_one_subprocess(arch: str, sname: str, multi_pod: bool, out: str,
                        timeout_s: int) -> dict:
    """Per-cell worker-process isolation: one OOM-killed or hung compile
    can't take down the sweep (same supervision posture as the trainer)."""
    import subprocess
    import sys
    tag = f"{arch} x {sname} x {'2x16x16' if multi_pod else '16x16'}"
    argv = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
            "--shape", sname, "--out", out]
    if multi_pod:
        argv.append("--multi-pod")
    try:
        proc = subprocess.run(argv, timeout=timeout_s,
                              capture_output=True, text=True)
        if proc.returncode == 0:
            for line in proc.stdout.splitlines():
                if line.startswith("[dryrun] OK") or \
                        line.startswith("[dryrun] FAIL"):
                    print(line, flush=True)
            return {"status": "ok"}
        err = {"arch": arch, "shape": sname,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "status": "fail",
               "error": f"worker exit {proc.returncode} "
                        f"(OOM-killed?): {proc.stdout[-300:]}"}
    except subprocess.TimeoutExpired:
        err = {"arch": arch, "shape": sname,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "status": "fail", "error": f"timeout after {timeout_s}s"}
    print(f"[dryrun] FAIL {tag}: {err['error'][:160]}", flush=True)
    with open(out, "a") as f:
        f.write(json.dumps(err) + "\n")
    return err


def _done_cells(out: str | None) -> set:
    done = set()
    if out and os.path.exists(out):
        with open(out) as f:
            for line in f:
                r = json.loads(line)
                if r.get("status") == "ok":
                    done.add((r["arch"], r["shape"], r["mesh"]))
    return done


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(LM_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="JSONL output path")
    ap.add_argument("--isolate", action="store_true",
                    help="one worker subprocess per cell + resume")
    ap.add_argument("--cell-timeout", type=int, default=3600)
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in ARCH_IDS:
            for sname in shapes_for(arch):
                cells.append((arch, sname))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape (or --all) required")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    done = _done_cells(args.out) if args.isolate else set()
    results = []
    for multi_pod in meshes:
        mesh_name = "2x16x16" if multi_pod else "16x16"
        for arch, sname in cells:
            if (arch, sname, mesh_name) in done:
                print(f"[dryrun] SKIP {arch} x {sname} x {mesh_name} "
                      f"(already ok)", flush=True)
                continue
            if args.isolate:
                rec = _run_one_subprocess(arch, sname, multi_pod, args.out,
                                          args.cell_timeout)
            else:
                rec = _run_one_inline(arch, sname, multi_pod, args.out)
            results.append(rec)
    n_ok = sum(r["status"] == "ok" for r in results)
    print(f"[dryrun] {n_ok}/{len(results)} cells compiled "
          f"({len(done)} skipped as done)")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
