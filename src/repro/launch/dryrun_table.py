"""Render the EXPERIMENTS.md §Dry-run table from sweep JSONL records."""

from __future__ import annotations

import argparse
import json


def fmt_b(x: float) -> str:
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f} {unit}"
    return f"{x:.0f} B"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("records", nargs="+")
    ap.add_argument("--md", default=None)
    args = ap.parse_args()
    rows = []
    for path in args.records:
        with open(path) as f:
            rows.extend(json.loads(l) for l in f)
    lines = [
        "| arch | shape | mesh | compile | args/dev | temp/dev | "
        "HLO GFLOP/dev | collective/dev | status |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                         f"| - | - | - | - | - | FAIL: {r['error'][:40]} |")
            continue
        m = r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['lower_compile_s']:.0f}s "
            f"| {fmt_b(m['argument_size_in_bytes'])} "
            f"| {fmt_b(m['temp_size_in_bytes'])} "
            f"| {r['flops']/1e9:.1f} "
            f"| {fmt_b(r['collectives']['total_weighted'])} | ok |")
    md = "\n".join(lines)
    print(md)
    if args.md:
        with open(args.md, "w") as f:
            f.write(md + "\n")


if __name__ == "__main__":
    main()
