"""Serving driver: continuous-batching engine over a (smoke or full) arch.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.config.base import ARCH_IDS, get_config, get_smoke_config
from repro.serve.engine import Request, ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prompt-len", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    eng = ServeEngine(cfg, max_batch=args.max_batch, max_len=args.max_len,
                      eos_id=-1)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len,
                                dtype=np.int32),
            max_new_tokens=args.max_new))
    done = eng.run_until_drained()
    wall = time.perf_counter() - t0
    total = sum(len(r.out_tokens) for r in done)
    ttft = [r.t_first - r.t_submit for r in done]
    print(f"[serve] {args.arch}: {len(done)} requests, {total} tokens, "
          f"{total/wall:.1f} tok/s, TTFT mean {np.mean(ttft)*1e3:.0f} ms "
          f"max {np.max(ttft)*1e3:.0f} ms")
    return 0


if __name__ == "__main__":
    sys.exit(main())
