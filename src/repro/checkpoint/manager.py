"""Checkpointing: sharded save/restore with atomic commits, async writer,
retention, and elastic RESHARD-ON-RESTORE (checkpoint written under mesh A
restores under mesh B — required for elastic scaling / failure recovery with
a different healthy-device count).

Format: one .npz per pytree ("params", "opt_state", "meta") + a manifest.
Single-process container: arrays are gathered to host; on a true multi-host
deployment each host writes its addressable shards (the manifest layout
already carries the pytree paths needed for that split).
"""

from __future__ import annotations

import concurrent.futures
import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template: Any, flat: dict[str, np.ndarray]) -> Any:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        os.makedirs(directory, exist_ok=True)
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._pending: concurrent.futures.Future | None = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------ #

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def save(self, step: int, params: Any, opt_state: Any,
             meta: dict | None = None) -> None:
        """Async by default; device->host copy happens synchronously (so the
        step can donate buffers), the file write overlaps the next steps."""
        host = {
            "params": _flatten(jax.device_get(params)),
            "opt_state": _flatten(jax.device_get(opt_state)),
        }
        meta = dict(meta or {})
        meta["step"] = step
        meta["time"] = time.time()
        if self.async_write:
            self.wait()
            self._pending = self._pool.submit(self._write, step, host, meta)
        else:
            self._write(step, host, meta)

    def _write(self, step: int, host: dict, meta: dict) -> None:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for name, flat in host.items():
            np.savez(os.path.join(tmp, f"{name}.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):      # re-save of the same step (idempotent)
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic commit
        self._gc()

    def wait(self) -> None:
        with self._lock:
            if self._pending is not None:
                self._pending.result()
                self._pending = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------ #

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, params_template: Any,
                opt_template: Any, shardings: Any | None = None
                ) -> tuple[Any, Any, dict]:
        """Restore into host trees; optionally device_put against NEW
        shardings (elastic reshard: the checkpoint is mesh-agnostic)."""
        d = self._step_dir(step)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        params = _unflatten_into(
            params_template,
            dict(np.load(os.path.join(d, "params.npz"), allow_pickle=False)))
        opt = _unflatten_into(
            opt_template,
            dict(np.load(os.path.join(d, "opt_state.npz"),
                         allow_pickle=False)))
        if shardings is not None:
            params = jax.device_put(params, shardings["params"])
            opt = jax.device_put(opt, shardings["opt_state"])
        return params, opt, meta
