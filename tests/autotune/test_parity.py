"""The autotuning invariant: a tuned plan changes only where/when
programs run — outputs stay bit-exact and ``EngineStats`` charges stay
identical to the static default, at every width, in eager and fused
modes, and with the reliability plane injecting faults."""

import numpy as np
import pytest

import repro.pum as pum
from repro.autotune import SearchSpace, Tuner, WorkloadProfile
from repro.reliability import ReliabilityConfig, calibrate

pytestmark = pytest.mark.autotune


def _operands(width, n, seed):
    rng = np.random.default_rng(seed)
    hi = (1 << width) - 1
    a = rng.integers(0, hi, n, dtype=np.uint64)
    b = rng.integers(0, hi, n, dtype=np.uint64)
    a[:2] = (0, hi)
    b[:2] = (hi, 0)
    b[::7] = 0  # div-by-zero lanes
    return a, b


def run_workload(dev, width, seed=7):
    """Mixed value + raw workload; returns every materialized output."""
    a_np, b_np = _operands(width, 4096, seed)
    a, b = dev.asarray(a_np), dev.asarray(b_np)
    q, r = divmod(a, b)
    outs = [
        (a + b).to_numpy(), (a * b).to_numpy(), (a - b).to_numpy(),
        ((a & b) | (a ^ b)).to_numpy(), q.to_numpy(), r.to_numpy(),
        (a < b).to_numpy(), (a >= b).to_numpy(),
        a.popcount().to_numpy(),
    ]
    dev.flush()
    return outs


def tuned_device(width, fuse, **cfg):
    """Build a device, profile a priming run, autotune from the measured
    counters, and hand it back with fresh stats for the scored run."""
    dev = pum.device(width=width, fuse=fuse, **cfg)
    if fuse:
        with pum.profile(dev):
            run_workload(dev, width, seed=3)
        dev.autotune(apply=True)
    dev.reset_stats()
    return dev


@pytest.mark.parametrize("width", [8, 32, 64])
@pytest.mark.parametrize("fuse", [True, False])
def test_tuned_matches_static(width, fuse):
    static = pum.device(width=width, fuse=fuse)
    want = run_workload(static, width)
    want_stats = static.stats.as_dict()
    static.close()

    tuned = tuned_device(width, fuse)
    got = run_workload(tuned, width)
    got_stats = tuned.stats.as_dict()
    tuned.close()

    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    assert got_stats == want_stats


def test_tuned_plan_is_nontrivial_on_raw_heavy_width32():
    """Guard against the parity test passing vacuously: on this workload
    the tuner must actually pick a non-default config (the raw logic ops
    reward the unsplit 64-bit layout)."""
    dev = pum.device(width=32, fuse=True)
    with pum.profile(dev):
        a = dev.asarray(np.arange(8192, dtype=np.uint64) * 0x9E3779B9)
        b = dev.asarray(np.arange(8192, dtype=np.uint64) ^ 0xDEADBEEF)
        for _ in range(4):
            ((a & b) | (a ^ b)).to_numpy()
    plan = dev.autotune(apply=False)
    assert plan.non_default(dev.config) != {}
    dev.close()


@pytest.mark.parametrize("width", [8, 32])
def test_tuned_matches_static_under_reliability_injection(width):
    """Fault injection + replication-vote correction runs on both sides;
    the tuned plan must not perturb the corrected outputs or the charged
    stats."""
    rmap = calibrate("M", banks=16, n_subarrays=2, n_columns=32,
                     n_patterns=2, seed=13)
    rcfg = ReliabilityConfig(map=rmap, inject=True, seed=5)

    static = pum.device(width=width, fuse=True, reliability=rcfg)
    want = run_workload(static, width)
    want_stats = static.stats.as_dict()
    static.close()

    tuned = tuned_device(width, True, reliability=rcfg)
    got = run_workload(tuned, width)
    got_stats = tuned.stats.as_dict()
    tuned.close()

    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    assert got_stats == want_stats


def test_every_searched_backend_is_parity_safe():
    """Brute force: pin each searchable (backend, layout) pair through a
    TunedPlan apply and check outputs + stats against the default."""
    width = 16
    static = pum.device(width=width, fuse=True)
    want = run_workload(static, width)
    want_stats = static.stats.as_dict()
    static.close()

    for cand in Tuner().candidates(pum.EngineConfig(width=width)):
        dev = pum.device(width=width, fuse=True)
        plan = Tuner(space=SearchSpace(
            backends=(cand.fused_backend,), layouts=(cand.word_bits,),
            flush_thresholds=(cand.flush_threshold,),
            cmd_buffer_lookahead=(cand.cmd_buffer_lookahead,),
        )).tune(
            WorkloadProfile(ops=100, flushes=1, ops_per_flush=100.0,
                            lanes=4096.0, op_mix={"add": 1.0},
                            width=width),
            dev.config)
        dev._apply_plan(plan)
        got = run_workload(dev, width)
        got_stats = dev.stats.as_dict()
        dev.close()
        label = (cand.fused_backend, cand.word_bits)
        for w, g in zip(want, got):
            np.testing.assert_array_equal(w, g, err_msg=str(label))
        assert got_stats == want_stats, label


def test_eager_device_rejects_autotune_but_not_reset():
    dev = pum.device(width=8, fuse=False)
    with pytest.raises(ValueError, match="fuse"):
        dev.autotune()
    dev.reset_counters()  # counter windows work regardless of mode
    dev.close()
