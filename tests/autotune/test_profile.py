"""WorkloadProfile extraction: counters -> features, round trips, windows."""

import numpy as np
import pytest

import repro.pum as pum
from repro.autotune import WorkloadProfile
from repro.telemetry import CounterBank

pytestmark = pytest.mark.autotune


def _bank(**counters):
    b = CounterBank()
    for k, v in counters.items():
        b.inc(k.replace("__", "."), v)
    return b


def synthetic_bank():
    b = CounterBank()
    b.inc("engine.ops_recorded", 100)
    b.inc("engine.op.add", 60)
    b.inc("engine.op.xor", 40)
    b.inc("engine.raw_ops", 40)
    b.inc("engine.flushes", 4)
    b.inc("engine.autoflush.ops", 2)
    b.inc("engine.pipeline_cache.hit", 3)
    b.inc("engine.pipeline_cache.miss", 1)
    b.inc("cmd_bus_utilization", 0.25)
    b.inc("wall_ns", 1000.0)
    b.inc("stall.trrd_ns", 100.0)
    b.inc("stall.tfaw_ns", 50.0)
    b.inc("row.hit", 6)
    b.inc("row.miss", 2)
    b.inc("row.conflict", 2)
    b.inc("refresh.stall_ns", 40.0)
    for lanes in (4096, 4096, 8192, 8192):
        b.observe("engine.flush_lanes", lanes)
    return b


def test_feature_extraction():
    p = WorkloadProfile.from_counters(synthetic_bank(), width=32,
                                      word_bits=32)
    assert p.ops == 100 and p.flushes == 4
    assert p.ops_per_flush == 25.0
    assert p.lanes == 6144.0
    assert p.op_mix == {"add": 0.6, "xor": 0.4}
    assert p.raw_fraction == 0.4
    assert p.cache_hit_rate == 0.75
    assert p.autoflush_ops_fraction == 0.5
    assert p.bus_utilization == 0.25
    assert p.stall_trrd_fraction == 0.1
    assert p.stall_tfaw_fraction == 0.05
    assert p.row_conflict_ratio == 0.2
    assert p.refresh_fraction == 0.04
    assert p.width == 32 and p.word_bits == 32


def test_empty_window_raises_with_hint():
    with pytest.raises(ValueError, match="pum.profile"):
        WorkloadProfile.from_counters(CounterBank())


def test_accepts_as_dict_payload_and_plain_mapping():
    bank = synthetic_bank()
    a = WorkloadProfile.from_counters(bank)
    b = WorkloadProfile.from_counters(bank.as_dict())
    assert a == b
    # A plain mapping loses histograms (lanes fall back to 0) but the
    # counter-derived features agree.
    c = WorkloadProfile.from_counters(bank.as_dict()["counters"])
    assert c.op_mix == a.op_mix and c.ops == a.ops and c.lanes == 0.0


def test_json_round_trip_and_fingerprint():
    import json
    p = WorkloadProfile.from_counters(synthetic_bank())
    q = WorkloadProfile.from_dict(json.loads(json.dumps(p.as_dict())))
    assert q == p
    assert q.fingerprint() == p.fingerprint()
    drifted = WorkloadProfile.from_dict(
        dict(p.as_dict(), raw_fraction=0.9))
    assert drifted.fingerprint() != p.fingerprint()


def test_from_device_measures_real_workload():
    with pum.device(width=16, fuse=True) as dev:
        with pum.profile(dev):
            x = dev.asarray(np.arange(512, dtype=np.uint64) & 0xFFFF)
            ((x + 5) * x ^ x).to_numpy()
        p = WorkloadProfile.from_device(dev)
    assert p.ops >= 3 and p.flushes >= 1
    assert p.lanes == 512.0
    assert set(p.op_mix) >= {"add", "mul", "xor"}
    assert abs(sum(p.op_mix.values()) - 1.0) < 1e-12
    assert p.width == 16 and p.word_bits == 32


def test_unprofiled_device_raises():
    with pum.device(width=16, fuse=True) as dev:
        x = dev.asarray(np.arange(64, dtype=np.uint64))
        (x + 1).to_numpy()  # no tracer attached -> no counters
        with pytest.raises(ValueError, match="pum.profile"):
            WorkloadProfile.from_device(dev)


# -- CounterBank windows (snapshot / delta / clear) --------------------- #


def test_snapshot_is_independent():
    b = _bank(a=1)
    b.observe("h", 4)
    s = b.snapshot()
    b.inc("a", 2)
    b.observe("h", 16)
    assert s.get("a") == 1 and b.get("a") == 3
    assert s.histogram("h")["count"] == 1
    assert b.histogram("h")["count"] == 2


def test_delta_subtracts_counters_and_histograms():
    b = CounterBank()
    b.inc("x", 5)
    b.observe("lat", 2)
    s = b.snapshot()
    b.inc("x", 7)
    b.inc("new", 1)
    b.observe("lat", 8)
    b.observe("lat", 8)
    d = b.delta(s)
    assert d.get("x") == 7 and d.get("new") == 1
    h = d.histogram("lat")
    assert h["count"] == 2 and h["total"] == 16 and h["mean"] == 8
    # zero-change entries are dropped
    assert "x" in d and len(d.as_dict()["counters"]) == 2


def test_delta_of_identical_snapshots_is_empty():
    b = synthetic_bank()
    d = b.delta(b.snapshot())
    assert len(d) == 0


def test_clear_resets_in_place():
    b = synthetic_bank()
    alias = b  # holders keep writing into the same object
    b.clear()
    assert len(alias) == 0
    alias.inc("fresh", 1)
    assert b.get("fresh") == 1


def test_device_reset_counters_preserves_bank_identity():
    with pum.device(width=8, fuse=True) as dev:
        bank = dev.counters
        with pum.profile(dev):
            (dev.asarray(np.arange(32, dtype=np.uint64)) + 1).to_numpy()
        assert bank.get("engine.ops_recorded") > 0
        dev.reset_counters()
        assert dev.counters is bank  # cleared in place, not rebound
        assert len(bank) == 0
