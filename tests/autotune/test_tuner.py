"""Tuner determinism, selection behavior, drift detection, persistence."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import repro.pum as pum
from repro.autotune import (CostModel, DriftDetector, SearchSpace,
                            TunedPlan, Tuner, WorkloadProfile)

pytestmark = pytest.mark.autotune


def profile_of(**overrides):
    base = dict(ops=1600, flushes=16, ops_per_flush=100.0, lanes=4096.0,
                op_mix={"add": 0.5, "xor": 0.5}, raw_fraction=0.0,
                cache_hit_rate=0.9, width=32, word_bits=32)
    base.update(overrides)
    return WorkloadProfile(**base)


# -- selection behavior ------------------------------------------------- #


def test_no_signal_keeps_static_config():
    """A profile with no exploitable structure must return the baseline
    exactly — no gratuitous knob churn."""
    plan = Tuner().tune(profile_of(), pum.EngineConfig(width=32))
    assert plan.non_default(pum.EngineConfig(width=32)) == {}
    assert plan.score_s == plan.baseline_score_s


def test_raw_heavy_workload_selects_64bit_layout():
    """Raw uint64 bitmaps split 2 lanes/word on the 32-bit layout; the
    tuner should move them to unsplit 64-bit lanes."""
    cfg = pum.EngineConfig(width=32)
    plan = Tuner().tune(profile_of(raw_fraction=1.0, lanes=8192.0), cfg)
    nd = plan.non_default(cfg)
    assert nd.get("word_bits") == 64
    assert plan.fused_backend == "words-cpu-64"
    assert plan.score_s < plan.baseline_score_s


def test_threshold_choked_workload_selects_larger_threshold():
    """When most flushes were forced by the ops threshold, a larger
    threshold merges dispatches."""
    cfg = pum.EngineConfig(width=16, flush_threshold=64)
    plan = Tuner().tune(
        profile_of(ops_per_flush=64.0, autoflush_ops_fraction=0.95,
                   cache_hit_rate=0.9, lanes=2048.0, width=16), cfg)
    assert plan.flush_threshold > 64
    assert plan.score_s < plan.baseline_score_s


def test_controller_signal_selects_ref_and_lookahead():
    """Refresh/stall fractions reward REF postponing and deeper crossbar
    lookahead — but only on the auto-controller cost path."""
    prof = profile_of(refresh_fraction=0.3, stall_trrd_fraction=0.2,
                      stall_tfaw_fraction=0.1, lanes=65536.0,
                      ops_per_flush=1000.0)
    auto = pum.EngineConfig(width=32, controller="auto")
    plan = Tuner().tune(prof, auto)
    assert plan.ref_postponing == 8
    assert plan.cmd_buffer_lookahead == 32
    # Closed-form path: ref_postponing pinned to the config's value.
    plain = Tuner().tune(prof, pum.EngineConfig(width=32))
    assert plain.ref_postponing == 1


def test_leaf_upload_bound_raw_chain_recommends_eager():
    """A doctored BMI-shaped window — long raw AND chains over huge
    bitmaps whose staged leaf-snapshot bytes dominate the flush — must
    flip the recommendation off the fused pipeline: the leaf-upload term
    prices what the flush path actually moves, and eager streams
    operands in place without snapshotting. The same window with zero
    staged bytes (a warm leaf cache) keeps fused."""
    cfg = pum.EngineConfig(width=32, layout=64)
    shape = dict(ops=480, flushes=16, ops_per_flush=30.0,
                 lanes=2_097_152.0, op_mix={"and": 1.0},
                 raw_fraction=1.0, cache_hit_rate=1.0,
                 width=32, word_bits=64)
    cold = profile_of(**shape, leaf_bytes_per_flush=2e8,
                      leaf_cache_hit_rate=0.0)
    plan = Tuner().tune(cold, cfg)
    assert plan.fuse is False
    assert plan.score_s < plan.baseline_score_s
    # Round-trips keep the recommendation.
    assert TunedPlan.from_dict(plan.as_dict()).fuse is False
    # apply() carries it; EngineConfig stays valid.
    assert plan.apply(cfg).fuse is False
    warm = profile_of(**shape)
    assert Tuner().tune(warm, cfg).fuse is True


def test_candidates_respect_registry_constraints():
    cfg = pum.EngineConfig(width=48)  # only 64-bit-layout backends fit
    for cand in Tuner().candidates(cfg):
        assert cand.word_bits == 64
        spec = pum.get_backend(cand.fused_backend)
        assert spec.max_width >= 48 and 64 in spec.layouts


def test_space_override_narrows_search():
    space = SearchSpace(backends=("words-cpu",), layouts=(32,),
                        flush_thresholds=(128,), cmd_buffer_lookahead=(4,))
    plan = Tuner(space=space).tune(profile_of(), pum.EngineConfig())
    # Baseline still wins scoring ties, but every non-baseline candidate
    # comes from the narrowed space.
    cands = Tuner(space=space).candidates(pum.EngineConfig())
    assert {c.fused_backend for c in cands} == {"words-cpu"}
    assert {c.flush_threshold for c in cands} == {128}
    assert isinstance(plan, TunedPlan)


# -- determinism -------------------------------------------------------- #

TUNE_SNIPPET = """
import json
from repro.autotune import Tuner, WorkloadProfile
from repro.pum import EngineConfig
prof = WorkloadProfile(ops=1600, flushes=16, ops_per_flush=100.0,
                       lanes=8192.0,
                       op_mix={"add": 0.25, "xor": 0.3, "mul": 0.2,
                               "and": 0.15, "divmod": 0.1},
                       raw_fraction=0.6, cache_hit_rate=0.8,
                       refresh_fraction=0.1, stall_trrd_fraction=0.05,
                       width=32, word_bits=32)
plan = Tuner().tune(prof, EngineConfig(width=32, controller="auto"))
print(json.dumps(plan.as_dict(), sort_keys=True))
"""


def run_in_subprocess(snippet, hashseed):
    env = dict(os.environ, PYTHONHASHSEED=str(hashseed))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    out = subprocess.run([sys.executable, "-c", snippet], env=env,
                         capture_output=True, text=True, check=True,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__)))))
    return json.loads(out.stdout)


def test_same_profile_same_plan_across_processes():
    a = run_in_subprocess(TUNE_SNIPPET, hashseed=0)
    b = run_in_subprocess(TUNE_SNIPPET, hashseed=98765)
    assert a == b  # exact knob + score equality under different salts


def test_tune_is_deterministic_in_process():
    prof = profile_of(raw_fraction=0.7, lanes=16384.0)
    cfg = pum.EngineConfig(width=32)
    assert Tuner().tune(prof, cfg) == Tuner().tune(prof, cfg)


# -- cost model sanity -------------------------------------------------- #


def test_cost_model_terms_are_positive_and_additive():
    est = CostModel().estimate(profile_of(),
                               Tuner().candidates(pum.EngineConfig())[0])
    assert est.compute_s > 0 and est.memory_s > 0 and est.overhead_s > 0
    assert est.controller_s == 0.0  # no controller counters in profile
    assert est.total_s == pytest.approx(
        est.compute_s + est.memory_s + est.overhead_s + est.controller_s)
    assert set(est.as_dict()) == {"compute_s", "memory_s", "overhead_s",
                                  "controller_s", "total_s"}


def test_ref_vertical_oracle_never_wins():
    space = SearchSpace(backends=("words-cpu", "ref-vertical"))
    plan = Tuner(space=space).tune(profile_of(), pum.EngineConfig())
    assert plan.fused_backend != "ref-vertical"


# -- persistence -------------------------------------------------------- #


def test_plan_round_trips_json_and_npz(tmp_path):
    prof = profile_of(raw_fraction=1.0, lanes=8192.0)
    plan = Tuner().tune(prof, pum.EngineConfig(width=32))
    for name in ("plan.json", "plan.npz"):
        path = tmp_path / name
        plan.save(path)
        loaded = TunedPlan.load(path)
        assert loaded == plan
        assert loaded.profile == prof


def test_plan_schema_guard(tmp_path):
    plan = Tuner().tune(profile_of(), pum.EngineConfig())
    blob = plan.as_dict()
    blob["schema"] = "repro.autotune/999"
    with pytest.raises(ValueError, match="schema"):
        TunedPlan.from_dict(blob)


def test_apply_splits_execution_and_cost_plane_knobs():
    cfg = pum.EngineConfig(width=32, controller="auto")
    plan = TunedPlan(fused_backend="words-cpu-64", word_bits=64,
                     flush_threshold=4096, ref_postponing=8,
                     cmd_buffer_lookahead=32)
    exe = plan.apply(cfg)
    assert exe.fused_backend == "words-cpu-64"
    assert exe.resolved_layout().word_bits == 64
    assert exe.flush_threshold == 4096
    assert exe.cmd_buffer_lookahead == 32
    assert exe.ref_postponing == cfg.ref_postponing  # cost plane untouched
    full = plan.apply(cfg, cost_plane=True)
    assert full.ref_postponing == 8


def test_selection_override_hook():
    from repro.backends import get_selection_override, select_backend
    plan = TunedPlan(fused_backend="words-cpu-64", word_bits=64)
    assert get_selection_override("fused") is None
    with plan.selection_override():
        assert get_selection_override("fused") == "words-cpu-64"
        # Satisfiable constraints: the pin wins over priority order.
        assert select_backend(require="fused", width=16,
                              layout=64).name == "words-cpu-64"
        # Unsatisfiable constraints: normal lookup proceeds.
        assert select_backend(require="fused", width=16,
                              layout=32).name == "words-cpu"
    assert get_selection_override("fused") is None


# -- drift detection + online re-tune ----------------------------------- #


def test_doctored_profile_fires_drift_detector():
    base = profile_of()
    det = DriftDetector(base, threshold=0.5)
    assert not det.fired(base)
    assert det.drift(base) == 0.0
    # Doctor the profile: the workload flipped to raw bitmaps on 16x the
    # lanes — both features breach the threshold on their own.
    doctored = WorkloadProfile.from_dict(
        dict(base.as_dict(), raw_fraction=1.0, lanes=base.lanes * 16))
    assert det.drift(doctored) >= 1.0
    assert det.fired(doctored)
    # Op-mix rotation alone fires too (total-variation distance).
    remixed = WorkloadProfile.from_dict(
        dict(base.as_dict(), op_mix={"divmod": 1.0}))
    assert det.fired(remixed)


def test_drift_triggers_online_retune():
    """A doctored counter window must make the online autotuner re-tune:
    phase 1 tunes on small value-mode programs, phase 2 flips the
    workload to wide raw bitmaps, and the drift detector (not the
    explore cadence — set astronomically high) must fire the re-tune."""
    dev = pum.device(width=32, fuse=True, flush_threshold=8)
    from repro.telemetry import Tracer
    dev.engine.tracer = Tracer()
    dev.autotune(online=True, window_flushes=2, explore_every=10**6,
                 drift_threshold=0.5)
    rng = np.random.default_rng(0)

    def small(seed):
        x = dev.asarray(np.arange(256, dtype=np.uint64))
        ((x + seed) * x).to_numpy()

    def raw(seed):
        a = dev.asarray(rng.integers(0, 2**64, 8192, dtype=np.uint64))
        b = dev.asarray(rng.integers(0, 2**64, 8192, dtype=np.uint64))
        ((a & b) | (a ^ b)).to_numpy()

    for i in range(8):
        small(i)
    ot = dev.engine.autotuner
    assert ot is not None and ot.windows >= 1
    retunes_before = ot.retunes
    plan_before = ot.plan
    for i in range(12):
        raw(i)
    assert ot.retunes > retunes_before
    assert ot.plan is not None and ot.plan != plan_before
    # The raw regime moved the device onto unsplit 64-bit lanes.
    assert dev.config.resolved_layout().word_bits == 64
    dev.engine.tracer = None
    dev.close()


def test_online_window_accounting_and_guards():
    with pytest.raises(ValueError):
        pum.device(width=8, fuse=True).autotune(online=True,
                                                window_flushes=0)
    dev = pum.device(width=8, fuse=False)
    with pytest.raises(ValueError, match="fuse"):
        dev.autotune()
