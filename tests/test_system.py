"""End-to-end system behaviour tests (cross-layer integration)."""

import numpy as np

from repro.core import (MFR_H, MFR_M, DramGeometry, PulsarChip,
                        PulsarEngine, PulsarExecutor)
from repro.core.alu import BitSerialAlu
from repro.core.charact import default_db


def test_public_api_surface():
    import repro.core as core
    for name in core.__all__:
        assert hasattr(core, name), name


def test_paper_headline_claims_hold_in_sim():
    """The three headline claims, end to end on the shipped defaults:
    1) up to 32 simultaneous rows (Mfr H), 16 (Mfr M);
    2) replication raises MAJ3 success (FracDRAM -> PULSAR);
    3) PULSAR-configured engine is never slower than the FracDRAM-configured
       engine on any of the seven microbenchmarks (paper Fig 17)."""
    geom = DramGeometry(row_bits=256, rows_per_subarray=512,
                        subarrays_per_bank=2, banks=1)
    for profile, max_rows in ((MFR_H, 32), (MFR_M, 16)):
        chip = PulsarChip(geom, profile, seed=0)
        chip.decoder = chip.decoder.__class__(geom, profile, None)
        x = PulsarExecutor(chip, 0, 0)
        assert x.max_n_rg() == max_rows
    db = default_db()
    assert db.mean("H", 3, 32) > db.mean("H", 3, 4) + 0.1
    pulsar = PulsarEngine(mfr="M", use_pulsar=True)
    frac = PulsarEngine(mfr="M", use_pulsar=False)
    for kind, planes in (("reduce_and", 64), ("reduce_xor", 64),
                         ("add", None), ("mul", None), ("div", None)):
        _, _, sr_p, c_p = pulsar._cfg_for(kind, 32, planes)
        _, _, sr_f, c_f = frac._cfg_for(kind, 32, planes)
        assert c_p.latency_ns / sr_p <= c_f.latency_ns / sr_f * 1.0001, kind


def test_full_stack_compute_pipeline():
    """Host ints -> vertical layout -> staged MAJ programs on the chip ->
    arithmetic -> read back, with latency/energy accounted."""
    geom = DramGeometry(row_bits=128, rows_per_subarray=256,
                        subarrays_per_bank=1, banks=1,
                        predecoder_widths=(2, 2, 2, 2))
    chip = PulsarChip(geom, MFR_H, seed=0)
    chip.decoder = chip.decoder.__class__(geom, MFR_H, None)
    alu = BitSerialAlu(PulsarExecutor(chip, 0, 0), width=8)
    rng = np.random.default_rng(0)
    a = rng.integers(0, 100, 128, dtype=np.uint64)
    b = rng.integers(1, 100, 128, dtype=np.uint64)
    va, vb = alu.load(a), alu.load(b)
    s = alu.add(va, vb)
    m = alu.mul(va, vb)
    np.testing.assert_array_equal(alu.store(s), (a + b) & 0xFF)
    np.testing.assert_array_equal(alu.store(m), (a * b) & 0xFF)
    assert chip.stats.latency_ns > 0
    assert chip.stats.energy_j > 0
    assert chip.stats.n_acts > 100  # real command traffic happened
