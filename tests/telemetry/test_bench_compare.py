"""tools/bench_compare.py gate logic: a doctored baseline with a >25%
regression must fail, within-threshold drift must pass, and the
structural row gate must catch renamed/dropped rows."""

import copy
import importlib.util
import json
import pathlib

import pytest

TOOLS = pathlib.Path(__file__).resolve().parents[2] / "tools"


def _load(name):
    spec = importlib.util.spec_from_file_location(name, TOOLS / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


bench_compare = _load("bench_compare")

HOST = {"platform": "x", "machine": "m", "python": "3.11", "cpu_count": 8}


def _doc(rows, host=HOST):
    return {"schema": 1, "bench": "unit", "git_sha": "abc", "host": host,
            "rows": {n: {"ns_per_call": ns} for n, ns in rows.items()}}


def test_identical_passes_both_modes():
    doc = _doc({"a": 100.0, "b": 50.0})
    assert bench_compare.compare(doc, copy.deepcopy(doc)) == []
    assert bench_compare.compare(doc, copy.deepcopy(doc),
                                 check_rows_only=True) == []


def test_regression_beyond_threshold_fails():
    """The acceptance demonstration: doctor the baseline so the fresh run
    looks >25% slower — the gate must fail and name the row."""
    baseline = _doc({"a": 100.0, "b": 200.0})
    fresh = _doc({"a": 130.0, "b": 200.0})   # a: 1.30x > 1.25x limit
    failures = bench_compare.compare(baseline, fresh)
    assert len(failures) == 1
    assert failures[0].startswith("a:")
    assert "1.30x" in failures[0]


def test_within_threshold_drift_passes():
    baseline = _doc({"a": 100.0})
    fresh = _doc({"a": 120.0})               # 1.20x < 1.25x
    assert bench_compare.compare(baseline, fresh) == []


def test_speedup_never_fails():
    baseline = _doc({"a": 100.0})
    fresh = _doc({"a": 10.0})
    assert bench_compare.compare(baseline, fresh) == []


def test_missing_and_extra_rows():
    baseline = _doc({"a": 1.0, "gone": 1.0})
    fresh = _doc({"a": 1.0, "new": 1.0})
    failures = bench_compare.compare(baseline, fresh, check_rows_only=True)
    assert any("gone" in f and "missing" in f for f in failures)
    assert any("new" in f and "not in baseline" in f for f in failures)
    # Row mismatches also fail the full mode.
    assert bench_compare.compare(baseline, fresh) != []


def test_host_grace_loosens_cross_host_threshold():
    baseline = _doc({"a": 100.0})
    other_host = dict(HOST, machine="different")
    fresh_same = _doc({"a": 160.0})                      # 1.6x
    fresh_other = _doc({"a": 160.0}, host=other_host)
    assert bench_compare.compare(baseline, fresh_same) != []
    # Cross-host: limit = 1.25 * 2.0 = 2.5x, so 1.6x passes...
    assert bench_compare.compare(baseline, fresh_other) == []
    # ...but a catastrophic regression still fails.
    assert bench_compare.compare(
        baseline, _doc({"a": 300.0}, host=other_host)) != []


def test_fused_slower_than_eager_sibling_fails_both_modes():
    """The app.* fused-vs-eager invariant: a fresh emit whose fused row
    regresses below its eager sibling fails even the structural gate
    (what CI runs on every push), regardless of the committed baseline."""
    rows = {"app.x_eager": 100.0, "app.x_fused": 150.0}
    for mode in (False, True):
        failures = bench_compare.compare(_doc(rows), _doc(rows),
                                         check_rows_only=mode)
        assert any("app.x_fused" in f and "eager" in f
                   for f in failures), failures
    # Fused at or below eager passes; non-app rows are never paired.
    ok = _doc({"app.x_eager": 100.0, "app.x_fused": 100.0,
               "engine.y_fused": 999.0})
    assert bench_compare.compare(ok, copy.deepcopy(ok)) == []
    assert bench_compare.compare(ok, copy.deepcopy(ok),
                                 check_rows_only=True) == []


def test_non_positive_time_is_error():
    baseline = _doc({"a": 100.0})
    fresh = _doc({"a": -1.0})
    failures = bench_compare.compare(baseline, fresh)
    assert any("non-positive" in f for f in failures)


def test_load_bench_rejects_wrong_schema(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"schema": 99, "rows": {}}))
    with pytest.raises(ValueError):
        bench_compare.load_bench(str(p))


def test_main_exit_codes(tmp_path, capsys):
    base_p = tmp_path / "base.json"
    fresh_p = tmp_path / "fresh.json"
    base_p.write_text(json.dumps(_doc({"a": 100.0})))

    fresh_p.write_text(json.dumps(_doc({"a": 105.0})))
    assert bench_compare.main([str(base_p), str(fresh_p)]) == 0
    assert "gate OK" in capsys.readouterr().out

    fresh_p.write_text(json.dumps(_doc({"a": 500.0})))
    assert bench_compare.main([str(base_p), str(fresh_p)]) == 1
    assert "FAILED" in capsys.readouterr().err

    assert bench_compare.main([str(base_p), str(tmp_path / "nope.json")]) == 2


def test_committed_baselines_self_compare():
    """The two BENCH files committed at the repo root are loadable and
    pass their own structural gate."""
    root = TOOLS.parent
    for name in ("BENCH_kernel.json", "BENCH_bankpar.json"):
        doc = bench_compare.load_bench(str(root / name))
        assert doc["rows"], name
        assert bench_compare.compare(doc, copy.deepcopy(doc),
                                     check_rows_only=True) == []
