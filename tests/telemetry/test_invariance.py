"""Telemetry must be provably free: bit-identical results, identical
EngineStats, and identical scheduled command traces whether telemetry is
attached or not — across widths, eager vs fused, and controller="auto"."""

import numpy as np
import pytest

import repro.pum as pum
from repro.controller import MemoryController, retarget_program
from repro.core.cost_model import CostModel

pytestmark = pytest.mark.fused


def _program(dev, a, b):
    x = dev.asarray(a)
    t = (x + b) * x
    t = t ^ b
    t = t & x
    q, r = divmod(t, (x | np.uint64(1)))
    return (q + r).to_numpy()


def _run(width, fuse, controller, profiled, a, b):
    dev = pum.device(width=width, fuse=fuse, controller=controller)
    if profiled:
        with pum.profile(dev) as tr:
            out = _program(dev, a, b)
        assert tr.events or not fuse  # fused runs record flush spans
    else:
        out = _program(dev, a, b)
    return out, dev.stats


@pytest.mark.parametrize("width", [8, 32, 64])
@pytest.mark.parametrize("fuse", [False, True])
def test_profile_does_not_perturb_results_or_stats(width, fuse):
    rng = np.random.default_rng(width)
    a = rng.integers(0, 1 << min(width, 63), 300, dtype=np.uint64)
    b = rng.integers(1, 1 << min(width, 63), 300, dtype=np.uint64)
    base, stats_base = _run(width, fuse, None, False, a, b)
    prof, stats_prof = _run(width, fuse, None, True, a, b)
    np.testing.assert_array_equal(base, prof)
    assert stats_base == stats_prof


def test_profile_invariance_with_controller_auto():
    rng = np.random.default_rng(5)
    a = rng.integers(0, 1 << 16, 200, dtype=np.uint64)
    b = rng.integers(1, 1 << 16, 200, dtype=np.uint64)
    base, stats_base = _run(16, True, "auto", False, a, b)
    prof, stats_prof = _run(16, True, "auto", True, a, b)
    np.testing.assert_array_equal(base, prof)
    assert stats_base == stats_prof


def test_counters_not_populated_without_tracer():
    """Zero-overhead contract: with no tracer attached the engine's
    CounterBank stays empty (no per-op work on the disabled path)."""
    dev = pum.device(width=16, fuse=True)
    _program(dev, np.arange(64, dtype=np.uint64),
             np.arange(64, dtype=np.uint64) + 1)
    assert len(dev.counters) == 0
    assert dev.engine.tracer is None


def test_schedule_identical_with_and_without_derivation():
    """Deriving counters replays the audit trail; the schedule itself is
    byte-identical whether or not anyone derives (and across repeats)."""
    unit = CostModel(row_bits=65536).maj_unit_programs(3, 8)
    progs = [retarget_program(p, i % 4) for i in range(8) for p in unit]
    tr1 = MemoryController(n_banks=4).schedule(progs)
    tr1.counters()
    tr2 = MemoryController(n_banks=4).schedule(progs)
    assert tr1.cmds == tr2.cmds
    assert tr1.issue_times == tr2.issue_times
    assert tr1.total_ns == tr2.total_ns
    assert tr1.energy_j == tr2.energy_j


def test_profile_restores_prior_tracer_and_flushes():
    dev = pum.device(width=16, fuse=True)
    a = np.arange(32, dtype=np.uint64)
    with pum.profile(dev) as tr:
        pending = dev.asarray(a) + 1
    # exit flushed the pending graph and detached the tracer
    assert dev.engine.tracer is None
    np.testing.assert_array_equal(pending.to_numpy(), a + 1)
    assert any(n == "flush.dispatch" for n in tr.span_names())
