"""CounterBank semantics and the post-hoc controller counter derivation,
including a hand-scheduled two-bank trace where bus utilization and the
tRRD/tFAW stall split are computable by hand."""

import dataclasses
import math

import numpy as np
import pytest

from repro.controller import MemoryController, retarget_program
from repro.core.commands import Cmd, CommandScheduler, Op, ScheduleResult
from repro.core.cost_model import CostModel
from repro.core.timing import DDR4_2400
from repro.telemetry import CounterBank, derive_controller_counters

# --------------------------------------------------------------------- #
# CounterBank
# --------------------------------------------------------------------- #


def test_counterbank_inc_get_contains():
    b = CounterBank()
    assert b.get("x") == 0 and "x" not in b
    b.inc("x")
    b.inc("x", 2.5)
    assert b["x"] == 3.5 and "x" in b and len(b) == 1


def test_counterbank_histogram_log2_buckets():
    b = CounterBank()
    for v in (0, 1, 2, 3, 4, 100):
        b.observe("lat", v)
    h = b.histogram("lat")
    assert h["count"] == 6
    assert h["total"] == 110
    assert h["min"] == 0 and h["max"] == 100
    assert h["mean"] == pytest.approx(110 / 6)
    # bucket k holds samples in (2^(k-1), 2^k]; non-positive and <=1 in 0
    assert h["buckets"] == {0: 2, 1: 1, 2: 2, 7: 1}


def test_counterbank_merge():
    a, b = CounterBank(), CounterBank()
    a.inc("n", 1)
    b.inc("n", 2)
    b.inc("m", 5)
    a.observe("h", 3)
    b.observe("h", 100)
    a.merge(b)
    assert a["n"] == 3 and a["m"] == 5
    h = a.histogram("h")
    assert h["count"] == 2 and h["min"] == 3 and h["max"] == 100


def test_counterbank_as_dict_json_shape():
    import json

    b = CounterBank()
    b.inc("z")
    b.inc("a", 2)
    b.observe("lat_ns", 7)
    d = b.as_dict()
    assert list(d["counters"]) == ["a", "z"]  # sorted
    json.dumps(d)  # plain-JSON types only
    assert "CounterBank(" in repr(b)


# --------------------------------------------------------------------- #
# Hand-computable trace: bus utilization + stall attribution
# --------------------------------------------------------------------- #

# Simple integral grid so every expected number is hand-derivable:
#   tCK=1, tBL=2, tRRD=4, tFAW=30.
T = dataclasses.replace(DDR4_2400, tck=1.0, tbl=2.0, trrd_s=4.0, tfaw=30.0)


def _hand_trace() -> ScheduleResult:
    ev = [
        (Cmd(Op.ACT, 0, 1), 0.0),    # miss class opens b0
        (Cmd(Op.ACT, 1, 2), 4.0),    # waited tRRD: stall 4
        (Cmd(Op.ACT, 2, 3), 8.0),    # stall 4
        (Cmd(Op.ACT, 3, 4), 12.0),   # stall 4
        # 5th ACT: bank ready at 0, tRRD-ready 16, tFAW-ready 0+30=30
        # -> 16 ns credited to tRRD, 14 ns to the tFAW window.
        (Cmd(Op.ACT, 4, 5), 30.0),
        (Cmd(Op.RD, 0, 1), 31.0),    # first column after ACT: row miss
        (Cmd(Op.RD, 0, 1), 33.0),    # same open row: row hit
        (Cmd(Op.PRE, 0, -1), 40.0),  # closes row 1
        (Cmd(Op.ACT, 0, 9), 44.0),   # re-opens b0 with a DIFFERENT row
        (Cmd(Op.WR, 0, 9), 48.0),    # -> row conflict
    ]
    return ScheduleResult(
        total_ns=48.0, energy_j=7e-9, n_acts=6, n_pres=1, n_rdwr=3,
        issue_times=[t for _, t in ev], cmds=[c for c, _ in ev])


def test_hand_trace_command_counts_and_bus_utilization():
    c = derive_controller_counters(_hand_trace(), T)
    assert c["cmd.act"] == 6
    assert c["cmd.pre"] == 1
    assert c["cmd.rdwr"] == 3
    assert c["cmd.total"] == 10
    assert c["wall_ns"] == 48.0
    # 10 non-NOP commands x 1 ns tCK on a 48 ns wall.
    assert c["cmd_bus_busy_ns"] == 10.0
    assert c["cmd_bus_utilization"] == pytest.approx(10 / 48)
    # 3 column bursts x 2 ns tBL.
    assert c["data_bus_busy_ns"] == 6.0
    assert c["data_bus_utilization"] == pytest.approx(6 / 48 )
    assert c["energy_j"] == pytest.approx(7e-9)


def test_hand_trace_stall_attribution():
    c = derive_controller_counters(_hand_trace(), T)
    # Stall = issue delay beyond the bank's own readiness (all five
    # banks ready at t=0 here), credited to tRRD up to the rank's tRRD
    # horizon: ACTs 2-5 waited 4, 8, 12 and 16 ns behind the previous
    # ACT's +4 ns horizon. The 5th then waited 14 ns more for the
    # four-activation window (tFAW horizon 0+30=30 vs tRRD horizon 16).
    assert c["stall.trrd_ns"] == pytest.approx(4 + 8 + 12 + 16)
    assert c["stall.tfaw_ns"] == pytest.approx(14.0)


def test_hand_trace_row_classification():
    c = derive_controller_counters(_hand_trace(), T)
    assert c["row.miss"] == 1       # first RD after opening an idle bank
    assert c["row.hit"] == 1        # second RD on the still-open row
    assert c["row.conflict"] == 1   # WR after re-opening a different row
    assert c["bank0.row_miss"] == 1
    assert c["bank0.row_hit"] == 1
    assert c["bank0.row_conflict"] == 1


def test_same_row_reopen_is_miss_not_conflict():
    ev = [
        (Cmd(Op.ACT, 0, 7), 0.0),
        (Cmd(Op.RD, 0, 7), 14.0),
        (Cmd(Op.PRE, 0, -1), 22.0),
        (Cmd(Op.ACT, 0, 7), 36.0),   # same row back: a miss, no conflict
        (Cmd(Op.RD, 0, 7), 50.0),
    ]
    r = ScheduleResult(total_ns=50.0, energy_j=0.0, n_acts=2, n_pres=1,
                      n_rdwr=2, issue_times=[t for _, t in ev],
                      cmds=[c for c, _ in ev])
    c = derive_controller_counters(r, T)
    assert c["row.miss"] == 2
    assert c.get("row.conflict", 0) == 0


def test_empty_trace():
    r = ScheduleResult(total_ns=0.0, energy_j=0.0, n_acts=0, n_pres=0,
                      n_rdwr=0, issue_times=[], cmds=[])
    c = derive_controller_counters(r, T)
    assert c["cmd.total"] == 0 and c["wall_ns"] == 0
    assert "cmd_bus_utilization" not in c  # undefined at zero wall


# --------------------------------------------------------------------- #
# Real controller traces
# --------------------------------------------------------------------- #


def _maj_programs(n_ops=8, banks=4):
    unit = CostModel(row_bits=65536).maj_unit_programs(3, 8)
    return [retarget_program(p, i % banks)
            for i in range(n_ops) for p in unit]


def test_controller_trace_counters_match_mux_accounting():
    ctrl = MemoryController(n_banks=4)
    tr = ctrl.schedule(_maj_programs())
    c = tr.counters()   # ControllerTrace carries its own timings
    assert c["cmd.act"] == tr.n_acts
    assert c["cmd.pre"] == tr.n_pres
    assert c["cmd.rdwr"] == tr.n_rdwr
    assert c["wall_ns"] == pytest.approx(tr.total_ns)
    assert c["energy_j"] == pytest.approx(tr.energy_j)
    assert c["refresh.n"] == tr.n_refreshes
    assert c["refresh.stall_ns"] == pytest.approx(tr.refresh_stall_ns)
    assert 0 < c["cmd_bus_utilization"] < 1


def test_sequential_scheduler_counters():
    flat = [c for p in _maj_programs(4, 1) for c in p]
    res = CommandScheduler(DDR4_2400).schedule(flat)
    c = res.counters()
    assert c["cmd.act"] == res.n_acts
    assert c["cmd.total"] == res.n_acts + res.n_pres + res.n_rdwr


def test_derivation_is_pure_and_idempotent():
    ctrl = MemoryController(n_banks=4)
    tr = ctrl.schedule(_maj_programs())
    before = (list(tr.cmds), list(tr.issue_times))
    c1 = tr.counters().as_dict()
    c2 = tr.counters().as_dict()
    assert c1 == c2
    assert (list(tr.cmds), list(tr.issue_times)) == before
