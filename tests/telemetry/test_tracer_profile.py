"""Tracer span mechanics, Chrome trace-event export, and the
pum.profile() flush-phase coverage + pipeline-cache counters."""

import json

import numpy as np
import pytest

import repro.pum as pum
from repro.kernels import fused_program as _fused
from repro.telemetry import NULL_TRACER, Tracer

pytestmark = pytest.mark.fused

FLUSH_PHASES = ["flush.record", "flush.optimize", "flush.leaf_upload",
                "flush.compile", "flush.dispatch", "flush.materialize"]


# --------------------------------------------------------------------- #
# Tracer primitives
# --------------------------------------------------------------------- #


def test_span_records_duration_and_args():
    tr = Tracer()
    with tr.span("work", n=3) as sp:
        sp.args["extra"] = "late"
    (name, t0, t1, args), = tr.events
    assert name == "work" and t1 >= t0
    assert args == {"n": 3, "extra": "late"}
    assert sp.dur_ns == t1 - t0


def test_null_tracer_is_inert():
    with NULL_TRACER.span("x", a=1) as sp:
        sp.args["y"] = 2       # writes vanish; no shared state mutated
    assert sp.dur_ns == 0
    assert sp.args == {}
    NULL_TRACER.instant("e")
    NULL_TRACER.add_span("s", 0, 5)


def test_chrome_export_shape(tmp_path):
    tr = Tracer()
    with tr.span("alpha", k="v"):
        pass
    tr.instant("tick")
    path = tmp_path / "trace.json"
    tr.export(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    complete = [e for e in evs if e["ph"] == "X"]
    instants = [e for e in evs if e["ph"] == "i"]
    assert [e["name"] for e in complete] == ["alpha"]
    assert complete[0]["args"] == {"k": "v"}
    assert complete[0]["dur"] >= 0          # microseconds
    assert [e["name"] for e in instants] == ["tick"]


# --------------------------------------------------------------------- #
# pum.profile(): flush-phase coverage + cache counters
# --------------------------------------------------------------------- #


def _work(dev, n=128):
    x = dev.asarray(np.arange(n, dtype=np.uint64))
    return ((x + 3) * x // (x + 1)).to_numpy()


def test_profile_covers_all_flush_phases(tmp_path):
    dev = pum.device(width=16, fuse=True)
    path = tmp_path / "trace.json"
    with pum.profile(dev, path=str(path)) as tr:
        _work(dev)
    names = tr.span_names()
    for phase in FLUSH_PHASES:
        assert phase in names, f"missing span {phase} in {names}"
    # Exported trace carries the same spans plus the counters snapshot.
    doc = json.loads(path.read_text())
    exported = {e["name"] for e in doc["traceEvents"]}
    assert set(FLUSH_PHASES) <= exported
    counter_evs = [e for e in doc["traceEvents"] if e["name"] == "counters"]
    assert len(counter_evs) == 1
    assert counter_evs[0]["args"]["counters"]["engine.flushes"] >= 1


def test_profile_cache_miss_then_hit():
    _fused._cached_pipeline.cache_clear()
    dev = pum.device(width=16, fuse=True)
    with pum.profile(dev):
        _work(dev)          # cold: compile miss
        dev.flush()
        _work(dev)          # identical structure: cache hit
    assert dev.counters["engine.pipeline_cache.miss"] >= 1
    assert dev.counters["engine.pipeline_cache.hit"] >= 1


def test_profile_counts_recorded_ops_and_autoflush():
    dev = pum.device(width=16, fuse=True, flush_threshold=4)
    with pum.profile(dev):
        x = dev.asarray(np.arange(32, dtype=np.uint64))
        for _ in range(6):
            x = x + 1
        x.to_numpy()
    assert dev.counters["engine.ops_recorded"] >= 6
    assert dev.counters["engine.op.add"] >= 6
    assert dev.counters["engine.autoflush.ops"] >= 1
    assert dev.counters["engine.flushes"] >= 2


def test_flush_span_args_carry_graph_shape():
    dev = pum.device(width=16, fuse=True)
    with pum.profile(dev) as tr:
        _work(dev, n=64)
    by_name = {name: args for name, _, _, args in tr.events}
    assert by_name["flush.optimize"]["n_ops_in"] >= 1
    assert by_name["flush.optimize"]["n_ops_out"] >= 1
    assert by_name["flush.dispatch"]["n_lanes"] == 64
    assert by_name["flush.compile"]["cache"] in ("hit", "miss")
