"""ServeEngine per-tick telemetry: counters/spans populate when enabled,
and — the smoke contract — token output is bit-identical with telemetry
on vs off."""

import numpy as np

from repro.config.base import get_smoke_config
from repro.serve.engine import Request, ServeEngine


def _prompts(n=3, rng_seed=1):
    cfg = get_smoke_config("qwen1.5-0.5b")
    rng = np.random.default_rng(rng_seed)
    return cfg, [rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
                 for _ in range(n)]


def _run(cfg, prompts, telemetry):
    eng = ServeEngine(cfg, max_batch=2, max_len=32, eos_id=3, seed=0,
                      telemetry=telemetry)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=5))
    done = eng.run_until_drained(max_ticks=100)
    return eng, sorted((r.rid, tuple(r.out_tokens)) for r in done)


def test_telemetry_does_not_perturb_token_streams():
    cfg, prompts = _prompts()
    _, off = _run(cfg, prompts, telemetry=False)
    _, on = _run(cfg, prompts, telemetry=True)
    assert off == on


def test_telemetry_off_is_inert():
    cfg, prompts = _prompts(n=1)
    eng, _ = _run(cfg, prompts, telemetry=False)
    assert eng.tracer is None
    assert len(eng.counters) == 0


def test_tick_counters_and_slot_occupancy():
    cfg, prompts = _prompts()
    eng, outs = _run(cfg, prompts, telemetry=True)
    assert outs
    assert eng.counters["serve.ticks"] >= 1
    occ = eng.counters.histogram("serve.active_slots")
    assert occ["count"] == eng.counters["serve.ticks"]
    assert 1 <= occ["max"] <= 2    # max_batch=2 bounds occupancy
    # Stop-predicate flush latency histogram: one sample per tick, real
    # wall-clock durations.
    lat = eng.counters.histogram("serve.stop_flush_ns")
    assert lat["count"] == eng.counters["serve.ticks"]
    assert lat["min"] >= 0


def test_tick_spans_nest_stop_predicate():
    cfg, prompts = _prompts(n=2)
    eng, _ = _run(cfg, prompts, telemetry=True)
    names = eng.tracer.span_names()
    assert "serve.tick" in names
    assert "serve.stop_predicate" in names
    by_name = {}
    for name, t0, t1, args in eng.tracer.events:
        by_name.setdefault(name, []).append((t0, t1, args))
    # Every stop-predicate span sits inside some tick span.
    ticks = by_name["serve.tick"]
    for t0, t1, args in by_name["serve.stop_predicate"]:
        assert any(tt0 <= t0 and t1 <= tt1 for tt0, tt1, _ in ticks)
        assert args["path"] in ("pum", "host")
    # Tick spans carry the live occupancy they observed.
    assert all(1 <= a["active_slots"] <= 2 for _, _, a in ticks)


def test_pum_engine_tracer_attached_when_telemetry_on():
    cfg, prompts = _prompts(n=1)
    eng, _ = _run(cfg, prompts, telemetry=True)
    if eng.pum is not None:        # pum_bulk default routes through PuM
        assert eng.pum.engine.tracer is eng.tracer
