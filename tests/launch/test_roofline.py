"""Pin launch/roofline.py estimates on hand-computable shapes.

Every expected value here is written out as explicit arithmetic from the
model-config fields so a reviewer can recompute it by hand; drift in the
analytic FLOPs/bytes model or the term assembly fails loudly.
"""

import pytest

from repro.config.base import LM_SHAPES, get_config
from repro.launch.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                   RooflinePoint, _attn_flops_per_token,
                                   _ffn_flops_per_token,
                                   _layer_flops_per_token, analytic_flops,
                                   analyze_record, loop_trip, model_flops,
                                   to_markdown)

ARCH = "qwen1.5-0.5b"  # dense GQA, no windows -> fully hand-computable


@pytest.fixture(scope="module")
def cfg():
    return get_config(ARCH)


def test_attn_flops_gqa_closed_form(cfg):
    # proj = 2*d*dh*(h + 2*h_kv) + 2*h*dh*d; attn = 4*ctx*h*dh
    d, h, hkv, dh = 1024, 16, 16, 64
    assert (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.resolved_head_dim) == (d, h, hkv, dh)
    proj = 2 * d * dh * (h + 2 * hkv) + 2 * h * dh * d
    assert proj == 8_388_608
    for ctx in (0.0, 2048.0):
        expected = proj + 4 * ctx * h * dh
        assert _attn_flops_per_token(cfg, ctx) == expected


def test_ffn_flops_dense_closed_form(cfg):
    assert cfg.d_ff == 2816 and not cfg.moe
    assert _ffn_flops_per_token(cfg) == 6 * 1024 * 2816 == 17_301_504


def test_layer_flops_is_attn_plus_ffn(cfg):
    ctx = 4096.0 / 2
    expected = _attn_flops_per_token(cfg, ctx) + _ffn_flops_per_token(cfg)
    assert _layer_flops_per_token(cfg, 0, ctx) == expected
    # All 24 layers identical (no sliding windows on this arch).
    assert _layer_flops_per_token(cfg, 23, ctx) == expected


def test_analytic_flops_train_assembly(cfg):
    # train: tokens = B*S, ctx = S/2, mult = 3 + 1 (remat=full),
    # head = 2*d*padded_vocab*tokens*3
    shape = LM_SHAPES["train_4k"]
    tokens = shape.global_batch * shape.seq_len
    per_tok = 24 * (_attn_flops_per_token(cfg, shape.seq_len / 2)
                    + _ffn_flops_per_token(cfg))
    assert cfg.remat == "full"
    head = 2 * cfg.d_model * cfg.padded_vocab * tokens * 3.0
    assert analytic_flops(ARCH, "train_4k") == per_tok * tokens * 4.0 + head


def test_analytic_flops_decode_assembly(cfg):
    # decode: one token per sequence against the full cache context.
    shape = LM_SHAPES["decode_32k"]
    tokens = shape.global_batch
    per_tok = 24 * (_attn_flops_per_token(cfg, float(shape.seq_len))
                    + _ffn_flops_per_token(cfg))
    head = 2 * cfg.d_model * cfg.padded_vocab * tokens
    assert analytic_flops(ARCH, "decode_32k") == per_tok * tokens + head


def test_model_flops_classic_estimators(cfg):
    n = cfg.param_count()
    assert model_flops(ARCH, "train_4k") == 6.0 * n * 256 * 4096
    assert model_flops(ARCH, "prefill_32k") == 2.0 * n * 32 * 32768
    assert model_flops(ARCH, "decode_32k") == 2.0 * n * 128


def test_loop_trip_scanned_layers(cfg):
    # Uniform dense stack: the layer scan dominates on every shape.
    n_scan = cfg.n_layers - cfg.first_dense_layers
    assert n_scan == 24
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        assert loop_trip(ARCH, shape) == n_scan


def test_analyze_record_term_assembly():
    rec = {
        "arch": ARCH, "shape": "decode_32k", "mesh": "2x16x16",
        "n_devices": 512, "flops": 1.0e15,
        "collectives": {"total_weighted": 9.0e9, "region_weighted": 4.0e9},
    }
    p = analyze_record(rec)
    assert p.compute_s == analytic_flops(ARCH, "decode_32k") / (512 * PEAK_FLOPS)
    # region bytes replayed once per scanned layer, main bytes once
    assert p.collective_s == (5.0e9 + 4.0e9 * 24) / ICI_BW
    assert p.hlo_flops_raw == 1.0e15
    assert p.model_flops == model_flops(ARCH, "decode_32k")
    assert p.memory_s > 0


def test_roofline_point_derived_properties():
    p = RooflinePoint(arch="x", shape="y", mesh="2x16x16",
                      compute_s=2e-3, memory_s=5e-3, collective_s=1e-3,
                      model_flops=512 * PEAK_FLOPS * 1e-3,
                      analytic_flops_total=512 * PEAK_FLOPS * 4e-3,
                      hlo_flops_raw=-1.0)
    assert p.dominant == "memory"
    assert p.bound_s == 5e-3
    assert p.useful_ratio == pytest.approx(0.25)
    # useful_s = model_flops/(512*PEAK) = 1e-3; fraction = 1e-3 / 5e-3
    assert p.roofline_fraction == pytest.approx(0.2)


def test_markdown_table_shape():
    p = RooflinePoint(arch="a", shape="s", mesh="m", compute_s=1e-3,
                      memory_s=2e-3, collective_s=3e-3, model_flops=1e12,
                      analytic_flops_total=2e12, hlo_flops_raw=1e12)
    failed = RooflinePoint(arch="b", shape="s", mesh="m", compute_s=0,
                           memory_s=0, collective_s=0, model_flops=0,
                           analytic_flops_total=0, hlo_flops_raw=-1,
                           status="compile_error")
    md = to_markdown([p, failed])
    lines = md.splitlines()
    assert lines[0].startswith("| arch |") and len(lines) == 4
    assert "collective" in lines[2] and "FAILED" in lines[3]


def test_autotune_cost_model_anchors_to_roofline_constants():
    """repro.autotune's cost model derives its host-side rates from the
    same hardware roof — the anchoring the planner's estimates rely on."""
    from repro.autotune.cost import HOST_BW, HOST_WORD_RATE
    assert HOST_BW == HBM_BW / 16
    assert HOST_WORD_RATE == PEAK_FLOPS / 1e5
