"""Per-kernel validation: Pallas (interpret mode) vs jnp oracle vs NumPy,
swept over shapes/dtypes, plus hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dep: fixed-seed fallback
    from repro.testing import given, settings, st

from repro.core.layout import from_vertical, to_vertical
from repro.kernels import ref
from repro.kernels.bit_transpose import bit_transpose32
from repro.kernels.bitserial_add import bitserial_add
from repro.kernels.charge_share import charge_share
from repro.kernels.maj_n import maj_n


def rand_words(shape, seed, dtype=np.int32):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2**32, shape, dtype=np.uint64).astype(np.uint32) \
        .view(np.int32).astype(dtype) if dtype == np.int32 else \
        rng.integers(0, 2**32, shape, dtype=np.uint64).astype(dtype)


# --------------------------------------------------------------------- #
# maj_n
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("n,threshold", [(1, 1), (3, 2), (4, 3), (5, 3),
                                         (7, 4), (16, 9), (31, 16), (32, 17)])
@pytest.mark.parametrize("w", [128, 1024, 1536])
def test_maj_n_vs_numpy(n, threshold, w):
    x = rand_words((n, w), seed=n * 100 + w)
    got = np.asarray(maj_n(jnp.asarray(x), threshold, interpret=True))
    bits = ((x.view(np.uint32)[:, :, None] >> np.arange(32)[None, None]) & 1)
    want_bits = (bits.sum(0) >= threshold).astype(np.uint32)
    want = (want_bits << np.arange(32)[None]).sum(-1, dtype=np.uint64) \
        .astype(np.uint32).view(np.int32)
    np.testing.assert_array_equal(got.view(np.int32), want)


@pytest.mark.parametrize("n,threshold", [(3, 2), (5, 3), (9, 5)])
def test_maj_n_ref_matches_pallas(n, threshold):
    x = jnp.asarray(rand_words((n, 2048), seed=7))
    np.testing.assert_array_equal(
        np.asarray(maj_n(x, threshold, interpret=True)),
        np.asarray(ref.maj_n(x, threshold)))


@given(n=st.integers(1, 9), seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_maj_n_property_replication_invariance(n, seed):
    """MAJ over k-replicated inputs == MAJ over originals (the paper's
    majority-algebra identity behind input replication, §5.1)."""
    if n % 2 == 0:
        return
    x = jnp.asarray(rand_words((n, 256), seed=seed))
    base = ref.maj_n(x, n // 2 + 1)
    rep = jnp.concatenate([x, x, x], axis=0)  # 3 copies
    got = ref.maj_n(rep, (3 * n) // 2 + 1)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(got))


def test_maj_n_all_ones_zeros():
    ones = jnp.full((5, 256), -1, jnp.int32)
    zeros = jnp.zeros((5, 256), jnp.int32)
    assert (np.asarray(maj_n(ones, 3, interpret=True)) == -1).all()
    assert (np.asarray(maj_n(zeros, 3, interpret=True)) == 0).all()


# --------------------------------------------------------------------- #
# bitserial_add
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("width", [4, 8, 16, 32])
@pytest.mark.parametrize("n_el", [256, 4096])
def test_bitserial_add_vs_int_add(width, n_el):
    rng = np.random.default_rng(width + n_el)
    a = rng.integers(0, 1 << width, n_el, dtype=np.uint64)
    b = rng.integers(0, 1 << width, n_el, dtype=np.uint64)
    pa = to_vertical(a, width).view(np.int32)
    pb = to_vertical(b, width).view(np.int32)
    got_planes = np.asarray(bitserial_add(jnp.asarray(pa), jnp.asarray(pb),
                                          interpret=True))
    got = from_vertical(got_planes.view(np.uint32))
    np.testing.assert_array_equal(got, (a + b) & ((1 << width) - 1))


def test_bitserial_add_ref_matches():
    a = jnp.asarray(rand_words((8, 1024), 1))
    b = jnp.asarray(rand_words((8, 1024), 2))
    np.testing.assert_array_equal(
        np.asarray(bitserial_add(a, b, interpret=True)),
        np.asarray(ref.bitserial_add(a, b)))


@given(seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_bitserial_add_property(seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 1 << 16, 64, dtype=np.uint64)
    b = rng.integers(0, 1 << 16, 64, dtype=np.uint64)
    pa = jnp.asarray(to_vertical(a, 16).view(np.int32))
    pb = jnp.asarray(to_vertical(b, 16).view(np.int32))
    got = from_vertical(np.asarray(ref.bitserial_add(pa, pb)).view(np.uint32))
    np.testing.assert_array_equal(got, (a + b) & 0xFFFF)


# --------------------------------------------------------------------- #
# bit_transpose32
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("g", [1, 7, 128, 1024])
def test_transpose_matches_layout(g):
    rng = np.random.default_rng(g)
    vals = rng.integers(0, 2**32, 32 * g, dtype=np.uint64)
    # Horizontal: row k of tile t = vals[32t + k]
    horiz = vals.reshape(g, 32).T.astype(np.uint32).view(np.int32)  # [32, G]
    got = np.asarray(bit_transpose32(jnp.asarray(horiz), interpret=True))
    # Vertical oracle: per tile, plane j = bit j of the tile's 32 values.
    for t in range(min(g, 4)):
        planes = to_vertical(vals[32 * t:32 * (t + 1)], 32)
        np.testing.assert_array_equal(got[:, t].view(np.uint32), planes[:, 0])


def test_transpose_involution():
    x = jnp.asarray(rand_words((32, 256), 3))
    once = ref.bit_transpose32(x)
    twice = ref.bit_transpose32(once)
    np.testing.assert_array_equal(np.asarray(twice), np.asarray(x))


def test_transpose_pallas_vs_ref():
    x = jnp.asarray(rand_words((32, 2048), 4))
    np.testing.assert_array_equal(
        np.asarray(bit_transpose32(x, interpret=True)),
        np.asarray(ref.bit_transpose32(x)))


@given(seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_transpose_property_involution(seed):
    x = jnp.asarray(rand_words((32, 64), seed))
    np.testing.assert_array_equal(
        np.asarray(ref.bit_transpose32(ref.bit_transpose32(x))),
        np.asarray(x))


# --------------------------------------------------------------------- #
# charge_share
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("n,b", [(4, 256), (8, 1024), (32, 3000)])
def test_charge_share_vs_ref(n, b):
    rng = np.random.default_rng(n + b)
    v = rng.choice([0.0, 0.6, 1.2], (n, b)).astype(np.float32)
    caps = (20 + 2 * rng.standard_normal((n, b))).astype(np.float32)
    got = np.asarray(charge_share(jnp.asarray(v), jnp.asarray(caps),
                                  vdd=1.2, c_bl=116.0, interpret=True))
    want = np.asarray(ref.charge_share(jnp.asarray(v), jnp.asarray(caps),
                                       vdd=1.2, c_bl=116.0))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_charge_share_physics():
    """All-VDD cells give positive dV scaling with N/(N+r)."""
    n, b = 8, 128
    v = np.full((n, b), 1.2, np.float32)
    caps = np.full((n, b), 20.0, np.float32)
    dv = np.asarray(ref.charge_share(jnp.asarray(v), jnp.asarray(caps),
                                     vdd=1.2, c_bl=116.0))
    expected = 8 * 20 * 0.6 / (116 + 8 * 20)
    np.testing.assert_allclose(dv, expected, rtol=1e-6)


@pytest.mark.parametrize("n,threshold", [(3, 2), (7, 4), (31, 16)])
def test_maj_n_fast_matches_oracle(n, threshold):
    x = jnp.asarray(rand_words((n, 1024), seed=99 + n))
    np.testing.assert_array_equal(
        np.asarray(ref.maj_n_fast(x, threshold)),
        np.asarray(ref.maj_n(x, threshold)))


# --------------------------------------------------------------------- #
# fused_program
# --------------------------------------------------------------------- #

from repro.kernels.fused_program import (FusedOp, FusedProgram,  # noqa: E402
                                         get_pipeline, run_program_pallas,
                                         run_program_ref)

_FUSED_DEMO = FusedProgram(
    width=16, n_inputs=3,
    ops=(FusedOp("and", (0, 1)),
         FusedOp("xor", (3, 2)),
         FusedOp("add", (4, 0)),
         FusedOp("sub", (5, 1)),
         FusedOp("less", (6, 2)),
         FusedOp("popcount", (5,)),
         FusedOp("reduce_and", (3,), param=16),
         FusedOp("reduce_or", (6,)),
         FusedOp("reduce_xor", (5,))),
    outputs=(6, 7, 8, 9, 10, 11))


def _fused_demo_stacks(n_el, seed):
    rng = np.random.default_rng(seed)
    vals = [rng.integers(0, 1 << 16, n_el, dtype=np.uint64)
            for _ in range(3)]
    stack = jnp.asarray(np.stack([to_vertical(v, 16).view(np.int32)
                                  for v in vals]))
    return vals, stack


def _fused_demo_oracle(vals):
    a, b, c = vals
    mask = np.uint64(0xFFFF)
    t0 = a & b
    t1 = t0 ^ c
    t2 = (t1 + a) & mask
    t3 = (t2 - b) & mask
    return [t3, (t3 < c).astype(np.uint64),
            np.array([bin(int(x)).count("1") for x in t2], np.uint64),
            (t0 == mask).astype(np.uint64),
            (t3 != 0).astype(np.uint64),
            np.array([bin(int(x)).count("1") & 1 for x in t2], np.uint64)]


@pytest.mark.parametrize("n_el", [256, 4096])
def test_fused_program_ref_vs_numpy(n_el):
    vals, stack = _fused_demo_stacks(n_el, seed=n_el)
    got = np.asarray(run_program_ref(_FUSED_DEMO, stack)).view(np.uint32)
    for plane_stack, want in zip(got, _fused_demo_oracle(vals)):
        np.testing.assert_array_equal(from_vertical(plane_stack), want)


def test_fused_program_pallas_matches_ref():
    from repro.kernels import run_fused_program
    _, stack = _fused_demo_stacks(2048, seed=1)
    want = np.asarray(run_program_ref(_FUSED_DEMO, stack))
    np.testing.assert_array_equal(
        np.asarray(run_program_pallas(_FUSED_DEMO, stack, interpret=True)),
        want)
    # ops-layer dispatch: oracle on CPU, Pallas under force_pallas
    np.testing.assert_array_equal(
        np.asarray(run_fused_program(_FUSED_DEMO, stack)), want)
    np.testing.assert_array_equal(
        np.asarray(run_fused_program(_FUSED_DEMO, stack, force_pallas=True,
                                     interpret=True)), want)


def test_fused_pipeline_end_to_end():
    """get_pipeline handles the framing too, and the CPU word-domain path
    must agree bit-for-bit with the vertical transpose+planes form."""
    vals, _ = _fused_demo_stacks(512, seed=2)
    leaves = [jnp.asarray(v.astype(np.uint32).view(np.int32)) for v in vals]
    outs = get_pipeline(_FUSED_DEMO)(*leaves)
    vert = get_pipeline(_FUSED_DEMO, force_vertical=True)(*leaves)
    for got, gvert, want in zip(outs, vert, _fused_demo_oracle(vals)):
        np.testing.assert_array_equal(
            np.asarray(got).view(np.uint32).astype(np.uint64), want)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(gvert))


@given(seed=st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_fused_plane_algebra_property(seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 1 << 16, 64, dtype=np.uint64)
    b = rng.integers(0, 1 << 16, 64, dtype=np.uint64)
    pa = [jnp.asarray(p.view(np.int32)) for p in to_vertical(a, 16)]
    pb = [jnp.asarray(p.view(np.int32)) for p in to_vertical(b, 16)]

    add = np.stack([np.asarray(p).view(np.uint32)
                    for p in ref.plane_add(pa, pb)])
    np.testing.assert_array_equal(from_vertical(add), (a + b) & 0xFFFF)

    diff, borrow = ref.plane_sub(pa, pb)
    diff = np.stack([np.asarray(p).view(np.uint32) for p in diff])
    np.testing.assert_array_equal(from_vertical(diff), (a - b) & 0xFFFF)
    lt = from_vertical(np.asarray(borrow).view(np.uint32)[None])
    np.testing.assert_array_equal(lt, (a < b).astype(np.uint64))

    counts = ref.plane_popcount(pa)
    counts = np.stack([np.asarray(p).view(np.uint32) for p in counts])
    want = np.array([bin(int(x)).count("1") for x in a], np.uint64)
    np.testing.assert_array_equal(from_vertical(counts), want)


@given(seed=st.integers(0, 100))
@settings(max_examples=6, deadline=None)
def test_fused_plane_mul_divmod_property(seed):
    """plane_mul (shift-add) and plane_divmod (restoring division) match
    word arithmetic modulo 2**width, including zero divisors (-> 0)."""
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 1 << 16, 64, dtype=np.uint64)
    b = rng.integers(0, 1 << 16, 64, dtype=np.uint64)
    b[::5] = 0  # div/mod-by-zero lanes
    a[0], b[1], a[2] = 0xFFFF, 0xFFFF, 1 << 15
    pa = [jnp.asarray(p.view(np.int32)) for p in to_vertical(a, 16)]
    pb = [jnp.asarray(p.view(np.int32)) for p in to_vertical(b, 16)]

    prod = np.stack([np.asarray(p).view(np.uint32)
                     for p in ref.plane_mul(pa, pb)])
    np.testing.assert_array_equal(from_vertical(prod), (a * b) & 0xFFFF)

    q, r = ref.plane_divmod(pa, pb)
    q = np.stack([np.asarray(p).view(np.uint32) for p in q])
    r = np.stack([np.asarray(p).view(np.uint32) for p in r])
    safe = np.maximum(b, 1)
    np.testing.assert_array_equal(from_vertical(q),
                                  np.where(b == 0, 0, a // safe))
    np.testing.assert_array_equal(from_vertical(r),
                                  np.where(b == 0, 0, a % safe))


_ARITH_DEMO = FusedProgram(
    width=8, n_inputs=2,
    ops=(FusedOp("mul", (0, 1)),
         FusedOp("div", (0, 1)),
         FusedOp("mod", (0, 1)),
         FusedOp("div", (2, 1)),
         # the PR 4 tuple op: one divider pass feeding both selectors
         FusedOp("divmod", (2, 1)),
         FusedOp("fst", (6,)),
         FusedOp("snd", (6,))),
    outputs=(2, 3, 4, 5, 7, 8))


def test_fused_program_mul_div_mod_all_evaluators():
    """The three evaluators agree on the arithmetic opcodes added in PR 3
    (mul/div/mod) and the PR 4 divmod/fst/snd tuple form, including
    division by zero."""
    rng = np.random.default_rng(9)
    a = rng.integers(0, 256, 2048, dtype=np.uint64)
    b = rng.integers(0, 256, 2048, dtype=np.uint64)
    b[::7] = 0
    stack = jnp.asarray(np.stack([to_vertical(v, 8).view(np.int32)
                                  for v in (a, b)]))
    want = np.asarray(run_program_ref(_ARITH_DEMO, stack))
    np.testing.assert_array_equal(
        np.asarray(run_program_pallas(_ARITH_DEMO, stack, interpret=True)),
        want)
    leaves = [jnp.asarray(v.astype(np.uint32).view(np.int32))
              for v in (a, b)]
    word = get_pipeline(_ARITH_DEMO)(*leaves)
    vert = get_pipeline(_ARITH_DEMO, force_vertical=True)(*leaves)
    safe = np.maximum(b, 1)
    oracle = [(a * b) & 0xFF, np.where(b == 0, 0, a // safe),
              np.where(b == 0, 0, a % safe)]
    oracle.append(np.where(b == 0, 0, oracle[0] // safe))
    oracle.append(oracle[3])                       # fst(divmod) == div
    oracle.append(np.where(b == 0, 0, oracle[0] % safe))  # snd == mod
    for got, gvert, w in zip(word, vert, oracle):
        np.testing.assert_array_equal(
            np.asarray(got).view(np.uint32).astype(np.uint64), w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(gvert))


def test_optimize_program_cse_and_dce():
    from repro.kernels.fused_program import optimize_program
    p = FusedProgram(
        width=16, n_inputs=3,
        ops=(FusedOp("add", (0, 1)),      # 3
             FusedOp("add", (1, 0)),      # 4 == 3 (commutative CSE)
             FusedOp("xor", (3, 4)),      # 5 -> xor(3, 3)
             FusedOp("and", (0, 2)),      # 6: dead (leaf 2 with it)
             FusedOp("sub", (3, 4)),      # 7 -> sub(3, 3) kept: output
             FusedOp("sub", (4, 3))),     # 8 == 7 after canonicalization
        outputs=(5, 7, 8))
    opt, out_pos, leaf_map = optimize_program(p)
    assert leaf_map == (0, 1)             # leaf 2 pruned with the dead and
    assert len(opt.ops) == 3              # add, xor, sub survive
    assert [op.opcode for op in opt.ops] == ["add", "xor", "sub"]
    assert out_pos == (0, 1, 1)           # outputs 7 and 8 share a value
    assert len(opt.outputs) == 2
    # Determinism: the same structure normalizes identically (cache key).
    assert optimize_program(p)[0] == opt


def test_optimize_program_preserves_noncommutative_order():
    from repro.kernels.fused_program import optimize_program
    p = FusedProgram(
        width=8, n_inputs=2,
        ops=(FusedOp("sub", (0, 1)), FusedOp("sub", (1, 0))),
        outputs=(2, 3))
    opt, out_pos, _ = optimize_program(p)
    assert len(opt.ops) == 2              # a-b and b-a must NOT unify
    assert out_pos == (0, 1)
