"""Training stack: optimizer math, schedules, grad compression, trainer
loop convergence, checkpoint save/restore/resume, serve engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.config.base import TrainConfig, get_smoke_config
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                               plan_elastic_mesh)
from repro.models.model import init_params
from repro.serve.engine import Request, ServeEngine
from repro.train import grad_compress
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   clip_by_global_norm, global_norm,
                                   warmup_cosine)
from repro.train.trainer import TrainLoopHooks, build_train_step, \
    init_train_state, train_loop


def test_adamw_matches_reference():
    """One AdamW step vs a hand-rolled NumPy reference."""
    cfg = AdamWConfig(learning_rate=1e-2, beta1=0.9, beta2=0.99,
                      eps=1e-8, weight_decay=0.01)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    st = adamw_init(p)
    new_p, st2 = adamw_update(cfg, p, g, st)
    mu = 0.1 * np.array([0.1, 0.2, -0.3])
    nu = 0.01 * np.array([0.1, 0.2, -0.3]) ** 2
    mhat = mu / (1 - 0.9)
    nhat = nu / (1 - 0.99)
    want = (np.array([1.0, -2.0, 3.0])
            - 1e-2 * (mhat / (np.sqrt(nhat) + 1e-8)
                      + 0.01 * np.array([1.0, -2.0, 3.0])))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-6)
    assert int(st2["step"]) == 1


def test_grad_clip():
    g = {"a": jnp.full((10,), 3.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    assert float(norm) == pytest.approx(np.sqrt(90.0), rel=1e-5)


def test_warmup_cosine_shape():
    assert float(warmup_cosine(jnp.array(0), 10, 100)) == 0.0
    assert float(warmup_cosine(jnp.array(10), 10, 100)) == pytest.approx(1.0)
    assert float(warmup_cosine(jnp.array(100), 10, 100)) == pytest.approx(
        0.1, abs=1e-6)


def test_grad_compression_error_feedback():
    """Error feedback keeps the accumulated compressed signal unbiased:
    sum of dequantized grads ~ sum of true grads."""
    rng = np.random.default_rng(0)
    true = [jnp.asarray(rng.standard_normal(64).astype(np.float32) * 0.01)
            for _ in range(50)]
    err = {"g": jnp.zeros(64)}
    acc = np.zeros(64)
    for g in true:
        deq, err_new = grad_compress.compress_grads_with_feedback(
            {"g": g}, err)
        err = err_new
        acc += np.asarray(deq["g"])
    want = np.sum([np.asarray(g) for g in true], axis=0)
    np.testing.assert_allclose(acc, want, atol=2e-3)


def test_sign_bitmaps_pack_roundtrip_and_pum_parity():
    """The 1-bit sign/mask path: pack/unpack round-trips, the PuM-routed
    wire bitmap and MAJ3 agree with direct NumPy, and an eager device
    produces bit-identical bitmaps (and identical cost-plane charges) to
    a fused one — the raw packed-bitmap planewise contract."""
    import repro.pum as pum
    rng = np.random.default_rng(7)
    t = rng.standard_normal(1000).astype(np.float32)
    sign_w, mask_w, scale = grad_compress.sign_mask_bitmaps(t, 0.5)
    np.testing.assert_array_equal(
        grad_compress.unpack_bitmap(sign_w, t.size), t < 0)
    np.testing.assert_array_equal(
        grad_compress.unpack_bitmap(mask_w, t.size), np.abs(t) >= 0.5)
    assert scale == pytest.approx(float(np.abs(t[np.abs(t) >= 0.5]).mean()))

    eager = pum.device(width=32, fuse=False)
    fused = pum.device(width=32, fuse=True)
    wire_e = grad_compress.pum_wire_bitmap(sign_w, mask_w, eager)
    wire_f = grad_compress.pum_wire_bitmap(sign_w, mask_w, fused)
    np.testing.assert_array_equal(wire_e, sign_w & mask_w)
    np.testing.assert_array_equal(wire_e, wire_f)

    votes = [grad_compress.pack_bitmap(rng.standard_normal(1000) < 0)
             for _ in range(3)]
    maj_e = grad_compress.pum_sign_majority3(*votes, eager)
    maj_f = grad_compress.pum_sign_majority3(*votes, fused)
    want = (votes[0] & votes[1]) | (votes[1] & votes[2]) \
        | (votes[0] & votes[2])
    np.testing.assert_array_equal(maj_e, want)
    np.testing.assert_array_equal(maj_e, maj_f)
    assert eager.stats == fused.stats
    assert eager.stats.latency_ns > 0  # the bitmap ops were priced


def test_sign_compression_error_feedback_tracks_true_grads():
    """1-bit signSGD-style compression with error feedback stays unbiased
    over time, like the int8 path (eager and fused devices identical)."""
    import repro.pum as pum
    rng = np.random.default_rng(1)
    true = [rng.standard_normal(256).astype(np.float32) * 0.01
            for _ in range(60)]
    accs = []
    for fuse in (False, True):
        dev = pum.device(width=32, fuse=fuse)
        err = {"g": jnp.zeros(256)}
        acc = np.zeros(256)
        for g in true:
            deq, err = grad_compress.compress_grads_sign_with_feedback(
                {"g": jnp.asarray(g)}, err, device=dev, tau_factor=0.5)
            acc += np.asarray(deq["g"])
        accs.append(acc)
    np.testing.assert_array_equal(accs[0], accs[1])  # eager == fused
    want = np.sum(true, axis=0)
    # 1-bit is coarser than int8: error feedback still keeps the running
    # sum tracking the true gradient direction.
    cos = float(np.dot(accs[0], want)
                / (np.linalg.norm(accs[0]) * np.linalg.norm(want)))
    assert cos > 0.9


def test_train_loop_loss_decreases(tmp_path):
    cfg = get_smoke_config("qwen1.5-0.5b")
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=5, total_steps=60,
                       checkpoint_every=0)
    data = Prefetcher(SyntheticLM(DataConfig(seq_len=64, global_batch=8,
                                             vocab_size=cfg.vocab_size)))
    try:
        _, _, hist = train_loop(cfg, tcfg, data, 60)
    finally:
        data.close()
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.5, f"loss did not fall: {first} -> {last}"


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_smoke_config("qwen1.5-0.5b")
    data = SyntheticLM(DataConfig(seq_len=32, global_batch=8,
                                  vocab_size=cfg.vocab_size))
    batch = jax.tree.map(jnp.asarray, data.batch(0))
    t1 = TrainConfig(microbatches=1, grad_clip=1e9, z_loss=0.0)
    t4 = TrainConfig(microbatches=4, grad_clip=1e9, z_loss=0.0)
    params, opt = init_train_state(cfg, t1, jax.random.PRNGKey(0))
    p1, _, m1 = build_train_step(cfg, t1)(params, opt, batch)
    params, opt = init_train_state(cfg, t4, jax.random.PRNGKey(0))
    p4, _, m4 = build_train_step(cfg, t4)(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=2e-4)
    a = np.asarray(p1["embed"]["embedding"])
    b = np.asarray(p4["embed"]["embedding"])
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-5)


def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg = get_smoke_config("mamba2-130m")
    tcfg = TrainConfig(checkpoint_every=5, total_steps=10)
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    data = Prefetcher(SyntheticLM(DataConfig(seq_len=32, global_batch=4,
                                             vocab_size=cfg.vocab_size)))
    try:
        params, opt, _ = train_loop(cfg, tcfg, data, 10, checkpoint=ckpt)
    finally:
        data.close()
    ckpt.wait()
    assert ckpt.latest_step() == 10
    p2, o2, meta = ckpt.restore(10, params, opt)
    np.testing.assert_allclose(
        np.asarray(params["embed"]["embedding"]),
        np.asarray(p2["embed"]["embedding"]))
    assert meta["step"] == 10
    # Retention: only `keep` checkpoints remain.
    assert len(ckpt.all_steps()) <= 2


def test_checkpoint_resume_continues(tmp_path):
    cfg = get_smoke_config("mamba2-130m")
    tcfg = TrainConfig(checkpoint_every=5, total_steps=20)
    ckpt = CheckpointManager(str(tmp_path))
    data = Prefetcher(SyntheticLM(DataConfig(seq_len=32, global_batch=4,
                                             vocab_size=cfg.vocab_size)))
    try:
        train_loop(cfg, tcfg, data, 5, checkpoint=ckpt)  # partial run
    finally:
        data.close()
    ckpt.wait()
    data2 = Prefetcher(SyntheticLM(DataConfig(seq_len=32, global_batch=4,
                                              vocab_size=cfg.vocab_size)),
                       start_step=5)
    try:
        _, _, hist = train_loop(cfg, tcfg, data2, 8, checkpoint=ckpt,
                                resume=True)
    finally:
        data2.close()
    assert len(hist) == 3  # resumed from 5, ran to 8


def test_heartbeat_and_straggler():
    mon = HeartbeatMonitor(timeout_s=0.2)
    for w in ("a", "b", "c", "d"):
        for _ in range(8):
            mon.beat(w, 0.1 if w != "d" else 0.5)
    assert mon.stragglers() == ["d"]
    import time
    time.sleep(0.3)
    mon.beat("a")
    assert set(mon.dead_workers()) == {"b", "c", "d"}


def test_elastic_mesh_plan():
    assert plan_elastic_mesh(512, 16) == (32, 16)
    assert plan_elastic_mesh(496, 16) == (31, 16)  # one node lost
    with pytest.raises(RuntimeError):
        plan_elastic_mesh(8, 16)


def test_serve_engine_end_to_end():
    cfg = get_smoke_config("qwen1.5-0.5b")
    eng = ServeEngine(cfg, max_batch=2, max_len=64, eos_id=-1)
    rng = np.random.default_rng(0)
    for rid in range(4):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab_size, 8,
                                               dtype=np.int32),
                           max_new_tokens=4))
    done = eng.run_until_drained(max_ticks=200)
    assert len(done) == 4
    for req in done:
        assert len(req.out_tokens) == 4
        assert all(0 <= t < cfg.padded_vocab for t in req.out_tokens)


def test_serve_pum_bulk_stop_mask_matches_host_path():
    """The PuM-routed bulk stop predicate (pum_bulk=True, the default)
    must admit/finish exactly the same token streams as the host loop."""
    cfg = get_smoke_config("qwen1.5-0.5b")
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
               for _ in range(3)]
    outs = []
    for pum_bulk in (True, False):
        eng = ServeEngine(cfg, max_batch=2, max_len=32, eos_id=3, seed=0,
                          pum_bulk=pum_bulk)
        assert (eng.pum is not None) == pum_bulk
        for rid, p in enumerate(prompts):
            eng.submit(Request(rid=rid, prompt=p, max_new_tokens=5))
        done = eng.run_until_drained(max_ticks=100)
        outs.append(sorted((r.rid, tuple(r.out_tokens)) for r in done))
    assert outs[0] == outs[1]
    # the bulk bookkeeping was priced on the PuM cost plane
    eng2 = ServeEngine(cfg, max_batch=2, max_len=32, eos_id=3, seed=0)
    eng2.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=2))
    eng2.run_until_drained(max_ticks=20)
    assert eng2.pum.stats.latency_ns > 0


def test_serve_engine_matches_prefill_decode():
    """Engine slot path produces the same tokens as a direct loop."""
    from repro.models.model import decode_step, prefill
    cfg = get_smoke_config("mamba2-130m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    eng = ServeEngine(cfg, params=params, max_batch=2, max_len=32, eos_id=-1)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=3))
    done = eng.run_until_drained(max_ticks=50)
    logits, caches, _ = prefill(cfg, params, {"tokens": jnp.asarray(prompt)[None]}, 32)
    toks = [int(jnp.argmax(logits[0, :cfg.vocab_size]))]
    pos = 8
    for _ in range(2):
        logits, caches = decode_step(cfg, params, caches,
                                     jnp.asarray([toks[-1]]),
                                     jnp.asarray([pos], jnp.int32))
        toks.append(int(jnp.argmax(logits[0, :cfg.vocab_size])))
        pos += 1
    assert done[0].out_tokens == toks
