"""Bank-parallel MemoryController: bank machines, multiplexer, refresher."""

import pytest

from repro.controller import (BankMachine, BankState, MemoryController,
                              retarget_program)
from repro.core import commands as cmds
from repro.core.commands import Cmd, Op
from repro.core.cost_model import CostModel
from repro.core.timing import DDR4_2400 as T

ALL_PROGRAMS = {
    "apa": lambda b: cmds.prog_apa_charge_share(b, 0, 1, T),
    "aap": lambda b: cmds.prog_aap_multi_row_init(b, 0, 1, T),
    "bulk_write": lambda b: cmds.prog_bulk_write(b, 0, 1, 8, T),
    "write_row": lambda b: cmds.prog_write_row(b, 5, 8, T),
    "read_row": lambda b: cmds.prog_read_row(b, 5, 8, T),
    "frac": lambda b: cmds.prog_frac(b, 3, T),
}


# --------------------------------------------------------------------- #
# BankMachine: open-row tracking + precharge policy
# --------------------------------------------------------------------- #

def test_bank_machine_row_hit_miss_transitions():
    bm = BankMachine(0, T)
    bm.enqueue_access(5)                  # idle -> ACT + RD
    bm.enqueue_access(5)                  # hit  -> RD only
    bm.enqueue_access(9)                  # miss -> PRE + ACT + RD
    ops = [q.cmd.op for q in bm.queue]
    assert ops == [Op.ACT, Op.RD, Op.RD, Op.PRE, Op.ACT, Op.RD]
    # FSM state follows issued commands.
    assert bm.state is BankState.IDLE
    t = 0.0
    for _ in range(2):
        t = max(t + 1, bm.earliest_issue())
        bm.issue(t)
    assert bm.state is BankState.ACTIVE and bm.open_row == 5
    for _ in range(2):                    # hit RD + the PRE
        t = max(t + 1, bm.earliest_issue())
        bm.issue(t)
    assert bm.state is BankState.IDLE and bm.open_row is None


def test_bank_machine_closed_page_auto_precharges():
    bm = BankMachine(0, T, open_page=False)
    bm.enqueue_access(5)
    ops = [q.cmd.op for q in bm.queue]
    assert ops == [Op.ACT, Op.RD, Op.PRE]
    bm.enqueue_access(5)                  # closed page: never a hit
    assert [q.cmd.op for q in bm.queue][3:] == [Op.ACT, Op.RD, Op.PRE]


def test_bank_machine_sequence_boundaries():
    bm = BankMachine(2, T)
    bm.enqueue_program(cmds.prog_apa_charge_share(2, 0, 1, T))
    bm.enqueue_program(cmds.prog_frac(2, 3, T))
    starts = [q.seq_start for q in bm.queue]
    assert starts == [True, False, False, False, False, True, False, False]
    assert len({q.seq_id for q in bm.queue}) == 2


# --------------------------------------------------------------------- #
# Equivalence: single-bank controller == sequential CommandScheduler
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("name", sorted(ALL_PROGRAMS))
def test_single_bank_matches_legacy_scheduler(name):
    prog = ALL_PROGRAMS[name](0)
    legacy = cmds.CommandScheduler(T).schedule(prog)
    ctrl = MemoryController(n_banks=16).schedule(prog)
    assert ctrl.total_ns == pytest.approx(legacy.total_ns, abs=1.0)
    for (c, t_ctrl), t_leg in zip(ctrl.events, legacy.issue_times):
        assert t_ctrl == pytest.approx(t_leg, abs=1.0)
    assert ctrl.n_acts == legacy.n_acts
    assert ctrl.energy_j == pytest.approx(legacy.energy_j)


def test_maj_unit_programs_match_closed_form_cost():
    cm = CostModel()
    for m, n_rg in [(3, 4), (3, 8), (5, 8), (5, 16)]:
        unit = cm.maj_unit_programs(m, n_rg)
        sched = MemoryController(n_banks=1).schedule_batch(
            unit, 1, refresh=False).total_ns
        assert sched == pytest.approx(cm.maj_op(m, n_rg).latency_ns,
                                      abs=1e-6)


def test_schedule_result_events_are_auditable():
    prog = cmds.prog_bulk_write(0, 0, 1, 4, T)
    res = cmds.CommandScheduler(T).schedule(prog)
    assert len(res.cmds) == len(res.issue_times) == len(prog)
    assert [c.tag for c, _ in res.events] == [c.tag for c in prog]
    # Controller traces interleave banks; events keep the (cmd, t) pairing.
    multi = [retarget_program(prog, b) for b in range(4)]
    tr = MemoryController(n_banks=4).schedule(multi)
    assert len(tr.events) == 4 * len(prog)
    times = [t for _, t in tr.events]
    assert times == sorted(times)
    by_bank = {b: [t for c, t in tr.events if c.bank == b] for b in range(4)}
    assert all(len(v) == len(prog) for v in by_bank.values())


# --------------------------------------------------------------------- #
# Multiplexer: rank-wide tRRD / tFAW under concurrent programs
# --------------------------------------------------------------------- #

def test_multiplexer_enforces_trrd_and_tfaw():
    progs = [[Cmd(Op.ACT, b, 0, 0.0, f"act{b}")] for b in range(8)]
    tr = MemoryController(n_banks=8).schedule(progs)
    acts = sorted(t for c, t in tr.events if c.op is Op.ACT)
    assert len(acts) == 8
    for a, b in zip(acts, acts[1:]):
        assert b - a >= T.trrd_s - 1e-9
    for i in range(len(acts) - 4):
        assert acts[i + 4] - acts[i] >= T.tfaw - 1e-9


def test_multiplexer_overlaps_banks_but_not_fully():
    """Concurrent APA programs overlap (makespan < sequential) yet stay
    tFAW/tRRD-limited (makespan > one program)."""
    single = cmds.CommandScheduler(T).schedule(ALL_PROGRAMS["apa"](0))
    n = 8
    progs = [ALL_PROGRAMS["apa"](b) for b in range(n)]
    flat = [c for p in progs for c in p]
    seq = cmds.CommandScheduler(T).schedule(flat)
    par = MemoryController(n_banks=n).schedule(progs)
    assert par.total_ns < seq.total_ns          # strict overlap win
    assert par.total_ns > single.total_ns       # but not a free 8x


@pytest.mark.parametrize("banks", [2, 4, 8, 16])
def test_multibank_throughput_beats_sequential(banks):
    cm = CostModel()
    unit = cm.maj_unit_programs(3, 8)
    n_ops = 2 * banks
    progs = [retarget_program(p, i % banks)
             for i in range(n_ops) for p in unit]
    flat = [c for p in progs for c in p]
    seq_ns = cmds.CommandScheduler(T).schedule(flat).total_ns
    ctrl_ns = MemoryController(n_banks=banks).schedule(progs).total_ns
    assert ctrl_ns < seq_ns  # scheduled multi-bank MAJ strictly faster


# --------------------------------------------------------------------- #
# Refresher: preemption of in-flight PuM sequences
# --------------------------------------------------------------------- #

def test_refresher_preempts_apa_stream_atomically():
    ctrl = MemoryController(n_banks=1, trefi=300.0, trfc=100.0)
    stream = [cmds.prog_apa_charge_share(0, 0, 1, T) for _ in range(10)]
    tr = ctrl.schedule(stream)
    assert tr.n_refreshes > 0
    assert tr.refresh_stall_ns > 0
    # No command issues strictly inside a refresh lockout window (the
    # drained sequence's trailing NOP marker may coincide with its start).
    for start, end in tr.refresh_windows:
        for _, t in tr.events:
            assert not (start + 1e-9 < t < end - 1e-9)
    # An APA sequence is never split by REF: each program's 5 commands lie
    # entirely on one side of every lockout window.
    per_prog = [tr.issue_times[i:i + 5]
                for i in range(0, len(tr.issue_times), 5)]
    for times in per_prog:
        for start, end in tr.refresh_windows:
            assert all(t <= start + 1e-9 for t in times) or \
                all(t >= end - 1e-9 for t in times)
    # Refresh interference is a real latency term.
    no_ref = ctrl.schedule(stream, refresh=False)
    assert tr.total_ns > no_ref.total_ns


def test_refresh_stall_scales_with_trefi():
    cm = CostModel()
    unit = cm.maj_unit_programs(3, 8)
    slow = MemoryController(n_banks=16).batch_cost(unit, 16)
    fast = MemoryController(n_banks=16, trefi=3900.0).batch_cost(unit, 16)
    assert 1.0 < slow.refresh_factor < fast.refresh_factor


def test_refresh_reopens_row_for_pending_access():
    """REF closes every row; a queued row-hit RD gets a re-ACT injected."""
    ctrl = MemoryController(n_banks=1, trefi=120.0, trfc=60.0)
    progs = [[Cmd(Op.ACT, 0, 7, 0.0, "a"), Cmd(Op.RD, 0, 7, T.trcd, "r")]]
    progs += [[Cmd(Op.RD, 0, 7, T.tccd_l, f"hit{i}")] for i in range(40)]
    tr = ctrl.schedule(progs)
    assert tr.n_refreshes >= 1
    for _, end in tr.refresh_windows:
        after = [c for c, t in tr.events if t >= end - 1e-9]
        if after:  # the first command after a lockout re-opens the row
            assert after[0].op is Op.ACT and after[0].tag == "bm.reopen"


# --------------------------------------------------------------------- #
# Batch cost + engine integration
# --------------------------------------------------------------------- #

def test_batch_cost_speedup_bounded_and_cached():
    ctrl = MemoryController(n_banks=16)
    unit = CostModel().maj_unit_programs(3, 8)
    bc = ctrl.batch_cost(unit, 16)
    assert 1.0 < bc.parallel_speedup <= 16.0
    assert bc.refresh_factor >= 1.0
    assert ctrl.batch_cost(unit, 16) is bc  # cached


def test_engine_controller_pricing_adds_refresh_term():
    import numpy as np
    from repro.core.engine import PulsarEngine
    legacy = PulsarEngine(mfr="M", width=32, banks=16)
    ctrl = PulsarEngine(mfr="M", width=32, banks=16, controller="auto")
    a = np.arange(65536 * 4, dtype=np.uint64)
    legacy.add(a, a)
    ctrl.add(a, a)
    assert legacy.stats.refresh_stall_ns == 0.0
    assert ctrl.stats.refresh_stall_ns > 0.0
    # Scheduled pricing can only be slower than the ideal closed-form divide.
    assert ctrl.stats.latency_ns >= legacy.stats.latency_ns
    # Dataplane results are unaffected by the cost plane.
    np.testing.assert_array_equal(legacy.add(a, a), ctrl.add(a, a))
