"""Golden-trace regression: cost numbers pinned bit-identical.

The committed fixture (``golden_costs.json``) pins

* the legacy ``MemoryController.schedule`` trace for the canonical
  bank-parallel MAJ workload (event digest + totals), and that the
  crossbar in single-client mode reproduces it **byte-for-byte** — the
  same crossbar-off == legacy discipline PRs 1-7 used;
* fig17/fig20-style real-world cost-plane numbers (BMI active-users and
  BitWeaving scan) to the exact float.

Any arbitration or cost-model change that shifts these diffs loudly.
Intentional changes regenerate the fixture:

    PYTHONPATH=src python tests/controller/test_golden_costs.py --regen
"""

import hashlib
import json
import pathlib

import numpy as np

from repro.controller import MemoryController, retarget_program
from repro.core.cost_model import CostModel

FIXTURE = pathlib.Path(__file__).with_name("golden_costs.json")


def canonical_programs():
    unit = CostModel(row_bits=65536).maj_unit_programs(3, 8)
    progs = []
    for b in range(8):
        progs.extend(retarget_program(p, b) for p in unit)
    return progs


def trace_digest(tr) -> str:
    lines = [f"{c.op.name},{c.bank},{c.row},{c.min_gap!r},{t!r}"
             for c, t in zip(tr.cmds, tr.issue_times)]
    return hashlib.sha256("\n".join(lines).encode()).hexdigest()


def realworld_runs():
    """The fixture's fig20 workloads, on fixed seeds/config."""
    import repro.pum as pum
    from repro.core import realworld

    rng = np.random.default_rng(0)
    bitmaps = (rng.integers(0, 1 << 63, (7, 64), dtype=np.uint64)
               | rng.integers(0, 1 << 63, (7, 64), dtype=np.uint64))
    dev = pum.device(width=64, fuse=True)
    got_bmi, _, _ = realworld.bmi_active_users(dev, bitmaps)
    bmi = {"result": got_bmi, "latency_ns": dev.stats.latency_ns,
           "energy_j": dev.stats.energy_j,
           "n_sequences": dev.stats.n_sequences}
    col = rng.integers(0, 1 << 20, 4096, dtype=np.uint64)
    dev2 = pum.device(width=32, fuse=True)
    got_bw, _, _ = realworld.bitweaving_scan(dev2, col, 1000, 800000)
    bw = {"result": got_bw, "latency_ns": dev2.stats.latency_ns,
          "energy_j": dev2.stats.energy_j,
          "n_sequences": dev2.stats.n_sequences}
    return bmi, bw


def test_schedule_trace_matches_golden():
    fix = json.loads(FIXTURE.read_text())["schedule"]
    tr = MemoryController().schedule(canonical_programs())
    assert len(tr.cmds) == fix["n_events"]
    assert tr.total_ns == fix["total_ns"]          # bit-identical floats
    assert tr.energy_j == fix["energy_j"]
    assert tr.n_refreshes == fix["n_refreshes"]
    assert trace_digest(tr) == fix["events_sha256"]


def test_crossbar_single_client_matches_golden():
    """Crossbar off == legacy path: one port through the crossbar must
    reproduce the committed legacy trace byte-for-byte, at any
    lookahead."""
    fix = json.loads(FIXTURE.read_text())["schedule"]
    mc = MemoryController()
    for lookahead in (1, 8):
        tr = mc.schedule_concurrent([canonical_programs()],
                                    lookahead=lookahead)
        assert trace_digest(tr) == fix["events_sha256"]
        assert tr.total_ns == fix["total_ns"]
        assert tr.energy_j == fix["energy_j"]


def test_realworld_cost_numbers_match_golden():
    fix = json.loads(FIXTURE.read_text())
    bmi, bw = realworld_runs()
    assert bmi == fix["fig20_bmi_active_users"]
    assert bw == fix["fig20_bitweaving_scan"]


def _regen():                                       # pragma: no cover
    tr = MemoryController().schedule(canonical_programs())
    bmi, bw = realworld_runs()
    fix = {
        "_comment": "Golden cost/trace fixture: legacy schedule digest "
                    "(the crossbar in single-client mode must reproduce "
                    "it byte-for-byte) and fig17/fig20-style realworld "
                    "cost-plane numbers. Regenerate with "
                    "tests/controller/test_golden_costs.py --regen only "
                    "for an intentional cost-model change.",
        "schedule": {"workload": "maj_unit_programs(3, 8) x 8 banks",
                     "n_events": len(tr.cmds),
                     "total_ns": tr.total_ns, "energy_j": tr.energy_j,
                     "n_refreshes": tr.n_refreshes,
                     "events_sha256": trace_digest(tr)},
        "fig20_bmi_active_users": bmi,
        "fig20_bitweaving_scan": bw,
    }
    FIXTURE.write_text(json.dumps(fix, indent=2, sort_keys=True) + "\n")
    print(f"wrote {FIXTURE}")


if __name__ == "__main__":                          # pragma: no cover
    import sys
    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
