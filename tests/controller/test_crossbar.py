"""Crossbar arbitration: N client ports onto the shared bank machines.

The concurrency contract under test:

* single-client equivalence — one port through the crossbar is
  byte-for-byte the legacy ``MemoryController.schedule`` trace;
* rank-wide timing — no tFAW/tRRD/tCCD/bus/refresh violation under any
  seeded interleaving, audited post-hoc from the trace by
  ``repro.telemetry.check_timing_invariants`` (independent re-derivation,
  not the multiplexer's own bookkeeping);
* fairness — per-bank round-robin grants: equal work gets equal grants,
  and no port with queued requests is starved beyond a bounded window;
* per-(port, bank) FIFO order and refresh atomicity are preserved.
"""

import pytest

from repro.controller import Crossbar, MemoryController, retarget_program
from repro.core import commands as cmds
from repro.core.commands import Cmd, Op
from repro.core.cost_model import CostModel
from repro.core.timing import DDR4_2400 as T
from repro.telemetry import check_timing_invariants, derive_port_counters


def unit_programs(n_banks=8):
    """One MAJ unit program per bank — the bank-parallelism workload."""
    unit = CostModel(row_bits=65536).maj_unit_programs(3, 8)
    progs = []
    for b in range(n_banks):
        progs.extend(retarget_program(p, b) for p in unit)
    return progs


def seeded_requests(rng, n_ports, n_banks=16, n_req=30):
    """Random per-port request streams: a mix of accesses and programs."""
    streams = []
    for _ in range(n_ports):
        reqs = []
        for _ in range(n_req):
            bank = int(rng.integers(n_banks))
            if rng.random() < 0.3:
                reqs.append(("prog",
                             cmds.prog_apa_charge_share(bank, 0, 1, T)))
            else:
                reqs.append(("acc", bank, int(rng.integers(8)),
                             bool(rng.random() < 0.3)))
        streams.append(reqs)
    return streams


def submit_all(xb, streams):
    for p, reqs in enumerate(streams):
        for r in reqs:
            if r[0] == "prog":
                xb.port(p).submit([r[1]])
            else:
                xb.port(p).submit_access(r[1], r[2], write=r[3])


# --------------------------------------------------------------------- #
# Single-client equivalence: crossbar off == legacy path byte-for-byte
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("lookahead", [1, 2, 8, 64])
def test_single_port_matches_legacy_schedule(lookahead):
    progs = unit_programs()
    mc = MemoryController()
    legacy = mc.schedule(progs)
    xbar = mc.schedule_concurrent([progs], lookahead=lookahead)
    assert xbar.cmds == legacy.cmds          # Cmd is a frozen dataclass
    assert xbar.issue_times == legacy.issue_times
    assert xbar.total_ns == legacy.total_ns
    assert xbar.energy_j == legacy.energy_j
    assert xbar.n_refreshes == legacy.n_refreshes
    assert xbar.n_ports == 1


def test_single_port_counters_match_legacy():
    progs = unit_programs()
    mc = MemoryController()
    legacy = mc.schedule(progs).counters().as_dict()["counters"]
    xbar = mc.schedule_concurrent([progs]).counters().as_dict()["counters"]
    # the crossbar only *adds* port attribution; every legacy counter is
    # bit-identical
    for k, v in legacy.items():
        assert xbar[k] == v, k


# --------------------------------------------------------------------- #
# Timing invariants under seeded interleaving (property test)
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("n_ports", [2, 5, 8])
def test_no_timing_violations_under_interleaving(seed, n_ports):
    import numpy as np
    rng = np.random.default_rng(seed)
    xb = Crossbar(n_ports=n_ports,
                  lookahead=int(rng.integers(1, 9)),
                  auto_precharge=bool(rng.random() < 0.5))
    submit_all(xb, seeded_requests(rng, n_ports))
    tr = xb.run()
    assert check_timing_invariants(tr) == []
    # every port drained
    assert all(len(xb.port(p)) == 0 for p in range(n_ports))


def test_work_conserved_per_port():
    """Every submitted request is granted exactly once to the port that
    submitted it, and every issued command carries a port attribution.
    (Per-port *command* counts are not predictable in isolation — the
    page-policy expansion of an access depends on how the ports'
    requests interleave on the bank — but the sequence count is one per
    request by construction.)"""
    import numpy as np
    rng = np.random.default_rng(7)
    n_ports = 4
    streams = seeded_requests(rng, n_ports)
    xb = Crossbar(n_ports=n_ports, refresh=False)
    submit_all(xb, streams)
    tr = xb.run()
    c = derive_port_counters(tr)
    assert [c[f"port{p}.seqs"] for p in range(n_ports)] \
        == [len(reqs) for reqs in streams]
    assert sum(c[f"port{p}.cmds"] for p in range(n_ports)) \
        == sum(1 for cmd in tr.cmds if cmd.op is not Op.NOP)
    assert len(tr.port_of) == len(tr.cmds)


# --------------------------------------------------------------------- #
# Fairness
# --------------------------------------------------------------------- #

def test_round_robin_fairness_on_contended_bank():
    """4 ports hammering the same bank get exactly equal grant counts."""
    n_ports, n_req = 4, 25
    xb = Crossbar(n_ports=n_ports, refresh=False)
    for p in range(n_ports):
        for i in range(n_req):
            xb.port(p).submit_access(0, row=i % 3)
    tr = xb.run()
    c = derive_port_counters(tr)
    assert [c[f"port{p}.seqs"] for p in range(n_ports)] == [n_req] * n_ports


def test_no_port_starved_beyond_window():
    """Starvation bound: with R ports contending, a port's consecutive
    grants are separated by at most R full sequence services (plus any
    refresh lockout that lands in the gap)."""
    n_ports = 8
    xb = Crossbar(n_ports=n_ports, refresh=True)
    for p in range(n_ports):
        for i in range(20):
            xb.port(p).submit_access(0, row=(p + i) % 5)
    tr = xb.run()
    c = derive_port_counters(tr)
    # longest single sequence service on one bank: PRE + ACT + RD chain
    seq_span = T.trp + T.trcd + T.tras + T.tbl + T.twr
    bound = n_ports * seq_span + T.trfc + 3 * T.tck
    for p in range(n_ports):
        assert c[f"port{p}.grant_gap_max_ns"] <= bound


def test_late_port_granted_within_lookahead():
    """A port that shows up behind a long stream is served after at most
    ``lookahead`` already-buffered sequences, not after the whole
    stream."""
    lookahead = 4
    xb = Crossbar(n_ports=2, lookahead=lookahead, refresh=False)
    for i in range(50):
        xb.port(0).submit_access(0, row=i % 2)
    xb.port(1).submit_access(0, row=7)
    tr = xb.run()
    first_seqs = []         # grant order of sequence starts on bank 0
    for sq, p in zip(tr.seqs, tr.port_of):
        if sq not in first_seqs:
            first_seqs.append(sq)
            if p == 1:
                break
    assert len(first_seqs) <= lookahead + 1


# --------------------------------------------------------------------- #
# Ordering + refresh atomicity
# --------------------------------------------------------------------- #

def test_per_port_bank_fifo_order():
    """Sequences a port submitted to one bank issue in submission order
    (seq ids are assigned in enqueue order by the bank machine)."""
    import numpy as np
    rng = np.random.default_rng(3)
    n_ports = 3
    xb = Crossbar(n_ports=n_ports, refresh=False)
    submit_all(xb, seeded_requests(rng, n_ports, n_banks=4))
    tr = xb.run()
    seen: dict = {}
    for sq, p in zip(tr.seqs, tr.port_of):
        bank, sid = sq
        prev = seen.get((p, bank))
        if prev is None or sid != prev:
            assert prev is None or sid > prev, (p, bank, prev, sid)
            seen[(p, bank)] = sid


def test_refresh_drains_inflight_sequences():
    """Refresh fires during a long multi-port run and never splits an
    in-flight sequence (the straddle check in the invariant auditor)."""
    xb = Crossbar(n_ports=4, trefi=300.0, trfc=80.0)
    for p in range(4):
        for i in range(40):
            xb.port(p).submit_access((p + i) % 16, row=i % 3)
    tr = xb.run()
    assert tr.n_refreshes > 0
    assert check_timing_invariants(tr) == []


def test_invariant_checker_detects_corruption():
    """Negative control: a hand-corrupted trace trips the auditor."""
    import copy
    xb = Crossbar(n_ports=2, refresh=False)
    for p in range(2):
        for i in range(10):
            xb.port(p).submit_access(i % 8, row=0)
    tr = xb.run()
    assert check_timing_invariants(tr) == []
    bad = copy.copy(tr)
    bad.issue_times = list(tr.issue_times)
    acts = [i for i, c in enumerate(tr.cmds) if c.op is Op.ACT]
    bad.issue_times[acts[1]] = bad.issue_times[acts[0]] + 0.01
    assert check_timing_invariants(bad)


# --------------------------------------------------------------------- #
# Auto-precharge lookahead
# --------------------------------------------------------------------- #

def test_auto_precharge_attaches_pre_to_owning_sequence():
    """With lookahead auto-precharge, the closing PRE issues inside the
    access's own sequence (peeking the next queued row), instead of
    opening the next access's sequence."""
    def trace(ap):
        xb = Crossbar(n_ports=1, auto_precharge=ap, refresh=False)
        for i in range(10):
            xb.port(0).submit_access(0, row=i % 2)   # always a row switch
        return xb.run()

    tr = trace(True)
    assert check_timing_invariants(tr) == []
    by_seq: dict = {}
    for cmd, sq in zip(tr.cmds, tr.seqs):
        by_seq.setdefault(sq, []).append(cmd.op)
    # every sequence but possibly the last carries its own closing PRE
    closing = [ops for ops in by_seq.values() if ops[-1] is Op.PRE]
    assert len(closing) >= len(by_seq) - 1
    # total command work matches the no-auto-precharge schedule
    tr_off = trace(False)
    n = sum(1 for c in tr.cmds if c.op is not Op.NOP)
    n_off = sum(1 for c in tr_off.cmds if c.op is not Op.NOP)
    assert abs(n - n_off) <= 1   # the final PRE may be elided either way


# --------------------------------------------------------------------- #
# Validation
# --------------------------------------------------------------------- #

def test_port_and_config_validation():
    with pytest.raises(ValueError):
        Crossbar(n_ports=0)
    with pytest.raises(ValueError):
        Crossbar(lookahead=0)
    xb = Crossbar(n_ports=2, n_banks=4)
    with pytest.raises(ValueError):
        xb.port(0).submit_access(4, row=0)
    with pytest.raises(ValueError):
        xb.port(0).submit([[Cmd(Op.ACT, 0, 1, 0.0),
                            Cmd(Op.ACT, 1, 1, 0.0)]])
