"""Cross-process determinism of the success-rate DB and calibration maps.

The reliability calibration pass derives every PRNG stream from
zlib.crc32 folds of the query key, never from the salted builtin hash()
— so the same query returns bit-identical floats in any process, and a
saved ReliabilityMap can be regenerated exactly. These tests run the
same query under different PYTHONHASHSEED values to prove it.
"""

import json
import os
import subprocess
import sys

from repro.core.charact import SuccessRateDb

QUERY_SNIPPET = """
import json, sys
from repro.core.charact import SuccessRateDb
db = SuccessRateDb(n_bitlines=256, n_groups=4, n_patterns=6, seed=3)
p = db.point("M", 3, 8, subarray_frac=0.25, plan_style="pow2")
print(json.dumps([p.mean, p.q1, p.q3, p.lo, p.hi]))
"""

MAP_SNIPPET = """
import json
from repro.reliability import calibrate
m = calibrate("M", banks=2, n_subarrays=2, n_columns=32, n_patterns=3,
              seed=5)
print(json.dumps([m.success.sum(), float(m.flip_p.astype("f8").sum()),
                  m.bank_scale.tolist()]))
"""


def run_in_subprocess(snippet, hashseed):
    env = dict(os.environ, PYTHONHASHSEED=str(hashseed))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    out = subprocess.run([sys.executable, "-c", snippet], env=env,
                         capture_output=True, text=True, check=True,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__)))))
    return json.loads(out.stdout)


def test_success_db_identical_across_processes():
    a = run_in_subprocess(QUERY_SNIPPET, hashseed=0)
    b = run_in_subprocess(QUERY_SNIPPET, hashseed=12345)
    assert a == b  # exact float equality, different hash salts


def test_reliability_map_identical_across_processes():
    a = run_in_subprocess(MAP_SNIPPET, hashseed=1)
    b = run_in_subprocess(MAP_SNIPPET, hashseed=54321)
    assert a == b


def test_success_db_instances_agree_in_process():
    kw = dict(n_bitlines=256, n_groups=4, n_patterns=6, seed=3)
    p1 = SuccessRateDb(**kw).point("M", 3, 8)
    p2 = SuccessRateDb(**kw).point("M", 3, 8)
    assert p1 == p2
    # The cache returns the stored point, not a recomputation.
    db = SuccessRateDb(**kw)
    assert db.point("M", 3, 8) is db.point("M", 3, 8)


def test_success_db_seed_separates_streams():
    # MAJ5@8 at the W-profile peak: success < 1, so different seeds draw
    # visibly different Monte-Carlo samples.
    kw = dict(n_bitlines=256, n_groups=4, n_patterns=6)
    a = SuccessRateDb(seed=0, **kw).point("M", 5, 8, subarray_frac=0.0)
    b = SuccessRateDb(seed=9, **kw).point("M", 5, 8, subarray_frac=0.0)
    assert (a.mean, a.lo, a.hi) != (b.mean, b.lo, b.hi)
