"""Cost model, destruction, engine + real-world kernels."""

import numpy as np
import pytest

from repro.core.chip import PulsarChip
from repro.core.cost_model import (CostModel, MICROBENCHES,
                                   throughput_elems_per_s)
from repro.core.destruction import (destroy_bank_fracdram,
                                    destroy_bank_pulsar,
                                    destroy_bank_rowclone,
                                    fracdram_destruction_cost,
                                    plan_pulsar_cover,
                                    pulsar_destruction_cost,
                                    rowclone_destruction_cost)
from repro.core.engine import PulsarEngine
from repro.core.geometry import DramGeometry
import repro.pum as pum
from repro.core.profiles import MFR_H, MFR_M
from repro.core.pulsar import PulsarExecutor
from repro.core import realworld

GEOM = DramGeometry(row_bits=256, rows_per_subarray=256, subarrays_per_bank=2,
                    banks=1, predecoder_widths=(2, 2, 2, 2))


def _chip(profile=MFR_H):
    chip = PulsarChip(GEOM, profile, seed=0)
    chip.decoder = chip.decoder.__class__(GEOM, profile, None)
    return chip


# --------------------------------------------------------------------- #
# Cost model <-> executor cross-check
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("m,n_rg", [(3, 4), (3, 8), (3, 16), (5, 8), (7, 16)])
def test_maj_cost_matches_executed_trace(m, n_rg):
    chip = _chip()
    chip.stats.trace = []
    x = PulsarExecutor(chip, 0, 0)
    rng = np.random.default_rng(0)
    for i in range(m):
        chip.write_row(0, 200 + i, rng.integers(
            0, 2**32, GEOM.words_per_row, dtype=np.uint64).astype(np.uint32))
    base_lat = chip.stats.latency_ns
    base_seq = chip.stats.n_ops
    x.maj(240, [200 + i for i in range(m)], n_rg)
    executed_lat = chip.stats.latency_ns - base_lat
    executed_seq = chip.stats.n_ops - base_seq
    cm = CostModel(row_bits=GEOM.row_bits)
    cost = cm.maj_op(m, n_rg, frac_supported=True)
    assert cost.n_sequences == executed_seq
    assert cost.latency_ns == pytest.approx(executed_lat, rel=1e-9)


def test_fracdram_baseline_cost_shape():
    cm = CostModel()
    c = cm.fracdram_maj3()
    # 3 copy-ins + 1 frac + 1 APA + 1 copy-out
    assert c.n_sequences == 6
    assert c.latency_ns > 0


def test_tree_nodes():
    assert CostModel.tree_nodes(64, 2) == 63
    assert CostModel.tree_nodes(2, 2) == 1
    assert CostModel.tree_nodes(5, 5) == 1
    assert CostModel.tree_nodes(64, 4) == 21
    assert CostModel.tree_nodes(1, 2) == 0


def test_maj5_full_adder_cheaper_than_maj3():
    cm = CostModel()
    fa3 = cm.full_adder(3, 8)
    fa5 = cm.full_adder(5, 8)
    assert fa5.latency_ns < fa3.latency_ns  # 4 MAJ vs 6 MAJ


def test_microbench_costs_positive_and_ordered():
    cm = CostModel()
    for name in MICROBENCHES:
        c3 = cm.microbench(name, 3, 4, width=32)
        assert c3.latency_ns > 0
    # mul is the most expensive, and/or the cheapest arithmetic-free ones.
    assert (cm.microbench("mul", 3, 4).latency_ns
            > cm.microbench("add", 3, 4).latency_ns
            > cm.microbench("and", 3, 4).latency_ns)


def test_throughput_metric():
    cm = CostModel()
    c = cm.fracdram_maj3()
    full = throughput_elems_per_s(c, 65536, 1.0)
    half = throughput_elems_per_s(c, 65536, 0.5)
    assert full == pytest.approx(2 * half)


# --------------------------------------------------------------------- #
# Content destruction (Fig 19)
# --------------------------------------------------------------------- #

def test_pulsar_destruction_overwrites_everything():
    chip = _chip()
    rng = np.random.default_rng(3)
    for r in range(GEOM.rows_per_bank):
        chip.banks[0, r] = rng.integers(0, 2**32, GEOM.words_per_row,
                                        dtype=np.uint64).astype(np.uint32)
    rep = destroy_bank_pulsar(chip, 0, pattern=0)
    assert rep.rows_destroyed == GEOM.rows_per_bank
    assert (chip.banks[0] == 0).all()
    assert rep.latency_ns > 0


def test_destruction_speedup_ordering():
    """PULSAR > FracDRAM > RowClone in destruction speed (Fig 19)."""
    chip_p, chip_r, chip_f = _chip(), _chip(), _chip()
    rp = destroy_bank_pulsar(chip_p, 0)
    rr = destroy_bank_rowclone(chip_r, 0)
    rf = destroy_bank_fracdram(chip_f, 0)
    assert rp.latency_ns < rf.latency_ns < rr.latency_ns * 1.5
    assert rp.latency_ns < rr.latency_ns


def test_destruction_cost_model_scales():
    cm = CostModel(row_bits=65536)
    n_sa, rows_sa = 16, 512
    n_rows = n_sa * rows_sa
    p32 = pulsar_destruction_cost(cm, rows_sa, n_sa, 32)
    p4 = pulsar_destruction_cost(cm, rows_sa, n_sa, 4)
    rc = rowclone_destruction_cost(cm, n_rows)
    fr = fracdram_destruction_cost(cm, n_rows)
    assert p32.latency_ns < p4.latency_ns < rc.latency_ns
    # Paper: PULSAR up to 20.87x vs RowClone, 7.55x vs FracDRAM.
    speedup_rc = rc.latency_ns / p32.latency_ns
    speedup_fr = fr.latency_ns / p32.latency_ns
    assert 10 < speedup_rc < 40
    assert 4 < speedup_fr < 16


def test_plan_pulsar_cover_counts():
    blocks = plan_pulsar_cover(512, 16, 32)
    assert sum(blocks) == 512 * 16
    assert max(blocks) == 32


# --------------------------------------------------------------------- #
# Engine + real-world kernels (Fig 20)
# --------------------------------------------------------------------- #

def test_engine_dataplane_matches_numpy():
    eng = PulsarEngine(mfr="M", width=16, backend="fast")
    rng = np.random.default_rng(0)
    a = rng.integers(0, 2**16, 512, dtype=np.uint64)
    b = rng.integers(1, 2**16, 512, dtype=np.uint64)
    np.testing.assert_array_equal(eng.and_(a, b), a & b)
    np.testing.assert_array_equal(eng.add(a, b), (a + b) & 0xFFFF)
    np.testing.assert_array_equal(eng.mul(a, b), (a * b) & 0xFFFF)
    np.testing.assert_array_equal(eng.div(a, b), a // b)
    assert eng.stats.latency_ns > 0
    assert 0 < eng.stats.lane_efficiency <= 1


def test_engine_sim_backend_small():
    eng = PulsarEngine(mfr="H", width=8, backend="sim")
    rng = np.random.default_rng(1)
    n = eng._alu.words * 32
    a = rng.integers(0, 256, n, dtype=np.uint64)
    b = rng.integers(0, 256, n, dtype=np.uint64)
    np.testing.assert_array_equal(eng.and_(a, b), a & b)
    np.testing.assert_array_equal(eng.add(a, b), (a + b) & 0xFF)


def test_engine_pulsar_beats_fracdram_on_add():
    pulsar = PulsarEngine(mfr="M", width=32, use_pulsar=True)
    frac = PulsarEngine(mfr="M", width=32, use_pulsar=False)
    a = np.arange(65536, dtype=np.uint64)
    pulsar.add(a, a)
    frac.add(a, a)
    t_p = pulsar.stats.latency_ns / pulsar.stats.lane_efficiency
    t_f = frac.stats.latency_ns / frac.stats.lane_efficiency
    assert t_p < t_f  # the paper's headline performance claim


FUSE = [False, True]  # every app kernel runs on the fused path too (PR 3)


@pytest.mark.parametrize("fuse", FUSE)
def test_bmi(fuse):
    eng = pum.device(mfr="M", fuse=fuse)
    rng = np.random.default_rng(2)
    bitmaps = rng.integers(0, 2**64, (30, 128), dtype=np.uint64)
    got, pum_ms, cpu_ms = realworld.bmi_active_users(eng, bitmaps)
    assert pum_ms > 0 and cpu_ms >= 0


@pytest.mark.parametrize("fuse", FUSE)
def test_bitweaving(fuse):
    eng = pum.device(mfr="M", width=16, fuse=fuse)
    rng = np.random.default_rng(3)
    col = rng.integers(0, 1000, 4096, dtype=np.uint64)
    got, pum_ms, _ = realworld.bitweaving_scan(eng, col, 100, 500)
    assert got == int(((col >= 100) & (col <= 500)).sum())


@pytest.mark.parametrize("fuse", FUSE)
def test_bitweaving_boundary_ranges(fuse):
    """c1 == 0 must not underflow the strict-compare sentinel (2**64-1
    wrap) and a c2 at the width max must not overflow it out of width —
    both bounds short-circuit to trivially-true predicates."""
    eng = pum.device(mfr="M", width=16, fuse=fuse)
    rng = np.random.default_rng(9)
    col = rng.integers(0, 1 << 16, 2048, dtype=np.uint64)
    got, _, _ = realworld.bitweaving_scan(eng, col, 0, 500)
    assert got == int((col <= 500).sum())
    got, _, _ = realworld.bitweaving_scan(eng, col, 100, (1 << 16) - 1)
    assert got == int((col >= 100).sum())
    got, _, _ = realworld.bitweaving_scan(eng, col, 0, (1 << 16) - 1)
    assert got == col.size


@pytest.mark.parametrize("fuse", FUSE)
def test_triangle_count(fuse):
    eng = pum.device(mfr="M", fuse=fuse)
    rng = np.random.default_rng(4)
    n = 24
    adj = np.triu((rng.random((n, n)) < 0.3).astype(np.uint8), 1)
    adj = adj + adj.T
    got, pum_ms, _ = realworld.triangle_count(eng, adj)
    assert pum_ms > 0


@pytest.mark.parametrize("fuse", FUSE)
def test_knn(fuse):
    eng = pum.device(mfr="M", width=24, fuse=fuse)
    rng = np.random.default_rng(5)
    q = rng.integers(0, 256, (4, 16), dtype=np.int64)
    r = rng.integers(0, 256, (64, 16), dtype=np.int64)
    got, pum_ms, _ = realworld.knn_distances(eng, q, r)
    assert got.shape == (4,)


@pytest.mark.parametrize("fuse", FUSE)
def test_image_segmentation(fuse):
    eng = pum.device(mfr="M", width=16, fuse=fuse)
    rng = np.random.default_rng(6)
    img = rng.integers(0, 256, (32, 32), dtype=np.int64)
    colors = np.array([10, 90, 170, 250])
    labels, pum_ms, _ = realworld.image_segmentation(eng, img, colors)
    assert labels.max() <= 3


@pytest.mark.parametrize("fuse", FUSE)
def test_xnor_conv_cost_positive(fuse):
    eng = pum.device(mfr="M", fuse=fuse)
    ms = realworld.xnor_conv_cost(eng, 128, 128, 3, 3, 16, 16)
    assert ms > 0


def test_app_kernels_fused_matches_eager_results_and_stats():
    """The fuse=True routing (default for fig20/examples) must leave every
    kernel's result AND its cost-plane charges bit-identical to eager —
    the set intersections exercise the raw packed-bitmap path, KNN the
    fused mul, image segmentation the fused compare network."""
    rng = np.random.default_rng(7)

    def pair(**kw):
        return (pum.device(mfr="M", fuse=False, **kw),
                pum.device(mfr="M", fuse=True, **kw))

    bitmaps = rng.integers(0, 2**64, (12, 96), dtype=np.uint64)
    e, f = pair()
    r_e = realworld.bmi_active_users(e, bitmaps)
    r_f = realworld.bmi_active_users(f, bitmaps)
    assert r_e[0] == r_f[0] and r_e[1] == r_f[1] and e.stats == f.stats

    adj = np.triu((rng.random((16, 16)) < 0.4).astype(np.uint8), 1)
    adj = adj + adj.T
    e, f = pair()
    assert (realworld.kclique_star(e, adj, [(0, 1, 2), (3, 4, 5)])[0]
            == realworld.kclique_star(f, adj, [(0, 1, 2), (3, 4, 5)])[0])
    assert e.stats == f.stats

    q = rng.integers(0, 256, (3, 8), dtype=np.int64)
    r = rng.integers(0, 256, (32, 8), dtype=np.int64)
    e, f = pair(width=24)
    np.testing.assert_array_equal(realworld.knn_distances(e, q, r)[0],
                                  realworld.knn_distances(f, q, r)[0])
    assert e.stats == f.stats

    img = rng.integers(0, 256, (16, 16), dtype=np.int64)
    colors = np.array([15, 120, 240])
    e, f = pair(width=16)
    np.testing.assert_array_equal(
        realworld.image_segmentation(e, img, colors)[0],
        realworld.image_segmentation(f, img, colors)[0])
    assert e.stats == f.stats
