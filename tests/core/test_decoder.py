"""Row-decoder model tests (paper §4.2)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dep: fixed-seed fallback
    from repro.testing import given, settings, st

from repro.core.decoder import RowDecoder, join_groups, split_groups
from repro.core.geometry import TEST_GEOMETRY, DramGeometry
from repro.core.profiles import MFR_H, MFR_M, MFR_S

G9 = DramGeometry(row_bits=1024, rows_per_subarray=512, subarrays_per_bank=2,
                  banks=1)  # paper's 9-bit local address, groups (2,2,2,2,1)


def _decoder(profile, geometry=G9):
    return RowDecoder(geometry, profile, yield_mask=None)


def test_split_join_roundtrip():
    widths = (2, 2, 2, 2, 1)
    for addr in range(512):
        assert join_groups(split_groups(addr, widths), widths) == addr


def test_same_row_single_activation():
    d = _decoder(MFR_H)
    assert d.activated_rows(5, 5) == (5,)


def test_paper_walkthrough_fig8():
    """APA(row 0, row 7): rows 0 and 7 differ in groups A (bits 0-1) and
    B (bits 2-3) -> four rows {0,3,4,7}... with group-value semantics the
    cross product is {(a0|a1) x (b0|b1)} = rows 0, 3, 4, 7? The paper's
    figure uses single-bit predecoders for illustration and reports
    {0,1,6,7}; with our 2-bit groups A={0,3}, B={0,1}: addresses
    {0+0, 3+0, 0+4, 3+4} = {0,3,4,7}. Same cardinality & structure."""
    d = _decoder(MFR_H)
    rows = d.activated_rows(0, 7)
    assert rows == (0, 3, 4, 7)


def test_power_of_two_counts():
    d = _decoder(MFR_H)
    # Differ in k groups -> 2^k rows.
    assert d.n_activated(0, 1) == 2      # group A only
    assert d.n_activated(0, 4) == 2      # group B only
    assert d.n_activated(0, 5) == 4      # A and B
    assert d.n_activated(0, 0b101010101) == 32  # all five groups
    # Paper's §4.2 example "ACT 127 -> PRE -> ACT 128" reaches 32 rows under
    # the paper's bit grouping; with our (2,2,2,2,1) LSB-first grouping those
    # addresses differ in 4 groups (A,B,C,D) -> 16 rows. 0 vs 511 differs in
    # all five groups -> 32 rows.
    assert d.n_activated(127, 128) == 16
    assert d.n_activated(0, 511) == 32


def test_mfr_m_caps_at_16():
    d = _decoder(MFR_M)
    # All 5 groups differ, but only 4 double-latch -> 16 rows, and the
    # non-latching group takes R_S's value.
    rows = d.activated_rows(0, 0b111111111)
    assert len(rows) == 16
    assert all(((r >> 8) & 1) == 1 for r in rows)  # group E pinned to rs


def test_mfr_s_no_multi_activation():
    d = _decoder(MFR_S)
    assert d.activated_rows(0, 0b111111111) == (0b111111111,)


def test_cross_subarray_activates_rs_only():
    d = _decoder(MFR_H)
    assert d.activated_rows(5, 512 + 5) == (512 + 5,)
    assert d.activated_rows(5, 512 + 7) == (512 + 7,)


def test_rs_and_rf_always_in_set():
    d = _decoder(MFR_H)
    rng = np.random.default_rng(0)
    for _ in range(100):
        rf, rs = rng.integers(0, 512, 2)
        rows = d.activated_rows(int(rf), int(rs))
        assert int(rs) in rows
        if len(rows) > 1:
            assert int(rf) in rows


@given(rf=st.integers(0, 511), rs=st.integers(0, 511))
@settings(max_examples=200, deadline=None)
def test_property_count_is_power_of_two(rf, rs):
    d = _decoder(MFR_H)
    n = d.n_activated(rf, rs)
    assert n & (n - 1) == 0
    widths = (2, 2, 2, 2, 1)
    k = sum(a != b for a, b in zip(split_groups(rf, widths),
                                   split_groups(rs, widths)))
    if rf != rs:
        assert n == 1 << k


def test_find_group_pair():
    d = RowDecoder.build(G9, MFR_H, seed=7)
    for n in (2, 4, 8, 16, 32):
        try:
            rf, rs = d.find_group_pair(0, n)
        except ValueError:
            continue  # yield mask may disable groups
        assert d.n_activated(rf, rs) == n


def test_find_group_pair_rejects_impossible():
    d = _decoder(MFR_M)
    with pytest.raises(ValueError):
        d.find_group_pair(0, 32)


def test_nrg_census_structure():
    d = _decoder(MFR_H)
    census = d.nrg_census(0, sample=2000, seed=1)
    assert abs(sum(census.values()) - 1.0) < 1e-9
    assert set(census) <= {1, 2, 4, 8, 16, 32}
    # Random pairs most often differ in 4 of the 5 groups:
    # P(2-bit group differs)=3/4, P(1-bit)=1/2 -> mode at 16 rows, exactly
    # the structure Table 1 reports (e.g. H7-11: 16-row N_RG% = 35.33% max).
    assert census[16] == max(census.values())
    assert census[32] > 0.10  # perfect-yield chips reach 32 rows often


def test_yield_mask_reduces_counts():
    full = _decoder(MFR_H).nrg_census(0, sample=1500, seed=2)
    masked = RowDecoder.build(G9, MFR_H, seed=3).nrg_census(0, sample=1500,
                                                            seed=2)
    assert masked.get(32, 0) <= full[32] + 1e-9


def test_test_geometry_smoke():
    d = RowDecoder(TEST_GEOMETRY, MFR_H, None)
    rows = d.activated_rows(0, 0b010101)
    assert len(rows) == 8
