"""Fused dataplane (engine fuse=True) vs eager: bit-exactness and
cost-plane invariance, across widths and random op sequences."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dep: fixed-seed fallback
    from repro.testing import given, settings, st

from repro.core.engine import LazyArray, PulsarEngine, _vec_popcount
from repro.kernels import fused_program

pytestmark = pytest.mark.fused

# Chain ops: (engine method, n_operands). Applied as t = op(t, pool[i]).
_CHAIN_OPS = ["and", "or", "xor", "add", "sub"]
_TAIL_OPS = ["less", "popcount", "reduce_and", "reduce_or", "reduce_xor"]


def _rand_inputs(width, n, seed):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 1 << width, n, dtype=np.uint64)
            for _ in range(3)]


def _apply(e, name, t, other):
    if name == "and":
        return e.and_(t, other)
    if name == "or":
        return e.or_(t, other)
    if name == "xor":
        return e.xor(t, other)
    if name == "add":
        return e.add(t, other)
    if name == "sub":
        return e.sub(t, other)
    if name == "less":
        return e.less_than(t, other)
    if name == "popcount":
        return e.popcount(t)
    if name.startswith("reduce_"):
        return e.reduce_bits(t, name.removeprefix("reduce_"))
    raise KeyError(name)


def _run_sequence(e, inputs, op_seq):
    """Random chain over the input pool; returns every intermediate (so
    flush must materialize intermediates whose handles stay alive)."""
    outs = []
    t = inputs[0]
    for i, name in enumerate(op_seq):
        t = _apply(e, name, t, inputs[(i + 1) % len(inputs)])
        outs.append(t)
    return [np.asarray(o, np.uint64) for o in outs]


@given(width=st.sampled_from([8, 16, 32]), seed=st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_fused_matches_eager_random_sequence(width, seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(33, 400))  # deliberately not a multiple of 32
    inputs = _rand_inputs(width, n, seed)
    n_ops = int(rng.integers(2, 7))
    op_seq = [str(rng.choice(_CHAIN_OPS)) for _ in range(n_ops - 1)]
    op_seq.append(str(rng.choice(_CHAIN_OPS + _TAIL_OPS)))

    eager = PulsarEngine(width=width)
    fused = PulsarEngine(width=width, fuse=True)
    want = _run_sequence(eager, inputs, op_seq)
    got = _run_sequence(fused, inputs, op_seq)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    assert eager.stats == fused.stats


@pytest.mark.parametrize("width", [8, 16, 32])
def test_fused_all_opcodes_bit_exact(width):
    inputs = _rand_inputs(width, 256, seed=width)
    seq = ["and", "xor", "or", "add", "sub", "less"]
    tails = ["popcount", "reduce_and", "reduce_or", "reduce_xor"]
    eager = PulsarEngine(width=width)
    fused = PulsarEngine(width=width, fuse=True)

    def run(e):
        outs = _run_sequence(e, inputs, seq)
        base = e.add(inputs[0], inputs[1])
        outs += [np.asarray(_apply(e, t, base, None), np.uint64)
                 for t in tails]
        return outs

    for w, g in zip(run(eager), run(fused)):
        np.testing.assert_array_equal(w, g)
    assert eager.stats == fused.stats


def test_cost_plane_invariance_with_controller():
    """EngineStats must match eager exactly under controller pricing too
    (latency, energy, sequences, refresh stalls)."""
    inputs = _rand_inputs(32, 128, seed=3)
    seq = ["add", "xor", "sub", "and", "popcount"]
    eager = PulsarEngine(width=32, controller="auto")
    fused = PulsarEngine(width=32, controller="auto", fuse=True)
    for w, g in zip(_run_sequence(eager, inputs, seq),
                    _run_sequence(fused, inputs, seq)):
        np.testing.assert_array_equal(w, g)
    assert eager.stats == fused.stats
    assert fused.stats.refresh_stall_ns > 0


def test_charges_accrue_at_record_time():
    """The cost plane must not wait for flush(): recording IS charging."""
    import dataclasses
    e = PulsarEngine(fuse=True)
    a = _rand_inputs(32, 64, seed=5)[0]
    t = e.add(a, a)
    assert e.stats.latency_ns > 0 and e.stats.n_sequences > 0
    before = dataclasses.replace(e.stats)
    _ = np.asarray(t)  # flush: dataplane only
    assert e.stats == before


def test_lazy_array_api_and_flush():
    e = PulsarEngine(fuse=True)
    a = _rand_inputs(32, 64, seed=7)[0]
    t = e.xor(a, a)
    assert isinstance(t, LazyArray)
    assert t.shape == (64,) and t.size == 64 and t.ndim == 1
    assert t.dtype == np.uint64
    assert "pending" in repr(t)
    e.flush()
    assert "materialized" in repr(t)
    np.testing.assert_array_equal(t.materialize(), np.zeros(64, np.uint64))
    e.flush()  # idempotent no-op


def test_lazy_array_eq_and_bool_follow_ndarray_semantics():
    """`==` must compare values (not identity) and truth-testing must
    behave like ndarray — no silent scalars from ported eager code."""
    e = PulsarEngine(fuse=True)
    z = np.arange(4, dtype=np.uint64)
    t1 = e.add(z, z)
    t2 = e.add(z, z)
    np.testing.assert_array_equal(t1 == t2, np.full(4, True))
    np.testing.assert_array_equal(t1 != t2, np.full(4, False))
    with pytest.raises(ValueError):  # ambiguous, exactly like ndarray
        bool(e.add(z, z))
    one = e.add(np.ones(1, np.uint64), np.zeros(1, np.uint64))
    assert bool(one)


def test_eager_fallback_ops_consume_lazy_operands():
    """mul/div are outside the fused ISA: they must force materialization
    and still produce eager-identical results and stats."""
    inputs = _rand_inputs(16, 96, seed=11)
    inputs[1] |= np.uint64(1)  # no div-by-zero
    eager = PulsarEngine(width=16)
    fused = PulsarEngine(width=16, fuse=True)

    def run(e):
        t = e.add(inputs[0], inputs[2])
        m = e.mul(t, inputs[1])
        d = e.div(m, inputs[1])
        s = e.sub(d, t)  # fusion resumes after the eager island
        return [np.asarray(x, np.uint64) for x in (t, m, d, s)]

    for w, g in zip(run(eager), run(fused)):
        np.testing.assert_array_equal(w, g)
    assert eager.stats == fused.stats


def test_graph_splits_on_element_count_change():
    e = PulsarEngine(fuse=True)
    a = _rand_inputs(32, 64, seed=13)[0]
    b = _rand_inputs(32, 128, seed=14)[0]
    x = e.add(a, a)
    y = e.add(b, b)  # different n: previous graph flushes
    np.testing.assert_array_equal(np.asarray(x),
                                  (a + a) & np.uint64(0xFFFFFFFF))
    np.testing.assert_array_equal(np.asarray(y),
                                  (b + b) & np.uint64(0xFFFFFFFF))


def test_dead_handles_are_dead_code():
    e = PulsarEngine(fuse=True)
    a = _rand_inputs(32, 64, seed=17)[0]
    tmp = e.and_(a, a)
    tmp = e.xor(tmp, a)  # first AND's handle dies here
    keep = e.add(tmp, a)
    del tmp
    lat = e.stats.latency_ns  # dead ops were still charged
    e.flush()
    assert e.stats.latency_ns == lat
    np.testing.assert_array_equal(
        np.asarray(keep), (a + (a ^ (a & a))) & np.uint64(0xFFFFFFFF))


def test_pipeline_cache_reuses_compiled_programs():
    """Same graph structure across batches -> one compiled pipeline."""
    e = PulsarEngine(fuse=True)

    def batch(seed):
        a, b, c = _rand_inputs(32, 256, seed)
        t = e.and_(a, b)
        t = e.add(t, c)
        return np.asarray(t)

    batch(0)
    info = fused_program._cached_pipeline.cache_info()
    for s in range(1, 4):
        batch(s)
    after = fused_program._cached_pipeline.cache_info()
    assert after.currsize == info.currsize
    assert after.hits == info.hits + 3


def test_fuse_requires_fast_backend():
    with pytest.raises(ValueError):
        PulsarEngine(backend="sim", fuse=True)


def test_fused_rejects_out_of_width_operands():
    """Eager ops compute on raw uint64 values; fused computes modulo
    2**width. Out-of-range operands must fail loudly, not silently
    truncate into different answers."""
    e = PulsarEngine(width=8, fuse=True)
    with pytest.raises(ValueError, match="modulo"):
        e.and_(np.array([256, 1], np.uint64), np.array([1, 1], np.uint64))
    # eager keeps the raw-uint64 semantics realworld's kernels rely on
    eager = PulsarEngine(width=8)
    np.testing.assert_array_equal(
        eager.and_(np.array([256 + 5], np.uint64),
                   np.array([260], np.uint64)),
        np.array([256 + 4], np.uint64))


def test_temporary_operands_do_not_collide():
    """id()-keyed leaf dedup must pin operands: freed temporaries whose
    addresses get reused by later operands must not resolve to a stale
    leaf snapshot."""
    e = PulsarEngine(fuse=True)
    outs = []
    for k in range(8):
        tmp = np.full(64, k, np.uint64)  # dies each iteration
        outs.append(e.add(tmp, tmp))
        del tmp
    for k, o in enumerate(outs):
        np.testing.assert_array_equal(np.asarray(o),
                                      np.full(64, 2 * k, np.uint64))


def test_materialized_handles_release_the_graph():
    e = PulsarEngine(fuse=True)
    a = np.arange(64, dtype=np.uint64)
    t = e.add(a, a)
    assert any(p is a for p in e._graph._pins)  # id() key held alive
    np.testing.assert_array_equal(np.asarray(t), 2 * a)
    assert t._graph is None and t._engine is None  # snapshots reclaimable


def test_operand_mutation_after_record_does_not_alias():
    """The graph snapshots operands at record time: mutating the caller's
    buffer before flush must not change the result (eager parity)."""
    e = PulsarEngine(fuse=True)
    b = np.arange(64, dtype=np.uint64)
    t = e.add(b, b)
    b[:] = 0
    np.testing.assert_array_equal(np.asarray(t),
                                  2 * np.arange(64, dtype=np.uint64))


def test_operand_mutation_between_uses_registers_fresh_leaf():
    """Re-feeding the same buffer after an in-place mutation must see the
    new content (eager parity), not dedup to the stale snapshot."""
    e = PulsarEngine(fuse=True)
    a = np.zeros(64, dtype=np.uint64)
    t1 = e.add(a, a)
    a[:] = 5
    t2 = e.add(a, a)
    np.testing.assert_array_equal(np.asarray(t1), np.zeros(64, np.uint64))
    np.testing.assert_array_equal(np.asarray(t2),
                                  np.full(64, 10, np.uint64))


def test_flush_failure_keeps_handles_recoverable(monkeypatch):
    """A transient pipeline failure must not orphan pending handles: the
    graph is restored and a later materialize retries."""
    from repro.core import engine as engine_mod
    e = PulsarEngine(fuse=True)
    a = np.arange(64, dtype=np.uint64)
    t = e.add(a, a)

    def boom(*args, **kw):
        raise RuntimeError("transient backend failure")

    real = engine_mod.get_pipeline
    monkeypatch.setattr(engine_mod, "get_pipeline", boom)
    with pytest.raises(RuntimeError, match="transient"):
        t.materialize()
    monkeypatch.setattr(engine_mod, "get_pipeline", real)
    np.testing.assert_array_equal(t.materialize(), 2 * a)


def test_pending_lazy_crosses_engines_via_materialization():
    """A pending handle from one engine fed into another fused engine must
    materialize through its own engine, not alias the foreign graph."""
    a = _rand_inputs(32, 64, seed=29)[0]
    e1 = PulsarEngine(fuse=True)
    e2 = PulsarEngine(fuse=True)
    t = e1.add(a, a)
    r = e2.xor(t, a)
    np.testing.assert_array_equal(
        np.asarray(r), (((a + a) & np.uint64(0xFFFFFFFF)) ^ a))


# --------------------------------------------------------------------- #
# SWAR popcount regression (fixed-iteration replacement for the old
# data-dependent shift loop and the per-element Python path)
# --------------------------------------------------------------------- #


@given(seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_swar_popcount_matches_scalar_oracle(seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2**64, 257, dtype=np.uint64)  # full 64-bit range
    want = np.array([bin(int(x)).count("1") for x in a], np.uint64)
    np.testing.assert_array_equal(_vec_popcount(a), want)


def test_swar_popcount_edge_values():
    a = np.array([0, 1, 2**63, 2**64 - 1, 0x5555555555555555], np.uint64)
    np.testing.assert_array_equal(_vec_popcount(a),
                                  np.array([0, 1, 1, 64, 32], np.uint64))
    # 2-D shape preserved; input not mutated
    m = np.array([[3, 7], [15, 255]], np.uint64)
    m0 = m.copy()
    np.testing.assert_array_equal(_vec_popcount(m),
                                  np.array([[2, 3], [4, 8]], np.uint64))
    np.testing.assert_array_equal(m, m0)


def test_engine_popcount_small_arrays_use_swar():
    """The old per-element ``bin(int(x))`` path for size<4096 is gone; the
    vector path must be exact at every size."""
    e = PulsarEngine(width=32)
    rng = np.random.default_rng(23)
    for n in (1, 31, 33, 4095, 5000):
        a = rng.integers(0, 2**32, n, dtype=np.uint64)
        want = np.array([bin(int(x)).count("1") for x in a], np.uint64)
        np.testing.assert_array_equal(np.asarray(e.popcount(a)), want)
