"""Fused dataplane (engine fuse=True) vs eager: bit-exactness and
cost-plane invariance, across widths and random op sequences."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dep: fixed-seed fallback
    from repro.testing import given, settings, st

from repro.core.engine import LazyArray, PulsarEngine, _vec_popcount
from repro.kernels import fused_program

pytestmark = pytest.mark.fused

# Chain ops: (engine method, n_operands). Applied as t = op(t, pool[i]).
_CHAIN_OPS = ["and", "or", "xor", "add", "sub", "mul", "div", "mod"]
_TAIL_OPS = ["less", "popcount", "reduce_and", "reduce_or", "reduce_xor"]


def _rand_inputs(width, n, seed):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 1 << width, n, dtype=np.uint64)
            for _ in range(3)]


def _apply(e, name, t, other):
    if name == "and":
        return e.and_(t, other)
    if name == "or":
        return e.or_(t, other)
    if name == "xor":
        return e.xor(t, other)
    if name == "add":
        return e.add(t, other)
    if name == "sub":
        return e.sub(t, other)
    if name == "mul":
        return e.mul(t, other)
    if name == "div":
        return e.div(t, other)
    if name == "mod":
        return e.mod(t, other)
    if name == "less":
        return e.less_than(t, other)
    if name == "popcount":
        return e.popcount(t)
    if name.startswith("reduce_"):
        return e.reduce_bits(t, name.removeprefix("reduce_"))
    raise KeyError(name)


def _run_sequence(e, inputs, op_seq):
    """Random chain over the input pool; returns every intermediate (so
    flush must materialize intermediates whose handles stay alive)."""
    outs = []
    t = inputs[0]
    for i, name in enumerate(op_seq):
        t = _apply(e, name, t, inputs[(i + 1) % len(inputs)])
        outs.append(t)
    return [np.asarray(o, np.uint64) for o in outs]


@given(width=st.sampled_from([8, 16, 32]), seed=st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_fused_matches_eager_random_sequence(width, seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(33, 400))  # deliberately not a multiple of 32
    inputs = _rand_inputs(width, n, seed)
    n_ops = int(rng.integers(2, 7))
    op_seq = [str(rng.choice(_CHAIN_OPS)) for _ in range(n_ops - 1)]
    op_seq.append(str(rng.choice(_CHAIN_OPS + _TAIL_OPS)))

    eager = PulsarEngine(width=width)
    fused = PulsarEngine(width=width, fuse=True)
    want = _run_sequence(eager, inputs, op_seq)
    got = _run_sequence(fused, inputs, op_seq)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    assert eager.stats == fused.stats


@pytest.mark.parametrize("width", [8, 16, 32])
def test_fused_all_opcodes_bit_exact(width):
    inputs = _rand_inputs(width, 256, seed=width)
    seq = ["and", "xor", "or", "add", "sub", "mul", "div", "mod", "less"]
    tails = ["popcount", "reduce_and", "reduce_or", "reduce_xor"]
    eager = PulsarEngine(width=width)
    fused = PulsarEngine(width=width, fuse=True)

    def run(e):
        outs = _run_sequence(e, inputs, seq)
        base = e.add(inputs[0], inputs[1])
        outs += [np.asarray(_apply(e, t, base, None), np.uint64)
                 for t in tails]
        return outs

    for w, g in zip(run(eager), run(fused)):
        np.testing.assert_array_equal(w, g)
    assert eager.stats == fused.stats


def test_cost_plane_invariance_with_controller():
    """EngineStats must match eager exactly under controller pricing too
    (latency, energy, sequences, refresh stalls)."""
    inputs = _rand_inputs(32, 128, seed=3)
    seq = ["add", "xor", "sub", "and", "popcount"]
    eager = PulsarEngine(width=32, controller="auto")
    fused = PulsarEngine(width=32, controller="auto", fuse=True)
    for w, g in zip(_run_sequence(eager, inputs, seq),
                    _run_sequence(fused, inputs, seq)):
        np.testing.assert_array_equal(w, g)
    assert eager.stats == fused.stats
    assert fused.stats.refresh_stall_ns > 0


def test_charges_accrue_at_record_time():
    """The cost plane must not wait for flush(): recording IS charging."""
    import dataclasses
    e = PulsarEngine(fuse=True)
    a = _rand_inputs(32, 64, seed=5)[0]
    t = e.add(a, a)
    assert e.stats.latency_ns > 0 and e.stats.n_sequences > 0
    before = dataclasses.replace(e.stats)
    _ = np.asarray(t)  # flush: dataplane only
    assert e.stats == before


def test_lazy_array_api_and_flush():
    e = PulsarEngine(fuse=True)
    a = _rand_inputs(32, 64, seed=7)[0]
    t = e.xor(a, a)
    assert isinstance(t, LazyArray)
    assert t.shape == (64,) and t.size == 64 and t.ndim == 1
    assert t.dtype == np.uint64
    assert "pending" in repr(t)
    e.flush()
    assert "materialized" in repr(t)
    np.testing.assert_array_equal(t.materialize(), np.zeros(64, np.uint64))
    e.flush()  # idempotent no-op


def test_lazy_array_eq_and_bool_follow_ndarray_semantics():
    """`==` must compare values (not identity) and truth-testing must
    behave like ndarray — no silent scalars from ported eager code."""
    e = PulsarEngine(fuse=True)
    z = np.arange(4, dtype=np.uint64)
    t1 = e.add(z, z)
    t2 = e.add(z, z)
    np.testing.assert_array_equal(t1 == t2, np.full(4, True))
    np.testing.assert_array_equal(t1 != t2, np.full(4, False))
    with pytest.raises(ValueError):  # ambiguous, exactly like ndarray
        bool(e.add(z, z))
    one = e.add(np.ones(1, np.uint64), np.zeros(1, np.uint64))
    assert bool(one)


def test_mul_div_stay_inside_the_fused_flush():
    """mul/div/mod are in the fused ISA since PR 3: a mixed arithmetic
    chain records as ONE graph (no eager island, no intermediate
    materialization) and still matches eager bit-exactly with identical
    stats."""
    inputs = _rand_inputs(16, 96, seed=11)
    eager = PulsarEngine(width=16)
    fused = PulsarEngine(width=16, fuse=True)

    def run(e):
        t = e.add(inputs[0], inputs[2])
        m = e.mul(t, inputs[1])
        d = e.div(m, inputs[1])
        r = e.mod(m, inputs[1])
        s = e.sub(d, t)
        return (t, m, d, r, s)

    want = [np.asarray(x, np.uint64) for x in run(eager)]
    got = run(fused)
    # No eager fallback: every handle is still pending before the flush.
    assert all(isinstance(x, LazyArray) and x._value is None for x in got)
    # add + mul + sub = 3 ops; div and mod each lower to the shared
    # divmod tuple op plus a selector (2 ops each) — flush-time CSE
    # unifies the two divmods into ONE restoring-division pass.
    assert fused._graph is not None and len(fused._graph.ops) == 7
    opcodes = [op for op, _, _ in fused._graph.ops]
    assert opcodes.count("divmod") == 2  # unified to 1 by optimize_program
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, np.asarray(g, np.uint64))
    assert eager.stats == fused.stats


@given(width=st.sampled_from([8, 16, 32]), seed=st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_fused_mul_div_property(width, seed):
    """Fused mul/div/mod match eager bit-exactly across widths, including
    div-by-zero lanes and the signed-boundary values (0, 1, 2**(w-1),
    2**w - 1)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(40, 300))
    a = rng.integers(0, 1 << width, n, dtype=np.uint64)
    b = rng.integers(0, 1 << width, n, dtype=np.uint64)
    edges = np.array([0, 1, 1 << (width - 1), (1 << width) - 1], np.uint64)
    a[:4], b[:4] = edges, edges[::-1]
    b[::5] = 0  # div/mod by zero -> 0, the unsigned NumPy semantics
    eager = PulsarEngine(width=width)
    fused = PulsarEngine(width=width, fuse=True)
    for op in ("mul", "div", "mod"):
        w = np.asarray(getattr(eager, op)(a, b), np.uint64)
        g = getattr(fused, op)(a, b)
        assert isinstance(g, LazyArray)
        np.testing.assert_array_equal(w, np.asarray(g, np.uint64))
    assert eager.stats == fused.stats


def test_graph_splits_on_element_count_change():
    e = PulsarEngine(fuse=True)
    a = _rand_inputs(32, 64, seed=13)[0]
    b = _rand_inputs(32, 128, seed=14)[0]
    x = e.add(a, a)
    y = e.add(b, b)  # different n: previous graph flushes
    np.testing.assert_array_equal(np.asarray(x),
                                  (a + a) & np.uint64(0xFFFFFFFF))
    np.testing.assert_array_equal(np.asarray(y),
                                  (b + b) & np.uint64(0xFFFFFFFF))


def test_dead_handles_are_dead_code():
    e = PulsarEngine(fuse=True)
    a = _rand_inputs(32, 64, seed=17)[0]
    tmp = e.and_(a, a)
    tmp = e.xor(tmp, a)  # first AND's handle dies here
    keep = e.add(tmp, a)
    del tmp
    lat = e.stats.latency_ns  # dead ops were still charged
    e.flush()
    assert e.stats.latency_ns == lat
    np.testing.assert_array_equal(
        np.asarray(keep), (a + (a ^ (a & a))) & np.uint64(0xFFFFFFFF))


def test_pipeline_cache_reuses_compiled_programs():
    """Same graph structure across batches -> one compiled pipeline."""
    e = PulsarEngine(fuse=True)

    def batch(seed):
        a, b, c = _rand_inputs(32, 256, seed)
        t = e.and_(a, b)
        t = e.add(t, c)
        return np.asarray(t)

    batch(0)
    info = fused_program._cached_pipeline.cache_info()
    for s in range(1, 4):
        batch(s)
    after = fused_program._cached_pipeline.cache_info()
    assert after.currsize == info.currsize
    assert after.hits == info.hits + 3


def test_fuse_requires_fast_backend():
    with pytest.raises(ValueError):
        PulsarEngine(backend="sim", fuse=True)


def test_fused_arithmetic_rejects_out_of_width_operands():
    """Eager arithmetic computes on raw uint64 values; fused computes
    modulo 2**width. Out-of-range operands to arithmetic ops must fail
    loudly, not silently truncate into different answers."""
    e = PulsarEngine(width=8, fuse=True)
    big = np.array([256, 1], np.uint64)
    one = np.array([1, 1], np.uint64)
    for op in (e.add, e.sub, e.mul, e.div, e.mod, e.less_than):
        with pytest.raises(ValueError, match="modulo"):
            op(big, one)
    # popcount is the exception: out-of-width operands route through the
    # raw planewise graph (like and/or/xor) and the materialize fold sums
    # the per-lane counts — bit-exact with eager's raw-word popcount.
    np.testing.assert_array_equal(np.asarray(e.popcount(big)),
                                  np.array([1, 1], np.uint64))


def test_fused_raw_popcount_folds_lane_counts():
    """popcount on the raw packed-bitmap path: the evaluators emit
    per-lane partial counts and the materialize fold sums them into the
    caller-visible per-word count; a pending raw popcount consumed by a
    further op materializes (folds) first. Both bit-exact with eager."""
    rng = np.random.default_rng(5)
    a = rng.integers(0, 2**64, 257, dtype=np.uint64)
    b = rng.integers(0, 2**64, 257, dtype=np.uint64)
    want = _vec_popcount(a & b)
    for fuse in (False, True):
        e = PulsarEngine(width=32, fuse=fuse)
        pc = e._popcount(e._and(a, b), width=64)
        composed = np.asarray(e._mul(pc, np.full_like(a, 2)), np.uint64)
        np.testing.assert_array_equal(np.asarray(pc, np.uint64), want)
        np.testing.assert_array_equal(composed, want * 2)


def test_fused_planewise_raw_bitmap_path():
    """and_/or_/xor on out-of-width operands route through the raw
    packed-bitmap graph (two 32-bit lanes per 64-bit word) instead of
    rejecting: bit-exact with eager's raw-uint64 semantics — the contract
    realworld's packed-bitmap kernels (set intersection) rely on."""
    rng = np.random.default_rng(31)
    a = rng.integers(0, 2**64, 65, dtype=np.uint64)  # full 64-bit range
    b = rng.integers(0, 2**64, 65, dtype=np.uint64)
    c = rng.integers(0, 2**64, 65, dtype=np.uint64)
    for width in (8, 32):
        eager = PulsarEngine(width=width)
        fused = PulsarEngine(width=width, fuse=True)

        def chain(e):
            t = e.and_(a, b)
            t = e.xor(t, c)
            return e.or_(t, b)

        want = np.asarray(chain(eager), np.uint64)
        got = chain(fused)
        assert isinstance(got, LazyArray)
        # one raw graph, no flush between the three plane-wise ops
        assert fused._graph is not None and fused._graph.raw
        assert len(fused._graph.ops) == 3
        np.testing.assert_array_equal(want, np.asarray(got, np.uint64))
        assert eager.stats == fused.stats  # charged on words, not lanes


def test_raw_and_value_graphs_do_not_mix():
    """A raw packed-bitmap graph flushes before a value-mode op records
    (and vice versa); arithmetic on a raw out-of-width result still fails
    loudly at leaf registration."""
    rng = np.random.default_rng(33)
    bm = rng.integers(1 << 40, 2**64, 64, dtype=np.uint64)
    small = rng.integers(0, 256, 64, dtype=np.uint64)
    e = PulsarEngine(width=32, fuse=True)
    raw = e.and_(bm, bm)          # raw graph opens
    assert e._graph.raw
    t = e.add(small, small)       # value-mode: raw graph flushed first
    assert raw._value is not None and not e._graph.raw
    np.testing.assert_array_equal(np.asarray(raw), bm)
    with pytest.raises(ValueError, match="modulo"):
        e.add(e.and_(bm, bm), small)  # arithmetic on raw values: loud
    np.testing.assert_array_equal(np.asarray(t), 2 * small)


def test_temporary_operands_do_not_collide():
    """id()-keyed leaf dedup must pin operands: freed temporaries whose
    addresses get reused by later operands must not resolve to a stale
    leaf snapshot."""
    e = PulsarEngine(fuse=True)
    outs = []
    for k in range(8):
        tmp = np.full(64, k, np.uint64)  # dies each iteration
        outs.append(e.add(tmp, tmp))
        del tmp
    for k, o in enumerate(outs):
        np.testing.assert_array_equal(np.asarray(o),
                                      np.full(64, 2 * k, np.uint64))


def test_materialized_handles_release_the_graph():
    e = PulsarEngine(fuse=True)
    a = np.arange(64, dtype=np.uint64)
    t = e.add(a, a)
    assert any(p is a for p in e._graph._pins)  # id() key held alive
    np.testing.assert_array_equal(np.asarray(t), 2 * a)
    assert t._graph is None and t._engine is None  # snapshots reclaimable


def test_operand_mutation_after_record_does_not_alias():
    """The graph snapshots operands at record time: mutating the caller's
    buffer before flush must not change the result (eager parity)."""
    e = PulsarEngine(fuse=True)
    b = np.arange(64, dtype=np.uint64)
    t = e.add(b, b)
    b[:] = 0
    np.testing.assert_array_equal(np.asarray(t),
                                  2 * np.arange(64, dtype=np.uint64))


def test_operand_mutation_between_uses_registers_fresh_leaf():
    """Re-feeding the same buffer after an in-place mutation must see the
    new content (eager parity), not dedup to the stale snapshot."""
    e = PulsarEngine(fuse=True)
    a = np.zeros(64, dtype=np.uint64)
    t1 = e.add(a, a)
    a[:] = 5
    t2 = e.add(a, a)
    np.testing.assert_array_equal(np.asarray(t1), np.zeros(64, np.uint64))
    np.testing.assert_array_equal(np.asarray(t2),
                                  np.full(64, 10, np.uint64))


def test_flush_failure_keeps_handles_recoverable(monkeypatch):
    """A transient pipeline failure must not orphan pending handles: the
    graph is restored and a later materialize retries."""
    from repro.core import engine as engine_mod
    e = PulsarEngine(fuse=True)
    a = np.arange(64, dtype=np.uint64)
    t = e.add(a, a)

    def boom(*args, **kw):
        raise RuntimeError("transient backend failure")

    real = engine_mod.get_pipeline
    monkeypatch.setattr(engine_mod, "get_pipeline", boom)
    with pytest.raises(RuntimeError, match="transient"):
        t.materialize()
    monkeypatch.setattr(engine_mod, "get_pipeline", real)
    np.testing.assert_array_equal(t.materialize(), 2 * a)


def test_pending_lazy_crosses_engines_via_materialization():
    """A pending handle from one engine fed into another fused engine must
    materialize through its own engine, not alias the foreign graph."""
    a = _rand_inputs(32, 64, seed=29)[0]
    e1 = PulsarEngine(fuse=True)
    e2 = PulsarEngine(fuse=True)
    t = e1.add(a, a)
    r = e2.xor(t, a)
    np.testing.assert_array_equal(
        np.asarray(r), (((a + a) & np.uint64(0xFFFFFFFF)) ^ a))


# --------------------------------------------------------------------- #
# CSE / dead-node pruning (flush-time graph normalization)
# --------------------------------------------------------------------- #


def test_cse_does_not_change_results_or_stats():
    """Recording duplicate subexpressions (including commutative twins)
    must flush to eager-identical values and leave EngineStats exactly as
    eager charges them — CSE only drops redundant dataplane work."""
    rng = np.random.default_rng(41)
    a = rng.integers(0, 1 << 16, 128, dtype=np.uint64)
    b = rng.integers(0, 1 << 16, 128, dtype=np.uint64)
    eager = PulsarEngine(width=16)
    fused = PulsarEngine(width=16, fuse=True)

    def run(e):
        t1 = e.add(a, b)
        t2 = e.add(b, a)       # commutative duplicate of t1
        t3 = e.xor(t1, t2)     # == 0
        t4 = e.mul(t1, t1)
        t5 = e.mul(t2, t2)     # duplicate of t4 after t1/t2 unify
        return [np.asarray(x, np.uint64) for x in (t1, t2, t3, t4, t5)]

    for w, g in zip(run(eager), run(fused)):
        np.testing.assert_array_equal(w, g)
    assert eager.stats == fused.stats


def test_cse_normalized_programs_share_the_pipeline_cache():
    """Two recordings that differ only in redundant ops must normalize to
    the same program and hit the same compiled pipeline."""
    from repro.kernels import fused_program
    e = PulsarEngine(width=32, fuse=True)
    a, b, _ = _rand_inputs(32, 256, seed=43)

    t = e.and_(a, b)
    keep = e.add(t, a)
    np.asarray(keep)
    info = fused_program._cached_pipeline.cache_info()

    t = e.and_(a, b)
    dup = e.and_(a, b)     # live redundant twin: unified by CSE at flush
    keep = e.add(t, a)
    np.asarray(keep)
    after = fused_program._cached_pipeline.cache_info()
    assert after.currsize == info.currsize  # no new compiled pipeline
    assert after.hits == info.hits + 1
    # both handles materialized from the one computed value
    np.testing.assert_array_equal(np.asarray(dup), np.asarray(t))


def test_optimizer_prunes_dead_leaves_from_the_pipeline():
    """An op whose handle dies pulls its exclusive leaves out of the
    compiled program too (fewer pipeline inputs, same results)."""
    e = PulsarEngine(width=32, fuse=True)
    a, b, c = _rand_inputs(32, 64, seed=47)
    keep = e.add(a, b)
    dead = e.xor(c, c)     # only consumer of leaf c
    del dead
    np.testing.assert_array_equal(
        np.asarray(keep), (a + b) & np.uint64(0xFFFFFFFF))


# --------------------------------------------------------------------- #
# Auto-flush thresholds
# --------------------------------------------------------------------- #


def test_autoflush_graph_size_threshold():
    """flush_threshold bounds the recorded graph: the op that reaches the
    bound flushes (its handle materializes eagerly), and recording then
    continues into a fresh graph — results and stats unchanged."""
    a, b, c = _rand_inputs(16, 64, seed=51)
    eager = PulsarEngine(width=16)
    fused = PulsarEngine(width=16, fuse=True, flush_threshold=3)

    def run(e):
        t = e.add(a, b)
        t = e.xor(t, c)
        t = e.mul(t, b)    # fused: auto-flush fires here
        t = e.sub(t, a)
        t = e.or_(t, c)
        return t

    got = run(fused)
    assert fused._graph is not None and len(fused._graph.ops) == 2
    want = run(eager)
    np.testing.assert_array_equal(np.asarray(want, np.uint64),
                                  np.asarray(got, np.uint64))
    assert eager.stats == fused.stats


def test_autoflush_memory_threshold():
    e = PulsarEngine(width=32, fuse=True, flush_memory_bytes=4 * 64 * 4)
    a, b, _ = _rand_inputs(32, 64, seed=53)
    t = e.add(a, b)        # 2 leaves + 1 op = 3 held values: under bound
    assert e._graph is not None
    t2 = e.add(t, t)       # 4 held values * 4B * 64 lanes: bound reached
    assert e._graph is None and t2._value is not None
    np.testing.assert_array_equal(
        np.asarray(t2), (2 * ((a + b) & np.uint64(0xFFFFFFFF)))
        & np.uint64(0xFFFFFFFF))


def test_autoflush_disabled_with_none():
    e = PulsarEngine(width=16, fuse=True, flush_threshold=None,
                     flush_memory_bytes=None)
    a, b, _ = _rand_inputs(16, 64, seed=55)
    t = a
    for _ in range(64):
        t = e.add(t, b)
    assert e._graph is not None and len(e._graph.ops) == 64


# --------------------------------------------------------------------- #
# SWAR popcount regression (fixed-iteration replacement for the old
# data-dependent shift loop and the per-element Python path)
# --------------------------------------------------------------------- #


@given(seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_swar_popcount_matches_scalar_oracle(seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2**64, 257, dtype=np.uint64)  # full 64-bit range
    want = np.array([bin(int(x)).count("1") for x in a], np.uint64)
    np.testing.assert_array_equal(_vec_popcount(a), want)


def test_swar_popcount_edge_values():
    a = np.array([0, 1, 2**63, 2**64 - 1, 0x5555555555555555], np.uint64)
    np.testing.assert_array_equal(_vec_popcount(a),
                                  np.array([0, 1, 1, 64, 32], np.uint64))
    # 2-D shape preserved; input not mutated
    m = np.array([[3, 7], [15, 255]], np.uint64)
    m0 = m.copy()
    np.testing.assert_array_equal(_vec_popcount(m),
                                  np.array([[2, 3], [4, 8]], np.uint64))
    np.testing.assert_array_equal(m, m0)


def test_engine_popcount_small_arrays_use_swar():
    """The old per-element ``bin(int(x))`` path for size<4096 is gone; the
    vector path must be exact at every size."""
    e = PulsarEngine(width=32)
    rng = np.random.default_rng(23)
    for n in (1, 31, 33, 4095, 5000):
        a = rng.integers(0, 2**32, n, dtype=np.uint64)
        want = np.array([bin(int(x)).count("1") for x in a], np.uint64)
        np.testing.assert_array_equal(np.asarray(e.popcount(a)), want)
