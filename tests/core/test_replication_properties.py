"""Property tests for the fig-10 replication planner invariants."""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from repro.testing import given, settings, st

from repro.core.replication import fracdram_plan, plan, plan_pow2

ODD_M = st.sampled_from([3, 5, 7, 9])
N_RG = st.integers(min_value=3, max_value=64)


@settings(max_examples=50)
@given(m=ODD_M, n=N_RG)
def test_plan_partitions_all_rows(m, n):
    if n < m:
        with pytest.raises(ValueError):
            plan(m, n)
        return
    p = plan(m, n)
    assert p.copies * p.m_inputs + p.n_neutral == p.n_rg == n
    assert p.copies >= 1 and p.n_neutral >= 0
    # Odd fan-in with equal copies never ties: net votes >= copies >= 1.
    assert p.worst_case_net_votes == p.copies >= 1
    slots = p.row_assignment()
    assert len(slots) == n
    assert all(slots.count(i) == p.copies for i in range(m))
    assert slots.count(-1) == p.n_neutral


@settings(max_examples=50)
@given(m=ODD_M, n=N_RG)
def test_plan_pow2_copies_are_powers_of_two(m, n):
    if n < m:
        with pytest.raises(ValueError):
            plan_pow2(m, n)
        return
    p = plan_pow2(m, n)
    assert p.copies * p.m_inputs + p.n_neutral == p.n_rg == n
    assert p.copies >= 1
    assert p.copies & (p.copies - 1) == 0  # power of two
    # Rounded DOWN from the maximal plan, never past it.
    assert p.copies <= plan(m, n).copies < 2 * p.copies


@settings(max_examples=20)
@given(m=ODD_M, n=N_RG)
def test_plan_is_maximal(m, n):
    if n < m:
        return
    p = plan(m, n)
    # Maximal replication: one more copy per input would not fit.
    assert (p.copies + 1) * m > n


@given(m=st.sampled_from([2, 4, 6]), n=st.integers(min_value=8,
                                                   max_value=32))
def test_even_fan_in_rejected(m, n):
    with pytest.raises(ValueError):
        plan(m, n)
    with pytest.raises(ValueError):
        plan_pow2(m, n)


def test_fracdram_plan_shape():
    p = fracdram_plan()
    assert (p.m_inputs, p.n_rg, p.copies, p.n_neutral) == (3, 4, 1, 1)
    p5 = fracdram_plan(5)
    assert p5.n_rg == 6 and p5.copies == 1 and p5.n_neutral == 1
