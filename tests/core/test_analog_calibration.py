"""Analog model: charge-sharing math + calibration against paper anchors.

The paper's quantitative anchors (see DESIGN.md §2 / EXPERIMENTS.md §Repro):
  * N=32 MAJ3 deviation ~ +159% vs FracDRAM N=4 (§5.1) — analytic in our
    charge-conservation model given C_bl/C = 5.8,
  * MAJ3(1,1,0) @ N=4 deviation ~ 41% BELOW single-row activation (§3.1.1),
  * success rates: FracDRAM MAJ3 ~ 78.85% (Mfr H DDR4), PULSAR MAJ3@32
    ~ 97.9%, MAJ5 ~ 74%, MAJ7 ~ 29% (±tolerances here — Monte-Carlo model).
"""

import jax
import numpy as np
import pytest

from repro.core import analog
from repro.core.charact import SuccessRateDb, spatial_pv_multiplier
from repro.core.profiles import MFR_H, MFR_M
from repro.core.replication import fracdram_plan, plan

KEY = jax.random.PRNGKey(0)


def test_deviation_sign_follows_majority():
    dv1 = analog.deviation_distribution(KEY, MFR_H, m_inputs=3, copies=1,
                                        n_neutral=1, ones=2)
    dv0 = analog.deviation_distribution(KEY, MFR_H, m_inputs=3, copies=1,
                                        n_neutral=1, ones=1)
    assert float(dv1.mean()) > 0 > float(dv0.mean())


def test_replication_boosts_deviation_159pct():
    """N=32 (10 copies + 2 neutral) vs FracDRAM N=4: paper says +159%."""
    p32 = plan(3, 32)
    dv32 = analog.deviation_distribution(KEY, MFR_H, m_inputs=3,
                                         copies=p32.copies,
                                         n_neutral=p32.n_neutral, ones=2,
                                         process_variation=0.0)
    dv4 = analog.deviation_distribution(KEY, MFR_H, m_inputs=3, copies=1,
                                        n_neutral=1, ones=2,
                                        process_variation=0.0)
    boost = float(dv32.mean() / dv4.mean()) - 1.0
    assert 1.40 < boost < 1.80  # paper: 1.59


def test_maj3_deviation_below_single_row():
    """MAJ3(1,1,0) deviation ~41% below nominal single-row (§3.1.1)."""
    dv_maj = analog.deviation_distribution(KEY, MFR_H, m_inputs=3, copies=1,
                                           n_neutral=1, ones=2,
                                           process_variation=0.0)
    dv_one = analog.single_row_deviation(KEY, MFR_H, process_variation=0.0)
    drop = 1.0 - float(dv_maj.mean() / dv_one.mean())
    assert 0.30 < drop < 0.55  # paper: 0.41


def test_variation_widens_distribution():
    lo = analog.deviation_distribution(KEY, MFR_H, m_inputs=3, copies=1,
                                       n_neutral=1, ones=2,
                                       process_variation=0.1)
    hi = analog.deviation_distribution(KEY, MFR_H, m_inputs=3, copies=1,
                                       n_neutral=1, ones=2,
                                       process_variation=0.4)
    assert float(hi.std()) > float(lo.std())


def test_success_increases_with_replication():
    db = SuccessRateDb(n_bitlines=1024, n_groups=8, n_patterns=32)
    curve = [db.mean("H", 3, n) for n in (4, 8, 16, 32)]
    assert curve == sorted(curve)
    assert curve[-1] > curve[0] + 0.05


def test_success_decreases_with_fan_in():
    db = SuccessRateDb(n_bitlines=1024, n_groups=8, n_patterns=32)
    m3 = db.mean("H", 3, 32)
    m5 = db.mean("H", 5, 32)
    m7 = db.mean("H", 7, 32)
    assert m3 > m5 > m7


def test_mfr_m_beats_mfr_h():
    db = SuccessRateDb(n_bitlines=1024, n_groups=8, n_patterns=32)
    assert db.mean("M", 3, 16) > db.mean("H", 3, 16)


@pytest.mark.slow
def test_calibration_anchors():
    """The headline numbers (±8 points tolerance — Monte-Carlo device model,
    not a SPICE deck; EXPERIMENTS.md reports the exact values)."""
    db = SuccessRateDb(n_bitlines=2048, n_groups=12, n_patterns=48)
    frac = db.mean("H", 3, 4)
    pulsar = db.mean("H", 3, 32)
    maj5 = db.mean("H", 5, 32)
    assert 0.70 <= frac <= 0.88       # paper: 0.7885
    assert pulsar >= 0.93             # paper: 0.9791
    assert pulsar - frac > 0.10       # paper: +24.18 points
    assert 0.55 <= maj5 <= 0.92       # paper: 0.7393 (mean over modules)


def test_spatial_multiplier_m_shape():
    n = 16
    mult = [spatial_pv_multiplier(i, n) for i in range(n)]
    # W-shaped variation -> M-shaped success: minima near quarters.
    assert mult[4] == min(mult[:8])
    assert mult[12] == min(mult[8:])
    assert max(mult) <= 1.25 + 1e-9


def test_best_n_rg_prefers_replication_on_h_for_maj5():
    """On Mfr H, wide fan-ins only become usable with replication: the
    best-throughput N_RG for MAJ5 is > the minimal 8 (SR at 8 is ~0.3)."""
    db = SuccessRateDb(n_bitlines=512, n_groups=6, n_patterns=24)
    from repro.core.cost_model import CostModel
    cm = CostModel()
    n, thr = db.best_n_rg("H", 5, lambda m, nn: cm.maj_op(m, nn).latency_ns)
    assert n >= 16
