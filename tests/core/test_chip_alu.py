"""Chip model + PULSAR executor + bit-serial ALU: bit-exact vs NumPy."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dep: fixed-seed fallback
    from repro.testing import given, settings, st

from repro.core.alu import BitSerialAlu
from repro.core.chip import PulsarChip, majority_bits
from repro.core.geometry import DramGeometry
from repro.core.profiles import MFR_H, MFR_M
from repro.core.pulsar import PulsarExecutor, buddy_assign, build_region
from repro.core.replication import plan

GEOM = DramGeometry(row_bits=256, rows_per_subarray=256, subarrays_per_bank=2,
                    banks=1, predecoder_widths=(2, 2, 2, 2))
N_EL = 256  # elements per row (= row_bits)
W = GEOM.words_per_row


def fresh_alu(width=8, profile=MFR_H, max_n_rg=None):
    chip = PulsarChip(GEOM, profile, seed=0)
    chip.decoder = chip.decoder.__class__(GEOM, profile, None)  # full yield
    x = PulsarExecutor(chip, bank=0, subarray=0)
    return BitSerialAlu(x, width=width, max_n_rg=max_n_rg)


def rand(width, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << width, N_EL, dtype=np.uint64)


# --------------------------------------------------------------------- #
# majority_bits
# --------------------------------------------------------------------- #

@given(n=st.integers(1, 9), seed=st.integers(0, 999))
@settings(max_examples=50, deadline=None)
def test_majority_bits_matches_popcount(n, seed):
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, 2**32, (n, 8), dtype=np.uint64).astype(np.uint32)
    thresh = n // 2 + 1
    got = majority_bits(rows, thresh)
    bits = ((rows[:, :, None] >> np.arange(32)[None, None]) & 1).sum(0)
    want_bits = (bits >= thresh).astype(np.uint32)
    want = (want_bits << np.arange(32)[None]).sum(-1, dtype=np.uint64).astype(np.uint32)
    np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------------- #
# buddy assignment + region
# --------------------------------------------------------------------- #

@given(m=st.sampled_from([3, 5, 7]), n_log=st.integers(2, 5))
@settings(max_examples=40, deadline=None)
def test_buddy_assign_partitions_hypercube(m, n_log):
    n = 1 << n_log
    if n < m:
        return
    rp = plan(m, n)
    per_input, neutral = buddy_assign(m, rp.copies, rp.n_neutral, n_log)
    seen = set()
    for blocks, count in [(b, rp.copies) for b in per_input] + [(neutral, rp.n_neutral)]:
        tot = 0
        for start, size in blocks:
            assert start % size == 0  # buddy aligned
            blockset = set(range(start, start + size))
            assert not (blockset & seen)
            seen |= blockset
            tot += size
        assert tot == count
    assert seen == set(range(n))


def test_region_matches_decoder():
    chip = PulsarChip(GEOM, MFR_H, seed=0)
    chip.decoder = chip.decoder.__class__(GEOM, MFR_H, None)
    region = build_region(chip, 0, 0, 16)
    assert set(region.rows_by_combo) == set(
        chip.decoder.activated_rows(region.rf, region.rs))


# --------------------------------------------------------------------- #
# PULSAR primitives on the chip
# --------------------------------------------------------------------- #

def test_multi_row_init_copies_to_block():
    chip = PulsarChip(GEOM, MFR_H, seed=0)
    chip.decoder = chip.decoder.__class__(GEOM, MFR_H, None)
    x = PulsarExecutor(chip, 0, 0)
    data = np.arange(W, dtype=np.uint32)
    src = 200
    chip.write_row(0, src, data)
    rows = x.multi_row_init_block(src, 8)
    assert len(rows) == 8
    for r in rows:
        np.testing.assert_array_equal(chip.peek(0, r), data)


def test_bulk_write_block():
    chip = PulsarChip(GEOM, MFR_H, seed=0)
    chip.decoder = chip.decoder.__class__(GEOM, MFR_H, None)
    x = PulsarExecutor(chip, 0, 0)
    data = np.full(W, 0xDEADBEEF, np.uint32)
    rows = x.bulk_write_block(data, 16)
    assert len(rows) == 16
    for r in rows:
        np.testing.assert_array_equal(chip.peek(0, r), data)


@pytest.mark.parametrize("n_rg", [4, 8, 16])
@pytest.mark.parametrize("m", [3, 5])
def test_maj_on_random_data(n_rg, m):
    if n_rg < m:
        pytest.skip("N_RG < M")
    chip = PulsarChip(GEOM, MFR_H, seed=0)
    chip.decoder = chip.decoder.__class__(GEOM, MFR_H, None)
    x = PulsarExecutor(chip, 0, 0)
    rng = np.random.default_rng(42 + n_rg + m)
    srcs, datas = [], []
    for i in range(m):
        row = 200 + i
        data = rng.integers(0, 2**32, W, dtype=np.uint64).astype(np.uint32)
        chip.write_row(0, row, data)
        srcs.append(row)
        datas.append(data)
    dst = 240
    report = x.maj(dst, srcs, n_rg)
    votes = np.stack(datas)
    want = majority_bits(votes, m // 2 + 1)
    np.testing.assert_array_equal(chip.peek(0, dst), want)
    # Default pow2 staging plan: power-of-two copies.
    c = report.copies
    assert c & (c - 1) == 0 and c >= 1
    assert report.n_neutral == n_rg - m * c
    # Paper's maximal plan also executes correctly.
    dst2 = 241
    rep2 = x.maj(dst2, srcs, n_rg, plan_style="max")
    np.testing.assert_array_equal(chip.peek(0, dst2), want)
    assert rep2.copies == n_rg // m


def test_fracdram_baseline_maj3():
    chip = PulsarChip(GEOM, MFR_H, seed=0)
    chip.decoder = chip.decoder.__class__(GEOM, MFR_H, None)
    x = PulsarExecutor(chip, 0, 0)
    rng = np.random.default_rng(0)
    datas = [rng.integers(0, 2**32, W, dtype=np.uint64).astype(np.uint32)
             for _ in range(3)]
    for i, d in enumerate(datas):
        chip.write_row(0, 200 + i, d)
    rep = x.fracdram_maj3(240, [200, 201, 202])
    want = majority_bits(np.stack(datas), 2)
    np.testing.assert_array_equal(chip.peek(0, 240), want)
    assert rep.n_neutral == 1 and rep.copies == 1


def test_mfr_m_neutral_via_bias_write():
    chip = PulsarChip(GEOM, MFR_M, seed=0)
    chip.decoder = chip.decoder.__class__(GEOM, MFR_M, None)
    x = PulsarExecutor(chip, 0, 0)
    rng = np.random.default_rng(1)
    datas = [rng.integers(0, 2**32, W, dtype=np.uint64).astype(np.uint32)
             for _ in range(3)]
    for i, d in enumerate(datas):
        chip.write_row(0, 200 + i, d)
    x.maj(240, [200, 201, 202], n_rg=4)  # 1 neutral row via bias write
    want = majority_bits(np.stack(datas), 2)
    np.testing.assert_array_equal(chip.peek(0, 240), want)


def test_stability_mask_flips_unstable_bitlines():
    chip = PulsarChip(GEOM, MFR_H, seed=0)
    chip.decoder = chip.decoder.__class__(GEOM, MFR_H, None)
    x = PulsarExecutor(chip, 0, 0)
    datas = [np.zeros(W, np.uint32), np.zeros(W, np.uint32),
             np.full(W, 0xFFFFFFFF, np.uint32)]
    for i, d in enumerate(datas):
        chip.write_row(0, 200 + i, d)
    mask = np.ones(GEOM.row_bits, bool)
    mask[:32] = False  # first word unstable
    x.maj(240, [200, 201, 202], n_rg=8, stability_mask=mask)
    got = chip.peek(0, 240)
    assert got[0] == 0xFFFFFFFF  # flipped (correct result is 0)
    assert (got[1:] == 0).all()


# --------------------------------------------------------------------- #
# ALU vs NumPy
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("profile,n_rg", [(MFR_H, 16), (MFR_H, 4), (MFR_M, 8)])
def test_alu_logic(profile, n_rg):
    alu = fresh_alu(8, profile, max_n_rg=n_rg)
    a, b = rand(8, 1), rand(8, 2)
    va, vb = alu.load(a), alu.load(b)
    np.testing.assert_array_equal(alu.store(alu.and_(va, vb)), a & b)
    np.testing.assert_array_equal(alu.store(alu.or_(va, vb)), a | b)
    np.testing.assert_array_equal(alu.store(alu.xor(va, vb)), a ^ b)


@pytest.mark.parametrize("n_rg", [4, 8, 16])
def test_alu_add_sub(n_rg):
    alu = fresh_alu(8, max_n_rg=n_rg)
    a, b = rand(8, 3), rand(8, 4)
    va, vb = alu.load(a), alu.load(b)
    np.testing.assert_array_equal(alu.store(alu.add(va, vb)), (a + b) & 0xFF)
    np.testing.assert_array_equal(alu.store(alu.sub(va, vb)), (a - b) & 0xFF)


def test_alu_mul():
    alu = fresh_alu(8, max_n_rg=16)
    a, b = rand(8, 5), rand(8, 6)
    va, vb = alu.load(a), alu.load(b)
    np.testing.assert_array_equal(alu.store(alu.mul(va, vb)), (a * b) & 0xFF)


def test_alu_div():
    alu = fresh_alu(6, max_n_rg=16)
    a = rand(6, 7)
    b = rand(6, 8) | 1  # nonzero
    va, vb = alu.load(a), alu.load(b)
    q, r = alu.div(va, vb)
    np.testing.assert_array_equal(alu.store(q), a // b)
    np.testing.assert_array_equal(alu.store(r), a % b)


def test_alu_reductions():
    alu = fresh_alu(8, max_n_rg=16)
    a = rand(8, 9)
    va = alu.load(a)
    and_r = alu.store(alu.reduce_planes(va, "and"))
    or_r = alu.store(alu.reduce_planes(va, "or"))
    xor_r = alu.store(alu.xor_reduce_planes(va))
    np.testing.assert_array_equal(and_r, (a == 0xFF).astype(np.uint64))
    np.testing.assert_array_equal(or_r, (a != 0).astype(np.uint64))
    par = np.zeros_like(a)
    for j in range(8):
        par ^= (a >> j) & 1
    np.testing.assert_array_equal(xor_r, par)


def test_alu_popcount_less_than():
    alu = fresh_alu(8, max_n_rg=16)
    a, b = rand(8, 10), rand(8, 11)
    va, vb = alu.load(a), alu.load(b)
    pc = alu.store(alu.popcount_planes(va))
    want = np.array([bin(int(x)).count("1") for x in a], np.uint64)
    np.testing.assert_array_equal(pc, want)
    lt = alu.store(alu.less_than(va, vb))
    np.testing.assert_array_equal(lt, (a < b).astype(np.uint64))


def test_alu_stats_accumulate():
    alu = fresh_alu(8, max_n_rg=8)
    a, b = rand(8, 12), rand(8, 13)
    va, vb = alu.load(a), alu.load(b)
    alu.add(va, vb)
    st_ = alu.chip.stats
    assert st_.latency_ns > 0 and st_.energy_j > 0 and st_.n_acts > 0
    assert alu.op_counts.get("maj3", 0) > 0


@given(seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_property_add_commutes(seed):
    alu = fresh_alu(8, max_n_rg=8)
    a, b = rand(8, seed), rand(8, seed + 1000)
    va, vb = alu.load(a), alu.load(b)
    r1 = alu.store(alu.add(va, vb))
    r2 = alu.store(alu.add(vb, va))
    np.testing.assert_array_equal(r1, r2)


def test_chained_staging_in_place_input():
    """Chained-staging (§Perf P4): the previous APA leaves its result in all
    region rows; the next op in the same region skips that input's staging
    — bit-exact, with measurably fewer command sequences."""
    chip = PulsarChip(GEOM, MFR_H, seed=0)
    chip.decoder = chip.decoder.__class__(GEOM, MFR_H, None)
    x = PulsarExecutor(chip, 0, 0)
    rng = np.random.default_rng(5)
    rows = {}
    for i, name in enumerate("abcde"):
        r = 200 + i
        chip.write_row(0, r, rng.integers(0, 2**32, GEOM.words_per_row,
                                          dtype=np.uint64).astype(np.uint32))
        rows[name] = r
    # op1: t = MAJ3(a, b, c)
    x.maj(240, [rows["a"], rows["b"], rows["c"]], n_rg=8)
    seq_before = chip.stats.n_ops
    # op2 (chained): u = MAJ3(t, d, e) with t resident in the region.
    rep = x.maj(241, [240, rows["d"], rows["e"]], n_rg=8, in_place_input=0)
    chained_seqs = chip.stats.n_ops - seq_before
    want = majority_bits(np.stack([chip.peek(0, 240), chip.peek(0, rows["d"]),
                                   chip.peek(0, rows["e"])]), 2)
    np.testing.assert_array_equal(chip.peek(0, 241), want)
    # Unchained equivalent for comparison.
    seq_before = chip.stats.n_ops
    x.maj(242, [240, rows["d"], rows["e"]], n_rg=8)
    unchained_seqs = chip.stats.n_ops - seq_before
    np.testing.assert_array_equal(chip.peek(0, 242), want)
    assert chained_seqs < unchained_seqs


def test_chained_cost_model_cheaper():
    from repro.core.cost_model import CostModel
    cm = CostModel()
    base = cm.full_adder(5, 8, 4)
    chained = cm.full_adder(5, 8, 4, chained=True)
    assert chained.latency_ns < base.latency_ns
    assert chained.n_sequences < base.n_sequences
