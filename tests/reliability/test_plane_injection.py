"""ReliabilityPlane: config validation, fault injection mechanics, the
vote/retry/escalate loop, and the end-to-end acceptance scenario — a real
application kernel stays bit-exact under injected variation."""

import numpy as np
import pytest

from repro import pum
from repro.core.profiles import PROFILES
from repro.core.realworld import bitweaving_scan
from repro.reliability import (FaultInjector, ReliabilityConfig,
                               ReliabilityPlane, calibrate, majority_vote)
from repro.reliability.plane import ReliabilityMap

PV_M = PROFILES["M"].process_variation


def tiny_map(**kw):
    args = dict(banks=4, n_subarrays=4, n_columns=64, n_patterns=4, seed=13)
    args.update(kw)
    return calibrate("M", **args)


# --------------------------------------------------------------------- #
# Config / plane construction


def test_reliability_config_validation():
    with pytest.raises(ValueError):
        ReliabilityConfig(votes=2)
    with pytest.raises(ValueError):
        ReliabilityConfig(votes=0)
    with pytest.raises(ValueError):
        ReliabilityConfig(max_attempts=0)
    with pytest.raises(ValueError):
        ReliabilityConfig(min_margin=0)
    with pytest.raises(ValueError):
        ReliabilityConfig(target_success=0.0)
    with pytest.raises(ValueError):
        ReliabilityConfig(flip_scale=-1.0)


def test_plane_requires_matching_map():
    m = tiny_map()
    with pytest.raises(ValueError, match="manufacturer"):
        ReliabilityPlane(ReliabilityConfig(map=m), mfr="H", counters=None)
    with pytest.raises(ValueError, match="must be a ReliabilityMap"):
        ReliabilityPlane(ReliabilityConfig(), mfr="M", counters=None)
    with pytest.raises(TypeError):
        ReliabilityPlane(object(), mfr="M", counters=None)


def test_inject_requires_fused_device():
    m = tiny_map()
    cfg = ReliabilityConfig(map=m, inject=True)
    with pytest.raises(ValueError, match="fuse"):
        pum.Device(mfr="M", banks=4, fuse=False, reliability=cfg)


def test_plane_loads_map_from_path(tmp_path):
    m = tiny_map()
    p = tmp_path / "m.npz"
    m.save(p)
    plane = ReliabilityPlane(ReliabilityConfig(map=str(p)), mfr="M",
                             counters=None)
    np.testing.assert_array_equal(plane.map.flip_p, m.flip_p)


# --------------------------------------------------------------------- #
# Vote + injector mechanics


def test_majority_vote_hand_built():
    reps = np.array([[0b0110, 0b0010, 0b0010]], np.uint64).reshape(3, 1)
    maj, corrected, weak = majority_vote(reps, width=4, min_margin=2)
    # Bit 2 disagrees 1-vs-2: margin |2*1-3| = 1 < 2 -> weak (and counted
    # as corrected, since a minority was outvoted).
    assert maj[0] == 0b0010
    assert corrected == 1 and weak == 1
    maj5, c5, w5 = majority_vote(np.array([[6, 2, 2, 2, 2]], np.uint64
                                          ).reshape(5, 1), 4, 2)
    assert maj5[0] == 2 and c5 == 1 and w5 == 0  # margin 3 at R=5: strong


def test_majority_vote_unanimous():
    reps = np.full((3, 8), 0xAB, np.uint64)
    maj, corrected, weak = majority_vote(reps, width=8, min_margin=2)
    np.testing.assert_array_equal(maj, reps[0])
    assert corrected == 0 and weak == 0


def test_fault_injector_lane_tiling_and_determinism():
    m = tiny_map(process_variation=PV_M * 4)
    idx = m.config_index(3, 4)
    inj = FaultInjector(m, idx, width=16, n_ops=2, steer=True)
    n = m.n_columns * 3 + 7  # spans four homes, last partial
    p = inj.lane_probs(n)
    assert p.shape == (n,) and (p >= 0).all() and (p <= 1).all()
    homes = m.home_order(idx)
    b, s = homes[1]  # second chunk maps to the second-best home
    col = m.n_columns + 5
    expect = 1.0 - (1.0 - float(m.flip_p[b, s, idx, 5])) ** 2
    assert p[col] == pytest.approx(expect, rel=1e-6)
    # Unsteered tiling follows natural (bank, subarray) order instead.
    nat = FaultInjector(m, idx, width=16, n_ops=2, steer=False)
    pn = nat.lane_probs(n)
    expect0 = 1.0 - (1.0 - float(m.flip_p[0, 1, idx, 5])) ** 2
    assert pn[col] == pytest.approx(expect0, rel=1e-6)
    # Seeded masks are reproducible, and bits stay inside the word.
    ones = np.full(n, 1.0)
    mask1, k1 = inj.sample_mask(np.random.default_rng([1, 2]), ones,
                                np.dtype(np.uint64))
    mask2, k2 = inj.sample_mask(np.random.default_rng([1, 2]), ones,
                                np.dtype(np.uint64))
    np.testing.assert_array_equal(mask1, mask2)
    assert k1 == k2 == n
    assert (mask1 < (1 << 16)).all()
    assert (np.bitwise_count(mask1) == 1).all()


def test_flip_scale_saturates_probability():
    """Scaling pushes every fallible column to certainty; perfectly stable
    columns (flip_p exactly 0) stay clean at any scale."""
    m = tiny_map(process_variation=PV_M * 4)
    idx = m.config_index(3, 4)
    n = m.n_columns * m.n_banks * m.n_subarrays
    base = FaultInjector(m, idx, width=16).lane_probs(n)
    p = FaultInjector(m, idx, width=16, flip_scale=1e16).lane_probs(n)
    assert (p >= base).all()
    assert (p[base > 1e-12] == 1.0).all() and (p == 1.0).any()


# --------------------------------------------------------------------- #
# Devices: planning only (inject=False) is bit-exact and count-free


def fused_device(**kw):
    args = dict(mfr="M", width=16, banks=4, fuse=True, seed=7)
    args.update(kw)
    return pum.Device(**args)


def run_kernel(dev, a, b):
    x = dev.asarray(a)
    y = dev.asarray(b)
    out = (x & y) ^ (x + y)
    lt = x < y
    dev.flush()
    return out.to_numpy(), lt.to_numpy()


def test_plan_only_is_bit_exact_and_silent():
    rng = np.random.default_rng(3)
    a = rng.integers(0, 1 << 16, 256, np.uint64)
    b = rng.integers(0, 1 << 16, 256, np.uint64)
    plain = fused_device()
    want = run_kernel(plain, a, b)
    dev = fused_device()
    dev.calibrate(n_subarrays=4, n_columns=64, n_patterns=4,
                  process_variation=PV_M * 3)
    assert dev.reliability is not None and not dev.reliability.inject
    got = run_kernel(dev, a, b)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])
    counters = dev.counters.as_dict()["counters"]
    assert not any(k.startswith("reliability.") for k in counters)


def test_disabled_plane_leaves_engine_untouched():
    dev = fused_device()
    assert dev.reliability is None
    assert dev.engine.reliability is None


def test_variation_aware_planning_prefers_reliable_config():
    """At elevated variation the calibrated plane must not pick a config
    whose calibrated success is below an achievable target."""
    dev = fused_device()
    dev.calibrate(n_subarrays=4, n_columns=64, n_patterns=4,
                  process_variation=PV_M * 3, target_success=0.95)
    rel = dev.reliability
    m, n, sr, _ = dev.engine._cfg_for("and2", dev.width, None)
    achievable = max(rel.plan_success(mm, nn) or 0.0
                     for mm, nn in rel.map.configs)
    if achievable >= 0.95:
        assert sr >= 0.95
    # The chosen config's rate is the calibrated one when profiled.
    if rel.map.config_index(m, n) is not None:
        assert sr == rel.plan_success(m, n)


def test_bank_order_is_timing_symmetric():
    """Ranked bank placement reorders WHICH banks serve the batch, never
    the command timing — calibrated and plain devices charge identically."""
    plain = fused_device(controller="auto")
    dev = fused_device(controller="auto")
    dev.calibrate(n_subarrays=4, n_columns=64, n_patterns=4,
                  process_variation=PV_M * 3)
    order = dev.reliability.bank_order(4)
    assert sorted(order) == list(range(4))
    assert dev.engine._batch_for("and2", 3, 8) == \
        plain.engine._batch_for("and2", 3, 8)


def test_controller_rejects_bad_bank_order():
    dev = fused_device(controller="auto")
    ctrl = dev.engine.controller
    from repro.core import commands as cmds
    t = dev.engine.cost.t
    unit = [cmds.prog_write_row(0, 0, dev.engine.cost._wr_bursts, t)]
    with pytest.raises(ValueError):
        ctrl.batch_cost(unit, 2, bank_order=(0, 0))
    with pytest.raises(ValueError):
        ctrl.batch_cost(unit, 2, bank_order=(0, 99))


# --------------------------------------------------------------------- #
# Injection: vote correction, retries, escalation, oracle fallback


def calibrated_injecting_device(*, flip_scale, pv_scale=5.0, steer=False,
                                **policy):
    """A weak-lot chip (elevated variation, scaled flip probabilities) with
    steering OFF so the lanes actually land on fallible columns — with
    steering on, this workload's lanes fit entirely in strong subarrays and
    nothing injects (see test_steering_avoids_weak_columns)."""
    dev = fused_device()
    dev.calibrate(inject=True, n_subarrays=4, n_columns=64, n_patterns=4,
                  process_variation=PV_M * pv_scale, flip_scale=flip_scale,
                  steer=steer, **policy)
    return dev


def rel_counters(dev):
    c = dev.counters.as_dict()["counters"]
    return {k.split(".", 1)[1]: v for k, v in c.items()
            if k.startswith("reliability.")}


def test_injection_corrects_and_stays_bit_exact():
    rng = np.random.default_rng(11)
    a = rng.integers(0, 1 << 16, 512, np.uint64)
    b = rng.integers(0, 1 << 16, 512, np.uint64)
    want = run_kernel(fused_device(), a, b)
    dev = calibrated_injecting_device(flip_scale=40.0)
    got = run_kernel(dev, a, b)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])
    c = rel_counters(dev)
    assert c["flushes"] >= 1
    assert c["injected_bits"] > 0
    assert c["corrected_bits"] > 0
    assert c.get("oracle_fallbacks", 0) == 0


def test_retry_escalation_bounded_and_counted():
    rng = np.random.default_rng(12)
    a = rng.integers(0, 1 << 16, 512, np.uint64)
    b = rng.integers(0, 1 << 16, 512, np.uint64)
    dev = calibrated_injecting_device(flip_scale=40.0, max_attempts=3)
    want = run_kernel(fused_device(), a, b)
    got = run_kernel(dev, a, b)
    np.testing.assert_array_equal(got[0], want[0])
    c = rel_counters(dev)
    # With votes=3 and min_margin=2 ANY injected flip forces a retry; the
    # retry escalates replication and votes and must stay within bounds.
    assert 1 <= c["retries"] <= (3 - 1) * c["flushes"]
    assert c["weak_bits"] > 0
    assert c.get("oracle_fallbacks", 0) == 0


def test_injection_runs_are_deterministic():
    rng = np.random.default_rng(13)
    a = rng.integers(0, 1 << 16, 256, np.uint64)
    b = rng.integers(0, 1 << 16, 256, np.uint64)
    runs = []
    for _ in range(2):
        dev = calibrated_injecting_device(flip_scale=40.0)
        out = run_kernel(dev, a, b)
        runs.append((out, rel_counters(dev)))
    np.testing.assert_array_equal(runs[0][0][0], runs[1][0][0])
    assert runs[0][1] == runs[1][1]


def test_oracle_fallback_is_last_resort_and_bit_exact():
    rng = np.random.default_rng(14)
    a = rng.integers(0, 1 << 16, 128, np.uint64)
    b = rng.integers(0, 1 << 16, 128, np.uint64)
    want = run_kernel(fused_device(), a, b)
    # A lot so weak that every vote attempt has sub-margin bits: the loop
    # exhausts max_attempts and degrades to the eager oracle — bit-exact.
    dev = calibrated_injecting_device(flip_scale=10.0, pv_scale=6.0,
                                      max_attempts=2)
    got = run_kernel(dev, a, b)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])
    c = rel_counters(dev)
    assert c["oracle_fallbacks"] >= 1
    assert c["retries"] == (2 - 1) * c["flushes"]


def test_acceptance_bitweaving_scan_under_injection():
    """ISSUE acceptance: a realworld app kernel completes bit-exactly on an
    injecting device via vote correction + bounded retries. The kernel
    itself asserts PuM result == CPU oracle."""
    rng = np.random.default_rng(2026)
    column = rng.integers(0, 1 << 16, 1024, np.uint64)
    dev = calibrated_injecting_device(flip_scale=40.0)
    got, _, _ = bitweaving_scan(dev, column, 200, 40000)
    assert got == int(((column >= 200) & (column <= 40000)).sum())
    c = rel_counters(dev)
    assert c["injected_bits"] > 0
    assert c["corrected_bits"] > 0
    assert c.get("oracle_fallbacks", 0) == 0
    assert c.get("retries", 0) <= 2 * c["flushes"]


def test_steering_avoids_weak_columns():
    """Tentpole part 3: with map-guided steering the same workload on the
    same weak chip sees strictly fewer injected faults, because its lanes
    are placed on the strongest (bank, subarray) homes first."""
    rng = np.random.default_rng(14)
    a = rng.integers(0, 1 << 16, 128, np.uint64)
    b = rng.integers(0, 1 << 16, 128, np.uint64)
    injected = {}
    for steer in (True, False):
        dev = calibrated_injecting_device(flip_scale=40.0, steer=steer)
        run_kernel(dev, a, b)
        injected[steer] = rel_counters(dev).get("injected_bits", 0)
    assert injected[True] < injected[False]


def test_flush_span_reports_attempts(tmp_path):
    rng = np.random.default_rng(15)
    a = rng.integers(0, 1 << 16, 256, np.uint64)
    b = rng.integers(0, 1 << 16, 256, np.uint64)
    dev = calibrated_injecting_device(flip_scale=40.0)
    with pum.profile(path=str(tmp_path / "trace.json"), device=dev):
        run_kernel(dev, a, b)
    import json
    events = json.loads(
        (tmp_path / "trace.json").read_text())["traceEvents"]
    dispatch = [e for e in events if e.get("name") == "flush.dispatch"]
    assert dispatch and all("attempts" in e["args"] for e in dispatch)


# --------------------------------------------------------------------- #
# Device.calibrate / as_device plumbing


def test_device_calibrate_save_and_reuse(tmp_path):
    p = tmp_path / "chip.npz"
    dev = fused_device()
    rmap = dev.calibrate(attach=False, n_subarrays=4, n_columns=64,
                         n_patterns=4, save=p)
    assert dev.reliability is None  # attach=False leaves the device alone
    dev2 = pum.Device(mfr="M", width=16, banks=4, fuse=True, seed=7,
                      reliability=pum.ReliabilityConfig(map=str(p)))
    np.testing.assert_array_equal(dev2.reliability.map.flip_p, rmap.flip_p)


def test_calibrate_inject_on_eager_device_raises():
    dev = pum.Device(mfr="M", width=16, banks=4, fuse=False)
    with pytest.raises(ValueError, match="fuse"):
        dev.calibrate(inject=True, n_subarrays=4, n_columns=64,
                      n_patterns=4)


def test_as_device_carries_reliability():
    dev = fused_device()
    dev.calibrate(n_subarrays=4, n_columns=64, n_patterns=4)
    again = pum.as_device(dev.engine)
    assert again.config.reliability is dev.config.reliability
