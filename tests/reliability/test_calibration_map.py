"""Calibration pass + ReliabilityMap: determinism, spatial structure,
persistence, and the planning queries the engine consumes."""

import numpy as np
import pytest

from repro.core import analog
from repro.core.profiles import PROFILES
from repro.core.replication import plan as replication_plan
from repro.reliability import P_STABLE, ReliabilityMap, calibrate

PV_M = PROFILES["M"].process_variation


def small_map(**kw):
    args = dict(mfr="M", banks=4, n_subarrays=4, n_columns=64, n_patterns=4,
                seed=13)
    args.update(kw)
    return calibrate(args.pop("mfr"), **args)


def test_calibrate_is_deterministic():
    a = small_map()
    b = small_map()
    assert a.configs == b.configs
    np.testing.assert_array_equal(a.success, b.success)
    np.testing.assert_array_equal(a.flip_p, b.flip_p)
    np.testing.assert_array_equal(a.bank_scale, b.bank_scale)


def test_calibrate_seed_changes_map():
    a = small_map()
    b = small_map(seed=14)
    assert not np.array_equal(a.flip_p, b.flip_p)


def test_configs_respect_manufacturer_caps():
    m = small_map()  # Mfr M: max 16 rows, MAJ fan-in <= 7
    assert all(n <= 16 for _, n in m.configs)
    h = small_map(mfr="H", banks=2)  # Mfr H: 32 rows
    assert (3, 32) in h.configs
    assert all(mi <= PROFILES["H"].max_maj_fan_in for mi, _ in h.configs)


def test_replication_lifts_success():
    """Fig 11: more input replication (larger N_RG at fixed fan-in) must
    not lower the chip-wide success rate at elevated variation."""
    m = small_map(process_variation=PV_M * 3)
    s8 = m.mean_success(3, 8)
    s16 = m.mean_success(3, 16)
    assert s16 >= s8
    assert m.mean_success(5, 16) >= m.mean_success(5, 8)


def test_w_shaped_spatial_profile():
    """charact.spatial_pv_multiplier peaks at subarrays 0,3,4,7 (of 8) —
    those subarrays see more variation, so calibrated success is lower."""
    m = calibrate("M", banks=4, n_subarrays=8, n_columns=64, n_patterns=4,
                  seed=3, process_variation=PV_M * 3)
    per_sub = m.success.mean(axis=(0, 2))  # [n_subarrays]
    weak = per_sub[[0, 3, 4, 7]].mean()
    strong = per_sub[[1, 2, 5, 6]].mean()
    assert weak < strong


def test_save_load_roundtrip(tmp_path):
    m = small_map()
    path = tmp_path / "chip.npz"
    m.save(path)
    back = ReliabilityMap.load(path)
    assert back.mfr == m.mfr and back.seed == m.seed
    assert back.configs == m.configs
    np.testing.assert_array_equal(back.success, m.success)
    np.testing.assert_array_equal(back.flip_p, m.flip_p)
    np.testing.assert_array_equal(back.bank_scale, m.bank_scale)


def test_config_index_and_nearest():
    m = small_map()
    i = m.config_index(3, 16)
    assert m.configs[i] == (3, 16)
    assert m.config_index(3, 12) is None
    assert m.configs[m.nearest_config(3, 12)] == (3, 16)  # ties go larger
    assert m.configs[m.nearest_config(9, 16)][1] == 16


def test_escalation_ladder_saturates():
    m = small_map()
    base = m.config_index(3, 4)
    ns = [m.configs[m.escalated_config(base, k)][1] for k in range(5)]
    assert ns == sorted(ns)            # monotone toward more rows
    assert ns[0] == 4 and ns[-1] == 16  # starts at base, saturates at cap
    top = m.config_index(3, 16)
    assert m.escalated_config(top, 1) == top


def test_best_plan_meets_target_or_most_reliable():
    m = small_map(process_variation=PV_M * 3)
    rp, sr = m.best_plan(3, target_success=0.5)
    assert rp == replication_plan(3, rp.n_rg)
    assert sr >= 0.5
    # Impossible target: falls back to the most reliable profiled config.
    rp2, sr2 = m.best_plan(3, target_success=1.1)
    cands = [m.mean_success(3, n) for mm, n in m.configs if mm == 3]
    assert sr2 == max(cands)
    with pytest.raises(ValueError):
        m.best_plan(9, target_success=0.9)


def test_home_and_bank_order_are_ranked_permutations():
    m = small_map(process_variation=PV_M * 3)
    i = m.config_index(3, 4)
    homes = m.home_order(i)
    assert sorted(homes) == [(b, s) for b in range(4) for s in range(4)]
    sr = [m.success[b, s, i] for b, s in homes]
    assert sr == sorted(sr, reverse=True)
    order = m.bank_order()
    assert sorted(order) == list(range(4))
    means = m.success.mean(axis=(1, 2))
    assert [means[b] for b in order] == sorted(means, reverse=True)


def test_column_flip_probs_matches_success_rate():
    """The per-column characterization shares the Monte-Carlo margins with
    maj_success_rate: identical rate and stable mask for identical args."""
    import jax

    key = jax.random.PRNGKey(42)
    prof = PROFILES["M"]
    kw = dict(m_inputs=3, copies=5, n_neutral=1, n_bitlines=256,
              n_patterns=8, process_variation=PV_M * 3)
    rate, stable = analog.maj_success_rate(key, prof, **kw)
    cp = analog.column_flip_probs(key, prof, **kw)
    assert cp.rate == rate
    np.testing.assert_array_equal(cp.stable, np.asarray(stable))
    # Stability threshold consistency: stable columns sit below P_STABLE.
    assert (cp.flip_p[cp.stable] <= P_STABLE * (1 + 1e-6)).all()
    assert cp.flip_p.min() >= 0.0 and cp.flip_p.max() <= 1.0


def test_weak_column_frac_complements_success():
    m = small_map(process_variation=PV_M * 3)
    for i in range(len(m.configs)):
        assert m.weak_column_frac(i) == pytest.approx(
            1.0 - m.success[:, :, i].mean(), abs=1e-6)
