"""HeartbeatMonitor / Supervisor / elastic-mesh planning."""

import pytest

from repro.distributed.fault_tolerance import (HeartbeatMonitor, Supervisor,
                                               WorkerState, plan_elastic_mesh)


def test_dead_workers_timeout_path():
    mon = HeartbeatMonitor(timeout_s=5.0)
    mon.workers["w0"] = WorkerState(last_beat=0.0)
    mon.workers["w1"] = WorkerState(last_beat=8.0)
    assert mon.dead_workers(now=10.0) == ["w0"]
    assert mon.dead_workers(now=20.0) == ["w0", "w1"]


def test_dead_workers_epoch_zero_regression():
    """now=0.0 is a legitimate replay epoch and must not be coerced to the
    wall clock (the old `now or time.time()` truthiness bug would flag a
    worker whose last beat was at t=100 as alive-forever — or dead —
    depending on the real clock)."""
    mon = HeartbeatMonitor(timeout_s=5.0)
    mon.workers["w0"] = WorkerState(last_beat=100.0)
    assert mon.dead_workers(now=0.0) == []
    mon.workers["w1"] = WorkerState(last_beat=-10.0)
    assert mon.dead_workers(now=0.0) == ["w1"]


def test_beat_revives_and_tracks_step_times():
    mon = HeartbeatMonitor(timeout_s=5.0, window=3)
    mon.beat("w0", step_time_s=1.0)
    assert mon.dead_workers() == []
    for t in (2.0, 3.0, 4.0):
        mon.beat("w0", step_time_s=t)
    # Sliding window keeps only the newest `window` samples.
    assert mon.workers["w0"].step_times == [2.0, 3.0, 4.0]


def test_stragglers_need_three_reporting_workers():
    mon = HeartbeatMonitor()
    mon.beat("w0", step_time_s=1.0)
    mon.beat("w1", step_time_s=50.0)
    assert mon.stragglers() == []  # too few workers for a robust median


def test_stragglers_flagged_against_median():
    mon = HeartbeatMonitor(straggler_factor=2.0)
    for w in ("w0", "w1", "w2"):
        for t in (1.0, 1.1, 0.9):
            mon.beat(w, step_time_s=t)
    assert mon.stragglers() == []
    for t in (4.0, 4.0, 4.0):
        mon.beat("w2", step_time_s=t)
    assert mon.stragglers() == ["w2"]


def test_stragglers_ignore_workers_without_step_times():
    """A worker that only heartbeats (empty step-time window) must not
    poison the median with a divide-by-zero or a phantom zero mean."""
    mon = HeartbeatMonitor(straggler_factor=2.0)
    mon.beat("idle")  # beats, never reports a step time
    for w in ("w0", "w1", "w2"):
        mon.beat(w, step_time_s=1.0)
    mon.beat("w2", step_time_s=9.0)
    assert mon.stragglers() == ["w2"]


def test_plan_elastic_mesh_shrinks_data_axis():
    assert plan_elastic_mesh(16, 4) == (4, 4)
    assert plan_elastic_mesh(15, 4) == (3, 4)  # lost a node: DP shrinks
    assert plan_elastic_mesh(4, 4) == (1, 4)
    with pytest.raises(RuntimeError):
        plan_elastic_mesh(3, 4)  # TP degree no longer fits


def test_supervisor_clean_exit():
    sup = Supervisor(["-c", "raise SystemExit(0)"], max_restarts=2)
    assert sup.run() == 0
    assert sup.restarts == 0


def test_supervisor_restarts_then_gives_up():
    sup = Supervisor(["-c", "raise SystemExit(7)"], max_restarts=2)
    assert sup.run() == 7
    # Initial attempt + max_restarts relaunches, all failed.
    assert sup.restarts == sup.max_restarts + 1


def test_supervisor_recovers_after_transient_failure(tmp_path):
    """First launch crashes, relaunch (simulated restored checkpoint via a
    marker file) succeeds: the supervisor reports success."""
    marker = tmp_path / "ckpt"
    code = (f"import pathlib,sys; p=pathlib.Path({str(marker)!r});\n"
            "sys.exit(0) if p.exists() else (p.touch(), sys.exit(1))")
    sup = Supervisor(["-c", code], max_restarts=3)
    assert sup.run() == 0
    assert sup.restarts == 1
