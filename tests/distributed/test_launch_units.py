"""Launch-layer unit tests: input specs, roofline math, collective parsing
(no device mesh needed — pure functions)."""

import jax
import numpy as np
import pytest

from repro.config.base import ARCH_IDS, LM_SHAPES, get_config, shapes_for
from repro.launch.dryrun import parse_collective_bytes
from repro.launch.roofline import (analytic_bytes_per_chip, analytic_flops,
                                   analyze_record, loop_trip, model_flops)
from repro.launch.steps import input_specs


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_exist_for_all_cells(arch):
    """Deliverable e.2: ShapeDtypeStruct stand-ins for every model input,
    weak-type-correct, no device allocation."""
    for sname, shape in shapes_for(arch).items():
        spec = input_specs(arch, shape)
        assert "params" in spec
        leaves = jax.tree.leaves(spec)
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
        if shape.kind == "train":
            assert spec["batch"]["tokens"].dtype == np.int32
        if shape.kind == "decode":
            assert spec["token"].shape == (shape.global_batch,)
            assert len(jax.tree.leaves(spec["caches"])) > 0


def test_shape_grid_skips():
    """long_500k only for sub-quadratic archs (DESIGN.md §5)."""
    assert "long_500k" in shapes_for("hymba-1.5b")
    assert "long_500k" in shapes_for("mamba2-130m")
    for arch in ("qwen2.5-32b", "deepseek-v2-236b", "llava-next-mistral-7b"):
        assert "long_500k" not in shapes_for(arch)
    # 32 total runnable cells: 10 archs x (train+prefill+decode) + 2 long.
    assert sum(len(shapes_for(a)) for a in ARCH_IDS) == 32


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "deepseek-v2-236b",
                                  "mamba2-130m", "hymba-1.5b"])
def test_analytic_flops_sane(arch):
    """Analytic FLOPs >= classic 6ND/2ND estimators (they add attention
    context + MoE capacity + remat), within a sane factor."""
    for sname in shapes_for(arch):
        af = analytic_flops(arch, sname)
        mf = model_flops(arch, sname)
        assert af > 0 and mf > 0
        assert 0.5 < af / mf < 20, f"{arch}/{sname}: {af/mf}"


def test_analytic_bytes_positive():
    for arch in ("qwen2.5-32b", "moonshot-v1-16b-a3b"):
        for sname in shapes_for(arch):
            assert analytic_bytes_per_chip(arch, sname, 256) > 0


def test_loop_trip_counts():
    assert loop_trip("qwen2.5-32b", "train_4k") == 64
    assert loop_trip("deepseek-v2-236b", "train_4k") == 59  # 1 dense layer
    assert loop_trip("hymba-1.5b", "prefill_32k") == 32     # kv-block scan
    assert loop_trip("qwen3-1.7b", "decode_32k") == 28      # scanned decode


def test_parse_collective_bytes_regions():
    hlo = """
ENTRY %main (p0: f32[8,128]) -> f32[8,128] {
  %ag = f32[8,128]{1,0} all-gather(%p0), replica_groups={}
  %t = (f32[8,128]) tuple(%ag)
}
%region_0.1 (arg: (s32[], f32[4,64])) -> (s32[], f32[4,64]) {
  %ar = f32[4,64]{1,0} all-reduce(%x), to_apply=%sum
}
"""
    out = parse_collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 4
    assert out["all-reduce"] == 4 * 64 * 4
    # AR weighted x2, and it sits in a loop region.
    assert out["region_weighted"] == 2 * 4 * 64 * 4
    assert out["total_weighted"] == 8 * 128 * 4 + 2 * 4 * 64 * 4


def test_analyze_record_dominant_terms():
    rec = {"arch": "qwen3-1.7b", "shape": "train_4k", "mesh": "16x16",
           "n_devices": 256, "flops": 1e12, "bytes_accessed": 1e9,
           "collectives": {"total_weighted": 1e9, "region_weighted": 5e8},
           "status": "ok"}
    p = analyze_record(rec)
    assert p.dominant in ("compute", "memory", "collective")
    assert p.compute_s > 0 and p.collective_s > 0
    assert 0 < p.roofline_fraction <= 1.5
    # region bytes get multiplied by the layer trip count (28).
    assert p.collective_s * 50e9 == pytest.approx(5e8 + 5e8 * 28)
