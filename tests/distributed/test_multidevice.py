"""Multi-device tests: run in a subprocess with 8 forced host devices so the
main test process keeps its single CPU device (the dry-run-only flag rule).

Checks:
  * sharded train step == single-device train step (bitwise semantics of
    DP+TP+GSPMD don't change the math),
  * decode cell lowers/compiles on a small mesh with the production
    sharding rules (smoke-scale dry-run),
  * gradient compression composes with the sharded step.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def run_sub(body: str) -> str:
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        assert len(jax.devices()) == 8
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, f"STDOUT:{proc.stdout}\nSTDERR:{proc.stderr}"
    return proc.stdout


def test_sharded_train_step_matches_single_device():
    out = run_sub("""
        from repro.config.base import TrainConfig, get_smoke_config
        from repro.distributed.sharding import batch_shardings, param_shardings
        from repro.train.trainer import build_train_step, init_train_state
        from repro.data.pipeline import DataConfig, SyntheticLM

        cfg = get_smoke_config("qwen3-1.7b")
        tcfg = TrainConfig(z_loss=0.0)
        params, opt = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
        batch = jax.tree.map(jnp.asarray, SyntheticLM(DataConfig(
            seq_len=32, global_batch=8, vocab_size=cfg.vocab_size)).batch(0))
        step = build_train_step(cfg, tcfg)
        # single-device reference
        p1, _, m1 = jax.jit(step)(params, opt, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        p_sh = param_shardings(cfg, mesh, jax.eval_shape(lambda: params))
        o_sh = {"mu": param_shardings(cfg, mesh, jax.eval_shape(lambda: opt["mu"])),
                "nu": param_shardings(cfg, mesh, jax.eval_shape(lambda: opt["nu"])),
                "step": NamedSharding(mesh, P())}
        b_sh = batch_shardings(mesh, jax.eval_shape(lambda: batch))
        params_s = jax.device_put(params, p_sh)
        opt_s = jax.device_put({"mu": opt["mu"], "nu": opt["nu"],
                                "step": opt["step"]},
                               {"mu": o_sh["mu"], "nu": o_sh["nu"],
                                "step": o_sh["step"]})
        batch_s = jax.device_put(batch, b_sh)
        with mesh:
            p8, _, m8 = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh))(
                params_s, opt_s, batch_s)
        # bf16 reduction-order differences across shardings: ~1e-4 rel.
        np.testing.assert_allclose(float(m1["loss"]), float(m8["loss"]),
                                   rtol=1e-3)
        a = np.asarray(p1["embed"]["embedding"])
        b = np.asarray(jax.device_get(p8["embed"]["embedding"]))
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=1e-4)
        print("SHARDED_MATCH_OK", float(m1["loss"]))
    """)
    assert "SHARDED_MATCH_OK" in out


def test_smoke_cell_lowers_on_mesh():
    out = run_sub("""
        import dataclasses
        from repro.config.base import LM_SHAPES, ShapeConfig, get_smoke_config
        import repro.config.base as base
        import repro.launch.steps as steps
        smoke = get_smoke_config("qwen3-1.7b")
        # patch the registry so build_cell resolves to the smoke config
        steps.get_config = lambda arch: smoke
        shape = ShapeConfig("train_tiny", "train", 32, 8)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with mesh:
            cell = steps.build_cell("qwen3-1.7b", shape, mesh)
            compiled = cell.lower().compile()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):  # older jax returns [dict]
                cost = cost[0]
            assert cost.get("flops", 0) > 0
        shape_d = ShapeConfig("decode_tiny", "decode", 64, 8)
        with mesh:
            cell = steps.build_cell("qwen3-1.7b", shape_d, mesh)
            compiled = cell.lower().compile()
        print("CELL_LOWER_OK")
    """)
    assert "CELL_LOWER_OK" in out


def test_moe_ep_sharding_correct():
    out = run_sub("""
        from repro.config.base import get_smoke_config
        from repro.models import moe as moe_mod
        cfg = get_smoke_config("moonshot-v1-16b-a3b")
        p = moe_mod.moe_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
        y1, aux1 = moe_mod.moe_ffn(cfg, p, x)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        xb = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
        pb = jax.device_put(p, NamedSharding(mesh, P()))
        with mesh:
            y8, aux8 = jax.jit(lambda pp, xx: moe_mod.moe_ffn(cfg, pp, xx))(
                pb, xb)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y8),
                                   rtol=2e-3, atol=2e-5)
        print("MOE_EP_OK")
    """)
    assert "MOE_EP_OK" in out
