"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step on CPU, asserting output shapes + no NaNs (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import ARCH_IDS, get_config, get_smoke_config
from repro.models.model import (decode_step, forward, init_cache, init_params,
                                loss_fn, prefill)

B, S = 2, 16


def make_batch(cfg, key, batch=B, seq=S):
    ks = jax.random.split(key, 3)
    batch_d = {"tokens": jax.random.randint(ks[0], (batch, seq + 1), 0,
                                            cfg.vocab_size)}
    if cfg.encoder_decoder:
        batch_d["frames"] = jax.random.normal(
            ks[1], (batch, seq, cfg.d_model)) * 0.02
    if cfg.frontend == "vision":
        batch_d["patches"] = jax.random.normal(
            ks[2], (batch, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
    return batch_d


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_shapes(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    inner = dict(batch)
    inner["tokens"] = batch["tokens"][:, :-1]
    logits, _ = jax.jit(lambda p, b: forward(cfg, p, b))(params, inner)
    t_expected = S
    if cfg.frontend == "vision":
        t_expected += cfg.n_frontend_tokens
    assert logits.shape == (B, t_expected, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/inf logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """One SGD step: loss finite, grads finite, params change."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    @jax.jit
    def step(p):
        (loss, metrics), grads = jax.value_and_grad(
            lambda q: loss_fn(cfg, q, batch), has_aux=True)(p)
        new_p = jax.tree.map(lambda a, g: a - 1e-3 * g, p, grads)
        return loss, new_p, grads

    loss, new_params, grads = step(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    emb0 = params["embed"]["embedding"]
    emb1 = new_params["embed"]["embedding"]
    assert not np.allclose(np.asarray(emb0), np.asarray(emb1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    prompt = dict(batch)
    prompt["tokens"] = batch["tokens"][:, :S]
    max_len = S + 4
    if cfg.frontend == "vision":
        max_len += cfg.n_frontend_tokens
    logits, caches, memory = jax.jit(
        lambda p, b: prefill(cfg, p, b, max_len))(params, prompt)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    # Two decode steps.
    tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)
    pos0 = S + (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    dec = jax.jit(lambda p, c, t, q: decode_step(cfg, p, c, t, q,
                                                 memory=memory))
    for i in range(2):
        logits2, caches = dec(params, caches, tok,
                              jnp.full((B,), pos0 + i, jnp.int32))
        assert bool(jnp.isfinite(logits2).all())
        tok = jnp.argmax(logits2[:, :cfg.vocab_size], -1)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-130m",
                                  "qwen3-1.7b"])
def test_decode_consistent_with_forward(arch):
    """Prefill+decode logits == full-forward logits at the same position."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, S), 0,
                                cfg.vocab_size)
    # Full forward over S tokens: logits at position S-1 predict token S.
    logits_full, _ = forward(cfg, params, {"tokens": tokens})
    want = logits_full[:, -1]
    got, _, _ = prefill(cfg, params, {"tokens": tokens}, S + 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_loads_and_counts(arch):
    """Full (production) configs build shape-only and report param counts
    in the right ballpark (no allocation — eval_shape)."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "hymba-1.5b": (1.0e9, 2.8e9),
        "qwen1.5-0.5b": (0.4e9, 0.9e9),
        "qwen3-1.7b": (1.2e9, 2.8e9),
        "qwen2.5-32b": (28e9, 40e9),
        "phi3-medium-14b": (12e9, 18e9),
        "seamless-m4t-large-v2": (1.5e9, 3.5e9),
        "llava-next-mistral-7b": (6.5e9, 8.5e9),
        "moonshot-v1-16b-a3b": (14e9, 30e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "mamba2-130m": (0.1e9, 0.25e9),
    }[arch]
    assert expected[0] < n < expected[1], f"{arch}: {n/1e9:.2f}B params"
    if cfg.moe:
        assert cfg.active_param_count() < 0.25 * n
