"""Model-component tests: attention decode consistency, MLA absorbed path,
MoE invariants, Mamba2 chunked-vs-naive equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import ModelConfig
from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod

KEY = jax.random.PRNGKey(0)


def _dense_cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
                vocab_pad_multiple=128, remat="none", dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


# --------------------------------------------------------------------- #
# GQA attention
# --------------------------------------------------------------------- #

def test_attention_causal_prefix_property():
    """Output at position t must not depend on tokens > t."""
    cfg = _dense_cfg()
    p = attn.gqa_params(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 64))
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    full = attn.attention(cfg, p, x, pos)
    x2 = x.at[:, 5:].set(0.0)
    part = attn.attention(cfg, p, x2, pos)
    np.testing.assert_allclose(full[:, :5], part[:, :5], rtol=2e-5, atol=2e-5)


def test_decode_matches_prefill():
    """Token-by-token decode == full prefill attention outputs."""
    cfg = _dense_cfg()
    p = attn.gqa_params(KEY, cfg)
    b, t = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(2), (b, t, 64))
    pos = jnp.broadcast_to(jnp.arange(t), (b, t))
    full, (k, v) = attn.attention_prefill(cfg, p, x, pos)
    ck = jnp.zeros((b, t, cfg.n_kv_heads, 16))
    cv = jnp.zeros((b, t, cfg.n_kv_heads, 16))
    outs = []
    for i in range(t):
        o, ck, cv = attn.attention_decode(cfg, p, x[:, i:i + 1],
                                          jnp.full((b,), i), ck, cv)
        outs.append(o[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_masks_far_tokens():
    cfg = _dense_cfg(sliding_window=4)
    p = attn.gqa_params(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 64))
    pos = jnp.broadcast_to(jnp.arange(16), (1, 16))
    base = attn.attention(cfg, p, x, pos)
    x2 = x.at[:, :8].set(1e3)  # far past perturbation
    pert = attn.attention(cfg, p, x2, pos)
    np.testing.assert_allclose(base[:, 14:], pert[:, 14:], rtol=2e-5,
                               atol=2e-5)


def test_ring_cache_decode_swa():
    """Window-sized ring cache decode == full-cache decode under SWA."""
    cfg = _dense_cfg(sliding_window=4)
    p = attn.gqa_params(KEY, cfg)
    b, t = 1, 12
    x = jax.random.normal(jax.random.PRNGKey(4), (b, t, 64))
    ck_full = jnp.zeros((b, t, cfg.n_kv_heads, 16))
    cv_full = jnp.zeros((b, t, cfg.n_kv_heads, 16))
    ck_ring = jnp.zeros((b, 4, cfg.n_kv_heads, 16))
    cv_ring = jnp.zeros((b, 4, cfg.n_kv_heads, 16))
    for i in range(t):
        of, ck_full, cv_full = attn.attention_decode(
            cfg, p, x[:, i:i + 1], jnp.full((b,), i), ck_full, cv_full)
        orr, ck_ring, cv_ring = attn.attention_decode(
            cfg, p, x[:, i:i + 1], jnp.full((b,), i), ck_ring, cv_ring)
        np.testing.assert_allclose(np.asarray(of), np.asarray(orr),
                                   rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------- #
# MLA
# --------------------------------------------------------------------- #

def _mla_cfg():
    return _dense_cfg(attn_kind="mla", n_heads=4, q_lora_rank=32,
                      kv_lora_rank=24, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16)


def test_mla_decode_absorbed_equals_naive():
    cfg = _mla_cfg()
    p = mla_mod.mla_params(KEY, cfg)
    b, t = 2, 6
    x = jax.random.normal(jax.random.PRNGKey(5), (b, t, 64))
    cc = jnp.zeros((b, t, cfg.kv_lora_rank))
    ckr = jnp.zeros((b, t, cfg.qk_rope_head_dim))
    cc2, ckr2 = cc, ckr
    for i in range(t):
        oa, cc, ckr = mla_mod.mla_decode(cfg, p, x[:, i:i + 1],
                                         jnp.full((b,), i), cc, ckr,
                                         absorbed=True)
        on, cc2, ckr2 = mla_mod.mla_decode(cfg, p, x[:, i:i + 1],
                                           jnp.full((b,), i), cc2, ckr2,
                                           absorbed=False)
        np.testing.assert_allclose(np.asarray(oa), np.asarray(on),
                                   rtol=2e-4, atol=2e-4)


def test_mla_decode_matches_full_attention():
    cfg = _mla_cfg()
    p = mla_mod.mla_params(KEY, cfg)
    b, t = 1, 6
    x = jax.random.normal(jax.random.PRNGKey(6), (b, t, 64))
    pos = jnp.broadcast_to(jnp.arange(t), (b, t))
    full = mla_mod.mla_attention(cfg, p, x, pos)
    cc = jnp.zeros((b, t, cfg.kv_lora_rank))
    ckr = jnp.zeros((b, t, cfg.qk_rope_head_dim))
    outs = []
    for i in range(t):
        o, cc, ckr = mla_mod.mla_decode(cfg, p, x[:, i:i + 1],
                                        jnp.full((b,), i), cc, ckr)
        outs.append(o[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------- #
# MoE
# --------------------------------------------------------------------- #

def _moe_cfg(**kw):
    return _dense_cfg(moe=True, n_experts=8, top_k=2, moe_d_ff=32,
                      n_shared_experts=1, d_ff=0, **kw)


def test_moe_shapes_and_aux():
    cfg = _moe_cfg()
    p = moe_mod.moe_params(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 16, 64))
    y, aux = moe_mod.moe_ffn(cfg, p, x)
    assert y.shape == x.shape
    assert np.isfinite(float(aux["load_balance_loss"]))
    assert float(aux["load_balance_loss"]) >= 0.99  # >= 1 at balance


def test_moe_capacity_drops_gracefully():
    cfg = _moe_cfg(capacity_factor=0.1)  # tiny capacity -> heavy drops
    p = moe_mod.moe_params(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 32, 64))
    y, _ = moe_mod.moe_ffn(cfg, p, x)
    assert bool(jnp.isfinite(y).all())


def test_moe_matches_dense_routing_oracle():
    """With capacity >= tokens, slot dispatch == explicit per-token loop."""
    cfg = _moe_cfg(capacity_factor=8.0)
    p = moe_mod.moe_params(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 8, 64))
    y, _ = moe_mod.moe_ffn(cfg, p, x)
    xf = x.reshape(8, 64)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    tw, te = jax.lax.top_k(probs, cfg.top_k)
    tw = tw / tw.sum(-1, keepdims=True)
    want = np.zeros((8, 64), np.float32)
    for t in range(8):
        for j in range(cfg.top_k):
            e = int(te[t, j])
            g = jax.nn.silu(xf[t] @ p["w_gate"][e]) * (xf[t] @ p["w_up"][e])
            want[t] += float(tw[t, j]) * np.asarray(g @ p["w_down"][e])
    sp = p["shared"]
    shared = (jax.nn.silu(xf @ sp["w_gate"]) * (xf @ sp["w_up"])) @ sp["w_down"]
    want = want + np.asarray(shared)
    np.testing.assert_allclose(np.asarray(y[0]), want, rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------- #
# Mamba2 SSD
# --------------------------------------------------------------------- #

def _ssm_cfg(chunk=8):
    return _dense_cfg(attn_kind="none", ssm=True, ssm_state=16,
                      ssm_head_dim=16, ssm_expand=2, ssm_chunk=chunk,
                      d_ff=0)


def test_ssd_chunked_equals_naive():
    cfg = _ssm_cfg(chunk=8)
    p = ssm_mod.ssm_params(KEY, cfg)
    u = jax.random.normal(jax.random.PRNGKey(10), (2, 32, 64)) * 0.5
    y_chunk = ssm_mod.ssm_forward(cfg, p, u)
    y_naive = ssm_mod.ssm_naive(cfg, p, u)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunk_size_invariance():
    cfg8, cfg16 = _ssm_cfg(8), _ssm_cfg(16)
    p = ssm_mod.ssm_params(KEY, cfg8)
    u = jax.random.normal(jax.random.PRNGKey(11), (1, 32, 64)) * 0.5
    np.testing.assert_allclose(
        np.asarray(ssm_mod.ssm_forward(cfg8, p, u)),
        np.asarray(ssm_mod.ssm_forward(cfg16, p, u)), rtol=2e-3, atol=2e-3)


def test_ssm_decode_matches_forward():
    cfg = _ssm_cfg(chunk=8)
    p = ssm_mod.ssm_params(KEY, cfg)
    b, t = 1, 16
    u = jax.random.normal(jax.random.PRNGKey(12), (b, t, 64)) * 0.5
    full = ssm_mod.ssm_naive(cfg, p, u)
    cache = ssm_mod.ssm_init_cache(cfg, b)
    outs = []
    for i in range(t):
        y, cache = ssm_mod.ssm_decode(cfg, p, u[:, i:i + 1], cache)
        outs.append(y[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------- #
# Chunked (flash-style) attention paths
# --------------------------------------------------------------------- #

def test_sdpa_chunked_matches_dense():
    cfg = _dense_cfg()
    p = attn.gqa_params(KEY, cfg)
    b, t = 2, 64
    x = jax.random.normal(jax.random.PRNGKey(20), (b, t, 64))
    pos = jnp.broadcast_to(jnp.arange(t), (b, t))
    q, k, v = attn._project_qkv(cfg, p, x, pos)
    dense = attn._sdpa_dense(q, k, v, attn._mask(t, t, True, 0))
    chunk = attn._sdpa_chunked(q, k, v, causal=True, window=0, kv_block=16)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)


def test_sdpa_chunked_with_window():
    cfg = _dense_cfg()
    p = attn.gqa_params(KEY, cfg)
    b, t = 1, 48
    x = jax.random.normal(jax.random.PRNGKey(21), (b, t, 64))
    pos = jnp.broadcast_to(jnp.arange(t), (b, t))
    q, k, v = attn._project_qkv(cfg, p, x, pos)
    dense = attn._sdpa_dense(q, k, v, attn._mask(t, t, True, 8))
    chunk = attn._sdpa_chunked(q, k, v, causal=True, window=8, kv_block=16)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)


def test_mla_chunked_matches_dense():
    cfg = _mla_cfg()
    p = mla_mod.mla_params(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(22), (1, 32, 64))
    pos = jnp.broadcast_to(jnp.arange(32), (1, 32))
    dense = mla_mod.mla_attention(cfg, p, x, pos, chunked=False)
    q_nope, q_rope = mla_mod._q_proj(cfg, p, x, pos)
    c_kv, k_rope = mla_mod._kv_latent(cfg, p, x, pos)
    out = mla_mod._mla_chunked(cfg, p, q_nope, q_rope, c_kv, k_rope,
                               kv_block=8)
    chunk = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype))
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)
