"""Width-64 plane layout: operator parity across the eager, fused and
raw-lane paths at widths 33/48/64 (div-by-zero and boundary values
included), every registered 64-bit evaluator bit-exact against eager
NumPy, the layout-keyed pipeline cache, PumArray slicing, and the
``shard-words`` multi-device fused backend."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dep: fixed-seed fallback
    from repro.testing import given, settings, st

import repro.pum as pum
from repro.core.engine import LazyArray, PulsarEngine
from repro.kernels import fused_program
from repro.kernels.plane_layout import (LAYOUT32, LAYOUT64, PlaneLayout,
                                        get_layout, layout_for_width)

pytestmark = pytest.mark.fused

WIDE = [33, 48, 64]


def _operands(width, n, seed):
    rng = np.random.default_rng(seed)
    hi = (1 << width) - 1
    a = rng.integers(0, hi, n, dtype=np.uint64)
    b = rng.integers(0, hi, n, dtype=np.uint64)
    # Edge lanes: zeros, ones, the signed boundary, the max value, and
    # div-by-zero divisors.
    edges = np.array([0, 1, 1 << (width - 1), hi], np.uint64)
    a[:4], b[:4] = edges, edges[::-1]
    b[::5] = 0
    return a, b


# --------------------------------------------------------------------- #
# PlaneLayout contract
# --------------------------------------------------------------------- #


def test_layout_constants_derive_from_word_bits():
    assert LAYOUT32.swar_consts == (0x55555555, 0x33333333, 0x0F0F0F0F,
                                    0x01010101)
    assert LAYOUT64.swar_consts == (
        0x5555555555555555, 0x3333333333333333, 0x0F0F0F0F0F0F0F0F,
        0x0101010101010101)
    assert (LAYOUT32.popcount_shift, LAYOUT64.popcount_shift) == (24, 56)
    assert (LAYOUT32.raw_lanes_per_word, LAYOUT64.raw_lanes_per_word) \
        == (2, 1)
    assert (LAYOUT32.wire_words_per_lane, LAYOUT64.wire_words_per_lane) \
        == (1, 2)
    assert get_layout(64) is LAYOUT64 and get_layout(LAYOUT32) is LAYOUT32
    assert layout_for_width(32) is LAYOUT32
    assert layout_for_width(33) is LAYOUT64
    with pytest.raises(ValueError, match="no plane layout"):
        get_layout(48)
    with pytest.raises(ValueError, match="covers width"):
        layout_for_width(65)


def test_layout_wire_roundtrip():
    rng = np.random.default_rng(3)
    words = rng.integers(0, 1 << 64, 64, dtype=np.uint64)
    for layout in (LAYOUT32, LAYOUT64):
        lanes = layout.raw_lanes(words)
        assert lanes.dtype == layout.np_dtype
        np.testing.assert_array_equal(layout.join_raw(lanes), words)
        wire = layout.to_wire(lanes)
        assert wire.dtype == np.int32
        np.testing.assert_array_equal(layout.from_wire(wire), lanes)


def test_layout_is_hashable_and_part_of_program_identity():
    p32 = fused_program.FusedProgram(
        width=16, n_inputs=2, ops=(fused_program.FusedOp("add", (0, 1)),),
        outputs=(2,))
    p64 = fused_program.FusedProgram(
        width=16, n_inputs=2, ops=(fused_program.FusedOp("add", (0, 1)),),
        outputs=(2,), layout=LAYOUT64)
    assert p32.layout is LAYOUT32  # the default keeps old IR valid
    assert p32 != p64 and hash(p32) != hash(p64)
    assert PlaneLayout(name="u64", word_bits=64) == LAYOUT64


# --------------------------------------------------------------------- #
# Operator parity at widths 33/48/64 (eager vs fused)
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("width", WIDE)
def test_wide_fused_all_ops_match_eager(width):
    a, b = _operands(width, 257, seed=width)
    eager = pum.device(width=width, fuse=False)
    fused = pum.device(width=width, fuse=True)
    assert fused.config.fuse and fused.layout.word_bits == 64

    def run(dev):
        x = dev.asarray(a)
        q, r = divmod(x, b)
        outs = [x & b, x | b, x ^ b, x + b, x - b, x * b, x // b, x % b,
                q, r, x < b, x.popcount(),
                x.reduce_bits("and"), x.reduce_bits("or"),
                x.reduce_bits("xor")]
        return [np.asarray(o, np.uint64) for o in outs]

    for w, g in zip(run(eager), run(fused)):
        np.testing.assert_array_equal(w, g)
    assert eager.stats == fused.stats


@given(width=st.sampled_from(WIDE), seed=st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_wide_fused_random_chain_property(width, seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(33, 300))  # deliberately not a multiple of 32
    a, b = _operands(width, n, seed)
    ops = ["add", "sub", "mul", "div", "mod", "and", "or", "xor"]
    seq = [str(rng.choice(ops)) for _ in range(int(rng.integers(2, 7)))]

    def run(dev):
        t = dev.asarray(a)
        outs = []
        for name in seq:
            t = {"add": t + b, "sub": t - b, "mul": t * b, "div": t // b,
                 "mod": t % b, "and": t & b, "or": t | b,
                 "xor": t ^ b}[name]
            outs.append(t)
        return [np.asarray(o, np.uint64) for o in outs]

    eager = pum.device(width=width, fuse=False)
    fused = pum.device(width=width, fuse=True)
    for w, g in zip(run(eager), run(fused)):
        np.testing.assert_array_equal(w, g)
    assert eager.stats == fused.stats


def test_width64_full_range_divmod_shares_one_divider():
    a, b = _operands(64, 128, seed=9)
    fused = pum.device(width=64, fuse=True)
    x = fused.asarray(a)
    q, r = divmod(x, b)
    s = (x // b) ^ (x % b)  # CSEs onto the same divmod tuple op
    with np.errstate(divide="ignore", invalid="ignore"):
        nz = b != 0
        np.testing.assert_array_equal(
            np.asarray(q), np.where(nz, a // np.where(nz, b, 1), 0))
        np.testing.assert_array_equal(
            np.asarray(r), np.where(nz, a % np.where(nz, b, 1), 0))
        np.testing.assert_array_equal(
            np.asarray(s), np.asarray(q) ^ np.asarray(r))


# --------------------------------------------------------------------- #
# Raw-lane path on the 64-bit layout (the un-double-split bugfix)
# --------------------------------------------------------------------- #


def test_raw_planewise_on_64bit_layout_is_single_lane():
    """At a 64-bit layout an out-of-width uint64 word is ONE dataplane
    lane (the old code always split 2x32 — the hardcoded split this PR
    derives from the layout)."""
    rng = np.random.default_rng(21)
    a = rng.integers(0, 1 << 64, 65, dtype=np.uint64)
    b = rng.integers(0, 1 << 64, 65, dtype=np.uint64)
    eager = PulsarEngine(width=48)
    fused = PulsarEngine(width=48, fuse=True)

    def chain(e):
        t = e._and(a, b)
        t = e._xor(t, a)
        return e._or(t, b)

    want = np.asarray(chain(eager), np.uint64)
    got = chain(fused)
    assert isinstance(got, LazyArray)
    g = fused._graph
    assert g is not None and g.raw
    assert g.n == 65 and g.width == 64  # one 64-bit lane per word
    np.testing.assert_array_equal(want, np.asarray(got, np.uint64))
    assert eager.stats == fused.stats


def test_raw_planewise_on_32bit_layout_still_splits():
    rng = np.random.default_rng(23)
    a = rng.integers(0, 1 << 64, 33, dtype=np.uint64)
    e = PulsarEngine(width=16, fuse=True)
    t = e._and(a, a)
    g = e._graph
    assert g.raw and g.n == 66 and g.width == 32
    np.testing.assert_array_equal(np.asarray(t), a)


def test_explicit_64bit_layout_on_narrow_width():
    """layout=64 with width<=32 is legal: narrow values compute on wide
    lanes, and the raw path keeps full words unsplit."""
    rng = np.random.default_rng(25)
    a = rng.integers(0, 1 << 16, 64, dtype=np.uint64)
    bm = rng.integers(0, 1 << 64, 64, dtype=np.uint64)
    eager = pum.device(width=16, fuse=False)
    fused = pum.device(width=16, layout=64, fuse=True)
    assert fused.layout is LAYOUT64 and fused.config.fuse
    np.testing.assert_array_equal(
        np.asarray(eager.asarray(a) * a), np.asarray(fused.asarray(a) * a))
    np.testing.assert_array_equal(
        np.asarray(fused.asarray(bm) ^ bm), np.zeros(64, np.uint64))
    assert fused.engine._graph is None or not fused.engine._graph.ops


# --------------------------------------------------------------------- #
# Every registered 64-bit evaluator is bit-exact
# --------------------------------------------------------------------- #


def test_all_wide_evaluators_bit_exact():
    """words-cpu-64 (NumPy word domain), ref-vertical-64 (jnp planes) and
    pallas-tpu-64 (interpret mode off-TPU) agree with eager NumPy on the
    same wire leaves."""
    rng = np.random.default_rng(27)
    n = 96
    a = rng.integers(0, 1 << 64, n, dtype=np.uint64)
    b = rng.integers(0, 1 << 64, n, dtype=np.uint64)
    prog = fused_program.FusedProgram(
        width=64, n_inputs=2,
        ops=(fused_program.FusedOp("add", (0, 1)),
             fused_program.FusedOp("xor", (2, 0)),
             fused_program.FusedOp("less", (1, 3)),
             fused_program.FusedOp("popcount", (3,))),
        outputs=(3, 4, 5), layout=LAYOUT64)
    leaves = [LAYOUT64.to_wire(x) for x in (a, b)]
    t = (a + b) ^ a
    want = [t, (b < t).astype(np.uint64),
            np.array([bin(int(x)).count("1") for x in t], np.uint64)]
    for name in ("words-cpu-64", "ref-vertical-64", "pallas-tpu-64"):
        outs = fused_program.get_pipeline(prog, backend=name,
                                          interpret=True)(*leaves)
        for w, o in zip(want, outs):
            np.testing.assert_array_equal(
                w, LAYOUT64.from_wire(o)[:n], err_msg=name)


def test_wide_pipeline_rejects_narrow_only_backend():
    prog = fused_program.FusedProgram(
        width=64, n_inputs=1,
        ops=(fused_program.FusedOp("xor", (0, 0)),), outputs=(1,),
        layout=LAYOUT64)
    with pytest.raises(ValueError, match="64-bit plane layout"):
        fused_program.get_pipeline(prog, backend="words-cpu")


# --------------------------------------------------------------------- #
# Layout-keyed pipeline cache
# --------------------------------------------------------------------- #


def test_pipeline_cache_is_layout_keyed():
    """The same op structure at the same width on DIFFERENT layouts is
    two pipelines (cache miss), and re-recording on either layout hits
    its own cached trace."""
    a = np.arange(256, dtype=np.uint64)

    def batch(dev):
        x = dev.asarray(a)
        return np.asarray((x + a) ^ a)

    # Hermetic: earlier suites may have filled the LRU to maxsize, where
    # an insert evicts and currsize no longer grows.
    fused_program._cached_pipeline.cache_clear()
    d32 = pum.device(width=16, fuse=True)
    d64 = pum.device(width=16, layout=64, fuse=True)
    batch(d32)
    info0 = fused_program._cached_pipeline.cache_info()
    batch(d64)  # same structure, new layout: a genuinely new pipeline
    info1 = fused_program._cached_pipeline.cache_info()
    assert info1.currsize == info0.currsize + 1
    assert info1.hits == info0.hits
    batch(d32)
    batch(d64)  # both layouts re-hit their own compiled traces
    info2 = fused_program._cached_pipeline.cache_info()
    assert info2.currsize == info1.currsize
    assert info2.hits == info1.hits + 2


# --------------------------------------------------------------------- #
# PumArray slicing (__getitem__ / __len__)
# --------------------------------------------------------------------- #


def test_getitem_on_eager_values_is_a_view():
    dev = pum.device(width=16, fuse=False)
    a = np.arange(10, dtype=np.uint64)
    x = dev.asarray(a)
    s = x[2:7]
    assert isinstance(s, pum.PumArray) and s.shape == (5,)
    assert s._data.base is not None  # a view, not a copy
    np.testing.assert_array_equal(s.to_numpy(), a[2:7])
    np.testing.assert_array_equal(x[::3].to_numpy(), a[::3])
    assert len(x) == 10 and len(s) == 5


def test_getitem_on_lazy_handles_forces_materialize():
    dev = pum.device(width=16, fuse=True)
    a = np.arange(64, dtype=np.uint64)
    y = dev.asarray(a) + a
    assert isinstance(y._data, LazyArray) and y._data._value is None
    s = y[10:20]  # slicing is a host access: flushes, then slices
    assert y._data._value is not None
    np.testing.assert_array_equal(s.to_numpy(), 2 * a[10:20])
    # sliced arrays feed back into ops as ordinary operands
    np.testing.assert_array_equal(
        np.asarray(s + s), 4 * a[10:20])


def test_getitem_integer_index_yields_0d_pum_array():
    dev = pum.device(width=16, fuse=True)
    y = dev.asarray(np.arange(8, dtype=np.uint64)) + 1
    el = y[3]
    assert isinstance(el, pum.PumArray) and el.shape == ()
    assert int(np.asarray(el)) == 4
    with pytest.raises(TypeError):
        len(el)


# --------------------------------------------------------------------- #
# REF postponing plumbing (EngineConfig.ref_postponing -> auto controller)
# --------------------------------------------------------------------- #


def test_ref_postponing_reaches_the_auto_controller():
    dev = pum.device(width=16, controller="auto", ref_postponing=4)
    assert dev.engine.controller.postponing == 4
    # the policy actually changes the priced refresh schedule
    base = pum.device(width=16, controller="auto")
    a = np.arange(4096, dtype=np.uint64) & np.uint64(0xFFFF)
    for d in (dev, base):
        _ = np.asarray(d.asarray(a) + a)
    assert dev.stats.refresh_stall_ns != base.stats.refresh_stall_ns


def test_ref_postponing_validates_loudly():
    with pytest.raises(ValueError, match="JEDEC"):
        PulsarEngine(width=16, controller="auto", ref_postponing=9)
    with pytest.raises(ValueError, match="JEDEC"):
        pum.EngineConfig(ref_postponing=0)
    # silently-inert combination is rejected, not ignored
    with pytest.raises(ValueError, match="controller='auto'"):
        PulsarEngine(width=16, ref_postponing=4)


# --------------------------------------------------------------------- #
# shard-words fused backend
# --------------------------------------------------------------------- #


@pytest.mark.sharded
def test_shard_words_single_device_parity():
    """Requestable by name even on one device: same results/stats as the
    default fused path and as eager."""
    rng = np.random.default_rng(31)
    a = rng.integers(0, 1 << 32, 500, dtype=np.uint64)
    b = rng.integers(0, 1 << 32, 500, dtype=np.uint64)
    eager = pum.device(width=32, fuse=False)
    sharded = pum.device(width=32, fuse=True,
                         fused_backend="shard-words")
    assert sharded.engine.fused_backend == "shard-words"

    def run(dev):
        x = dev.asarray(a)
        t = (x + b) * x
        return np.asarray(t ^ b)

    np.testing.assert_array_equal(run(eager), run(sharded))
    assert eager.stats == sharded.stats


@pytest.mark.sharded
def test_shard_words_rejects_wide_layout():
    with pytest.raises(ValueError, match="layouts"):
        PulsarEngine(width=48, fuse=True, fused_backend="shard-words")
    with pytest.raises(ValueError, match="no fused"):
        PulsarEngine(width=16, fuse=True, fused_backend="fast")


@pytest.mark.sharded
def test_shard_words_multidevice_parity():
    """One flush executes one program across 8 forced host devices;
    results and EngineStats identical to single-device eager (subprocess:
    the flag must be set before jax initializes)."""
    import os
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=8"
        import numpy as np
        import jax
        assert len(jax.devices()) == 8
        import repro.pum as pum
        # multi-device hosts auto-select the sharded pipeline
        assert pum.select_backend(require="fused", width=32,
                                  layout=32).name == "shard-words"
        rng = np.random.default_rng(5)
        a = rng.integers(0, 1 << 32, 1000, dtype=np.uint64)
        b = rng.integers(0, 1 << 32, 1000, dtype=np.uint64)
        b[::7] = 0
        eager = pum.device(width=32, fuse=False)
        fused = pum.device(width=32, fuse=True)
        def run(d):
            x = d.asarray(a)
            t = (x + b) * x
            q, r = divmod(t, b)
            return [np.asarray(v) for v in (t, q, r, t.popcount())]
        for w, g in zip(run(eager), run(fused)):
            np.testing.assert_array_equal(w, g)
        assert eager.stats == fused.stats
        print("OK")
    """)
    repo = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, \
        f"STDOUT:{proc.stdout}\nSTDERR:{proc.stderr}"
    assert "OK" in proc.stdout
