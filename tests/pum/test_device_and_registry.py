"""Device lifecycle (context scoping, auto-flush), EngineConfig, the
backend registry contract, the deprecation shim, leaf-buffer donation and
the shared-divider divmod lowering."""

import dataclasses
import warnings

import numpy as np
import pytest

import repro.pum as pum
from repro.core.engine import LazyArray, PulsarEngine
from repro.kernels.fused_program import optimize_program


# --------------------------------------------------------------------- #
# Device lifecycle + EngineConfig
# --------------------------------------------------------------------- #


def test_device_context_scopes_default_and_autoflushes():
    outer = pum.default_device()
    with pum.device(width=16) as dev:
        assert pum.default_device() is dev
        x = pum.asarray(np.array([2, 3], np.uint64))  # scoped device
        assert x.device is dev
        y = x + x
        assert isinstance(y._data, LazyArray) and y._data._value is None
        with pum.device(width=8) as inner:
            assert pum.default_device() is inner
        assert pum.default_device() is dev
    # scope exit flushed the pending graph and popped the stack
    assert y._data._value is not None
    np.testing.assert_array_equal(y.to_numpy(), np.array([4, 6], np.uint64))
    assert pum.default_device() is outer


def test_engine_config_is_frozen_and_validates():
    cfg = pum.EngineConfig(width=16, banks=8)
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.width = 32
    assert cfg.replace(width=32).width == 32 and cfg.width == 16
    assert cfg.fuse  # the fused pipeline is the production default
    with pytest.raises(ValueError):
        pum.EngineConfig(width=0)
    with pytest.raises(ValueError):
        pum.EngineConfig(flush_threshold=0)


def test_device_builds_engine_from_config():
    cfg = pum.EngineConfig(mfr="H", width=16, banks=8, use_pulsar=False,
                           fuse=False, flush_threshold=7)
    dev = pum.device(cfg)
    e = dev.engine
    assert (e.mfr, e.width, e.banks, e.use_pulsar, e.fuse,
            e.flush_threshold) == ("H", 16, 8, False, False, 7)
    # keyword overrides derive a new config
    dev2 = pum.device(cfg, width=32)
    assert dev2.config.width == 32 and dev2.config.mfr == "H"


def test_wide_device_fuses_on_the_64bit_layout():
    """Widths above 32 resolve to the 64-bit plane layout and FUSE (the
    additively registered ``words-cpu-64`` evaluator), bit-exact against
    eager — the old transparent eager fallback is gone because a fused
    evaluator now covers the layout."""
    dev = pum.device(width=48)
    assert dev.config.fuse and dev.layout.word_bits == 64
    a = np.array([1 << 40, 5], np.uint64)
    np.testing.assert_array_equal(np.asarray(dev.asarray(a) + a), 2 * a)
    q, r = divmod(dev.asarray(a), np.array([3, 0], np.uint64))
    np.testing.assert_array_equal(np.asarray(q),
                                  np.array([(1 << 40) // 3, 0], np.uint64))
    # widths that fit no layout word still refuse loudly
    with pytest.raises(ValueError, match="does not fit"):
        PulsarEngine(width=48, layout=32)


def test_device_falls_back_to_eager_without_a_layout_evaluator():
    """When NO registered fused evaluator supports the device's layout,
    fuse transparently downgrades to eager (the pre-width-64 behavior,
    now reachable only by unregistering the 64-bit evaluators)."""
    saved = {n: pum.get_backend(n)
             for n in ("words-cpu-64", "pallas-tpu-64", "ref-vertical-64")}
    for n in saved:
        pum.unregister_backend(n)
    try:
        with pytest.raises(LookupError, match="64-bit plane layout"):
            pum.select_backend(require="fused", width=48, layout=64)
        dev = pum.device(width=48)
        assert not dev.config.fuse
        a = np.array([1 << 40, 5], np.uint64)
        np.testing.assert_array_equal(np.asarray(dev.asarray(a) + a),
                                      2 * a)
        # the direct engine path still refuses loudly
        with pytest.raises(ValueError, match="no registered fused"):
            PulsarEngine(width=48, fuse=True)
    finally:
        for n, s in saved.items():
            pum.register_backend(
                n, s.builder, capabilities=s.capabilities,
                max_width=s.max_width, priority=s.priority,
                available=s.available, layouts=s.layouts)


def test_sim_backend_device_is_eager_and_bit_exact():
    dev = pum.device(mfr="H", width=8, backend="sim")
    assert not dev.config.fuse  # sim has no word dataplane to fuse over
    n = dev.engine._alu.words * 32
    rng = np.random.default_rng(1)
    a = rng.integers(0, 256, n, dtype=np.uint64)
    b = rng.integers(1, 256, n, dtype=np.uint64)
    np.testing.assert_array_equal(np.asarray(dev.asarray(a) & b), a & b)
    np.testing.assert_array_equal(np.asarray(dev.asarray(a) + b),
                                  (a + b) & np.uint64(0xFF))
    # divmod runs ONE restoring-division pass on the chip model
    ops_before = dev.engine._alu.x.chip.stats.n_ops
    q, r = divmod(dev.asarray(a), b)
    one_pass_ops = dev.engine._alu.x.chip.stats.n_ops - ops_before
    np.testing.assert_array_equal(np.asarray(q), a // b)
    np.testing.assert_array_equal(np.asarray(r), a % b)
    ops_before = dev.engine._alu.x.chip.stats.n_ops
    _ = dev.asarray(a) // b
    div_only_ops = dev.engine._alu.x.chip.stats.n_ops - ops_before
    assert one_pass_ops < 1.5 * div_only_ops  # not 2x: divider shared
    # zero-divisor lanes yield 0 on the sim backend too (the engine-wide
    # unsigned-NumPy contract, not the ALU divider's raw output)
    bz = b.copy()
    bz[::3] = 0
    q, r = divmod(dev.asarray(a), bz)
    want_q = np.where(bz == 0, 0, a // np.maximum(bz, 1))
    want_r = np.where(bz == 0, 0, a % np.maximum(bz, 1))
    np.testing.assert_array_equal(np.asarray(q), want_q)
    np.testing.assert_array_equal(np.asarray(r), want_r)
    np.testing.assert_array_equal(np.asarray(dev.asarray(a) // bz), want_q)
    np.testing.assert_array_equal(np.asarray(dev.asarray(a) % bz), want_r)


def test_scalar_broadcasts_share_one_leaf():
    """Repeated scalar operands must dedup to ONE graph leaf (the device
    caches the broadcast buffer), not snapshot a fresh full-size leaf
    per op."""
    dev = pum.device(width=16, fuse=True)
    x = dev.asarray(np.arange(64, dtype=np.uint64))
    t1 = x + 5
    t2 = x ^ 5
    t3 = x | 5
    g = dev.engine._graph
    assert len(g.leaves) == 2  # x and one shared broadcast of 5
    np.testing.assert_array_equal(np.asarray(t1),
                                  np.arange(64, dtype=np.uint64) + 5)
    np.testing.assert_array_equal(np.asarray(t2),
                                  np.arange(64, dtype=np.uint64) ^ 5)
    np.testing.assert_array_equal(np.asarray(t3),
                                  np.arange(64, dtype=np.uint64) | 5)


def test_as_device_wraps_engines_and_passes_devices_through():
    dev = pum.device(width=16)
    assert pum.as_device(dev) is dev
    eng = PulsarEngine(width=16, banks=4)
    wrapped = pum.as_device(eng)
    assert wrapped.engine is eng and wrapped.config.banks == 4
    # the characterization DB carries into the config: a twin derived
    # via wrapped.config.replace(...) prices with the SAME success rates
    assert wrapped.config.success_db is eng.db
    twin = pum.device(wrapped.config.replace(use_pulsar=False))
    assert twin.engine.db is eng.db
    with pytest.raises(TypeError):
        pum.as_device(object())


# --------------------------------------------------------------------- #
# Backend registry
# --------------------------------------------------------------------- #


def test_registry_lists_builtin_backends():
    names = pum.available_backends()
    for n in ("fast", "sim", "words-cpu", "pallas-tpu", "ref-vertical"):
        assert n in names
    assert "fast" in pum.available_backends("eager")
    assert "words-cpu" in pum.available_backends("fused")
    assert "words-cpu" not in pum.available_backends("eager")


def test_select_backend_capability_lookup():
    # On this host the word-domain evaluator wins (Pallas needs a TPU;
    # shard-words needs >1 device).
    spec = pum.select_backend(require="fused", width=32, layout=32)
    assert spec.name in ("words-cpu", "pallas-tpu", "shard-words")
    # width 64 resolves to an evaluator declaring the 64-bit layout
    spec64 = pum.select_backend(require="fused", width=64)
    assert spec64.layouts == frozenset({64})
    with pytest.raises(LookupError):
        pum.select_backend(require="no-such-capability")
    with pytest.raises(LookupError):  # layout filter: sharded is 32-only
        pum.select_backend(require="sharded", layout=64)
    with pytest.raises(KeyError, match="unknown backend"):
        pum.get_backend("nope")


def test_register_backend_is_additive_and_selectable():
    """A new evaluator registers without touching engine/compiler code:
    highest priority + available wins the capability lookup."""
    calls = []

    def builder(program, interpret=False, donate=False):
        calls.append(program)
        from repro.kernels.fused_program import build_words_pipeline
        return build_words_pipeline(program, donate=donate)

    pum.register_backend("test-words", builder, capabilities=("fused",),
                         max_width=32, priority=99)
    try:
        dev = pum.device(width=16, fuse=True)
        a = np.array([5, 6], np.uint64)
        np.testing.assert_array_equal(np.asarray(dev.asarray(a) + a),
                                      2 * a)
        assert len(calls) == 1  # our backend built the pipeline
        # Re-registering the name replaces the builder for FUTURE
        # pipelines even of identical structure: the cache is keyed on
        # the spec, so the replaced builder's pipelines can't be served.
        pum.register_backend("test-words", builder, capabilities=("fused",),
                             max_width=32, priority=99)
        np.testing.assert_array_equal(np.asarray(dev.asarray(a) + a),
                                      2 * a)
        assert len(calls) == 2  # fresh spec -> fresh compile, no stale hit
    finally:
        pum.unregister_backend("test-words")


def test_unknown_eager_backend_fails_loudly():
    with pytest.raises(KeyError, match="unknown backend"):
        pum.device(backend="warp-drive", fuse=False)
    with pytest.raises(ValueError, match="no eager dataplane"):
        pum.device(backend="words-cpu", fuse=False)


# --------------------------------------------------------------------- #
# Deprecation shim
# --------------------------------------------------------------------- #


def test_engine_method_surface_emits_deprecation_warnings():
    """The legacy PulsarEngine op methods survive as a compat shim: same
    results, but each call warns toward repro.pum."""
    e = PulsarEngine(width=16)
    a = np.array([9, 5], np.uint64)
    b = np.array([3, 0], np.uint64)
    for name, args, want in [
            ("and_", (a, b), a & b), ("or_", (a, b), a | b),
            ("xor", (a, b), a ^ b), ("add", (a, b), a + b),
            ("sub", (a, b), a - b), ("mul", (a, b), a * b),
            ("div", (a, b), np.array([3, 0], np.uint64)),
            ("mod", (a, b), np.array([0, 0], np.uint64)),
            ("less_than", (a, b), np.zeros(2, np.uint64)),
            ("popcount", (a,), np.array([2, 2], np.uint64)),
            ("reduce_bits", (a, "or"), np.ones(2, np.uint64))]:
        with pytest.warns(DeprecationWarning, match=f"PulsarEngine.{name}"):
            got = getattr(e, name)(*args)
        np.testing.assert_array_equal(np.asarray(got, np.uint64), want,
                                      err_msg=name)
    with pytest.warns(DeprecationWarning, match="PulsarEngine.divmod"):
        q, r = e.divmod(a, np.array([2, 2], np.uint64))
    np.testing.assert_array_equal(q, a // 2)
    np.testing.assert_array_equal(r, a % 2)


def test_pum_api_does_not_warn():
    dev = pum.device(width=16, fuse=True)
    a = np.array([9, 5], np.uint64)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        x = dev.asarray(a)
        _ = np.asarray((x + a) * a // (x ^ 3) % (x | 1))
        _ = np.asarray(x.popcount())


# --------------------------------------------------------------------- #
# Leaf-buffer donation
# --------------------------------------------------------------------- #


@pytest.mark.fused
def test_donate_leaves_is_bit_exact():
    rng = np.random.default_rng(5)
    n = 4096 + 17
    a = rng.integers(0, 1 << 16, n, dtype=np.uint64)
    b = rng.integers(0, 1 << 16, n, dtype=np.uint64)
    plain = pum.device(width=16, fuse=True)
    donating = pum.device(width=16, fuse=True, donate_leaves=True)
    assert donating.engine.donate_leaves

    def prog(dev):
        x, y = dev.asarray(a), dev.asarray(b)
        t = (x + y) * x
        q, r = divmod(t, y)
        return [np.asarray(v, np.uint64) for v in (t, q, r, t ^ y)]

    for w, g in zip(prog(plain), prog(donating)):
        np.testing.assert_array_equal(w, g)
    assert plain.stats == donating.stats
    # operand snapshots live on the host: caller buffers are untouched
    assert a.max() < 1 << 16 and b.max() < 1 << 16
    # and a second flush through the same donated pipeline still works
    for w, g in zip(prog(plain), prog(donating)):
        np.testing.assert_array_equal(w, g)


# --------------------------------------------------------------------- #
# Shared-divider divmod lowering
# --------------------------------------------------------------------- #


def test_divmod_charges_one_division_pass():
    a = np.array([100, 37], np.uint64)
    b = np.array([7, 5], np.uint64)
    one = pum.device(width=16, fuse=False)
    _ = divmod(one.asarray(a), b)
    single = pum.device(width=16, fuse=False)
    _ = single.asarray(a) // b
    assert one.stats == single.stats  # divmod == ONE div charge


def test_div_and_mod_cse_into_one_divider_pass():
    """`a // b` and `a % b` of the same operands lower to two divmod
    records that optimize_program unifies: the compiled pipeline runs ONE
    restoring division."""
    dev = pum.device(width=16, fuse=True)
    a = np.array([100, 37, 8], np.uint64)
    b = np.array([7, 0, 3], np.uint64)
    x = dev.asarray(a)
    q = x // b
    r = x % b
    g = dev.engine._graph
    assert [op for op, _, _ in g.ops].count("divmod") == 2
    # mirror the engine's flush-time normalization and count dividers
    from repro.core.engine import FusedOp, FusedProgram
    n_leaves = len(g.leaves)
    program = FusedProgram(
        width=g.width, n_inputs=n_leaves,
        ops=tuple(FusedOp(op, tuple(
            t[1] if t[0] == "leaf" else n_leaves + t[1] for t in args),
            param) for op, args, param in g.ops),
        outputs=(n_leaves + 1, n_leaves + 3))  # the two selector results
    opt, _, _ = optimize_program(program)
    assert [op.opcode for op in opt.ops].count("divmod") == 1
    np.testing.assert_array_equal(np.asarray(q),
                                  np.array([14, 0, 2], np.uint64))
    np.testing.assert_array_equal(np.asarray(r),
                                  np.array([2, 0, 2], np.uint64))
