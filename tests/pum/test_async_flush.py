"""Concurrent-client dataplane: async flush, client contexts, capture.

The correctness contract:

* N client streams recording into one device produce results bit-exact
  to the same programs flushed serially, and ``EngineStats`` totals are
  identical under any arbitration/flush order (per-client stats shards
  merge in a deterministic order);
* ``Device.flush_async`` returns a future-like handle; a failed flush
  parks the graph for retry exactly like the synchronous path, and the
  restoration never interleaves with another client's in-flight
  recording;
* ``Device.capture`` replays a compiled program with zero re-recording
  and a bit-identical cost plane.

The thread stress tests carry the ``concurrent`` marker and run in CI
under a wall-clock timeout; the ``thread_guard`` fixture fails any test
that leaks live worker threads.
"""

import threading
import time

import numpy as np
import pytest

import repro.pum as pum
from repro.kernels import fused_program

pytestmark = pytest.mark.fused


@pytest.fixture(scope="module", autouse=True)
def _pipeline_cache_hygiene():
    # The random programs here compile hundreds of unique structures;
    # clear the shared pipeline LRU afterwards so later suites don't
    # run against a saturated cache.
    yield
    fused_program._cached_pipeline.cache_clear()

_BINOPS = [
    ("add", lambda x, y: x + y),
    ("and", lambda x, y: x & y),
    ("or", lambda x, y: x | y),
    ("xor", lambda x, y: x ^ y),
    ("mul", lambda x, y: x * y),
    ("sub", lambda x, y: x - y),
]


def _mask(width):
    return np.uint64(2**width - 1) if width < 64 else np.uint64(2**64 - 1)


def random_program(rng, width, n=64, depth=4):
    """A random op chain and its numpy reference, masked to the width."""
    mask = _mask(width)
    arrays = [rng.integers(0, int(mask) + 1, n, dtype=np.uint64) & mask
              for _ in range(3)]
    picks = [int(rng.integers(len(_BINOPS))) for _ in range(depth)]
    operand = [int(rng.integers(len(arrays))) for _ in range(depth)]

    def run(asarray):
        acc = asarray(arrays[0])
        for p, i in zip(picks, operand):
            acc = _BINOPS[p][1](acc, asarray(arrays[i]))
        return acc

    want = run(lambda a: a)
    want = np.asarray(want, dtype=np.uint64) & mask
    return run, want


@pytest.fixture
def thread_guard():
    """Fail the test if it leaks live threads (and act as a cheap
    timeout backstop: a deadlocked worker shows up as a leak)."""
    before = set(threading.enumerate())
    yield
    deadline = time.monotonic() + 15.0
    while time.monotonic() < deadline:
        leaked = [t for t in threading.enumerate()
                  if t not in before and t.is_alive()]
        if not leaked:
            return
        time.sleep(0.05)
    pytest.fail(f"leaked threads: {[t.name for t in leaked]}")


# --------------------------------------------------------------------- #
# flush_async: handle semantics
# --------------------------------------------------------------------- #

def test_flush_async_result_matches_sync(thread_guard):
    with pum.device(width=32, fuse=True) as dev:
        a = np.arange(256, dtype=np.uint64)
        t = dev.asarray(a) + a
        h = dev.flush_async()
        assert isinstance(h, pum.FlushHandle)
        assert h.exception() is None
        np.testing.assert_array_equal(
            t.to_numpy(), (2 * a) & np.uint64(0xFFFFFFFF))
        assert h.done()


def test_flush_async_empty_graph_is_done_noop(thread_guard):
    with pum.device(width=32, fuse=True) as dev:
        h = dev.flush_async()
        assert h.done() and h.result() is None


def test_flush_async_double_buffered_back_to_back(thread_guard):
    """Two async flushes in flight at once (the staging double buffer);
    both materialize correctly."""
    with pum.device(width=32, fuse=True) as dev:
        a = np.arange(128, dtype=np.uint64)
        outs, handles = [], []
        for k in range(4):
            outs.append(dev.asarray(a) + np.uint64(k))
            handles.append(dev.flush_async())
        for h in handles:
            h.result(timeout=30)
        for k, t in enumerate(outs):
            np.testing.assert_array_equal(t.to_numpy(), a + np.uint64(k))


def test_materialize_waits_for_inflight_async(thread_guard):
    with pum.device(width=32, fuse=True) as dev:
        a = np.arange(64, dtype=np.uint64)
        t = dev.asarray(a) ^ a
        dev.flush_async()
        np.testing.assert_array_equal(t.to_numpy(), np.zeros_like(a))


def test_flush_async_latency_off_caller_thread(thread_guard):
    """The handle resolves on the worker: the caller observes completion
    without invoking any flush machinery itself."""
    with pum.device(width=32, fuse=True) as dev:
        a = np.arange(4096, dtype=np.uint64)
        t = dev.asarray(a) * a
        h = dev.flush_async()
        deadline = time.monotonic() + 30.0
        while not h.done() and time.monotonic() < deadline:
            time.sleep(0.001)
        assert h.done()
        # already materialized by the worker — no graph left to flush
        np.testing.assert_array_equal(
            t.to_numpy(), (a * a) & np.uint64(0xFFFFFFFF))


# --------------------------------------------------------------------- #
# failure parks the graph; retry recovers — sync, async, and under
# concurrent recording (the exception-safety small fix)
# --------------------------------------------------------------------- #

def _boom(*a, **kw):
    raise RuntimeError("transient backend failure")


def test_failed_async_flush_parks_graph_for_retry(monkeypatch,
                                                  thread_guard):
    from repro.core import engine as engine_mod
    dev = pum.device(width=32, fuse=True)
    a = np.arange(64, dtype=np.uint64)
    t = dev.asarray(a) + a
    real = engine_mod.get_pipeline
    monkeypatch.setattr(engine_mod, "get_pipeline", _boom)
    h = dev.flush_async()
    with pytest.raises(RuntimeError, match="transient"):
        h.result(timeout=30)
    assert isinstance(h.exception(timeout=30), RuntimeError)
    monkeypatch.setattr(engine_mod, "get_pipeline", real)
    np.testing.assert_array_equal(t.to_numpy(), 2 * a)   # retried
    dev.close()


def test_failed_flush_restore_is_isolated_from_other_clients(
        monkeypatch, thread_guard):
    """The small-fix regression: while client A's flush fails and parks
    its graph, client B records and flushes concurrently; B's stream is
    unaffected and A's graph retries cleanly afterwards."""
    from repro.core import engine as engine_mod
    dev = pum.device(width=32, fuse=True)
    a = np.arange(64, dtype=np.uint64)

    with dev.client("A"):
        ta = dev.asarray(a) + a
    real = engine_mod.get_pipeline
    monkeypatch.setattr(engine_mod, "get_pipeline", _boom)
    with dev.client("A"):
        with pytest.raises(RuntimeError, match="transient"):
            dev.flush()

    errors = []

    def b_stream():
        try:
            with dev.client("B"):
                for k in range(20):
                    t = dev.asarray(a) ^ np.uint64(k)
                    dev.flush()
                    np.testing.assert_array_equal(
                        t.to_numpy(), a ^ np.uint64(k))
        except Exception as exc:                # pragma: no cover
            errors.append(exc)

    monkeypatch.setattr(engine_mod, "get_pipeline", real)
    th = threading.Thread(target=b_stream)
    th.start()
    # A's parked graph retries while B records on another thread
    np.testing.assert_array_equal(ta.to_numpy(), 2 * a)
    th.join(timeout=30)
    assert not th.is_alive() and not errors
    dev.close()


# --------------------------------------------------------------------- #
# N client streams: bit-exact + stats-identical vs serial
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("seed", range(4))
def test_interleaved_clients_bit_exact_and_stats_equal_serial(seed):
    """Property test: the same client streams produce bit-identical
    results and bit-identical EngineStats whether recorded serially or
    interleaved in a seeded arbitrary order."""
    rng = np.random.default_rng(seed)
    n_clients = int(rng.integers(2, 6))
    streams = [[random_program(rng, 32) for _ in range(3)]
               for _ in range(n_clients)]

    # serial: one client at a time, flushed in submission order
    serial = pum.device(width=32, fuse=True)
    serial_out = []
    for ci, progs in enumerate(streams):
        with serial.client(f"c{ci}"):
            outs = [run(serial.asarray) for run, _ in progs]
            serial.flush()
            serial_out.append([o.to_numpy() for o in outs])

    # interleaved: a seeded arbitrary interleaving across clients (each
    # client's own stream stays FIFO — that is the arbitration model),
    # flushed in another seeded order
    inter = pum.device(width=32, fuse=True)
    remaining = [list(range(3)) for _ in range(n_clients)]
    order = []
    while any(remaining):
        ci = int(rng.integers(n_clients))
        if remaining[ci]:
            order.append((ci, remaining[ci].pop(0)))
    handles = {}
    for ci, pi in order:
        with inter.client(f"c{ci}"):
            handles[(ci, pi)] = streams[ci][pi][0](inter.asarray)
    flush_order = list(range(n_clients))
    rng.shuffle(flush_order)
    for ci in flush_order:
        with inter.client(f"c{ci}"):
            inter.flush()

    for ci, progs in enumerate(streams):
        for pi, (_, want) in enumerate(progs):
            np.testing.assert_array_equal(handles[(ci, pi)].to_numpy(),
                                          want)
            np.testing.assert_array_equal(serial_out[ci][pi], want)
    assert inter.stats == serial.stats
    assert inter.stats.latency_ns > 0
    serial.close()
    inter.close()


def test_single_context_stats_bit_identical_to_legacy():
    """One implicit context == the pre-concurrency engine: merging a
    single stats shard must not perturb a single float."""
    a = np.arange(512, dtype=np.uint64)
    d1 = pum.device(width=32, fuse=True)
    r1 = (d1.asarray(a) + a) * a
    r1.to_numpy()
    d2 = pum.device(width=32, fuse=True)
    r2 = (d2.asarray(a) + a) * a
    r2.to_numpy()
    assert d1.stats == d2.stats
    d1.close()
    d2.close()


# --------------------------------------------------------------------- #
# capture: zero re-recording, cost-plane invariance
# --------------------------------------------------------------------- #

def test_capture_replays_without_rerecording():
    with pum.device(width=32, fuse=True) as dev:
        prog = dev.capture(lambda x, y: (x + y) * x)
        a = np.arange(64, dtype=np.uint64)
        for k in range(5):
            got = prog(a + np.uint64(k), a)
            want = ((2 * a + np.uint64(k)) * (a + np.uint64(k))) \
                & np.uint64(0xFFFFFFFF)
            np.testing.assert_array_equal(got, want)
        assert prog.n_records == 1 and prog.n_replays == 4


def test_capture_stats_match_uncaptured_recording():
    a = np.arange(128, dtype=np.uint64)
    b = a[::-1].copy()
    cap = pum.device(width=32, fuse=True)
    prog = cap.capture(lambda x, y: (x ^ y) + (x & y))
    for _ in range(3):
        prog(a, b)
    raw = pum.device(width=32, fuse=True)
    for _ in range(3):
        x, y = raw.asarray(a), raw.asarray(b)
        r = (x ^ y) + (x & y)
        r.to_numpy()
    assert cap.stats == raw.stats
    cap.close()
    raw.close()


def test_capture_new_shape_rerecords():
    with pum.device(width=32, fuse=True) as dev:
        prog = dev.capture(lambda x: x + x)
        prog(np.arange(64, dtype=np.uint64))
        prog(np.arange(32, dtype=np.uint64))
        assert prog.n_records == 2
        prog(np.arange(64, dtype=np.uint64))
        assert prog.n_records == 2 and prog.n_replays == 1


def test_capture_requires_fused_device():
    with pum.device(width=32, fuse=False) as dev:
        with pytest.raises(ValueError, match="fused"):
            dev.capture(lambda x: x + x)


def test_capture_call_async(thread_guard):
    with pum.device(width=32, fuse=True) as dev:
        prog = dev.capture(lambda x: x * x)
        a = np.arange(64, dtype=np.uint64)
        h0 = prog.call_async(a)           # new shape: records, done handle
        assert h0.done()
        h1 = prog.call_async(a + np.uint64(1))
        np.testing.assert_array_equal(h0.result(), a * a)
        np.testing.assert_array_equal(
            h1.result(timeout=30),
            ((a + np.uint64(1)) ** 2) & np.uint64(0xFFFFFFFF))
        assert prog.n_records == 1 and prog.n_replays >= 1


# --------------------------------------------------------------------- #
# thread stress: 8 clients on one shared device, widths 8/32/64
# --------------------------------------------------------------------- #

@pytest.mark.concurrent
@pytest.mark.parametrize("width", [8, 32, 64])
def test_eight_client_thread_stress(width, thread_guard):
    """8 threads share one device, each recording random op programs and
    flushing (sync or async at random); every stream's results must be
    bit-exact to its numpy reference, with no cross-talk, no deadlock,
    no leaked threads."""
    dev = pum.device(width=width, fuse=True)
    n_threads, n_iter = 8, 6
    errors: list = []
    barrier = threading.Barrier(n_threads)

    def worker(tid):
        try:
            rng = np.random.default_rng(1000 * width + tid)
            barrier.wait(timeout=30)
            for it in range(n_iter):
                run, want = random_program(rng, width, n=32 + 8 * tid)
                out = run(dev.asarray)
                if rng.random() < 0.5:
                    h = dev.flush_async()
                    h.result(timeout=60)
                np.testing.assert_array_equal(out.to_numpy(), want,
                                              err_msg=f"t{tid} it{it}")
        except Exception as exc:
            errors.append((tid, exc))

    threads = [threading.Thread(target=worker, args=(i,),
                                name=f"stress-{i}")
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    alive = [t.name for t in threads if t.is_alive()]
    assert not alive, f"stress threads hung: {alive}"
    assert not errors, errors[:3]
    assert dev.stats.latency_ns > 0
    dev.close()


@pytest.mark.concurrent
def test_thread_stress_stats_deterministic():
    """The merged stats total is independent of thread scheduling: two
    stress runs with the same per-thread streams land on identical
    EngineStats (per-thread shards merge in deterministic order, and
    client-named shards make the totals reproducible across runs)."""
    def run_once():
        dev = pum.device(width=32, fuse=True)
        threads = []

        def worker(tid):
            rng = np.random.default_rng(tid)
            with dev.client(f"w{tid}"):
                for _ in range(4):
                    run, want = random_program(rng, 32)
                    out = run(dev.asarray)
                    np.testing.assert_array_equal(out.to_numpy(), want)

        for i in range(6):
            t = threading.Thread(target=worker, args=(i,))
            threads.append(t)
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not any(t.is_alive() for t in threads)
        stats = dev.stats
        dev.close()
        return stats

    assert run_once() == run_once()
